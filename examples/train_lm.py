"""End-to-end training driver: a ~100M-parameter qwen2-family model trained
for a few hundred steps on synthetic data, with checkpoint/restart fault
tolerance and explicit ABI gradient sync.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The harness CPU budget: ~100M params, batch 8 x seq 128.  On TPU, drop
--smoke-dims and use the full assigned config via launch/train.py.)
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import repro.configs as cfgs
from repro.configs.base import ModelConfig, ParallelismConfig
from repro.launch import train as train_cli


def hundred_m_config() -> ModelConfig:
    """~100M params: 8L x d512 x ffn2048, 50k vocab (qwen2 family shape)."""
    base = cfgs.get_config("qwen2-0.5b")
    return dataclasses.replace(
        base,
        name="qwen2-100m",
        num_layers=12, d_model=512, d_ff=2048, vocab_size=50048,
        num_heads=8, num_kv_heads=4, head_dim=64,
        tie_embeddings=False,   # ~98M params
        max_seq_len=512, param_dtype="float32", compute_dtype="float32",
        parallelism=ParallelismConfig(microbatch=0, remat="none", grad_sync="abi"),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    # register the 100M config under the qwen2-0.5b CLI slot
    cfg = hundred_m_config()
    cfgs._REGISTRY[cfg.name] = cfg
    orig_names = cfgs.ARCH_NAMES
    cfgs.ARCH_NAMES = orig_names + (cfg.name,)
    report = train_cli.main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--global-batch", str(args.global_batch), "--seq-len", str(args.seq_len),
        "--lr", "6e-4", "--warmup", "30", "--ckpt-dir", "/tmp/repro_100m_ckpt",
        "--ckpt-every", "100", "--log-every", "20",
    ])
    assert report.losses[-1] < report.losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
