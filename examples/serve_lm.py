"""Serving example: batched generation with continuous KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_cli

if __name__ == "__main__":
    reqs = serve_cli.main(["--arch", "qwen2-0.5b", "--smoke",
                           "--batch", "4", "--prompt-len", "12",
                           "--new-tokens", "12"])
    assert all(len(r.out_tokens) == 12 for r in reqs)
