"""Quickstart: the PAX ABI in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. initialize the ABI (pick an implementation — the paper's point is that
   this choice never touches your code);
2. make communicators, query handles, run collectives inside shard_map;
3. register a user-defined reduction (the callback surface);
4. stack a profiling tool (PMPI-style) and read its byte ledger.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as C
from repro.core.compat import make_mesh

mesh = make_mesh((1, 1), ("data", "model"))

# --- 1. init with tools stacked (works identically for any impl) -----------
counter = C.ByteCounter()
abi = C.pax_init(mesh, impl="paxi", tools=[counter])
print("implementation:", abi.backend.name, "| available:", C.available_backends())

# --- 2. handles: bit-encoded metadata (paper §5.4 / A.3) --------------------
print("PAX_FLOAT32 =", bin(C.PAX_FLOAT32), "-> size", abi.type_size(C.PAX_FLOAT32))
print("PAX_BFLOAT16 =", bin(C.PAX_BFLOAT16), "-> size", abi.type_size(C.PAX_BFLOAT16))
print("describe(PAX_SUM) =", C.describe(C.PAX_SUM))

# --- 3. collectives over mesh-axis communicators ----------------------------
dp = abi.comm_from_axes(("data",), "dp")

def program(x):
    y = abi.allreduce(x * 2, C.PAX_SUM, dp)
    z = abi.allgather(x, dp)
    return y, z

f = abi.shard_region(program, in_specs=P(), out_specs=(P(), P()))
y, z = jax.jit(f)(jnp.arange(4.0))
print("allreduce:", np.asarray(y), "| allgather:", np.asarray(z))

# --- 4. user-defined op (callback through the ABI) --------------------------
l2 = abi.op_create(lambda a, b: jnp.sqrt(a * a + b * b), name="l2")
g = abi.shard_region(lambda x: abi.allreduce(x, l2, dp), in_specs=P(), out_specs=P())
print("user op result:", np.asarray(jax.jit(g)(jnp.ones(3) * 3)))

# --- 5. the tool saw every call ---------------------------------------------
print("tool ledger:", dict(counter.bytes), "total bytes:", counter.total())
