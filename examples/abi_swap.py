"""The paper's headline property, demonstrated end-to-end: the SAME traced
training program runs on every ABI implementation — native, algorithmic
(ring), compressed-wire, and foreign-through-Mukautuva — with no user-code
changes, and the native path adds zero equations to the jaxpr.

    PYTHONPATH=src python examples/abi_swap.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as cfgs
import repro.core as C
from repro.core.compat import make_mesh
from repro.models import build_model, make_batch
from repro.optim.adamw import AdamWConfig
from repro.runtime.dist import make_dist
from repro.train import train_loop

mesh = make_mesh((1, 1), ("data", "model"))
cfg = cfgs.smoke_config("chatglm3-6b")
api = build_model(cfg)
key = jax.random.PRNGKey(0)
batch = make_batch(key, cfg, 2, 16)

losses = {}
for impl in ("paxi", "ring", "ring-bf16", "ompix", "muk:paxi", "minimal"):
    dist = make_dist(mesh, impl=impl)
    state = train_loop.init_state(api, key)              # same init
    step = jax.jit(train_loop.make_train_step(api, dist, AdamWConfig()))
    for _ in range(3):
        state, m = step(state, batch)
    losses[impl] = float(m.loss)
    print(f"{impl:10s} loss after 3 steps: {losses[impl]:.6f}")

ref = losses["paxi"]
for impl, l in losses.items():
    tol = 5e-3 if "bf16" in impl else 1e-5
    assert abs(l - ref) <= tol * max(abs(ref), 1), (impl, l, ref)
print("\nall implementations agree — the ABI is the contract, "
      "the backend is a deployment choice (paper, Conclusions).")
