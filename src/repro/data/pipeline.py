"""Sharded data pipeline: per-host token streams with background prefetch.

Production shape: each host reads only its shard of the global batch
(``host_shard``), a background thread keeps a bounded prefetch queue ahead
of the training loop (straggler absorption), and documents are packed into
fixed-length sequences with -1 padding targets (masked in the loss).

Sources: synthetic LM streams (seeded, reproducible) and memory-mapped
token files (.bin of uint16/uint32).
"""
from __future__ import annotations

import queue
import threading
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


class TokenSource:
    """Abstract token-document source."""

    def documents(self, start_doc: int) -> Iterator[np.ndarray]:
        raise NotImplementedError


class SyntheticSource(TokenSource):
    """Reproducible synthetic documents (zipf-ish unigram)."""

    def __init__(self, vocab_size: int, seed: int = 0,
                 mean_len: int = 512) -> None:
        self.vocab = vocab_size
        self.seed = seed
        self.mean_len = mean_len

    def documents(self, start_doc: int) -> Iterator[np.ndarray]:
        i = start_doc
        while True:
            rng = np.random.default_rng((self.seed, i))
            n = int(rng.integers(self.mean_len // 2, self.mean_len * 2))
            ranks = rng.zipf(1.3, size=n).astype(np.int64)
            yield (ranks % self.vocab).astype(np.int32)
            i += 1


class FileSource(TokenSource):
    """Memory-mapped flat token file, split into pseudo-documents."""

    def __init__(self, path: str | Path, dtype=np.uint16, doc_len: int = 2048) -> None:
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.doc_len = doc_len

    def documents(self, start_doc: int) -> Iterator[np.ndarray]:
        n_docs = len(self.tokens) // self.doc_len
        i = start_doc
        while True:
            j = i % max(n_docs, 1)
            yield np.asarray(
                self.tokens[j * self.doc_len:(j + 1) * self.doc_len], dtype=np.int32)
            i += 1


def pack_documents(docs: Iterator[np.ndarray], batch: int, seq_len: int,
                   pad_id: int = 0) -> Iterator[dict]:
    """Greedy sequence packing; targets are next-token with -1 on pad."""
    buf = np.full((batch, seq_len + 1), pad_id, np.int32)
    mask = np.zeros((batch, seq_len + 1), bool)
    row, col = 0, 0
    for doc in docs:
        off = 0
        while off < len(doc):
            take = min(seq_len + 1 - col, len(doc) - off)
            buf[row, col:col + take] = doc[off:off + take]
            mask[row, col:col + take] = True
            col += take
            off += take
            if col >= seq_len + 1:
                row += 1
                col = 0
                if row == batch:
                    tokens = buf[:, :-1].copy()
                    targets = np.where(mask[:, 1:], buf[:, 1:], -1).astype(np.int32)
                    yield {"tokens": tokens, "targets": targets}
                    buf[:] = pad_id
                    mask[:] = False
                    row = 0


class DataPipeline:
    """Host-sharded, prefetched batch stream.

    ``host_id``/``num_hosts`` split the GLOBAL batch; each host materializes
    only its rows.  ``prefetch`` bounds the background queue (absorbs input
    stalls — the straggler-mitigation surface at the data layer).
    """

    def __init__(self, source: TokenSource, *, global_batch: int, seq_len: int,
                 host_id: int = 0, num_hosts: int = 1, prefetch: int = 4,
                 start_step: int = 0) -> None:
        assert global_batch % num_hosts == 0
        self.local_batch = global_batch // num_hosts
        self.seq_len = seq_len
        self.host_id = host_id
        self.num_hosts = num_hosts
        # deterministic disjoint document streams per host
        start_doc = start_step * global_batch + host_id * 1_000_000_007
        self._packed = pack_documents(
            source.documents(start_doc), self.local_batch, seq_len)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        try:
            for batch in self._packed:
                if self._stop.is_set():
                    return
                self._q.put(batch)
        except Exception as e:  # pragma: no cover
            self._q.put(e)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self) -> None:
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
