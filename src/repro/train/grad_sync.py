"""Data-parallel gradient synchronization through the PAX ABI.

This is where the paper's ABI carries the framework's heaviest traffic.
Modes (config ``parallelism.grad_sync``):

* ``abi`` — explicit ZeRO-1: the flat gradient vector is **reduce-scattered**
  over the dp communicator (each rank keeps 1/dp), the optimizer updates its
  shard, and the updated shard is **all-gathered** back.  Collective bytes:
  2x the parameter bytes per step (vs 2x for plain all-reduce but with 1/dp
  optimizer memory).  Options:
    - bucketing: the vector is split into N buckets issued as nonblocking
      ``ireduce_scatter`` requests (XLA's latency-hiding scheduler can
      overlap them with the optimizer math of earlier buckets);
    - compression: ``bf16`` casts the wire payload (+error feedback);
      ``int8`` routes through a ring backend that quantizes per hop.
* ``gspmd`` — implicit: gradients/optimizer state are sharded by XLA via
  in_shardings; no explicit collectives (used by the 300B-class archs whose
  parameters are FSDP-sharded over dp).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import PAX_SUM
from ..optim.adamw import flatten, unflatten_like
from ..runtime.dist import DistContext, dp_comm_of


def pad_to(vec, multiple: int):
    pad = (-vec.shape[0]) % multiple
    if pad:
        vec = jnp.concatenate([vec, jnp.zeros((pad,), vec.dtype)])
    return vec


def _transposed_bucket_parts(wire, dp: int, buckets: int) -> list:
    """The transposed bucket split (one definition for the pooled and
    persistent paths): bucket b carries every rank's b-th sub-slice, so each
    rank's concatenated reduce-scatter results form its *contiguous* slice
    of the full vector — the layout `_interleave_bucket_gathers` inverts and
    the slice an unbucketed reduce-scatter would deliver."""
    blocks = wire.reshape(dp, buckets, -1)
    return [blocks[:, b, :].reshape(-1) for b in range(buckets)]


def _interleave_bucket_gathers(outs, dp: int, rest: tuple = ()):
    """Inverse of the transposed split: outs[b] is rank-major over bucket b;
    re-interleave to one rank-major full vector (trailing dims preserved)."""
    chunks = [o.reshape((dp, -1) + rest) for o in outs]
    return jnp.concatenate(chunks, axis=1).reshape((-1,) + rest)


# ---------------------------------------------------------------------------
# Persistent plans for the zero1 round trip (MPI-4 <name>_init).  The
# bucketed reduce-scatter/all-gather a training loop issues is *identical*
# every step — same shapes, same comm, same op — which is exactly the shape
# persistent collectives amortize: the plans are built once (init_state) and
# every step's start() is a bare closure call into the backend.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Zero1Plans:
    """Per-bucket persistent plans for one zero1 layout.

    ``rs`` lives on the wire context (the compressed ring context for int8),
    ``ag`` on the primary context; both are keyed by the layout contract
    (padded length, dp, bucket count, wire dtype AND compression mode — the
    mode picks the wire *context*, which the dtype alone cannot distinguish:
    ``None`` and ``"int8"`` both ship f32) so callers can verify the plans
    match the sync they are about to run and fall back otherwise.
    """

    dp: int
    buckets: int
    padded: int
    wire_dtype: object
    compression: Optional[str]
    rs: tuple    # bucket -> reduce_scatter Plan (wire context)
    ag: tuple    # bucket -> allgather Plan (primary context)

    def matches(self, n: int, dp: int, buckets: int, wire_dtype,
                compression: Optional[str]) -> bool:
        return (self.padded == n and self.dp == dp
                and self.buckets == max(buckets, 1)
                and self.compression == compression
                and jnp.dtype(self.wire_dtype) == jnp.dtype(wire_dtype))

    def free(self) -> None:
        """Retire every plan's request slot (rebuild/teardown path)."""
        for p in self.rs + self.ag:
            p.free()


def build_zero1_plans(dist: DistContext, padded: int, buckets: int = 1,
                      compression: Optional[str] = None) -> Zero1Plans:
    """Build the per-bucket persistent plans for a (padded, buckets) layout.

    Payloads are bound abstractly (shape/dtype): each reduce-scatter bucket
    carries ``padded / buckets`` wire elements, each all-gather bucket this
    rank's ``padded / (dp * buckets)`` updated shard slice.
    """
    dp = dist.dp_size
    b = max(buckets, 1)
    assert padded % (dp * b) == 0, (padded, dp, b)
    wire_dtype = jnp.bfloat16 if compression == "bf16" else jnp.float32
    abi_w, comm = dp_comm_of(dist, compression == "int8")
    blen = padded // b
    ex_rs = jax.ShapeDtypeStruct((blen,), wire_dtype)
    ex_ag = jax.ShapeDtypeStruct((blen // dp,), jnp.float32)
    rs = tuple(abi_w.reduce_scatter_init(ex_rs, PAX_SUM, comm)
               for _ in range(b))
    ag = tuple(dist.abi.allgather_init(ex_ag, dist.dp_comm) for _ in range(b))
    return Zero1Plans(dp, b, padded, wire_dtype, compression, rs, ag)


def reduce_scatter_grads(
    dist: DistContext,
    flat_g: jax.Array,
    *,
    compression: Optional[str] = None,
    buckets: int = 1,
    ef: Optional[jax.Array] = None,
    plans: Optional[Zero1Plans] = None,
):
    """flat_g: (padded_n,) f32, padded_n % dp_size == 0.
    Returns (g_shard (padded_n/dp,), new_ef).  Mean over dp ranks.

    With ``plans`` matching the layout, the bucketed round trip rides the
    persistent reduce-scatter plans (start on restartable pooled requests)
    instead of re-dispatching ``ireduce_scatter`` per bucket per step."""
    dp = dist.dp_size
    n = flat_g.shape[0]
    assert n % dp == 0
    if ef is not None and ef.shape[0] == n:
        flat_g = flat_g + ef
    wire = flat_g
    new_ef = ef
    if compression == "bf16":
        wire16 = flat_g.astype(jnp.bfloat16)
        if ef is not None and ef.shape[0] == n:
            new_ef = flat_g - wire16.astype(jnp.float32)
        wire = wire16
    abi, comm = dp_comm_of(dist, compression == "int8")

    if plans is not None and plans.matches(n, dp, buckets, wire.dtype,
                                           compression):
        # persistent path: one start per bucket plan on the restartable
        # slots, waitall through the shared pool API
        parts = _transposed_bucket_parts(wire, dp, plans.buckets)
        reqs = [plans.rs[b].start(p) for b, p in enumerate(parts)]
        shard = jnp.concatenate(abi.waitall(reqs))
    elif buckets <= 1:
        shard = abi.reduce_scatter(wire, PAX_SUM, comm)
    else:
        assert n % (dp * buckets) == 0, "bucket count must divide the shard"
        parts = _transposed_bucket_parts(wire, dp, buckets)
        reqs = [abi.ireduce_scatter(p, PAX_SUM, comm) for p in parts]
        shards = abi.waitall(reqs)
        shard = jnp.concatenate(shards)
    shard = shard.astype(jnp.float32) / dp
    return shard, new_ef


def allgather_params(dist: DistContext, shard: jax.Array, *, buckets: int = 1,
                     plans: Optional[Zero1Plans] = None) -> jax.Array:
    """Inverse of the scatter: collect every rank's updated shard.

    With ``buckets > 1`` the shard is split and issued as nonblocking
    ``iallgather`` requests (the spec-generated path), so the scheduler can
    overlap the gather of early buckets with whatever consumes them; the
    bucket-major chunks are re-interleaved into rank-major order.  With
    matching ``plans``, each bucket rides its persistent all-gather plan."""
    abi = dist.abi
    use_plans = (plans is not None
                 and plans.dp == dist.dp_size
                 and plans.padded == shard.shape[0] * plans.dp
                 and plans.buckets == max(buckets, 1)
                 and shard.ndim == 1)
    if use_plans:
        parts = (jnp.split(shard, plans.buckets) if plans.buckets > 1
                 else [shard])
        outs = abi.waitall([plans.ag[b].start(p.astype(jnp.float32))
                            for b, p in enumerate(parts)])
        if plans.buckets == 1:
            return outs[0].astype(jnp.float32)
        return _interleave_bucket_gathers(outs, dist.dp_size).astype(jnp.float32)
    if buckets <= 1:
        return abi.allgather(shard, dist.dp_comm).astype(jnp.float32)
    assert shard.shape[0] % buckets == 0, "bucket count must divide the shard"
    parts = jnp.split(shard, buckets)
    reqs = [abi.iallgather(p, dist.dp_comm) for p in parts]
    outs = abi.waitall(reqs)
    full = _interleave_bucket_gathers(outs, dist.dp_size, shard.shape[1:])
    return full.astype(jnp.float32)


def zero1_step(
    dist: DistContext,
    flat_g: jax.Array,
    update_shard,
    *,
    buckets: int = 1,
    compression: Optional[str] = None,
    ef: Optional[jax.Array] = None,
    plans: Optional[Zero1Plans] = None,
):
    """One explicit ZeRO-1 round trip through the generated ABI surface:
    bucketed nonblocking reduce-scatter -> per-shard optimizer update
    (``update_shard(g_shard) -> p_shard``) -> bucketed nonblocking
    all-gather of the updated shard.  Returns (params_full, new_ef).

    The ABI's free-list request pool recycles the bucket requests in place,
    so a steady-state training loop reuses one preallocated request batch
    per step instead of allocating per bucket (train_loop's ``body_zero1``
    drives this every step).  With ``plans`` (built once by
    :func:`build_zero1_plans`), both legs ride persistent plans instead —
    the requests are the plans' restartable slots and even the per-bucket
    dispatch is plan-time work."""
    g_shard, new_ef = reduce_scatter_grads(
        dist, flat_g, compression=compression, buckets=buckets, ef=ef,
        plans=plans,
    )
    p_shard = update_shard(g_shard)
    return allgather_params(dist, p_shard, buckets=buckets, plans=plans), new_ef


def allreduce_scalar(dist: DistContext, x):
    """Mean of a scalar metric over the dp group (loss, grad-norm²)."""
    return dist.abi.allreduce(x, PAX_SUM, dist.dp_comm) / dist.dp_size


def sync_tree_allreduce(dist: DistContext, grads):
    """Plain all-reduce of a gradient pytree (non-ZeRO baseline path)."""
    flat = flatten(grads)
    summed = dist.abi.allreduce(flat, PAX_SUM, dist.dp_comm) / dist.dp_size
    return unflatten_like(summed, grads)
