"""Data-parallel gradient synchronization through the PAX ABI.

This is where the paper's ABI carries the framework's heaviest traffic.
Modes (config ``parallelism.grad_sync``):

* ``abi`` — explicit ZeRO-1: the flat gradient vector is **reduce-scattered**
  over the dp communicator (each rank keeps 1/dp), the optimizer updates its
  shard, and the updated shard is **all-gathered** back.  Collective bytes:
  2x the parameter bytes per step (vs 2x for plain all-reduce but with 1/dp
  optimizer memory).  Options:
    - bucketing: the vector is split into N buckets; with persistent plans
      (``Zero1Plans``) all buckets ride ONE Startall plan-group start/wait
      pair per leg (a single fused, backend-stacked collective), and the
      pooled nonblocking ``ireduce_scatter`` path remains the fallback
      (XLA's latency-hiding scheduler can overlap either with the
      optimizer math of earlier buckets);
    - compression: ``bf16`` casts the wire payload (+error feedback);
      ``int8`` routes through a ring backend that quantizes per hop.
* ``gspmd`` — implicit: gradients/optimizer state are sharded by XLA via
  in_shardings; no explicit collectives (used by the 300B-class archs whose
  parameters are FSDP-sharded over dp).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import PAX_SUM
from ..optim.adamw import flatten, unflatten_like
from ..runtime.dist import DistContext, dp_comm_of


def pad_to(vec, multiple: int):
    pad = (-vec.shape[0]) % multiple
    if pad:
        vec = jnp.concatenate([vec, jnp.zeros((pad,), vec.dtype)])
    return vec


def _transposed_bucket_parts(wire, dp: int, buckets: int) -> list:
    """The transposed bucket split (one definition for the pooled and
    persistent paths): bucket b carries every rank's b-th sub-slice, so each
    rank's concatenated reduce-scatter results form its *contiguous* slice
    of the full vector — the layout `_interleave_bucket_gathers` inverts and
    the slice an unbucketed reduce-scatter would deliver."""
    blocks = wire.reshape(dp, buckets, -1)
    return [blocks[:, b, :].reshape(-1) for b in range(buckets)]


def _interleave_bucket_gathers(outs, dp: int, rest: tuple = ()):
    """Inverse of the transposed split: outs[b] is rank-major over bucket b;
    re-interleave to one rank-major full vector (trailing dims preserved)."""
    chunks = [o.reshape((dp, -1) + rest) for o in outs]
    return jnp.concatenate(chunks, axis=1).reshape((-1,) + rest)


# ---------------------------------------------------------------------------
# Persistent plan groups for the zero1 round trip (MPI-4 <name>_init +
# MPI Startall).  The bucketed reduce-scatter/all-gather a training loop
# issues is *identical* every step — same shapes, same comm, same op — which
# is exactly the shape persistent collectives amortize: the plans are built
# once (init_state, idempotent via the ABI's layout-keyed plan cache) and
# every step drives ONE group.start()/group.wait() pair per leg instead of
# N per-bucket starts — one inactive-check, one fused (backend-stacked)
# collective, one completion scan.
# ---------------------------------------------------------------------------
def zero1_wire_dtype(compression: Optional[str]):
    """The dtype the reduce-scatter leg puts on the wire for a compression
    mode — one definition for plan building and layout matching."""
    return jnp.bfloat16 if compression == "bf16" else jnp.float32


@dataclasses.dataclass(frozen=True)
class Zero1Plans:
    """Per-bucket persistent plans + their Startall groups for one zero1
    layout.

    ``rs``/``rs_group`` live on the wire context (the compressed ring
    context for int8), ``ag``/``ag_group`` on the primary context; all are
    keyed by the layout contract (padded length, dp, bucket count, wire
    dtype AND compression mode — the mode picks the wire *context*, which
    the dtype alone cannot distinguish: ``None`` and ``"int8"`` both ship
    f32) so callers can verify the plans match the sync they are about to
    run and fall back otherwise.  Because ``<name>_init`` is layout-cached,
    the ``rs``/``ag`` tuples typically repeat ONE cached plan per leg; the
    groups bind one payload slot per bucket regardless.
    """

    dp: int
    buckets: int
    padded: int
    wire_dtype: object
    compression: Optional[str]
    rs: tuple          # bucket -> reduce_scatter Plan (wire context)
    ag: tuple          # bucket -> allgather Plan (primary context)
    rs_group: object   # PlanGroup fusing all rs buckets (one start/wait)
    ag_group: object   # PlanGroup fusing all ag buckets
    # fused flatten/bucket kernels, attached at build time when the
    # ring_wire Pallas pack is available for this layout/platform:
    # ``pack(flat_g, ef) -> (parts, new_ef)`` replaces the ef fold + wire
    # cast + `_transposed_bucket_parts` chain with one kernel pass;
    # ``unpack(outs) -> flat`` replaces `_interleave_bucket_gathers`.
    # None -> the lax pipeline below runs (the permanent fallback).
    pack: Optional[object] = None
    unpack: Optional[object] = None
    wire_kernel: str = "lax"   # observability: which pipeline pack/unpack use

    def matches(self, n: int, dp: int, buckets: int, wire_dtype,
                compression: Optional[str]) -> bool:
        return (self.padded == n and self.dp == dp
                and self.buckets == max(buckets, 1)
                and self.compression == compression
                and jnp.dtype(self.wire_dtype) == jnp.dtype(wire_dtype))

    def free(self) -> None:
        """Retire the groups' and every distinct plan's request slot
        (layout-change/teardown path; the plan cache is evicted too, so the
        next build re-plans from scratch)."""
        self.rs_group.free()
        self.ag_group.free()
        for p in {id(p): p for p in self.rs + self.ag}.values():
            p.free()


def build_zero1_plans(dist: DistContext, padded: int, buckets: int = 1,
                      compression: Optional[str] = None) -> Zero1Plans:
    """Build the per-bucket persistent plans + groups for a (padded,
    buckets) layout.

    Payloads are bound abstractly (shape/dtype): each reduce-scatter bucket
    carries ``padded / buckets`` wire elements, each all-gather bucket this
    rank's ``padded / (dp * buckets)`` updated shard slice.  The per-bucket
    ``<name>_init`` calls hit the ABI's layout-keyed plan cache (buckets
    share one layout), and the Startall groups bind one payload slot per
    bucket on top.
    """
    dp = dist.dp_size
    b = max(buckets, 1)
    assert padded % (dp * b) == 0, (padded, dp, b)
    wire_dtype = zero1_wire_dtype(compression)
    abi_w, comm = dp_comm_of(dist, compression == "int8")
    blen = padded // b
    ex_rs = jax.ShapeDtypeStruct((blen,), wire_dtype)
    ex_ag = jax.ShapeDtypeStruct((blen // dp,), jnp.float32)
    rs = tuple(abi_w.reduce_scatter_init(ex_rs, PAX_SUM, comm)
               for _ in range(b))
    ag = tuple(dist.abi.allgather_init(ex_ag, dist.dp_comm) for _ in range(b))
    rs_group = abi_w.plan_group(rs, name="zero1-rs")
    ag_group = dist.abi.plan_group(ag, name="zero1-ag")

    # Plan-time kernel selection (mirrors the backend plan hooks): attach
    # the fused flatten/bucket kernels iff the registry says Pallas can run
    # here and the layout divides cleanly; otherwise pack/unpack stay None
    # and callers run the identical lax pipeline.  No caller changes —
    # the choice is frozen into the plans object.
    pack = unpack = None
    wire_kernel = "lax"
    from ..kernels import kernel_mode
    if kernel_mode("ring_wire") == "pallas":
        from ..kernels.ring_wire import ops as wire_ops
        if wire_ops.pack_eligible(padded, dp, b):
            interp = wire_ops.interpret_on()
            wire_kernel = "pallas"

            def pack(flat_g, ef, _dp=dp, _b=b, _wd=wire_dtype,
                     _c=compression):
                fold = ef is not None and ef.shape[0] == flat_g.shape[0]
                if _c == "bf16" and fold:
                    # ef fold + bf16 cast + residual + bucket gather fused
                    return wire_ops.pack_parts_ef(flat_g, ef, _dp, _b,
                                                  interpret=interp)
                if fold:
                    flat_g = flat_g + ef
                return (wire_ops.pack_parts(flat_g, _dp, _b, _wd,
                                            interpret=interp), ef)

            def unpack(outs, _dp=dp):
                return wire_ops.unpack_gathers(outs, _dp, interpret=interp)

    return Zero1Plans(dp, b, padded, wire_dtype, compression, rs, ag,
                      rs_group, ag_group, pack, unpack, wire_kernel)


@dataclasses.dataclass
class PendingShard:
    """An in-flight reduce-scatter leg: issued by
    :func:`reduce_scatter_grads_start`, completed by
    :func:`reduce_scatter_grads_finish`.  Splitting issue from completion
    lets the caller put independent work (param flatten / rank slice — or,
    across jit steps, the next microbatch's backward) between the two, so
    XLA's latency-hiding scheduler can overlap the collective with it."""

    abi: object
    mode: str       # "group" | "pooled" | "value"
    pending: object  # group Request | list[Request] | the computed wire value
    dp: int


def reduce_scatter_grads_start(
    dist: DistContext,
    flat_g: jax.Array,
    *,
    compression: Optional[str] = None,
    buckets: int = 1,
    ef: Optional[jax.Array] = None,
    plans: Optional[Zero1Plans] = None,
):
    """Issue the reduce-scatter of ``flat_g`` ((padded_n,) f32, padded_n %
    dp_size == 0); returns ``(PendingShard, new_ef)``.

    With ``plans`` matching the layout, all buckets ride ONE
    ``rs_group.start()`` — a single inactive-check and a single fused
    (backend-stacked) collective on the restartable group slot — instead of
    per-bucket dispatch; otherwise the pooled nonblocking ``i*`` path (or
    the blocking single-bucket call) is used."""
    dp = dist.dp_size
    n = flat_g.shape[0]
    assert n % dp == 0
    abi, comm = dp_comm_of(dist, compression == "int8")

    if (plans is not None and plans.pack is not None
            and plans.matches(n, dp, buckets, zero1_wire_dtype(compression),
                              compression)):
        # fused path: ef fold + wire cast + transposed bucket gather in one
        # kernel pass (plan-time selection — see build_zero1_plans)
        parts, new_ef = plans.pack(flat_g, ef)
        return (PendingShard(abi, "group", plans.rs_group.start(parts), dp),
                new_ef)

    if ef is not None and ef.shape[0] == n:
        flat_g = flat_g + ef
    wire = flat_g
    new_ef = ef
    if compression == "bf16":
        wire16 = flat_g.astype(jnp.bfloat16)
        if ef is not None and ef.shape[0] == n:
            new_ef = flat_g - wire16.astype(jnp.float32)
        wire = wire16

    if plans is not None and plans.matches(n, dp, buckets, wire.dtype,
                                           compression):
        parts = _transposed_bucket_parts(wire, dp, plans.buckets)
        pending = PendingShard(abi, "group", plans.rs_group.start(parts), dp)
    elif buckets <= 1:
        pending = PendingShard(abi, "value",
                               abi.reduce_scatter(wire, PAX_SUM, comm), dp)
    else:
        assert n % (dp * buckets) == 0, "bucket count must divide the shard"
        parts = _transposed_bucket_parts(wire, dp, buckets)
        pending = PendingShard(
            abi, "pooled",
            [abi.ireduce_scatter(p, PAX_SUM, comm) for p in parts], dp)
    return pending, new_ef


def reduce_scatter_grads_finish(pending: PendingShard) -> jax.Array:
    """Complete an in-flight reduce-scatter leg: one group wait (one
    completion scan for every bucket), or the pooled waitall fallback.
    Returns the dp-mean (padded_n/dp,) f32 shard."""
    if pending.mode == "group":
        outs = pending.abi.wait(pending.pending)
        shard = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
    elif pending.mode == "pooled":
        shard = jnp.concatenate(pending.abi.waitall(pending.pending))
    else:
        shard = pending.pending
    return shard.astype(jnp.float32) / pending.dp


def reduce_scatter_grads(
    dist: DistContext,
    flat_g: jax.Array,
    *,
    compression: Optional[str] = None,
    buckets: int = 1,
    ef: Optional[jax.Array] = None,
    plans: Optional[Zero1Plans] = None,
):
    """flat_g: (padded_n,) f32, padded_n % dp_size == 0.
    Returns (g_shard (padded_n/dp,), new_ef).  Mean over dp ranks.

    Convenience wrapper issuing and completing the leg back-to-back; the
    train loop uses the start/finish split to overlap the in-flight group
    with independent compute."""
    pending, new_ef = reduce_scatter_grads_start(
        dist, flat_g, compression=compression, buckets=buckets, ef=ef,
        plans=plans,
    )
    return reduce_scatter_grads_finish(pending), new_ef


def allgather_params(dist: DistContext, shard: jax.Array, *, buckets: int = 1,
                     plans: Optional[Zero1Plans] = None) -> jax.Array:
    """Inverse of the scatter: collect every rank's updated shard.

    With ``buckets > 1`` the shard is split and issued as nonblocking
    ``iallgather`` requests (the spec-generated path), so the scheduler can
    overlap the gather of early buckets with whatever consumes them; the
    bucket-major chunks are re-interleaved into rank-major order.  With
    matching ``plans``, every bucket rides ONE ``ag_group.start()``/
    ``wait()`` pair on the persistent group slot."""
    abi = dist.abi
    use_plans = (plans is not None
                 and plans.dp == dist.dp_size
                 and plans.padded == shard.shape[0] * plans.dp
                 and plans.buckets == max(buckets, 1)
                 and shard.ndim == 1)
    if use_plans:
        parts = (jnp.split(shard, plans.buckets) if plans.buckets > 1
                 else [shard])
        outs = abi.wait(plans.ag_group.start(
            [p.astype(jnp.float32) for p in parts]))
        if plans.buckets == 1:
            return outs[0].astype(jnp.float32)
        if plans.unpack is not None:  # fused inverse gather (f32 out)
            return plans.unpack(outs)
        return _interleave_bucket_gathers(outs, dist.dp_size).astype(jnp.float32)
    if buckets <= 1:
        return abi.allgather(shard, dist.dp_comm).astype(jnp.float32)
    assert shard.shape[0] % buckets == 0, "bucket count must divide the shard"
    parts = jnp.split(shard, buckets)
    reqs = [abi.iallgather(p, dist.dp_comm) for p in parts]
    outs = abi.waitall(reqs)
    full = _interleave_bucket_gathers(outs, dist.dp_size, shard.shape[1:])
    return full.astype(jnp.float32)


def zero1_step(
    dist: DistContext,
    flat_g: jax.Array,
    update_shard,
    *,
    buckets: int = 1,
    compression: Optional[str] = None,
    ef: Optional[jax.Array] = None,
    plans: Optional[Zero1Plans] = None,
):
    """One explicit ZeRO-1 round trip through the generated ABI surface:
    bucketed nonblocking reduce-scatter -> per-shard optimizer update
    (``update_shard(g_shard) -> p_shard``) -> bucketed nonblocking
    all-gather of the updated shard.  Returns (params_full, new_ef).

    The ABI's free-list request pool recycles the bucket requests in place,
    so a steady-state training loop reuses one preallocated request batch
    per step instead of allocating per bucket (train_loop's ``body_zero1``
    drives this every step).  With ``plans`` (built once by
    :func:`build_zero1_plans`), each leg is ONE plan-group start/wait pair
    over all buckets — per-bucket dispatch, the inactive-checks and the
    completion scans are all group-build-time or once-per-step work."""
    g_shard, new_ef = reduce_scatter_grads(
        dist, flat_g, compression=compression, buckets=buckets, ef=ef,
        plans=plans,
    )
    p_shard = update_shard(g_shard)
    return allgather_params(dist, p_shard, buckets=buckets, plans=plans), new_ef


def allreduce_scalar(dist: DistContext, x):
    """Mean of a scalar metric over the dp group (loss, grad-norm²)."""
    return dist.abi.allreduce(x, PAX_SUM, dist.dp_comm) / dist.dp_size


def sync_tree_allreduce(dist: DistContext, grads):
    """Plain all-reduce of a gradient pytree (non-ZeRO baseline path)."""
    flat = flatten(grads)
    summed = dist.abi.allreduce(flat, PAX_SUM, dist.dp_comm) / dist.dp_size
    return unflatten_like(summed, grads)
