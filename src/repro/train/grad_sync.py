"""Data-parallel gradient synchronization through the PAX ABI.

This is where the paper's ABI carries the framework's heaviest traffic.
Modes (config ``parallelism.grad_sync``):

* ``abi`` — explicit ZeRO-1: the flat gradient vector is **reduce-scattered**
  over the dp communicator (each rank keeps 1/dp), the optimizer updates its
  shard, and the updated shard is **all-gathered** back.  Collective bytes:
  2x the parameter bytes per step (vs 2x for plain all-reduce but with 1/dp
  optimizer memory).  Options:
    - bucketing: the vector is split into N buckets issued as nonblocking
      ``ireduce_scatter`` requests (XLA's latency-hiding scheduler can
      overlap them with the optimizer math of earlier buckets);
    - compression: ``bf16`` casts the wire payload (+error feedback);
      ``int8`` routes through a ring backend that quantizes per hop.
* ``gspmd`` — implicit: gradients/optimizer state are sharded by XLA via
  in_shardings; no explicit collectives (used by the 300B-class archs whose
  parameters are FSDP-sharded over dp).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core import PAX_SUM
from ..optim.adamw import flatten, unflatten_like
from ..runtime.dist import DistContext, dp_comm_of


def pad_to(vec, multiple: int):
    pad = (-vec.shape[0]) % multiple
    if pad:
        vec = jnp.concatenate([vec, jnp.zeros((pad,), vec.dtype)])
    return vec


def reduce_scatter_grads(
    dist: DistContext,
    flat_g: jax.Array,
    *,
    compression: Optional[str] = None,
    buckets: int = 1,
    ef: Optional[jax.Array] = None,
):
    """flat_g: (padded_n,) f32, padded_n % dp_size == 0.
    Returns (g_shard (padded_n/dp,), new_ef).  Mean over dp ranks."""
    dp = dist.dp_size
    n = flat_g.shape[0]
    assert n % dp == 0
    if ef is not None and ef.shape[0] == n:
        flat_g = flat_g + ef
    wire = flat_g
    new_ef = ef
    if compression == "bf16":
        wire16 = flat_g.astype(jnp.bfloat16)
        if ef is not None and ef.shape[0] == n:
            new_ef = flat_g - wire16.astype(jnp.float32)
        wire = wire16
    abi, comm = dp_comm_of(dist, compression == "int8")

    if buckets <= 1:
        shard = abi.reduce_scatter(wire, PAX_SUM, comm)
    else:
        assert n % (dp * buckets) == 0, "bucket count must divide the shard"
        # transposed split: bucket b carries every rank's b-th sub-slice, so
        # each rank's concatenated result is its *contiguous* slice of the
        # full vector — the same layout allgather_params reassembles and the
        # same slice `wire[r*shard : (r+1)*shard]` an unbucketed
        # reduce-scatter would deliver
        blocks = wire.reshape(dp, buckets, -1)
        parts = [blocks[:, b, :].reshape(-1) for b in range(buckets)]
        reqs = [abi.ireduce_scatter(p, PAX_SUM, comm) for p in parts]
        shards = abi.waitall(reqs)
        shard = jnp.concatenate(shards)
    shard = shard.astype(jnp.float32) / dp
    return shard, new_ef


def allgather_params(dist: DistContext, shard: jax.Array, *, buckets: int = 1) -> jax.Array:
    """Inverse of the scatter: collect every rank's updated shard.

    With ``buckets > 1`` the shard is split and issued as nonblocking
    ``iallgather`` requests (the spec-generated path), so the scheduler can
    overlap the gather of early buckets with whatever consumes them; the
    bucket-major chunks are re-interleaved into rank-major order."""
    abi = dist.abi
    if buckets <= 1:
        return abi.allgather(shard, dist.dp_comm).astype(jnp.float32)
    assert shard.shape[0] % buckets == 0, "bucket count must divide the shard"
    parts = jnp.split(shard, buckets)
    reqs = [abi.iallgather(p, dist.dp_comm) for p in parts]
    outs = abi.waitall(reqs)
    # outs[b] is rank-major over bucket b; interleave back to rank-major full,
    # preserving any trailing dims so both bucket settings return one shape
    rest = shard.shape[1:]
    chunks = [o.reshape((dist.dp_size, -1) + rest) for o in outs]
    full = jnp.concatenate(chunks, axis=1).reshape((-1,) + rest)
    return full.astype(jnp.float32)


def zero1_step(
    dist: DistContext,
    flat_g: jax.Array,
    update_shard,
    *,
    buckets: int = 1,
    compression: Optional[str] = None,
    ef: Optional[jax.Array] = None,
):
    """One explicit ZeRO-1 round trip through the generated ABI surface:
    bucketed nonblocking reduce-scatter -> per-shard optimizer update
    (``update_shard(g_shard) -> p_shard``) -> bucketed nonblocking
    all-gather of the updated shard.  Returns (params_full, new_ef).

    The ABI's free-list request pool recycles the bucket requests in place,
    so a steady-state training loop reuses one preallocated request batch
    per step instead of allocating per bucket (train_loop's ``body_zero1``
    drives this every step)."""
    g_shard, new_ef = reduce_scatter_grads(
        dist, flat_g, compression=compression, buckets=buckets, ef=ef
    )
    p_shard = update_shard(g_shard)
    return allgather_params(dist, p_shard, buckets=buckets), new_ef


def allreduce_scalar(dist: DistContext, x):
    """Mean of a scalar metric over the dp group (loss, grad-norm²)."""
    return dist.abi.allreduce(x, PAX_SUM, dist.dp_comm) / dist.dp_size


def sync_tree_allreduce(dist: DistContext, grads):
    """Plain all-reduce of a gradient pytree (non-ZeRO baseline path)."""
    flat = flatten(grads)
    summed = dist.abi.allreduce(flat, PAX_SUM, dist.dp_comm) / dist.dp_size
    return unflatten_like(summed, grads)
