"""Training step/loop builders.

Two step constructions per DESIGN.md:

* ``abi`` (default, ≤15B-class archs): a partial-manual ``shard_map`` over
  the dp axes; TP stays GSPMD (auto) inside.  Two gradient-sync layouts:

  - **ZeRO-1 flat** (``parallelism.zero1`` and ``init_state`` given the
    dist): the flat gradient vector is bucketed-**reduce-scattered**
    through the pooled nonblocking ABI path, the AdamW update runs on this
    rank's shard only (optimizer memory 1/dp), and the updated shard is
    bucketed-**all-gathered** back.  Moments live as (padded,) flat
    vectors sharded ``P(dp_axes)``: every rank holds its contiguous slice,
    the same slice the (transposed-split) bucketed reduce-scatter
    delivers.  The request pool recycles the bucket requests in place, so
    the steady-state step allocates no request objects.
  - **per-leaf DDP** (``init_state`` without a dist, the legacy layout):
    nonblocking ``iallreduce`` per leaf, moments TP-sharded like the
    params and dp-replicated.

  Optional bf16 wire compression; optional int8 via a ring-compressed
  backend.  The ABI carries all dp traffic either way.

* ``gspmd`` (300B-class: grok-1, nemotron-4): plain jit; params, grads and
  moments are FSDP x TP sharded via in_shardings (ZeRO-style memory
  scaling) and XLA inserts the collectives implicitly.

Both support gradient accumulation over microbatches (lax.scan) and buffer
donation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import PAX_SUM
from ..core.communicator import comm_rank_traced
from ..models.model import ModelApi
from ..optim import adamw
from ..optim.adamw import AdamState, AdamWConfig, FlatAdamState
from ..runtime.dist import DistContext, dp_comm_of
from ..runtime.sharding import use_rules
from .grad_sync import (allgather_params, pad_to, reduce_scatter_grads_finish,
                        reduce_scatter_grads_start)


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    step: jax.Array


class Metrics(NamedTuple):
    loss: jax.Array
    grad_norm: jax.Array


def _flat_opt_specs(dp_axes) -> FlatAdamState:
    """The one place the ZeRO-1 flat state's sharding is written down:
    moments shard over the dp axes, step replicated.  The error-feedback
    buffer is *per-rank* state (each rank's own wire-quantization residual),
    so it shards over the dp axes too — its global layout is (dp * padded,)
    (or a (dp,) dummy when compression is off), one full-length residual per
    rank."""
    dpP = P(tuple(dp_axes)) if dp_axes else P()
    return FlatAdamState(P(), dpP, dpP, dpP)


def init_state(api: ModelApi, key, dist: Optional[DistContext] = None) -> TrainState:
    """Build the initial train state.

    With ``dist`` provided and ``parallelism.zero1`` set in abi mode, the
    optimizer state is the ZeRO-1 flat layout (moments for 1/dp of the
    parameters per rank); otherwise the classic per-leaf tree layout.

    The zero1 layout also (a) allocates the error-feedback buffer when bf16
    wire compression is configured (per-rank residuals, see
    :func:`_flat_opt_specs`) and (b) builds the persistent collective plans
    and their Startall groups for the bucketed round trip
    (``dist.zero1_plans``) — argument binding, handle conversion, recipe
    composition, group fusion AND the wire-kernel choice (the fused Pallas
    flatten/bucket pack when the registry + layout allow it, the lax
    pipeline otherwise — ``Zero1Plans.wire_kernel`` records which) happen
    here, once, not per step.

    Re-initialization is **layout-transparent** (the ABI's layout-keyed
    plan cache): re-init with the same (padded, dp, buckets, wire) layout
    keeps the live plans/groups untouched — zero new request slots — while
    a genuine layout change (re-sharding, elastic dp, bucket retune)
    retires the old slots and re-plans."""
    params = api.init(key)
    par = api.cfg.parallelism
    if dist is not None and par.grad_sync == "abi" and par.zero1:
        buckets = max(par.zero1_buckets, 1)
        with_ef = par.grad_compression == "bf16"
        opt = adamw.init_flat_global(
            params, dist.dp_size, buckets=buckets, with_ef=with_ef)
        from .grad_sync import build_zero1_plans, zero1_wire_dtype
        old = dist.zero1_plans
        if old is None or not old.matches(
                opt.m.shape[0], dist.dp_size, buckets,
                zero1_wire_dtype(par.grad_compression), par.grad_compression):
            # genuine layout change: retire the old plans' request slots
            # before rebuilding, or every re-init leaks slots
            dist.drop_zero1_plans()
            dist.zero1_plans = build_zero1_plans(
                dist, opt.m.shape[0], buckets, par.grad_compression)
    else:
        opt = adamw.init_tree(params)
    return TrainState(params, opt, jnp.zeros((), jnp.int32))


def _microbatched_grads(loss_fn, params, batch, n_micro: int):
    """Gradient accumulation via scan; returns (mean_loss, grads)."""
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    mbatches = jax.tree.map(reshape, batch)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        loss_acc, g_acc = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
        return (loss_acc + loss, g_acc), None

    (loss_sum, gsum), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mbatches)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)


def sync_grads_abi(dist: DistContext, grads, compression: Optional[str],
                   grad_specs=None):
    """Per-leaf nonblocking all-reduce over the dp communicator (each leaf is
    a bucket; requests are issued together and awaited together so the
    scheduler can overlap them).

    ``grad_specs`` (the TP param specs) pins each leaf's model-axis sharding
    through the collective: without the constraint GSPMD lowers the dp psum
    of a TP-sharded gradient as all-gather + full all-reduce + re-slice —
    16x the wire bytes (§Perf qwen2-moe iteration 4 finding).
    """
    abi, comm = dp_comm_of(dist, compression == "int8")
    dp = dist.dp_size
    leaves, treedef = jax.tree.flatten(grads)
    specs = (jax.tree.leaves(grad_specs, is_leaf=lambda v: isinstance(v, P))
             if grad_specs is not None else [None] * len(leaves))

    def pin(x, spec):
        if spec is None:
            return x
        try:
            return jax.lax.with_sharding_constraint(x, _trim_spec(spec, x.ndim))
        except Exception:
            return x

    wires = [l.astype(jnp.bfloat16) if compression == "bf16" else l for l in leaves]
    wires = [pin(w, s) for w, s in zip(wires, specs)]
    reqs = [abi.iallreduce(w, PAX_SUM, comm) for w in wires]
    summed = abi.waitall(reqs)
    out = [pin(s, sp).astype(jnp.float32) / dp for s, sp in zip(summed, specs)]
    return jax.tree.unflatten(treedef, out)


def _trim_spec(spec: P, rank: int) -> P:
    parts = tuple(spec)[:rank]
    return P(*parts)


# ---------------------------------------------------------------------------
# ABI mode
# ---------------------------------------------------------------------------
def make_train_step_abi(
    api: ModelApi,
    dist: DistContext,
    opt_cfg: AdamWConfig,
    *,
    schedule: Optional[Callable] = None,
):
    cfg = api.cfg
    par = cfg.parallelism
    n_micro = max(par.microbatch, 1)
    compression = par.grad_compression
    buckets = max(par.zero1_buckets, 1)
    # TP shardings of the gradients (== param specs without fsdp axes)
    grad_specs = api.param_specs(fsdp=None, tp=dist.tp_axis)

    def body(params, opt: AdamState, step, batch):
        with use_rules(dist.rules):
            loss, grads = _microbatched_grads(
                lambda p, b: api.loss_fn(p, b, dist), params, batch, n_micro)
            grads = sync_grads_abi(dist, grads, compression, grad_specs)
            lr_scale = schedule(step) if schedule is not None else jnp.float32(1.0)
            new_params, new_opt, gnorm = adamw.update_tree(
                opt_cfg, grads, opt, params, lr_scale)
            loss = dist.abi.allreduce(loss, PAX_SUM, dist.dp_comm) / dist.dp_size
        return new_params, new_opt, loss, gnorm

    def body_zero1(params, opt: FlatAdamState, step, batch):
        """Explicit ZeRO-1 round trip (the ROADMAP wiring): one
        reduce-scatter *group* start -> shard-local AdamW -> one all-gather
        group start/wait, riding the Startall plan groups built at
        ``init_state`` (``dist.zero1_plans``; pooled nonblocking ``i*``
        requests as the fallback).  The reduce-scatter group is issued
        BEFORE the param flatten/rank-slice compute and waited after, so
        the in-flight fused collective overlaps the independent work (and,
        across jitted steps, the next microbatch's backward — XLA's
        latency-hiding scheduler sees the start/wait dataflow gap).  With
        bf16 wire compression the per-rank error-feedback residual
        (``opt.ef``) is folded into the next step's gradient and refreshed
        from this step's quantization error."""
        dp = dist.dp_size
        plans = dist.zero1_plans
        with use_rules(dist.rules):
            loss, grads = _microbatched_grads(
                lambda p, b: api.loss_fn(p, b, dist), params, batch, n_micro)
            flat_g = pad_to(adamw.flatten(grads), dp * buckets)
            n_flat = sum(int(l.size) for l in jax.tree.leaves(grads))
            # error feedback: opt.ef is this rank's full-length residual
            # exactly when compression is on (a (1,)-dummy otherwise)
            ef = opt.ef if opt.ef.shape[0] == flat_g.shape[0] else None
            pending, new_ef = reduce_scatter_grads_start(
                dist, flat_g, compression=compression, buckets=buckets,
                ef=ef, plans=plans)
            # overlapped with the in-flight reduce-scatter group: this
            # rank's contiguous param slice (same layout as g_shard and as
            # the P(dp_axes)-sharded moment vectors) depends only on params
            flat_p = pad_to(adamw.flatten(params), dp * buckets)
            shard_len = flat_p.shape[0] // dp
            r = comm_rank_traced(dist.abi.comms.info(dist.dp_comm))
            p_shard = jax.lax.dynamic_slice_in_dim(flat_p, r * shard_len, shard_len)
            g_shard = reduce_scatter_grads_finish(pending)
            # ||mean grad||²: each element lives on exactly one rank's shard
            gnorm = jnp.sqrt(dist.abi.allreduce(
                jnp.sum(jnp.square(g_shard)), PAX_SUM, dist.dp_comm))
            lr_scale = schedule(step) if schedule is not None else jnp.float32(1.0)
            new_p_shard, new_opt = adamw.update_flat_shard(
                opt_cfg, g_shard, opt, p_shard, gnorm, lr_scale)
            if ef is not None and new_ef is not None:
                new_opt = new_opt._replace(ef=new_ef)
            p_full = allgather_params(dist, new_p_shard, buckets=buckets,
                                      plans=plans)
            new_params = adamw.unflatten_like(p_full[:n_flat], params)
            loss = dist.abi.allreduce(loss, PAX_SUM, dist.dp_comm) / dp
        return new_params, new_opt, loss, gnorm

    flat_opt_specs = _flat_opt_specs(dist.dp_axes)

    def step_fn(state: TrainState, batch):
        rep = lambda tree: jax.tree.map(lambda _: P(), tree)
        zero1 = isinstance(state.opt, FlatAdamState)
        f = dist.abi.shard_region(
            body_zero1 if zero1 else body,
            # step passed explicitly: closures over tracers are
            # illegal inside shard_map bodies
            in_specs=(rep(state.params),
                      flat_opt_specs if zero1 else rep(state.opt), P(),
                      jax.tree.map(lambda _: P(dist.dp_axes), batch)),
            out_specs=(rep(state.params),
                       flat_opt_specs if zero1 else rep(state.opt), P(), P()),
            axis_names=set(dist.dp_axes),
        )
        new_params, new_opt, loss, gnorm = f(state.params, state.opt, state.step, batch)
        return TrainState(new_params, new_opt, state.step + 1), Metrics(loss, gnorm)

    return step_fn


# ---------------------------------------------------------------------------
# GSPMD mode
# ---------------------------------------------------------------------------
def make_train_step_gspmd(
    api: ModelApi,
    dist: Optional[DistContext],
    opt_cfg: AdamWConfig,
    *,
    schedule: Optional[Callable] = None,
):
    cfg = api.cfg
    n_micro = max(cfg.parallelism.microbatch, 1)
    rules = dist.rules if dist is not None else None

    def step_fn(state: TrainState, batch):
        with use_rules(rules):
            loss, grads = _microbatched_grads(
                lambda p, b: api.loss_fn(p, b, dist), state.params, batch, n_micro)
            lr_scale = schedule(state.step) if schedule is not None else 1.0
            new_params, new_opt, gnorm = adamw.update_tree(
                opt_cfg, grads, state.opt, state.params, lr_scale)
        return TrainState(new_params, new_opt, state.step + 1), Metrics(loss, gnorm)

    return step_fn


def make_train_step(api: ModelApi, dist, opt_cfg: AdamWConfig, **kw):
    if api.cfg.parallelism.grad_sync == "abi" and dist is not None:
        return make_train_step_abi(api, dist, opt_cfg, **kw)
    return make_train_step_gspmd(api, dist, opt_cfg, **kw)


# ---------------------------------------------------------------------------
# elastic-dp recovery (the fault-tier consumer)
# ---------------------------------------------------------------------------
def with_failure_probe(dist: DistContext, step_fn: Callable) -> Callable:
    """Prepend a host-side fault-tier probe to a (possibly jitted) step_fn.

    A compiled step cannot raise on a later rank death — injection and
    detection live at dispatch time in the single-controller simulation —
    so the supervised loop's failure notification is an agreement on the
    data-parallel communicator before each launch: ``comm_agree`` raises
    ``PAX_ERR_PROC_FAILED`` the moment the failure detector reports an
    unacknowledged death (the ULFM notification idiom)."""

    def probed(state, batch):
        dist.abi.comm_agree(1, dist.dp_comm)
        return step_fn(state, batch)

    return probed


def rebalance_batch(batch, dp: int):
    """Trim a global batch's leading dim to the largest multiple of ``dp``
    (identity when ``dp`` already divides it).

    The uneven-shard recovery mode keeps ALL survivors (dp=7 instead of a
    power-of-two trim to 4), so the fixed global batch no longer divides
    the dp extent; the ``shard_map`` over ``P(dp_axes)`` requires it to.
    Trimming happens OUTSIDE the jitted step — host-side, before tracing —
    so the compiled step sees a clean ``(B', ...)`` with ``dp | B'``.  The
    dropped rows are the batch tail, deterministically, so an oracle run
    using the same function sees the same data."""
    def trim(x):
        b = (x.shape[0] // dp) * dp
        if b == 0:
            raise ValueError(f"batch dim {x.shape[0]} < dp={dp}: nothing to shard")
        return x if b == x.shape[0] else x[:b]

    return jax.tree.map(trim, batch)


def elastic_recovery_policy(api: ModelApi, opt_cfg: AdamWConfig, dist: DistContext,
                            key, *, impl=None, schedule=None, tools=(),
                            uneven_shards: bool = False,
                            integrity: Optional[bool] = None):
    """The canonical ``RecoveryPolicy`` for elastic-dp training.

    After ``run_supervised``'s fault-tier walk (revoke → ack → get_failed →
    agree → shrink) the ``rebuild`` callback re-derives the training world:

    * a dense mesh over the survivors (``survivor_mesh``), trimmed to the
      largest power-of-two dp extent so batch and flat-layout divisibility
      survive arbitrary casualty counts (8 ranks − 1 dead → dp=4) — or,
      with ``uneven_shards=True``, kept at the full survivor count (dp=7)
      with the global batch rebalanced per step via
      :func:`rebalance_batch` (host-side trim to a dp multiple; use the
      per-leaf DDP optimizer layout — the zero1 flat layout re-pads to the
      new dp and cannot restore an old checkpoint shape);
    * a fresh ``DistContext`` over it (``impl`` names the *recovered*
      backend — typically the plain implementation underneath the
      fault-injection wrapper);
    * ``init_state`` on the new dist, which re-plans the zero1 collective
      plans through the layout-keyed cache (a genuine layout change retires
      the old slots; an identical layout reuses live plans);
    * the new step_fn (jitted, failure-probed) and the restore specs for
      ``Checkpointer.restore(mesh=new_mesh, specs=...)``.

    Ranks are linearized mesh positions, so this assumes the dp axis leads
    the mesh (tp groups must survive intact — elastic *data* parallelism).
    ``policy.dist`` is updated to the rebuilt context, so a second failure
    recovers from the already-shrunk world.  ``integrity`` carries the
    checksummed-wire mode into the rebuilt context — a recovered world
    keeps the detection guarantees of the one it replaces (default: the
    original ``dist``'s setting).
    """
    from ..runtime.dist import make_dist, survivor_mesh
    from ..runtime.fault import RecoveryPolicy, RecoveryTarget

    def rebuild(survivors: int, failed: tuple) -> RecoveryTarget:
        mesh = survivor_mesh(policy.dist.mesh, failed)
        names = tuple(mesh.axis_names)
        dp_avail = mesh.shape[names[0]]
        if uneven_shards:
            dp_new = dp_avail       # keep every survivor; rebalance batches
        else:
            dp_new = 1 << (dp_avail.bit_length() - 1)
            if dp_new != dp_avail:
                mesh = jax.sharding.Mesh(mesh.devices[:dp_new], names)
        keep_integrity = (dist.abi.integrity if integrity is None
                          else integrity)
        new_dist = make_dist(mesh, impl=impl, tools=tools,
                             integrity=keep_integrity)
        state_like = init_state(api, key, new_dist)
        jstep = jax.jit(make_train_step(api, new_dist, opt_cfg,
                                        schedule=schedule))
        if uneven_shards:
            # trim outside the jitted step: the shard_map's P(dp_axes)
            # in_spec needs dp | batch, and tracing must see the final shape
            jstep = (lambda _j, _dp: lambda state, batch:
                     _j(state, rebalance_batch(batch, _dp)))(jstep, dp_new)
        step_fn = with_failure_probe(new_dist, jstep)
        par = api.cfg.parallelism
        zero1 = par.grad_sync == "abi" and par.zero1
        specs = state_specs(api, "abi",
                            dp_axes=new_dist.dp_axes if zero1 else None)
        policy.dist = new_dist
        return RecoveryTarget(step_fn, state_like, mesh=mesh, specs=specs)

    policy = RecoveryPolicy(dist=dist, rebuild=rebuild)
    return policy


# ---------------------------------------------------------------------------
# state sharding specs (for jit in_shardings / checkpoint layouts)
# ---------------------------------------------------------------------------
def state_specs(api: ModelApi, mode: str, fsdp="data", tp="model", dp_axes=None):
    """PartitionSpec pytree for TrainState.

    * abi mode: params TP-sharded only (dp-replicated); moments likewise in
      the per-leaf layout, or — with ``dp_axes`` given for the ZeRO-1 flat
      layout — (padded,) flat vectors sharded over the dp axes;
    * gspmd mode: params/moments FSDP x TP sharded (param specs already
      carry the fsdp axes).
    """
    pspecs = api.param_specs(fsdp=fsdp, tp=tp) if mode == "gspmd" else (
        api.param_specs(fsdp=None, tp=tp))
    if mode == "abi" and dp_axes is not None:
        return TrainState(pspecs, _flat_opt_specs(dp_axes), P())
    return TrainState(
        pspecs,
        AdamState(P(), jax.tree.map(lambda s: s, pspecs),
                  jax.tree.map(lambda s: s, pspecs)),
        P(),
    )
