"""Serving fault supervisor: observe the death, shrink the tp comm, replay
in-flight requests token-identically.

The training tier got its fault story in PR 7 (``run_supervised`` +
``elastic_recovery_policy``); this module is the serving counterpart, built
from the same three ingredients:

* **notification** — before each engine step the supervisor beats the
  :class:`~repro.runtime.liveness.HeartbeatMonitor` (on its cadence) and
  runs the ULFM notification idiom, a host-side ``comm_agree(1, tp_comm)``
  probe that raises ``PAX_ERR_PROC_FAILED`` the moment the failure
  detector reports an unacknowledged death.  A failure can also surface
  from the ``decode-tp`` ``group.start()`` itself; both land in the same
  handler.
* **recovery** — the canonical fault-tier walk on the tp communicator:
  revoke → failure_ack → get_failed → agree(1) → shrink.  The dead
  ``DecodeSync`` group is retired (``free()`` — its plans were already
  force-reset by the revoke) and rebuilt as a **fresh plan group on the
  survivor communicator**: the shrunk comm carries the parent's axes with
  the corpse excluded, so the broadcasts lower over the same mesh axes and
  the PR-5 layout-keyed cache makes the re-plan allocate only genuinely
  new slots.  The monitor rebinds its heartbeat comm onto the survivor.
* **replay** — every in-flight request is evicted (blocks freed), its
  generated tokens counted and discarded, and re-queued **at the front of
  the waiting queue in admission order**, so re-admission order equals the
  original submission order.  Sampling keys are
  ``fold_in(fold_in(PRNGKey(seed), rid), step)`` with
  ``step = len(out_tokens)`` — replaying from the prompt regenerates the
  exact token stream, so clients observe latency, never corruption.

Request-level robustness rides the same ledger
(:class:`ServeRecoveryReport`, the serving shape of PR 7's
``SupervisorReport``): bounded failures with exponential backoff
accounting, bounded per-request retries (a request that keeps dying is
dropped with its ``failed`` flag set, never silently), and deadline
expiry/graceful re-queueing delegated to the scheduler.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Optional

from ..core.errors import PAX_ERR_PROC_FAILED, PaxError
from ..runtime.fault import TRANSPORT_ERRORS

log = logging.getLogger("repro.serve.supervisor")


@dataclasses.dataclass
class ServeRecoveryReport:
    """The supervisor's ledger — every recovery action is accounted here.

    Invariants (``assert_consistent``): each replay event re-queues or
    drops every then-in-flight request exactly once, so
    ``sum(retries) == requeued + dropped``; replays never exceed failures
    (a failure with nothing in flight replays nothing); backoff totals are
    the closed-form sum of the exponential schedule.
    """

    failures: int = 0                 # PROC_FAILED events handled
    replays: int = 0                  # recovery passes that evicted slots
    tokens_replayed: int = 0          # generated tokens discarded for replay
    requeued: int = 0                 # eviction -> front-of-queue re-admissions
    dropped: int = 0                  # requests past max_retries (failed flag)
    expired: int = 0                  # deadline expiries observed
    backoff_s_total: float = 0.0
    failed_ranks: list = dataclasses.field(default_factory=list)
    retries: dict = dataclasses.field(default_factory=dict)  # rid -> count
    # transport-integrity accounting (PR 10): in-place step re-runs that
    # cured a corrupted/timed-out decode sync, and retry exhaustions that
    # escalated into the rank-death recovery above
    transport_retries: int = 0
    transport_escalations: int = 0

    def assert_consistent(self) -> None:
        assert self.replays <= self.failures, (self.replays, self.failures)
        assert sum(self.retries.values()) == self.requeued + self.dropped, \
            (self.retries, self.requeued, self.dropped)
        assert self.tokens_replayed >= 0
        assert len(self.failed_ranks) == self.failures, \
            (self.failed_ranks, self.failures)


class ServeSupervisor:
    """Drive a :class:`~.engine.ServeEngine` with fault supervision.

    ``monitor`` (optional) is beaten every ``heartbeat_every`` supervisor
    steps — liveness is amortized over tokens, so a never-failed engine's
    per-token cost is one host-side ``comm_agree`` probe (the
    ``serve_fault_dispatch_ratio`` gate pins it at 1.0 ± 5%).
    ``max_failures`` bounds recoveries (like ``max_restarts``);
    ``backoff_s`` doubles per failure; ``max_retries`` bounds how many
    times one request may be replayed before it is dropped.

    Transport faults (PR 10): ``wait_timeout_s`` bounds the decode sync's
    group/pooled waits, so a *dropped* tp broadcast surfaces as
    ``PAX_ERR_TIMEOUT`` instead of hanging the serve loop; a corrupted one
    (integrity mode) surfaces as ``PAX_ERR_DATA_CORRUPTION`` at token
    materialization.  Either aborts the wedged plan group
    (``DecodeSync.reset``) and re-runs THE SAME engine step — the decode
    re-reads the same KV positions, so a cured fault is invisible in the
    token stream.  After ``transport_retries`` failed re-runs the fault
    escalates into :meth:`_recover`: the heartbeat monitor confirms the
    silent rank (a dropping link stops answering heartbeats), and the
    standard shrink → rebuild → replay walk takes over.
    """

    def __init__(self, engine, *, monitor=None, heartbeat_every: int = 1,
                 max_failures: int = 3, backoff_s: float = 0.0,
                 max_retries: int = 3, sleep=time.sleep,
                 wait_timeout_s: Optional[float] = None,
                 transport_retries: int = 2) -> None:
        if engine.decode_sync is None:
            raise ValueError("ServeSupervisor needs an engine with a "
                             "DecodeSync (the tp comm is what it recovers)")
        self.engine = engine
        self.monitor = monitor
        self.heartbeat_every = max(1, heartbeat_every)
        self.max_failures = max_failures
        self.backoff_s = backoff_s
        self.max_retries = max_retries
        self.wait_timeout_s = wait_timeout_s
        self.transport_retries = transport_retries
        if wait_timeout_s is not None:
            engine.decode_sync.wait_timeout_s = wait_timeout_s
        self.report = ServeRecoveryReport()
        self._sleep = sleep
        self._steps = 0

    # -- the supervised step ------------------------------------------------
    def step(self) -> None:
        eng = self.engine
        self._steps += 1
        if self.monitor is not None and self._steps % self.heartbeat_every == 0:
            self.monitor.beat()
        ds = eng.decode_sync
        try:
            # the ULFM notification idiom: agree raises PROC_FAILED while
            # an observed failure is unacknowledged — the host-side probe
            # that turns a detector view into a step-loop exception
            ds.abi.comm_agree(1, ds.comm)
            eng.step()
            self.report.expired += len(eng.last_expired)
        except PaxError as e:
            if e.code == PAX_ERR_PROC_FAILED:
                self._recover(e)
            elif e.code in TRANSPORT_ERRORS:
                self._transport_fault(e)
            else:
                raise

    def drain(self) -> None:
        while self.engine.has_work:
            self.step()

    def run(self, requests) -> ServeRecoveryReport:
        for r in requests:
            self.engine.submit(r)
        self.drain()
        self.report.assert_consistent()
        return self.report

    # -- transport faults ---------------------------------------------------
    def _transport_fault(self, cause: PaxError) -> None:
        """Retry-with-backoff for a corrupted or timed-out decode sync.

        Each attempt: abort the wedged plan group (``DecodeSync.reset`` —
        the post-timeout contract; the slot stays ACTIVE across a timeout
        raise precisely so this abort is possible), back off, re-run the
        SAME engine step.  The step is idempotent under re-run: no token
        was appended (the append happens after the sync), so the decode
        re-reads the same KV positions with the same lengths and the cured
        step is bitwise what the unfailed step would have been.  Exhausted
        retries escalate into the rank-death walk — a persistently-dropping
        link IS a dead peer as far as the serving tier is concerned, and
        the heartbeat confirmation inside :meth:`_recover` names it.
        """
        eng, rep = self.engine, self.report
        err = cause
        tries = 0
        while True:
            eng.decode_sync.reset()
            tries += 1
            if tries > self.transport_retries:
                rep.transport_escalations += 1
                log.error("transport fault persists after %d retries (%s); "
                          "escalating to rank-death recovery",
                          self.transport_retries, err)
                self._recover(err)
                return
            rep.transport_retries += 1
            log.warning("transport fault (%s); retrying step in place "
                        "%d/%d", err, tries, self.transport_retries)
            if self.backoff_s:
                delay = self.backoff_s * (2 ** (tries - 1))
                rep.backoff_s_total += delay
                self._sleep(delay)
            try:
                eng.step()
                rep.expired += len(eng.last_expired)
                return
            except PaxError as e:
                if e.code == PAX_ERR_PROC_FAILED:
                    self._recover(e)
                    return
                if e.code not in TRANSPORT_ERRORS:
                    raise
                err = e

    # -- recovery -----------------------------------------------------------
    def _recover(self, cause: PaxError) -> tuple:
        rep = self.report
        rep.failures += 1
        if rep.failures > self.max_failures:
            raise RuntimeError(
                f"exceeded {self.max_failures} serving recoveries") from cause
        if self.backoff_s:
            delay = self.backoff_s * (2 ** (rep.failures - 1))
            rep.backoff_s_total += delay
            self._sleep(delay)

        eng = self.engine
        ds = eng.decode_sync
        abi, comm = ds.abi, ds.comm

        # Detection convergence: the tripwire can raise before the monitor
        # has confirmed the corpse.  Beat (on the un-revoked heartbeat dup
        # comm) until the detector names somebody; bounded by the monitor's
        # own confirmation horizon so a spurious failure cannot spin here.
        if self.monitor is not None and not abi.comm_get_failed(comm):
            budget = (self.monitor.miss_threshold
                      + self.monitor.suspicion_ticks + 1)
            while budget > 0 and not abi.comm_get_failed(comm):
                self.monitor.beat()
                budget -= 1
        failed = tuple(abi.comm_get_failed(comm))
        if not failed:
            raise RuntimeError(
                "PROC_FAILED raised but no failure detector names a corpse "
                "(liveness monitor not installed?)") from cause

        # the canonical ULFM walk on the tp communicator
        abi.comm_revoke(comm)          # poisons the comm, force-resets the
        abi.comm_failure_ack(comm)     # decode-tp plans/group bound to it
        failed = tuple(abi.comm_get_failed(comm))
        abi.comm_agree(1, comm)
        survivor = abi.comm_shrink(comm)
        log.warning("serving recovery: ranks %s failed on the tp comm, "
                    "%d survivors", list(failed), abi.comm_size(survivor))

        # retire the dead group's request slot; rebuild on the survivor
        # comm (same axes, corpse excluded — the layout-keyed cache makes
        # the unchanged-shape re-plan free of redundant work)
        ds.free()
        eng.rebuild_decode_sync(
            abi, survivor, ds.mesh,
            wait_timeout_s=getattr(ds, "wait_timeout_s", self.wait_timeout_s))
        if self.monitor is not None:
            self.monitor.rebind(survivor)

        rep.failed_ranks.append(failed)
        self._replay_inflight()
        return failed

    def _replay_inflight(self) -> None:
        """Evict every occupied slot and re-queue (or drop) its request for
        a from-the-prompt replay.  Front-of-queue in admission order keeps
        re-admission order == original submission order, which the
        token-identity oracle relies on."""
        eng, rep = self.engine, self.report
        sched = eng.scheduler
        occupied = sorted(
            (i for i, s in enumerate(sched.slots) if s is not None),
            key=lambda i: sched.slots[i].admit_seq)
        if not occupied:
            return
        rep.replays += 1
        requeue = []
        for i in occupied:
            req = sched.evict(i)
            rep.tokens_replayed += len(req.out_tokens)
            req.out_tokens = []
            req.done = False
            req.retries += 1
            rep.retries[req.rid] = req.retries
            if req.retries > self.max_retries:
                req.failed = True
                req.done = True
                rep.dropped += 1
                log.warning("request %d dropped after %d replays",
                            req.rid, req.retries)
                continue
            requeue.append(req)
        sched.requeue(requeue)
        rep.requeued += len(requeue)
