"""Paged KV-cache memory: fixed-size blocks, a free-list allocator with
generation-tagged handles, and block-table views for the paged decode path.

This is the request-pool design (PR 2/3, ``core/abi.py``) applied to KV
memory instead of request slots:

* KV memory is one preallocated slab of **fixed-size blocks** per layer
  (``(L, num_blocks, block_size, kv_heads, head_dim)``); a sequence owns a
  list of blocks, so fragmentation is impossible by construction — any free
  block serves any sequence (vLLM's PagedAttention layout).
* ``alloc()`` pops the free list (O(1)); ``free()`` pushes the block back
  and **bumps the block's generation**, so every handle the old owner held
  is stale *forever* — a use-after-free reads as a clean
  :class:`StaleBlockError`, never as silently reading another request's KV
  (the exact aliasing bug the request pool's generation scheme kills).
* handles pack the physical block id in the low bits and the generation
  above (``gen << _GEN_SHIFT | block_id``); Python ints are unbounded, so
  generations never wrap (the PR-3 widening, inherited).
* **block 0 is the reserved null block**: never allocated, the padding
  target of every block-table view, and the write target of inactive decode
  slots — garbage writes land there by construction and no live sequence
  ever reads it.
* exhaustion raises :class:`KVCacheOOM` with the full accounting (blocks
  in use / free / requested), so the scheduler's admission gate can reason
  about capacity and a genuine overcommit fails loudly, not with a corrupt
  cache.

The allocator is pure host-side bookkeeping — device memory is the slab in
:func:`repro.models.transformer.init_paged_cache`; the allocator only
decides which physical block a logical page maps to, and
:func:`block_table_view` renders an owner's handle list as the padded int32
table the paged attention kernels index through.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class KVCacheOOM(RuntimeError):
    """The block pool is exhausted (clean OOM — nothing was corrupted)."""


class StaleBlockError(RuntimeError):
    """A handle from a previous allocation of the block was used after
    ``free`` (generation mismatch — the paged analogue of
    ``PAX_ERR_REQUEST`` on a retired request handle)."""


class DoubleFreeError(RuntimeError):
    """``free`` of a handle whose block is already on the free list."""


_GEN_SHIFT = 32
_ID_MASK = (1 << _GEN_SHIFT) - 1

#: physical id of the reserved null block (padding / inactive-slot target)
NULL_BLOCK = 0


@dataclasses.dataclass
class BlockAllocator:
    """Free-list allocator over ``num_blocks`` fixed-size KV blocks.

    Block 0 is reserved as the null block and never handed out; the usable
    pool is ``num_blocks - 1`` blocks of ``block_size`` token positions
    each.
    """

    num_blocks: int
    block_size: int

    def __post_init__(self) -> None:
        if self.num_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the reserved "
                             f"null block), got {self.num_blocks}")
        # LIFO free list over physical ids 1..num_blocks-1 (0 is reserved).
        # Popping from the end hands out high ids first — deterministic, and
        # reuse-heavy workloads churn a small hot set of blocks.
        self._free: list[int] = list(range(1, self.num_blocks))
        self._gen: list[int] = [0] * self.num_blocks
        self._live: int = 0

    # -- capacity ----------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return self._live

    def blocks_for(self, positions: int) -> int:
        """Blocks needed to hold ``positions`` token positions."""
        return -(-max(positions, 0) // self.block_size)

    # -- alloc / free ------------------------------------------------------
    def alloc(self) -> int:
        """Allocate one block; returns its generation-tagged handle."""
        if not self._free:
            raise KVCacheOOM(
                f"KV cache out of blocks: {self._live} live / "
                f"{self.num_blocks - 1} usable ({self.block_size} positions "
                "per block); free completed requests or grow num_blocks")
        bid = self._free.pop()
        self._live += 1
        return (self._gen[bid] << _GEN_SHIFT) | bid

    def alloc_many(self, n: int) -> list[int]:
        """Allocate ``n`` blocks atomically — all or none (a partial grab
        under OOM would strand blocks on a request that cannot run)."""
        if n > len(self._free):
            raise KVCacheOOM(
                f"KV cache cannot serve {n} blocks: {len(self._free)} free "
                f"of {self.num_blocks - 1} usable ({self._live} live)")
        return [self.alloc() for _ in range(n)]

    def block_id(self, handle: int) -> int:
        """The physical block id behind a handle, checked for staleness."""
        bid = handle & _ID_MASK
        gen = handle >> _GEN_SHIFT
        if bid <= 0 or bid >= self.num_blocks:
            raise StaleBlockError(f"not a block handle: {handle:#x}")
        if self._gen[bid] != gen:
            raise StaleBlockError(
                f"stale KV block handle {handle:#x}: block {bid} is at "
                f"generation {self._gen[bid]}, handle carries {gen} "
                "(the owner freed it; this handle is dead forever)")
        return bid

    def free(self, handle: int) -> None:
        """Return a block to the pool; the handle (and every copy of it)
        is stale forever after (generation bump)."""
        bid = self.block_id(handle)  # staleness check first
        if not self._gen[bid] == handle >> _GEN_SHIFT:  # pragma: no cover
            raise StaleBlockError(f"stale handle {handle:#x}")
        # a live handle whose block already sits on the free list cannot
        # exist (free bumps the generation), but guard the invariant anyway
        if bid in self._free:  # pragma: no cover - defensive
            raise DoubleFreeError(f"block {bid} already free")
        self._gen[bid] += 1
        self._free.append(bid)
        self._live -= 1

    def free_many(self, handles) -> None:
        for h in handles:
            self.free(h)


def block_table_view(alloc: BlockAllocator, handles, width: int) -> np.ndarray:
    """Render a request's block-handle list as the padded physical-id row
    the paged attention path indexes through.

    Logical page ``j`` of the sequence lives in physical block
    ``table[j]``; entries past ``len(handles)`` point at the reserved null
    block (reads there are masked out by the length mask, writes only
    happen from inactive slots).  Every handle is staleness-checked — a
    table can never be built over freed memory.
    """
    if len(handles) > width:
        raise ValueError(f"block table width {width} cannot hold "
                         f"{len(handles)} blocks")
    row = np.full((width,), NULL_BLOCK, np.int32)
    for j, h in enumerate(handles):
        row[j] = alloc.block_id(h)
    return row
