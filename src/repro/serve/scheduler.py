"""Continuous-batching scheduler: slot/admission bookkeeping over the
paged KV pool.

The engine owns the model steps; this module owns the *policy*:

* ``max_batch`` decode **slots**; submitted requests wait in a FIFO queue
  and are admitted as slots free (continuous batching at step
  granularity — a finishing request's slot turns over next step, it never
  waits for its batch-mates).
* admission is **fully funded**: a request is admitted only when the pool
  can hand it every block it may ever touch (padded prefill span and all
  ``max_new_tokens`` decode positions, ``alloc_many`` all-or-none).  A
  running request can therefore never hit :class:`~.kv_cache.KVCacheOOM`
  mid-decode — overload shows up as queueing delay, not as a corrupted or
  aborted sequence (the same loud-at-the-edge stance as the allocator).
* prefill is **chunked and interleaved**: each engine step runs at most
  ONE prefill chunk (for the earliest-admitted still-prefilling slot)
  alongside the decode step for every decoding slot, so a long prompt
  costs its neighbours one chunk of latency per step, never a full-prompt
  stall.
* ``finish`` frees the sequence's blocks (generation-bumped — every
  handle the slot held is stale forever) and clears the slot.

The scheduler is pure host-side bookkeeping (deques, lists, int32 block
tables); everything device-shaped stays in the engine.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from .kv_cache import BlockAllocator, KVCacheOOM, block_table_view

#: sequence states (a slot holds a PREFILL or DECODE sequence; WAITING
#: sequences live in the queue, not in a slot)
WAITING = "waiting"
PREFILL = "prefill"
DECODE = "decode"


@dataclasses.dataclass
class Sequence:
    """A request bound to a slot: its KV blocks, block-table row, and
    prefill progress.  ``fed`` counts prompt *positions written to KV*
    (chunk-padded, so it can overshoot the prompt; the pad-tail garbage is
    overwritten by decode before any mask exposes it)."""

    req: object                  # serve.engine.Request
    handles: list                # generation-tagged block handles (owned)
    table: np.ndarray            # (table_width,) int32 physical block ids
    admit_seq: int               # admission order (prefill priority)
    state: str = PREFILL
    fed: int = 0

    @property
    def prompt_len(self) -> int:
        return len(self.req.prompt)

    @property
    def prefill_done(self) -> bool:
        return self.fed >= self.prompt_len


class Scheduler:
    """Admit/evict policy over ``max_batch`` slots and a block pool."""

    def __init__(self, alloc: BlockAllocator, *, max_batch: int,
                 prefill_chunk: int, table_width: int) -> None:
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.alloc = alloc
        self.max_batch = max_batch
        self.prefill_chunk = prefill_chunk
        self.table_width = table_width
        self.waiting: collections.deque = collections.deque()
        self.slots: list[Optional[Sequence]] = [None] * max_batch
        self._admitted = 0

    # -- capacity ----------------------------------------------------------
    def positions_needed(self, req) -> int:
        """Every KV position the request may ever write: the chunk-padded
        prefill span or prompt+decode tail, whichever reaches further."""
        s = len(req.prompt)
        c = self.prefill_chunk
        padded = -(-s // c) * c
        return max(padded, s + req.max_new_tokens)

    def blocks_needed(self, req) -> int:
        return self.alloc.blocks_for(self.positions_needed(req))

    def check_admissible(self, req) -> None:
        """Reject (loudly, at submit time) a request that could *never* be
        admitted — larger than the table or the whole pool."""
        need = self.blocks_needed(req)
        if need > self.table_width:
            raise ValueError(
                f"request {req.rid}: needs {need} KV blocks but the block "
                f"table holds {self.table_width} (raise max_seq or shrink "
                f"prompt+max_new_tokens)")
        if need > self.alloc.num_blocks - 1:
            raise ValueError(
                f"request {req.rid}: needs {need} KV blocks but the pool "
                f"has {self.alloc.num_blocks - 1} (raise num_blocks)")

    # -- queue / admission -------------------------------------------------
    def submit(self, req) -> None:
        self.check_admissible(req)
        self.waiting.append(req)

    def requeue(self, reqs) -> None:
        """Re-queue evicted requests at the FRONT of the waiting queue, in
        the given order (recovery replay: re-admission order must equal the
        original submission order).  Bypasses ``check_admissible`` — these
        requests were admissible once and graceful degradation means an
        unfundable request *waits* on the shrunk world rather than fails."""
        for req in reversed(list(reqs)):
            self.waiting.appendleft(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def admit(self) -> list[int]:
        """Fill free slots FIFO while the pool can fully fund the head of
        the queue; returns the newly-filled slot indices.  Head-of-line
        blocking is deliberate: admission order == submission order, which
        the token-identity oracle test relies on."""
        filled = []
        for i in range(self.max_batch):
            if self.slots[i] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            try:
                handles = self.alloc.alloc_many(self.blocks_needed(req))
            except KVCacheOOM:
                break                      # pool full: wait for an evict
            self.waiting.popleft()
            self.slots[i] = Sequence(
                req=req, handles=handles,
                table=block_table_view(self.alloc, handles, self.table_width),
                admit_seq=self._admitted)
            self._admitted += 1
            filled.append(i)
        return filled

    # -- per-step work selection ------------------------------------------
    def prefill_slot(self) -> Optional[int]:
        """The ONE slot that prefills this step: earliest-admitted sequence
        still working through its prompt (None when all slots decode)."""
        best, best_seq = None, None
        for i, s in enumerate(self.slots):
            if s is not None and s.state == PREFILL:
                if best is None or s.admit_seq < best_seq:
                    best, best_seq = i, s.admit_seq
        return best

    def decode_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots)
                if s is not None and s.state == DECODE]

    # -- deadlines ---------------------------------------------------------
    @staticmethod
    def _past_deadline(req, now_step: int) -> bool:
        deadline = getattr(req, "deadline_steps", None)
        start = getattr(req, "submit_step", None)
        return (deadline is not None and start is not None
                and now_step - start >= deadline)

    def expire(self, now_step: int) -> list:
        """Abandon every waiting or running request whose deadline has
        passed (``deadline_steps`` engine steps since submission): running
        ones are evicted (blocks freed, slot opened), waiting ones leave
        the queue; each is marked ``expired`` and ``done``.  Returns the
        expired requests — partial output stays on the request, truncated,
        never corrupted."""
        out = []
        for i, s in enumerate(self.slots):
            if s is not None and self._past_deadline(s.req, now_step):
                out.append(self.evict(i))
        if self.waiting:
            keep = collections.deque()
            for req in self.waiting:
                (out if self._past_deadline(req, now_step)
                 else keep).append(req)
            self.waiting = keep
        for req in out:
            req.expired = True
            req.done = True
        return out

    # -- eviction ----------------------------------------------------------
    def evict(self, i: int):
        """Free slot ``i`` and return its request *unchanged* (recovery
        replay / deadline expiry — the caller decides the request's fate;
        :meth:`finish` is the normal completion path)."""
        seq = self.slots[i]
        if seq is None:
            raise ValueError(f"slot {i} is already empty")
        req = seq.req
        self.finish(i)
        return req

    def finish(self, i: int) -> None:
        """Evict slot ``i``: free its blocks (handles go stale forever) and
        open the slot for the next admit."""
        seq = self.slots[i]
        if seq is None:
            raise ValueError(f"slot {i} is already empty")
        self.alloc.free_many(seq.handles)
        seq.handles = []
        self.slots[i] = None
