"""Serving engine: continuous batching over a paged KV cache, with decode
collectives driven by ONE persistent plan group per token step.

Architecture (dense/moe families):

* **paged KV** — one preallocated block slab
  (:func:`~repro.models.transformer.init_paged_cache`), blocks owned per
  request through :class:`~.kv_cache.BlockAllocator` handles; decode
  attention reads through per-request block tables
  (:func:`~repro.models.transformer.decode_step_paged`).
* **continuous batching** — :class:`~.scheduler.Scheduler` admits/evicts
  at step granularity; each engine step runs at most one B=1 prefill
  *chunk* (long prompts never stall running decodes) plus one full-width
  decode step.
* **fixed decode shape** — decode always runs the full ``max_batch``
  batch; inactive slots carry token 0, length 0, and an all-null block
  table (their garbage writes land in the reserved null block).  Because
  the compiled decode function and each row's float math are batch-
  composition-independent, continuous-batched output is **token-identical
  to the one-request-at-a-time oracle** — the contract
  ``tests/test_serve_engine.py`` pins.
* **per-request RNG** — sampling keys are
  ``fold_in(fold_in(PRNGKey(seed), rid), step)``; a request's sampled
  tokens never depend on which other requests share its batch (the old
  engine-wide ``split`` chain did — that was the PR-8 bugfix).
* **decode plan group** — per-token tensor-parallel control-plane sync
  (sampled tokens + active mask broadcast from tp root 0, the
  sample-on-rank-0 idiom) is built ONCE at engine init as two persistent
  ``bcast_init`` plans fused into one ``plan_group("decode-tp")``; every
  token step is a single ``group.start()/wait()`` pair — no per-token ABI
  work, and a ``CallCounter`` attached via ``attach_tool`` counts exactly
  one ``decode-tp`` call per sampling step.

ssm/hybrid families keep the legacy static-batch path (no KV pages to
page).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kv_cache import BlockAllocator
from .scheduler import DECODE, Scheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # -- robustness bookkeeping (PR 9) ------------------------------------
    #: engine steps from submission before the request is abandoned
    #: (None: no deadline); measured against ``stats["steps"]``
    deadline_steps: Optional[int] = None
    submit_step: Optional[int] = None  # stamped by ServeEngine.submit
    retries: int = 0                   # replay count (supervisor recovery)
    expired: bool = False              # deadline passed; done, no more tokens
    failed: bool = False               # dropped after max_retries replays


def sample(logits, key, temperature: float, top_k: int):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[..., -1:], -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class DecodeSync:
    """The per-token decode collective, persistent-plan-group edition.

    Sampling happens on the tensor-parallel root; the sampled token vector
    and the active-slot mask are broadcast to the other tp ranks so every
    rank feeds identical tokens into the next decode step (at tp=1 the
    broadcast is the identity, but the plan group still runs — which is
    what lets a 1-device test count it).  Both broadcasts are built ONCE as
    persistent plans and fused into one ``plan_group`` named
    ``"decode-tp"``; :meth:`step` is a single ``start()/wait()`` pair.

    :meth:`step_pooled` runs the same two broadcasts through the pooled
    nonblocking ``ibcast``/``waitall`` path — the bitwise reference the
    multidev battery compares the group against.
    """

    NAME = "decode-tp"

    def __init__(self, abi, comm, max_batch: int, mesh, *,
                 wait_timeout_s: Optional[float] = None) -> None:
        from jax.sharding import PartitionSpec as P

        from ..core.compat import shard_map

        self.abi = abi
        self.comm = comm
        self.mesh = mesh     # kept for supervisor rebuilds on a survivor comm
        # deadline for the group/pooled waits: None blocks forever (the
        # faithful hang on a dropped broadcast); a bound turns the drop into
        # PAX_ERR_TIMEOUT, which the supervisor retries and escalates.  Read
        # per call — the shard_map below is eager, so a live change applies
        # to the very next token step.
        self.wait_timeout_s = wait_timeout_s
        ex = jax.ShapeDtypeStruct((max_batch,), jnp.int32)
        self._p_tok = abi.bcast_init(ex, 0, comm)
        self._p_act = abi.bcast_init(ex, 0, comm)
        self.group = abi.plan_group([self._p_tok, self._p_act],
                                    name=self.NAME)

        # the collectives bind mesh axis names, so the start/wait pair runs
        # under an *eager* shard_map (payloads replicated): each call
        # re-drives the plan protocol and the tool interposition — one
        # before/after per token step, which is what the counting test pins
        def _group_call(tok, act):
            outs = abi.wait(self.group.start([tok, act]),
                            timeout_s=self.wait_timeout_s)
            return outs[0], outs[1]

        def _pooled_call(tok, act):
            outs = abi.waitall([abi.ibcast(tok, 0, comm),
                                abi.ibcast(act, 0, comm)],
                               timeout_s=self.wait_timeout_s)
            return outs[0], outs[1]

        spec = (P(), P())
        self._group_call = shard_map(_group_call, mesh=mesh,
                                     in_specs=spec, out_specs=spec)
        self._pooled_call = shard_map(_pooled_call, mesh=mesh,
                                      in_specs=spec, out_specs=spec)

    def reset(self) -> None:
        """Abort a start whose wait timed out (the post-timeout contract):
        force the group and member plans inactive so the next token step
        starts on a clean slot instead of a wedged one."""
        self.group.reset()
        self._p_tok.reset()
        self._p_act.reset()

    def step(self, tokens: np.ndarray, active: np.ndarray):
        """ONE group start/wait for the whole token step."""
        tok, act = self._group_call(jnp.asarray(tokens), jnp.asarray(active))
        tok, act = np.asarray(tok), np.asarray(act)
        # corruption folded into the wire payload in-trace surfaces here,
        # at materialization (no-op when integrity mode is off)
        self.abi.verify_clean((tok, act), "decode-tp sync")
        return tok, act

    def step_pooled(self, tokens: np.ndarray, active: np.ndarray):
        """The pooled ``i*`` reference path (two requests, one waitall)."""
        tok, act = self._pooled_call(jnp.asarray(tokens), jnp.asarray(active))
        tok, act = np.asarray(tok), np.asarray(act)
        self.abi.verify_clean((tok, act), "decode-tp pooled sync")
        return tok, act

    def free(self) -> None:
        self.group.free()
        self._p_tok.free()
        self._p_act.free()


class ServeEngine:
    """Continuous-batching engine over ``max_batch`` decode slots."""

    def __init__(self, api, params, *, max_batch: int = 4, max_seq: int = 512,
                 dist=None, eos_id: Optional[int] = None,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 prefill_chunk: int = 32, seed: int = 0) -> None:
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.dist = dist
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.seed = seed
        self._base_key = jax.random.PRNGKey(seed)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "prefill_chunks": 0, "requests": 0, "steps": 0,
                      "expired": 0}
        self.last_expired: list = []   # requests expired by the last step()
        self.paged = self.cfg.family in ("dense", "moe")
        self.decode_sync: Optional[DecodeSync] = None

        if self.paged:
            from ..models import transformer
            width = -(-max_seq // block_size)
            if num_blocks is None:
                num_blocks = max_batch * width + 1   # +1: reserved null block
            self.block_size = block_size
            self.prefill_chunk = prefill_chunk
            self.alloc = BlockAllocator(num_blocks, block_size)
            self.scheduler = Scheduler(self.alloc, max_batch=max_batch,
                                       prefill_chunk=prefill_chunk,
                                       table_width=width)
            self._pages = transformer.init_paged_cache(
                self.cfg, num_blocks, block_size)
            # the two compiled steps of the serving loop, shapes frozen:
            # prefill (1, chunk), decode (max_batch, 1); pages donated so
            # the slab updates in place on device
            self._prefill_chunk_fn = jax.jit(
                lambda p, toks, pages, table, start: transformer.
                prefill_chunk_paged(p, toks, pages, table, start,
                                    self.cfg, dist),
                donate_argnums=(2,))
            self._decode_paged = jax.jit(
                lambda p, tok, pages, tables, lengths: transformer.
                decode_step_paged(p, tok, pages, tables, lengths,
                                  self.cfg, dist),
                donate_argnums=(2,))
            if dist is not None:
                self.decode_sync = DecodeSync(dist.abi, dist.tp_comm,
                                              max_batch, dist.mesh)
        else:
            self._decode = jax.jit(
                lambda p, tok, cache, idx: api.decode_step(
                    p, tok, cache, idx, dist))

    # -- per-request RNG (batch-composition-independent) --------------------
    def _req_key(self, rid: int, step: int):
        """Key for request ``rid``'s ``step``-th sampled token: depends on
        (engine seed, rid, step) ONLY — never on batch composition."""
        return jax.random.fold_in(
            jax.random.fold_in(self._base_key, rid), step)

    def _sample_one(self, row_logits: np.ndarray, req: Request) -> int:
        if req.temperature <= 0.0:
            return int(np.argmax(row_logits))
        key = self._req_key(req.rid, len(req.out_tokens))
        return int(sample(jnp.asarray(row_logits), key,
                          float(req.temperature), int(req.top_k)))

    def _append(self, req: Request, tok: int) -> None:
        req.out_tokens.append(tok)
        if self.eos_id is not None and tok == self.eos_id:
            req.done = True
        if len(req.out_tokens) >= req.max_new_tokens:
            req.done = True

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request (admitted by the next :meth:`step` with a free
        slot and enough KV blocks)."""
        if not self.paged:
            raise NotImplementedError(
                f"submit/step serving requires a paged family, not "
                f"{self.cfg.family}; use run()")
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.submit_step is None:
            req.submit_step = self.stats["steps"]  # deadline clock starts now
        self.scheduler.submit(req)
        self.stats["requests"] += 1

    def rebuild_decode_sync(self, abi, comm, mesh,
                            wait_timeout_s: Optional[float] = None) -> None:
        """Bind a fresh ``DecodeSync`` (new plans + plan group) on ``comm``
        — the supervisor's recovery hook after a tp-comm shrink.  The old
        sync must already be retired (``free()``)."""
        self.decode_sync = DecodeSync(abi, comm, self.max_batch, mesh,
                                      wait_timeout_s=wait_timeout_s)

    @property
    def has_work(self) -> bool:
        return self.paged and self.scheduler.has_work

    def generate(self, prompt: np.ndarray, *, max_new_tokens: int = 32,
                 temperature: float = 0.0, top_k: int = 0) -> np.ndarray:
        reqs = [Request(0, prompt, max_new_tokens, temperature, top_k)]
        self.run(reqs)
        return np.asarray(reqs[0].out_tokens, np.int32)

    def run(self, requests: list[Request]) -> None:
        """Serve a closed batch to completion (continuous-batched on the
        paged path; legacy static batching for ssm/hybrid)."""
        if self.paged:
            for r in requests:
                self.submit(r)
            self.drain()
        else:
            self.stats["requests"] += len(requests)
            self._run_static(requests)

    def drain(self) -> None:
        """Step until the queue and every slot are empty."""
        while self.has_work:
            self.step()

    # -- the engine step -----------------------------------------------------
    def step(self) -> None:
        """One serving step: admit waiting requests into free slots, run at
        most one prefill chunk, then one decode step for every decoding
        slot (ending in one ``decode-tp`` plan-group start/wait)."""
        sched = self.scheduler
        self.stats["steps"] += 1
        # deadline pass first: an expired request frees its blocks before
        # admission, so its capacity funds the queue head this very step
        self.last_expired = sched.expire(self.stats["steps"])
        self.stats["expired"] += len(self.last_expired)
        sched.admit()
        i = sched.prefill_slot()
        if i is not None:
            self._prefill_step(i)
        dslots = sched.decode_slots()
        if dslots:
            self._decode_step(dslots)

    def _prefill_step(self, i: int) -> None:
        """Feed the next B=1 prompt chunk of slot ``i`` into its KV blocks;
        on the final chunk, sample the request's first token."""
        seq = self.scheduler.slots[i]
        req, C = seq.req, self.prefill_chunk
        start = seq.fed
        real = np.asarray(req.prompt[start:start + C], np.int32)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :len(real)] = real
        logits, self._pages = self._prefill_chunk_fn(
            self.params, jnp.asarray(chunk), self._pages,
            jnp.asarray(seq.table[None]), jnp.int32(start))
        seq.fed = start + C
        self.stats["prefill_tokens"] += int(len(real))
        self.stats["prefill_chunks"] += 1
        if seq.prefill_done:
            last = (seq.prompt_len - 1) - start    # last real row of chunk
            tok = self._sample_one(np.asarray(logits[0, last]), req)
            self._append(req, tok)
            if req.done:
                self.scheduler.finish(i)
            else:
                seq.state = DECODE

    def _decode_step(self, dslots: list[int]) -> None:
        """One full-width decode step.  Inactive slots run too (fixed
        shape), but with length 0 and an all-null block table: their writes
        land in the reserved null block and their logits are discarded."""
        sched = self.scheduler
        B = self.max_batch
        toks = np.zeros((B, 1), np.int32)
        lengths = np.zeros((B,), np.int32)
        tables = np.zeros((B, sched.table_width), np.int32)  # NULL_BLOCK rows
        for i in dslots:
            seq = sched.slots[i]
            toks[i, 0] = seq.req.out_tokens[-1]
            lengths[i] = seq.prompt_len + len(seq.req.out_tokens) - 1
            tables[i] = seq.table
        logits, self._pages = self._decode_paged(
            self.params, jnp.asarray(toks), self._pages,
            jnp.asarray(tables), jnp.asarray(lengths))
        self.stats["decode_steps"] += 1
        logits_np = np.asarray(logits)
        sampled = np.zeros((B,), np.int32)
        active = np.zeros((B,), np.int32)
        for i in dslots:
            sampled[i] = self._sample_one(logits_np[i], sched.slots[i].req)
            active[i] = 1
        if self.decode_sync is not None:
            sampled, active = self.decode_sync.step(sampled, active)
        for i in dslots:
            seq = sched.slots[i]
            self._append(seq.req, int(sampled[i]))
            if seq.req.done:
                sched.finish(i)

    # -- legacy static batching (ssm/hybrid: no KV pages) --------------------
    def _run_static(self, requests: list[Request]) -> None:
        """Pad all prompts to one length, prefill together, decode
        round-robin until every request finishes (the pre-PR-8 path, kept
        for the recurrent families)."""
        B = len(requests)
        S = max(len(r.prompt) for r in requests)
        tokens = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            tokens[i, S - len(r.prompt):] = r.prompt  # left-pad
        tokens = jnp.asarray(tokens)

        state = self.api.decode_init(B, self.max_seq)
        logits = None
        for t in range(S):
            logits, state = self._decode(self.params, tokens[:, t:t + 1],
                                         state, jnp.int32(t))
        idx = jnp.int32(S)
        self.stats["prefill_tokens"] += int(B * S)

        max_new = max(r.max_new_tokens for r in requests)
        cur = self._sample_rows(logits, requests)
        self._append_live(cur, requests)
        for _ in range(1, max_new):
            if all(r.done for r in requests):
                break
            logits, state = self._decode(self.params,
                                         jnp.asarray(cur)[:, None], state, idx)
            idx = idx + 1
            self.stats["decode_steps"] += 1
            cur = self._sample_rows(logits, requests)
            self._append_live(cur, requests)

    def _sample_rows(self, logits, requests: list[Request]) -> np.ndarray:
        logits_np = np.asarray(logits)
        return np.asarray([self._sample_one(logits_np[i], r)
                           for i, r in enumerate(requests)], np.int32)

    def _append_live(self, cur, requests: list[Request]) -> None:
        for i, r in enumerate(requests):
            if not r.done:
                self._append(r, int(cur[i]))
