"""Batched serving engine: continuous-batching slot manager over the
model's prefill/decode steps.

* fixed ``max_batch`` decode slots; requests queue up and are admitted as
  slots free (continuous batching at step granularity);
* prefill runs per-admission (chunked prefill is a config lever);
* decode is one jitted ``decode_step`` for the whole slot batch, KV cache
  donated (in-place on device);
* sampling: greedy / temperature / top-k.

This engine drives the decode cells of the dry-run shapes and the serve
example; the ABI is underneath every collective the sharded decode step
issues.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


def sample(logits, key, temperature: float, top_k: int):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[..., -1:], -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class ServeEngine:
    """Single-sequence-slot engine (max_batch=1 per slot group on CPU;
    batched decode across slots)."""

    def __init__(self, api, params, *, max_batch: int = 4, max_seq: int = 512,
                 dist=None, eos_id: Optional[int] = None) -> None:
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.dist = dist
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self._decode = jax.jit(
            lambda p, tok, cache, idx: api.decode_step(p, tok, cache, idx, dist))
        self._key = jax.random.PRNGKey(0)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "requests": 0}

    # -- single-request generation (prefill + decode loop) ------------------
    def generate(self, prompt: np.ndarray, *, max_new_tokens: int = 32,
                 temperature: float = 0.0, top_k: int = 0) -> np.ndarray:
        reqs = [Request(0, prompt, max_new_tokens, temperature, top_k)]
        self.run(reqs)
        return np.asarray(reqs[0].out_tokens, np.int32)

    # -- batched run ----------------------------------------------------------
    def run(self, requests: list[Request]) -> None:
        """Greedy static batching: pad all prompts to one length, prefill
        together, decode round-robin until every request finishes."""
        self.stats["requests"] += len(requests)
        B = len(requests)
        S = max(len(r.prompt) for r in requests)
        tokens = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            tokens[i, S - len(r.prompt):] = r.prompt  # left-pad
        tokens = jnp.asarray(tokens)

        from ..models import transformer, vlm

        if self.cfg.family in ("dense", "moe"):
            logits, cache, idx = transformer.prefill(
                self.params, tokens, self.cfg, self.dist, max_seq=self.max_seq)
        elif self.cfg.family in ("ssm", "hybrid"):
            # recurrent prefill: feed tokens stepwise (chunked prefill would
            # use the chunked kernels; step-wise keeps the example simple)
            state = self.api.decode_init(B, self.max_seq)
            logits = None
            for t in range(S):
                logits, state = self._decode(self.params, tokens[:, t:t + 1],
                                             state, jnp.int32(t))
            cache, idx = state, jnp.int32(S)
        else:
            raise NotImplementedError(self.cfg.family)
        self.stats["prefill_tokens"] += int(B * S)

        max_new = max(r.max_new_tokens for r in requests)
        cur = self._sample_batch(logits, requests)
        self._append_tokens(cur, requests)
        for step in range(1, max_new):
            if all(r.done for r in requests):
                break
            logits, cache = self._decode(self.params, jnp.asarray(cur)[:, None],
                                         cache, idx)
            idx = idx + 1
            self.stats["decode_steps"] += 1
            cur = self._sample_batch(logits, requests)
            self._append_tokens(cur, requests)

    def _append_tokens(self, cur, requests: list[Request]) -> None:
        """Record one sampled token per non-done request, applying that
        request's own eos / max_new_tokens cutoffs (including on the very
        first, prefill-sampled token)."""
        for i, r in enumerate(requests):
            if r.done:
                continue
            tok = int(cur[i])
            r.out_tokens.append(tok)
            if self.eos_id is not None and tok == self.eos_id:
                r.done = True
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample_batch(self, logits, requests: list[Request]) -> np.ndarray:
        """Sample one token per request honoring *that request's* sampling
        params.  Rows are grouped by (temperature, top_k) so the homogeneous
        batch (the common case) stays a single device call."""
        groups: dict[tuple[float, int], list[int]] = {}
        for i, r in enumerate(requests):
            groups.setdefault((float(r.temperature), int(r.top_k)), []).append(i)
        if len(groups) == 1:
            (temperature, top_k), _ = next(iter(groups.items()))
            return np.asarray(sample(logits, self._next_key(), temperature, top_k))
        out = np.zeros((len(requests),), np.int32)
        for (temperature, top_k), idxs in sorted(groups.items()):
            rows = sample(logits[np.asarray(idxs)], self._next_key(),
                          temperature, top_k)
            out[np.asarray(idxs)] = np.asarray(rows)
        return out
