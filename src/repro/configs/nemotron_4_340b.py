"""Nemotron-4-340B  [arXiv:2402.16819] — GQA (kv=8), squared-ReLU, LN."""
from .base import ModelConfig, ParallelismConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    d_ff=73728,
    vocab_size=256000,
    num_heads=96,
    num_kv_heads=8,
    activation="relu2",
    norm="layernorm",
    parallelism=ParallelismConfig(
        microbatch=16, remat="full", sequence_parallel=True,
        grad_sync="gspmd")  # FSDP/ZeRO via GSPMD for the 300B-class,
)
