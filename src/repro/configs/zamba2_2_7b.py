"""Zamba2-2.7B  [arXiv:2411.15242] — Mamba2 backbone + shared attention
block (every 6 layers, concat(h, emb0) input); runs long_500k."""
from .base import HybridConfig, ModelConfig, ParallelismConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    d_ff=10240,                # shared block MLP width
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    activation="geglu",
    ssm=SSMConfig(kind="mamba2", state_size=64, head_dim=64, expand=2,
                  conv_kernel=4, chunk_size=64),
    hybrid=HybridConfig(shared_attn_every=6, concat_embedding=True),
    supports_long_context=True,
    parallelism=ParallelismConfig(microbatch=4, remat="full"),
)
