"""Phi-3-vision-4.2B  [hf:microsoft/Phi-3-vision-128k-instruct] —
phi3-mini backbone + CLIP frontend STUB (precomputed patch embeddings)."""
from .base import ModelConfig, ParallelismConfig, VLMConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab_size=32064,
    num_heads=32,
    num_kv_heads=32,
    activation="swiglu",
    vlm=VLMConfig(num_patches=576, patch_embed_dim=1024),
    parallelism=ParallelismConfig(microbatch=4, remat="full"),
)
