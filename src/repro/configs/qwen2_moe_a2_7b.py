"""Qwen1.5-MoE-A2.7B  [hf:Qwen/Qwen1.5-MoE-A2.7B]  — 4 shared + 60 routed top-4.

EP divisibility: 60 routed experts are padded to 64 for the 16-way model
axis (DESIGN.md §Arch-applicability); padding experts get no router mass.
"""
from .base import ModelConfig, MoEConfig, ParallelismConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    d_ff=1408,                 # routed expert width
    vocab_size=151936,
    num_heads=16,
    num_kv_heads=16,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        num_experts=60,
        padded_experts=64,
        num_shared_experts=4,
        top_k=4,
        expert_d_ff=1408,
        parallelism="ep",
        capacity_factor=1.25,
    ),
    parallelism=ParallelismConfig(microbatch=4, remat="full"),
)
