"""ChatGLM3-6B  [arXiv:2406.12793] — 2d RoPE (half dims), GQA kv=2, QKV bias."""
from .base import ModelConfig, ParallelismConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    d_ff=13696,
    vocab_size=65024,
    num_heads=32,
    num_kv_heads=2,
    activation="swiglu",
    qkv_bias=True,
    rope_fraction=0.5,        # rotary on half the head dims ("RoPE 2d")
    parallelism=ParallelismConfig(microbatch=4, remat="full"),
)
