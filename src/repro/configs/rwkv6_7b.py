"""RWKV6-7B "Finch"  [arXiv:2404.05892] — attention-free, data-dependent
decay; O(1) state => runs the long_500k cell."""
from .base import ModelConfig, ParallelismConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk_size=32),
    supports_long_context=True,
    parallelism=ParallelismConfig(microbatch=4, remat="full"),
)
