"""Grok-1 314B  [hf:xai-org/grok-1; unverified] — 8 experts top-2.

8 experts don't divide the 16-way model axis, so experts use TP-MoE
(d_ff sharded over model; DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig, MoEConfig, ParallelismConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    d_ff=32768,
    vocab_size=131072,
    num_heads=48,
    num_kv_heads=8,
    activation="geglu",
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        expert_d_ff=32768,
        parallelism="tp",
        capacity_factor=1.25,
    ),
    parallelism=ParallelismConfig(
        microbatch=16, remat="full", sequence_parallel=True,
        grad_sync="gspmd")  # FSDP/ZeRO via GSPMD for the 300B-class,
)
