"""Gemma-7B  [arXiv:2403.08295] — GeGLU, head_dim=256, tied embeddings."""
from .base import ModelConfig, ParallelismConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    d_ff=24576,
    vocab_size=256000,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    activation="geglu",
    tie_embeddings=True,
    parallelism=ParallelismConfig(microbatch=4, remat="full"),
)
