"""Config registry: ``get_config("<arch>")`` + reduced smoke variants.

The ten assigned architectures (``--arch <id>``):

    qwen2-moe-a2.7b  grok-1-314b  qwen2-0.5b  nemotron-4-340b  gemma-7b
    chatglm3-6b  whisper-tiny  rwkv6-7b  zamba2-2.7b  phi-3-vision-4.2b
"""
from __future__ import annotations

import dataclasses

from .base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    EncDecConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    ParallelismConfig,
    ShapeConfig,
    SSMConfig,
    shapes_for,
)

from . import (
    chatglm3_6b,
    gemma_7b,
    grok_1_314b,
    nemotron_4_340b,
    phi_3_vision_4_2b,
    qwen2_0_5b,
    qwen2_moe_a2_7b,
    rwkv6_7b,
    whisper_tiny,
    zamba2_2_7b,
)

_REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_moe_a2_7b, grok_1_314b, qwen2_0_5b, nemotron_4_340b, gemma_7b,
        chatglm3_6b, whisper_tiny, rwkv6_7b, zamba2_2_7b, phi_3_vision_4_2b,
    )
}

ARCH_NAMES = tuple(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; available: {ARCH_NAMES}") from None


def smoke_config(name: str) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests: small layers/width,
    few experts, tiny vocab — one forward/train step on CPU."""
    cfg = get_config(name)
    changes: dict = dict(
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        max_seq_len=128,
        param_dtype="float32",
        compute_dtype="float32",
        parallelism=ParallelismConfig(microbatch=0, remat="none",
                                      scan_layers=True, grad_sync="abi"),
    )
    if cfg.num_heads:
        changes.update(num_heads=4, num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
                       head_dim=16)
    if cfg.moe is not None:
        # capacity 4.0: no token dropping, so stepwise decode == batched fwd
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, padded_experts=4, top_k=2, expert_d_ff=32,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            capacity_factor=4.0)
        changes["d_ff"] = 32
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, head_dim=16, state_size=8, chunk_size=8)
    if cfg.hybrid is not None:
        changes["num_layers"] = 4
        changes["hybrid"] = dataclasses.replace(cfg.hybrid, shared_attn_every=2)
    if cfg.encdec is not None:
        changes["encdec"] = dataclasses.replace(cfg.encdec, encoder_layers=2,
                                                encoder_frames=16)
    if cfg.vlm is not None:
        changes["vlm"] = dataclasses.replace(cfg.vlm, num_patches=8, patch_embed_dim=32)
    return dataclasses.replace(cfg, **changes)
