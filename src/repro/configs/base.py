"""Model / parallelism / run configuration dataclasses.

One ``ModelConfig`` instance per assigned architecture lives in
``repro/configs/<arch>.py``.  Shapes (the harness's train_4k / prefill_32k /
decode_32k / long_500k cells) are :class:`ShapeConfig` instances shared by
all LM-family archs.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Optional


@dataclasses.dataclass(frozen=True)
class XLAFlagsConfig:
    """Declarative XLA latency-hiding / async-collective wiring.

    The explicit-collective training path (zero1 start/finish split,
    bucketed nonblocking legs, the ring backend's per-hop ``ppermute``
    schedules) is built so XLA's latency-hiding scheduler can overlap
    collectives with compute — but on GPU that scheduler and the async
    collective lowering sit behind ``XLA_FLAGS``.  This config makes the
    flag set declarative and :func:`apply_xla_flags` installs it
    idempotently before the first backend-client creation.

    GPU-only flags are emitted only when the resolved platform is GPU.
    ``enable_async_collectives`` maps to
    ``--xla_gpu_enable_pipelined_collectives``: the historical
    ``--xla_gpu_enable_async_collectives`` spelling was removed from XLA
    (unknown XLA_FLAGS abort the process at client creation — every
    spelling emitted here is validated against the pinned jaxlib).
    """

    enable_async_collectives: bool = True
    enable_latency_hiding_scheduler: bool = True
    enable_highest_priority_async_stream: bool = True
    triton_softmax_fusion: bool = True
    triton_gemm_any: bool = True
    extra: tuple[str, ...] = ()   # verbatim extra tokens, platform-agnostic

    def flags(self, platform: str) -> tuple[str, ...]:
        """The ``--flag=value`` tokens for a platform."""
        out: list[str] = []
        if platform == "gpu":
            def b(v: bool) -> str:
                return "true" if v else "false"
            out += [
                f"--xla_gpu_enable_pipelined_collectives={b(self.enable_async_collectives)}",
                f"--xla_gpu_enable_latency_hiding_scheduler={b(self.enable_latency_hiding_scheduler)}",
                f"--xla_gpu_enable_highest_priority_async_stream={b(self.enable_highest_priority_async_stream)}",
                f"--xla_gpu_enable_triton_softmax_fusion={b(self.triton_softmax_fusion)}",
                f"--xla_gpu_triton_gemm_any={b(self.triton_gemm_any)}",
            ]
        out += list(self.extra)
        return tuple(out)


def _flag_key(token: str) -> str:
    return token.split("=", 1)[0]


def apply_xla_flags(cfg: Optional[XLAFlagsConfig] = None, *,
                    platform: Optional[str] = None,
                    env: Optional[Mapping] = None) -> str:
    """Merge ``cfg``'s flags into ``env["XLA_FLAGS"]``; returns the result.

    * idempotent: applying twice is a no-op;
    * preserving: an existing token with the same ``--key=`` wins (a user's
      hand-set ``XLA_FLAGS`` — e.g. ``--xla_force_host_platform_device_count``
      in the test battery — is never overridden);
    * platform-aware: ``platform`` defaults to ``JAX_PLATFORMS`` /
      ``JAX_PLATFORM_NAME`` (first entry) or ``"gpu"`` — flags must be set
      *before* the backend client exists, so jax must not be imported to
      sniff; absent any hint we emit the GPU set, which only a GPU client
      ever parses.

    Call before the first jax operation (launchers do this at the top of
    ``main``): XLA_FLAGS is read when the backend client is created, not at
    import.
    """
    cfg = cfg or XLAFlagsConfig()
    env = os.environ if env is None else env
    if platform is None:
        hint = env.get("JAX_PLATFORMS") or env.get("JAX_PLATFORM_NAME") or ""
        hint = hint.split(",")[0].strip().lower()
        platform = {"cuda": "gpu", "rocm": "gpu"}.get(hint, hint) or "gpu"
    existing = [t for t in env.get("XLA_FLAGS", "").split() if t]
    seen = {_flag_key(t) for t in existing}
    merged = existing + [t for t in cfg.flags(platform)
                         if _flag_key(t) not in seen]
    value = " ".join(merged)
    env["XLA_FLAGS"] = value
    return value


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts
    num_shared_experts: int = 0   # always-active shared experts
    top_k: int = 2
    expert_d_ff: int = 0          # per-expert hidden width
    parallelism: str = "ep"       # "ep": experts over model axis via ABI alltoall
    #                               "tp": expert d_ff sharded over model axis
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    padded_experts: int = 0       # experts padded up for EP divisibility (0 = none)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"          # "mamba2" | "rwkv6"
    state_size: int = 64          # N (mamba) — rwkv6 state is head_dim x head_dim
    head_dim: int = 64
    expand: int = 2               # mamba d_inner = expand * d_model
    conv_kernel: int = 4
    chunk_size: int = 64          # chunked-scan block length
    dt_rank: int = 0              # 0 = auto


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    shared_attn_every: int = 6    # apply the shared attention block every k layers
    concat_embedding: bool = True # Zamba-style concat(h, emb0) input to shared block


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 4
    encoder_frames: int = 1500    # whisper 30s @ 50Hz after conv stub
    frontend: str = "stub"        # precomputed frame embeddings via input_specs()


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 576
    patch_embed_dim: int = 1024   # CLIP-L/14 hidden
    frontend: str = "stub"        # precomputed patch embeddings via input_specs()


@dataclasses.dataclass(frozen=True)
class ParallelismConfig:
    """How this arch maps onto the production mesh (runtime/sharding.py)."""

    fsdp_axes: tuple[str, ...] = ("pod", "data")  # param/optimizer sharding
    tp_axis: str = "model"
    tp_size: int = 16                 # production model-axis width; param dims
    #                                   that don't divide it evenly (e.g. GQA
    #                                   kv-heads < 16) are replicated instead
    #                                   of unevenly sharded (Megatron practice)
    sequence_parallel: bool = False   # shard long-seq activations over tp axis
    microbatch: int = 0               # 0 = no grad accumulation
    remat: str = "full"               # "none" | "full" | "dots"
    scan_layers: bool = True
    grad_sync: str = "abi"            # "abi" explicit | "gspmd" implicit
    grad_compression: Optional[str] = None  # None | "bf16" | "int8"
    zero1: bool = True                # shard optimizer state over fsdp axes
    #                                   (abi mode: explicit ZeRO-1 round trip
    #                                   through the pooled nonblocking path
    #                                   when init_state is given the dist)
    zero1_buckets: int = 1            # nonblocking buckets per zero1 round
    #                                   trip (must divide the padded shard)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    num_heads: int = 0            # 0 for attention-free archs
    num_kv_heads: int = 0
    head_dim: int = 0             # 0 = d_model // num_heads
    activation: str = "swiglu"    # swiglu | geglu | gelu | relu2 | silu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0    # chatglm "2d" rope: rotate only this fraction
    max_seq_len: int = 32768
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attention_impl: str = "xla"   # "xla" | "flash" (Pallas kernel, TPU target)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    parallelism: ParallelismConfig = dataclasses.field(default_factory=ParallelismConfig)
    # which assigned shapes are architecturally meaningful (DESIGN.md §Arch)
    supports_long_context: bool = False  # sub-quadratic -> long_500k runs

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline
        MODEL_FLOPS = 6*N*D."""
        from repro.models.model import analytic_param_count

        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model import analytic_param_count

        return analytic_param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(config: ModelConfig) -> tuple[ShapeConfig, ...]:
    """The assigned shapes that are architecturally meaningful for this arch
    (long_500k only for sub-quadratic archs — DESIGN.md §Arch-applicability)."""
    if config.supports_long_context:
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)
