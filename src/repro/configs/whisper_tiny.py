"""Whisper-tiny  [arXiv:2212.04356] — enc-dec, conv frontend STUB.

decode_32k is an architectural stretch (the real decoder caps at 448
positions); the learned position table is extended to the assigned shape.
"""
from .base import EncDecConfig, ModelConfig, ParallelismConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,              # decoder layers
    d_model=384,
    d_ff=1536,
    vocab_size=51865,
    num_heads=6,
    num_kv_heads=6,
    activation="gelu",
    norm="layernorm",
    tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=4, encoder_frames=1500),
    parallelism=ParallelismConfig(microbatch=4, remat="full"),
)
