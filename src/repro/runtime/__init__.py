"""Distributed runtime: sharding rules, dist context, fault tolerance."""
from .sharding import AxisRules, production_rules, shard, use_rules  # noqa: F401
