"""Heartbeat liveness: an *observed* failure detector built from the ABI.

PR 7's fault tier recovers from ``PAX_ERR_PROC_FAILED``, but until now the
failure itself was always *declared* — a ``faulty:`` schedule or a
hand-set ``local_failed`` view told the detector who died.  This module
closes that gap the way the MPICH extension papers prototype liveness: as
a **library walk over the existing surface**, no new ABI entries.

:class:`HeartbeatMonitor` piggybacks a periodic tick exchange over the
ABI's own ``sendrecv`` on a **dedicated duplicated communicator**
(``comm_dup``), so heartbeat traffic never contends with the workload's
plan groups and is never poisoned by a workload-comm revoke.  Each
:meth:`~HeartbeatMonitor.beat`:

* runs one ring ``sendrecv`` of the current tick over the heartbeat comm
  (eager ``shard_map``, same cost model as a ``DecodeSync`` step);
* attributes non-responders through the transport's
  ``Backend.heartbeat_silent`` hook (a rank declared dead by a ``faulty:``
  schedule stops answering — the wrapper is now one *producer* of missed
  heartbeats, not the only failure source) plus any test-injected silence;
* advances a miss-threshold → suspicion → confirmation state machine:
  a rank silent for ``miss_threshold`` consecutive ticks becomes
  *suspected*; silent for ``suspicion_ticks`` more it is *confirmed*
  failed; answering while suspected clears the suspicion (a straggler is
  not a corpse).

:meth:`~HeartbeatMonitor.install` chains the monitor's confirmed view
onto the backend's ``local_failed`` **instance attribute** — the one
funnel both the native fault hooks and the emulation recipes read — so a
heartbeat-confirmed death surfaces through ``comm_get_failed`` /
``comm_agree`` exactly like a declared one, and the standard
revoke → ack → agree → shrink walk recovers from it.  After the shrink,
:meth:`~HeartbeatMonitor.rebind` re-dups the heartbeat comm onto the
survivor communicator (confirmed corpses stay confirmed; they are
non-members of the survivor comm and filter out of its view).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..core.errors import PAX_ERR_PROC_FAILED, PaxError


class HeartbeatMonitor:
    """Miss-threshold failure detector over a duplicated heartbeat comm.

    ``miss_threshold`` consecutive missed ticks raise suspicion;
    ``suspicion_ticks`` total silent ticks in the suspected state (the
    suspicion tick included) confirm the death.  A rank is therefore
    confirmed after exactly ``miss_threshold + suspicion_ticks - 1``
    consecutive silent ticks — the edge the unit tests pin.
    """

    def __init__(self, abi, comm, mesh, *, miss_threshold: int = 3,
                 suspicion_ticks: int = 2) -> None:
        if miss_threshold < 1:
            raise ValueError(f"miss_threshold must be >= 1, got {miss_threshold}")
        if suspicion_ticks < 1:
            raise ValueError(f"suspicion_ticks must be >= 1, got {suspicion_ticks}")
        self.abi = abi
        self.comm = comm
        self.mesh = mesh
        self.miss_threshold = miss_threshold
        self.suspicion_ticks = suspicion_ticks
        self.tick = 0
        self.last_seen: dict[int, int] = {}
        self.suspected: dict[int, int] = {}   # rank -> tick suspicion began
        self.confirmed: set[int] = set()
        self._injected: set[int] = set()
        self._installed: Optional[tuple] = None
        # heartbeats ride their own duplicated comm: never revoked by the
        # workload walk, never sharing the workload's plan slots
        self.hb_comm = abi.comm_dup(comm)
        self._build_exchange()

    # -- membership ---------------------------------------------------------
    def members(self) -> list[int]:
        info = self.abi.comms.info(self.comm, allow_revoked=True)
        return [r for r in range(info.full_size) if r not in info.excludes]

    def _build_exchange(self) -> None:
        from jax.sharding import PartitionSpec as P

        from ..core.compat import shard_map

        abi, hb = self.abi, self.hb_comm
        members = self.members()
        # ring over the members in full-rank space (excludes skipped): every
        # member sends its tick to the next and hears from the previous —
        # one silent rank starves exactly its ring neighbour's receive
        perm = [(members[i], members[(i + 1) % len(members)])
                for i in range(len(members))]

        def _beat(x):
            return abi.sendrecv(x, perm, hb)

        self._exchange = shard_map(_beat, mesh=self.mesh,
                                   in_specs=P(), out_specs=P())

    # -- test hooks ---------------------------------------------------------
    def inject_silence(self, rank: int) -> None:
        """Make ``rank`` stop answering (test hook; the ``faulty:`` wrapper
        injects the same way through ``heartbeat_silent``)."""
        self._injected.add(rank)

    def clear_silence(self, rank: int) -> None:
        self._injected.discard(rank)

    def _silent_now(self) -> set[int]:
        silent = set(self._injected)
        fn = getattr(self.abi.backend, "heartbeat_silent", None)
        if fn is not None:
            silent.update(fn(self.hb_comm))
        return silent

    # -- the beat -----------------------------------------------------------
    def beat(self) -> tuple:
        """One heartbeat round; returns the currently-confirmed failures.

        The tick exchange's ``PAX_ERR_PROC_FAILED`` is absorbed here (a
        failed heartbeat is an *observation*, not an error); ``REVOKED``
        and every other error propagate — the heartbeat comm is ours and
        nothing should be revoking it.
        """
        self.tick += 1
        exchanged = True
        try:
            self._exchange(jnp.full((1,), self.tick, jnp.int32))
        except PaxError as e:
            if e.code != PAX_ERR_PROC_FAILED:
                raise
            exchanged = False
        silent = self._silent_now()
        members = self.members()
        if exchanged or silent:
            responders = {r for r in members if r not in silent}
        else:
            # the exchange died with no transport attribution: trust nobody
            # this tick (conservative — everyone's miss counter advances)
            responders = set()
        for r in members:
            if r in responders:
                self.last_seen[r] = self.tick
                self.suspected.pop(r, None)
                continue
            if r in self.confirmed:
                continue
            misses = self.tick - self.last_seen.get(r, 0)
            if r not in self.suspected and misses >= self.miss_threshold:
                self.suspected[r] = self.tick
            began = self.suspected.get(r)
            if began is not None and self.tick - began + 1 >= self.suspicion_ticks:
                self.suspected.pop(r)
                self.confirmed.add(r)
        return self.failed(self.comm)

    # -- the detector view --------------------------------------------------
    def failed(self, comm) -> tuple:
        """Confirmed failures that are members of ``comm`` — the shape of
        ``Backend.local_failed``, which :meth:`install` chains onto."""
        try:
            info = self.abi.comms.info(comm, allow_revoked=True)
        except PaxError:
            return ()
        if not info.axes:
            return ()
        return tuple(r for r in sorted(self.confirmed)
                     if r not in info.excludes and r < info.full_size)

    def install(self) -> "HeartbeatMonitor":
        """Chain the monitor onto the backend's ``local_failed`` funnel.

        Set as an *instance attribute* on the backend, so the native fault
        hooks (rebound class functions reading ``self.local_failed``), the
        emulation recipes (``EmulationContext.local_failed``) and the
        Mukautuva adapter all observe the union of the transport's own
        view and the monitor's confirmed deaths.
        """
        if self._installed is not None:
            return self
        backend = self.abi.backend
        inner = backend.local_failed
        monitor = self

        def local_failed(comm):
            seen = tuple(inner(comm))
            return seen + tuple(r for r in monitor.failed(comm)
                                if r not in seen)

        backend.local_failed = local_failed
        self._installed = (backend, inner)
        return self

    def uninstall(self) -> None:
        if self._installed is None:
            return
        backend, inner = self._installed
        backend.local_failed = inner
        self._installed = None

    # -- recovery -----------------------------------------------------------
    def rebind(self, survivor_comm) -> None:
        """Move the heartbeat onto the post-shrink survivor communicator.

        Confirmed corpses stay confirmed (they are non-members of the
        survivor comm, so :meth:`failed` filters them from its view);
        suspicion and miss counters reset — the survivors just proved
        themselves live by completing the shrink agreement.
        """
        self.comm = survivor_comm
        self.hb_comm = self.abi.comm_dup(survivor_comm)
        self._build_exchange()
        self.suspected.clear()
        for r in self.members():
            self.last_seen[r] = self.tick
