"""DistContext: the distributed-runtime handle threaded through the stack.

Bundles the ABI context, the mesh, the axis rules and the standard
communicators (data-parallel group, tensor/expert-parallel group).  Model
and training code receive this object and never touch backend internals —
the paper's implementation-agnosticism carried through the whole framework.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax

from ..core import PAX_COMM_WORLD, PaxABI, pax_init
from .sharding import AxisRules, production_rules


@dataclasses.dataclass
class DistContext:
    abi: PaxABI
    mesh: jax.sharding.Mesh
    rules: AxisRules
    dp_axes: tuple[str, ...]
    tp_axis: str
    dp_comm: int
    tp_comm: int
    world: int = PAX_COMM_WORLD
    # optional second context whose backend compresses on the wire
    abi_compressed: Optional[PaxABI] = None
    # persistent zero1 collective plans + their Startall groups
    # (grad_sync.Zero1Plans), built once by train_loop.init_state when the
    # ZeRO-1 flat layout is active; kept as-is across re-inits whose layout
    # matches (the ABI's layout-keyed plan cache makes rebuilds identity)
    zero1_plans: Optional[object] = None

    @property
    def dp_size(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.dp_axes)

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    def drop_zero1_plans(self) -> None:
        """Retire the zero1 plans' and groups' request slots (layout change
        or teardown); the next ``init_state`` re-plans from scratch."""
        if self.zero1_plans is not None:
            self.zero1_plans.free()
            self.zero1_plans = None


def make_dist(
    mesh: jax.sharding.Mesh,
    *,
    impl: Optional[str] = None,
    tools=(),
    sequence_parallel: bool = False,
    compression: Optional[str] = None,
    integrity: Optional[bool] = None,
) -> DistContext:
    """Build the distributed context over ``mesh``.

    ``impl`` is a backend name (``pax_init`` resolution rules) or a prebuilt
    ``Backend`` instance — the fault-injection path hands a composed
    ``FaultyBackend`` straight through.  ``integrity`` opts into the
    checksummed-wire mode (default: ``PAX_WIRE_INTEGRITY``); the flag rides
    the ABI context, so every plan/group this context compiles carries it.
    """
    abi = pax_init(mesh, impl=impl, tools=tools, integrity=integrity)
    names = tuple(mesh.axis_names)
    tp_axis = "model" if "model" in names else names[-1]
    dp_axes = tuple(a for a in names if a != tp_axis)
    dp_comm = abi.comm_from_axes(dp_axes, "dp") if dp_axes else abi.comms.info(PAX_COMM_WORLD).handle
    tp_comm = abi.comm_from_axes((tp_axis,), "tp")
    rules = production_rules(
        pod="pod" in names,
        sequence_parallel=sequence_parallel,
        tp_axis=tp_axis,
        data_axes=tuple(a for a in dp_axes if a != "pod"),
        axis_sizes=dict(mesh.shape),
        mesh=mesh,
    )
    abi_c = None
    if compression in ("int8", "bf16"):
        abi_c = pax_init(mesh, impl=f"ring-{compression}", tools=tools)
        abi_c.comm_from_axes(dp_axes, "dp")  # mirror handle allocation order
    dist = DistContext(abi, mesh, rules, dp_axes, tp_axis, dp_comm, tp_comm,
                       abi_compressed=abi_c)
    return dist


def survivor_mesh(mesh: jax.sharding.Mesh, failed_ranks) -> jax.sharding.Mesh:
    """The dense mesh over the devices that survive ``failed_ranks``.

    Ranks are linearized positions in ``mesh.devices.flat`` (the ABI's rank
    convention).  The data-parallel leading axis shrinks by the number of
    casualties; every non-data axis keeps its extent, so model-parallel
    groups stay intact — elastic-dp recovery, not re-sharding.  The failure
    set must therefore be closed under model-parallel groups (with tp=1,
    any set works).
    """
    failed = frozenset(failed_ranks)
    devices = [d for r, d in enumerate(mesh.devices.flat) if r not in failed]
    names = tuple(mesh.axis_names)
    tail = [mesh.shape[a] for a in names[1:]]
    tail_prod = math.prod(tail) if tail else 1
    if not devices or len(devices) % tail_prod:
        raise ValueError(
            f"cannot shrink mesh {dict(mesh.shape)} by ranks {sorted(failed)}: "
            f"{len(devices)} survivors do not fill the non-data axes {tail}")
    import numpy as np

    shaped = np.array(devices, dtype=object).reshape(
        [len(devices) // tail_prod] + tail)
    return jax.sharding.Mesh(shaped, names)


def dp_comm_of(dist: DistContext, compressed: bool) -> tuple[PaxABI, int]:
    """The (abi, comm) pair to use for gradient traffic."""
    if compressed and dist.abi_compressed is not None:
        # handles are allocated in the same order in both contexts
        return dist.abi_compressed, dist.dp_comm
    return dist.abi, dist.dp_comm
