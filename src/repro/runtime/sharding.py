"""Logical-axis sharding rules (MaxText-style) + constraint helper.

Model code annotates activations with *logical* axes:

    x = shard(x, "batch", "seq", "embed")

and the active :class:`AxisRules` maps logical -> mesh axes.  The default
production mapping:

    batch   -> ("pod", "data")     (data parallel, incl. the pod axis)
    seq     -> "model" IF sequence_parallel else None
    heads   -> "model"             (tensor parallel)
    ffn     -> "model"
    vocab   -> "model"
    embed   -> None                (replicated; FSDP shards the *params*)
    experts -> "model"             (expert parallel)
    kv      -> "model"             (decode-time KV-head sharding)

Param shardings combine FSDP (over ``fsdp`` axes) with TP (over ``tp``):
see the per-module ``spec_*`` functions in repro.models.*.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    rules: dict[str, Axis]
    enabled: bool = True
    # concrete mesh for plain-jit contexts (bare-P constraints need an
    # ambient mesh; under jit-without-set_mesh we build a NamedSharding)
    mesh: object = None
    # mesh axis sizes; when known, constraints that would shard a dimension
    # unevenly are dropped (uneven shardings inside partial-manual shard_map
    # regions crash the XLA SPMD partitioner; evenness is also what a
    # production config wants anyway)
    axis_sizes: dict = dataclasses.field(default_factory=dict)

    def to_spec(self, *logical: Optional[str]) -> P:
        return P(*(self.rules.get(name) if name else None for name in logical))

    def _axes_size(self, axes: Axis) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            return self.axis_sizes.get(axes, 1)
        out = 1
        for a in axes:
            out *= self.axis_sizes.get(a, 1)
        return out

    def to_spec_for(self, shape: tuple, *logical: Optional[str]) -> P:
        parts = []
        used: set = set()
        for dim, name in zip(shape, logical):
            axes = self.rules.get(name) if name else None
            if axes is not None and self.axis_sizes:
                size = self._axes_size(axes)
                if size <= 1 or dim % size != 0:
                    axes = None
            # a mesh axis may appear at most once per spec (e.g. with
            # sequence parallelism both 'seq' and 'heads' map to the tp
            # axis: the earlier dimension wins)
            if axes is not None:
                flat = (axes,) if isinstance(axes, str) else tuple(axes)
                if any(a in used for a in flat):
                    axes = None
                else:
                    used.update(flat)
            parts.append(axes)
        return P(*parts)


def production_rules(
    *,
    pod: bool = True,
    sequence_parallel: bool = False,
    tp_axis: str = "model",
    data_axes: tuple[str, ...] = ("data",),
    axis_sizes: Optional[dict] = None,
    mesh=None,
) -> AxisRules:
    batch = (("pod",) + data_axes) if pod else data_axes
    return AxisRules(
        mesh=mesh,
        rules={
            "batch": batch,
            "seq": tp_axis if sequence_parallel else None,
            "kv_seq": None,
            "heads": tp_axis,
            "kv_heads": tp_axis,
            "ffn": tp_axis,
            "vocab": tp_axis,
            "embed": None,
            "experts": tp_axis,
            "state": None,
        },
        axis_sizes=dict(axis_sizes or {}),
    )


_current: contextvars.ContextVar[Optional[AxisRules]] = contextvars.ContextVar(
    "axis_rules", default=None
)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    token = _current.set(rules)
    try:
        yield
    finally:
        _current.reset(token)


def current_rules() -> Optional[AxisRules]:
    return _current.get()


def _manual_axes() -> frozenset:
    """Axes that are Manual in the current abstract mesh (inside a
    partial-manual shard_map region, the dp axes)."""
    try:
        ctx = jax.sharding.get_abstract_mesh()
        if ctx is None or not ctx.axis_names:
            return frozenset()
        return frozenset(
            name for name, t in zip(ctx.axis_names, ctx.axis_types)
            if t == jax.sharding.AxisType.Manual
        )
    except Exception:
        return frozenset()


def _strip_axes(spec: P, drop: frozenset) -> P:
    parts = []
    for p in tuple(spec):
        if p is None:
            parts.append(None)
        elif isinstance(p, tuple):
            kept = tuple(a for a in p if a not in drop)
            parts.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            parts.append(None if p in drop else p)
    return P(*parts)


def shard(x, *logical: Optional[str]):
    """Apply a sharding constraint if rules are active; no-op otherwise.

    Uses bare PartitionSpec constraints (legal under plain jit and inside
    partial-manual shard_map bodies).  Axes that are Manual in the ambient
    mesh (the dp axes of the ABI train step) are stripped from the spec —
    constraints may only reference Auto axes there; referencing a Manual
    axis raises, which previously silently disabled ALL constraints.
    """
    rules = _current.get()
    if rules is None or not rules.enabled:
        return x
    spec = rules.to_spec_for(x.shape, *logical)
    manual = _manual_axes()
    if manual:
        spec = _strip_axes(spec, manual)
        target = spec  # inside shard_map: bare P against the abstract mesh
    elif rules.mesh is not None:
        from jax.sharding import NamedSharding

        target = NamedSharding(rules.mesh, spec)  # plain jit: concrete mesh
    else:
        target = spec
    try:
        return jax.lax.with_sharding_constraint(x, target)
    except Exception:
        # no mesh context (e.g. pure-CPU smoke test): constraints are advisory
        return x


def fsdp_spec(*dims: Optional[str], fsdp: Axis, tp: str) -> P:
    """Helper for param specs: map 'fsdp'/'tp' placeholders to mesh axes."""
    out = []
    for d in dims:
        if d == "fsdp":
            out.append(fsdp)
        elif d == "tp":
            out.append(tp)
        else:
            out.append(d)
    return P(*out)
