"""Fault tolerance: supervised training with checkpoint/restart, step-time
watchdog, and bounded-retry restart on failure.

What 1000-node SPMD reality allows (DESIGN.md §9): a rank failure kills the
step; recovery = restart from the latest checkpoint, possibly on a resized
mesh (elastic resharding via Checkpointer.restore(mesh=new_mesh)).  This
module provides the in-process skeleton of that supervisor:

* :class:`StepWatchdog` — records step latencies, flags stragglers
  (> k * rolling median), and exposes the restart decision hook;
* :func:`run_supervised` — drives (step_fn, state, batches) with periodic
  async checkpoints; on exception it restores the latest checkpoint and
  resumes, up to ``max_restarts`` with exponential backoff.

The simulated-failure tests (tests/test_fault.py) inject exceptions at
chosen steps and assert exactly-once-per-step semantics after recovery.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Callable, Iterable, Optional

import jax

from ..checkpoint.checkpointer import Checkpointer

log = logging.getLogger("repro.fault")


class StepWatchdog:
    def __init__(self, window: int = 32, straggler_factor: float = 3.0) -> None:
        self.times: deque[float] = deque(maxlen=window)
        self.factor = straggler_factor
        self.stragglers: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step was a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.factor * med:
                is_straggler = True
                self.stragglers.append((step, dt))
                log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt, med)
        self.times.append(dt)
        return is_straggler


@dataclasses.dataclass
class SupervisorReport:
    steps_completed: int
    restarts: int
    stragglers: int
    final_state: object
    losses: list


def run_supervised(
    step_fn: Callable,
    init_state,
    batches: Iterable,
    *,
    checkpointer: Checkpointer,
    total_steps: int,
    checkpoint_every: int = 50,
    max_restarts: int = 3,
    backoff_s: float = 0.0,
    state_like=None,
) -> SupervisorReport:
    """Run ``total_steps`` of ``state, metrics = step_fn(state, batch)`` with
    checkpoint/restart fault tolerance.

    ``batches`` must be restartable by step index: we require a callable
    ``batches(step) -> batch`` or an indexable; iterables are materialized
    per step via the callable protocol to keep data/step alignment across
    restarts (exactly-once consumption per completed step).
    """
    get_batch = batches if callable(batches) else (lambda i: batches[i])
    watchdog = StepWatchdog()
    restarts = 0
    losses = []

    state = init_state
    step = 0
    # resume from an existing checkpoint if present
    latest = checkpointer.latest_step()
    if latest is not None:
        state, step = checkpointer.restore(state_like or init_state)
        log.info("resuming from checkpoint step %d", step)

    while step < total_steps:
        try:
            t0 = time.time()
            state, metrics = step_fn(state, get_batch(step))
            loss = getattr(metrics, "loss", None)
            if loss is not None:
                losses.append(float(loss))
            watchdog.observe(step, time.time() - t0)
            step += 1
            if step % checkpoint_every == 0 or step == total_steps:
                checkpointer.save_async(step, state)
        except KeyboardInterrupt:  # pragma: no cover
            raise
        except Exception as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts at step {step}") from e
            log.warning("step %d failed (%s); restart %d/%d", step, e, restarts,
                        max_restarts)
            if backoff_s:
                time.sleep(backoff_s * (2 ** (restarts - 1)))
            checkpointer.wait()
            latest = checkpointer.latest_step()
            if latest is not None:
                state, step = checkpointer.restore(state_like or init_state)
            else:
                state, step = init_state, 0

    checkpointer.wait()
    return SupervisorReport(step, restarts, len(watchdog.stragglers), state, losses)
