"""Fault tolerance: supervised training with checkpoint/restart, step-time
watchdog, and ULFM-style elastic recovery on process failure.

What 1000-node SPMD reality allows (DESIGN.md §9): a rank failure kills the
step; recovery = restart from the latest checkpoint, possibly on a resized
mesh (elastic resharding via Checkpointer.restore(mesh=new_mesh)).  This
module provides the in-process skeleton of that supervisor:

* :class:`StepWatchdog` — records step latencies, flags stragglers
  (> k * rolling median), and decides via :meth:`StepWatchdog.on_straggler`
  whether to ride it out or to checkpoint-and-restart proactively;
* :class:`RecoveryPolicy` — how to come back from ``PAX_ERR_PROC_FAILED``:
  which communicator to revoke/shrink, and a ``rebuild`` callback that
  re-derives (step_fn, state skeleton, mesh, specs) for the survivors;
* :func:`run_supervised` — drives (step_fn, state, batches) with periodic
  async checkpoints; on exception it restores the latest checkpoint and
  resumes, up to ``max_restarts`` with exponential backoff.  When a
  :class:`RecoveryPolicy` is given and the exception is a process failure,
  the restart first walks the fault tier — revoke → ack/get_failed →
  agree → shrink — and resumes on the shrunk data-parallel world.

Unit coverage lives in tests/test_fault_tier.py; the end-to-end
kill-a-rank-mid-run legs (paxi native, minimal recipe-emulated, ompix
rc-translated) live in tests/multidev_battery.py.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Callable, Iterable, Optional

import jax

from ..checkpoint.checkpointer import Checkpointer
from ..core.errors import (
    PAX_ERR_DATA_CORRUPTION,
    PAX_ERR_PROC_FAILED,
    PAX_ERR_TIMEOUT,
    PaxError,
)

log = logging.getLogger("repro.fault")


class StepWatchdog:
    def __init__(
        self,
        window: int = 32,
        straggler_factor: float = 3.0,
        on_straggler: Optional[Callable[[int, float], str]] = None,
    ) -> None:
        self.times: deque[float] = deque(maxlen=window)
        self.factor = straggler_factor
        self.stragglers: list[tuple[int, float]] = []
        self._decide = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step was a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.factor * med:
                is_straggler = True
                self.stragglers.append((step, dt))
                log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt, med)
        self.times.append(dt)
        return is_straggler

    def on_straggler(self, step: int, dt: float) -> str:
        """The restart decision for a flagged straggler: ``"continue"`` to
        ride it out, ``"restart"`` to checkpoint now and restart the step
        loop (proactive recovery before a slow rank turns into a dead one).
        Policy is injected via the constructor's ``on_straggler`` callable;
        the default always continues.
        """
        if self._decide is None:
            return "continue"
        decision = self._decide(step, dt)
        if decision not in ("continue", "restart"):
            raise ValueError(f"on_straggler policy returned {decision!r} "
                             "(expected 'continue' or 'restart')")
        return decision


#: the transport-integrity error classes a retry can cure (or at least
#: distinguish from a rank death): a corrupted payload re-runs cleanly when
#: the fault was one-shot; a timed-out wait re-runs when the drop was
#: transient — and keeps timing out when the link is really down, which is
#: what escalation is for
TRANSPORT_ERRORS = (PAX_ERR_DATA_CORRUPTION, PAX_ERR_TIMEOUT)


@dataclasses.dataclass
class RetryPolicy:
    """Retry-with-backoff for transport faults, escalating to rank death.

    ``run(attempt)`` executes ``attempt()`` and returns its result.  A
    :class:`PaxError` whose code is in ``retryable`` (default: the two
    transport classes, ``PAX_ERR_DATA_CORRUPTION`` and ``PAX_ERR_TIMEOUT``)
    triggers: ``reset()`` (abort wedged plan/group slots — the post-timeout
    contract), an exponential backoff sleep, and a re-run.  Persistent plans
    make the re-run a bare ``start()``; a one-shot corruption therefore
    retries to a bitwise-identical result.  After ``max_retries`` failed
    re-runs the ``escalate(cause)`` hook feeds the offender into the
    rank-death funnel (typically :func:`escalate_to_failure`: heartbeat
    confirmation → ``local_failed`` → the ULFM revoke→shrink walk) and the
    final error propagates.

    ``verify`` is an optional post-hoc integrity verdict on the attempt's
    result (e.g. ``abi.verify_clean`` on materialized metrics): detection
    that is folded into values in-trace surfaces here, at host time.
    Every other error class propagates untouched — a rank death is not a
    flaky link.  ``retries``/``escalations`` account over the policy's
    lifetime (the bench and the report read them).
    """

    max_retries: int = 2
    backoff_s: float = 0.0
    verify: Optional[Callable] = None
    reset: Optional[Callable] = None
    escalate: Optional[Callable] = None
    retryable: tuple = TRANSPORT_ERRORS
    retries: int = 0
    escalations: int = 0

    def run(self, attempt: Callable, *, what: str = ""):
        tries = 0
        while True:
            try:
                out = attempt()
                if self.verify is not None:
                    self.verify(out)
                return out
            except PaxError as e:
                if e.code not in self.retryable:
                    raise
                if self.reset is not None:
                    self.reset()
                tries += 1
                if tries > self.max_retries:
                    self.escalations += 1
                    log.error("%s: transport fault persists after %d retries "
                              "(%s); escalating", what or "attempt",
                              self.max_retries, e)
                    if self.escalate is not None:
                        self.escalate(e)
                    raise
                self.retries += 1
                log.warning("%s: transport fault (%s); retry %d/%d",
                            what or "attempt", e, tries, self.max_retries)
                if self.backoff_s:
                    time.sleep(self.backoff_s * (2 ** (tries - 1)))


def escalate_to_failure(monitor, max_ticks: int = 32) -> Callable:
    """Build a :class:`RetryPolicy` ``escalate`` hook from a heartbeat
    monitor: beat until the monitor *confirms* a death (the dropping rank
    has stopped answering heartbeats — ``heartbeat_silent`` attribution),
    then raise ``PAX_ERR_PROC_FAILED`` so the existing rank-death recovery
    (``run_supervised``/``ServeSupervisor``) takes over.  If ``max_ticks``
    beats confirm nobody, return — the transport error propagates as-is
    (a corrupted wire with every rank live is not a death)."""

    def escalate(cause: BaseException) -> None:
        for _ in range(max_ticks):
            failed = monitor.beat()
            if failed:
                raise PaxError(
                    PAX_ERR_PROC_FAILED,
                    f"transport fault escalated: ranks {list(failed)} "
                    f"confirmed silent after {cause}") from cause

    return escalate


@dataclasses.dataclass
class RecoveryTarget:
    """What ``RecoveryPolicy.rebuild`` returns: the training closure for the
    survivor world.  ``mesh``/``specs`` feed ``Checkpointer.restore`` for the
    elastic reshard; ``state_like`` is the restore skeleton (its tree
    structure, not its values, is used)."""

    step_fn: Callable
    state_like: object
    mesh: Optional[jax.sharding.Mesh] = None
    specs: Optional[object] = None


@dataclasses.dataclass
class RecoveryPolicy:
    """Elastic-dp recovery from ``PAX_ERR_PROC_FAILED``.

    ``dist`` is the live context whose data-parallel communicator the
    failure poisoned.  ``rebuild(survivors, failed)`` is called after the
    shrink with the survivor count and the agreed failure set; it must
    return a :class:`RecoveryTarget` for the shrunk world (typically:
    ``survivor_mesh`` → ``make_dist`` → ``init_state``/``make_train_step``)
    and may update ``dist`` to the new context for a subsequent failure.
    """

    dist: object
    rebuild: Callable[[int, tuple], RecoveryTarget]


def _execute_recovery(policy: RecoveryPolicy,
                      monitor=None) -> RecoveryTarget:
    """The ULFM sequence over the failed data-parallel communicator:
    revoke → ack → get_failed → agree(resume) → shrink, then retire the
    plans bound to the dead world and rebuild for the survivors.  A
    heartbeat ``monitor`` rebinds onto the survivor comm after the shrink
    (its confirmed corpses are non-members there and filter out)."""
    dist = policy.dist
    abi, comm = dist.abi, dist.dp_comm
    abi.comm_revoke(comm)          # poison the comm; reset plans/groups on it
    abi.comm_failure_ack(comm)     # acknowledge the locally-detected deaths
    failed = tuple(abi.comm_get_failed(comm))
    abi.comm_agree(1, comm)        # survivors agree the failure set is stable
    survivor = abi.comm_shrink(comm)
    survivors = abi.comm_size(survivor)
    log.warning("recovered comm: %d survivors after failure of ranks %s",
                survivors, list(failed))
    dist.drop_zero1_plans()
    if monitor is not None:
        monitor.rebind(survivor)
    return policy.rebuild(survivors, failed)


@dataclasses.dataclass
class SupervisorReport:
    steps_completed: int
    restarts: int
    stragglers: int
    final_state: object
    losses: list
    # first step of this supervisor run (nonzero when resuming a previous
    # run's checkpoint): losses are recorded per step from here on
    resumed_from: int = 0
    # transport-integrity accounting (PR 10): in-step retries that cured a
    # corrupted/timed-out collective, and retry exhaustions that escalated
    # into the rank-death funnel
    transport_retries: int = 0
    transport_escalations: int = 0
    # checkpoint-integrity events: each is the loud record of a corrupt or
    # torn shard that forced a fallback to an earlier retained checkpoint
    checkpoint_fallbacks: list = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        # one loss per completed step — the replay-truncation invariant
        # (step_fns with no loss metric legitimately record nothing)
        assert not self.losses or (
            len(self.losses) == self.steps_completed - self.resumed_from
        ), (len(self.losses), self.steps_completed, self.resumed_from)


def run_supervised(
    step_fn: Callable,
    init_state,
    batches: Iterable,
    *,
    checkpointer: Checkpointer,
    total_steps: int,
    checkpoint_every: int = 50,
    max_restarts: int = 3,
    backoff_s: float = 0.0,
    state_like=None,
    watchdog: Optional[StepWatchdog] = None,
    recover: Optional[RecoveryPolicy] = None,
    retry: Optional[RetryPolicy] = None,
    monitor=None,
) -> SupervisorReport:
    """Run ``total_steps`` of ``state, metrics = step_fn(state, batch)`` with
    checkpoint/restart fault tolerance.

    ``batches`` must be restartable by step index: we require a callable
    ``batches(step) -> batch`` or an indexable; iterables are materialized
    per step via the callable protocol to keep data/step alignment across
    restarts (exactly-once consumption per completed step).

    ``recover`` arms elastic-dp recovery: a ``PAX_ERR_PROC_FAILED`` escaping
    ``step_fn`` triggers the fault-tier sequence (revoke → ack → agree →
    shrink), ``recover.rebuild`` swaps in the survivor world's step_fn and
    restore skeleton, and the latest checkpoint is restored *onto the new
    mesh* via its specs.  Without it, process failures take the plain
    same-world restart path.  ``watchdog`` may carry an ``on_straggler``
    policy; a ``"restart"`` decision checkpoints synchronously at the
    current step (zero replay) and restarts through the same bounded-retry
    backoff accounting as the exception path.

    ``retry`` (PR 10) arms in-step transport-fault recovery: a
    ``PAX_ERR_DATA_CORRUPTION``/``PAX_ERR_TIMEOUT`` escaping ``step_fn``
    (or its ``verify`` hook) re-runs THE SAME step with backoff — no
    checkpoint restore, no replay — and only retry exhaustion reaches the
    restart machinery.  ``monitor`` (a ``HeartbeatMonitor``) is installed
    onto the training backend at entry and beaten between steps, so a
    drop-induced hang is attributed by the same detector that serves; it
    rebinds onto the survivor comm after an elastic recovery.  A transport
    error surviving the retries escalates down the standard funnel: the
    monitor confirms the silent rank (``escalate_to_failure``), the
    failure surfaces as ``PAX_ERR_PROC_FAILED``, and the existing
    revoke→shrink path recovers — or, when the confirmed death shows up in
    ``comm_get_failed`` without the re-raise, the recovery walk runs
    directly off the transport error.
    """
    get_batch = batches if callable(batches) else (lambda i: batches[i])
    if watchdog is None:
        watchdog = StepWatchdog()
    if monitor is not None:
        monitor.install()
    restarts = 0
    losses: list[float] = []
    restore_mesh = None
    restore_specs = None

    def _backoff(cause: Optional[BaseException], at_step: int, why: str) -> None:
        nonlocal restarts
        restarts += 1
        if restarts > max_restarts:
            raise RuntimeError(
                f"exceeded {max_restarts} restarts at step {at_step}") from cause
        log.warning("step %d %s; restart %d/%d", at_step, why, restarts,
                    max_restarts)
        if backoff_s:
            time.sleep(backoff_s * (2 ** (restarts - 1)))

    def _restore() -> tuple:
        """Latest checkpoint → (state, step), resharded onto the recovery
        mesh when one is active, with the loss record truncated to the
        restored step (the replay steps get re-recorded — satellite of the
        exactly-once-per-step contract)."""
        checkpointer.wait()
        latest = checkpointer.latest_step()
        if latest is None:
            if restore_mesh is not None:
                raise RuntimeError(
                    "elastic recovery requires a checkpoint to reshard from, "
                    "and none was ever written")
            losses.clear()
            return init_state, 0
        state, step = checkpointer.restore(
            state_like or init_state, mesh=restore_mesh, specs=restore_specs)
        del losses[max(0, step - resumed_from):]
        return state, step

    state = init_state
    step = 0
    resumed_from = 0
    # resume from an existing checkpoint if present
    latest = checkpointer.latest_step()
    if latest is not None:
        state, step = checkpointer.restore(state_like or init_state)
        resumed_from = step
        log.info("resuming from checkpoint step %d", step)

    while step < total_steps:
        try:
            t0 = time.time()
            if retry is not None:
                # same-step transport retry: the pre-step state is still in
                # hand, so a cured fault re-records nothing and replays
                # nothing (persistent plans make the re-run a bare start)
                _s, _b = state, get_batch(step)
                state, metrics = retry.run(
                    lambda: step_fn(_s, _b), what=f"step {step}")
            else:
                state, metrics = step_fn(state, get_batch(step))
            loss = getattr(metrics, "loss", None)
            if loss is not None:
                losses.append(float(loss))
            if monitor is not None:
                monitor.beat()  # between-step liveness tick
            dt = time.time() - t0
            straggler = watchdog.observe(step, dt)
            step += 1
            if step % checkpoint_every == 0 or step == total_steps:
                checkpointer.save_async(step, state)
            if straggler and step < total_steps and \
                    watchdog.on_straggler(step - 1, dt) == "restart":
                _backoff(None, step - 1, f"straggled ({dt:.3f}s)")
                checkpointer.save(step, state)  # sync: restart replays nothing
                state, step = _restore()
        except KeyboardInterrupt:  # pragma: no cover
            raise
        except Exception as e:
            _backoff(e, step, f"failed ({e})")
            needs_recovery = (recover is not None and isinstance(e, PaxError)
                              and e.code == PAX_ERR_PROC_FAILED)
            if (not needs_recovery and recover is not None
                    and isinstance(e, PaxError)
                    and e.code in TRANSPORT_ERRORS):
                # transport error that exhausted its retries without the
                # escalate hook re-raising PROC_FAILED: the funnel's last
                # segment — if a confirmed death reached local_failed
                # (heartbeat attribution), recover; else plain restart
                needs_recovery = bool(
                    recover.dist.abi.comm_get_failed(recover.dist.dp_comm))
            if needs_recovery:
                target = _execute_recovery(recover, monitor)
                step_fn = target.step_fn
                if target.state_like is not None:
                    state_like = target.state_like
                restore_mesh = target.mesh
                restore_specs = target.specs
            state, step = _restore()

    checkpointer.wait()
    return SupervisorReport(
        step, restarts, len(watchdog.stragglers), state, losses, resumed_from,
        transport_retries=retry.retries if retry is not None else 0,
        transport_escalations=retry.escalations if retry is not None else 0,
        checkpoint_fallbacks=list(
            getattr(checkpointer, "integrity_events", ())))
