"""Pipeline parallelism: GPipe-style microbatch schedule over explicit ABI
``sendrecv`` (collective_permute) hops.

The pipeline runs inside a manual ``shard_map`` over the stage axis (the
``pod`` axis of the multi-pod mesh is the natural choice: stage hops are
the only inter-pod traffic, matching the slow-link topology).  Layers are
split into S contiguous stages; each device holds only its stage's layer
stack (params sharded over the stage axis on the layer dim).  The schedule
is the classic GPipe loop of ``M + S - 1`` ticks:

    tick t: every stage computes on its current microbatch (real or bubble)
            then activations hop stage i -> i+1 via ONE ppermute

Autodiff differentiates straight through the ppermute hops (its transpose
is the reverse permutation), so ``jax.grad`` of the pipelined loss yields
the standard GPipe backward with bubbles — no custom VJP needed.

Bubble fraction = (S-1)/(M+S-1); the §Perf log records the collective
bytes of the hop schedule from the lowered HLO.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import PAX_SUM


def pipeline_forward(
    layer_stack_fn: Callable,   # (stage_params, x) -> x  (one stage's layers)
    stage_params,               # pytree, leaves (S*L_per_stage, ...) split over stage axis
    x_microbatches,             # (M, mb, ...) microbatched input activations
    *,
    dist,
    stage_axis: str = "pod",
    broadcast_out: bool = True,
):
    """Returns (M, mb, ...) outputs as produced by the LAST stage (replicated
    to all stages when ``broadcast_out``; otherwise valid only on the last
    stage — the training path).

    Must be called inside a shard_map region where ``stage_axis`` is manual;
    ``stage_params`` leaves are the local stage's slice, ``x_microbatches``
    are replicated across stages (only stage 0 consumes them).
    """
    S = dist.mesh.shape[stage_axis]
    M = x_microbatches.shape[0]
    stage = jax.lax.axis_index(stage_axis)
    fwd_perm = [(i, i + 1) for i in range(S - 1)]

    mb_shape = x_microbatches.shape[1:]
    buf = jnp.zeros(mb_shape, x_microbatches.dtype)      # current activation
    outs = jnp.zeros((M,) + mb_shape, x_microbatches.dtype)

    def tick(t, carry):
        buf, outs = carry
        # stage 0 ingests microbatch t (if any); others keep what arrived
        def ingest():
            idx = jnp.clip(t, 0, M - 1)
            return jax.lax.dynamic_index_in_dim(x_microbatches, idx, 0, keepdims=False)

        buf = jnp.where(stage == 0, jnp.where(t < M, ingest(), buf), buf)
        y = layer_stack_fn(stage_params, buf)
        # last stage emits microbatch (t - (S-1)) when it is real
        out_idx = t - (S - 1)

        def emit(outs):
            idx = jnp.clip(out_idx, 0, M - 1)
            return jax.lax.cond(
                (out_idx >= 0) & (out_idx < M) & (stage == S - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(o, y, idx, 0),
                lambda o: o,
                outs,
            )

        outs = emit(outs)
        # hop: stage i -> i+1 (one collective_permute per tick)
        buf = dist.abi.sendrecv(y, fwd_perm, dist.pp_comm)
        return buf, outs

    buf, outs = jax.lax.fori_loop(0, M + S - 1, tick, (buf, outs))
    if broadcast_out:
        # inference: replicate the last stage's outputs everywhere.  NOTE:
        # do NOT differentiate through this path — bcast transposes to a
        # psum over stages, scaling gradients by S.  Training uses
        # ``pipelined_loss`` (masked-loss pattern) instead.
        outs = dist.abi.bcast(outs, S - 1, dist.pp_comm)
    return outs


def make_pp_dist(dist, stage_axis: str = "pod"):
    """Attach a pipeline communicator to an existing DistContext."""
    if not hasattr(dist, "pp_comm") or dist.pp_comm is None:
        dist.pp_comm = dist.abi.comm_from_axes((stage_axis,), "pp")
    return dist


def pipelined_loss(layer_stack_fn, stage_params, x_microbatches, loss_of_out,
                   *, dist, stage_axis: str = "pod"):
    """Differentiation-safe pipelined loss: the loss is evaluated on the last
    stage only and all-reduced with a stage mask, so the gradient is exact
    (no bcast-transpose double counting)."""
    S = dist.mesh.shape[stage_axis]
    stage = jax.lax.axis_index(stage_axis)
    ym = pipeline_forward(layer_stack_fn, stage_params, x_microbatches,
                          dist=dist, stage_axis=stage_axis, broadcast_out=False)
    local = loss_of_out(ym)
    masked = jnp.where(stage == S - 1, local, 0.0)
    # value = replicated total; gradient flows ONLY through the local masked
    # term (without vma tracking, psum transposes to psum and would scale
    # gradients by S — the stop_gradient split sidesteps that)
    total = dist.abi.allreduce(jax.lax.stop_gradient(masked), PAX_SUM, dist.pp_comm)
    return masked + (total - jax.lax.stop_gradient(masked))


def pipelined_loss_fn(embed_fn, layer_stack_fn, head_fn, stage_params,
                      batch, *, dist, n_microbatches: int,
                      stage_axis: str = "pod"):
    """Convenience: embed -> pipeline(stages) -> head/loss, fully inside the
    caller's shard_map region.  ``embed_fn``/``head_fn`` run replicated on
    every stage (cheap); the layer stacks are the pipelined part."""
    x = embed_fn(batch)  # (B, S, d)
    B = x.shape[0]
    M = n_microbatches
    assert B % M == 0
    xm = x.reshape((M, B // M) + x.shape[1:])

    def loss_of_out(ym):
        y = ym.reshape(x.shape)
        return head_fn(y, batch)

    return pipelined_loss(layer_stack_fn, stage_params, xm, loss_of_out,
                          dist=dist, stage_axis=stage_axis)
