"""Integer constants of the PAX ABI (paper §5.4).

The paper prescribes, for the standard MPI ABI:

* integer constants that must have *special* values are unique negative
  numbers, so an implementation can tell the user exactly which constant was
  passed when one is misused (e.g. ``MPI_ANY_TAG`` passed as a rank);
* constants combinable with XOR are powers of two;
* string-length constants take the largest value used by existing
  implementations (8192 for the library-version string; "no issues with this
  value (used by MPICH) have ever been reported");
* for maximum portability no integer constant exceeds 32767 (the smallest
  maximum of ``int`` the C standard guarantees);
* buffer address constants (``MPI_BOTTOM``, ``MPI_IN_PLACE``) must be
  distinguishable from user buffers — here they are unique sentinel objects;
* predefined attribute callbacks are ``0x0`` for the null copy/delete
  functions and ``0xD`` for the dup function.

Everything here is a compile-time constant in the C sense: plain ints known
before tracing, so they bake into jaxprs exactly like C constants bake into
object code.
"""
from __future__ import annotations

# --------------------------------------------------------------------------
# Unique negative integer constants (each value used exactly once across the
# whole ABI so errors are precisely attributable — paper §5.4).
# --------------------------------------------------------------------------
PAX_ANY_SOURCE = -1
PAX_ANY_TAG = -2
PAX_PROC_NULL = -3
PAX_ROOT = -4
PAX_UNDEFINED = -5
PAX_KEYVAL_INVALID = -6

# --------------------------------------------------------------------------
# XOR-combinable constants: powers of two (paper §5.4, e.g. MPI_MODE_*).
# --------------------------------------------------------------------------
PAX_MODE_NOCHECK = 1
PAX_MODE_NOSTORE = 2
PAX_MODE_NOPUT = 4
PAX_MODE_NOPRECEDE = 8
PAX_MODE_NOSUCCEED = 16

# --------------------------------------------------------------------------
# String length constants (array-declaration suitable; paper §5.4).
# --------------------------------------------------------------------------
PAX_MAX_PROCESSOR_NAME = 256
PAX_MAX_ERROR_STRING = 512
PAX_MAX_OBJECT_NAME = 128
PAX_MAX_LIBRARY_VERSION_STRING = 8192  # the MPICH value the paper keeps

# Largest guaranteed-portable int constant; assert discipline in tests.
PAX_INT_CONSTANT_MAX = 32767

# --------------------------------------------------------------------------
# Buffer address constants. In C these are magic pointers; here they are
# unique sentinel singletons that can never alias a user array.
# --------------------------------------------------------------------------
class _BufferSentinel:
    __slots__ = ("_name",)

    def __init__(self, name: str) -> None:
        self._name = name

    def __repr__(self) -> str:  # pragma: no cover - trivial
        return self._name


PAX_BOTTOM = _BufferSentinel("PAX_BOTTOM")
PAX_IN_PLACE = _BufferSentinel("PAX_IN_PLACE")
PAX_STATUS_IGNORE = _BufferSentinel("PAX_STATUS_IGNORE")
PAX_STATUSES_IGNORE = _BufferSentinel("PAX_STATUSES_IGNORE")

# --------------------------------------------------------------------------
# Predefined attribute callbacks (paper §5.4: "predefined attribute callbacks
# were set to 0x0 for MPI_XXX_NULL_COPY_FN and MPI_XXX_NULL_DELETE_FN, and
# 0xD for MPI_XXX_DUP_FN").
# --------------------------------------------------------------------------
PAX_NULL_COPY_FN = 0x0
PAX_NULL_DELETE_FN = 0x0
PAX_DUP_FN = 0xD

# --------------------------------------------------------------------------
# Threading levels (ordinary small ints; MPI requires them ordered).
# --------------------------------------------------------------------------
PAX_THREAD_SINGLE = 0
PAX_THREAD_FUNNELED = 1
PAX_THREAD_SERIALIZED = 2
PAX_THREAD_MULTIPLE = 3

# The integer-size "ABI string" of §5.1: A{bits-of-Aint}O{bits-of-Offset}.
# JAX arrays index with 64-bit sizes; offsets are 64-bit. One ABI, as the
# paper recommends for all 64-bit platforms.
PAX_ABI_INTEGER_MODEL = "A64O64"
PAX_AINT_BYTES = 8
PAX_OFFSET_BYTES = 8
PAX_COUNT_BYTES = 8  # max(Aint, Offset) per §5.1

PAX_VERSION = (4, 0)  # MPI standard level the ABI surface models
PAX_ABI_VERSION = (1, 0)


def unique_negative_constants() -> dict[str, int]:
    """All special-value integer constants, for uniqueness property tests."""
    return {
        name: value
        for name, value in globals().items()
        if name.startswith("PAX_") and isinstance(value, int) and value < 0
    }


def xor_constants() -> dict[str, int]:
    return {
        name: value
        for name, value in globals().items()
        if name.startswith("PAX_MODE_") and isinstance(value, int)
    }
