"""The 10-bit Huffman handle code of the PAX ABI (paper §5.4 + Appendix A).

Bit-for-bit mirror of the paper's proposal:

* Handles are small scalar tokens. ``0`` is **always invalid**, so
  uninitialized handles are detectable errors rather than legal nulls.
* All predefined constants fit in 10 bits — the "zero page" — so
  implementations that heap-allocate user handles never collide with them.
* *Null* handles are the non-zero bits of the handle kind followed by zeros.
* Handle kind is decodable from the bit pattern alone with a bitmask
  ("the modified Huffman encoding enables fast error checking by
  implementations, simply by applying a bitmask").
* Half the code space (prefix ``0b10``) is reserved for datatypes.
  Fixed-size datatypes (prefix ``0b1001``) encode ``log2(size)`` in bits
  3..5; variable-size C types (prefix ``0b1000``) do not, so their constant
  values are not a function of the platform ABI.
* Intentional gaps ("reserved") leave room for future extensions without
  breaking changes.  This module allocates three such slots for TPU dtypes
  (bfloat16, float8_e4m3, float8_e5m2) inside reserved ranges — exactly the
  extension mechanism the paper designed for.

User (non-predefined) handles live strictly above the zero page and also
encode their kind, MPICH-style, so conversions and error checks stay O(1).

**Zero-page kind table.**  Because the entire predefined constant space is
10 bits, every per-call classification query over it can be answered by one
index into a precomputed 1024-entry table instead of re-running the mask
chain (and, for the ``0b01`` object page, a linear range scan) on every
call.  :data:`ZERO_PAGE_KINDS` and :data:`ZERO_PAGE_IS_NULL` are those
tables, materialized once at import from the same bit rules the paper
specifies — the bitmask logic stays the *definition* (kept in
``_classify_zero_page`` and verified against the table by the test suite);
the table is the *dispatch* representation.  ``handle_kind``,
``check_handle`` and ``is_null`` are therefore one list index for any
predefined handle; user handles still decode by bitmask.  Init-time
specialized layers (``PaxABI._specialize``, Mukautuva's predefined-handle
pages) index these tables directly.
"""
from __future__ import annotations

import enum
from typing import Iterator

ZERO_PAGE_BITS = 10
ZERO_PAGE_SIZE = 1 << ZERO_PAGE_BITS  # 1024

# ---------------------------------------------------------------------------
# Handle kinds
# ---------------------------------------------------------------------------


class HandleKind(enum.IntEnum):
    INVALID = 0
    OP = 1
    COMM = 2
    GROUP = 3
    WIN = 4
    FILE = 5
    SESSION = 6
    MESSAGE = 7
    ERRHANDLER = 8
    REQUEST = 9
    DATATYPE = 10
    INFO = 11


# ---------------------------------------------------------------------------
# Appendix A.1 — operations: prefix 0b00001 (values 32..63)
# ---------------------------------------------------------------------------
PAX_OP_NULL = 0b0000100000  # 32
# arithmetic ops
PAX_SUM = 0b0000100001  # 33
PAX_MIN = 0b0000100010  # 34
PAX_MAX = 0b0000100011  # 35
PAX_PROD = 0b0000100100  # 36
# 0b00001001xx reserved arithmetic (37..39)
# binary ops
PAX_BAND = 0b0000101000  # 40
PAX_BOR = 0b0000101001  # 41
PAX_BXOR = 0b0000101010  # 42
# 0b000010xxxx reserved bit ops (43..47)
# logical ops
PAX_LAND = 0b0000110000  # 48
PAX_LOR = 0b0000110001  # 49
PAX_LXOR = 0b0000110010  # 50
# 0b000011xxxx reserved logical ops (51..55)
PAX_MINLOC = 0b0000111000  # 56
PAX_MAXLOC = 0b0000111001  # 57
# 0b00001110xx reserved other op (58..59)
PAX_REPLACE = 0b0000111100  # 60
PAX_NO_OP = 0b0000111101  # 61
# 0b000011111x reserved other op (62..63)

_OP_MASK = 0b1111100000
_OP_PREFIX = 0b0000100000

# ---------------------------------------------------------------------------
# Appendix A.2 — other opaque handles: prefix 0b01 (values 256..511)
# ---------------------------------------------------------------------------
# communicator
PAX_COMM_NULL = 0b0100000000  # 256
PAX_COMM_WORLD = 0b0100000001  # 257
PAX_COMM_SELF = 0b0100000010  # 258
# 0b0100000011 reserved comm (259)
# group
PAX_GROUP_NULL = 0b0100000100  # 260
PAX_GROUP_EMPTY = 0b0100000101  # 261
# 0b01000001xx reserved group (262..263)
# windows
PAX_WIN_NULL = 0b0100001000  # 264
# 0b01000010xx reserved win (265..267)
# file
PAX_FILE_NULL = 0b0100001100  # 268
# 0b01000011xx reserved file (269..271)
# session
PAX_SESSION_NULL = 0b0100010000  # 272
# message
PAX_MESSAGE_NULL = 0b0100010100  # 276
PAX_MESSAGE_NO_PROC = 0b0100010101  # 277
# 0b01000101xx reserved message (278..279)
# error handler
PAX_ERRHANDLER_NULL = 0b0100011000  # 280
PAX_ERRORS_ARE_FATAL = 0b0100011001  # 281
PAX_ERRORS_RETURN = 0b0100011010  # 282
PAX_ERRORS_ABORT = 0b0100011011  # 283
# 0b01000111xx reserved handle (284..287)
# requests
PAX_REQUEST_NULL = 0b0100100000  # 288
# 0b01001000xx reserved request (289..291)
# info (extension in the 0b01xxxxxxxx reserved space, range 296..299)
PAX_INFO_NULL = 0b0100101000  # 296
PAX_INFO_ENV = 0b0100101001  # 297

# sub-range masks for the 0b01 page (kind = bits 2..5 within the page)
_OBJ_PAGE_MASK = 0b1100000000
_OBJ_PAGE_PREFIX = 0b0100000000

_OBJ_KIND_RANGES: list[tuple[int, int, HandleKind]] = [
    (0b0100000000, 0b0100000100, HandleKind.COMM),
    (0b0100000100, 0b0100001000, HandleKind.GROUP),
    (0b0100001000, 0b0100001100, HandleKind.WIN),
    (0b0100001100, 0b0100010000, HandleKind.FILE),
    (0b0100010000, 0b0100010100, HandleKind.SESSION),
    (0b0100010100, 0b0100011000, HandleKind.MESSAGE),
    (0b0100011000, 0b0100100000, HandleKind.ERRHANDLER),
    (0b0100100000, 0b0100101000, HandleKind.REQUEST),
    (0b0100101000, 0b0100110000, HandleKind.INFO),
]

# ---------------------------------------------------------------------------
# Appendix A.3 — datatypes: prefix 0b10 (values 512..1023)
# ---------------------------------------------------------------------------
PAX_DATATYPE_NULL = 0b1000000000  # 512

# variable-size C types: prefix 0b1000 — size NOT encoded (platform-dependent)
PAX_AINT = 0b1000000001  # 513
PAX_COUNT = 0b1000000010  # 514
PAX_OFFSET = 0b1000000011  # 515
# 0b100000010x reserved (516..517), 518 reserved
PAX_PACKED = 0b1000000111  # 519
PAX_SHORT = 0b1000001000  # 520
PAX_INT = 0b1000001001  # 521
PAX_LONG = 0b1000001010  # 522
PAX_LONG_LONG = 0b1000001011  # 523
PAX_UNSIGNED_SHORT = 0b1000001100  # 524
PAX_UNSIGNED_INT = 0b1000001101  # 525
PAX_UNSIGNED_LONG = 0b1000001110  # 526
PAX_UNSIGNED_LONG_LONG = 0b1000001111  # 527
PAX_FLOAT = 0b1000010000  # 528
PAX_DOUBLE = 0b1000010001  # 529 (next in sequence after the paper's excerpt)
PAX_LONG_DOUBLE = 0b1000010010  # 530
PAX_C_BOOL = 0b1000010011  # 531

# fixed-size types: prefix 0b1001, log2(size) in bits 3..5
PAX_INT8_T = 0b1001000000  # 576
PAX_UINT8_T = 0b1001000001  # 577
PAX_FLOAT8_E5M2 = 0b1001000010  # 578  (paper's "<float 8b>" slot)
PAX_CHAR = 0b1001000011  # 579
PAX_SIGNED_CHAR = 0b1001000100  # 580
PAX_UNSIGNED_CHAR = 0b1001000101  # 581
PAX_FLOAT8_E4M3 = 0b1001000110  # 582  (reserved slot -> TPU extension)
PAX_BYTE = 0b1001000111  # 583
PAX_INT16_T = 0b1001001000  # 584
PAX_UINT16_T = 0b1001001001  # 585
PAX_FLOAT16 = 0b1001001010  # 586  (paper's "<float 16b>")
PAX_C_COMPLEX_2X8 = 0b1001001011  # 587
PAX_BFLOAT16 = 0b1001001100  # 588  (reserved 0b10010011xx slot -> TPU extension)
PAX_CXX_COMPLEX_2X8 = 0b1001001111  # 591
PAX_INT32_T = 0b1001010000  # 592
PAX_UINT32_T = 0b1001010001  # 593
PAX_FLOAT32 = 0b1001010010  # 594  (paper's "<C float 32b>")
PAX_C_COMPLEX_2X16 = 0b1001010011  # 595
PAX_INT64_T = 0b1001011000  # 600
PAX_UINT64_T = 0b1001011001  # 601
PAX_FLOAT64 = 0b1001011010  # 602  (paper's "<C float64>")
PAX_COMPLEX64 = 0b1001011011  # 603  (paper's "<C complex 2x32b>")
PAX_COMPLEX128 = 0b1001100011  # 611  (2x64b, same offset pattern, size group 16)

_DTYPE_PAGE_MASK = 0b1100000000
_DTYPE_PAGE_PREFIX = 0b1000000000
_DTYPE_FIXED_MASK = 0b1111000000
_DTYPE_FIXED_PREFIX = 0b1001000000
_DTYPE_VARIABLE_PREFIX = 0b1000000000

# ---------------------------------------------------------------------------
# Null handles: kind prefix followed by zeros (paper §5.4)
# ---------------------------------------------------------------------------
NULL_HANDLES: dict[HandleKind, int] = {
    HandleKind.OP: PAX_OP_NULL,
    HandleKind.COMM: PAX_COMM_NULL,
    HandleKind.GROUP: PAX_GROUP_NULL,
    HandleKind.WIN: PAX_WIN_NULL,
    HandleKind.FILE: PAX_FILE_NULL,
    HandleKind.SESSION: PAX_SESSION_NULL,
    HandleKind.MESSAGE: PAX_MESSAGE_NULL,
    HandleKind.ERRHANDLER: PAX_ERRHANDLER_NULL,
    HandleKind.REQUEST: PAX_REQUEST_NULL,
    HandleKind.DATATYPE: PAX_DATATYPE_NULL,
    HandleKind.INFO: PAX_INFO_NULL,
}

# ---------------------------------------------------------------------------
# User handles (above the zero page, kind-encoded, MPICH-style)
# ---------------------------------------------------------------------------
_USER_BIT = 1 << 30
_USER_KIND_SHIFT = 24
_USER_INDEX_MASK = (1 << _USER_KIND_SHIFT) - 1


def make_user_handle(kind: HandleKind, index: int) -> int:
    """Allocate-encode a non-predefined handle.

    Encodes the kind in the upper bits (so ``handle_kind`` stays a bitmask
    check) and an allocation index in the lower 24 bits.  Values are far
    above the zero page, so they can never collide with predefined constants
    — the property the paper's 10-bit code was designed to guarantee.
    """
    if not 0 <= index <= _USER_INDEX_MASK:
        raise ValueError(f"user handle index out of range: {index}")
    if kind in (HandleKind.INVALID,):
        raise ValueError("cannot allocate INVALID handles")
    return _USER_BIT | (int(kind) << _USER_KIND_SHIFT) | index


def is_user_handle(handle: int) -> bool:
    return bool(handle & _USER_BIT)


def user_handle_index(handle: int) -> int:
    if not is_user_handle(handle):
        raise ValueError(f"not a user handle: {handle:#x}")
    return handle & _USER_INDEX_MASK


def is_predefined(handle: int) -> bool:
    return 0 <= handle < ZERO_PAGE_SIZE


# ---------------------------------------------------------------------------
# Classification.  The bitmask logic below is the *definition* (pure bit
# rules, as the paper requires); the zero-page tables materialize it once at
# import so the per-call query is a single list index.
# ---------------------------------------------------------------------------


def _classify_zero_page(handle: int) -> HandleKind:
    """The paper's bitmask classification of a zero-page value (0..1023)."""
    if handle <= 0:
        return HandleKind.INVALID
    if (handle & _OP_MASK) == _OP_PREFIX:
        return HandleKind.OP
    if (handle & _DTYPE_PAGE_MASK) == _DTYPE_PAGE_PREFIX:
        return HandleKind.DATATYPE
    if (handle & _OBJ_PAGE_MASK) == _OBJ_PAGE_PREFIX:
        for lo, hi, kind in _OBJ_KIND_RANGES:
            if lo <= handle < hi:
                return kind
        return HandleKind.INVALID  # reserved object range
    return HandleKind.INVALID  # reserved 0b00... space


#: kind of every zero-page value, one list index per query (import-time
#: materialization of the mask chain above)
ZERO_PAGE_KINDS: tuple[HandleKind, ...] = tuple(
    _classify_zero_page(h) for h in range(ZERO_PAGE_SIZE)
)

_NULL_SET = frozenset(NULL_HANDLES.values())

#: null-ness of every zero-page value (all null handles are predefined)
ZERO_PAGE_IS_NULL: tuple[bool, ...] = tuple(
    h in _NULL_SET for h in range(ZERO_PAGE_SIZE)
)


def handle_kind(handle: int) -> HandleKind:
    """Decode the kind of a handle from its bit pattern alone.

    Zero-page (predefined) handles resolve through the precomputed kind
    table; user handles decode their kind field by bitmask.
    """
    if 0 <= handle < ZERO_PAGE_SIZE:
        return ZERO_PAGE_KINDS[handle]
    if handle > 0 and handle & _USER_BIT:
        kind_bits = (handle >> _USER_KIND_SHIFT) & 0xF
        try:
            return HandleKind(kind_bits)
        except ValueError:
            return HandleKind.INVALID
    return HandleKind.INVALID


def is_null(handle: int) -> bool:
    """Null handles are kind-prefix || zeros (plus MESSAGE_NO_PROC is not null)."""
    return 0 <= handle < ZERO_PAGE_SIZE and ZERO_PAGE_IS_NULL[handle]


def check_handle(handle: int, expected: HandleKind) -> None:
    """The fast error check the Huffman code enables (table index + compare)."""
    if handle_kind(handle) is not expected:
        from .errors import PAX_ERR_ARG, PaxError

        raise PaxError(
            PAX_ERR_ARG,
            f"expected {expected.name} handle, got {describe(handle)}",
        )


# ---------------------------------------------------------------------------
# Datatype bit queries (paper §5.4 / A.3)
# ---------------------------------------------------------------------------


def datatype_is_fixed_size(handle: int) -> bool:
    return (handle & _DTYPE_FIXED_MASK) == _DTYPE_FIXED_PREFIX


def datatype_is_variable_size(handle: int) -> bool:
    return (
        (handle & _DTYPE_PAGE_MASK) == _DTYPE_PAGE_PREFIX
        and not datatype_is_fixed_size(handle)
        and handle != PAX_DATATYPE_NULL
    )


def datatype_log2_size(handle: int) -> int:
    """log2(size in bytes), encoded in bits 3..5 of fixed-size handles.

    The MPICH-heritage trick (§3.3 ``MPIR_Datatype_get_basic_size``) carried
    into the standard ABI: a pure bit extraction, no memory access.
    """
    if not datatype_is_fixed_size(handle):
        raise ValueError(f"size not encoded in handle {handle:#b}")
    return (handle >> 3) & 0b111


def datatype_encoded_size(handle: int) -> int:
    """Size in bytes of a fixed-size datatype, from the handle bits alone."""
    return 1 << datatype_log2_size(handle)


# ---------------------------------------------------------------------------
# Names / introspection
# ---------------------------------------------------------------------------

PREDEFINED_NAMES: dict[int, str] = {
    value: name
    for name, value in list(globals().items())
    if name.startswith("PAX_") and isinstance(value, int) and 0 < value < ZERO_PAGE_SIZE
}


def describe(handle: int) -> str:
    """Human-readable description — 'tell the user by name what constant they
    passed' (paper §5.4)."""
    if handle in PREDEFINED_NAMES:
        return PREDEFINED_NAMES[handle]
    if handle == 0:
        return "INVALID(0, uninitialized)"
    if is_user_handle(handle):
        kind = handle_kind(handle)
        return f"user-{kind.name.lower()}-handle#{user_handle_index(handle)}"
    return f"invalid-handle({handle:#x})"


def iter_predefined(kind: HandleKind) -> Iterator[int]:
    for value in sorted(PREDEFINED_NAMES):
        if handle_kind(value) == kind:
            yield value


PREDEFINED_OPS = tuple(
    h for h in sorted(PREDEFINED_NAMES) if handle_kind(h) == HandleKind.OP
)
PREDEFINED_DATATYPES = tuple(
    h for h in sorted(PREDEFINED_NAMES) if handle_kind(h) == HandleKind.DATATYPE
)
