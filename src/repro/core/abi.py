"""The PAX ABI surface — what applications and the framework link against.

The design mirrors the paper's runtime structure (§6.2): at ``pax_init`` the
context resolves a backend (the ``dlopen``/``dlsym`` analogue lives in
``registry.py``), stacks the interposition tools (PMPI/QMPI, §4.8) around
the backend's entry points, and exposes the standard functions.  User code
holds only ABI handles; swapping the backend never requires re-tracing user
code (the "recompile-free" property).

Nonblocking operations return :class:`Request` handles.  The value is
produced eagerly in dataflow terms (XLA schedules collectives
asynchronously; on TPU the latency-hiding scheduler overlaps them with
compute), and ``wait``/``test`` introduce the consumer dependency — the MPI
overlap idiom, preserved.  The per-request temporary state (e.g. converted
datatype vectors for ``alltoallw``) lives in the request map exactly like
Mukautuva's ``std::map`` (§6.2), including the worst case where ``testall``
scans many outstanding requests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from . import handles as H
from .communicator import CommTable
from .constants import PAX_ANY_SOURCE, PAX_ANY_TAG
from .datatypes import DatatypeRegistry
from .errors import PAX_ERR_REQUEST, PAX_SUCCESS, PaxError
from .ops import OpRegistry
from .status import Status


@dataclasses.dataclass
class Request:
    """An ABI request handle plus its completion payload."""

    handle: int
    value: Any = None
    kind: str = ""
    done: bool = False
    # Mukautuva-style per-request temporaries (converted handle vectors etc.)
    temp_state: Any = None
    on_complete: Optional[Callable[["Request"], Any]] = None

    def __hash__(self) -> int:
        return self.handle


REQUEST_NULL = Request(H.PAX_REQUEST_NULL, done=True)


class PaxABI:
    """One initialized ABI context (``MPI_Init`` .. ``MPI_Finalize``)."""

    def __init__(self, backend, mesh=None, tools: Sequence = ()) -> None:
        self.backend = backend
        self.mesh = mesh if mesh is not None else backend.mesh
        # ABI-domain tables (shared with a native backend, private otherwise)
        self.comms: CommTable = getattr(backend, "comms", None) or CommTable(self.mesh)
        self.ops: OpRegistry = getattr(backend, "ops", None) or OpRegistry()
        self.datatypes: DatatypeRegistry = getattr(backend, "datatypes", None) or DatatypeRegistry()
        self.tools = list(tools)
        for t in self.tools:
            t.attach(self)
        self._requests: dict[int, Request] = {}
        self._next_request = 0
        self.finalized = False

    # ------------------------------------------------------------------
    # function-table dispatch with tool interposition (PMPI chain)
    # ------------------------------------------------------------------
    def _dispatch(self, fname: str, impl: Callable, *args, **info):
        for t in self.tools:
            t.before(fname, args, info)
        result = impl(*args)
        for t in reversed(self.tools):
            result = t.after(fname, args, info, result)
        return result

    # -- init/finalize ----------------------------------------------------
    def finalize(self) -> None:
        if self._requests:
            raise PaxError(PAX_ERR_REQUEST, f"{len(self._requests)} outstanding requests")
        self.finalized = True

    # -- identity ----------------------------------------------------------
    def comm_size(self, comm: int) -> int:
        return self._dispatch("comm_size", self.backend.size, comm)

    def comm_rank(self, comm: int):
        return self._dispatch("comm_rank", self.backend.rank, comm)

    def comm_from_axes(self, axes: Sequence[str], name: str = "") -> int:
        h = self.comms.comm_from_axes(axes, name)
        if self.backend.convention == "foreign":
            self.backend.register_comm(h, axes)
        return h

    def comm_dup(self, comm: int) -> int:
        info = self.comms.info(comm)
        return self.comm_from_axes(info.axes, info.name + "+dup")

    def comm_free(self, comm: int) -> None:
        self.comms.comm_free(comm)

    # -- datatypes ----------------------------------------------------------
    def type_size(self, datatype: int) -> int:
        H.check_handle(datatype, H.HandleKind.DATATYPE)
        return self._dispatch("type_size", self.backend.type_size, datatype)

    def type_contiguous(self, count: int, base: int) -> int:
        h = self.datatypes.type_contiguous(count, base)
        if self.backend.convention == "foreign":
            self.backend.register_datatype(h, count, base)
        return h

    def type_from_array(self, x) -> int:
        return self.datatypes.from_array(x)

    # -- user ops (callback registration) -----------------------------------
    def op_create(self, fn: Callable, *, commutative: bool = True, name: str = "") -> int:
        h = self.ops.op_create(fn, commutative=commutative, name=name)
        if self.backend.convention == "foreign":
            self.backend.register_op(h)
        return h

    def op_free(self, op: int) -> None:
        self.ops.op_free(op)

    # -- blocking collectives ------------------------------------------------
    def allreduce(self, x, op: int, comm: int, datatype: Optional[int] = None):
        H.check_handle(op, H.HandleKind.OP)
        H.check_handle(comm, H.HandleKind.COMM)
        return self._dispatch(
            "allreduce", self.backend.allreduce, x, op, comm,
            bytes=_nbytes(x, self, datatype), comm_handle=comm,
        )

    def reduce(self, x, op: int, root: int, comm: int):
        H.check_handle(op, H.HandleKind.OP)
        return self._dispatch(
            "reduce", self.backend.reduce, x, op, root, comm, bytes=_nbytes(x, self)
        )

    def bcast(self, x, root: int, comm: int):
        return self._dispatch(
            "bcast", self.backend.bcast, x, root, comm, bytes=_nbytes(x, self)
        )

    def reduce_scatter(self, x, op: int, comm: int, axis: int = 0):
        H.check_handle(op, H.HandleKind.OP)
        return self._dispatch(
            "reduce_scatter", self.backend.reduce_scatter, x, op, comm, axis,
            bytes=_nbytes(x, self),
        )

    def allgather(self, x, comm: int, axis: int = 0):
        return self._dispatch(
            "allgather", self.backend.allgather, x, comm, axis, bytes=_nbytes(x, self)
        )

    def alltoall(self, x, comm: int, split_axis: int = 0, concat_axis: int = 0):
        return self._dispatch(
            "alltoall", self.backend.alltoall, x, comm, split_axis, concat_axis,
            bytes=_nbytes(x, self),
        )

    def alltoallw(self, blocks, sendtypes: Sequence[int], recvtypes: Sequence[int], comm: int):
        for t in list(sendtypes) + list(recvtypes):
            H.check_handle(t, H.HandleKind.DATATYPE)
        return self._dispatch(
            "alltoallw", self.backend.alltoallw, blocks, tuple(sendtypes),
            tuple(recvtypes), comm, bytes=_nbytes(blocks, self),
        )

    def sendrecv(self, x, perm: Sequence[tuple[int, int]], comm: int,
                 status: Optional[Status] = None):
        y = self._dispatch(
            "sendrecv", self.backend.sendrecv, x, tuple(perm), comm,
            bytes=_nbytes(x, self),
        )
        if status is not None:
            status.SOURCE = PAX_ANY_SOURCE
            status.TAG = PAX_ANY_TAG
            status.ERROR = PAX_SUCCESS
        return y

    def barrier(self, comm: int):
        return self._dispatch("barrier", self.backend.barrier, comm)

    def scatter(self, x, root: int, comm: int, axis: int = 0):
        return self._dispatch(
            "scatter", self.backend.scatter, x, root, comm, axis, bytes=_nbytes(x, self)
        )

    def gather(self, x, root: int, comm: int, axis: int = 0):
        return self._dispatch(
            "gather", self.backend.gather, x, root, comm, axis, bytes=_nbytes(x, self)
        )

    # -- nonblocking --------------------------------------------------------
    def _new_request(self, value, kind: str, temp_state=None, on_complete=None) -> Request:
        handle = H.make_user_handle(H.HandleKind.REQUEST, self._next_request)
        self._next_request += 1
        req = Request(handle, value, kind, False, temp_state, on_complete)
        self._requests[handle] = req
        return req

    def iallreduce(self, x, op: int, comm: int) -> Request:
        return self._new_request(self.allreduce(x, op, comm), "iallreduce")

    def iallgather(self, x, comm: int, axis: int = 0) -> Request:
        return self._new_request(self.allgather(x, comm, axis), "iallgather")

    def ireduce_scatter(self, x, op: int, comm: int, axis: int = 0) -> Request:
        return self._new_request(self.reduce_scatter(x, op, comm, axis), "ireduce_scatter")

    def ialltoall(self, x, comm: int, split_axis: int = 0, concat_axis: int = 0) -> Request:
        return self._new_request(self.alltoall(x, comm, split_axis, concat_axis), "ialltoall")

    def ialltoallw(self, blocks, sendtypes, recvtypes, comm: int) -> Request:
        value = self.alltoallw(blocks, sendtypes, recvtypes, comm)
        # the converted handle vectors must stay alive until completion (§6.2)
        temp = getattr(self.backend, "last_alltoallw_temps", None)
        return self._new_request(value, "ialltoallw", temp_state=temp)

    def isendrecv(self, x, perm, comm: int) -> Request:
        return self._new_request(self.sendrecv(x, perm, comm), "isendrecv")

    def ibarrier(self, comm: int) -> Request:
        return self._new_request(self.barrier(comm), "ibarrier")

    # -- completion -----------------------------------------------------------
    def wait(self, request: Request, status: Optional[Status] = None):
        if request.handle == H.PAX_REQUEST_NULL:
            return None
        live = self._requests.pop(request.handle, None)
        if live is None and not request.done:
            raise PaxError(PAX_ERR_REQUEST, "unknown or already-completed request")
        request.done = True
        if request.on_complete is not None:
            request.value = request.on_complete(request)
        request.temp_state = None  # free converted vectors
        if status is not None:
            status.ERROR = PAX_SUCCESS
        return request.value

    def test(self, request: Request, status: Optional[Status] = None):
        """Nonblocking completion check.  In dataflow execution the value is
        always ready once traced, so test == wait that also reports flag=True;
        the cost that matters (and that bench_request_map measures) is the
        request-map lookup."""
        if request.handle not in self._requests and not request.done:
            raise PaxError(PAX_ERR_REQUEST, "unknown request")
        return True, self.wait(request, status)

    def waitall(self, requests: Sequence[Request], statuses=None):
        return [self.wait(r, None if statuses is None else statuses[i])
                for i, r in enumerate(requests)]

    def testall(self, requests: Sequence[Request], statuses=None):
        """The §6.2 worst case: every call scans the request map."""
        flag = all((r.handle in self._requests) or r.done for r in requests)
        if not flag:
            return False, None
        return True, self.waitall(requests, statuses)

    @property
    def outstanding_requests(self) -> int:
        return len(self._requests)

    # -- convenience: run a function in a manual-collective region ----------
    def shard_region(self, fn: Callable, in_specs, out_specs, axis_names=None,
                     check_vma: bool = False):
        """shard_map over this context's mesh; collectives inside may use any
        communicator whose axes are in ``axis_names`` (default: all axes).

        ``check_vma`` defaults off: MPI collective semantics guarantee
        replication of reduction results, but JAX cannot infer that through
        the generic (gather+fold) reductions the ABI uses for exotic ops.
        """
        if self.mesh is None:
            raise PaxError(PAX_ERR_REQUEST, "no mesh bound")
        kwargs = {"check_vma": check_vma}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )


def _nbytes(x, abi: PaxABI, datatype: Optional[int] = None) -> int:
    """Payload size for tool accounting; handles pytrees."""
    total = 0
    for leaf in jax.tree.leaves(x):
        if hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            if datatype is not None:
                total += leaf.size * abi.datatypes.type_size_encoded(datatype)
            else:
                total += leaf.size * np.dtype(leaf.dtype).itemsize
    return int(total)
