"""The PAX ABI surface — what applications and the framework link against.

The design mirrors the paper's runtime structure (§6.2): at ``pax_init`` the
context resolves a backend (the ``dlopen`` analogue lives in
``registry.py``), **negotiates the standard function table against it**
(the ``dlsym`` analogue: every entry point of
:data:`repro.core.abi_spec.ABI_TABLE` is resolved once, and a backend
missing an entry raises ``PAX_ERR_UNSUPPORTED_OPERATION`` at init — never
mid-step), stacks the interposition tools (PMPI/QMPI, §4.8) around the
resolved entries, and exposes the standard functions.

**Every per-entry-point method here is generated from the declarative
spec**, not hand-written: the blocking methods, their ``i*`` nonblocking
twins, the handle checks (from each argument's declared domain), and the
byte-accounting info handed to tools.  Two dispatch paths are compiled per
entry:

* a **zero-tool fast path** — handle checks + one dict lookup + the direct
  backend call, no interposition loop and no payload-size computation
  (``grad_sync`` drives this every training step);
* the tool path — the PMPI chain (``before`` outer→inner, ``after``
  inner→outer) with payload bytes computed per the entry's accounting rule.

To add an ABI entry point: add one row to ``abi_spec.ABI_TABLE`` and
implement the method on the backends that support it.  The ABI methods,
``i*`` variants, capability negotiation, and Mukautuva translation wrappers
are all derived.

Nonblocking operations return :class:`Request` handles.  The value is
produced eagerly in dataflow terms (XLA schedules collectives
asynchronously; on TPU the latency-hiding scheduler overlaps them with
compute), and ``wait``/``test`` introduce the consumer dependency — the MPI
overlap idiom, preserved.  The per-request temporary state (e.g. converted
datatype vectors for ``alltoallw``) lives in the request map exactly like
Mukautuva's ``std::map`` (§6.2), including the worst case where ``testall``
scans many outstanding requests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from . import abi_spec
from . import compat
from . import handles as H
from .communicator import CommTable
from .constants import PAX_ANY_SOURCE, PAX_ANY_TAG
from .datatypes import DatatypeRegistry
from .errors import (
    PAX_ERR_REQUEST,
    PAX_ERR_UNSUPPORTED_OPERATION,
    PAX_SUCCESS,
    PaxError,
)
from .ops import OpRegistry
from .status import Status


@dataclasses.dataclass
class Request:
    """An ABI request handle plus its completion payload."""

    handle: int
    value: Any = None
    kind: str = ""
    done: bool = False
    # Mukautuva-style per-request temporaries (converted handle vectors etc.)
    temp_state: Any = None
    on_complete: Optional[Callable[["Request"], Any]] = None

    def __hash__(self) -> int:
        return self.handle


REQUEST_NULL = Request(H.PAX_REQUEST_NULL, done=True)


class PaxABI:
    """One initialized ABI context (``MPI_Init`` .. ``MPI_Finalize``)."""

    def __init__(self, backend, mesh=None, tools: Sequence = ()) -> None:
        self.backend = backend
        self.mesh = mesh if mesh is not None else backend.mesh
        # ABI-domain tables (shared with a native backend, private otherwise)
        self.comms: CommTable = getattr(backend, "comms", None) or CommTable(self.mesh)
        self.ops: OpRegistry = getattr(backend, "ops", None) or OpRegistry()
        self.datatypes: DatatypeRegistry = getattr(backend, "datatypes", None) or DatatypeRegistry()
        # dlsym-style negotiation: resolve every function-table entry now.
        self._table: dict[str, Callable] = {}
        missing = []
        for entry in abi_spec.ABI_TABLE:
            if backend.supports(entry):
                self._table[entry.name] = getattr(backend, entry.backend_method)
            else:
                missing.append(entry.name)
        if missing:
            raise PaxError(
                PAX_ERR_UNSUPPORTED_OPERATION,
                f"backend {backend.name!r} is missing function-table entry "
                f"point(s) {missing} (init-time negotiation, paper §6.2)",
            )
        self.tools = list(tools)
        for t in self.tools:
            t.attach(self)
        self._requests: dict[int, Request] = {}
        self._next_request = 0
        self.finalized = False

    # ------------------------------------------------------------------
    # tool-path dispatch (PMPI chain); the zero-tool fast path is inlined
    # into each generated method and never reaches this.
    # ------------------------------------------------------------------
    def _dispatch_tools(self, fname: str, impl: Callable, args: tuple, info: dict):
        for t in self.tools:
            t.before(fname, args, info)
        result = impl(*args)
        for t in reversed(self.tools):
            result = t.after(fname, args, info, result)
        return result

    # -- init/finalize ----------------------------------------------------
    def finalize(self) -> None:
        if self._requests:
            raise PaxError(PAX_ERR_REQUEST, f"{len(self._requests)} outstanding requests")
        self.finalized = True

    # -- identity / registration (not per-collective dispatch) -------------
    def comm_from_axes(self, axes: Sequence[str], name: str = "") -> int:
        h = self.comms.comm_from_axes(axes, name)
        if self.backend.convention == "foreign":
            self.backend.register_comm(h, axes)
        return h

    def comm_dup(self, comm: int) -> int:
        info = self.comms.info(comm)
        return self.comm_from_axes(info.axes, info.name + "+dup")

    def comm_free(self, comm: int) -> None:
        self.comms.comm_free(comm)

    # -- datatypes ----------------------------------------------------------
    def type_contiguous(self, count: int, base: int) -> int:
        h = self.datatypes.type_contiguous(count, base)
        if self.backend.convention == "foreign":
            self.backend.register_datatype(h, count, base)
        return h

    def type_from_array(self, x) -> int:
        return self.datatypes.from_array(x)

    # -- user ops (callback registration) -----------------------------------
    def op_create(self, fn: Callable, *, commutative: bool = True, name: str = "") -> int:
        h = self.ops.op_create(fn, commutative=commutative, name=name)
        if self.backend.convention == "foreign":
            self.backend.register_op(h)
        return h

    def op_free(self, op: int) -> None:
        self.ops.op_free(op)

    # -- nonblocking request plumbing ---------------------------------------
    def _new_request(self, value, kind: str, temp_state=None, on_complete=None) -> Request:
        handle = H.make_user_handle(H.HandleKind.REQUEST, self._next_request)
        self._next_request += 1
        req = Request(handle, value, kind, False, temp_state, on_complete)
        self._requests[handle] = req
        return req

    # -- completion -----------------------------------------------------------
    def wait(self, request: Request, status: Optional[Status] = None):
        if request.handle == H.PAX_REQUEST_NULL:
            return None
        live = self._requests.pop(request.handle, None)
        if live is None and not request.done:
            raise PaxError(PAX_ERR_REQUEST, "unknown or already-completed request")
        request.done = True
        if request.on_complete is not None:
            request.value = request.on_complete(request)
        request.temp_state = None  # free converted vectors
        if status is not None:
            status.ERROR = PAX_SUCCESS
        return request.value

    def test(self, request: Request, status: Optional[Status] = None):
        """Nonblocking completion check.  In dataflow execution the value is
        always ready once traced, so test == wait that also reports flag=True;
        the cost that matters (and that bench_request_map measures) is the
        request-map lookup."""
        if request.handle not in self._requests and not request.done:
            raise PaxError(PAX_ERR_REQUEST, "unknown request")
        return True, self.wait(request, status)

    def waitall(self, requests: Sequence[Request], statuses=None):
        return [self.wait(r, None if statuses is None else statuses[i])
                for i, r in enumerate(requests)]

    def testall(self, requests: Sequence[Request], statuses=None):
        """The §6.2 worst case: every call scans the request map."""
        flag = all((r.handle in self._requests) or r.done for r in requests)
        if not flag:
            return False, None
        return True, self.waitall(requests, statuses)

    @property
    def outstanding_requests(self) -> int:
        return len(self._requests)

    # -- convenience: run a function in a manual-collective region ----------
    def shard_region(self, fn: Callable, in_specs, out_specs, axis_names=None,
                     check_vma: bool = False):
        """shard_map over this context's mesh; collectives inside may use any
        communicator whose axes are in ``axis_names`` (default: all axes).

        ``check_vma`` defaults off: MPI collective semantics guarantee
        replication of reduction results, but JAX cannot infer that through
        the generic (gather+fold) reductions the ABI uses for exotic ops.
        """
        if self.mesh is None:
            raise PaxError(PAX_ERR_REQUEST, "no mesh bound")
        return compat.shard_map(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=check_vma,
        )


def _nbytes(x, abi: PaxABI, datatype: Optional[int] = None) -> int:
    """Payload size for tool accounting; handles pytrees."""
    total = 0
    for leaf in jax.tree.leaves(x):
        if hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            if datatype is not None:
                total += leaf.size * abi.datatypes.type_size_encoded(datatype)
            else:
                total += leaf.size * np.dtype(leaf.dtype).itemsize
    return int(total)


# ---------------------------------------------------------------------------
# Method generation from the declarative function table.
#
# For each spec entry we compile (via exec, namedtuple-style) a blocking
# method with the entry's exact signature, and — when the entry declares a
# nonblocking variant — its ``i*`` twin.  The blocking method contains the
# precompiled zero-tool fast path.
# ---------------------------------------------------------------------------
_GEN_ENV = {
    "_nbytes": _nbytes,
    "PAX_ANY_SOURCE": PAX_ANY_SOURCE,
    "PAX_ANY_TAG": PAX_ANY_TAG,
    "PAX_SUCCESS": PAX_SUCCESS,
    "_check": H.check_handle,
}
_GEN_ENV.update({f"_HK_{k.name}": k for k in H.HandleKind})


def _blocking_src(entry: abi_spec.AbiEntry) -> str:
    params = abi_spec.signature_src(entry, extra_kwargs=True)
    call_args = abi_spec.call_args_src(entry)
    lines = [f"def {entry.name}(self, {params}):"]
    # handle checks / coercions from the declared argument domains
    for a in entry.args:
        if a.kind == abi_spec.DATATYPE_VEC:
            lines.append(f"    {a.name} = tuple({a.name})")
            lines.append(f"    for _t in {a.name}:")
            lines.append(f"        _check(_t, _HK_{a.check_kind.name})")
        elif a.check_kind is not None:
            lines.append(f"    _check({a.name}, _HK_{a.check_kind.name})")
        elif a.kind in (abi_spec.PERM, abi_spec.COUNTS):
            lines.append(f"    {a.name} = tuple({a.name})")
    lines.append(f"    _impl = self._table[{entry.name!r}]")
    lines.append("    if not self.tools:")
    lines.append(f"        _res = _impl({call_args})")
    lines.append("    else:")
    if entry.bytes_arg:
        dt = ", datatype" if entry.dtype_size_kwarg else ""
        bytes_expr = f"_nbytes({entry.bytes_arg}, self{dt})"
        comm_arg = next(a.name for a in entry.args if a.kind == abi_spec.COMM)
        lines.append(
            f"        _info = {{'bytes': {bytes_expr}, 'comm_handle': {comm_arg}}}"
        )
    else:
        lines.append("        _info = {}")
    lines.append(
        f"        _res = self._dispatch_tools({entry.name!r}, _impl, "
        f"({call_args},), _info)"
    )
    if entry.fills_status:
        lines.append("    if status is not None:")
        lines.append("        status.SOURCE = PAX_ANY_SOURCE")
        lines.append("        status.TAG = PAX_ANY_TAG")
        lines.append("        status.ERROR = PAX_SUCCESS")
    lines.append("    return _res")
    return "\n".join(lines) + "\n"


def _nonblocking_src(entry: abi_spec.AbiEntry) -> str:
    params = abi_spec.signature_src(entry)
    call_args = abi_spec.call_args_src(entry)
    lines = [f"def i{entry.name}(self, {params}):"]
    lines.append(f"    _value = self.{entry.name}({call_args})")
    if entry.temps:
        # converted handle vectors stay alive until completion (§6.2)
        lines.append(
            f"    _temp = getattr(self.backend, {entry.temps_attr!r}, None)"
        )
    else:
        lines.append("    _temp = None")
    lines.append(
        f"    return self._new_request(_value, 'i{entry.name}', temp_state=_temp)"
    )
    return "\n".join(lines) + "\n"


def _install_generated_methods() -> None:
    for entry in abi_spec.ABI_TABLE:
        fn = abi_spec.compile_method(_blocking_src(entry), _GEN_ENV, entry.name)
        fn.__qualname__ = f"PaxABI.{entry.name}"
        setattr(PaxABI, entry.name, fn)
        if entry.nonblocking:
            ifn = abi_spec.compile_method(
                _nonblocking_src(entry), _GEN_ENV, f"i{entry.name}"
            )
            ifn.__qualname__ = f"PaxABI.i{entry.name}"
            setattr(PaxABI, f"i{entry.name}", ifn)


_install_generated_methods()
