"""The PAX ABI surface — what applications and the framework link against.

The design mirrors the paper's runtime structure (§6.2): at ``pax_init`` the
context resolves a backend (the ``dlopen`` analogue lives in
``registry.py``), **negotiates the standard function table against it**
(the ``dlsym`` analogue: every entry point of
:data:`repro.core.abi_spec.ABI_TABLE` is resolved once), stacks the
interposition tools (PMPI/QMPI, §4.8) around the resolved entries, and
exposes the standard functions.

**Tiered, generative negotiation.**  Negotiation admits *partial* backends
the way Mukautuva admits unequal MPI implementations: a missing REQUIRED
entry still raises ``PAX_ERR_UNSUPPORTED_OPERATION`` at init, but a missing
OPTIONAL entry is *synthesized* from its spec-declared emulation recipe
(:mod:`repro.core.emulation`) when the recipe's dependency chain grounds out
in entries the backend does export — built in topological order, so
emulations chain arbitrarily deep.  Only when no chain grounds out does the
entry resolve to a raiser, deferring ``PAX_ERR_UNSUPPORTED_OPERATION`` to
the first call.  Emulated closures sit in ``self._table`` exactly like
native callables, so ``_specialize`` compiles the same per-context inline
fast path around them (and their ``i*`` twins), and tools interpose on them
identically.  :meth:`PaxABI.capabilities` reports what resolved how.

**Every per-entry-point method here is generated from the declarative
spec**, not hand-written: the blocking methods, their ``i*`` nonblocking
twins, the handle checks (from each argument's declared domain), and the
byte-accounting info handed to tools.

**Init-time specialization.**  Because negotiation resolves the whole
function table once, nothing about the per-call path is dynamic after
``pax_init`` — so :meth:`PaxABI._specialize` compiles one entry-point
function *per context instance* that closes over the resolved backend
callable and the attached tool chain directly.  The specialized zero-tool
path is handle checks + the direct backend call: no ``self._table[name]``
dict lookup, no ``if not self.tools`` branch, no bound-method re-resolution
per call.  The tool path bakes the tool tuple (``before`` outer→inner,
``after`` inner→outer) and the entry's byte-accounting rule into the
closure.  Attaching or detaching a tool (:meth:`attach_tool` /
:meth:`detach_tool`) recompiles the entry points — tool membership changes
are init-frequency events, per-call dispatch is not.  The generic
spec-generated methods remain on the class as the uninstantiated fallback.

**Persistent plans (MPI-4 ``<name>_init``).**  Every nonblocking entry also
generates a plan constructor: ``allreduce_init(x, op, comm)`` binds the
arguments (payload abstractly — shape/dtype, the dataflow edition of MPI's
bound buffer) and hoists ALL remaining per-call work to plan time: handle
checks, comm→axes lookup, the backend's op/schedule branch (native
``plan_<method>`` hooks), Mukautuva's foreign-handle conversion, emulation
recipe-chain composition with precomputed padding/slicing
(``Recipe.plan``), and the tool-interposition decision.  ``plan.start(x)``
is then an inactive-check plus a bare closure call into the backend, and
the plan's request is a *restartable* pool slot (inactive⇄active, zero
generation churn; see the PR 4 ROADMAP note for the plan-time/call-time
split and the attach_tool respecialization contract).  Emulation recipes
build lazily — on first call or first plan — and ``capabilities()`` reports
``emulated`` without forcing the build.

**Plan groups (MPI ``Startall``) and the layout-keyed plan cache (PR 5).**
:meth:`PaxABI.plan_group` fuses N plans at *group-build* time: members are
clustered by (entry, non-payload args) and each cluster resolves to one
fused run — a backend group hook stacking same-comm same-op members into a
single collective, a recipe group stage (emulated members run all their
reduce-scatter legs before any all-gather leg), or a per-member loop.  The
group owns one restartable request: ``group.start(payloads)`` is ONE
inactive-check + the fused closure, ``group.wait()`` one completion scan,
and tools see one interposition with group-summed bytes — the per-plan
fixed cost the zero1 loop used to pay N times per step is paid once.
``<name>_init`` is idempotent per layout: normalized plan signatures key a
weak per-context cache, so re-planning after re-sharding/elastic-dp costs
nothing unless the layout genuinely changed (see the PR 5 ROADMAP note).

**Free-list request pool.**  Nonblocking operations return
:class:`Request` handles.  The value is produced eagerly in dataflow terms
(XLA schedules collectives asynchronously; on TPU the latency-hiding
scheduler overlaps them with compute), and ``wait``/``test`` introduce the
consumer dependency — the MPI overlap idiom, preserved.  Requests live in a
slab of pooled slots rather than the ever-growing map of Mukautuva's
``std::map`` worst case (§6.2): the 24-bit user-handle index field holds the
slot (the per-context ``req_slot_bits`` split caps how many — default
16384) and the generation lives *above* the handle-classification bits as
an unbounded counter, so completion checks are one array index plus a
generation compare (O(1), no hashing), a freed slot's generation bump makes
use-after-wait an *exactly detected* ``PAX_ERR_REQUEST`` forever (the
generation never wraps, so a stale handle can never alias a later reuse of
its slot), and the handle space never exhausts — the old monotonically
increasing index made ``make_user_handle`` raise after 2^24 nonblocking
calls, mid-training.
Slots also recycle their ``Request`` objects in place, so a steady-state
workload (e.g. ``zero1_step``'s bucketed round trip) reuses one
preallocated request batch per step instead of allocating per bucket.
Per-request temporary state (converted datatype vectors for ``alltoallw``)
rides in the pooled request exactly like Mukautuva's map entries, freed at
completion.
"""
from __future__ import annotations

import dataclasses
import os
import time
import weakref
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import abi_spec
from . import compat
from . import emulation
from . import handles as H
from .communicator import CommTable, comm_rank_traced
from .constants import PAX_ANY_SOURCE, PAX_ANY_TAG
from .datatypes import DatatypeRegistry
from .errors import (
    PAX_ERR_DATA_CORRUPTION,
    PAX_ERR_REQUEST,
    PAX_ERR_TIMEOUT,
    PAX_ERR_UNSUPPORTED_OPERATION,
    PAX_SUCCESS,
    IncompleteValue,
    PaxError,
)
from .ops import OpRegistry
from .status import Status


@dataclasses.dataclass(eq=False, slots=True)
class Request:
    """An ABI request handle plus its completion payload.

    ``eq=False``: requests are identity objects (the pool recycles them in
    place), so equality and hashing are object identity — the default
    field-wise dataclass ``__eq__`` combined with a handle-based ``__hash__``
    would break the hash/eq contract.  ``slots=True`` keeps the pooled
    objects compact so a 1000-request ``testall`` scan stays cache-resident.
    """

    handle: int
    value: Any = None
    kind: str = ""
    done: bool = False
    # Mukautuva-style per-request temporaries (converted handle vectors etc.)
    temp_state: Any = None
    on_complete: Optional[Callable[["Request"], Any]] = None
    #: persistent (plan-owned) requests are *restartable* pool slots: wait
    #: deactivates (done=True) without retiring, start reactivates, and the
    #: slot's generation only advances when the owning plan is freed.
    persistent: bool = False


REQUEST_NULL = Request(H.PAX_REQUEST_NULL, done=True)


class Plan:
    """A persistent-operation plan (the MPI-4 ``<name>_init`` analogue).

    Built by the generated ``<name>_init`` constructors.  At plan time the
    context hoists **everything** the specialized per-call path still does
    per call: handle classification, comm→axes lookup, dtype/op conversion
    (Mukautuva converts foreign handles once), emulation-chain composition
    with precomputed padding/slicing, and the tool-interposition decision.
    ``start(payload...)`` is then a bare closure call into the backend that
    reactivates the plan's pooled request, and ``wait()`` (or the ABI-level
    ``wait``/``waitall``/``testall`` on the returned request) deactivates it.
    The request slot is allocated once and its generation never advances
    across start/wait cycles — a training loop restarts the same slot every
    step without churning Request objects or handles.  ``free()`` retires
    the slot; the handle is then stale forever (generation bump).

    Payload arguments are bound as *abstract* shapes: a plan is specific to
    the payload's shape/dtype (and to its non-payload arguments), exactly
    like an MPI persistent collective is specific to its bound buffer.
    ``attach_tool``/``detach_tool`` respecialize live plans the same way
    they respecialize the per-context entry points.

    Plans are **layout-cached** (PR 5): ``<name>_init`` with a signature
    already planned returns the same live plan (``PaxABI._plan_cache``), so
    re-planning after a layout change is free when the layout did not in
    fact change.  ``free()`` evicts the cache entry; the next same-layout
    ``<name>_init`` builds a fresh plan.
    """

    __slots__ = ("abi", "entry", "bound", "request", "freed", "_cache_key",
                 "start", "wait", "_finalizer", "__weakref__")

    def __init__(self, abi, entry, bound) -> None:
        self.abi = abi
        self.entry = entry
        self.bound = bound        # table-order args, payloads abstracted
        self.request = None       # the restartable pooled Request
        self.freed = False
        self._cache_key = None    # layout key in abi._plan_cache (if hashable)
        self._finalizer = None    # GC fallback reclaiming the slot
        # start/wait are compiled closures installed by _compile_plan

    def reset(self) -> None:
        """Force the plan inactive (escape hatch for an aborted trace that
        left a ``start`` without its ``wait``)."""
        req = self.request
        if req is not None and not self.freed:
            req.done = True
            req.value = None

    def free(self) -> None:
        """Retire the plan's request slot (``MPI_Request_free``).

        The plan must be inactive (started requests must be waited first).
        The slot returns to the pool with its generation bumped, so every
        handle the plan ever returned is stale *forever* — exactly like a
        retired nonblocking request.
        """
        if self.freed:
            return
        req = self.request
        if req is not None and not req.done:
            raise PaxError(
                PAX_ERR_REQUEST,
                f"freeing an active persistent {self.entry.name!r} plan "
                "(wait the started request first)",
            )
        self.freed = True
        abi = self.abi
        if self._finalizer is not None:
            self._finalizer.detach()
        if req is not None:
            # one definition of slot retirement, shared with the GC fallback
            _reclaim_plan_slot(abi, req, req.handle)
        abi._plans.discard(self)
        if self._cache_key is not None:
            if abi._plan_cache.get(self._cache_key) is self:
                del abi._plan_cache[self._cache_key]

        def dead(*args, **kwargs):
            raise PaxError(
                PAX_ERR_REQUEST,
                f"persistent {self.entry.name!r} plan was freed",
            )

        self.start = dead
        self.wait = dead


class PlanGroup:
    """A fused group of persistent plans (the MPI ``Startall`` analogue).

    Built by :meth:`PaxABI.plan_group` from live plans of the same context.
    At **group-build time** the members are clustered by (entry, non-payload
    arguments) and each cluster compiles to one fused run closure: a backend
    group hook (``Backend.plan_group_<method>`` — paxi/ring stack same-comm
    same-op members into ONE collective over a concatenated buffer, ring
    sharing one compressed wire across members; Mukautuva's generated group
    wrappers cache every foreign-handle conversion), the recipe's group
    builder for emulated entries (stage-fused: all members' reduce-scatter
    legs before any all-gather leg), or a per-member plan-run loop.

    The group owns ONE restartable pooled request: ``start(payloads)`` is a
    single inactive-check (for the whole group), two field writes and the
    fused closure; ``wait()`` — or ``abi.wait``/``waitall``/``testall`` on
    the returned request — deactivates it and yields the member results in
    member order.  Tool interposition is one ``before``/``after`` pair with
    group-summed byte accounting.  ``payloads`` is a sequence with one item
    per member (items for payload-less members such as ``barrier`` are
    ignored).  Members stay independently usable; a group may list the same
    (cached) plan several times — each occurrence binds its own payload
    slot.  ``attach_tool``/``detach_tool`` respecialize live groups exactly
    like plans; an aborted trace between start and wait is recovered by
    :meth:`reset`; ``free()`` retires the group's slot only (never the
    members').
    """

    __slots__ = ("abi", "name", "plans", "request", "freed",
                 "start", "wait", "_finalizer", "__weakref__")

    def __init__(self, abi, plans, name: str) -> None:
        self.abi = abi
        self.name = name
        self.plans = tuple(plans)
        self.request = None
        self.freed = False
        self._finalizer = None
        # start/wait are compiled closures installed by _compile_plan_group

    def __len__(self) -> int:
        return len(self.plans)

    def reset(self) -> None:
        """Force the group inactive (escape hatch for an aborted trace that
        left a ``start`` without its ``wait``)."""
        req = self.request
        if req is not None and not self.freed:
            req.done = True
            req.value = None

    def free(self) -> None:
        """Retire the group's request slot (members are untouched).

        The group must be inactive; every handle it ever returned goes
        stale forever (generation bump), exactly like :meth:`Plan.free`.
        """
        if self.freed:
            return
        req = self.request
        if req is not None and not req.done:
            raise PaxError(
                PAX_ERR_REQUEST,
                f"freeing an active plan group {self.name!r} "
                "(wait the started request first)",
            )
        self.freed = True
        abi = self.abi
        if self._finalizer is not None:
            self._finalizer.detach()
        if req is not None:
            _reclaim_plan_slot(abi, req, req.handle)
        abi._plan_groups.discard(self)

        def dead(*args, **kwargs):
            raise PaxError(
                PAX_ERR_REQUEST, f"plan group {self.name!r} was freed",
            )

        self.start = dead
        self.wait = dead

# ---------------------------------------------------------------------------
# Request-pool handle layout (widened, per-context).  The slot index lives in
# the 24-bit user index field (the context's ``req_slot_bits`` — default 14,
# i.e. 16384 simultaneous outstanding requests — caps the pool size, and is
# per-context configurable up to the full field).  The generation is stored
# ABOVE the handle-classification bits, at shift ``_REQ_GEN_SHIFT``: Python
# ints are unbounded, so generations never wrap and a retired handle can
# never alias a later reuse of its slot, no matter how many times the slot
# recycles.  The low 31 bits of a request handle remain a well-formed
# REQUEST user handle (kind decodes by bitmask, ``describe`` names the slot).
# ---------------------------------------------------------------------------
_REQ_SLOT_BITS = 14                      # default per-context split
_REQ_MAX_SLOTS = 1 << _REQ_SLOT_BITS
_REQ_GEN_SHIFT = 31                      # first bit above _USER_BIT (bit 30)
_REQ_HANDLE_BASE = H.make_user_handle(H.HandleKind.REQUEST, 0)
_USER_INDEX_MASK = H._USER_INDEX_MASK
_UKS = H._USER_KIND_SHIFT  # shift that exposes a user handle's kind bits


def _unavailable_entry(entry: abi_spec.AbiEntry, backend_name: str, reason: str):
    """Table slot for an optional entry that resolved neither way: calling it
    (not initializing the context) raises PAX_ERR_UNSUPPORTED_OPERATION."""

    def unavailable(*args, **kwargs):
        raise PaxError(
            PAX_ERR_UNSUPPORTED_OPERATION,
            f"{entry.name!r} is unavailable on backend {backend_name!r}: "
            f"{reason}",
        )

    unavailable.__name__ = entry.backend_method
    unavailable.__qualname__ = f"unavailable.{entry.name}"
    return unavailable


def _reclaim_plan_slot(abi: "PaxABI", req: Request, handle: int) -> None:
    """``weakref.finalize`` callback for a :class:`Plan` collected without
    ``free()``: return its slot to the pool (same retirement as ``free``).
    No-op when the plan was freed explicitly (``persistent`` cleared) or the
    slot already moved on (handle mismatch after a generation bump)."""
    if not req.persistent or req.handle != handle:
        return
    slot = handle & _USER_INDEX_MASK
    abi._req_gen[slot] += 1
    abi._req_free.append(slot)
    req.persistent = False
    req.done = True
    req.value = req.temp_state = req.on_complete = None


def _lazy_entry(abi: "PaxABI", entry: abi_spec.AbiEntry):
    """Table slot for an emulated entry whose recipe has not been built yet.

    Negotiation decides *that* the entry is emulated at init (the dependency
    chain grounds out — ``capabilities()`` reports it without forcing
    anything); the closure itself is compiled on the first call, which also
    swaps the built closure into the table and respecializes the entry.

    **Self-patching via a mutable cell** (the PR-4 footgun, fixed): the shim
    dispatches through ``cell[0]``, which starts as a build-and-call stub
    and is overwritten with the built closure by ``_build_recipe`` — so a
    callable hoisted *before* the first call pays one list index after the
    build, not the old dict-lookup-plus-branch forever.  Specialized entry
    points that captured the shim are healed the same way: their compiled
    globals are patched in place (``_entry_envs``), so warmup re-fetching
    is unnecessary anywhere."""
    state = {"impl": None}
    cell = [None]

    def _build_and_call(*args, **kwargs):
        return abi._build_recipe(entry.name)(*args, **kwargs)

    cell[0] = _build_and_call

    def lazy(*args, _cell=cell, **kwargs):
        return _cell[0](*args, **kwargs)

    lazy.__lazy_recipe__ = state
    lazy.__lazy_cell__ = cell
    lazy.__name__ = entry.backend_method
    lazy.__qualname__ = f"lazy-emulated.{entry.name}"
    return lazy


def _comm_arg_index(entry: abi_spec.AbiEntry) -> Optional[int]:
    for i, a in enumerate(entry.args):
        if a.kind == abi_spec.COMM:
            return i
    return None


def _wrap_revoke(abi: "PaxABI", inner: Callable) -> Callable:
    """The ``comm_revoke`` entry point with ABI-layer consequences attached:
    after the (native or emulated) revoke lands, plans and plan groups bound
    to the comm are reset.  Control-plane path — never specialized away."""

    def comm_revoke(comm):
        out = inner(comm)
        abi._after_revoke(comm)
        return out

    comm_revoke.__wrapped__ = inner
    comm_revoke.__name__ = "comm_revoke"
    if hasattr(inner, "__generated_src__"):
        # the wrapper adds bookkeeping around the compiled entry point; the
        # specialized source that runs underneath is unchanged
        comm_revoke.__generated_src__ = inner.__generated_src__
    return comm_revoke


# ---------------------------------------------------------------------------
# Transport-integrity tier (PR 10).
#
# The wire may lie: a corrupted payload is a *silent* wrong answer, a dropped
# message is a *hang*.  Neither is representable as a backend return code, so
# the ABI handles them at its two natural choke points:
#
# * **Checksum envelope** — opt-in (``PaxABI(integrity=True)`` /
#   ``PAX_WIRE_INTEGRITY=1``).  The plan/group compilers wrap each run
#   closure with ONE fused checksum reduction built at plan time (the PR-4/5
#   hoisting discipline — when disabled the wrap returns the closure
#   unchanged, so the off path is byte-identical to a context that never
#   heard of integrity).  Because production collectives run at trace time
#   inside shard_map regions, the verdict cannot raise there; instead a
#   failed check folds the canonical POISON fill into the result
#   (whole-payload NaN for floats, INT_MIN for ints — a bitwise pass-through
#   ``select`` when the check passes), and :meth:`PaxABI.verify_clean`
#   raises ``PAX_ERR_DATA_CORRUPTION`` at the first host materialization —
#   the same dispatch-time-injection / host-time-detection split the failure
#   probe uses for rank death.
#
#   Two per-entry rules (declared in ``abi_spec.AbiEntry.integrity``):
#   ``replicated`` (allreduce/bcast/allgather: every member must hold the
#   same bits — exact agreement of a bit-pattern checksum) and ``conserved``
#   (reduce_scatter under SUM: the value total is conserved across the
#   scatter — tolerance compare of one fused (in, out) sum pair).
#
# * **Wait timeouts** — ``wait``/``waitall``/``plan.wait``/``group.wait``
#   accept ``timeout_s``.  A dropped operation's value is the
#   :class:`IncompleteValue` sentinel planted by the injection layer; a wait
#   that meets it sleeps out the deadline and raises ``PAX_ERR_TIMEOUT``
#   **leaving the request active** — ``Plan.reset``/``PlanGroup.reset`` is
#   the abort path that re-arms the slot, so a timed-out plan is never
#   wedged.  Without a deadline the wait blocks forever: a drop is a hang,
#   faithfully.
# ---------------------------------------------------------------------------

INTEGRITY_ENV_VAR = "PAX_WIRE_INTEGRITY"

#: checksums are kept below 2**20 so every value in the agreement
#: arithmetic (sums over <= full_size members, their mean, deviations) is
#: exactly representable in float32 — detection is deterministic, not
#: probabilistic-up-to-rounding
_CK_MOD = 1048573  # largest prime below 2**20

_BITCAST_WIDTH = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}


def _bits_checksum(x):
    """Exact bit-pattern checksum of a payload (pytree or member list):
    every element's representation reduced mod ``_CK_MOD`` **before** the
    uint32 wrap-sum, then folded mod ``_CK_MOD`` again into an
    exactly-representable float32 scalar.

    The per-element reduction is what makes detection deterministic for
    structured corruption: a same-bit flip applied to every element (the
    injector's sign flip) shifts a plain wrap-sum by ``n * 2**31``, which
    vanishes mod ``2**32`` whenever ``n`` is even.  Reduced mod a prime
    first, the per-element delta becomes ``2**31 % _CK_MOD`` (nonzero, not
    a power of two), and ``n`` of them cannot cancel mod the prime for any
    payload smaller than the prime itself."""
    total = jnp.uint32(0)
    for leaf in jax.tree.leaves(x):
        if not hasattr(leaf, "dtype"):
            continue
        if leaf.dtype == jnp.bool_:
            u = jnp.asarray(leaf).astype(jnp.uint32)
        else:
            width = _BITCAST_WIDTH.get(jnp.dtype(leaf.dtype).itemsize)
            if width is None:  # 8-byte lanes (x64 only): value-fold instead
                u = jnp.asarray(leaf).astype(jnp.uint32)
            else:
                u = lax.bitcast_convert_type(leaf, width).astype(jnp.uint32)
        total = total + jnp.sum(u % jnp.uint32(_CK_MOD))
    return (total % jnp.uint32(_CK_MOD)).astype(jnp.float32)


def _value_checksum(x):
    """Value-semantic checksum for conservation laws: the float32 sum over
    every leaf (reassociation noise is covered by the relative tolerance
    in :func:`_conservation_bad`)."""
    total = jnp.float32(0)
    for leaf in jax.tree.leaves(x):
        if hasattr(leaf, "dtype"):
            total = total + jnp.sum(jnp.asarray(leaf).astype(jnp.float32))
    return total


def _member_gate(info):
    """Trace-time membership of this shard in ``info``'s comm (None when
    the comm has no excludes — every shard of the axes is a member)."""
    if not info.excludes:
        return None
    r = comm_rank_traced(info)
    return jnp.all(r != jnp.asarray(info.excludes, jnp.int32))


def _agreement_bad(ck, info, n_members: int):
    """Replicated-output rule: all members must hold the same checksum.
    Masked mean/deviation over the comm's axes (excluded shards contribute
    zero), exact in float32 by the ``_CK_MOD`` bound — deviation is 0.0
    iff every member agrees."""
    member = _member_gate(info)
    ckm = ck if member is None else jnp.where(member, ck, 0.0)
    mean = lax.psum(ckm, info.axes) / n_members
    dev = jnp.abs(ck - mean)
    if member is not None:
        dev = jnp.where(member, dev, 0.0)
    return lax.psum(dev, info.axes) > 0.25


def _conservation_bad(ck_in, ck_out, info):
    """Conserved-total rule (reduce_scatter under SUM): what went onto the
    wire must come off it.  One fused psum of the stacked (in, out) pair,
    then a relative-tolerance compare."""
    pair = jnp.stack([ck_in, ck_out])
    member = _member_gate(info)
    if member is not None:
        pair = jnp.where(member, pair, jnp.zeros_like(pair))
    s = lax.psum(pair, info.axes)
    return jnp.abs(s[0] - s[1]) > 1e-3 * (jnp.abs(s[0]) + 1.0)


def _poison_where(bad, out):
    """Fold the integrity verdict into the payload: a bitwise pass-through
    select when clean, the canonical whole-payload poison fill when not
    (NaN for floats, INT_MIN for ints; bools pass through — no pattern).
    ``verify_clean`` recognizes the fill at host materialization."""

    def leaf(o):
        if not hasattr(o, "dtype"):
            return o
        if jnp.issubdtype(o.dtype, jnp.floating):
            p = jnp.full(o.shape, jnp.nan, o.dtype)
        elif jnp.issubdtype(o.dtype, jnp.integer):
            p = jnp.full(o.shape, jnp.iinfo(o.dtype).min, o.dtype)
        else:
            return o
        return jnp.where(bad, p, o)

    return jax.tree_util.tree_map(leaf, out)


#: poll period of a deadline-less wait on a dropped operation (a real hang,
#: interruptible from the keyboard)
_HANG_POLL_S = 0.05


def _await_incomplete(iv: IncompleteValue, timeout_s, what: str):
    """A wait met a dropped operation's sentinel.  With a deadline: sleep
    it out and raise ``PAX_ERR_TIMEOUT`` (the caller has NOT mutated the
    request — it stays active, so ``reset`` can abort and re-arm).  Without
    one: block forever, because that is what a dropped message does."""
    if timeout_s is None:
        while True:
            time.sleep(_HANG_POLL_S)
    time.sleep(max(0.0, float(timeout_s)))
    raise PaxError(
        PAX_ERR_TIMEOUT,
        f"{what} did not complete within {timeout_s}s: {iv.detail}")


def _first_incomplete(value) -> Optional[IncompleteValue]:
    """The drop sentinel in a wait's value, if any (group values are member
    lists — scan them).  Identity type checks: ~nothing on the clean path."""
    if value.__class__ is IncompleteValue:
        return value
    if value.__class__ is list or value.__class__ is tuple:
        for x in value:
            if x.__class__ is IncompleteValue:
                return x
    return None


class PaxABI:
    """One initialized ABI context (``MPI_Init`` .. ``MPI_Finalize``)."""

    def __init__(self, backend, mesh=None, tools: Sequence = (),
                 req_slot_bits: Optional[int] = None,
                 integrity: Optional[bool] = None) -> None:
        self.backend = backend
        self.mesh = mesh if mesh is not None else backend.mesh
        # end-to-end wire integrity (PR 10): the opt-in decision is taken
        # HERE, once — plan/group compilation consults the flag and the off
        # path compiles byte-identical closures to a pre-integrity context
        if integrity is None:
            integrity = os.environ.get(
                INTEGRITY_ENV_VAR, "").lower() in ("1", "true", "on")
        self.integrity = bool(integrity)
        # Only a loss-capable backend (the faulty: injection wrapper) can
        # ever plant the IncompleteValue drop sentinel, so the plan/group
        # wait closures compile the sentinel guard ONLY behind this flag —
        # the common-backend wait stays the bare two-field flip (the PR-4
        # dispatch discipline: a robustness feature may not tax the hot
        # path of a backend that cannot exhibit the fault).
        self._can_drop = bool(getattr(backend, "can_lose_messages", False))
        # ABI-domain tables (shared with a native backend, private otherwise)
        self.comms: CommTable = getattr(backend, "comms", None) or CommTable(self.mesh)
        self.ops: OpRegistry = getattr(backend, "ops", None) or OpRegistry()
        self.datatypes: DatatypeRegistry = getattr(backend, "datatypes", None) or DatatypeRegistry()
        # Tiered dlsym-style negotiation: resolve every function-table entry
        # now.  Native entries bind the backend method; missing OPTIONAL
        # entries are compiled from their emulation recipe when the recipe's
        # dependency chain grounds out in resolved entries (topological
        # order, so emulations chain); entries that resolve neither way get
        # a raiser — PAX_ERR_UNSUPPORTED_OPERATION fires at *call* time for
        # them, while a missing REQUIRED entry still fails here at init.
        self._table: dict[str, Callable] = {}
        self._source: dict[str, str] = {}   # name -> native|emulated|unavailable
        self._unavailable_reasons: dict[str, str] = {}
        # the CURRENT compiled-entry-point globals dict per entry;
        # _build_recipe patches its `_impl` in place when a lazy recipe
        # resolves.  Only the latest is kept (respecialization replaces it)
        # — a superseded hoisted callable is already stale by the
        # attach_tool contract and still heals through the shim's cell.
        self._entry_envs: dict[str, dict] = {}
        missing_required = []
        for entry in abi_spec.ABI_TABLE:
            if backend.supports(entry):
                self._table[entry.name] = getattr(backend, entry.backend_method)
                self._source[entry.name] = "native"
            elif entry.tier == abi_spec.REQUIRED:
                missing_required.append(entry.name)
        if missing_required:
            raise PaxError(
                PAX_ERR_UNSUPPORTED_OPERATION,
                f"backend {backend.name!r} is missing required function-table "
                f"entry point(s) {missing_required} (init-time negotiation, "
                "paper §6.2)",
            )
        for name in abi_spec.EMULATION_ORDER:
            if name in self._table:
                continue
            entry = abi_spec.ENTRY_BY_NAME[name]
            recipe = entry.recipe
            if recipe is not None and all(
                self._source.get(d) in ("native", "emulated") for d in recipe.deps
            ):
                # Lazy resolution: negotiation *decides* emulated here (the
                # chain grounds out), but the closure is compiled on first
                # call or first plan (_build_recipe), not at init — contexts
                # using few entries never pay for the rest.
                self._table[name] = _lazy_entry(self, entry)
                self._source[name] = "emulated"
            else:
                if recipe is None:
                    reason = "no native implementation and no emulation recipe"
                else:
                    unmet = [d for d in recipe.deps
                             if self._source.get(d) not in ("native", "emulated")]
                    reason = (f"emulation recipe dependency chain does not "
                              f"ground out (unresolved: {unmet})")
                self._table[name] = _unavailable_entry(entry, backend.name, reason)
                self._source[name] = "unavailable"
                self._unavailable_reasons[name] = reason
        # free-list request pool (see module docstring); the slot/generation
        # split is per-context: slots cap the outstanding-request count and
        # must fit the 24-bit user index field, generations live above the
        # classification bits and never wrap (no stale-handle aliasing).
        # Validated before tools attach, so a bad split cannot leave tools
        # bound to a context that was never created.
        bits = _REQ_SLOT_BITS if req_slot_bits is None else int(req_slot_bits)
        if not 1 <= bits <= H._USER_KIND_SHIFT:
            raise ValueError(
                f"req_slot_bits must be in 1..{H._USER_KIND_SHIFT}, got {bits}"
            )
        self.tools = list(tools)
        for t in self.tools:
            t.attach(self)
        self._req_slot_bits = bits
        self._req_max_slots = 1 << bits
        self._req_pool: list[Request] = []
        self._req_gen: list[int] = []
        self._req_free: list[int] = []
        self._req_live = 0
        self.requests_issued = 0  # lifetime stat; NOT part of any handle
        self.finalized = False
        # live persistent plans (weak: a dropped plan is garbage, its slot is
        # reclaimed only by an explicit free); respecialized with the entry
        # points on attach_tool/detach_tool
        self._plans: weakref.WeakSet[Plan] = weakref.WeakSet()
        # live plan groups (same weak/respecialization contract as plans)
        self._plan_groups: weakref.WeakSet[PlanGroup] = weakref.WeakSet()
        # layout-keyed plan cache: (entry, comm, non-payload args, payload
        # shape/dtype signature) -> Plan.  <name>_init is idempotent: the
        # same layout returns the SAME live plan (weak values, so dropped
        # plans still GC; Plan.free evicts its key).  This is what makes
        # re-sharding / elastic-dp re-plans transparent: callers rebuild
        # unconditionally and only genuinely new layouts allocate.
        self._plan_cache: "weakref.WeakValueDictionary[tuple, Plan]" = (
            weakref.WeakValueDictionary())
        # compile the per-instance specialized entry points (the init-time
        # half of the paper's "dispatch costs nothing per call" claim)
        self._specialize()

    # ------------------------------------------------------------------
    # init-time specialization
    # ------------------------------------------------------------------
    def _specialize(self) -> None:
        """(Re)compile per-context entry points.

        Called at init and again on every :meth:`attach_tool` /
        :meth:`detach_tool` — the only events that change what a call must
        do.  The compiled functions shadow the generic class methods via
        instance attributes; the code objects are cached per
        (entry, tooled?) so respecialization is an exec-with-new-globals,
        not a recompile.
        """
        tools = tuple(self.tools)
        rtools = tuple(reversed(tools))
        for entry in abi_spec.ABI_TABLE:
            self._specialize_entry(entry, tools, rtools)
        # live persistent plans and plan groups carry the tool decision baked
        # in: recompile them with the new tool tuple (same contract as the
        # entry points)
        for plan in list(self._plans):
            self._compile_plan(plan)
        for group in list(self._plan_groups):
            self._compile_plan_group(group)

    def _specialize_entry(self, entry: abi_spec.AbiEntry,
                          tools: Optional[tuple] = None,
                          rtools: Optional[tuple] = None) -> None:
        """Compile one entry's per-instance blocking + ``i*`` entry points."""
        if tools is None:
            tools = tuple(self.tools)
            rtools = tuple(reversed(tools))
        tooled = bool(tools)
        env = dict(_GEN_ENV)
        env["_impl"] = self._table[entry.name]
        env["_abi"] = self
        env["_tools"] = tools
        env["_rtools"] = rtools
        fn = _compile_cached(
            _SPEC_BLOCKING_SRC, (entry.name, tooled),
            lambda: _spec_blocking_src(entry, tooled), entry.name, env,
        )
        # record the compiled globals so _build_recipe can patch `_impl` in
        # place when a lazy recipe resolves — hoisted specialized callables
        # then run the built closure directly, no shim indirection
        self._entry_envs[entry.name] = env
        if entry.name == "comm_revoke":
            # ABI-layer revoke bookkeeping rides on the entry point (not the
            # backend impl, which may be native or emulated): after a revoke
            # lands in the CommTable, live plans and plan groups bound to the
            # revoked comm are forced inactive via their reset() escape
            # hatches — their frozen axes closures must never start again.
            fn = _wrap_revoke(self, fn)
        object.__setattr__(self, entry.name, fn)
        if entry.nonblocking:
            ienv = {
                "_blocking": fn,
                "_new_request": self._new_request,
                "_backend": self.backend,
            }
            ifn = _compile_cached(
                _SPEC_NONBLOCKING_SRC, (entry.name, False),
                lambda: _spec_nonblocking_src(entry), f"i{entry.name}", ienv,
            )
            object.__setattr__(self, f"i{entry.name}", ifn)

    def attach_tool(self, tool) -> None:
        """Attach an interposition tool and respecialize the dispatch path."""
        tool.attach(self)
        self.tools.append(tool)
        self._specialize()

    def detach_tool(self, tool) -> None:
        """Detach a tool; the zero-tool fast path returns when none remain."""
        self.tools.remove(tool)
        self._specialize()

    # ------------------------------------------------------------------
    # lazy emulation-recipe resolution
    # ------------------------------------------------------------------
    def _ensure_built(self, name: str) -> Callable:
        """The concrete resolved callable for ``name``, building a lazily
        deferred emulation recipe now if this is its first use."""
        fn = self._table[name]
        if getattr(fn, "__lazy_recipe__", None) is not None:
            return self._build_recipe(name)
        return fn

    def _build_recipe(self, name: str) -> Callable:
        """Compile a deferred recipe: swap the built closure into the table,
        respecialize the entry, patch the shim's dispatch cell, and patch
        every previously-compiled entry point's globals — so steady-state
        dispatch is identical to the old eager build even for callables
        hoisted before the first call (no warmup re-fetch needed)."""
        fn = self._table[name]
        state = getattr(fn, "__lazy_recipe__", None)
        if state is None:
            return fn  # already built (possibly through another path)
        impl = state["impl"]
        if impl is None:
            entry = abi_spec.ENTRY_BY_NAME[name]
            impl = entry.recipe.build(emulation.EmulationContext(self))
            state["impl"] = impl
            self._table[name] = impl
            # heal hoisted references: the shim's cell now IS the built
            # closure, and the current specialized function compiled
            # against the shim gets its `_impl` global swapped in place
            fn.__lazy_cell__[0] = impl
            env = self._entry_envs.get(name)
            if env is not None:
                env["_impl"] = impl
            self._specialize_entry(entry)
        return impl

    # ------------------------------------------------------------------
    # persistent plans (MPI-4 <name>_init): hoist per-call work to plan time
    # ------------------------------------------------------------------
    def _make_plan(self, name: str, call_args: tuple) -> Plan:
        """Build a persistent plan for entry ``name`` bound to ``call_args``.

        Plan-time work (done exactly once): argument-domain handle checks,
        payload abstraction (shape/dtype), run-closure compilation via
        :meth:`_plan_run`, tool-decision baking, and allocation of the
        restartable request slot.  Unavailable entries fail *here*, at plan
        time — never at ``start``.

        ``<name>_init`` is **idempotent per layout**: the normalized
        arguments (payloads as shape/dtype signatures) key the per-context
        plan cache, and a hit returns the cached live plan — zero new
        slots, zero recompilation.  Only an *inactive* plan is handed out
        again (an in-flight one gets a fresh, independently startable twin
        — the MPI ``_init`` contract), and a shared hit really is the same
        plan: one holder's ``free()`` retires it for every holder.  A
        signature that does not hash (exotic payload leaves) simply skips
        the cache.
        """
        entry = abi_spec.ENTRY_BY_NAME[name]
        args = []
        for a, v in zip(entry.args, call_args):
            if a.kind == abi_spec.DATATYPE_VEC:
                v = tuple(v)
                for t in v:
                    H.check_handle(t, a.check_kind)
            elif a.check_kind is not None:
                H.check_handle(v, a.check_kind)
            elif a.kind in (abi_spec.PERM, abi_spec.COUNTS):
                v = tuple(v)
            elif a.kind == abi_spec.PAYLOAD:
                v = _abstract_payload(v)
            args.append(v)
        key = _plan_cache_key(entry, args)
        if key is not None:
            cached = self._plan_cache.get(key)
            if (cached is not None and not cached.freed
                    and cached.request.done):
                # inactive cached plan: the idempotency hit.  An ACTIVE one
                # is skipped — the MPI _init contract promises every init an
                # independently startable request (double-buffered overlap),
                # so a caller planning while the cached plan is in flight
                # gets a fresh plan (which takes over the cache slot).
                return cached
        plan = Plan(self, entry, tuple(args))
        plan._cache_key = key
        plan.request = self._new_persistent_request(f"p{name}")
        # GC fallback: a plan dropped without free() must not leak its slot
        # forever.  The finalizer re-checks handle+persistent so an explicit
        # free (or the slot's later reuse) makes it a no-op.
        plan._finalizer = weakref.finalize(
            plan, _reclaim_plan_slot, self, plan.request, plan.request.handle)
        self._compile_plan(plan)
        self._plans.add(plan)
        if key is not None:
            self._plan_cache[key] = plan
        return plan

    def _plan_run(self, name: str, bound: tuple) -> Callable:
        """Compile the untooled run closure for entry ``name``.

        Resolution order mirrors negotiation: a backend-declared native plan
        hook (``plan_<method>`` — paxi/ring freeze comm→axes and the op
        branch, Mukautuva converts foreign handles once), then the recipe's
        plan builder for emulated entries (precomposed chain), then generic
        argument freezing around the resolved callable — which still hoists
        every ABI-layer check out of the call path.
        """
        entry = abi_spec.ENTRY_BY_NAME[name]
        source = self._source[name]
        if source == "native":
            hook = getattr(self.backend, f"plan_{entry.backend_method}", None)
            if hook is not None:
                return hook(*bound)
            impl = self._table[name]
        elif source == "emulated":
            if entry.recipe.plan is not None:
                return entry.recipe.plan(emulation.PlanContext(self), *bound)
            impl = self._ensure_built(name)
        else:
            raise PaxError(
                PAX_ERR_UNSUPPORTED_OPERATION,
                f"cannot plan {name!r} on backend {self.backend.name!r}: "
                f"{self._unavailable_reasons[name]}",
            )
        return _freeze_run(entry, impl, bound)

    # ------------------------------------------------------------------
    # transport-integrity envelope (PR 10) — plan-time hoisted checksums
    # ------------------------------------------------------------------
    def _integrity_rule(self, entry: abi_spec.AbiEntry, bound: tuple):
        """``(rule, comm_info)`` when this plan qualifies for the checksum
        envelope, else ``None``.  The decision is wholly plan-time: the
        context flag, the entry's declared rule, a real-axes comm (there is
        no wire on COMM_SELF), and — for conservation — a SUM op."""
        if not self.integrity:
            return None
        rule = entry.integrity
        if rule is None:
            return None
        ci = next((i for i, a in enumerate(entry.args)
                   if a.kind == abi_spec.COMM), None)
        if ci is None or len(entry.payload_args) != 1:
            return None
        info = self.comms.info(bound[ci])
        if not info.axes:
            return None
        if rule == "conserved":
            oi = next((i for i, a in enumerate(entry.args)
                       if a.kind == abi_spec.OP), None)
            if oi is None or bound[oi] != H.PAX_SUM:
                return None  # the conservation law holds for SUM only
        return rule, info

    def _wrap_plan_integrity(self, entry: abi_spec.AbiEntry, bound: tuple,
                             run: Callable) -> Callable:
        """Wrap a plan run closure with the end-to-end checksum envelope.

        Disabled (or unsupported for the entry/comm/op): returns ``run``
        unchanged — zero per-call Python, the PR-4 contract.  Enabled: one
        fused checksum reduction per start, verdict folded into the output
        as the poison fill (raising happens at host materialization via
        :meth:`verify_clean` — trace-time code cannot raise on data)."""
        q = self._integrity_rule(entry, bound)
        if q is None:
            return run
        rule, info = q
        n_members = info.full_size - len(info.excludes)
        if rule == "replicated":
            def checked(x, _run=run):
                out = _run(x)
                bad = _agreement_bad(_bits_checksum(out), info, n_members)
                return _poison_where(bad, out)
        else:  # conserved
            def checked(x, _run=run):
                ck_in = _value_checksum(x)
                out = _run(x)
                bad = _conservation_bad(ck_in, _value_checksum(out), info)
                return _poison_where(bad, out)
        return checked

    def _wrap_group_integrity(self, entry: abi_spec.AbiEntry, bounds,
                              run: Callable) -> Callable:
        """Group edition of :meth:`_wrap_plan_integrity`: ONE checksum over
        the whole fused segment (members share entry, op and comm by the
        cluster key), one agreement/conservation verdict, poison folded
        into every member output.  Unchanged closure when disabled."""
        q = self._integrity_rule(entry, tuple(bounds[0]))
        if q is None:
            return run
        rule, info = q
        n_members = info.full_size - len(info.excludes)
        if rule == "replicated":
            def checked(xs, _run=run):
                outs = _run(xs)
                bad = _agreement_bad(_bits_checksum(outs), info, n_members)
                return [_poison_where(bad, o) for o in outs]
        else:  # conserved
            def checked(xs, _run=run):
                ck_in = _value_checksum(xs)
                outs = _run(xs)
                bad = _conservation_bad(
                    ck_in, _value_checksum(outs), info)
                return [_poison_where(bad, o) for o in outs]
        return checked

    def verify_clean(self, value, what: str = "payload") -> None:
        """Host-side integrity verdict on MATERIALIZED results: raise
        ``PAX_ERR_DATA_CORRUPTION`` if any leaf carries the canonical
        poison fill the checksum envelope folds in (whole-leaf NaN /
        INT_MIN).  No-op when integrity mode is off.  This is the raising
        half of the two-level design — call it where values become
        concrete (between steps, on decoded tokens), exactly where the
        failure probe raises for rank death."""
        if not self.integrity:
            return
        for leaf in jax.tree_util.tree_leaves(value):
            if not hasattr(leaf, "dtype") or getattr(leaf, "size", 0) == 0:
                continue
            a = np.asarray(leaf)
            if np.issubdtype(a.dtype, np.floating):
                poisoned = bool(np.isnan(a).all())
            elif np.issubdtype(a.dtype, np.integer):
                poisoned = bool((a == np.iinfo(a.dtype).min).all())
            else:
                continue
            if poisoned:
                raise PaxError(
                    PAX_ERR_DATA_CORRUPTION,
                    f"{what}: checksummed collective disagreed across the "
                    "communicator (payload carries the poison fill)")

    def _compile_plan(self, plan: Plan) -> None:
        """(Re)compile a plan's start/wait closures.

        Called at plan creation and again from :meth:`_specialize` when the
        tool chain changes — live plans are *respecialized*, not invalidated
        (same contract as the compiled entry points).
        """
        entry = plan.entry
        run = self._plan_run(entry.name, plan.bound)
        run = self._wrap_plan_integrity(entry, plan.bound, run)
        if self.tools:
            # bake the tool decision: chain, byte accounting from the bound
            # abstract shape (ShapeDtypeStruct leaves carry .size/.dtype, so
            # the one _nbytes definition serves plans too), and the
            # table-order arg splice.  The info dict is built fresh per
            # start, like the per-call path builds _info per call — tools
            # may annotate it without leaking state across starts.
            tools = tuple(self.tools)
            rtools = tuple(reversed(tools))
            if entry.bytes_arg:
                idx = {a.name: i for i, a in enumerate(entry.args)}
                bytes_val = _nbytes(plan.bound[idx[entry.bytes_arg]], self)
                comm_h = next(plan.bound[i] for i, a in enumerate(entry.args)
                              if a.kind == abi_spec.COMM)
            else:
                bytes_val = comm_h = None
            splice = _payload_splicer(entry, plan.bound)
            fname = entry.name
            base_run = run

            def run(*payload):
                targs = splice(payload)
                info = ({} if bytes_val is None
                        else {"bytes": bytes_val, "comm_handle": comm_h})
                for t in tools:
                    t.before(fname, targs, info)
                res = base_run(*payload)
                for t in rtools:
                    res = t.after(fname, targs, info, res)
                return res

        if entry.temps:
            # converted handle vectors live exactly as long as the plan
            plan.request.temp_state = getattr(
                self.backend, entry.temps_attr, None)

        req = plan.request
        ename = entry.name
        if len(entry.payload_args) == 1:
            def start(x, _req=req, _run=run):
                if not _req.done:
                    raise PaxError(
                        PAX_ERR_REQUEST,
                        f"persistent {ename!r} started while already active "
                        "(wait the previous start first)",
                    )
                _req.done = False
                _req.value = _run(x)
                return _req
        elif not entry.payload_args:
            def start(_req=req, _run=run):
                if not _req.done:
                    raise PaxError(
                        PAX_ERR_REQUEST,
                        f"persistent {ename!r} started while already active "
                        "(wait the previous start first)",
                    )
                _req.done = False
                _req.value = _run()
                return _req
        else:  # pragma: no cover - no current entry has >1 payload arg
            def start(*payload, _req=req, _run=run):
                if not _req.done:
                    raise PaxError(PAX_ERR_REQUEST, f"persistent {ename!r} "
                                   "started while already active")
                _req.done = False
                _req.value = _run(*payload)
                return _req

        if self._can_drop:
            def wait(timeout_s=None, _req=req, _IV=IncompleteValue):
                # wait on an inactive persistent request returns immediately
                # (MPI semantics); completion deactivates without retiring —
                # the slot's generation is untouched, the plan is restartable
                if _req.done:
                    return None
                v = _req.value
                if v.__class__ is _IV:
                    # dropped op: never completes.  Without a deadline this
                    # blocks forever (the faithful hang); with one it raises
                    # PAX_ERR_TIMEOUT and leaves the request ACTIVE so the
                    # post-timeout abort path is Plan.reset, never a wedge.
                    _await_incomplete(v, timeout_s,
                                      f"persistent {ename!r} wait")
                _req.done = True
                _req.value = None  # drop the (possibly traced) value eagerly
                return v
        else:
            def wait(timeout_s=None, _req=req):
                # loss-incapable backend: every start completed synchronously,
                # so the sentinel guard (and with it any timeout) is
                # unreachable — the bare two-field flip is the whole wait
                if _req.done:
                    return None
                _req.done = True
                v = _req.value
                _req.value = None  # drop the (possibly traced) value eagerly
                return v

        plan.start = start
        plan.wait = wait

    def _new_persistent_request(self, kind: str) -> Request:
        """Allocate the restartable pool slot backing one plan.

        Comes from the same free list as nonblocking requests (one handle
        space, one liveness rule) but is *not* counted live while inactive,
        and — unlike :meth:`_retire` — completion never bumps its
        generation: the slot flips inactive⇄active for the plan's lifetime
        and only :meth:`Plan.free` advances the generation (after which every
        handle the plan returned is stale forever).
        """
        if self._req_free:
            slot = self._req_free.pop()
            req = self._req_pool[slot]
            req.handle = (self._req_gen[slot] << _REQ_GEN_SHIFT) | _REQ_HANDLE_BASE | slot
            req.value = None
            req.kind = kind
            req.done = True  # inactive
            req.temp_state = None
            req.on_complete = None
        else:
            slot = len(self._req_pool)
            if slot >= self._req_max_slots:
                raise PaxError(
                    PAX_ERR_REQUEST,
                    f"request pool exhausted: {self._req_max_slots} slots "
                    "(free some plans or wait outstanding requests)",
                )
            req = Request(_REQ_HANDLE_BASE | slot, None, kind, True, None, None)
            self._req_pool.append(req)
            self._req_gen.append(0)
        req.persistent = True
        self.requests_issued += 1  # the allocation; starts allocate nothing
        return req

    # ------------------------------------------------------------------
    # plan groups (MPI Startall): fuse N plans into one start + one wait
    # ------------------------------------------------------------------
    def plan_group(self, plans: Sequence[Plan], name: str = "") -> PlanGroup:
        """Compile a :class:`PlanGroup` from live plans of this context.

        Group-build-time work (done exactly once): member validation,
        clustering by (entry, non-payload arguments), fused-run resolution
        per cluster (backend group hook → recipe group stage → per-member
        loop), tool-decision baking with group-summed byte accounting, and
        allocation of the group's own restartable request slot.
        ``group.start(payloads)`` is then ONE inactive-check plus the fused
        closure, and ``group.wait()`` one completion scan for all members.
        """
        plans = tuple(plans)
        if not plans:
            raise PaxError(PAX_ERR_REQUEST, "plan_group of zero plans")
        for p in plans:
            if not isinstance(p, Plan) or p.abi is not self:
                raise PaxError(
                    PAX_ERR_REQUEST,
                    f"plan group {name!r} member is not a plan of this "
                    "context",
                )
            if p.freed:
                raise PaxError(
                    PAX_ERR_REQUEST,
                    f"plan group {name!r} member ({p.entry.name!r} plan) "
                    "was already freed",
                )
        group = PlanGroup(self, plans, name or f"group[{len(plans)}]")
        group.request = self._new_persistent_request(f"g{group.name}")
        group._finalizer = weakref.finalize(
            group, _reclaim_plan_slot, self, group.request,
            group.request.handle)
        self._compile_plan_group(group)
        self._plan_groups.add(group)
        return group

    def _plan_group_run(self, name: str, bounds: Sequence[tuple]) -> Callable:
        """Compile one fused run closure for ``len(bounds)`` same-entry,
        same-non-payload-argument plan members.

        Resolution mirrors :meth:`_plan_run`, lifted to lists: a
        backend-declared **group hook** (``plan_group_<method>`` — paxi/ring
        stack the members into one collective, Mukautuva's generated
        wrappers cache all foreign conversion), then the recipe's
        ``plan_group`` stage fusion for emulated entries, then a loop over
        per-member plan runs.  Hooks/recipes may decline (return ``None``)
        and fall through.  The returned closure maps the member payload
        list to the member output list.
        """
        entry = abi_spec.ENTRY_BY_NAME[name]
        bounds = list(bounds)
        if len(bounds) > 1:
            source = self._source[name]
            if source == "native":
                hook = getattr(self.backend,
                               f"plan_group_{entry.backend_method}", None)
                if hook is not None:
                    run = hook(bounds)
                    if run is not None:
                        return run
            elif source == "emulated" and entry.recipe.plan_group is not None:
                run = entry.recipe.plan_group(
                    emulation.PlanContext(self), bounds)
                if run is not None:
                    return run
        runs = [self._plan_run(name, tuple(b)) for b in bounds]
        if entry.payload_args:
            return lambda xs: [r(x) for r, x in zip(runs, xs)]
        return lambda xs: [r() for r in runs]

    def _compile_plan_group(self, group: PlanGroup) -> None:
        """(Re)compile a group's fused start/wait closures.

        Called at group build and again from :meth:`_specialize` when the
        tool chain changes — live groups are respecialized, not
        invalidated (the same contract as plans and entry points).
        """
        plans = group.plans
        n = len(plans)
        # cluster members by (entry, non-payload bound args); each cluster
        # compiles to one fused segment, outputs reassembled in member order
        clusters: dict[tuple, list[int]] = {}
        for i, p in enumerate(plans):
            pay = set(p.entry.payload_args)
            key = (p.entry.name, tuple(
                v for j, v in enumerate(p.bound) if j not in pay))
            clusters.setdefault(key, []).append(i)
        segments = []
        for (ename, _), idxs in clusters.items():
            bnds = [plans[i].bound for i in idxs]
            seg_run = self._plan_group_run(ename, bnds)
            seg_run = self._wrap_group_integrity(
                abi_spec.ENTRY_BY_NAME[ename], bnds, seg_run)
            segments.append((tuple(idxs), seg_run))

        if len(segments) == 1 and segments[0][0] == tuple(range(n)):
            run = segments[0][1]  # homogeneous group: no reassembly layer
        else:
            seg_t = tuple(segments)

            def run(payloads, _segs=seg_t, _n=n):
                outs = [None] * _n
                for idxs, seg in _segs:
                    for i, v in zip(idxs, seg([payloads[i] for i in idxs])):
                        outs[i] = v
                return outs

        if self.tools:
            # one interposition for the whole group: the info dict carries
            # the byte total summed over every member's bound payload shape
            # (built fresh per start, like the per-call path)
            tools = tuple(self.tools)
            rtools = tuple(reversed(tools))
            total = 0
            comms = set()
            for p in plans:
                entry = p.entry
                if entry.bytes_arg:
                    idx = {a.name: i for i, a in enumerate(entry.args)}
                    total += _nbytes(p.bound[idx[entry.bytes_arg]], self)
                for i, a in enumerate(entry.args):
                    if a.kind == abi_spec.COMM:
                        comms.add(p.bound[i])
            comm_h = comms.pop() if len(comms) == 1 else None
            fname = group.name
            gsize = n
            base_run = run

            def run(payloads):
                targs = tuple(payloads)
                info = {"bytes": total, "comm_handle": comm_h,
                        "group": fname, "members": gsize}
                for t in tools:
                    t.before(fname, targs, info)
                res = base_run(payloads)
                for t in rtools:
                    res = t.after(fname, targs, info, res)
                return res

        req = group.request
        gname = group.name

        def start(payloads, _req=req, _run=run, _n=n):
            if len(payloads) != _n:
                raise PaxError(
                    PAX_ERR_REQUEST,
                    f"plan group {gname!r} started with {len(payloads)} "
                    f"payloads for {_n} members (one per member; items for "
                    "payload-less members are ignored)",
                )
            if not _req.done:
                raise PaxError(
                    PAX_ERR_REQUEST,
                    f"plan group {gname!r} started while already active "
                    "(wait the previous start first)",
                )
            _req.done = False
            _req.value = _run(payloads)
            return _req

        if self._can_drop:
            def wait(timeout_s=None, _req=req, _scan=_first_incomplete):
                # wait on an inactive group returns immediately (MPI
                # semantics); completion deactivates without retiring —
                # one scan, restartable
                if _req.done:
                    return None
                v = _req.value
                iv = _scan(v)
                if iv is not None:
                    # a dropped member never completes; the request stays
                    # ACTIVE across the raise so PlanGroup.reset can abort
                    _await_incomplete(iv, timeout_s,
                                      f"plan group {gname!r} wait")
                _req.done = True
                _req.value = None
                return v
        else:
            def wait(timeout_s=None, _req=req):
                # loss-incapable backend: no member can carry the drop
                # sentinel, so the scan is unreachable — bare flip only
                if _req.done:
                    return None
                _req.done = True
                v = _req.value
                _req.value = None
                return v

        group.start = start
        group.wait = wait

    # ------------------------------------------------------------------
    # capability report (what tiered negotiation resolved, per entry)
    # ------------------------------------------------------------------
    def capabilities(self) -> dict[str, dict]:
        """Per-entry resolution report for this context.

        Each entry maps to ``{"tier", "source", ...}`` where ``source`` is
        ``"native"`` (the backend exports it), ``"emulated"`` (compiled from
        its recipe; ``"deps"`` lists the entries the emulation stands on),
        or ``"unavailable"`` (calling it raises
        ``PAX_ERR_UNSUPPORTED_OPERATION``; ``"reason"`` says why).  The
        backend contributes its own view via ``Backend.capability`` —
        Mukautuva translates the foreign library's symbol table across the
        layer, so the report distinguishes ABI-layer emulation from
        foreign-library support.

        The fault tier (``tier == "fault"``: ``comm_revoke`` /
        ``comm_shrink`` / ``comm_agree`` / ``comm_failure_ack`` /
        ``comm_get_failed``) reports through the same per-entry sources:
        ``"native"`` on backends with ULFM-style hooks (paxi), ``"emulated"``
        where the spec recipes synthesize the tier (minimal, and Mukautuva
        fronting libraries that dropped the symbols, e.g. ompix) — so
        "does this stack have a fault model, and whose?" is answered per
        entry without calling anything.
        """
        report: dict[str, dict] = {}
        for entry in abi_spec.ABI_TABLE:
            source = self._source[entry.name]
            info: dict = {"tier": entry.tier, "source": source}
            if source == "emulated":
                info["deps"] = entry.recipe.deps
            elif source == "unavailable":
                info["reason"] = self._unavailable_reasons[entry.name]
            if entry.persistent:
                # how a <name>_init plan would compile (never forces a build)
                if source == "unavailable":
                    info["plan"] = "unavailable"
                elif source == "native" and self.backend.supports_persistent(entry):
                    info["plan"] = "backend-hook"
                elif source == "emulated" and entry.recipe.plan is not None:
                    info["plan"] = "recipe-plan"
                else:
                    info["plan"] = "generic"
                # how a plan_group cluster of this entry would fuse
                if source == "unavailable":
                    info["plan_group"] = "unavailable"
                elif (source == "native"
                        and self.backend.supports_persistent_group(entry)):
                    info["plan_group"] = "backend-hook"
                elif (source == "emulated"
                        and entry.recipe.plan_group is not None):
                    info["plan_group"] = "recipe-stage"
                else:
                    info["plan_group"] = "generic"
            info.update(self.backend.capability(entry))
            report[entry.name] = info
        return report

    # ------------------------------------------------------------------
    # tool-path dispatch (PMPI chain) for the generic class-level methods;
    # specialized instance entry points inline this loop.
    # ------------------------------------------------------------------
    def _dispatch_tools(self, fname: str, impl: Callable, args: tuple, info: dict):
        for t in self.tools:
            t.before(fname, args, info)
        result = impl(*args)
        for t in reversed(self.tools):
            result = t.after(fname, args, info, result)
        return result

    # -- init/finalize ----------------------------------------------------
    def finalize(self) -> None:
        live = self.outstanding_requests
        if live:
            raise PaxError(PAX_ERR_REQUEST, f"{live} outstanding requests")
        self.finalized = True

    # -- identity / registration (not per-collective dispatch) -------------
    def comm_from_axes(self, axes: Sequence[str], name: str = "") -> int:
        h = self.comms.comm_from_axes(axes, name)
        if self.backend.convention == "foreign":
            self.backend.register_comm(h, axes)
        return h

    def comm_dup(self, comm: int) -> int:
        info = self.comms.info(comm)
        return self.comm_from_axes(info.axes, info.name + "+dup")

    def comm_free(self, comm: int) -> None:
        self.comms.comm_free(comm)

    def _after_revoke(self, comm: int) -> None:
        """Revoked-comm plan semantics: every live plan or plan group bound
        to ``comm`` is forced inactive (``reset()``) — their plan-time-frozen
        axes closures must not be startable once the comm is revoked.  The
        layout-keyed plan cache needs no flush: cached plans on the revoked
        comm key by its handle, and recovery plans over the survivor comm
        key differently, so re-planning allocates only genuinely new
        layouts.  Plans on *other* comms are untouched."""
        for plan in list(self._plans):
            ci = _comm_arg_index(plan.entry)
            if ci is not None and plan.bound[ci] == comm:
                plan.reset()
        for group in list(self._plan_groups):
            for member in group.plans:
                ci = _comm_arg_index(member.entry)
                if ci is not None and member.bound[ci] == comm:
                    group.reset()
                    break

    # -- datatypes ----------------------------------------------------------
    def type_contiguous(self, count: int, base: int) -> int:
        h = self.datatypes.type_contiguous(count, base)
        if self.backend.convention == "foreign":
            self.backend.register_datatype(h, count, base)
        return h

    def type_from_array(self, x) -> int:
        return self.datatypes.from_array(x)

    # -- user ops (callback registration) -----------------------------------
    def op_create(self, fn: Callable, *, commutative: bool = True, name: str = "") -> int:
        h = self.ops.op_create(fn, commutative=commutative, name=name)
        if self.backend.convention == "foreign":
            self.backend.register_op(h)
        return h

    def op_free(self, op: int) -> None:
        self.ops.op_free(op)

    # -- nonblocking request plumbing ---------------------------------------
    def _new_request(self, value, kind: str, temp_state=None, on_complete=None) -> Request:
        if self._req_free:
            slot = self._req_free.pop()
            req = self._req_pool[slot]
            req.handle = (self._req_gen[slot] << _REQ_GEN_SHIFT) | _REQ_HANDLE_BASE | slot
            req.value = value
            req.kind = kind
            req.done = False
            req.temp_state = temp_state
            req.on_complete = on_complete
        else:
            slot = len(self._req_pool)
            if slot >= self._req_max_slots:
                raise PaxError(
                    PAX_ERR_REQUEST,
                    f"request pool exhausted: {self._req_max_slots} outstanding "
                    "nonblocking requests (wait/test some before issuing more)",
                )
            req = Request(_REQ_HANDLE_BASE | slot, value, kind, False,
                          temp_state, on_complete)
            self._req_pool.append(req)
            self._req_gen.append(0)
        self._req_live += 1
        self.requests_issued += 1
        return req

    def _request_is_live(self, handle: int) -> bool:
        """O(1) liveness: slot index + generation compare, no hashing."""
        if not handle & H._USER_BIT:
            return False
        slot = handle & _USER_INDEX_MASK
        return slot < len(self._req_gen) and self._req_gen[slot] == handle >> _REQ_GEN_SHIFT

    def _retire(self, handle: int) -> None:
        """Free the handle's slot; bump generation so the handle goes stale.

        The generation is an unbounded counter (stored above the handle's
        classification bits), so a retired handle stays stale forever — no
        wrap, no aliasing, regardless of how often the slot is reused.
        """
        slot = handle & _USER_INDEX_MASK
        self._req_gen[slot] += 1
        self._req_free.append(slot)
        self._req_live -= 1
        pooled = self._req_pool[slot]
        if pooled.handle == handle and not pooled.done:
            # completion arrived through a different Request object carrying
            # a live handle: retire the pooled twin too so nothing leaks
            pooled.done = True
            pooled.value = pooled.temp_state = pooled.on_complete = None

    # -- completion -----------------------------------------------------------
    def wait(self, request: Request, status: Optional[Status] = None,
             *, timeout_s: Optional[float] = None):
        if request.handle == H.PAX_REQUEST_NULL:
            return None
        if not request.done:
            if request.persistent:
                # restartable slot: deactivate WITHOUT retiring — the
                # generation is untouched (only Plan.free advances it), so
                # the same handle restarts next step with no pool churn
                slot = request.handle & _USER_INDEX_MASK
                gens = self._req_gen
                if slot >= len(gens) or gens[slot] != request.handle >> _REQ_GEN_SHIFT:
                    raise PaxError(
                        PAX_ERR_REQUEST,
                        "stale persistent request (its plan was freed)",
                    )
                iv = _first_incomplete(request.value)
                if iv is not None:
                    # dropped op: stays ACTIVE across the timeout raise so
                    # Plan.reset/PlanGroup.reset can abort the slot
                    _await_incomplete(iv, timeout_s, "persistent wait")
                request.done = True
                value = request.value
                request.value = None
                if status is not None:
                    status.ERROR = PAX_SUCCESS
                return value
            if not self._request_is_live(request.handle):
                raise PaxError(
                    PAX_ERR_REQUEST,
                    "stale, unknown or already-completed request "
                    "(use-after-wait is detected by the generation check)",
                )
            iv = _first_incomplete(request.value)
            if iv is not None:
                # raise BEFORE retiring: the request stays live, a later
                # wait (or cancel-by-reset at the plan layer) still works
                _await_incomplete(iv, timeout_s, "wait")
            request.done = True  # mark first: _retire must see the twin live
            self._retire(request.handle)
            if request.on_complete is not None:
                request.value = request.on_complete(request)
            request.temp_state = None  # free converted vectors
        if status is not None:
            status.ERROR = PAX_SUCCESS
        return request.value

    def test(self, request: Request, status: Optional[Status] = None):
        """Nonblocking completion check.  In dataflow execution the value is
        always ready once traced, so test == wait that also reports flag=True;
        the cost that matters (and that bench_request_map measures) is the
        request liveness check — now a slot index, not a map lookup."""
        if not request.done and not self._request_is_live(request.handle):
            raise PaxError(PAX_ERR_REQUEST, "unknown request")
        return True, self.wait(request, status)

    def waitall(self, requests: Sequence[Request], statuses=None,
                *, timeout_s: Optional[float] = None):
        return [self.wait(r, None if statuses is None else statuses[i],
                          timeout_s=timeout_s)
                for i, r in enumerate(requests)]

    def _scan_ready(self, requests: Sequence[Request]) -> bool:
        """The testall flag scan: N array-index + generation-compares, flat
        per request regardless of how many are outstanding (what
        bench_request_map measures)."""
        gens = self._req_gen
        for r in requests:
            if r.done:
                continue
            h = r.handle
            slot = h & _USER_INDEX_MASK
            if (not h & H._USER_BIT or slot >= len(gens)
                    or gens[slot] != h >> _REQ_GEN_SHIFT):
                return False
        return True

    def testall(self, requests: Sequence[Request], statuses=None):
        """The §6.2 worst case, de-fanged by the pool (see _scan_ready)."""
        if not self._scan_ready(requests):
            return False, None
        return True, self.waitall(requests, statuses)

    @property
    def outstanding_requests(self) -> int:
        """Live nonblocking requests plus *active* (started, unwaited)
        persistent plans and plan groups.  Inactive plans/groups hold their
        slot but are not outstanding work — they do not block ``finalize``."""
        live = self._req_live
        for p in self._plans:
            r = p.request
            if r is not None and not r.done:
                live += 1
        for g in self._plan_groups:
            r = g.request
            if r is not None and not r.done:
                live += 1
        return live

    # -- convenience: run a function in a manual-collective region ----------
    def shard_region(self, fn: Callable, in_specs, out_specs, axis_names=None,
                     check_vma: bool = False):
        """shard_map over this context's mesh; collectives inside may use any
        communicator whose axes are in ``axis_names`` (default: all axes).

        ``check_vma`` defaults off: MPI collective semantics guarantee
        replication of reduction results, but JAX cannot infer that through
        the generic (gather+fold) reductions the ABI uses for exotic ops.
        """
        if self.mesh is None:
            raise PaxError(PAX_ERR_REQUEST, "no mesh bound")
        return compat.shard_map(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=check_vma,
        )


def _nbytes(x, abi: PaxABI, datatype: Optional[int] = None) -> int:
    """Payload size for tool accounting; handles pytrees."""
    total = 0
    for leaf in jax.tree.leaves(x):
        if hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            if datatype is not None:
                total += leaf.size * abi.datatypes.type_size_encoded(datatype)
            else:
                total += leaf.size * np.dtype(leaf.dtype).itemsize
    return int(total)


def _abstract_payload(x):
    """Plan-time payload binding: keep only shape/dtype per leaf (a plan is
    specific to the payload geometry, never to its values — and must not pin
    a model-sized buffer, or pytree of buffers, alive for its lifetime)."""

    def leaf(l):
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            return jax.ShapeDtypeStruct(tuple(l.shape), l.dtype)
        return l

    return jax.tree.map(leaf, x)


def _plan_cache_key(entry: abi_spec.AbiEntry, args: Sequence) -> Optional[tuple]:
    """The layout key of one normalized plan-argument list: entry name plus
    every non-payload argument verbatim and every payload as its
    (treedef, per-leaf shape/dtype) signature.  Returns ``None`` when any
    component does not hash (exotic payload leaves) — the plan is then
    simply not cached."""
    parts: list = [entry.name]
    try:
        for a, v in zip(entry.args, args):
            if a.kind == abi_spec.PAYLOAD:
                leaves, treedef = jax.tree.flatten(v)
                parts.append((treedef, tuple(
                    (tuple(l.shape), str(l.dtype))
                    if hasattr(l, "shape") and hasattr(l, "dtype") else l
                    for l in leaves)))
            else:
                parts.append(v)
        key = tuple(parts)
        hash(key)
        return key
    except TypeError:
        return None


def _payload_splicer(entry: abi_spec.AbiEntry, bound: tuple) -> Callable:
    """The one definition of how start-time payloads splice back into the
    table-order argument tuple (frozen template from the plan's bound args).
    Returns ``payload_tuple -> full_arg_tuple``."""
    payload_idx = entry.payload_args
    if not payload_idx:
        frozen = tuple(bound)
        return lambda payload: frozen
    if payload_idx == (0,):
        rest = tuple(bound[1:])
        return lambda payload: payload + rest
    template = list(bound)  # pragma: no cover - no current entry hits this

    def splice(payload):
        a = list(template)
        for i, p in zip(payload_idx, payload):
            a[i] = p
        return tuple(a)

    return splice


def _freeze_run(entry: abi_spec.AbiEntry, impl: Callable, bound: tuple) -> Callable:
    """Generic plan compiler: freeze every non-payload argument around the
    resolved callable.  Backends/recipes without a dedicated plan hook still
    hoist the whole ABI layer (checks, table lookup, tools branch) out of the
    start path; only the callable's own internal dispatch remains."""
    payload_idx = entry.payload_args
    if not payload_idx:
        frozen = tuple(bound)
        return lambda _impl=impl, _a=frozen: _impl(*_a)
    if payload_idx == (0,):
        # fast path worth keeping off the splicer: direct positional call
        rest = tuple(bound[1:])
        return lambda x, _impl=impl, _rest=rest: _impl(x, *_rest)
    splice = _payload_splicer(entry, bound)  # pragma: no cover

    def run(*payload):
        return impl(*splice(payload))

    return run


# ---------------------------------------------------------------------------
# Method generation from the declarative function table.
#
# Two layers of codegen share the helpers below:
#
# * class-level generic methods (installed once at import): correct for any
#   instance, pay a table lookup + tools branch per call;
# * instance-level specialized entry points (compiled by ``_specialize``):
#   close over the resolved backend callable and tool tuple directly.
# ---------------------------------------------------------------------------
_GEN_ENV = {
    "_nbytes": _nbytes,
    "PAX_ANY_SOURCE": PAX_ANY_SOURCE,
    "PAX_ANY_TAG": PAX_ANY_TAG,
    "PAX_SUCCESS": PAX_SUCCESS,
    "_check": H.check_handle,
    "_ZPK": H.ZERO_PAGE_KINDS,
}
_GEN_ENV.update({f"_HK_{k.name}": k for k in H.HandleKind})
# a user handle's upper bits (handle >> kind-shift) are exactly
# (USER_BIT >> shift) | kind — one shift+compare classifies it
_GEN_ENV.update({
    f"_UK_{k.name}": (H._USER_BIT >> H._USER_KIND_SHIFT) | int(k)
    for k in H.HandleKind
})


def _check_lines(entry: abi_spec.AbiEntry, indent: str = "    ",
                 inline: bool = False) -> list[str]:
    """Handle checks / coercions from the declared argument domains.

    With ``inline`` (the specialized path) the zero-page kind table and the
    user-handle shift compare are emitted inline, so a well-formed handle
    costs two integer compares and no function call; only a *failing* check
    falls back to ``_check`` for the named-constant error message.
    """
    lines = []
    for a in entry.args:
        if a.kind == abi_spec.DATATYPE_VEC:
            lines.append(f"{indent}{a.name} = tuple({a.name})")
            lines.append(f"{indent}for _t in {a.name}:")
            lines.append(f"{indent}    _check(_t, _HK_{a.check_kind.name})")
        elif a.check_kind is not None:
            k = a.check_kind.name
            if inline:
                lines.append(
                    f"{indent}if {a.name} >> {_UKS} != _UK_{k} and ("
                    f"{a.name} < 0 or {a.name} > 1023 "
                    f"or _ZPK[{a.name}] is not _HK_{k}):"
                )
                lines.append(f"{indent}    _check({a.name}, _HK_{k})")
            else:
                lines.append(f"{indent}_check({a.name}, _HK_{k})")
        elif a.kind in (abi_spec.PERM, abi_spec.COUNTS):
            lines.append(f"{indent}{a.name} = tuple({a.name})")
    return lines


def _blocking_src(entry: abi_spec.AbiEntry) -> str:
    params = abi_spec.signature_src(entry, extra_kwargs=True)
    call_args = abi_spec.call_args_src(entry)
    lines = [f"def {entry.name}(self, {params}):"]
    lines += _check_lines(entry)
    lines.append(f"    _impl = self._table[{entry.name!r}]")
    lines.append("    if not self.tools:")
    lines.append(f"        _res = _impl({call_args})")
    lines.append("    else:")
    if entry.bytes_arg:
        dt = ", datatype" if entry.dtype_size_kwarg else ""
        bytes_expr = f"_nbytes({entry.bytes_arg}, self{dt})"
        comm_arg = next(a.name for a in entry.args if a.kind == abi_spec.COMM)
        lines.append(
            f"        _info = {{'bytes': {bytes_expr}, 'comm_handle': {comm_arg}}}"
        )
    else:
        lines.append("        _info = {}")
    lines.append(
        f"        _res = self._dispatch_tools({entry.name!r}, _impl, "
        f"({call_args},), _info)"
    )
    if entry.fills_status:
        lines.append("    if status is not None:")
        lines.append("        status.SOURCE = PAX_ANY_SOURCE")
        lines.append("        status.TAG = PAX_ANY_TAG")
        lines.append("        status.ERROR = PAX_SUCCESS")
    lines.append("    return _res")
    return "\n".join(lines) + "\n"


def _nonblocking_src(entry: abi_spec.AbiEntry) -> str:
    params = abi_spec.signature_src(entry)
    call_args = abi_spec.call_args_src(entry)
    lines = [f"def i{entry.name}(self, {params}):"]
    lines.append(f"    _value = self.{entry.name}({call_args})")
    if entry.temps:
        # converted handle vectors stay alive until completion (§6.2)
        lines.append(
            f"    _temp = getattr(self.backend, {entry.temps_attr!r}, None)"
        )
    else:
        lines.append("    _temp = None")
    lines.append(
        f"    return self._new_request(_value, 'i{entry.name}', temp_state=_temp)"
    )
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Specialized (per-instance) entry-point sources.  No ``self``: the resolved
# backend callable (``_impl``), the tool tuples and the context are free
# variables bound into the function's globals at specialization time.
# ---------------------------------------------------------------------------
def _spec_blocking_src(entry: abi_spec.AbiEntry, tooled: bool) -> str:
    params = abi_spec.signature_src(entry, extra_kwargs=True)
    call_args = abi_spec.call_args_src(entry)
    lines = [f"def {entry.name}({params}):"]
    lines += _check_lines(entry, inline=True)
    if not tooled:
        # the fast path: checks + the direct backend call, nothing else
        if not entry.fills_status:
            lines.append(f"    return _impl({call_args})")
            return "\n".join(lines) + "\n"
        lines.append(f"    _res = _impl({call_args})")
    else:
        if entry.bytes_arg:
            dt = ", datatype" if entry.dtype_size_kwarg else ""
            bytes_expr = f"_nbytes({entry.bytes_arg}, _abi{dt})"
            comm_arg = next(a.name for a in entry.args if a.kind == abi_spec.COMM)
            lines.append(
                f"    _info = {{'bytes': {bytes_expr}, 'comm_handle': {comm_arg}}}"
            )
        else:
            lines.append("    _info = {}")
        lines.append(f"    _args = ({call_args},)")
        lines.append("    for _t in _tools:")
        lines.append(f"        _t.before({entry.name!r}, _args, _info)")
        lines.append(f"    _res = _impl({call_args})")
        lines.append("    for _t in _rtools:")
        lines.append(f"        _res = _t.after({entry.name!r}, _args, _info, _res)")
    if entry.fills_status:
        lines.append("    if status is not None:")
        lines.append("        status.SOURCE = PAX_ANY_SOURCE")
        lines.append("        status.TAG = PAX_ANY_TAG")
        lines.append("        status.ERROR = PAX_SUCCESS")
    lines.append("    return _res")
    return "\n".join(lines) + "\n"


def _spec_nonblocking_src(entry: abi_spec.AbiEntry) -> str:
    params = abi_spec.signature_src(entry)
    call_args = abi_spec.call_args_src(entry)
    lines = [f"def i{entry.name}({params}):"]
    if entry.temps:
        lines.append(f"    _value = _blocking({call_args})")
        lines.append(
            f"    _temp = getattr(_backend, {entry.temps_attr!r}, None)"
        )
        lines.append(
            f"    return _new_request(_value, 'i{entry.name}', temp_state=_temp)"
        )
    else:
        lines.append(
            f"    return _new_request(_blocking({call_args}), 'i{entry.name}')"
        )
    return "\n".join(lines) + "\n"


# code-object caches: source depends only on (entry, tooled?), so each shape
# compiles once per process and every context exec's it with its own globals
_SPEC_BLOCKING_SRC: dict = {}
_SPEC_NONBLOCKING_SRC: dict = {}


def _compile_cached(cache: dict, key, src_fn, name: str, env: dict):
    entry = cache.get(key)
    if entry is None:
        src = src_fn()
        entry = (compile(src, f"<abi_spec:{name}:specialized>", "exec"), src)
        cache[key] = entry
    code, src = entry
    ns: dict = {}
    exec(code, env, ns)
    fn = ns[name]
    fn.__generated_src__ = src
    fn.__qualname__ = f"PaxABI.{name} [specialized]"
    return fn


def _plan_init_src(entry: abi_spec.AbiEntry) -> str:
    """``<name>_init`` source: bind arguments, hand off to the plan compiler.
    Plan construction is an init-frequency event — no specialization needed,
    the *product* (the plan's start/wait closures) is what must be fast."""
    params = abi_spec.signature_src(entry)
    call_args = abi_spec.call_args_src(entry)
    return (
        f"def {entry.name}_init(self, {params}):\n"
        f"    return self._make_plan({entry.name!r}, ({call_args},))\n"
    )


def _install_generated_methods() -> None:
    for entry in abi_spec.ABI_TABLE:
        fn = abi_spec.compile_method(_blocking_src(entry), _GEN_ENV, entry.name)
        fn.__qualname__ = f"PaxABI.{entry.name}"
        setattr(PaxABI, entry.name, fn)
        if entry.nonblocking:
            ifn = abi_spec.compile_method(
                _nonblocking_src(entry), _GEN_ENV, f"i{entry.name}"
            )
            ifn.__qualname__ = f"PaxABI.i{entry.name}"
            setattr(PaxABI, f"i{entry.name}", ifn)
        if entry.persistent:
            pfn = abi_spec.compile_method(
                _plan_init_src(entry), _GEN_ENV, f"{entry.name}_init"
            )
            pfn.__qualname__ = f"PaxABI.{entry.name}_init"
            pfn.__doc__ = (
                f"Persistent-plan constructor for {entry.name!r} (MPI-4 "
                f"{entry.impl_name}_init): binds arguments and hoists all "
                "per-call dispatch work to plan time; returns a Plan whose "
                "start()/wait() are bare closure calls into the backend."
            )
            setattr(PaxABI, f"{entry.name}_init", pfn)


_install_generated_methods()
