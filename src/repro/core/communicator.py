"""Communicators over JAX mesh axes.

An MPI communicator names an ordered process group.  In the JAX SPMD world
the processes are mesh devices, so a communicator resolves to an ordered
tuple of mesh axis names; collective calls made inside ``shard_map`` regions
lower over exactly those axes.

* ``PAX_COMM_WORLD`` → every axis of the active mesh (in mesh order);
* ``PAX_COMM_SELF``  → the empty axis tuple (group of one device);
* derived communicators (``comm_from_axes`` — the ``MPI_Comm_split``-shaped
  constructor) name axis subsets, e.g. the data-parallel group
  ``("pod", "data")`` or the expert-parallel group ``("model",)``.

Handles are the ABI ints from :mod:`handles`; per-context tables map them to
:class:`CommInfo`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
from jax import lax

from . import compat

from . import handles as H
from .errors import PAX_ERR_COMM, PaxError


@dataclasses.dataclass(frozen=True)
class CommInfo:
    handle: int
    axes: tuple[str, ...]  # ordered mesh axes; () == SELF
    mesh_axis_sizes: tuple[int, ...]
    name: str = ""

    @property
    def size(self) -> int:
        return math.prod(self.mesh_axis_sizes) if self.mesh_axis_sizes else 1


class CommTable:
    """Per-ABI-context communicator table."""

    def __init__(self, mesh: Optional[jax.sharding.Mesh]) -> None:
        self._mesh = mesh
        self._table: dict[int, CommInfo] = {}
        self._next_index = 0
        # registration-time-maintained flat lookup (handle -> axes) for the
        # per-call hot path: one dict index, no handle re-check, no CommInfo
        # attribute chase.  `info()` stays the checked metadata query.
        self.axes_by_handle: dict[int, tuple[str, ...]] = {}
        axes = tuple(mesh.axis_names) if mesh is not None else ()
        sizes = tuple(mesh.shape[a] for a in axes) if mesh is not None else ()
        self._table[H.PAX_COMM_WORLD] = CommInfo(
            H.PAX_COMM_WORLD, axes, sizes, "PAX_COMM_WORLD"
        )
        self._table[H.PAX_COMM_SELF] = CommInfo(H.PAX_COMM_SELF, (), (), "PAX_COMM_SELF")
        self.axes_by_handle[H.PAX_COMM_WORLD] = axes
        self.axes_by_handle[H.PAX_COMM_SELF] = ()

    @property
    def mesh(self) -> Optional[jax.sharding.Mesh]:
        return self._mesh

    def info(self, handle: int) -> CommInfo:
        H.check_handle(handle, H.HandleKind.COMM)
        if handle == H.PAX_COMM_NULL:
            raise PaxError(PAX_ERR_COMM, "PAX_COMM_NULL")
        try:
            return self._table[handle]
        except KeyError:
            raise PaxError(PAX_ERR_COMM, H.describe(handle)) from None

    def comm_from_axes(self, axes: Sequence[str], name: str = "") -> int:
        """Create a communicator over a subset of mesh axes (split analogue)."""
        if self._mesh is None:
            raise PaxError(PAX_ERR_COMM, "no mesh bound to this context")
        axes = tuple(axes)
        for a in axes:
            if a not in self._mesh.axis_names:
                raise PaxError(PAX_ERR_COMM, f"axis {a!r} not in mesh {self._mesh.axis_names}")
        handle = H.make_user_handle(H.HandleKind.COMM, self._next_index)
        self._next_index += 1
        sizes = tuple(self._mesh.shape[a] for a in axes)
        self._table[handle] = CommInfo(handle, axes, sizes, name or f"axes{axes}")
        self.axes_by_handle[handle] = axes
        return handle

    def comm_dup(self, handle: int) -> int:
        info = self.info(handle)
        new = H.make_user_handle(H.HandleKind.COMM, self._next_index)
        self._next_index += 1
        self._table[new] = dataclasses.replace(info, handle=new, name=info.name + "+dup")
        self.axes_by_handle[new] = info.axes
        return new

    def comm_free(self, handle: int) -> None:
        if H.is_predefined(handle):
            raise PaxError(PAX_ERR_COMM, "cannot free a predefined communicator")
        self._table.pop(handle, None)
        self.axes_by_handle.pop(handle, None)


def comm_rank_traced(info: CommInfo):
    """Linearized rank within the communicator (row-major over its axes).

    Only valid inside a shard_map region where the axes are bound manual.
    """
    if not info.axes:
        return 0
    rank = lax.axis_index(info.axes[0])
    for a in info.axes[1:]:
        rank = rank * compat.axis_size(a) + lax.axis_index(a)
    return rank


def comm_size_static(info: CommInfo) -> int:
    return info.size
