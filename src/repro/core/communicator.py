"""Communicators over JAX mesh axes.

An MPI communicator names an ordered process group.  In the JAX SPMD world
the processes are mesh devices, so a communicator resolves to an ordered
tuple of mesh axis names; collective calls made inside ``shard_map`` regions
lower over exactly those axes.

* ``PAX_COMM_WORLD`` → every axis of the active mesh (in mesh order);
* ``PAX_COMM_SELF``  → the empty axis tuple (group of one device);
* derived communicators (``comm_from_axes`` — the ``MPI_Comm_split``-shaped
  constructor) name axis subsets, e.g. the data-parallel group
  ``("pod", "data")`` or the expert-parallel group ``("model",)``.

Handles are the ABI ints from :mod:`handles`; per-context tables map them to
:class:`CommInfo`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
from jax import lax

from . import compat

from . import handles as H
from .errors import PAX_ERR_COMM, PAX_ERR_REVOKED, PaxError


@dataclasses.dataclass(frozen=True)
class CommInfo:
    handle: int
    axes: tuple[str, ...]  # ordered mesh axes; () == SELF
    mesh_axis_sizes: tuple[int, ...]
    name: str = ""
    #: ranks excluded from the group (ULFM shrink survivors-only comms).  The
    #: axes stay those of the parent — in the single-controller simulation a
    #: shrunk comm is the *transition artifact* carried from "revoked" to
    #: "training rebuilt a dense mesh over the survivors"; its job is to name
    #: the survivor group, not to run collectives inside the dead mesh.
    excludes: tuple[int, ...] = ()

    @property
    def full_size(self) -> int:
        """Group size before exclusions (the parent's extent)."""
        return math.prod(self.mesh_axis_sizes) if self.mesh_axis_sizes else 1

    @property
    def size(self) -> int:
        return self.full_size - len(self.excludes)


class CommTable:
    """Per-ABI-context communicator table."""

    def __init__(self, mesh: Optional[jax.sharding.Mesh]) -> None:
        self._mesh = mesh
        self._table: dict[int, CommInfo] = {}
        self._next_index = 0
        # registration-time-maintained flat lookup (handle -> axes) for the
        # per-call hot path: one dict index, no handle re-check, no CommInfo
        # attribute chase.  `info()` stays the checked metadata query.
        self.axes_by_handle: dict[int, tuple[str, ...]] = {}
        # -- fault tier state (ULFM) --------------------------------------
        # Revocation poisons the hot path by *construction*: `revoke()` pops
        # the handle from axes_by_handle, so the per-call fast lookup misses
        # and falls through to `info()`, which raises PAX_ERR_REVOKED.  The
        # unrevoked path stays byte-identical — no added check anywhere hot.
        self.revoked: set[int] = set()
        #: per-comm acknowledged failures (comm_failure_ack); agree refuses
        #: to proceed while unacknowledged failures exist (ULFM contract)
        self.acked: dict[int, frozenset] = {}
        axes = tuple(mesh.axis_names) if mesh is not None else ()
        sizes = tuple(mesh.shape[a] for a in axes) if mesh is not None else ()
        self._table[H.PAX_COMM_WORLD] = CommInfo(
            H.PAX_COMM_WORLD, axes, sizes, "PAX_COMM_WORLD"
        )
        self._table[H.PAX_COMM_SELF] = CommInfo(H.PAX_COMM_SELF, (), (), "PAX_COMM_SELF")
        self.axes_by_handle[H.PAX_COMM_WORLD] = axes
        self.axes_by_handle[H.PAX_COMM_SELF] = ()

    @property
    def mesh(self) -> Optional[jax.sharding.Mesh]:
        return self._mesh

    def info(self, handle: int, *, allow_revoked: bool = False) -> CommInfo:
        H.check_handle(handle, H.HandleKind.COMM)
        if handle == H.PAX_COMM_NULL:
            raise PaxError(PAX_ERR_COMM, "PAX_COMM_NULL")
        try:
            info = self._table[handle]
        except KeyError:
            raise PaxError(PAX_ERR_COMM, H.describe(handle)) from None
        if self.revoked and handle in self.revoked and not allow_revoked:
            # only the fault-tier entries (revoke/agree/shrink/ack/get_failed)
            # may operate on a revoked communicator — the ULFM contract
            raise PaxError(PAX_ERR_REVOKED, info.name or H.describe(handle))
        return info

    def comm_from_axes(self, axes: Sequence[str], name: str = "") -> int:
        """Create a communicator over a subset of mesh axes (split analogue)."""
        if self._mesh is None:
            raise PaxError(PAX_ERR_COMM, "no mesh bound to this context")
        axes = tuple(axes)
        for a in axes:
            if a not in self._mesh.axis_names:
                raise PaxError(PAX_ERR_COMM, f"axis {a!r} not in mesh {self._mesh.axis_names}")
        handle = H.make_user_handle(H.HandleKind.COMM, self._next_index)
        self._next_index += 1
        sizes = tuple(self._mesh.shape[a] for a in axes)
        self._table[handle] = CommInfo(handle, axes, sizes, name or f"axes{axes}")
        self.axes_by_handle[handle] = axes
        return handle

    def comm_dup(self, handle: int) -> int:
        info = self.info(handle)
        new = H.make_user_handle(H.HandleKind.COMM, self._next_index)
        self._next_index += 1
        self._table[new] = dataclasses.replace(info, handle=new, name=info.name + "+dup")
        self.axes_by_handle[new] = info.axes
        return new

    def comm_free(self, handle: int) -> None:
        if H.is_predefined(handle):
            raise PaxError(PAX_ERR_COMM, "cannot free a predefined communicator")
        self._table.pop(handle, None)
        self.axes_by_handle.pop(handle, None)
        self.revoked.discard(handle)
        self.acked.pop(handle, None)

    # -- fault tier (ULFM) --------------------------------------------------
    def revoke(self, handle: int) -> None:
        """Mark ``handle`` revoked.  Idempotent.

        Enforcement is by hot-path poisoning: the handle leaves
        ``axes_by_handle``, so every collective's registration-time fast
        lookup misses and lands in :meth:`info`, which raises
        ``PAX_ERR_REVOKED``.  Nothing is added to the unrevoked path.
        """
        self.info(handle, allow_revoked=True)  # validate the handle
        self.revoked.add(handle)
        self.axes_by_handle.pop(handle, None)

    def is_revoked(self, handle: int) -> bool:
        return handle in self.revoked

    def register_shrunk(self, parent: int, excludes, name: str = "") -> int:
        """Register the dense survivor communicator of an ULFM shrink.

        The child carries the parent's axes with ``excludes`` recorded, so
        ``size`` reports the survivor count.  The child is *not* revoked
        even when the parent is — that is the entire point of shrink.
        """
        info = self.info(parent, allow_revoked=True)
        handle = H.make_user_handle(H.HandleKind.COMM, self._next_index)
        self._next_index += 1
        self._table[handle] = CommInfo(
            handle, info.axes, info.mesh_axis_sizes,
            name or (info.name + "+shrink"),
            excludes=tuple(sorted(set(info.excludes) | set(excludes))),
        )
        self.axes_by_handle[handle] = info.axes
        return handle


def comm_rank_traced(info: CommInfo):
    """Linearized rank within the communicator (row-major over its axes).

    Only valid inside a shard_map region where the axes are bound manual.
    """
    if not info.axes:
        return 0
    rank = lax.axis_index(info.axes[0])
    for a in info.axes[1:]:
        rank = rank * compat.axis_size(a) + lax.axis_index(a)
    return rank


def comm_size_static(info: CommInfo) -> int:
    return info.size
