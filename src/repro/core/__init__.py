"""repro.core — the PAX ABI: a standard collective ABI for JAX runtimes.

The paper's contribution (MPI ABI standardization, EuroMPI'23) as a
composable JAX module.  See DESIGN.md for the full mapping.

Public surface::

    from repro.core import pax_init, PAX_SUM, PAX_COMM_WORLD, ...

    abi = pax_init(mesh, impl="paxi")          # or "ompix", "ring", ...
    dp  = abi.comm_from_axes(("pod", "data"))  # derived communicator
    ... inside shard_map: abi.allreduce(g, PAX_SUM, dp) ...
"""
from .abi import PaxABI, Plan, Request  # noqa: F401
from .communicator import CommInfo, CommTable  # noqa: F401
from .constants import *  # noqa: F401,F403
from .datatypes import DatatypeRegistry, TypeDescriptor, N_PREDEFINED  # noqa: F401
from .errors import PAX_SUCCESS, PaxError, error_string  # noqa: F401
from .handles import *  # noqa: F401,F403
from .handles import HandleKind, describe, handle_kind, is_null, is_predefined  # noqa: F401
from .interpose import ByteCounter, CallCounter, SequenceStamper, Tool, WallClockTracer  # noqa: F401
from .ops import OpRegistry  # noqa: F401
from .registry import available_backends, get_backend, pax_init, register_backend  # noqa: F401
from .status import STATUS_BYTES, Status, status_array, traced_status  # noqa: F401
