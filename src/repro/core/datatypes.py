"""Datatype registry: ABI datatype handles <-> jnp dtypes, and the two
``type_size`` strategies the paper benchmarks (§6.1).

* :func:`type_size_encoded` — MPICH-style: extract the size from the handle
  bits (fixed-size types only; falls back to the table for variable-size).
* :func:`type_size_lookup` — Open-MPI-style: always go through an object
  table (the 352-byte-struct pointer chase of §3.3, modelled as a dict of
  descriptor objects).

Both must agree everywhere; the benchmark ``benchmarks/bench_type_size.py``
reproduces the paper's measurement that the two are equally negligible.

Derived datatypes (``type_contiguous``/``type_vector``) allocate user handles
above the zero page and register descriptors, giving the Mukautuva layer a
nontrivial conversion job (the paper's alltoallw worst case needs vectors of
derived types).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import handles as H
from .errors import PAX_ERR_TYPE, PaxError

try:  # jax is required by the framework but keep this module importable alone
    import jax.numpy as jnp

    _JNP = True
except Exception:  # pragma: no cover
    jnp = None
    _JNP = False


@dataclasses.dataclass(frozen=True)
class TypeDescriptor:
    """The IMPL-side object an Open-MPI-style backend would point to."""

    handle: int
    name: str
    size: int  # bytes; element size * count for derived types
    numpy_dtype: Optional[np.dtype]
    count: int = 1  # elements (for contiguous/vector derived types)
    base: Optional[int] = None  # base type handle for derived types


def _np(name: str) -> np.dtype:
    return np.dtype(name)


# ---------------------------------------------------------------------------
# Predefined datatype table. Sizes of variable-size C types follow the A64O64
# LP64 platform model (§5.1): int=4, long=8, size-like=8.
# ---------------------------------------------------------------------------
_PREDEFINED: dict[int, TypeDescriptor] = {}


def _register(handle: int, name: str, size: int, np_dtype: Optional[np.dtype]) -> None:
    _PREDEFINED[handle] = TypeDescriptor(handle, name, size, np_dtype)


_register(H.PAX_DATATYPE_NULL, "PAX_DATATYPE_NULL", 0, None)
# variable-size C types (size from table, never from bits)
_register(H.PAX_AINT, "PAX_AINT", 8, _np("int64"))
_register(H.PAX_COUNT, "PAX_COUNT", 8, _np("int64"))
_register(H.PAX_OFFSET, "PAX_OFFSET", 8, _np("int64"))
_register(H.PAX_PACKED, "PAX_PACKED", 1, _np("uint8"))
_register(H.PAX_SHORT, "PAX_SHORT", 2, _np("int16"))
_register(H.PAX_INT, "PAX_INT", 4, _np("int32"))
_register(H.PAX_LONG, "PAX_LONG", 8, _np("int64"))
_register(H.PAX_LONG_LONG, "PAX_LONG_LONG", 8, _np("int64"))
_register(H.PAX_UNSIGNED_SHORT, "PAX_UNSIGNED_SHORT", 2, _np("uint16"))
_register(H.PAX_UNSIGNED_INT, "PAX_UNSIGNED_INT", 4, _np("uint32"))
_register(H.PAX_UNSIGNED_LONG, "PAX_UNSIGNED_LONG", 8, _np("uint64"))
_register(H.PAX_UNSIGNED_LONG_LONG, "PAX_UNSIGNED_LONG_LONG", 8, _np("uint64"))
_register(H.PAX_FLOAT, "PAX_FLOAT", 4, _np("float32"))
_register(H.PAX_DOUBLE, "PAX_DOUBLE", 8, _np("float64"))
_register(H.PAX_LONG_DOUBLE, "PAX_LONG_DOUBLE", 8, _np("float64"))
_register(H.PAX_C_BOOL, "PAX_C_BOOL", 1, _np("bool"))
# fixed-size types (size ALSO encoded in bits 3..5; table must agree)
_register(H.PAX_INT8_T, "PAX_INT8_T", 1, _np("int8"))
_register(H.PAX_UINT8_T, "PAX_UINT8_T", 1, _np("uint8"))
_register(H.PAX_CHAR, "PAX_CHAR", 1, _np("int8"))
_register(H.PAX_SIGNED_CHAR, "PAX_SIGNED_CHAR", 1, _np("int8"))
_register(H.PAX_UNSIGNED_CHAR, "PAX_UNSIGNED_CHAR", 1, _np("uint8"))
_register(H.PAX_BYTE, "PAX_BYTE", 1, _np("uint8"))
_register(H.PAX_INT16_T, "PAX_INT16_T", 2, _np("int16"))
_register(H.PAX_UINT16_T, "PAX_UINT16_T", 2, _np("uint16"))
_register(H.PAX_FLOAT16, "PAX_FLOAT16", 2, _np("float16"))
_register(H.PAX_INT32_T, "PAX_INT32_T", 4, _np("int32"))
_register(H.PAX_UINT32_T, "PAX_UINT32_T", 4, _np("uint32"))
_register(H.PAX_FLOAT32, "PAX_FLOAT32", 4, _np("float32"))
_register(H.PAX_INT64_T, "PAX_INT64_T", 8, _np("int64"))
_register(H.PAX_UINT64_T, "PAX_UINT64_T", 8, _np("uint64"))
_register(H.PAX_FLOAT64, "PAX_FLOAT64", 8, _np("float64"))
_register(H.PAX_COMPLEX64, "PAX_COMPLEX64", 8, _np("complex64"))
_register(H.PAX_COMPLEX128, "PAX_COMPLEX128", 16, _np("complex128"))

# TPU extension dtypes, allocated in reserved fixed-size slots (DESIGN.md §1.4)
if _JNP:
    _register(H.PAX_BFLOAT16, "PAX_BFLOAT16", 2, np.dtype(jnp.bfloat16))
    try:
        _register(H.PAX_FLOAT8_E4M3, "PAX_FLOAT8_E4M3", 1, np.dtype(jnp.float8_e4m3fn))
        _register(H.PAX_FLOAT8_E5M2, "PAX_FLOAT8_E5M2", 1, np.dtype(jnp.float8_e5m2))
    except Exception:  # pragma: no cover - older jax without fp8
        pass

N_PREDEFINED = len(_PREDEFINED)

# dtype -> canonical handle (first registration wins for aliases like CHAR)
_NP_TO_HANDLE: dict[np.dtype, int] = {}
for _h, _d in sorted(_PREDEFINED.items()):
    if _d.numpy_dtype is not None and _d.numpy_dtype not in _NP_TO_HANDLE:
        # prefer fixed-size canonical handles for numpy-visible dtypes
        _NP_TO_HANDLE[_d.numpy_dtype] = _h
# canonical overrides: fixed-size handles win over C aliases
for _h in (
    H.PAX_INT8_T,
    H.PAX_UINT8_T,
    H.PAX_INT16_T,
    H.PAX_UINT16_T,
    H.PAX_INT32_T,
    H.PAX_UINT32_T,
    H.PAX_INT64_T,
    H.PAX_UINT64_T,
    H.PAX_FLOAT16,
    H.PAX_FLOAT32,
    H.PAX_FLOAT64,
    H.PAX_COMPLEX64,
    H.PAX_COMPLEX128,
):
    _NP_TO_HANDLE[_PREDEFINED[_h].numpy_dtype] = _h
if _JNP:
    _NP_TO_HANDLE[np.dtype(jnp.bfloat16)] = H.PAX_BFLOAT16


class DatatypeRegistry:
    """Predefined + derived datatype registry.

    One instance per ABI context; derived types allocate user handles above
    the zero page (``handles.make_user_handle``).
    """

    def __init__(self) -> None:
        self._derived: dict[int, TypeDescriptor] = {}
        self._next_index = 0

    # -- queries ------------------------------------------------------------

    def descriptor(self, handle: int) -> TypeDescriptor:
        desc = _PREDEFINED.get(handle)
        if desc is None:
            desc = self._derived.get(handle)
        if desc is None:
            raise PaxError(PAX_ERR_TYPE, H.describe(handle))
        return desc

    def type_size_encoded(self, handle: int) -> int:
        """MPICH-style: bit extraction for fixed-size types (§3.3/§6.1)."""
        if H.datatype_is_fixed_size(handle):
            return H.datatype_encoded_size(handle)
        return self.descriptor(handle).size

    def type_size_lookup(self, handle: int) -> int:
        """Open-MPI-style: always dereference the descriptor (§3.3/§6.1)."""
        return self.descriptor(handle).size

    type_size = type_size_encoded  # ABI default

    def to_numpy_dtype(self, handle: int) -> np.dtype:
        d = self.descriptor(handle)
        if d.numpy_dtype is None:
            raise PaxError(PAX_ERR_TYPE, f"{d.name} has no array dtype")
        return d.numpy_dtype

    def from_array(self, array) -> int:
        """Infer the canonical ABI datatype handle from an array's dtype."""
        dt = np.dtype(array.dtype)
        try:
            return _NP_TO_HANDLE[dt]
        except KeyError:
            raise PaxError(PAX_ERR_TYPE, f"no ABI datatype for dtype {dt}") from None

    # -- derived types (gives Mukautuva real conversion work) ---------------

    def type_contiguous(self, count: int, base: int) -> int:
        H.check_handle(base, H.HandleKind.DATATYPE)
        bdesc = self.descriptor(base)
        handle = H.make_user_handle(H.HandleKind.DATATYPE, self._next_index)
        self._next_index += 1
        self._derived[handle] = TypeDescriptor(
            handle,
            f"contig({count},{bdesc.name})",
            bdesc.size * count,
            bdesc.numpy_dtype,
            count=count * bdesc.count,
            base=base,
        )
        return handle

    def type_vector(self, count: int, blocklength: int, stride: int, base: int) -> int:
        H.check_handle(base, H.HandleKind.DATATYPE)
        bdesc = self.descriptor(base)
        handle = H.make_user_handle(H.HandleKind.DATATYPE, self._next_index)
        self._next_index += 1
        self._derived[handle] = TypeDescriptor(
            handle,
            f"vector({count},{blocklength},{stride},{bdesc.name})",
            bdesc.size * count * blocklength,
            bdesc.numpy_dtype,
            count=count * blocklength * bdesc.count,
            base=base,
        )
        return handle

    def type_free(self, handle: int) -> None:
        self._derived.pop(handle, None)


def predefined_descriptors() -> dict[int, TypeDescriptor]:
    return dict(_PREDEFINED)
