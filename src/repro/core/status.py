"""The standard status object (paper §5.2).

::

    typedef struct MPI_Status {
        int MPI_SOURCE;
        int MPI_TAG;
        int MPI_ERROR;
        int mpi_reserved[5];
    } MPI_Status;

32 bytes — "good alignment when arrays of statuses are used, and includes at
least two extra fields more than current implementations".  The reserved
slack is the feature §4.8 gives to tools: interposition layers can hide
state there (``core/interpose.py`` uses reserved[0..1] for a tool id and a
per-call sequence number).

Two concrete representations share the layout:

* :class:`Status` — a NumPy-backed view (host side, eager calls);
* :func:`traced_status` — a ``(8,) int32`` jnp array for use inside jit.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

STATUS_WORDS = 8
STATUS_BYTES = STATUS_WORDS * 4
N_RESERVED = 5
_IDX_SOURCE, _IDX_TAG, _IDX_ERROR = 0, 1, 2


class Status:
    """A 32-byte status backed by an ``int32[8]`` NumPy buffer."""

    __slots__ = ("_buf",)

    def __init__(self, buf: np.ndarray | None = None) -> None:
        if buf is None:
            buf = np.zeros(STATUS_WORDS, dtype=np.int32)
        if buf.dtype != np.int32 or buf.shape != (STATUS_WORDS,):
            raise ValueError("status buffer must be int32[8]")
        self._buf = buf

    # public fields -----------------------------------------------------
    @property
    def SOURCE(self) -> int:
        return int(self._buf[_IDX_SOURCE])

    @SOURCE.setter
    def SOURCE(self, v: int) -> None:
        self._buf[_IDX_SOURCE] = v

    @property
    def TAG(self) -> int:
        return int(self._buf[_IDX_TAG])

    @TAG.setter
    def TAG(self, v: int) -> None:
        self._buf[_IDX_TAG] = v

    @property
    def ERROR(self) -> int:
        return int(self._buf[_IDX_ERROR])

    @ERROR.setter
    def ERROR(self, v: int) -> None:
        self._buf[_IDX_ERROR] = v

    # reserved slack (tool-visible, §4.8) --------------------------------
    def get_reserved(self, i: int) -> int:
        if not 0 <= i < N_RESERVED:
            raise IndexError(i)
        return int(self._buf[3 + i])

    def set_reserved(self, i: int, v: int) -> None:
        if not 0 <= i < N_RESERVED:
            raise IndexError(i)
        self._buf[3 + i] = v

    # layout ------------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return int(self._buf.nbytes)

    def raw(self) -> np.ndarray:
        return self._buf

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"Status(SOURCE={self.SOURCE}, TAG={self.TAG}, ERROR={self.ERROR}, "
            f"reserved={[self.get_reserved(i) for i in range(N_RESERVED)]})"
        )


def status_array(n: int) -> np.ndarray:
    """A contiguous array of n statuses: shape (n, 8) int32 — 32n bytes, the
    alignment property §5.2 calls out for arrays of statuses."""
    return np.zeros((n, STATUS_WORDS), dtype=np.int32)


def status_view(arr: np.ndarray, i: int) -> Status:
    return Status(arr[i])


def traced_status(source: int = -1, tag: int = -1, error: int = 0):
    """Status as a traced jnp value for use inside jitted code."""
    base = jnp.zeros((STATUS_WORDS,), dtype=jnp.int32)
    base = base.at[_IDX_SOURCE].set(source)
    base = base.at[_IDX_TAG].set(tag)
    return base.at[_IDX_ERROR].set(error)
