"""Reduction operations: predefined op handles + user-defined ops.

User-defined ops are the ABI's *callback* surface (paper §3 item 4): the
user registers a function against the ABI; backends only ever see the op
*handle*. When a foreign backend executes a user op, the Mukautuva layer
interposes a trampoline that converts backend-domain values back to the ABI
domain before invoking the user function — the paper's callback-translation
mechanism (§6.2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from . import handles as H
from .errors import PAX_ERR_OP, PaxError

# Semantics of the predefined ops as binary jnp functions (the portable
# definition; backends may use faster native collectives for SUM/MIN/MAX).
PREDEFINED_OP_FNS: dict[int, Callable] = {
    H.PAX_SUM: lambda a, b: a + b,
    H.PAX_PROD: lambda a, b: a * b,
    H.PAX_MIN: jnp.minimum,
    H.PAX_MAX: jnp.maximum,
    H.PAX_BAND: lambda a, b: a & b,
    H.PAX_BOR: lambda a, b: a | b,
    H.PAX_BXOR: lambda a, b: a ^ b,
    H.PAX_LAND: lambda a, b: (a.astype(bool) & b.astype(bool)).astype(a.dtype),
    H.PAX_LOR: lambda a, b: (a.astype(bool) | b.astype(bool)).astype(a.dtype),
    H.PAX_LXOR: lambda a, b: (a.astype(bool) ^ b.astype(bool)).astype(a.dtype),
    H.PAX_REPLACE: lambda a, b: b,
    H.PAX_NO_OP: lambda a, b: a,
}


def _minloc(a, b):
    """MINLOC over (value, index) pairs stacked on the last axis."""
    av, ai = a[..., 0], a[..., 1]
    bv, bi = b[..., 0], b[..., 1]
    take_a = (av < bv) | ((av == bv) & (ai <= bi))
    v = jnp.where(take_a, av, bv)
    i = jnp.where(take_a, ai, bi)
    return jnp.stack([v, i], axis=-1)


def _maxloc(a, b):
    av, ai = a[..., 0], a[..., 1]
    bv, bi = b[..., 0], b[..., 1]
    take_a = (av > bv) | ((av == bv) & (ai <= bi))
    v = jnp.where(take_a, av, bv)
    i = jnp.where(take_a, ai, bi)
    return jnp.stack([v, i], axis=-1)


PREDEFINED_OP_FNS[H.PAX_MINLOC] = _minloc
PREDEFINED_OP_FNS[H.PAX_MAXLOC] = _maxloc

# Ops whose reduction XLA supports natively on the wire.
NATIVE_COLLECTIVE_OPS = frozenset({H.PAX_SUM, H.PAX_MIN, H.PAX_MAX})

# All predefined ops are commutative per MPI semantics.
COMMUTATIVE_PREDEFINED = frozenset(PREDEFINED_OP_FNS)


@dataclasses.dataclass(frozen=True)
class OpDescriptor:
    handle: int
    fn: Callable
    commutative: bool
    name: str


class OpRegistry:
    """Per-context table of user-defined reduction ops (``MPI_Op_create``)."""

    def __init__(self) -> None:
        self._user: dict[int, OpDescriptor] = {}
        self._next_index = 0

    def op_create(self, fn: Callable, *, commutative: bool = True, name: str = "") -> int:
        handle = H.make_user_handle(H.HandleKind.OP, self._next_index)
        self._next_index += 1
        self._user[handle] = OpDescriptor(
            handle, fn, commutative, name or getattr(fn, "__name__", "user_op")
        )
        return handle

    def op_free(self, handle: int) -> None:
        self._user.pop(handle, None)

    def descriptor(self, handle: int) -> OpDescriptor:
        if handle in self._user:
            return self._user[handle]
        if handle in PREDEFINED_OP_FNS:
            return OpDescriptor(
                handle,
                PREDEFINED_OP_FNS[handle],
                True,
                H.PREDEFINED_NAMES.get(handle, "?"),
            )
        raise PaxError(PAX_ERR_OP, H.describe(handle))

    def fn(self, handle: int) -> Callable:
        return self.descriptor(handle).fn

    def is_user(self, handle: int) -> bool:
        return handle in self._user
