"""Fault injection below the ABI: a backend wrapper that kills a rank.

The fault tier is only testable if something can actually fail, and a
single-controller JAX stack has no ranks to ``kill -9``.  This module is the
deterministic stand-in: a :class:`FaultyBackend` wraps any paxi-convention
backend (and :class:`FaultyLib` wraps a foreign ompix-convention library),
counts collective calls, and at a configured call count declares a
configured rank dead.  From that point every collective on a communicator
that still *contains* the dead rank raises ``PAX_ERR_PROC_FAILED`` — until
the caller walks the ULFM sequence (revoke → ack → agree → shrink) and
continues on a survivor communicator, which excludes the corpse and is
therefore absolved.

Placement matters: the wrapper sits **below the ABI**, like a tool sits
above it.  Negotiation resolves the function table against the wrapper, so
the injected failures surface through exactly the dispatch path real
failures would take — native entries trip in the wrapped method, emulated
recipes trip in their grounded primitives, Mukautuva translates the foreign
``OMPIX_ERR_PROC_FAILED`` rc through its :class:`ErrorTranslator`.

Deliberately NOT registered in the backend registry's factory table: the
battery's backend sweep must never meet a booby-trapped backend by accident.
Selection is by the explicit ``faulty:<inner>`` prefix
(:func:`repro.core.registry.get_backend`) or by constructing the wrapper
directly; the kill schedule comes from ``PAX_FAULT_SCHEDULE`` (deterministic
CI chaos — ``"rank=5,at=12"``) or from :meth:`FaultSchedule.arm`.

Beyond rank death, the schedule knows three *transport* fault modes
(``mode=corrupt|drop|delay``, PR 10) — the wire misbehaving short of a
process dying:

* ``corrupt`` — a deterministic bit-flip of the scheduled collective's
  payload, applied **once** and only on the scheduled rank's shard (the
  flip is built into the trace behind a ``lax.axis_index`` mask, so the
  cross-rank disagreement is real and detectable by the ABI's integrity
  mode, never a host-side fiction);
* ``drop`` — from the scheduled call on, collectives on comms containing
  the rank never complete: the wrapper plants an
  :class:`~repro.core.errors.IncompleteValue` sentinel as the result, and
  the only place it ever surfaces is the ``wait`` family's ``timeout_s``
  (a drop is a hang, not an error).  Payload-less or status-convention
  entries (``barrier``, ``sendrecv``) cannot carry the sentinel and raise
  ``PAX_ERR_PROC_FAILED`` instead — which the heartbeat exchange absorbs
  as an observation, exactly the attribution path a real dropped link
  feeds.  ``local_failed`` stays **silent** for drops: only timeout plus
  an installed :class:`~repro.runtime.liveness.HeartbeatMonitor` may name
  the offender, which is the entire point of the mode.
* ``delay`` — straggler latency: ``delay_s`` of host sleep on every
  scheduled hop from the armed call on (surfaced by ``StepWatchdog``).

All three ride the same tripwire/rc machinery as death, so they compose
under Mukautuva and reach paxi/minimal/ompix identically.  On emulated
entries (minimal) a dropped ground primitive propagates its sentinel
through the recipe chain — downstream tripwired calls pass it through
untouched — so the drop surfaces at the top-level wait like anywhere else.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .. import abi_spec
from ..errors import PAX_ERR_PROC_FAILED, IncompleteValue, PaxError
from . import ompix as ox
from ._lax import rank as _lax_rank
from .base import Backend

ENV_VAR = "PAX_FAULT_SCHEDULE"

#: transport faults the schedule grammar accepts (``die`` is the PR-7 kill)
_MODES = ("die", "corrupt", "drop", "delay")

#: entries whose results cannot carry the drop sentinel (no payload, or a
#: status convention that is unpacked before any wait sees it); a drop there
#: degrades to PROC_FAILED — which the heartbeat beat exchange absorbs as a
#: missed-beat observation, the same signal a really-dropped link produces
_UNDROPPABLE = ("barrier", "sendrecv")


def _flip_sign_bit(x):
    """The deterministic corruption: XOR the top bit of every element's
    representation — a pure bit-flip (sign for floats/ints), large in value
    terms so both the exact-agreement and the conservation checksum rules
    see it.  Bitcast in, XOR, bitcast out; dtype and shape unchanged."""
    dt = x.dtype
    if dt == jnp.bool_:
        return jnp.logical_not(x)
    size = jnp.dtype(dt).itemsize
    width = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}.get(size)
    if width is None:  # 8-byte lanes only exist under x64; negate instead
        return -x
    bits = lax.bitcast_convert_type(x, width)
    flipped = bits ^ jnp.array(1 << (8 * size - 1), width)
    return lax.bitcast_convert_type(flipped, dt)


def _corrupt_member(value, axes, kill_rank: int, calls: int):
    """Corrupt ``value`` on the shard whose linearized rank over ``axes``
    is ``kill_rank`` (row-major, the comm rank convention).  Runs at trace
    time inside the collective's shard_map region, so the divergence is a
    real cross-rank fact in the compiled computation."""
    r = _lax_rank(axes)

    def leaf(x):
        if not hasattr(x, "dtype"):
            return x
        return jnp.where(r == kill_rank, _flip_sign_bit(x), x)

    return jax.tree_util.tree_map(leaf, value)


@dataclasses.dataclass
class FaultSchedule:
    """When which rank misbehaves *how*, plus the call counter deciding it.

    ``kill_rank`` is a linearized world rank; ``at_call`` is the collective
    call count after which the fault arms (-1 disarms).  ``mode`` selects the
    fault class: ``die`` (PR 7 — the rank is dead from then on), ``corrupt``
    (one bit-flipped payload at the armed call, then clean — so a retry of
    the same collective is provably bitwise-identical to an unfailed run),
    ``drop`` (every collective on a comm containing the rank hangs from then
    on — a downed link, so retries also time out and escalation to the
    rank-death funnel is the only way out), and ``delay`` (``delay_s`` of
    straggler latency on every scheduled hop from then on).  The same
    schedule object is shared by every wrapper layer of one backend, so the
    counter is global per context — deterministic for a fixed call sequence.
    """

    kill_rank: int = -1
    at_call: int = -1
    calls: int = 0
    dead: bool = False
    mode: str = "die"
    delay_s: float = 0.05
    dropping: bool = False   # drop armed and past at_call (sticky)
    corrupted: bool = False  # the one-shot corruption has been spent

    @classmethod
    def from_env(cls, text: Optional[str] = None) -> "FaultSchedule":
        """Parse ``"rank=R,at=N[,mode=M][,delay=S]"`` (the CI chaos knob);
        empty → disarmed.  ``mode`` defaults to ``die`` so the pre-existing
        two-field grammar keeps its exact meaning."""
        if text is None:
            text = os.environ.get(ENV_VAR, "")
        sched = cls()
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            key, _, val = part.partition("=")
            key = key.strip()
            if key == "rank":
                sched.kill_rank = int(val)
            elif key == "at":
                sched.at_call = int(val)
            elif key == "mode":
                val = val.strip()
                if val not in _MODES:
                    raise ValueError(
                        f"bad {ENV_VAR} mode {val!r} (one of {_MODES})")
                sched.mode = val
            elif key == "delay":
                sched.delay_s = float(val)
            else:
                raise ValueError(f"bad {ENV_VAR} field {part!r} "
                                 "(expected rank=R,at=N[,mode=M][,delay=S])")
        return sched

    @property
    def armed(self) -> bool:
        return self.kill_rank >= 0 and (self.at_call >= 0 or self.dead)

    def arm(self, kill_rank: int, after: int = 0,
            mode: Optional[str] = None) -> None:
        """Fault ``kill_rank`` after ``after`` more collective calls."""
        self.kill_rank = kill_rank
        self.at_call = self.calls + after
        if mode is not None:
            if mode not in _MODES:
                raise ValueError(f"bad fault mode {mode!r} (one of {_MODES})")
            self.mode = mode

    def fault_now(self) -> Optional[str]:
        """Count one collective call; the fault to inject on THIS call
        (``None`` when the wire is clean).  ``die`` and ``drop`` are sticky,
        ``corrupt`` fires once (the injector marks it spent via
        ``corrupted`` after actually applying it), ``delay`` repeats."""
        self.calls += 1
        if self.dead:
            return "die"
        if self.kill_rank < 0 or self.at_call < 0 or self.calls <= self.at_call:
            return None
        if self.mode == "die":
            self.dead = True
            return "die"
        if self.mode == "corrupt":
            return None if self.corrupted else "corrupt"
        if self.mode == "drop":
            self.dropping = True
            return "drop"
        return "delay"

    def on_call(self) -> bool:
        """Count one collective call; returns whether the rank is now dead
        (the PR-7 surface — transport modes never flip ``dead``)."""
        return self.fault_now() == "die"


def _comm_arg(entry: abi_spec.AbiEntry):
    for i, a in enumerate(entry.args):
        if a.kind == abi_spec.COMM:
            return i, a.name
    return None, None


class FaultyBackend(Backend):
    """Registry-composable fault-injection wrapper for abi-convention
    backends (paxi, minimal, ring).

    Shares the inner backend's handle tables (it IS the same context), and
    resolves the function table per entry:

    * REQUIRED queries delegate untouched (a dead rank still has metadata);
    * OPTIONAL collectives are wrapped with the kill-schedule tripwire;
    * FAULT entries are **rebound** onto this wrapper, so the inner
      backend's native ULFM hooks observe this wrapper's ``local_failed``
      failure detector instead of the base no-failures default.
    """

    convention = "abi"
    #: drops are injectable here — tells the ABI to compile the sentinel
    #: guard into plan/group wait closures (loss-incapable backends get
    #: the bare fast-path wait; see ``PaxABI._can_drop``)
    can_lose_messages = True

    def __init__(self, inner: Backend, schedule: Optional[FaultSchedule] = None,
                 *, declare_failures: bool = True) -> None:
        super().__init__(inner.mesh)
        self.inner = inner
        self.schedule = schedule if schedule is not None else FaultSchedule.from_env()
        # declare_failures=False turns the wrapper into a *silent* killer:
        # collectives still trip and heartbeats still go quiet, but
        # local_failed never names the corpse — only an observed detector
        # (an installed HeartbeatMonitor) can, which is how the battery
        # proves detection is real rather than declared
        self.declare_failures = declare_failures
        self.name = f"faulty:{inner.name}"
        # shared context tables — the wrapper adds failures, not a new world
        self.comms = inner.comms
        self.ops = inner.ops
        self.datatypes = inner.datatypes
        for entry in abi_spec.ABI_TABLE:
            if not inner.supports(entry):
                continue  # the ABI emulates it; recipes trip in the ground entries
            method = entry.backend_method
            if entry.tier == abi_spec.FAULT:
                # rebind the inner *class* function onto this wrapper: the
                # hook's `self.local_failed` / `self.comms` must be ours
                setattr(self, method,
                        getattr(type(inner), method).__get__(self))
            elif entry.tier == abi_spec.REQUIRED:
                setattr(self, method, getattr(inner, method))
            else:
                setattr(self, method, self._tripwire(entry, getattr(inner, method)))

    # -- capability negotiation: the wrapper is exactly as capable ---------
    def supports(self, entry: abi_spec.AbiEntry) -> bool:
        return self.inner.supports(entry)

    def capability(self, entry: abi_spec.AbiEntry) -> dict:
        info = self.inner.capability(entry)
        info["fault_injection"] = True
        return info

    def supports_persistent(self, entry: abi_spec.AbiEntry) -> bool:
        # no type-level plan hooks here: plans compile through the generic
        # argument-freezing path around the *wrapped* instance methods, so
        # a plan start() hits the tripwire exactly like a plain call
        return False

    def supports_persistent_group(self, entry: abi_spec.AbiEntry) -> bool:
        return False

    # -- handle domain ------------------------------------------------------
    def comm_axes(self, comm: Any):
        return self.inner.comm_axes(comm)

    def op_fn(self, op: Any) -> Callable:
        return self.inner.op_fn(op)

    def op_is_native(self, op: Any) -> bool:
        return self.inner.op_is_native(op)

    def wire_pad_multiple(self) -> int:
        return self.inner.wire_pad_multiple()

    # -- the failure detector ----------------------------------------------
    def local_failed(self, comm: Any) -> tuple:
        # a drop is NOT a declared death: a downed link surfaces only as
        # timeouts plus heartbeat silence, never through local knowledge
        if not self.declare_failures or not self.schedule.dead:
            return ()
        return self._faulty_member(comm)

    def heartbeat_silent(self, comm: Any) -> tuple:
        """A schedule-dead rank stops answering heartbeats too — and so does
        a *dropping* one (a partitioned link loses its beats with everything
        else): the wrapper is one producer of missed beats for the liveness
        monitor, whether or not it also *declares* the death through
        ``local_failed``."""
        if not (self.schedule.dead or self.schedule.dropping):
            return ()
        return self._faulty_member(comm)

    def _faulty_member(self, comm: Any) -> tuple:
        try:
            info = self.comms.info(comm, allow_revoked=True)
        except PaxError:
            return ()
        k = self.schedule.kill_rank
        if not info.axes or k in info.excludes or k >= info.full_size:
            return ()
        return (k,)

    # -- the tripwire -------------------------------------------------------
    def _tripwire(self, entry: abi_spec.AbiEntry, inner_fn: Callable) -> Callable:
        schedule = self.schedule
        comms = self.comms
        idx, cname = _comm_arg(entry)
        undroppable = entry.name in _UNDROPPABLE

        def wrapped(*args, **kwargs):
            for a in args:
                if a.__class__ is IncompleteValue:
                    return a  # an upstream drop: this leg never hits the wire
            fault = schedule.fault_now()
            if fault is not None:
                comm = (args[idx] if idx is not None and idx < len(args)
                        else kwargs.get(cname))
                # revoked comms raise PAX_ERR_REVOKED in the inner backend
                # (hot-path poisoning) — REVOKED outranks PROC_FAILED, ULFM
                if comm is not None and not comms.is_revoked(comm):
                    info = comms.info(comm)
                    k = schedule.kill_rank
                    if info.axes and k not in info.excludes and k < info.full_size:
                        if fault == "die":
                            raise PaxError(
                                PAX_ERR_PROC_FAILED,
                                f"rank {k} died (injected, call "
                                f"{schedule.calls}) on {info.name or 'comm'}",
                            )
                        if fault == "delay":
                            time.sleep(schedule.delay_s)
                        elif fault == "drop":
                            if undroppable:
                                raise PaxError(
                                    PAX_ERR_PROC_FAILED,
                                    f"message from rank {k} lost (injected "
                                    f"drop, call {schedule.calls}) on "
                                    f"{info.name or 'comm'}",
                                )
                            return IncompleteValue(
                                f"{entry.name} dropped at rank {k} (injected,"
                                f" call {schedule.calls}) on "
                                f"{info.name or 'comm'}")
                        elif fault == "corrupt":
                            out = inner_fn(*args, **kwargs)
                            schedule.corrupted = True
                            return _corrupt_member(
                                out, info.axes, k, schedule.calls)
            return inner_fn(*args, **kwargs)

        wrapped.__name__ = entry.backend_method
        wrapped.__qualname__ = f"faulty.{entry.backend_method}"
        return wrapped


class FaultyLib:
    """Fault injection for the *foreign* convention: wraps an ompix-style
    library, returning ``(OMPIX_ERR_PROC_FAILED, None)`` from collectives
    once the scheduled rank is dead — the ompix rc convention, so the
    failure crosses the Mukautuva layer through its generated wrappers and
    :class:`ErrorTranslator` exactly like a real implementation's rc would.

    The fault symbols themselves stay **absent** (``hasattr`` negotiation
    reports them missing, as for plain ompix), so the ABI's recipes supply
    revoke/agree/shrink while the rc path proves the translation story.
    Communicators created after the death are survivor comms (recovery
    re-registration) and are absolved from injection.
    """

    _COLLECTIVES = (
        "Allreduce", "Bcast", "Reduce_scatter", "Allgather", "Alltoall",
        "Alltoallv", "Alltoallw", "Scan", "Exscan", "Sendrecv", "Barrier",
        "Scatter",
    )

    can_lose_messages = True  # as FaultyBackend: drops are injectable

    def __init__(self, lib, schedule: Optional[FaultSchedule] = None,
                 *, declare_failures: bool = True) -> None:
        self._lib = lib
        self.schedule = schedule if schedule is not None else FaultSchedule.from_env()
        self.declare_failures = declare_failures
        self._absolved: set = set()  # comms registered post-mortem (identity)
        for sym in self._COLLECTIVES:
            if hasattr(lib, sym):
                setattr(self, sym, self._wrap(sym))

    def __getattr__(self, attr):
        return getattr(self._lib, attr)

    def Comm_from_axes(self, axes):
        code, comm = self._lib.Comm_from_axes(axes)
        if code == 0 and (self.schedule.dead or self.schedule.dropping):
            self._absolved.add(comm)
        return code, comm

    def local_failed(self, comm) -> tuple:
        """Failure detector surfaced to Mukautuva (ABI-domain comm handle;
        membership filtering happens in the shared ``comm_failure_view``).
        Drops stay silent here — only heartbeat attribution may name them."""
        if not self.declare_failures:
            return ()
        return (self.schedule.kill_rank,) if self.schedule.dead else ()

    def heartbeat_silent(self, comm) -> tuple:
        """Transport attribution for the liveness monitor (crosses the
        Mukautuva adapter's ``heartbeat_silent`` delegation): the scheduled
        corpse goes quiet whether or not it is declared dead — and so does
        a rank whose link the schedule is dropping."""
        sched = self.schedule
        return (sched.kill_rank,) if (sched.dead or sched.dropping) else ()

    #: per-symbol failure return, matching each symbol's rc convention
    #: (Barrier returns a bare rc, Sendrecv a (rc, value, status) triple)
    _FAIL_RC = {
        "Barrier": ox.OMPIX_ERR_PROC_FAILED,
        "Sendrecv": (ox.OMPIX_ERR_PROC_FAILED, None, None),
    }

    def _wrap(self, sym: str) -> Callable:
        inner = getattr(self._lib, sym)
        schedule = self.schedule
        absolved = self._absolved
        fail_rc = self._FAIL_RC.get(sym, (ox.OMPIX_ERR_PROC_FAILED, None))
        # a dropped payload crosses Mukautuva as a success rc whose value is
        # the sentinel (the generated WRAP_* passes values through untouched);
        # rc-only / status conventions cannot carry it and degrade to the
        # PROC_FAILED rc, which the heartbeat exchange absorbs as a miss
        undroppable = sym in ("Barrier", "Sendrecv")

        def wrapped(*args, **kwargs):
            for a in args:
                if a.__class__ is IncompleteValue:
                    return (0, a)  # upstream drop propagating through a chain
            fault = schedule.fault_now()
            if fault is not None:
                comm = next(
                    (a for a in args if isinstance(a, ox.OmpixComm)), None)
                if comm is not None and comm not in absolved and comm.axes:
                    if fault == "die":
                        return fail_rc
                    if fault == "delay":
                        time.sleep(schedule.delay_s)
                    elif fault == "drop":
                        if undroppable:
                            return fail_rc
                        return (0, IncompleteValue(
                            f"{sym} dropped at rank {schedule.kill_rank} "
                            f"(injected, call {schedule.calls})"))
                    elif fault == "corrupt":
                        ret = inner(*args, **kwargs)
                        if not isinstance(ret, tuple) or ret[0] != 0:
                            return ret
                        schedule.corrupted = True
                        value = _corrupt_member(
                            ret[1], comm.axes, schedule.kill_rank,
                            schedule.calls)
                        return (ret[0], value) + ret[2:]
            return inner(*args, **kwargs)

        wrapped.__name__ = sym
        wrapped.__qualname__ = f"FaultyLib.{sym}"
        return wrapped


def fault_schedule_of(backend) -> Optional[FaultSchedule]:
    """The kill schedule driving ``backend``, however it is wrapped:
    a :class:`FaultyBackend` directly, or a Mukautuva adapter over a
    :class:`FaultyLib`.  ``None`` when no injection layer is present."""
    sched = getattr(backend, "schedule", None)
    if isinstance(sched, FaultSchedule):
        return sched
    lib = getattr(backend, "lib", None)
    sched = getattr(lib, "schedule", None)
    return sched if isinstance(sched, FaultSchedule) else None
