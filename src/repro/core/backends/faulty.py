"""Fault injection below the ABI: a backend wrapper that kills a rank.

The fault tier is only testable if something can actually fail, and a
single-controller JAX stack has no ranks to ``kill -9``.  This module is the
deterministic stand-in: a :class:`FaultyBackend` wraps any paxi-convention
backend (and :class:`FaultyLib` wraps a foreign ompix-convention library),
counts collective calls, and at a configured call count declares a
configured rank dead.  From that point every collective on a communicator
that still *contains* the dead rank raises ``PAX_ERR_PROC_FAILED`` — until
the caller walks the ULFM sequence (revoke → ack → agree → shrink) and
continues on a survivor communicator, which excludes the corpse and is
therefore absolved.

Placement matters: the wrapper sits **below the ABI**, like a tool sits
above it.  Negotiation resolves the function table against the wrapper, so
the injected failures surface through exactly the dispatch path real
failures would take — native entries trip in the wrapped method, emulated
recipes trip in their grounded primitives, Mukautuva translates the foreign
``OMPIX_ERR_PROC_FAILED`` rc through its :class:`ErrorTranslator`.

Deliberately NOT registered in the backend registry's factory table: the
battery's backend sweep must never meet a booby-trapped backend by accident.
Selection is by the explicit ``faulty:<inner>`` prefix
(:func:`repro.core.registry.get_backend`) or by constructing the wrapper
directly; the kill schedule comes from ``PAX_FAULT_SCHEDULE`` (deterministic
CI chaos — ``"rank=5,at=12"``) or from :meth:`FaultSchedule.arm`.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Optional

from .. import abi_spec
from ..errors import PAX_ERR_PROC_FAILED, PaxError
from . import ompix as ox
from .base import Backend

ENV_VAR = "PAX_FAULT_SCHEDULE"


@dataclasses.dataclass
class FaultSchedule:
    """When which rank dies, plus the call counter that decides it.

    ``kill_rank`` is a linearized world rank; ``at_call`` is the collective
    call count after which the rank is dead (-1 disarms).  The same schedule
    object is shared by every wrapper layer of one backend, so the counter
    is global per context — deterministic for a fixed call sequence.
    """

    kill_rank: int = -1
    at_call: int = -1
    calls: int = 0
    dead: bool = False

    @classmethod
    def from_env(cls, text: Optional[str] = None) -> "FaultSchedule":
        """Parse ``"rank=R,at=N"`` (the CI chaos knob); empty → disarmed."""
        if text is None:
            text = os.environ.get(ENV_VAR, "")
        sched = cls()
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            key, _, val = part.partition("=")
            key = key.strip()
            if key == "rank":
                sched.kill_rank = int(val)
            elif key == "at":
                sched.at_call = int(val)
            else:
                raise ValueError(f"bad {ENV_VAR} field {part!r} "
                                 "(expected rank=R,at=N)")
        return sched

    @property
    def armed(self) -> bool:
        return self.kill_rank >= 0 and (self.at_call >= 0 or self.dead)

    def arm(self, kill_rank: int, after: int = 0) -> None:
        """Kill ``kill_rank`` after ``after`` more collective calls."""
        self.kill_rank = kill_rank
        self.at_call = self.calls + after

    def on_call(self) -> bool:
        """Count one collective call; returns whether the rank is now dead."""
        self.calls += 1
        if (not self.dead and self.kill_rank >= 0 and self.at_call >= 0
                and self.calls > self.at_call):
            self.dead = True
        return self.dead


def _comm_arg(entry: abi_spec.AbiEntry):
    for i, a in enumerate(entry.args):
        if a.kind == abi_spec.COMM:
            return i, a.name
    return None, None


class FaultyBackend(Backend):
    """Registry-composable fault-injection wrapper for abi-convention
    backends (paxi, minimal, ring).

    Shares the inner backend's handle tables (it IS the same context), and
    resolves the function table per entry:

    * REQUIRED queries delegate untouched (a dead rank still has metadata);
    * OPTIONAL collectives are wrapped with the kill-schedule tripwire;
    * FAULT entries are **rebound** onto this wrapper, so the inner
      backend's native ULFM hooks observe this wrapper's ``local_failed``
      failure detector instead of the base no-failures default.
    """

    convention = "abi"

    def __init__(self, inner: Backend, schedule: Optional[FaultSchedule] = None,
                 *, declare_failures: bool = True) -> None:
        super().__init__(inner.mesh)
        self.inner = inner
        self.schedule = schedule if schedule is not None else FaultSchedule.from_env()
        # declare_failures=False turns the wrapper into a *silent* killer:
        # collectives still trip and heartbeats still go quiet, but
        # local_failed never names the corpse — only an observed detector
        # (an installed HeartbeatMonitor) can, which is how the battery
        # proves detection is real rather than declared
        self.declare_failures = declare_failures
        self.name = f"faulty:{inner.name}"
        # shared context tables — the wrapper adds failures, not a new world
        self.comms = inner.comms
        self.ops = inner.ops
        self.datatypes = inner.datatypes
        for entry in abi_spec.ABI_TABLE:
            if not inner.supports(entry):
                continue  # the ABI emulates it; recipes trip in the ground entries
            method = entry.backend_method
            if entry.tier == abi_spec.FAULT:
                # rebind the inner *class* function onto this wrapper: the
                # hook's `self.local_failed` / `self.comms` must be ours
                setattr(self, method,
                        getattr(type(inner), method).__get__(self))
            elif entry.tier == abi_spec.REQUIRED:
                setattr(self, method, getattr(inner, method))
            else:
                setattr(self, method, self._tripwire(entry, getattr(inner, method)))

    # -- capability negotiation: the wrapper is exactly as capable ---------
    def supports(self, entry: abi_spec.AbiEntry) -> bool:
        return self.inner.supports(entry)

    def capability(self, entry: abi_spec.AbiEntry) -> dict:
        info = self.inner.capability(entry)
        info["fault_injection"] = True
        return info

    def supports_persistent(self, entry: abi_spec.AbiEntry) -> bool:
        # no type-level plan hooks here: plans compile through the generic
        # argument-freezing path around the *wrapped* instance methods, so
        # a plan start() hits the tripwire exactly like a plain call
        return False

    def supports_persistent_group(self, entry: abi_spec.AbiEntry) -> bool:
        return False

    # -- handle domain ------------------------------------------------------
    def comm_axes(self, comm: Any):
        return self.inner.comm_axes(comm)

    def op_fn(self, op: Any) -> Callable:
        return self.inner.op_fn(op)

    def op_is_native(self, op: Any) -> bool:
        return self.inner.op_is_native(op)

    def wire_pad_multiple(self) -> int:
        return self.inner.wire_pad_multiple()

    # -- the failure detector ----------------------------------------------
    def local_failed(self, comm: Any) -> tuple:
        if not self.declare_failures:
            return ()
        return self._dead_member(comm)

    def heartbeat_silent(self, comm: Any) -> tuple:
        """A schedule-dead rank stops answering heartbeats too: the wrapper
        is one producer of missed beats for the liveness monitor, whether
        or not it also *declares* the death through ``local_failed``."""
        return self._dead_member(comm)

    def _dead_member(self, comm: Any) -> tuple:
        if not self.schedule.dead:
            return ()
        try:
            info = self.comms.info(comm, allow_revoked=True)
        except PaxError:
            return ()
        k = self.schedule.kill_rank
        if not info.axes or k in info.excludes or k >= info.full_size:
            return ()
        return (k,)

    # -- the tripwire -------------------------------------------------------
    def _tripwire(self, entry: abi_spec.AbiEntry, inner_fn: Callable) -> Callable:
        schedule = self.schedule
        comms = self.comms
        idx, cname = _comm_arg(entry)

        def wrapped(*args, **kwargs):
            if schedule.on_call():
                comm = (args[idx] if idx is not None and idx < len(args)
                        else kwargs.get(cname))
                # revoked comms raise PAX_ERR_REVOKED in the inner backend
                # (hot-path poisoning) — REVOKED outranks PROC_FAILED, ULFM
                if comm is not None and not comms.is_revoked(comm):
                    info = comms.info(comm)
                    k = schedule.kill_rank
                    if info.axes and k not in info.excludes and k < info.full_size:
                        raise PaxError(
                            PAX_ERR_PROC_FAILED,
                            f"rank {k} died (injected, call "
                            f"{schedule.calls}) on {info.name or 'comm'}",
                        )
            return inner_fn(*args, **kwargs)

        wrapped.__name__ = entry.backend_method
        wrapped.__qualname__ = f"faulty.{entry.backend_method}"
        return wrapped


class FaultyLib:
    """Fault injection for the *foreign* convention: wraps an ompix-style
    library, returning ``(OMPIX_ERR_PROC_FAILED, None)`` from collectives
    once the scheduled rank is dead — the ompix rc convention, so the
    failure crosses the Mukautuva layer through its generated wrappers and
    :class:`ErrorTranslator` exactly like a real implementation's rc would.

    The fault symbols themselves stay **absent** (``hasattr`` negotiation
    reports them missing, as for plain ompix), so the ABI's recipes supply
    revoke/agree/shrink while the rc path proves the translation story.
    Communicators created after the death are survivor comms (recovery
    re-registration) and are absolved from injection.
    """

    _COLLECTIVES = (
        "Allreduce", "Bcast", "Reduce_scatter", "Allgather", "Alltoall",
        "Alltoallv", "Alltoallw", "Scan", "Exscan", "Sendrecv", "Barrier",
        "Scatter",
    )

    def __init__(self, lib, schedule: Optional[FaultSchedule] = None,
                 *, declare_failures: bool = True) -> None:
        self._lib = lib
        self.schedule = schedule if schedule is not None else FaultSchedule.from_env()
        self.declare_failures = declare_failures
        self._absolved: set = set()  # comms registered post-mortem (identity)
        for sym in self._COLLECTIVES:
            if hasattr(lib, sym):
                setattr(self, sym, self._wrap(sym))

    def __getattr__(self, attr):
        return getattr(self._lib, attr)

    def Comm_from_axes(self, axes):
        code, comm = self._lib.Comm_from_axes(axes)
        if code == 0 and self.schedule.dead:
            self._absolved.add(comm)
        return code, comm

    def local_failed(self, comm) -> tuple:
        """Failure detector surfaced to Mukautuva (ABI-domain comm handle;
        membership filtering happens in the shared ``comm_failure_view``)."""
        if not self.declare_failures:
            return ()
        return (self.schedule.kill_rank,) if self.schedule.dead else ()

    def heartbeat_silent(self, comm) -> tuple:
        """Transport attribution for the liveness monitor (crosses the
        Mukautuva adapter's ``heartbeat_silent`` delegation): the scheduled
        corpse goes quiet whether or not it is declared dead."""
        return (self.schedule.kill_rank,) if self.schedule.dead else ()

    #: per-symbol failure return, matching each symbol's rc convention
    #: (Barrier returns a bare rc, Sendrecv a (rc, value, status) triple)
    _FAIL_RC = {
        "Barrier": ox.OMPIX_ERR_PROC_FAILED,
        "Sendrecv": (ox.OMPIX_ERR_PROC_FAILED, None, None),
    }

    def _wrap(self, sym: str) -> Callable:
        inner = getattr(self._lib, sym)
        schedule = self.schedule
        absolved = self._absolved
        fail_rc = self._FAIL_RC.get(sym, (ox.OMPIX_ERR_PROC_FAILED, None))

        def wrapped(*args, **kwargs):
            if schedule.on_call():
                comm = next(
                    (a for a in args if isinstance(a, ox.OmpixComm)), None)
                if comm is not None and comm not in absolved and comm.axes:
                    return fail_rc
            return inner(*args, **kwargs)

        wrapped.__name__ = sym
        wrapped.__qualname__ = f"FaultyLib.{sym}"
        return wrapped


def fault_schedule_of(backend) -> Optional[FaultSchedule]:
    """The kill schedule driving ``backend``, however it is wrapped:
    a :class:`FaultyBackend` directly, or a Mukautuva adapter over a
    :class:`FaultyLib`.  ``None`` when no injection layer is present."""
    sched = getattr(backend, "schedule", None)
    if isinstance(sched, FaultSchedule):
        return sched
    lib = getattr(backend, "lib", None)
    sched = getattr(lib, "schedule", None)
    return sched if isinstance(sched, FaultSchedule) else None
