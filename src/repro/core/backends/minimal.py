"""minimal — a deliberately-partial native backend: the emulation stress test.

The paper's ecosystem bet is that one standard function table can front many
*unequal* implementations.  This backend is the most unequal one we can
admit: it exports only the REQUIRED handle queries plus the three primitives
every recipe chain grounds out in —

* ``sendrecv``       (point-to-point permutation),
* ``reduce_scatter`` (the reduction primitive),
* ``allgather``      (the collection primitive).

Everything else — allreduce, bcast, barrier, reduce, scan, exscan, alltoall,
alltoallv, alltoallw, gather, scatter, and every ``i*`` twin — is
synthesized at ``pax_init`` by tiered negotiation from the spec's emulation
recipes, including the deepest chain in the table
(``scatter -> bcast -> allreduce -> reduce_scatter + allgather``).  The
multidev battery runs this backend through the same oracle checks as the
full implementations, which is the end-to-end proof that partial backends
are first-class citizens of the ABI.

Implementation-wise the exported entries reuse the paxi lowering (this is a
*native-convention* backend: ABI handles are its handles); the partial
surface is declared with ``ABI_SUBSET``, the tier-aware capability gate in
:class:`repro.core.backends.base.Backend`.

Persistent plans compose the same way: the native ``reduce_scatter`` /
``allgather`` entries inherit paxi's plan hooks, and every emulated entry's
plan is precomposed from them by the recipe plan builders — so a
``<name>_init`` plan on this backend starts with the same bare-closure cost
as on a full implementation (the ``persistent_emulated_native_ratio`` CI
gate measures exactly this).

Plan groups (MPI ``Startall``, PR 5) stack the same way one level up: the
native rs/ag entries inherit paxi's ``plan_group_*`` stacking hooks, and an
emulated ``allreduce`` group fuses per stage through the recipe's group
builder — every member's reduce-scatter leg (one stacked collective via the
inherited hook) before any all-gather leg.  ``capabilities()`` reports
``plan_group: recipe-stage`` for the emulated entries and ``backend-hook``
for the native primitives.
"""
from __future__ import annotations

from .paxi import PaxiBackend


class MinimalBackend(PaxiBackend):
    """Native backend exporting only the recipe-ground primitives."""

    name = "minimal"

    ABI_SUBSET = frozenset({
        # REQUIRED tier: handle queries
        "comm_size", "comm_rank", "type_size",
        # the primitives recipes ground out in
        "sendrecv", "reduce_scatter", "allgather",
    })
