"""paxi — the native implementation of the PAX ABI.

The analogue of MPICH built with ``--enable-mpi-abi`` (paper §6.3): its
internal handles ARE the standard ABI handles, so the "conversions" are the
identity and the ABI adds **zero** overhead over raw ``jax.lax`` collectives.
``tests/test_abi_hlo_identity.py`` proves the Table-1 claim structurally:
the optimized HLO of a step traced through the ABI equals the HLO of the
same step written directly against ``jax.lax``.

Handle metadata queries use the bit-encoded fast path
(``handles.datatype_encoded_size``), i.e. the MPICH-heritage design of §3.3.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax

import jax.numpy as jnp

from .. import handles as H
from ..communicator import CommTable
from ..datatypes import DatatypeRegistry
from ..emulation import agree_value, comm_failure_view
from ..ops import NATIVE_COLLECTIVE_OPS, OpRegistry
from . import _lax
from .base import Backend


def uniform_payload(bounds, min_ndim: int = 0):
    """The stackability test shared by the plan-group hooks: every member's
    bound payload (first argument) must be a single array signature of the
    same shape and dtype with at least ``min_ndim`` dims.  Returns
    (shape, dtype) or ``None`` (pytree payloads / mixed geometry — the
    hook declines and the group falls back to per-member runs)."""
    x0 = bounds[0][0]
    if not (hasattr(x0, "shape") and hasattr(x0, "dtype")):
        return None
    shape, dtype = tuple(x0.shape), x0.dtype
    if len(shape) < min_ndim:
        return None
    for b in bounds[1:]:
        x = b[0]
        if (not hasattr(x, "shape") or tuple(x.shape) != shape
                or getattr(x, "dtype", None) != dtype):
            return None
    return shape, dtype


class PaxiBackend(Backend):
    convention = "abi"
    name = "paxi"

    def __init__(
        self,
        mesh: Optional[jax.sharding.Mesh] = None,
        *,
        comms: Optional[CommTable] = None,
        ops: Optional[OpRegistry] = None,
        datatypes: Optional[DatatypeRegistry] = None,
    ) -> None:
        super().__init__(mesh)
        # Native backend shares the ABI-context tables (it IS the ABI).
        self.comms = comms if comms is not None else CommTable(mesh)
        self.ops = ops if ops is not None else OpRegistry()
        self.datatypes = datatypes if datatypes is not None else DatatypeRegistry()

    # -- handle domain ------------------------------------------------------
    def comm_axes(self, comm: int) -> tuple[str, ...]:
        # hot path: the registration-time flat map; miss -> the checked
        # metadata query, which raises the proper PAX_ERR_COMM
        axes = self.comms.axes_by_handle.get(comm)
        return axes if axes is not None else self.comms.info(comm).axes

    def op_fn(self, op: int) -> Callable:
        return self.ops.fn(op)

    def op_is_native(self, op: int) -> bool:
        return op in NATIVE_COLLECTIVE_OPS

    # -- queries --------------------------------------------------------
    def size(self, comm: int) -> int:
        return self.comms.info(comm).size

    def rank(self, comm: int):
        return _lax.rank(self.comm_axes(comm))

    def type_size(self, datatype: int) -> int:
        return self.datatypes.type_size_encoded(datatype)

    # -- collectives ------------------------------------------------------
    def allreduce(self, x, op: int, comm: int):
        # heaviest-traffic entry point: comm_axes inlined (one dict index),
        # group-of-one identity returned without touching the lax layer
        axes = self.comms.axes_by_handle.get(comm)
        if axes is None:
            axes = self.comms.info(comm).axes
        if op == H.PAX_SUM:
            return x if not axes else _lax.psum(x, axes)
        if op == H.PAX_MAX:
            return _lax.pmax(x, axes)
        if op == H.PAX_MIN:
            return _lax.pmin(x, axes)
        return _lax.allreduce_generic(x, self.op_fn(op), axes)

    def reduce(self, x, op: int, root: int, comm: int):
        # SPMD: result computed everywhere; defined at root per MPI contract.
        return self.allreduce(x, op, comm)

    def bcast(self, x, root: int, comm: int):
        return _lax.bcast(x, root, self.comm_axes(comm))

    def reduce_scatter(self, x, op: int, comm: int, axis: int = 0):
        axes = self.comm_axes(comm)
        if op == H.PAX_SUM:
            return _lax.reduce_scatter_sum(x, axes, axis=axis)
        return _lax.reduce_scatter_generic(x, self.op_fn(op), axes, axis=axis)

    def allgather(self, x, comm: int, axis: int = 0):
        return _lax.allgather(x, self.comm_axes(comm), axis=axis)

    def alltoall(self, x, comm: int, split_axis: int = 0, concat_axis: int = 0):
        return _lax.alltoall(x, self.comm_axes(comm), split_axis, concat_axis)

    def sendrecv(self, x, perm: Sequence[tuple[int, int]], comm: int):
        return _lax.ppermute(x, self.comm_axes(comm), perm)

    def barrier(self, comm: int):
        return _lax.barrier(self.comm_axes(comm))

    def scatter(self, x, root: int, comm: int, axis: int = 0):
        return _lax.scatter_from_root(x, root, self.comm_axes(comm), axis=axis)

    def gather(self, x, root: int, comm: int, axis: int = 0):
        # SPMD gather == allgather (result defined on root, replicated
        # elsewhere per the MPI contract).
        return _lax.allgather(x, self.comm_axes(comm), axis=axis)

    def scan(self, x, op: int, comm: int):
        return _lax.scan_fold(x, self.op_fn(op), self.comm_axes(comm), inclusive=True)

    def exscan(self, x, op: int, comm: int):
        return _lax.scan_fold(x, self.op_fn(op), self.comm_axes(comm), inclusive=False)

    def alltoallv(self, x, sendcounts: Sequence[int], recvcounts: Sequence[int], comm: int):
        return _lax.alltoallv(x, sendcounts, recvcounts, self.comm_axes(comm))

    def alltoallw(self, blocks, sendtypes, recvtypes, comm: int):
        """Native path: handle vectors need no conversion (they ARE the ABI);
        per-peer recv-type casts are applied directly."""
        out = _lax.alltoall(blocks, self.comm_axes(comm), 0, 0)
        return [
            out[i].astype(self.datatypes.to_numpy_dtype(recvtypes[i]))
            for i in range(out.shape[0])
        ]

    # -- fault tier (ULFM analogues, native hooks) --------------------------
    # paxi IS the ABI, so the native hooks act directly on the shared
    # CommTable; the failure detector is `local_failed` (the base default
    # reports nothing — a FaultyBackend wrapper reports the killed rank).
    # The agree/shrink semantics are the shared single-controller kernels
    # from core.emulation, so native and recipe-emulated backends cannot
    # diverge on the agreement value.
    def comm_revoke(self, comm: int):
        self.comms.revoke(comm)
        return None

    def comm_failure_ack(self, comm: int):
        _, failed, acked = comm_failure_view(self.comms, self.local_failed, comm)
        self.comms.acked[comm] = acked | failed
        return None

    def comm_get_failed(self, comm: int) -> tuple[int, ...]:
        _, failed, _ = comm_failure_view(self.comms, self.local_failed, comm)
        return tuple(sorted(failed))

    def comm_agree(self, flag, comm: int):
        return agree_value(self.comms, self.local_failed, flag, comm)

    def comm_shrink(self, comm: int) -> int:
        # implicit ack + agreement on the failure-set bitmask, then dense
        # survivor registration (see build_comm_shrink for the recipe twin)
        info, failed, acked = comm_failure_view(self.comms, self.local_failed, comm)
        self.comms.acked[comm] = acked | failed
        mask = 0
        for r in failed:
            mask |= 1 << r
        agreed = self.comm_agree(mask, comm)
        excludes = [r for r in range(info.full_size) if (agreed >> r) & 1]
        return self.comms.register_shrunk(comm, excludes)

    # -- persistent plans (MPI-4 <name>_init) ------------------------------
    # Native plan hooks for the heavy-traffic entries: the comm→axes lookup
    # and the op branch are taken once at plan time, so a plan start() goes
    # straight to the frozen _lax lowering — no dict index, no compares.
    # Entries without a hook get the ABI layer's generic argument freezing.
    def plan_allreduce(self, x, op: int, comm: int):
        axes = self.comm_axes(comm)
        if op == H.PAX_SUM:
            if not axes:
                return lambda x: x  # group-of-one identity, frozen
            return lambda x: _lax.psum(x, axes)
        if op == H.PAX_MAX:
            return lambda x: _lax.pmax(x, axes)
        if op == H.PAX_MIN:
            return lambda x: _lax.pmin(x, axes)
        fn = self.op_fn(op)
        return lambda x: _lax.allreduce_generic(x, fn, axes)

    def plan_reduce_scatter(self, x, op: int, comm: int, axis: int = 0):
        axes = self.comm_axes(comm)
        if op == H.PAX_SUM:
            return lambda x: _lax.reduce_scatter_sum(x, axes, axis=axis)
        fn = self.op_fn(op)
        return lambda x: _lax.reduce_scatter_generic(x, fn, axes, axis=axis)

    def plan_allgather(self, x, comm: int, axis: int = 0):
        axes = self.comm_axes(comm)
        return lambda x: _lax.allgather(x, axes, axis=axis)

    def plan_bcast(self, x, root: int, comm: int):
        axes = self.comm_axes(comm)
        return lambda x: _lax.bcast(x, root, axes)

    # -- plan-group hooks (MPI Startall): stack same-comm, same-op members
    # into ONE collective.  Members are stacked on a fresh leading axis and
    # the collective runs over axis 1 (reduce_scatter/allgather) or
    # elementwise (allreduce/bcast), so N member plans cost one XLA
    # collective instead of N.  Mixed shapes/pytrees decline (None) and the
    # ABI layer falls back to per-member plan runs.
    def plan_group_allreduce(self, bounds):
        _, op, comm = bounds[0]
        u = uniform_payload(bounds)
        if u is None:
            return None
        axes = self.comm_axes(comm)
        n = len(bounds)
        if not axes:
            return lambda xs: list(xs)  # group-of-one identity, frozen
        if op == H.PAX_SUM:
            red = lambda s: _lax.psum(s, axes)
        elif op == H.PAX_MAX:
            red = lambda s: _lax.pmax(s, axes)
        elif op == H.PAX_MIN:
            red = lambda s: _lax.pmin(s, axes)
        else:
            return None  # generic-op fold: per-member fallback

        def run(xs):
            out = red(jnp.stack(xs))
            return [out[i] for i in range(n)]

        return run

    def plan_group_reduce_scatter(self, bounds):
        _, op, comm, axis = bounds[0]
        u = uniform_payload(bounds, min_ndim=1)
        if u is None or axis != 0 or op != H.PAX_SUM:
            return None
        axes = self.comm_axes(comm)
        n = len(bounds)
        if not axes:
            return lambda xs: list(xs)
        if u[0][0] % self.comms.info(comm).size:
            return None

        def run(xs):
            out = _lax.reduce_scatter_sum(jnp.stack(xs), axes, axis=1)
            return [out[i] for i in range(n)]

        return run

    def plan_group_allgather(self, bounds):
        _, comm, axis = bounds[0]
        u = uniform_payload(bounds, min_ndim=1)
        if u is None or axis != 0:
            return None
        axes = self.comm_axes(comm)
        n = len(bounds)
        if not axes:
            return lambda xs: list(xs)

        def run(xs):
            out = _lax.allgather(jnp.stack(xs), axes, axis=1)
            return [out[i] for i in range(n)]

        return run

    def plan_group_bcast(self, bounds):
        _, root, comm = bounds[0]
        u = uniform_payload(bounds)
        if u is None:
            return None
        axes = self.comm_axes(comm)
        n = len(bounds)
        if not axes:
            return lambda xs: list(xs)

        def run(xs):
            out = _lax.bcast(jnp.stack(xs), root, axes)
            return [out[i] for i in range(n)]

        return run
