"""The implementation-side (IMPL) interface every backend provides.

This is what the paper calls "the implementation": the paxi backend speaks
the ABI handle convention natively; foreign backends (ompix) speak their own
convention and are adapted by :mod:`repro.core.mukautuva`.

The methods take *backend-domain* handles.  For paxi those ARE the ABI ints;
for ompix they are its own objects.  The ABI layer never calls a foreign
backend directly.

The per-entry-point surface is **generated from the declarative function
table** (:mod:`repro.core.abi_spec`): every entry gets an
unsupported-operation placeholder here, and backends override the entries
they implement.  :meth:`Backend.supports` reports exactly which entries are
overridden — the capability answer ``PaxABI.__init__`` negotiates against
(the ``dlsym`` analogue).  Negotiation is *tiered*: a backend missing a
REQUIRED entry fails at init with ``PAX_ERR_UNSUPPORTED_OPERATION``, while
missing OPTIONAL entries are emulated from their spec recipes (or deferred
to a call-time error when no recipe chain grounds out) — partial backends
are first-class.  A deliberately-partial backend declares its surface with
``ABI_SUBSET`` (only these entries count as native) or ``ABI_DROPPED``
(everything overridden except these), and :meth:`Backend.capability` is the
per-entry report the ABI layer folds into ``PaxABI.capabilities()``.
"""
from __future__ import annotations

import abc
from typing import Any, Callable, Optional

import jax

from ..abi_spec import ABI_TABLE, AbiEntry
from ..errors import PAX_ERR_UNSUPPORTED_OPERATION, PaxError

_ENTRY_NAMES = frozenset(e.name for e in ABI_TABLE)


class Backend(abc.ABC):
    """Abstract collective backend."""

    #: "abi" if the backend's handle convention IS the standard ABI
    #: (no translation layer needed), "foreign" otherwise.
    convention: str = "abi"
    name: str = "base"

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None) -> None:
        self.mesh = mesh
        # A typo in a declared partial surface must fail loudly here, not
        # degrade into call-time unsupported errors far from the mistake.
        for attr in ("ABI_SUBSET", "ABI_DROPPED"):
            names = getattr(self, attr) or frozenset()
            unknown = set(names) - _ENTRY_NAMES
            if unknown:
                raise ValueError(
                    f"{type(self).__name__}.{attr} names unknown function-"
                    f"table entries {sorted(unknown)}"
                )

    # -- handle domain ----------------------------------------------------
    @abc.abstractmethod
    def comm_axes(self, comm: Any) -> tuple[str, ...]:
        """Ordered mesh axes of a backend-domain communicator."""

    @abc.abstractmethod
    def op_fn(self, op: Any) -> Callable:
        """Binary reduction fn of a backend-domain op handle."""

    def op_is_native(self, op: Any) -> bool:
        return False

    # -- capability negotiation (the dlsym answer) -------------------------

    #: restrict the native surface to exactly these entry names (a
    #: deliberately-partial backend); None means "whatever is overridden"
    ABI_SUBSET: Optional[frozenset] = None
    #: entry names a subclass disclaims even though an implementation is
    #: inherited (e.g. ring dropping its hand-written derived allreduce so
    #: the spec recipe composes its native reduce-scatter/all-gather)
    ABI_DROPPED: frozenset = frozenset()

    def supports(self, entry: AbiEntry) -> bool:
        """Whether this backend natively implements a function-table entry.

        Tier-aware surface declaration: ``ABI_SUBSET``/``ABI_DROPPED`` gate
        the answer before the override check, so a backend can be partial on
        purpose and let negotiation emulate (optional tier) or reject
        (required tier) the rest.  Default otherwise: the entry's method was
        overridden somewhere below :class:`Backend` (the generated
        placeholders carry a marker).  Foreign adapters override this to ask
        their library instead.
        """
        if self.ABI_SUBSET is not None and entry.name not in self.ABI_SUBSET:
            return False
        if entry.name in self.ABI_DROPPED:
            return False
        impl = getattr(type(self), entry.backend_method, None)
        return impl is not None and not getattr(impl, "_pax_unsupported", False)

    def capability(self, entry: AbiEntry) -> dict:
        """This backend's view of one entry, folded into the per-context
        report ``PaxABI.capabilities()``.  Adapters (Mukautuva) override to
        translate the foreign library's symbol table across the layer.
        Persistent entries additionally report ``group_hook`` — whether the
        backend declares a native plan-group fusion for the entry."""
        info = {"backend": self.name, "native": self.supports(entry)}
        if entry.persistent:
            info["group_hook"] = self.supports_persistent_group(entry)
        return info

    # -- fault model (ULFM tier) -------------------------------------------
    def local_failed(self, comm: Any) -> tuple:
        """Ranks this backend knows to be dead on ``comm``.

        The failure-detector hook of the fault tier: the default backend
        never observes failures (an empty report keeps every fault entry a
        cheap no-op), while fault-injecting wrappers
        (:mod:`repro.core.backends.faulty`) report the killed rank here.
        Both the native paxi fault hooks and the emulation recipes read
        failures exclusively through this method.
        """
        return ()

    def heartbeat_silent(self, comm: Any) -> tuple:
        """Ranks whose transport stopped carrying heartbeats on ``comm``.

        The *attribution* hook of the liveness layer
        (:class:`repro.runtime.liveness.HeartbeatMonitor`): when a
        heartbeat exchange fails, the monitor asks the transport who went
        quiet.  Unlike :meth:`local_failed` this is an observation about
        traffic, not a declaration of death — the monitor still applies
        its miss-threshold/suspicion state machine before confirming.
        The default backend's wire never goes quiet; fault-injecting
        wrappers report the scheduled corpse here.
        """
        return ()

    def wire_pad_multiple(self) -> int:
        """Element-count multiple that keeps this backend's wire on its
        fastest path for padded payloads.  Emulation recipes that invent
        padding (the composed all-reduce) round up to this multiple so the
        padded reduce-scatter leg stays eligible for the backend's wire
        kernels; 1 means no preference (padding stays minimal)."""
        return 1

    # -- persistent plans (MPI-4 <name>_init) ------------------------------
    # A backend declares *native persistent support* for an entry by
    # defining ``plan_<backend_method>(self, <entry args>)`` returning a run
    # closure over the payload argument(s): everything derivable from the
    # non-payload arguments and the payload's shape/dtype (comm→axes, op
    # branch, schedule selection, foreign-handle conversion) must be frozen
    # in the closure.  The payload is bound abstractly (shape/dtype only) —
    # hooks must not read values.  Backends without a hook inherit the
    # generic plan compiler in the ABI layer (argument freezing around the
    # resolved entry), which already hoists all ABI-layer per-call work;
    # the hook additionally hoists the backend's own dispatch.  paxi and
    # ring declare hooks for the traffic-bearing entries; Mukautuva
    # generates hooks that cache foreign-handle conversion at plan time.

    def supports_persistent(self, entry: AbiEntry) -> bool:
        """Whether this backend declares a native plan hook for ``entry``."""
        return (self.supports(entry)
                and getattr(type(self), f"plan_{entry.backend_method}", None)
                is not None)

    # -- plan groups (MPI Startall) ----------------------------------------
    # A backend declares *native group fusion* for an entry by defining
    # ``plan_group_<backend_method>(self, bounds)`` where ``bounds`` is a
    # list of bound-argument tuples, one per group member, guaranteed by the
    # ABI layer to share every non-payload argument (same comm, same op,
    # same axis...).  The hook returns a run closure mapping the member
    # payload list to the member output list — typically ONE stacked
    # collective over the concatenated buffers — or ``None`` to decline
    # (e.g. mixed payload shapes), in which case the group falls back to
    # per-member plan runs.  Payloads are bound abstractly; hooks must not
    # read values.

    def supports_persistent_group(self, entry: AbiEntry) -> bool:
        """Whether this backend declares a native plan-group hook for
        ``entry`` (reported as ``group_hook`` in :meth:`capability`)."""
        return (self.supports(entry)
                and getattr(type(self),
                            f"plan_group_{entry.backend_method}", None)
                is not None)


def _make_placeholder(entry: AbiEntry):
    def placeholder(self, *args, **kwargs):
        raise PaxError(
            PAX_ERR_UNSUPPORTED_OPERATION,
            f"backend {self.name!r} does not implement {entry.name!r}",
        )

    placeholder.__name__ = entry.backend_method
    placeholder.__qualname__ = f"Backend.{entry.backend_method}"
    placeholder.__doc__ = (
        f"Function-table entry {entry.name!r}: not implemented by this backend."
    )
    placeholder._pax_unsupported = True
    return placeholder


# One placeholder per function-table row — the single source of what a
# backend *may* implement.  Collective semantics live in the subclasses.
for _entry in ABI_TABLE:
    if _entry.backend_method not in Backend.__dict__:
        setattr(Backend, _entry.backend_method, _make_placeholder(_entry))
del _entry
