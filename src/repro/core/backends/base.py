"""The implementation-side (IMPL) interface every backend provides.

This is what the paper calls "the implementation": the paxi backend speaks
the ABI handle convention natively; foreign backends (ompix) speak their own
convention and are adapted by :mod:`repro.core.mukautuva`.

The methods take *backend-domain* handles.  For paxi those ARE the ABI ints;
for ompix they are its own objects.  The ABI layer never calls a foreign
backend directly.
"""
from __future__ import annotations

import abc
from typing import Any, Callable, Optional, Sequence

import jax


class Backend(abc.ABC):
    """Abstract collective backend."""

    #: "abi" if the backend's handle convention IS the standard ABI
    #: (no translation layer needed), "foreign" otherwise.
    convention: str = "abi"
    name: str = "base"

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None) -> None:
        self.mesh = mesh

    # -- handle domain ----------------------------------------------------
    @abc.abstractmethod
    def comm_axes(self, comm: Any) -> tuple[str, ...]:
        """Ordered mesh axes of a backend-domain communicator."""

    @abc.abstractmethod
    def op_fn(self, op: Any) -> Callable:
        """Binary reduction fn of a backend-domain op handle."""

    def op_is_native(self, op: Any) -> bool:
        return False

    # -- queries -----------------------------------------------------------
    @abc.abstractmethod
    def size(self, comm: Any) -> int: ...

    @abc.abstractmethod
    def rank(self, comm: Any): ...

    @abc.abstractmethod
    def type_size(self, datatype: Any) -> int: ...

    # -- collectives (values are per-device jnp arrays inside shard_map) ---
    @abc.abstractmethod
    def allreduce(self, x, op: Any, comm: Any): ...

    @abc.abstractmethod
    def reduce(self, x, op: Any, root: int, comm: Any): ...

    @abc.abstractmethod
    def bcast(self, x, root: int, comm: Any): ...

    @abc.abstractmethod
    def reduce_scatter(self, x, op: Any, comm: Any, axis: int = 0): ...

    @abc.abstractmethod
    def allgather(self, x, comm: Any, axis: int = 0): ...

    @abc.abstractmethod
    def alltoall(self, x, comm: Any, split_axis: int = 0, concat_axis: int = 0): ...

    @abc.abstractmethod
    def sendrecv(self, x, perm: Sequence[tuple[int, int]], comm: Any): ...

    @abc.abstractmethod
    def barrier(self, comm: Any): ...

    @abc.abstractmethod
    def scatter(self, x, root: int, comm: Any, axis: int = 0): ...

    def gather(self, x, root: int, comm: Any, axis: int = 0):
        # SPMD gather == allgather (result defined on root, replicated
        # elsewhere); subclasses may specialize.
        return self.allgather(x, comm, axis=axis)

    def alltoallw(self, blocks, sendtypes, recvtypes, comm: Any):
        raise NotImplementedError(f"{self.name} does not implement alltoallw")
