"""Collective backends — the "MPI implementations" behind the PAX ABI.

* :mod:`paxi`  — native implementation of the standard ABI (MPICH-with-
  ``--enable-mpi-abi`` analogue): ABI handles are its internal handles,
  conversions are the identity, overhead is zero by construction.
* :mod:`ompix` — a *foreign-convention* implementation (Open-MPI analogue):
  object handles, its own predefined globals, its own error codes and status
  layout.  Only usable through the Mukautuva translation layer.
* :mod:`ring`  — algorithmic backend implementing collectives as explicit
  ``ppermute`` rings (reduce-scatter + all-gather), with an optional int8
  compressed wire format; used for collective-schedule experiments.
* :mod:`minimal` — deliberately-partial native backend (handle queries +
  sendrecv/reduce_scatter/allgather only); everything else is synthesized
  by tiered negotiation from the spec's emulation recipes.
"""
from . import paxi, ompix, ring, minimal  # noqa: F401
from .base import Backend  # noqa: F401
