"""ring — an algorithmic ABI-native backend: explicit ring collectives.

Same handle convention as :mod:`paxi` (it is a second *native* implementation
of the standard ABI — the ecosystem the paper wants: N interchangeable
implementations behind one ABI).  Collectives lower to explicit
``ppermute`` ring schedules instead of single XLA collective ops:

* ring reduce-scatter + ring all-gather == bandwidth-optimal all-reduce,
  with per-step traffic visible in the HLO (useful for the roofline tool
  and for overlap experiments — each hop is an independently schedulable
  ``collective-permute``);
* optional wire compression (``compress="bf16"|"int8"``): payload quantized
  per hop, accumulated in the original dtype.  int8 uses a per-hop absmax
  scale.  This is the gradient-compression substrate (train/compression.py
  adds error feedback on top).

Multi-axis communicators reduce hierarchically (axis by axis) — the classic
2D-torus schedule.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .. import compat

from .. import handles as H
from . import _lax
from .paxi import PaxiBackend


def _quantize(x, compress: Optional[str]):
    if compress is None:
        return x, None
    if compress == "bf16":
        return x.astype(jnp.bfloat16), None
    if compress == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return q, scale
    raise ValueError(f"unknown compression {compress!r}")


def _dequantize(q, scale, dtype, compress: Optional[str]):
    if compress is None:
        return q
    if compress == "bf16":
        return q.astype(dtype)
    return q.astype(dtype) * scale


def ring_reduce_scatter(x, axis_name: str, compress: Optional[str] = None):
    """Returns this rank's fully-reduced chunk (chunk index == rank).

    ``x`` must have leading dim divisible by the axis size. S-1 hops.
    """
    S = compat.axis_size(axis_name)
    if S == 1:
        return x
    i = lax.axis_index(axis_name)
    n = x.shape[0]
    assert n % S == 0, f"ring reduce_scatter needs {S} | {n}"
    c = n // S
    perm = [(s, (s + 1) % S) for s in range(S)]

    def chunk_at(idx):
        return lax.dynamic_slice_in_dim(x, idx * c, c, axis=0)

    travel = chunk_at((i - 1) % S)
    for t in range(S - 1):
        q, scale = _quantize(travel, compress)
        q = lax.ppermute(q, axis_name, perm)
        if scale is not None:
            scale = lax.ppermute(scale, axis_name, perm)
        received = _dequantize(q, scale, x.dtype, compress)
        travel = received + chunk_at((i - 2 - t) % S)
    return travel  # chunk index == own rank


def ring_allgather(x, axis_name: str):
    """Inverse of ring_reduce_scatter: collect every rank's chunk. S-1 hops."""
    S = compat.axis_size(axis_name)
    if S == 1:
        return x
    i = lax.axis_index(axis_name)
    c = x.shape[0]
    perm = [(s, (s + 1) % S) for s in range(S)]
    out = jnp.zeros((S * c,) + x.shape[1:], dtype=x.dtype)
    out = lax.dynamic_update_slice_in_dim(out, x, i * c, axis=0)
    travel = x
    for t in range(S - 1):
        travel = lax.ppermute(travel, axis_name, perm)
        src = (i - 1 - t) % S  # who produced the chunk we just received
        out = lax.dynamic_update_slice_in_dim(out, travel, src * c, axis=0)
    return out


def ring_scan_sum(x, axis_name: str, inclusive: bool = True):
    """SUM prefix over ranks via S-1 explicit hops: every hop forwards the
    neighbour's contribution one step; rank i accumulates the terms with
    source index < i (masked add).  Exclusive scan leaves rank 0's input
    unchanged — the ABI-wide exscan convention (MPI: undefined)."""
    S = compat.axis_size(axis_name)
    i = lax.axis_index(axis_name)
    if S == 1:
        return x
    perm = [(s, (s + 1) % S) for s in range(S)]
    acc = x if inclusive else jnp.where(i == 0, x, jnp.zeros_like(x))
    travel = x
    for t in range(S - 1):
        travel = lax.ppermute(travel, axis_name, perm)
        # after hop t, rank i holds rank (i-1-t)'s contribution
        acc = acc + jnp.where(i >= t + 1, travel, jnp.zeros_like(travel))
    return acc


class RingBackend(PaxiBackend):
    """ABI-native backend with explicit ring schedules for SUM collectives.

    Non-SUM ops and non-flattenable payloads fall back to the paxi lowering
    (an implementation is free to mix algorithms per op — MPI
    implementations do exactly this).

    ``allreduce`` is deliberately **not** exported (``ABI_DROPPED``): the
    hand-written RS+AG composition this backend used to carry is exactly the
    spec's emulation recipe, so tiered negotiation now composes the ring
    reduce-scatter and ring all-gather below — the backend shrank while its
    coverage (and the compressed wire) stayed.  Reduce-scatter and
    all-gather gained hierarchical multi-axis schedules (forward/reverse
    axis order, chunk index == linearized rank) so the composed all-reduce
    still runs the ring wire — compression included — on multi-axis
    communicators.
    """

    name = "ring"

    ABI_DROPPED = frozenset({"allreduce"})

    def __init__(self, *args, compress: Optional[str] = None, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.compress = compress

    def _axis_sizes(self, axes) -> list[int]:
        mesh = self.comms.mesh
        return [mesh.shape[a] if mesh else 1 for a in axes]

    def reduce_scatter(self, x, op: int, comm: int, axis: int = 0):
        axes = self.comm_axes(comm)
        if op != H.PAX_SUM or not axes or axis != 0:
            return super().reduce_scatter(x, op, comm, axis=axis)
        if x.shape[0] % math.prod(self._axis_sizes(axes)):
            return super().reduce_scatter(x, op, comm, axis=axis)
        for a in axes:  # forward axis order: chunk == linearized rank
            x = ring_reduce_scatter(x, a, self.compress)
        return x

    def allgather(self, x, comm: int, axis: int = 0):
        axes = self.comm_axes(comm)
        if not axes or axis != 0:
            return super().allgather(x, comm, axis=axis)
        for a in reversed(axes):  # reverse order: inverse of reduce_scatter
            x = ring_allgather(x, a)
        return x

    def scan(self, x, op: int, comm: int):
        axes = self.comm_axes(comm)
        if op != H.PAX_SUM or len(axes) != 1:
            return super().scan(x, op, comm)
        return ring_scan_sum(x, axes[0], inclusive=True)

    def exscan(self, x, op: int, comm: int):
        axes = self.comm_axes(comm)
        if op != H.PAX_SUM or len(axes) != 1:
            return super().exscan(x, op, comm)
        return ring_scan_sum(x, axes[0], inclusive=False)
