"""ring — an algorithmic ABI-native backend: explicit ring collectives.

Same handle convention as :mod:`paxi` (it is a second *native* implementation
of the standard ABI — the ecosystem the paper wants: N interchangeable
implementations behind one ABI).  Collectives lower to explicit
``ppermute`` ring schedules instead of single XLA collective ops:

* ring reduce-scatter + ring all-gather == bandwidth-optimal all-reduce,
  with per-step traffic visible in the HLO (useful for the roofline tool
  and for overlap experiments — each hop is an independently schedulable
  ``collective-permute``);
* optional wire compression (``compress="bf16"|"int8"``): payload quantized
  per hop, accumulated in the original dtype.  int8 uses a per-hop absmax
  scale.  This is the gradient-compression substrate (train/compression.py
  adds error feedback on top).  The compressed wire covers the SUM prefix
  scans too: ``ring_scan_sum`` quantizes each forwarded contribution, and
  multi-axis communicators use the hierarchical ``ring_scan_sum_multi``
  schedule (minor-axis scan + ``ring_allreduce_sum`` row totals + major-axis
  scan of the totals) instead of falling back to the generic fold.

Multi-axis communicators reduce hierarchically (axis by axis) — the classic
2D-torus schedule.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .. import compat

from .. import handles as H
from . import _lax
from .paxi import PaxiBackend, uniform_payload


def _quantize(x, compress: Optional[str]):
    if compress is None:
        return x, None
    if compress == "bf16":
        return x.astype(jnp.bfloat16), None
    if compress == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return q, scale
    raise ValueError(f"unknown compression {compress!r}")


def _dequantize(q, scale, dtype, compress: Optional[str]):
    if compress is None:
        return q
    if compress == "bf16":
        return q.astype(dtype)
    return q.astype(dtype) * scale


def ring_reduce_scatter(x, axis_name: str, compress: Optional[str] = None):
    """Returns this rank's fully-reduced chunk (chunk index == rank).

    ``x`` must have leading dim divisible by the axis size. S-1 hops.
    """
    S = compat.axis_size(axis_name)
    if S == 1:
        return x
    i = lax.axis_index(axis_name)
    n = x.shape[0]
    assert n % S == 0, f"ring reduce_scatter needs {S} | {n}"
    c = n // S
    perm = [(s, (s + 1) % S) for s in range(S)]

    def chunk_at(idx):
        return lax.dynamic_slice_in_dim(x, idx * c, c, axis=0)

    travel = chunk_at((i - 1) % S)
    for t in range(S - 1):
        q, scale = _quantize(travel, compress)
        q = lax.ppermute(q, axis_name, perm)
        if scale is not None:
            scale = lax.ppermute(scale, axis_name, perm)
        received = _dequantize(q, scale, x.dtype, compress)
        travel = received + chunk_at((i - 2 - t) % S)
    return travel  # chunk index == own rank


def ring_reduce_scatter_fused(x, axis_name: str, compress: str,
                              interpret: bool):
    """:func:`ring_reduce_scatter` on the fused Pallas wire
    (:mod:`repro.kernels.ring_wire`): the traveling block stays *quantized*
    between hops and each hop's dequantize + accumulate + re-quantize is one
    kernel pass — one read of the traveling block, one write of the outgoing
    block, instead of three materialized lax intermediates.  Same
    quantization-point sequence as the lax schedule (quantize at every send,
    plain dequant-accumulate after the last hop), so the bf16 wire is
    bitwise-identical; int8 upgrades the global absmax scale to per-block
    scales (strictly finer — bounded in the battery, section 12).

    Only called from plan closures: eligibility (compressed wire, f32,
    WIRE_BLOCK-divisible chunk, platform) is decided at plan time by
    ``RingBackend._wire_kernel_axes``.
    """
    from ...kernels.ring_wire import ops as wire_ops

    S = compat.axis_size(axis_name)
    if S == 1:
        return x
    i = lax.axis_index(axis_name)
    n = x.shape[0]
    assert n % S == 0, f"ring reduce_scatter needs {S} | {n}"
    c = n // S
    perm = [(s, (s + 1) % S) for s in range(S)]

    def chunk_at(idx):
        return lax.dynamic_slice_in_dim(x, idx * c, c, axis=0)

    q, scales = wire_ops.quant(chunk_at((i - 1) % S), compress,
                               interpret=interpret)
    for t in range(S - 1):
        q = lax.ppermute(q, axis_name, perm)
        if scales is not None:
            scales = lax.ppermute(scales, axis_name, perm)
        local = chunk_at((i - 2 - t) % S)
        if t < S - 2:
            q, scales = wire_ops.hop_add_quant(q, scales, local, compress,
                                               interpret=interpret)
        else:
            return wire_ops.hop_accum(q, scales, local, compress,
                                      interpret=interpret)


def ring_allgather(x, axis_name: str):
    """Inverse of ring_reduce_scatter: collect every rank's chunk. S-1 hops."""
    S = compat.axis_size(axis_name)
    if S == 1:
        return x
    i = lax.axis_index(axis_name)
    c = x.shape[0]
    perm = [(s, (s + 1) % S) for s in range(S)]
    out = jnp.zeros((S * c,) + x.shape[1:], dtype=x.dtype)
    out = lax.dynamic_update_slice_in_dim(out, x, i * c, axis=0)
    travel = x
    for t in range(S - 1):
        travel = lax.ppermute(travel, axis_name, perm)
        src = (i - 1 - t) % S  # who produced the chunk we just received
        out = lax.dynamic_update_slice_in_dim(out, travel, src * c, axis=0)
    return out


def ring_scan_sum(x, axis_name: str, inclusive: bool = True,
                  compress: Optional[str] = None):
    """SUM prefix over ranks via S-1 explicit hops: every hop forwards the
    neighbour's contribution one step; rank i accumulates the terms with
    source index < i (masked add).  Exclusive scan leaves rank 0's input
    unchanged — the ABI-wide exscan convention (MPI: undefined).

    With ``compress`` the traveling contribution is quantized per hop
    exactly like :func:`ring_reduce_scatter`'s wire; accumulation stays in
    the original dtype.  Error compounds with hop count (bounded in the
    multidev battery, section 6)."""
    S = compat.axis_size(axis_name)
    i = lax.axis_index(axis_name)
    if S == 1:
        return x
    perm = [(s, (s + 1) % S) for s in range(S)]
    acc = x if inclusive else jnp.where(i == 0, x, jnp.zeros_like(x))
    travel = x
    for t in range(S - 1):
        q, scale = _quantize(travel, compress)
        q = lax.ppermute(q, axis_name, perm)
        if scale is not None:
            scale = lax.ppermute(scale, axis_name, perm)
        travel = _dequantize(q, scale, x.dtype, compress)
        # after hop t, rank i holds rank (i-1-t)'s contribution
        acc = acc + jnp.where(i >= t + 1, travel, jnp.zeros_like(travel))
    return acc


def ring_allreduce_sum(x, axis_name: str, compress: Optional[str] = None):
    """Divisibility-free SUM all-reduce: S-1 broadcast-add hops (each rank's
    contribution travels the whole ring once).  Used by the hierarchical
    multi-axis scan for row totals, where the payload need not split into
    rank chunks.  Wire compressed per hop like the other ring schedules."""
    S = compat.axis_size(axis_name)
    if S == 1:
        return x
    perm = [(s, (s + 1) % S) for s in range(S)]
    acc = x
    travel = x
    for t in range(S - 1):
        q, scale = _quantize(travel, compress)
        q = lax.ppermute(q, axis_name, perm)
        if scale is not None:
            scale = lax.ppermute(scale, axis_name, perm)
        travel = _dequantize(q, scale, x.dtype, compress)
        acc = acc + travel
    return acc


def ring_scan_sum_multi(x, axes, inclusive: bool = True,
                        compress: Optional[str] = None):
    """Hierarchical SUM prefix over a multi-axis communicator, all on the
    ring wire (compression included): the prefix over linearized (row-major)
    rank splits as

        scan(x)[iA, iB]  =  scan_minor(x within row iA)
                          + sum of all full rows jA < iA,

    where the row totals ride :func:`ring_allreduce_sum` and the major-axis
    prefix is a :func:`ring_scan_sum` of the totals.  The exclusive variant
    keeps the ABI convention (linearized rank 0 returns its input)."""
    axes = tuple(axes)
    if len(axes) == 1:
        return ring_scan_sum(x, axes[0], inclusive, compress)
    tail = axes[1:]
    row_total = x
    for a in reversed(tail):
        row_total = ring_allreduce_sum(row_total, a, compress)
    # true-exclusive prefix of the row totals over the major axis
    major_excl = ring_scan_sum(row_total, axes[0], True, compress) - row_total
    inner_incl = ring_scan_sum_multi(x, tail, True, compress)
    if inclusive:
        return inner_incl + major_excl
    r = _lax.rank(axes)  # linearized rank 0 keeps its input (ABI convention)
    return jnp.where(r == 0, x, inner_incl - x + major_excl)


class RingBackend(PaxiBackend):
    """ABI-native backend with explicit ring schedules for SUM collectives.

    Non-SUM ops and non-flattenable payloads fall back to the paxi lowering
    (an implementation is free to mix algorithms per op — MPI
    implementations do exactly this).

    ``allreduce`` is deliberately **not** exported (``ABI_DROPPED``): the
    hand-written RS+AG composition this backend used to carry is exactly the
    spec's emulation recipe, so tiered negotiation now composes the ring
    reduce-scatter and ring all-gather below — the backend shrank while its
    coverage (and the compressed wire) stayed.  Reduce-scatter and
    all-gather gained hierarchical multi-axis schedules (forward/reverse
    axis order, chunk index == linearized rank) so the composed all-reduce
    still runs the ring wire — compression included — on multi-axis
    communicators.
    """

    name = "ring"

    ABI_DROPPED = frozenset({"allreduce"})

    def __init__(self, *args, compress: Optional[str] = None, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.compress = compress

    def _axis_sizes(self, axes) -> list[int]:
        mesh = self.comms.mesh
        return [mesh.shape[a] if mesh else 1 for a in axes]

    # -- fused-wire kernel selection (plan time only) -----------------------
    def _wire_kernel_mode(self) -> str:
        """``"pallas"`` iff the fused ring-wire kernels can carry this
        backend's compressed wire on the current platform (kernel registry
        answer); plain-ring and unknown platforms stay ``"lax"``."""
        if self.compress is None:
            return "lax"
        from ...kernels import kernel_mode
        return kernel_mode("ring_wire")

    def _wire_kernel_axes(self, shape, dtype, axes) -> list[bool]:
        """Per-axis fused-kernel eligibility for a reduce-scatter plan bound
        to ``shape``/``dtype``: the hop chunk along each axis (after the
        preceding axes' reductions shrank the leading dim) must satisfy
        :func:`repro.kernels.ring_wire.wire_eligible`.  Ineligible axes run
        the lax schedule — selection is per hop-loop, not all-or-nothing."""
        if self._wire_kernel_mode() != "pallas":
            return [False] * len(axes)
        from ...kernels.ring_wire import ops as wire_ops
        trailing = math.prod(shape[1:]) if len(shape) > 1 else 1
        rows = shape[0]
        flags = []
        for S in self._axis_sizes(axes):
            if S <= 1:
                flags.append(False)
            else:
                flags.append(wire_ops.wire_eligible(
                    ((rows // S) * trailing,), dtype, self.compress))
            rows //= max(S, 1)
        return flags

    def capability(self, entry):
        """Extend the per-entry report with the wire-kernel source: which
        implementation a plan bound to an eligible payload would run.  The
        fused kernels exist only for the reduce-scatter hop loop; every
        other wire-bearing entry (and plain ring) reports ``"lax"`` — the
        fallback the battery keeps exercised."""
        info = super().capability(entry)
        if entry.name in ("reduce_scatter", "allgather", "scan", "exscan"):
            info["wire_kernel"] = (self._wire_kernel_mode()
                                   if entry.name == "reduce_scatter"
                                   else "lax")
        return info

    def wire_pad_multiple(self) -> int:
        """Padding granule for emulation recipes: with the fused wire
        active, rounding invented padding up to WIRE_BLOCK keeps the
        composed all-reduce's reduce-scatter leg kernel-eligible."""
        if self._wire_kernel_mode() != "pallas":
            return 1
        from ...kernels.ring_wire import ops as wire_ops
        return wire_ops.WIRE_BLOCK

    def reduce_scatter(self, x, op: int, comm: int, axis: int = 0):
        axes = self.comm_axes(comm)
        if op != H.PAX_SUM or not axes or axis != 0:
            return super().reduce_scatter(x, op, comm, axis=axis)
        if x.shape[0] % math.prod(self._axis_sizes(axes)):
            return super().reduce_scatter(x, op, comm, axis=axis)
        for a in axes:  # forward axis order: chunk == linearized rank
            x = ring_reduce_scatter(x, a, self.compress)
        return x

    def allgather(self, x, comm: int, axis: int = 0):
        axes = self.comm_axes(comm)
        if not axes or axis != 0:
            return super().allgather(x, comm, axis=axis)
        for a in reversed(axes):  # reverse order: inverse of reduce_scatter
            x = ring_allgather(x, a)
        return x

    def scan(self, x, op: int, comm: int):
        axes = self.comm_axes(comm)
        if op != H.PAX_SUM or not axes:
            return super().scan(x, op, comm)
        return ring_scan_sum_multi(x, axes, inclusive=True,
                                   compress=self.compress)

    def exscan(self, x, op: int, comm: int):
        axes = self.comm_axes(comm)
        if op != H.PAX_SUM or not axes:
            return super().exscan(x, op, comm)
        return ring_scan_sum_multi(x, axes, inclusive=False,
                                   compress=self.compress)

    # -- persistent plans: decide ring-vs-fallback once from the example ----
    def plan_reduce_scatter(self, x, op: int, comm: int, axis: int = 0):
        axes = self.comm_axes(comm)
        if (op != H.PAX_SUM or not axes or axis != 0
                or tuple(x.shape)[0] % math.prod(self._axis_sizes(axes))):
            return super().plan_reduce_scatter(x, op, comm, axis)
        compress = self.compress
        # kernel-vs-lax decided HERE, from the bound shape/dtype/platform —
        # the run closure carries a fixed per-axis schedule, callers never
        # see the choice (capabilities() reports it as `wire_kernel`)
        fused = self._wire_kernel_axes(tuple(x.shape), x.dtype, axes)
        if any(fused):
            from ...kernels.ring_wire import ops as wire_ops
            interp = wire_ops.interpret_on()

        def run(x):
            for a, k in zip(axes, fused):  # forward order: chunk == rank
                x = (ring_reduce_scatter_fused(x, a, compress, interp)
                     if k else ring_reduce_scatter(x, a, compress))
            return x

        return run

    def plan_allgather(self, x, comm: int, axis: int = 0):
        axes = self.comm_axes(comm)
        if not axes or axis != 0:
            return super().plan_allgather(x, comm, axis)

        def run(x):
            for a in reversed(axes):  # inverse of reduce_scatter
                x = ring_allgather(x, a)
            return x

        return run

    # -- plan-group hooks: fuse the members into ONE ring schedule whose
    # wire carries all buckets side by side (stacked on a trailing member
    # axis, so the leading axis keeps the rank-chunk layout the hops slice).
    # Compression quantizes the fused block per hop — one absmax scale
    # covers every member's traveling contribution, and the group pays one
    # set of S-1 hops instead of N.
    def plan_group_reduce_scatter(self, bounds):
        _, op, comm, axis = bounds[0]
        axes = self.comm_axes(comm)
        u = uniform_payload(bounds, min_ndim=1)
        if (u is None or op != H.PAX_SUM or not axes or axis != 0
                or u[0][0] % math.prod(self._axis_sizes(axes))):
            return super().plan_group_reduce_scatter(bounds)
        compress = self.compress
        n = len(bounds)
        # same plan-time selection as the single plan, against the *stacked*
        # payload the group wire actually carries
        stacked = (u[0][0], n) + tuple(u[0][1:])
        fused = self._wire_kernel_axes(stacked, u[1], axes)
        if any(fused):
            from ...kernels.ring_wire import ops as wire_ops
            interp = wire_ops.interpret_on()

        def run(xs):
            x = jnp.stack(xs, axis=1)  # (rows, members, ...): one fused wire
            for a, k in zip(axes, fused):  # forward order: chunk == rank
                x = (ring_reduce_scatter_fused(x, a, compress, interp)
                     if k else ring_reduce_scatter(x, a, compress))
            return [x[:, i] for i in range(n)]

        return run

    def plan_group_allgather(self, bounds):
        _, comm, axis = bounds[0]
        axes = self.comm_axes(comm)
        if uniform_payload(bounds, min_ndim=1) is None or not axes or axis != 0:
            return super().plan_group_allgather(bounds)
        n = len(bounds)

        def run(xs):
            x = jnp.stack(xs, axis=1)
            for a in reversed(axes):  # inverse of reduce_scatter
                x = ring_allgather(x, a)
            return [x[:, i] for i in range(n)]

        return run
