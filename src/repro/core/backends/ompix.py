"""ompix — a foreign-convention collective implementation (Open MPI analogue).

Everything about it deliberately mismatches the standard ABI, the way Open
MPI's convention mismatches MPICH's (paper §3):

* handles are **objects** (the incomplete-struct-pointer design of §3.3,
  "increased type safety ... compiler can flag mismatches"): identity-
  compared, not integers, not compile-time constants;
* predefined handles are module-level globals (``ompix_comm_world``,
  ``ompix_mpi_float`` — cf. ``OMPI_PREDEFINED_GLOBAL``);
* datatype size is found by dereferencing a descriptor (the 352-byte struct
  chase of §3.3, ``opal_datatype_type_size``), never from handle bits;
* the status convention is Open MPI's §3.2.3 layout:
  ``{MPI_SOURCE, MPI_TAG, MPI_ERROR, _cancelled, _ucount}``;
* error codes use ompix's own numbering (success is 0 — the one value every
  convention shares).

All functions follow the C-ish convention ``(code, result)`` — no
exceptions.  Only :mod:`repro.core.mukautuva` should call this module.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from ..ops import PREDEFINED_OP_FNS  # semantics only; handle domains differ
from .. import handles as H
from . import _lax

# ---------------------------------------------------------------------------
# ompix error codes (its own numbering)
# ---------------------------------------------------------------------------
OMPIX_SUCCESS = 0
OMPIX_ERR_ARG = 71
OMPIX_ERR_COMM = 72
OMPIX_ERR_TYPE = 73
OMPIX_ERR_OP = 74
OMPIX_ERR_UNSUPPORTED = 75
OMPIX_ERR_COUNT = 76
OMPIX_ERR_RANK = 77
OMPIX_ERR_INTERN = 78
# ULFM-shaped fault codes.  ompix itself never raises them — it deliberately
# drops the fault symbols (Comm_revoke/Comm_shrink/Comm_agree/...), the way
# most MPI implementations shipped without ULFM for a decade; the codes exist
# so a fault-*injecting* wrapper library (backends/faulty.FaultyLib) can
# return them through the ompix rc convention and Mukautuva's translator can
# carry them across the layer as PAX_ERR_PROC_FAILED / PAX_ERR_REVOKED.
OMPIX_ERR_PROC_FAILED = 79
OMPIX_ERR_REVOKED = 80


# ---------------------------------------------------------------------------
# ompix handle objects ("incomplete struct pointers": opaque, identity-based)
# ---------------------------------------------------------------------------
class OmpixComm:
    __slots__ = ("axes", "_name")

    def __init__(self, axes: tuple[str, ...], name: str) -> None:
        self.axes = axes
        self._name = name

    def __repr__(self) -> str:  # pragma: no cover
        return f"<ompix_communicator_t* {self._name}>"


@dataclasses.dataclass(eq=False)
class OmpixDatatype:
    """The descriptor an OMPI-style impl chases a pointer into (§3.3)."""

    dname: str
    size: int
    numpy_dtype: Optional[np.dtype]
    # padding fields modelling the large internal struct (never read)
    _align: int = 8
    _flags: int = 0
    _id: int = 0

    def __repr__(self) -> str:  # pragma: no cover
        return f"<ompix_datatype_t* {self.dname}>"


class OmpixOp:
    __slots__ = ("fn", "commute", "oname", "is_native")

    def __init__(self, fn: Callable, commute: bool, oname: str, is_native: bool) -> None:
        self.fn = fn
        self.commute = commute
        self.oname = oname
        self.is_native = is_native

    def __repr__(self) -> str:  # pragma: no cover
        return f"<ompix_op_t* {self.oname}>"


# predefined globals (OMPI_PREDEFINED_GLOBAL analogue) ----------------------
ompix_comm_null = OmpixComm((), "OMPIX_COMM_NULL")
# world/self axes are bound per-instance (mesh-dependent); the globals below
# are the *identity tokens*; OmpixLib maps them to per-mesh axis tuples.

_OMPIX_DTYPE_GLOBALS: dict[str, OmpixDatatype] = {}


def _dt(dname: str, size: int, np_dtype: Optional[str]) -> OmpixDatatype:
    d = OmpixDatatype(dname, size, np.dtype(np_dtype) if np_dtype else None)
    _OMPIX_DTYPE_GLOBALS[dname] = d
    return d


ompix_datatype_null = _dt("OMPIX_DATATYPE_NULL", 0, None)
ompix_mpi_int8 = _dt("OMPIX_INT8", 1, "int8")
ompix_mpi_uint8 = _dt("OMPIX_UINT8", 1, "uint8")
ompix_mpi_int16 = _dt("OMPIX_INT16", 2, "int16")
ompix_mpi_uint16 = _dt("OMPIX_UINT16", 2, "uint16")
ompix_mpi_int32 = _dt("OMPIX_INT32", 4, "int32")
ompix_mpi_uint32 = _dt("OMPIX_UINT32", 4, "uint32")
ompix_mpi_int64 = _dt("OMPIX_INT64", 8, "int64")
ompix_mpi_uint64 = _dt("OMPIX_UINT64", 8, "uint64")
ompix_mpi_float16 = _dt("OMPIX_FLOAT16", 2, "float16")
ompix_mpi_float = _dt("OMPIX_FLOAT", 4, "float32")
ompix_mpi_double = _dt("OMPIX_DOUBLE", 8, "float64")
ompix_mpi_complex64 = _dt("OMPIX_COMPLEX64", 8, "complex64")
ompix_mpi_complex128 = _dt("OMPIX_COMPLEX128", 16, "complex128")
ompix_mpi_byte = _dt("OMPIX_BYTE", 1, "uint8")
try:
    import jax.numpy as _jnp

    ompix_mpi_bfloat16 = _dt("OMPIX_BFLOAT16", 2, None)
    _OMPIX_DTYPE_GLOBALS["OMPIX_BFLOAT16"] = OmpixDatatype(
        "OMPIX_BFLOAT16", 2, np.dtype(_jnp.bfloat16)
    )
    ompix_mpi_bfloat16 = _OMPIX_DTYPE_GLOBALS["OMPIX_BFLOAT16"]
except Exception:  # pragma: no cover
    pass

_OMPIX_OP_GLOBALS: dict[str, OmpixOp] = {}


def _op(oname: str, abi_handle: int, native: bool) -> OmpixOp:
    o = OmpixOp(PREDEFINED_OP_FNS[abi_handle], True, oname, native)
    _OMPIX_OP_GLOBALS[oname] = o
    return o


ompix_op_sum = _op("OMPIX_SUM", H.PAX_SUM, True)
ompix_op_min = _op("OMPIX_MIN", H.PAX_MIN, True)
ompix_op_max = _op("OMPIX_MAX", H.PAX_MAX, True)
ompix_op_prod = _op("OMPIX_PROD", H.PAX_PROD, False)
ompix_op_band = _op("OMPIX_BAND", H.PAX_BAND, False)
ompix_op_bor = _op("OMPIX_BOR", H.PAX_BOR, False)
ompix_op_bxor = _op("OMPIX_BXOR", H.PAX_BXOR, False)
ompix_op_land = _op("OMPIX_LAND", H.PAX_LAND, False)
ompix_op_lor = _op("OMPIX_LOR", H.PAX_LOR, False)
ompix_op_lxor = _op("OMPIX_LXOR", H.PAX_LXOR, False)
ompix_op_minloc = _op("OMPIX_MINLOC", H.PAX_MINLOC, False)
ompix_op_maxloc = _op("OMPIX_MAXLOC", H.PAX_MAXLOC, False)
ompix_op_replace = _op("OMPIX_REPLACE", H.PAX_REPLACE, False)
ompix_op_no_op = _op("OMPIX_NO_OP", H.PAX_NO_OP, False)


def opal_datatype_type_size(dtype: OmpixDatatype) -> tuple[int, int]:
    """The §3.3 lookup: ``*size = pData->size; return 0;``"""
    return OMPIX_SUCCESS, dtype.size


class OmpixLib:
    """The foreign implementation library ("libompix.so")."""

    name = "ompix"

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None) -> None:
        self.mesh = mesh
        axes = tuple(mesh.axis_names) if mesh is not None else ()
        self.comm_world = OmpixComm(axes, "OMPIX_COMM_WORLD")
        self.comm_self = OmpixComm((), "OMPIX_COMM_SELF")
        self.comm_null = ompix_comm_null
        self.dtype_globals = dict(_OMPIX_DTYPE_GLOBALS)
        self.op_globals = dict(_OMPIX_OP_GLOBALS)

    # -- object constructors --------------------------------------------
    def Comm_from_axes(self, axes: Sequence[str]) -> tuple[int, Optional[OmpixComm]]:
        if self.mesh is None:
            return OMPIX_ERR_COMM, None
        axes = tuple(axes)
        if any(a not in self.mesh.axis_names for a in axes):
            return OMPIX_ERR_ARG, None
        return OMPIX_SUCCESS, OmpixComm(axes, f"ompix_comm{axes}")

    def Op_create(self, fn: Callable, commute: bool) -> tuple[int, Optional[OmpixOp]]:
        if not callable(fn):
            return OMPIX_ERR_OP, None
        return OMPIX_SUCCESS, OmpixOp(fn, commute, "ompix_user_op", False)

    def Type_contiguous(
        self, count: int, base: OmpixDatatype
    ) -> tuple[int, Optional[OmpixDatatype]]:
        if not isinstance(base, OmpixDatatype):
            return OMPIX_ERR_TYPE, None
        return OMPIX_SUCCESS, OmpixDatatype(
            f"contig({count},{base.dname})", base.size * count, base.numpy_dtype
        )

    # -- queries ----------------------------------------------------------
    def Comm_size(self, comm: OmpixComm) -> tuple[int, int]:
        if not isinstance(comm, OmpixComm) or comm is ompix_comm_null:
            return OMPIX_ERR_COMM, -1
        if self.mesh is None or not comm.axes:
            return OMPIX_SUCCESS, 1
        import math

        return OMPIX_SUCCESS, math.prod(self.mesh.shape[a] for a in comm.axes)

    def Comm_rank(self, comm: OmpixComm) -> tuple[int, Any]:
        if not isinstance(comm, OmpixComm) or comm is ompix_comm_null:
            return OMPIX_ERR_COMM, -1
        return OMPIX_SUCCESS, _lax.rank(comm.axes)

    def Type_size(self, dtype: OmpixDatatype) -> tuple[int, int]:
        if not isinstance(dtype, OmpixDatatype):
            return OMPIX_ERR_TYPE, -1
        return opal_datatype_type_size(dtype)

    # -- collectives -------------------------------------------------------
    def _check(self, comm, op=None) -> int:
        if not isinstance(comm, OmpixComm) or comm is ompix_comm_null:
            return OMPIX_ERR_COMM
        if op is not None and not isinstance(op, OmpixOp):
            return OMPIX_ERR_OP
        return OMPIX_SUCCESS

    def Allreduce(self, x, op: OmpixOp, comm: OmpixComm):
        rc = self._check(comm, op)
        if rc:
            return rc, None
        if op is self.op_globals.get("OMPIX_SUM") or op.oname == "OMPIX_SUM":
            return OMPIX_SUCCESS, _lax.psum(x, comm.axes)
        if op.oname == "OMPIX_MAX":
            return OMPIX_SUCCESS, _lax.pmax(x, comm.axes)
        if op.oname == "OMPIX_MIN":
            return OMPIX_SUCCESS, _lax.pmin(x, comm.axes)
        return OMPIX_SUCCESS, _lax.allreduce_generic(x, op.fn, comm.axes)

    # NB: no ``Reduce`` and no ``Gather`` — this library deliberately does
    # not export the derived collectives (they were hand-written forwards to
    # Allreduce/Allgather).  The ABI layer's tiered negotiation emulates
    # them from the entries the library *does* export, which is exactly how
    # a partial foreign implementation is admitted behind the standard
    # function table (paper §6; Mukautuva reports the symbol as absent and
    # the recipe fills the hole above the translation layer).

    def Bcast(self, x, root: int, comm: OmpixComm):
        rc = self._check(comm)
        if rc:
            return rc, None
        return OMPIX_SUCCESS, _lax.bcast(x, root, comm.axes)

    def Reduce_scatter(self, x, op: OmpixOp, comm: OmpixComm, axis: int = 0):
        rc = self._check(comm, op)
        if rc:
            return rc, None
        if op.oname == "OMPIX_SUM":
            return OMPIX_SUCCESS, _lax.reduce_scatter_sum(x, comm.axes, axis=axis)
        return OMPIX_SUCCESS, _lax.reduce_scatter_generic(x, op.fn, comm.axes, axis=axis)

    def Allgather(self, x, comm: OmpixComm, axis: int = 0):
        rc = self._check(comm)
        if rc:
            return rc, None
        return OMPIX_SUCCESS, _lax.allgather(x, comm.axes, axis=axis)

    def Alltoall(self, x, comm: OmpixComm, split_axis: int = 0, concat_axis: int = 0):
        rc = self._check(comm)
        if rc:
            return rc, None
        try:
            return OMPIX_SUCCESS, _lax.alltoall(x, comm.axes, split_axis, concat_axis)
        except NotImplementedError:
            return OMPIX_ERR_UNSUPPORTED, None

    def Alltoallw(self, blocks, sendtypes, recvtypes, comm: OmpixComm):
        """Per-peer-typed alltoall over leading axis (one block per peer).

        The cast to each peer's recv type is the per-element conversion work
        whose bookkeeping gives Mukautuva its worst case (§6.2).
        """
        rc = self._check(comm)
        if rc:
            return rc, None
        if any(not isinstance(t, OmpixDatatype) for t in list(sendtypes) + list(recvtypes)):
            return OMPIX_ERR_TYPE, None
        try:
            out = _lax.alltoall(blocks, comm.axes, 0, 0)
        except NotImplementedError:
            return OMPIX_ERR_UNSUPPORTED, None
        import jax.numpy as jnp

        parts = [
            out[i].astype(recvtypes[i].numpy_dtype) if recvtypes[i].numpy_dtype else out[i]
            for i in range(out.shape[0])
        ]
        return OMPIX_SUCCESS, parts

    def Scan(self, x, op: OmpixOp, comm: OmpixComm):
        rc = self._check(comm, op)
        if rc:
            return rc, None
        return OMPIX_SUCCESS, _lax.scan_fold(x, op.fn, comm.axes, inclusive=True)

    def Exscan(self, x, op: OmpixOp, comm: OmpixComm):
        rc = self._check(comm, op)
        if rc:
            return rc, None
        return OMPIX_SUCCESS, _lax.scan_fold(x, op.fn, comm.axes, inclusive=False)

    def Alltoallv(self, x, sendcounts, recvcounts, comm: OmpixComm):
        rc = self._check(comm)
        if rc:
            return rc, None
        if len(sendcounts) != len(recvcounts):
            return OMPIX_ERR_COUNT, None
        try:
            return OMPIX_SUCCESS, _lax.alltoallv(x, sendcounts, recvcounts, comm.axes)
        except NotImplementedError:
            return OMPIX_ERR_UNSUPPORTED, None

    def Sendrecv(self, x, perm, comm: OmpixComm):
        rc = self._check(comm)
        if rc:
            return rc, None, None
        try:
            y = _lax.ppermute(x, comm.axes, perm)
        except NotImplementedError:
            return OMPIX_ERR_UNSUPPORTED, None, None
        # ompix status convention (§3.2.3 layout)
        status = {
            "MPI_SOURCE": -1,
            "MPI_TAG": 0,
            "MPI_ERROR": OMPIX_SUCCESS,
            "_cancelled": 0,
            "_ucount": int(np.prod(x.shape)) if hasattr(x, "shape") else 0,
        }
        return OMPIX_SUCCESS, y, status

    def Barrier(self, comm: OmpixComm):
        rc = self._check(comm)
        if rc:
            return rc
        _lax.barrier(comm.axes)
        return OMPIX_SUCCESS

    def Scatter(self, x, root: int, comm: OmpixComm, axis: int = 0):
        rc = self._check(comm)
        if rc:
            return rc, None
        return OMPIX_SUCCESS, _lax.scatter_from_root(x, root, comm.axes, axis=axis)
