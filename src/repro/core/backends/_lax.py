"""Shared ``jax.lax`` lowering of the abstract collectives.

All backends that map to XLA collectives funnel through these helpers.
Axes are ordered mesh-axis tuples (row-major rank order — see
``communicator.comm_rank_traced``):

* ``reduce_scatter`` applies per-axis scatters in *forward* axis order and
* ``all_gather`` applies per-axis gathers in *reverse* axis order,

so that chunk index == linearized communicator rank, and the two compose to
an all-reduce exactly like a ring implementation would.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .. import compat
from ..emulation import prefix_fold

#: jaxpr primitives that put payload on the inter-chip wire — the canonical
#: list for traffic classification (launch/hlo_analysis.wire_breakdown
#: separates these from the HBM-side intermediates a fused kernel removes)
WIRE_PRIMITIVES = frozenset({
    "ppermute", "psum", "all_gather", "psum_scatter", "all_to_all",
})


def rank(axes: Sequence[str]):
    if not axes:
        return jnp.int32(0)
    r = lax.axis_index(axes[0])
    for a in axes[1:]:
        r = r * compat.axis_size(a) + lax.axis_index(a)
    return r


def _cpu_safe_dtype(x):
    """XLA-CPU's AllReducePromotion pass crashes on sub-f32 float all-reduce /
    reduce-scatter emitted by shard_map (CreateBinary(copy) in CloneAllReduce).
    On the CPU dry-run container we upcast the wire to f32 and downcast after;
    on TPU (the target) this shim is inert and the wire stays bf16.
    EXPERIMENTS.md §Dry-run footnotes the 2x all-reduce-byte inflation."""
    import jax

    if jax.default_backend() != "cpu":
        return x, None
    if jnp.issubdtype(x.dtype, jnp.floating) and jnp.dtype(x.dtype).itemsize < 4:
        return x.astype(jnp.float32), x.dtype
    return x, None


def psum(x, axes: Sequence[str]):
    if not axes:
        return x
    xw, orig = _cpu_safe_dtype(x)
    out = lax.psum(xw, tuple(axes))
    return out.astype(orig) if orig is not None else out


def pmax(x, axes: Sequence[str]):
    return lax.pmax(x, tuple(axes)) if axes else x


def pmin(x, axes: Sequence[str]):
    return lax.pmin(x, tuple(axes)) if axes else x


def allreduce_generic(x, fn: Callable, axes: Sequence[str]):
    """All-reduce for ops XLA has no wire-reduction for (PROD, bitwise,
    logical, MINLOC/MAXLOC, user callbacks): all-gather + local fold,
    applied per axis.  This mirrors how MPI implementations lower exotic
    ops to pt2pt; the ABI makes no claim that every op is wire-native."""
    for a in axes:
        g = lax.all_gather(x, a, axis=0, tiled=False)  # (axis_size, *x.shape)
        n = g.shape[0]
        acc = g[0]
        for i in range(1, n):
            acc = fn(acc, g[i])
        x = acc
    return x


def allgather(x, axes: Sequence[str], axis: int = 0, tiled: bool = True):
    for a in reversed(tuple(axes)):
        x = lax.all_gather(x, a, axis=axis, tiled=tiled)
    return x


def reduce_scatter_sum(x, axes: Sequence[str], axis: int = 0):
    xw, orig = _cpu_safe_dtype(x)
    for a in tuple(axes):
        xw = lax.psum_scatter(xw, a, scatter_dimension=axis, tiled=True)
    return xw.astype(orig) if orig is not None else xw


def reduce_scatter_generic(x, fn: Callable, axes: Sequence[str], axis: int = 0):
    """Generic-op reduce-scatter: all-reduce then slice own chunk."""
    x = allreduce_generic(x, fn, axes)
    r = rank(axes)
    import math

    total = math.prod(compat.axis_size(a) for a in axes) if axes else 1
    chunk = x.shape[axis] // total
    return lax.dynamic_slice_in_dim(x, r * chunk, chunk, axis=axis)


def alltoall(x, axes: Sequence[str], split_axis: int, concat_axis: int, tiled: bool = True):
    if len(axes) != 1:
        raise NotImplementedError(
            "alltoall is defined over single-axis communicators "
            f"(got axes={tuple(axes)}); split the communicator"
        )
    return lax.all_to_all(x, axes[0], split_axis, concat_axis, tiled=tiled)


def scan_fold(x, fn: Callable, axes: Sequence[str], inclusive: bool = True):
    """Prefix reduction over linearized communicator rank (MPI_Scan/Exscan).

    Gathers every rank's contribution into a leading axis in linearized
    (row-major) rank order, then folds via the shared kernel
    (``emulation.prefix_fold`` — one definition of the exscan rank-0
    convention for native and emulated backends alike)."""
    axes = tuple(axes)
    if not axes:
        return x
    g = allgather(x[None], axes, axis=0)  # (S, *x.shape), linear rank order
    return prefix_fold(g, rank(axes), fn, x, inclusive)


def _alltoall_hier_uniform(x, axes: Sequence[str], c: int):
    """Hierarchical uniform-count all-to-all over a multi-axis communicator
    (row-major linearized rank/peer order), decomposed axis by axis the way
    ``ring_scan_sum_multi`` decomposes the prefix scan: route the major
    digit of every destination over the major axis first, transpose the
    minor destination blocks to the front, recurse over the remaining axes,
    and transpose back into source-major order.  ``len(axes)`` single-axis
    ``all_to_all`` phases move the same bytes a flat S-peer exchange would,
    but each phase stays inside one mesh axis — the 2D-torus schedule.

    ``x``: ``(S*c, ...)`` rows grouped by linearized destination; returns
    the same shape grouped by linearized source."""
    a0 = axes[0]
    A = compat.axis_size(a0)
    tail = x.shape[1:]
    if len(axes) == 1:
        return alltoall(x, (a0,), 0, 0)
    import math

    R = math.prod(compat.axis_size(a) for a in axes[1:])
    # phase 1: deliver each destination's major digit over the major axis
    # (A blocks of R*c rows); block a0 is then the data *from* major-source
    # a0, still ordered by minor destination
    y = alltoall(x, (a0,), 0, 0)
    y = y.reshape((A, R, c) + tail)
    # group by minor destination and recurse (blocks of A*c rows)
    y = jnp.swapaxes(y, 0, 1).reshape((R * A * c,) + tail)
    y = _alltoall_hier_uniform(y, axes[1:], A * c)
    # rows are now (minor-source, major-source); back to row-major source
    y = y.reshape((R, A, c) + tail)
    return jnp.swapaxes(y, 0, 1).reshape((A * R * c,) + tail)


def alltoallv(x, sendcounts: Sequence[int], recvcounts: Sequence[int],
              axes: Sequence[str]):
    """Counted all-to-all over the leading array axis (MPI_Alltoallv).

    ``x`` holds ``sum(sendcounts)`` rows: block *i* (``sendcounts[i]`` rows)
    goes to peer *i*; ``recvcounts[j]`` rows come back from peer *j*, in
    peer order.  Multi-axis communicators decompose hierarchically
    (:func:`_alltoall_hier_uniform`); peers are linearized row-major, so
    the result is indistinguishable from a flat single-axis exchange.

    **SPMD restriction:** a single static trace shares one counts vector
    across every rank, so per-rank-varying counts are not representable —
    rank *j* would be sending ``sendcounts[i]`` rows toward rank *i* while
    rank *i* slices ``recvcounts[j]``, and the two only agree when all
    counts are equal.  Non-uniform counts therefore raise ``ValueError``
    instead of silently fabricating padding or dropping rows."""
    axes = tuple(axes)
    sendcounts = tuple(int(c) for c in sendcounts)
    recvcounts = tuple(int(c) for c in recvcounts)
    if len(sendcounts) != len(recvcounts):
        raise ValueError("sendcounts and recvcounts must have equal length")
    uniform = set(sendcounts) | set(recvcounts)
    if len(uniform) != 1:
        raise ValueError(
            "SPMD alltoallv requires uniform counts (one static trace cannot "
            f"express per-rank-varying counts); got sendcounts={sendcounts}, "
            f"recvcounts={recvcounts}"
        )
    c = sendcounts[0]
    S = len(sendcounts)
    if x.shape[0] != S * c:
        raise ValueError(
            f"payload has {x.shape[0]} rows, counts promise {S}x{c}"
        )
    if not axes:
        # group of one: the only peer is self
        if S != 1:
            raise ValueError("group-of-one alltoallv takes exactly one count")
        return x
    if c == 0:
        return x[:0]
    if len(axes) > 1:
        return _alltoall_hier_uniform(x, tuple(axes), c)
    out = alltoall(x.reshape((S, c) + x.shape[1:]), axes, 0, 0)
    return out.reshape((S * c,) + x.shape[1:])


def ppermute(x, axes: Sequence[str], perm):
    if not axes:  # group of one: the only legal perm is the identity
        return x
    if len(axes) != 1:
        raise NotImplementedError("point-to-point permutation needs a single-axis comm")
    return lax.ppermute(x, axes[0], perm)


def bcast(x, root: int, axes: Sequence[str]):
    """Broadcast from linearized rank ``root`` via masked psum (one
    all-reduce; avoids materializing a full all-gather)."""
    if not axes:
        return x
    r = rank(axes)
    mask = (r == root).astype(x.dtype)
    return lax.psum(x * mask, tuple(axes)) if jnp.issubdtype(x.dtype, jnp.floating) else lax.psum(
        jnp.where(r == root, x, jnp.zeros_like(x)), tuple(axes)
    )


def barrier(axes: Sequence[str]):
    """Synchronization point: a zero-payload all-reduce the scheduler cannot
    elide (optimization_barrier on both sides)."""
    if not axes:
        return None
    t = jnp.zeros((), dtype=jnp.float32)
    (t,) = lax.optimization_barrier((t,))
    t = lax.psum(t, tuple(axes))
    (t,) = lax.optimization_barrier((t,))
    return t


def scatter_from_root(x, root: int, axes: Sequence[str], axis: int = 0):
    """SPMD scatter: input replicated (or defined on root); each device takes
    its chunk. With root!=self the payload still moves via the bcast."""
    x = bcast(x, root, axes)
    r = rank(axes)
    import math

    total = math.prod(compat.axis_size(a) for a in axes) if axes else 1
    chunk = x.shape[axis] // total
    return lax.dynamic_slice_in_dim(x, r * chunk, chunk, axis=axis)
