"""The declarative ABI function table — one spec driving the whole stack.

The paper's core artifact is a *standard function table*: a fixed set of
symbols with fixed handle semantics that any implementation can be resolved
against at init (the ``dlopen``/``dlsym`` protocol of §6.2), and that a
translation layer (Mukautuva) can be generated against mechanically, one
wrapper per entry point.

This module is that table, as data.  Every ABI entry point is one
:class:`AbiEntry` row declaring:

* its name and argument list, with each argument's *domain*
  (:class:`Arg` kind) — which drives handle checking in the ABI layer and
  handle conversion in Mukautuva;
* its byte-accounting rule (``bytes_arg`` — which argument is the payload
  the interposition tools should account);
* whether a nonblocking ``i*`` variant exists (``nonblocking``);
* the Mukautuva conversion signature: the foreign-library symbol
  (``impl_name``), the return protocol (``muk_ret``), and whether converted
  handle vectors must be kept alive in the request map until completion
  (``temps`` — the §6.2 ``alltoallw`` worst case);
* its negotiation **tier** (``REQUIRED`` entries must resolve natively at
  ``pax_init`` or init fails; ``OPTIONAL`` entries admit partial backends)
  and, for optional entries, an **emulation recipe** (:class:`Recipe`) — a
  declarative expression of the entry in terms of *other entries*, which
  negotiation compiles into a closure when the backend does not export the
  symbol but the recipe's dependency chain grounds out in entries it does.

Consumers generate their layer from the table instead of hand-writing each
entry point four times:

* :mod:`repro.core.abi` generates ``PaxABI``'s blocking and nonblocking
  methods (with a precompiled zero-tool fast path);
* :mod:`repro.core.backends.base` generates unsupported-operation
  placeholders, so ``supports()`` can report a backend's capabilities;
* :mod:`repro.core.mukautuva` generates the WRAP_* translation wrappers;
* ``PaxABI.__init__`` performs dlsym-style *negotiation*: every entry is
  resolved against the backend once at init, so a missing entry point is a
  clean ``PAX_ERR_UNSUPPORTED_OPERATION`` at init time, never mid-step.

Adding an entry point is one row here plus the per-backend implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

from . import emulation as em
from . import handles as H

# ---------------------------------------------------------------------------
# Negotiation tiers.  A REQUIRED entry must be natively resolvable at init
# (it is either a pure handle query or the ground every recipe stands on);
# an OPTIONAL entry may be emulated via its recipe, or left unresolved —
# in which case *calling* it raises PAX_ERR_UNSUPPORTED_OPERATION, init
# does not.
# ---------------------------------------------------------------------------
REQUIRED = "required"
OPTIONAL = "optional"
#: ULFM-style fault-tolerance extension entries (comm_revoke / comm_shrink /
#: comm_agree / comm_failure_ack / comm_get_failed).  Negotiates exactly like
#: OPTIONAL — native when the backend exports the symbol, recipe-emulated
#: otherwise — but is reported as its own tier by ``capabilities()`` so a
#: caller can ask "does this stack have a fault model?" as one question.
FAULT = "fault"


@dataclasses.dataclass(frozen=True)
class Recipe:
    """A declarative emulation of one entry in terms of other entries.

    ``deps`` names the function-table entries the emulation calls; ``build``
    is the compiler (see :mod:`repro.core.emulation`): it receives an
    ``EmulationContext`` whose ``dep(name)`` returns the *resolved* callable
    for each dependency — native backend method or previously-built
    emulation — and returns a closure with the entry's backend signature.
    ``validate_table`` guarantees the dependency graph is acyclic and
    computes the topological build order.

    ``plan`` is the optional *persistent-plan* compiler: given a
    ``PlanContext`` and the plan-time bound arguments (payloads as abstract
    shapes), it returns a bare run closure with every chain decision —
    padding, slicing, dependency resolution — already taken, so a plan
    ``start()`` on an emulated entry costs the same as on a native one.
    Entries without one still get a generic plan (argument freezing around
    the built emulation closure).

    ``plan_group`` is the optional *plan-group* compiler (the MPI
    ``Startall`` analogue, PR 5): given a ``PlanContext`` and a list of
    bound-argument tuples — one per group member, all sharing the same
    non-payload arguments — it returns one fused run closure executing the
    recipe **per stage across members** (e.g. every member's
    reduce-scatter leg before any all-gather leg, each stage itself fused
    through ``PlanContext.plan_group_dep`` when the backend has a group
    hook).  Returning ``None`` declines the fusion and the group falls
    back to per-member plan runs.
    """

    deps: Tuple[str, ...]
    build: Callable
    plan: Optional[Callable] = None
    plan_group: Optional[Callable] = None

# ---------------------------------------------------------------------------
# Argument domains.  The domain decides (a) the ABI-layer handle check and
# (b) the Mukautuva conversion applied before the foreign library sees it.
# ---------------------------------------------------------------------------
PAYLOAD = "payload"        # array / pytree payload — passed through
OP = "op"                  # op handle      -> check OP,       muk _convert_op
COMM = "comm"              # comm handle    -> check COMM,     muk _convert_comm
DATATYPE = "datatype"      # dtype handle   -> check DATATYPE, muk _convert_dtype
DATATYPE_VEC = "datatype_vec"  # vector of dtype handles -> per-element both
ROOT = "root"              # rank integer — passed through
AXIS = "axis"              # array-axis integer — passed through
COUNTS = "counts"          # per-peer count vector — coerced to tuple
PERM = "perm"              # (src, dst) permutation — coerced to tuple

_CHECK_KIND = {
    OP: H.HandleKind.OP,
    COMM: H.HandleKind.COMM,
    DATATYPE: H.HandleKind.DATATYPE,
    DATATYPE_VEC: H.HandleKind.DATATYPE,
}

class _NoDefault:
    def __repr__(self) -> str:  # pragma: no cover
        return "<required>"


_NO_DEFAULT = _NoDefault()


@dataclasses.dataclass(frozen=True)
class Arg:
    name: str
    kind: str
    default: object = _NO_DEFAULT

    @property
    def has_default(self) -> bool:
        return self.default is not _NO_DEFAULT

    @property
    def check_kind(self) -> Optional[H.HandleKind]:
        return _CHECK_KIND.get(self.kind)


@dataclasses.dataclass(frozen=True)
class AbiEntry:
    """One row of the standard function table."""

    name: str                      # ABI function name ("allreduce")
    impl_name: str                 # foreign-library symbol ("Allreduce")
    args: Tuple[Arg, ...]
    backend_method: str = ""       # Backend method name; defaults to `name`
    nonblocking: bool = False      # generate the i<name> variant
    bytes_arg: Optional[str] = None  # payload arg for tool byte accounting
    dtype_size_kwarg: bool = False   # extra `datatype=None` kwarg for bytes
    fills_status: bool = False       # ABI-level `status=None` out-param
    muk_ret: str = "value"           # "value" | "rc_only" | "status"
    temps: bool = False              # stash converted vectors for the request map
    tier: str = OPTIONAL             # REQUIRED | OPTIONAL | FAULT (negotiation tier)
    recipe: Optional[Recipe] = None  # emulation of this entry, if not REQUIRED
    #: generate the MPI-4 persistent variant (``<name>_init`` plan
    #: constructor).  ``None`` (default) derives from ``nonblocking`` — every
    #: entry with an ``i*`` twin gets a plan constructor, the way MPI-4 gave
    #: every nonblocking collective a persistent ``_init`` twin.
    persistent: Optional[bool] = None
    #: end-to-end integrity rule for the opt-in checksummed-wire mode
    #: (PR 10).  ``"replicated"`` — the entry's result is identical on every
    #: member (allreduce/bcast/allgather), so a fused cross-member checksum
    #: *agreement* detects a corrupted payload; ``"conserved"`` — under
    #: ``PAX_SUM`` the entry conserves the payload total
    #: (reduce_scatter), so an input-vs-output checksum *conservation* check
    #: does.  ``None`` — no plan-time checksum envelope for this entry.
    integrity: Optional[str] = None

    def __post_init__(self):
        if not self.backend_method:
            object.__setattr__(self, "backend_method", self.name)
        if self.persistent is None:
            object.__setattr__(self, "persistent", self.nonblocking)

    @property
    def payload_args(self) -> Tuple[int, ...]:
        """Indices of the PAYLOAD arguments (the plan ``start`` signature)."""
        return tuple(i for i, a in enumerate(self.args) if a.kind == PAYLOAD)

    @property
    def temps_attr(self) -> str:
        """Backend attribute holding per-call temporaries (§6.2 request map)."""
        return f"last_{self.name}_temps"


def _e(name, impl_name, args, **kw) -> AbiEntry:
    return AbiEntry(name=name, impl_name=impl_name, args=tuple(args), **kw)


# ---------------------------------------------------------------------------
# The standard function table.
# ---------------------------------------------------------------------------
ABI_TABLE: Tuple[AbiEntry, ...] = (
    # -- queries (REQUIRED: pure handle queries every implementation can
    #    answer; also the ground most recipes stand on) --------------------
    _e("comm_size", "Comm_size", [Arg("comm", COMM)], backend_method="size",
       tier=REQUIRED),
    _e("comm_rank", "Comm_rank", [Arg("comm", COMM)], backend_method="rank",
       tier=REQUIRED),
    _e("type_size", "Type_size", [Arg("datatype", DATATYPE)], tier=REQUIRED),
    # -- collectives (OPTIONAL; recipes express the derived ones) ----------
    _e("allreduce", "Allreduce",
       [Arg("x", PAYLOAD), Arg("op", OP), Arg("comm", COMM)],
       nonblocking=True, bytes_arg="x", dtype_size_kwarg=True,
       integrity="replicated",
       recipe=Recipe(("reduce_scatter", "allgather", "comm_size"),
                     em.build_allreduce, em.plan_allreduce,
                     em.plan_group_allreduce)),
    _e("reduce", "Reduce",
       [Arg("x", PAYLOAD), Arg("op", OP), Arg("root", ROOT), Arg("comm", COMM)],
       nonblocking=True, bytes_arg="x",
       recipe=Recipe(("allreduce",), em.build_reduce, em.plan_reduce,
                     em.plan_group_reduce)),
    _e("bcast", "Bcast",
       [Arg("x", PAYLOAD), Arg("root", ROOT), Arg("comm", COMM)],
       nonblocking=True, bytes_arg="x", integrity="replicated",
       recipe=Recipe(("allreduce", "comm_rank"), em.build_bcast,
                     em.plan_bcast)),
    _e("reduce_scatter", "Reduce_scatter",
       [Arg("x", PAYLOAD), Arg("op", OP), Arg("comm", COMM), Arg("axis", AXIS, 0)],
       nonblocking=True, bytes_arg="x", integrity="conserved"),
    _e("allgather", "Allgather",
       [Arg("x", PAYLOAD), Arg("comm", COMM), Arg("axis", AXIS, 0)],
       nonblocking=True, bytes_arg="x", integrity="replicated"),
    _e("alltoall", "Alltoall",
       [Arg("x", PAYLOAD), Arg("comm", COMM),
        Arg("split_axis", AXIS, 0), Arg("concat_axis", AXIS, 0)],
       nonblocking=True, bytes_arg="x",
       recipe=Recipe(("allgather", "comm_rank", "comm_size"),
                     em.build_alltoall)),
    _e("alltoallv", "Alltoallv",
       [Arg("x", PAYLOAD), Arg("sendcounts", COUNTS), Arg("recvcounts", COUNTS),
        Arg("comm", COMM)],
       nonblocking=True, bytes_arg="x",
       recipe=Recipe(("alltoall", "comm_size"), em.build_alltoallv)),
    _e("alltoallw", "Alltoallw",
       [Arg("blocks", PAYLOAD), Arg("sendtypes", DATATYPE_VEC),
        Arg("recvtypes", DATATYPE_VEC), Arg("comm", COMM)],
       nonblocking=True, bytes_arg="blocks", temps=True,
       recipe=Recipe(("alltoall",), em.build_alltoallw)),
    _e("scan", "Scan",
       [Arg("x", PAYLOAD), Arg("op", OP), Arg("comm", COMM)],
       nonblocking=True, bytes_arg="x",
       recipe=Recipe(("allgather", "comm_rank", "comm_size"), em.build_scan,
                     em.plan_scan)),
    _e("exscan", "Exscan",
       [Arg("x", PAYLOAD), Arg("op", OP), Arg("comm", COMM)],
       nonblocking=True, bytes_arg="x",
       recipe=Recipe(("allgather", "comm_rank", "comm_size"), em.build_exscan,
                     em.plan_exscan)),
    _e("sendrecv", "Sendrecv",
       [Arg("x", PAYLOAD), Arg("perm", PERM), Arg("comm", COMM)],
       nonblocking=True, bytes_arg="x", fills_status=True, muk_ret="status"),
    _e("barrier", "Barrier", [Arg("comm", COMM)],
       nonblocking=True, muk_ret="rc_only",
       recipe=Recipe(("allreduce",), em.build_barrier, em.plan_barrier)),
    _e("scatter", "Scatter",
       [Arg("x", PAYLOAD), Arg("root", ROOT), Arg("comm", COMM), Arg("axis", AXIS, 0)],
       nonblocking=True, bytes_arg="x",
       recipe=Recipe(("bcast", "comm_rank", "comm_size"), em.build_scatter)),
    _e("gather", "Gather",
       [Arg("x", PAYLOAD), Arg("root", ROOT), Arg("comm", COMM), Arg("axis", AXIS, 0)],
       nonblocking=True, bytes_arg="x",
       recipe=Recipe(("allgather",), em.build_gather, em.plan_gather)),
    # -- fault tier (ULFM-style extension entries; "The Case for ABI
    #    Interoperability in a Fault Tolerant MPI").  Blocking-only — the
    #    recovery path is control plane, not hot path.  Every entry carries a
    #    recipe grounding in REQUIRED queries, so even `minimal` negotiates a
    #    complete fault model; backends that lack the symbols (ompix) fall
    #    back to the same recipes through Mukautuva, whose generated wrappers
    #    translate foreign PROC_FAILED/REVOKED rcs when the symbols do exist.
    _e("comm_revoke", "Comm_revoke", [Arg("comm", COMM)],
       muk_ret="rc_only", tier=FAULT,
       recipe=Recipe((), em.build_comm_revoke)),
    _e("comm_failure_ack", "Comm_failure_ack", [Arg("comm", COMM)],
       muk_ret="rc_only", tier=FAULT,
       recipe=Recipe((), em.build_comm_failure_ack)),
    _e("comm_get_failed", "Comm_get_failed", [Arg("comm", COMM)],
       tier=FAULT,
       recipe=Recipe((), em.build_comm_get_failed)),
    _e("comm_agree", "Comm_agree",
       [Arg("flag", PAYLOAD), Arg("comm", COMM)],
       tier=FAULT,
       recipe=Recipe((), em.build_comm_agree)),
    _e("comm_shrink", "Comm_shrink", [Arg("comm", COMM)],
       tier=FAULT,
       recipe=Recipe(("comm_agree", "comm_get_failed"),
                     em.build_comm_shrink)),
)


# ---------------------------------------------------------------------------
# Spec-load validation + the emulation build order.
# ---------------------------------------------------------------------------
def validate_table(table: Tuple[AbiEntry, ...]) -> Tuple[str, ...]:
    """Validate tiers/recipes and return the topological resolution order.

    Raises ``ValueError`` at spec-load time (never at ``pax_init``) when:

    * two rows share a name;
    * a recipe depends on an entry the table does not define;
    * a REQUIRED entry carries a recipe (required means *natively* required —
      an emulable entry is by definition optional);
    * the recipe dependency graph has a cycle (no resolution order exists).

    The returned order lists every entry name with all recipe dependencies
    before their dependents, so negotiation can build emulation closures in
    one forward pass.
    """
    by_name: dict = {}
    for entry in table:
        if entry.name in by_name:
            raise ValueError(f"duplicate function-table entry {entry.name!r}")
        by_name[entry.name] = entry
    for entry in table:
        if entry.recipe is None:
            continue
        if entry.tier == REQUIRED:
            raise ValueError(
                f"required entry {entry.name!r} carries an emulation recipe"
            )
        for dep in entry.recipe.deps:
            if dep not in by_name:
                raise ValueError(
                    f"recipe for {entry.name!r} depends on unknown entry {dep!r}"
                )
    # DFS topo sort over recipe edges; entries without recipes are leaves.
    order: list = []
    state: dict = {}  # name -> 1 (on stack) | 2 (done)

    def visit(name: str, chain: tuple) -> None:
        if state.get(name) == 2:
            return
        if state.get(name) == 1:
            cycle = chain[chain.index(name):] + (name,)
            raise ValueError(
                "recipe dependency cycle: " + " -> ".join(cycle)
            )
        state[name] = 1
        recipe = by_name[name].recipe
        if recipe is not None:
            for dep in recipe.deps:
                visit(dep, chain + (name,))
        state[name] = 2
        order.append(name)

    for entry in table:
        visit(entry.name, ())
    return tuple(order)


#: entries by name (negotiation + capability reporting index)
ENTRY_BY_NAME: dict = {e.name: e for e in ABI_TABLE}

#: topological resolution order — recipe deps always precede dependents
EMULATION_ORDER: Tuple[str, ...] = validate_table(ABI_TABLE)

# ---------------------------------------------------------------------------
# Codegen helpers shared by the generating layers.
# ---------------------------------------------------------------------------
def signature_src(entry: AbiEntry, *, extra_kwargs: bool = False) -> str:
    """``x, op, comm, axis=0`` source text for an entry's parameter list.

    With ``extra_kwargs`` the ABI-level-only trailing kwargs are included
    (``datatype=`` for byte accounting, ``status=`` for the out-param).
    """
    parts = []
    for a in entry.args:
        parts.append(f"{a.name}={a.default!r}" if a.has_default else a.name)
    if extra_kwargs and entry.dtype_size_kwarg:
        parts.append("datatype=None")
    if extra_kwargs and entry.fills_status:
        parts.append("status=None")
    return ", ".join(parts)


def call_args_src(entry: AbiEntry) -> str:
    """``x, op, comm, axis`` — forwarding text in table order."""
    return ", ".join(a.name for a in entry.args)


def compile_method(src: str, env: dict, name: str):
    """Compile generated method source; tag it for introspection."""
    ns: dict = {}
    code = compile(src, f"<abi_spec:{name}>", "exec")
    exec(code, env, ns)
    fn = ns[name]
    fn.__generated_src__ = src
    return fn
