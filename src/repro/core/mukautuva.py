"""Mukautuva — the external ABI translation layer (paper §6.2).

"Adaptable" in Finnish.  The worst-case implementation of the standard ABI:
a standalone layer that makes a *foreign-convention* implementation (here
:mod:`backends.ompix`, the Open-MPI analogue) speak the standard ABI without
any change to the implementation itself.

The paper's point is that this layer can be produced *mechanically*, one
wrapper per entry point of the standard function table.  This module does
exactly that: **every WRAP_* method is generated from the declarative spec**
(:mod:`repro.core.abi_spec`) — the entry's argument domains decide the
CONVERT_* calls, its ``muk_ret`` decides the return-code protocol, and its
``temps`` flag decides whether converted handle vectors are stashed for the
request map.  Nothing per-collective is hand-written; adding an entry point
to the spec adds its translation wrapper automatically.

Faithful to the paper's structure:

* ``CONVERT_*`` handle conversion with fast paths for the predefined
  handles — comms keep the WORLD/SELF/NULL ``if`` chain of the §6.2 listing;
  ops and datatypes index **zero-page flat arrays** built once at init (the
  paper's "compile-time knowledge of both ABIs", materialized) — and a dict
  table for user (heap) handles only;
* an **O(1) reverse map** (impl handle → ABI handle) maintained at
  registration time, replacing a linear scan — callback trampolines hit this
  once per reduction element;
* return-code translation with an inlined success fast path
  (``RETURN_CODE_IMPL_TO_MUK``);
* **callback trampolines**: a user reduction op registered against the ABI
  is handed to the foreign implementation as a wrapper that converts
  IMPL-domain handles back to ABI-domain before invoking the user function;
* a **request map** associating temporary state (converted datatype-handle
  vectors for ``alltoallw``) with requests until completion — including the
  paper's worst case, ``testall`` scanning many outstanding requests;
* status-layout conversion (ompix's OMPI-style status → the standard
  32-byte status);
* capability answers for init-time negotiation: :meth:`MukBackend.supports`
  reports whether the foreign library exports an entry's symbol, so a
  missing entry point surfaces at ``pax_init`` rather than mid-step.

The measured claim (Table 1): this layer adds a small per-call overhead on
top of the implementation.  ``benchmarks/bench_message_rate.py`` reproduces
that measurement; the multidev battery checks semantics equivalence against
the native backend.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional, Sequence

import jax

from . import abi_spec
from . import handles as H
from .communicator import CommTable
from .datatypes import DatatypeRegistry
from .errors import (
    PAX_ERR_ARG,
    PAX_ERR_COMM,
    PAX_ERR_COUNT,
    PAX_ERR_INTERN,
    PAX_ERR_OP,
    PAX_ERR_PROC_FAILED,
    PAX_ERR_RANK,
    PAX_ERR_REVOKED,
    PAX_ERR_TYPE,
    PAX_ERR_UNSUPPORTED_OPERATION,
    ErrorTranslator,
    PaxError,
)
from .ops import OpRegistry
from .backends import ompix as ox
from .backends.base import Backend
from .status import Status


class MukBackend(Backend):
    """The ABI-side adapter: Backend interface in ABI handle domain,
    delegating to a foreign library through conversions."""

    convention = "foreign"
    name = "mukautuva"

    def __init__(self, lib: ox.OmpixLib, mesh: Optional[jax.sharding.Mesh] = None) -> None:
        super().__init__(mesh if mesh is not None else lib.mesh)
        self.lib = lib
        self.name = f"muk:{lib.name}"
        # loss capability crosses the ABI boundary with the lib (a wrapped
        # FaultyLib can drop; a plain foreign lib cannot) — the ABI uses it
        # to decide whether plan/group waits need the drop-sentinel guard
        self.can_lose_messages = bool(getattr(lib, "can_lose_messages", False))
        # ABI-domain tables owned by the context; Mukautuva keeps its own so
        # it can translate without asking the implementation anything.
        self.comms = CommTable(self.mesh)
        self.ops = OpRegistry()
        self.datatypes = DatatypeRegistry()
        # user-handle conversion tables (ABI handle -> impl object)
        self._comm_table: dict[int, ox.OmpixComm] = {}
        self._op_table: dict[int, ox.OmpixOp] = {}
        self._dtype_table: dict[int, ox.OmpixDatatype] = {}
        self._predef_ops = self._build_predef_op_map()
        self._predef_dtypes = self._build_predef_dtype_map()
        # The §6.2 "compile-time knowledge of both ABIs", materialized:
        # zero-page-indexed flat arrays built once at init, so a predefined
        # handle converts with one list index (no dict hashing, no if-chain).
        # The dict tables above remain the registration-time source of truth;
        # the user-handle dicts stay for heap handles only.
        self._predef_op_page: list = [None] * H.ZERO_PAGE_SIZE
        for _h, _obj in self._predef_ops.items():
            self._predef_op_page[_h] = _obj
        self._predef_dtype_page: list = [None] * H.ZERO_PAGE_SIZE
        for _h, _obj in self._predef_dtypes.items():
            self._predef_dtype_page[_h] = _obj
        # O(1) reverse conversion (impl dtype object -> ABI handle), kept in
        # sync at registration; first registration wins for aliased
        # predefined handles (PAX_CHAR and PAX_INT8_T both map to the impl's
        # int8 — the canonical fixed-size handle is registered first).
        self._dtype_rev: dict[ox.OmpixDatatype, int] = {}
        for abi_h, obj in self._predef_dtypes.items():
            self._dtype_rev.setdefault(obj, abi_h)
        self.errors = ErrorTranslator(
            {
                ox.OMPIX_ERR_ARG: PAX_ERR_ARG,
                ox.OMPIX_ERR_COMM: PAX_ERR_COMM,
                ox.OMPIX_ERR_TYPE: PAX_ERR_TYPE,
                ox.OMPIX_ERR_OP: PAX_ERR_OP,
                ox.OMPIX_ERR_UNSUPPORTED: PAX_ERR_UNSUPPORTED_OPERATION,
                ox.OMPIX_ERR_COUNT: PAX_ERR_COUNT,
                ox.OMPIX_ERR_RANK: PAX_ERR_RANK,
                ox.OMPIX_ERR_INTERN: PAX_ERR_INTERN,
                # fault-tier rc translation: a fault-injecting foreign lib
                # reports dead peers / revoked comms in its own numbering;
                # the ABI caller sees the standard ULFM-shaped classes.
                ox.OMPIX_ERR_PROC_FAILED: PAX_ERR_PROC_FAILED,
                ox.OMPIX_ERR_REVOKED: PAX_ERR_REVOKED,
            }
        )
        self.last_alltoallw_temps: Any = None
        self.last_status: Optional[Status] = None

    # ------------------------------------------------------------------
    # capability negotiation: does the foreign library export the symbol?
    # ------------------------------------------------------------------
    def supports(self, entry: abi_spec.AbiEntry) -> bool:
        return hasattr(self.lib, entry.impl_name)

    def capability(self, entry: abi_spec.AbiEntry) -> dict:
        """Translate capability info across the layer: the ABI-side report
        names the foreign symbol that was (or was not) resolved, so
        ``PaxABI.capabilities()`` distinguishes "the foreign library exports
        ``Allreduce`` behind the trampoline" from "the ABI layer emulated
        ``reduce`` because ``libompix`` has no ``Reduce`` symbol"."""
        info = {
            "backend": self.name,
            "native": self.supports(entry),
            "impl": self.lib.name,
            "impl_symbol": entry.impl_name,
        }
        if entry.persistent:
            info["group_hook"] = self.supports_persistent_group(entry)
        return info

    # -- fault model: the failure detector lives in the foreign library
    # (a fault-injecting lib reports its killed rank); quiet libs report
    # nothing and the fault tier stays a set of cheap no-ops.
    def local_failed(self, comm: int) -> tuple:
        fn = getattr(self.lib, "local_failed", None)
        return tuple(fn(comm)) if fn is not None else ()

    def heartbeat_silent(self, comm: int) -> tuple:
        fn = getattr(self.lib, "heartbeat_silent", None)
        return tuple(fn(comm)) if fn is not None else ()

    # ------------------------------------------------------------------
    # predefined-handle maps (the compile-time knowledge of both ABIs)
    # ------------------------------------------------------------------
    def _build_predef_op_map(self) -> dict[int, ox.OmpixOp]:
        g = self.lib.op_globals
        return {
            H.PAX_SUM: g["OMPIX_SUM"],
            H.PAX_MIN: g["OMPIX_MIN"],
            H.PAX_MAX: g["OMPIX_MAX"],
            H.PAX_PROD: g["OMPIX_PROD"],
            H.PAX_BAND: g["OMPIX_BAND"],
            H.PAX_BOR: g["OMPIX_BOR"],
            H.PAX_BXOR: g["OMPIX_BXOR"],
            H.PAX_LAND: g["OMPIX_LAND"],
            H.PAX_LOR: g["OMPIX_LOR"],
            H.PAX_LXOR: g["OMPIX_LXOR"],
            H.PAX_MINLOC: g["OMPIX_MINLOC"],
            H.PAX_MAXLOC: g["OMPIX_MAXLOC"],
            H.PAX_REPLACE: g["OMPIX_REPLACE"],
            H.PAX_NO_OP: g["OMPIX_NO_OP"],
        }

    def _build_predef_dtype_map(self) -> dict[int, ox.OmpixDatatype]:
        g = self.lib.dtype_globals
        m = {
            H.PAX_DATATYPE_NULL: g["OMPIX_DATATYPE_NULL"],
            H.PAX_INT8_T: g["OMPIX_INT8"],
            H.PAX_UINT8_T: g["OMPIX_UINT8"],
            H.PAX_CHAR: g["OMPIX_INT8"],
            H.PAX_SIGNED_CHAR: g["OMPIX_INT8"],
            H.PAX_UNSIGNED_CHAR: g["OMPIX_UINT8"],
            H.PAX_BYTE: g["OMPIX_BYTE"],
            H.PAX_INT16_T: g["OMPIX_INT16"],
            H.PAX_UINT16_T: g["OMPIX_UINT16"],
            H.PAX_FLOAT16: g["OMPIX_FLOAT16"],
            H.PAX_INT32_T: g["OMPIX_INT32"],
            H.PAX_UINT32_T: g["OMPIX_UINT32"],
            H.PAX_FLOAT32: g["OMPIX_FLOAT"],
            H.PAX_FLOAT: g["OMPIX_FLOAT"],
            H.PAX_INT64_T: g["OMPIX_INT64"],
            H.PAX_UINT64_T: g["OMPIX_UINT64"],
            H.PAX_FLOAT64: g["OMPIX_DOUBLE"],
            H.PAX_DOUBLE: g["OMPIX_DOUBLE"],
            H.PAX_INT: g["OMPIX_INT32"],
            H.PAX_LONG: g["OMPIX_INT64"],
            H.PAX_LONG_LONG: g["OMPIX_INT64"],
            H.PAX_SHORT: g["OMPIX_INT16"],
            H.PAX_UNSIGNED_SHORT: g["OMPIX_UINT16"],
            H.PAX_UNSIGNED_INT: g["OMPIX_UINT32"],
            H.PAX_UNSIGNED_LONG: g["OMPIX_UINT64"],
            H.PAX_UNSIGNED_LONG_LONG: g["OMPIX_UINT64"],
            H.PAX_AINT: g["OMPIX_INT64"],
            H.PAX_COUNT: g["OMPIX_INT64"],
            H.PAX_OFFSET: g["OMPIX_INT64"],
            H.PAX_COMPLEX64: g["OMPIX_COMPLEX64"],
            H.PAX_COMPLEX128: g["OMPIX_COMPLEX128"],
        }
        if "OMPIX_BFLOAT16" in g:
            m[H.PAX_BFLOAT16] = g["OMPIX_BFLOAT16"]
        return m

    # ------------------------------------------------------------------
    # CONVERT_* (paper §6.2 listing shape: predefined fast path, then table)
    # ------------------------------------------------------------------
    def _convert_comm(self, comm: int) -> ox.OmpixComm:
        # revoked-comm gate first: Mukautuva's comm table mirrors the ABI
        # CommTable, so revocation state lives there (one empty-set membership
        # test — the conversion below already hashes, this adds no lookup
        # class the path didn't have).  Fault-tier entries never convert
        # comms through here; they act on the ABI-side table directly.
        if comm in self.comms.revoked:
            raise PaxError(PAX_ERR_REVOKED, H.describe(comm))
        if comm == H.PAX_COMM_WORLD:
            return self.lib.comm_world
        if comm == H.PAX_COMM_SELF:
            return self.lib.comm_self
        if comm == H.PAX_COMM_NULL:
            return self.lib.comm_null
        try:
            return self._comm_table[comm]
        except KeyError:
            raise PaxError(PAX_ERR_COMM, H.describe(comm)) from None

    def _convert_op(self, op: int) -> ox.OmpixOp:
        if 0 <= op < H.ZERO_PAGE_SIZE:
            impl = self._predef_op_page[op]
            if impl is not None:
                return impl
            raise PaxError(PAX_ERR_OP, H.describe(op))  # reserved/null slot
        try:
            return self._op_table[op]
        except KeyError:
            raise PaxError(PAX_ERR_OP, H.describe(op)) from None

    def _convert_dtype(self, dt: int) -> ox.OmpixDatatype:
        if 0 <= dt < H.ZERO_PAGE_SIZE:
            impl = self._predef_dtype_page[dt]
            if impl is not None:
                return impl
            raise PaxError(PAX_ERR_TYPE, H.describe(dt))  # reserved slot
        try:
            return self._dtype_table[dt]
        except KeyError:
            raise PaxError(PAX_ERR_TYPE, H.describe(dt)) from None

    def _dtype_to_abi(self, impl_dt: ox.OmpixDatatype) -> int:
        """Reverse conversion, needed inside callback trampolines.  O(1):
        the reverse dict is maintained at registration time."""
        return self._dtype_rev.get(impl_dt, H.PAX_DATATYPE_NULL)

    def _rc(self, code: int) -> None:
        if code == 0:  # success fast path (inline)
            return
        raise PaxError(self.errors.to_abi(code), f"{self.lib.name} rc={code}")

    def _store_status(self, impl_status) -> None:
        """Status layout conversion (ompix §3.2.3 layout -> standard §5.2);
        the converted status is attached for the ABI layer / tools."""
        self.last_status = None
        if impl_status is not None:
            s = Status()
            s.SOURCE = impl_status["MPI_SOURCE"]
            s.TAG = impl_status["MPI_TAG"]
            s.ERROR = self.errors.to_abi(impl_status["MPI_ERROR"])
            s.set_reserved(0, impl_status["_cancelled"])
            s.set_reserved(1, impl_status["_ucount"] & 0x7FFFFFFF)
            self.last_status = s

    # ------------------------------------------------------------------
    # registration of ABI user handles with the foreign implementation
    # ------------------------------------------------------------------
    def register_comm(self, abi_handle: int, axes: Sequence[str]) -> None:
        code, impl = self.lib.Comm_from_axes(tuple(axes))
        self._rc(code)
        self._comm_table[abi_handle] = impl

    def register_op(self, abi_handle: int) -> None:
        desc = self.ops.descriptor(abi_handle)
        user_fn = desc.fn
        wants_dtype = len(inspect.signature(user_fn).parameters) >= 3

        # The callback trampoline (§6.2): the implementation invokes this with
        # ITS handles; we convert back to ABI handles before calling user code.
        def trampoline(a, b, impl_dtype=None):
            if wants_dtype:
                return user_fn(a, b, self._dtype_to_abi(impl_dtype))
            return user_fn(a, b)

        code, impl = self.lib.Op_create(trampoline, desc.commutative)
        self._rc(code)
        self._op_table[abi_handle] = impl

    def register_datatype(self, abi_handle: int, count: int, base: int) -> None:
        code, impl = self.lib.Type_contiguous(count, self._convert_dtype(base))
        self._rc(code)
        self._dtype_table[abi_handle] = impl
        self._dtype_rev.setdefault(impl, abi_handle)

    # ------------------------------------------------------------------
    # non-table handle queries used by native lowering helpers
    # ------------------------------------------------------------------
    def comm_axes(self, comm: int) -> tuple[str, ...]:
        return self._convert_comm(comm).axes

    def op_fn(self, op: int) -> Callable:
        return self._convert_op(op).fn

    def op_is_native(self, op: int) -> bool:
        return self._convert_op(op).is_native


# ---------------------------------------------------------------------------
# WRAP_* generation — one translation wrapper per function-table entry.
#
# Each argument's declared domain picks its CONVERT_*; the entry's return
# protocol picks the rc handling; ``temps`` entries stash their converted
# vectors for the request map (freed by ``PaxABI.wait``).
# ---------------------------------------------------------------------------
_CONVERT_EXPR = {
    abi_spec.OP: "self._convert_op({a})",
    abi_spec.COMM: "self._convert_comm({a})",
    abi_spec.DATATYPE: "self._convert_dtype({a})",
}


def _wrap_src(entry: abi_spec.AbiEntry) -> str:
    params = abi_spec.signature_src(entry)
    lines = [f"def {entry.backend_method}(self, {params}):"]
    impl_args = []
    vec_names = []
    for a in entry.args:
        if a.kind == abi_spec.DATATYPE_VEC:
            cname = f"_c_{a.name}"
            lines.append(
                f"    {cname} = tuple(self._convert_dtype(_t) for _t in {a.name})"
            )
            impl_args.append(cname)
            vec_names.append(cname)
        elif a.kind in _CONVERT_EXPR:
            impl_args.append(_CONVERT_EXPR[a.kind].format(a=a.name))
        else:
            impl_args.append(a.name)
    if entry.temps:
        # §6.2: converted handle vectors must stay alive until completion
        lines.append(f"    self.{entry.temps_attr} = ({', '.join(vec_names)},)")
    call = f"self.lib.{entry.impl_name}({', '.join(impl_args)})"
    if entry.muk_ret == "rc_only":
        lines.append(f"    _code = {call}")
        lines.append("    if _code:")
        lines.append("        self._rc(_code)")
        lines.append("    return None")
    elif entry.muk_ret == "status":
        lines.append(f"    _code, _v, _s = {call}")
        lines.append("    if _code:")
        lines.append("        self._rc(_code)")
        lines.append("    self._store_status(_s)")
        lines.append("    return _v")
    else:
        lines.append(f"    _code, _v = {call}")
        lines.append("    if _code:")
        lines.append("        self._rc(_code)")
        lines.append("    return _v")
    return "\n".join(lines) + "\n"


def _plan_src(entry: abi_spec.AbiEntry) -> str:
    """Generated persistent-plan hook: the WRAP_* wrapper with every
    conversion hoisted to plan time.

    Handle conversion (comm/op/dtype, including vectors) runs once when the
    plan is built; the returned run closure calls the foreign symbol with the
    cached IMPL-domain handles and only translates the return code per start.
    This is the Mukautuva half of the persistent-operations claim: the
    translation layer's per-call cost collapses to rc translation because
    its actual work — conversion — is plan-time."""
    params = abi_spec.signature_src(entry)
    payload_names = [a.name for a in entry.args if a.kind == abi_spec.PAYLOAD]
    lines = [f"def plan_{entry.backend_method}(self, {params}):"]
    impl_args = []
    vec_names = []
    for a in entry.args:
        if a.kind == abi_spec.DATATYPE_VEC:
            cname = f"_c_{a.name}"
            lines.append(
                f"    {cname} = tuple(self._convert_dtype(_t) for _t in {a.name})"
            )
            impl_args.append(cname)
            vec_names.append(cname)
        elif a.kind in _CONVERT_EXPR:
            cname = f"_c_{a.name}"
            lines.append(
                f"    {cname} = " + _CONVERT_EXPR[a.kind].format(a=a.name))
            impl_args.append(cname)
        else:
            impl_args.append(a.name)
    if entry.temps:
        # converted handle vectors stay alive for the plan's lifetime (the
        # ABI layer rides them in the plan's pooled request)
        lines.append(f"    self.{entry.temps_attr} = ({', '.join(vec_names)},)")
    lines.append(f"    _lib_fn = self.lib.{entry.impl_name}")
    lines.append("    _rc = self._rc")
    call = f"_lib_fn({', '.join(impl_args)})"
    lines.append(f"    def _run({', '.join(payload_names)}):")
    if entry.muk_ret == "rc_only":
        lines.append(f"        _code = {call}")
        lines.append("        if _code:")
        lines.append("            _rc(_code)")
        lines.append("        return None")
    elif entry.muk_ret == "status":
        lines.append(f"        _code, _v, _s = {call}")
        lines.append("        if _code:")
        lines.append("            _rc(_code)")
        lines.append("        self._store_status(_s)")
        lines.append("        return _v")
    else:
        lines.append(f"        _code, _v = {call}")
        lines.append("        if _code:")
        lines.append("            _rc(_code)")
        lines.append("        return _v")
    lines.append("    return _run")
    return "\n".join(lines) + "\n"


def _plan_group_src(entry: abi_spec.AbiEntry) -> str:
    """Generated plan-group hook (the ``Startall`` analogue of the WRAP_*
    layer): every member's handle conversion runs once at group-build time,
    and the fused run is one tight loop over the foreign symbol with the
    cached IMPL-domain argument tuples — per start, the translation layer
    pays N rc translations and nothing else.  Generated only for
    single-payload value-returning entries; the rest fall back to the ABI
    layer's per-member composition of the (also conversion-cached)
    ``plan_*`` hooks."""
    names = [a.name for a in entry.args]
    frozen_exprs = []
    for a in entry.args:
        if a.kind == abi_spec.PAYLOAD:
            continue
        if a.kind == abi_spec.DATATYPE_VEC:
            frozen_exprs.append(
                f"tuple(self._convert_dtype(_t) for _t in {a.name})")
        elif a.kind in _CONVERT_EXPR:
            frozen_exprs.append(_CONVERT_EXPR[a.kind].format(a=a.name))
        else:
            frozen_exprs.append(a.name)
    lines = [
        f"def plan_group_{entry.backend_method}(self, bounds):",
        f"    _lib_fn = self.lib.{entry.impl_name}",
        "    _rc = self._rc",
        "    _frozen = []",
        "    for _b in bounds:",
        f"        ({', '.join(names)},) = _b",
        f"        _frozen.append(({', '.join(frozen_exprs)},))",
        "    def _run(_payloads):",
        "        _out = []",
        "        _append = _out.append",
        "        for _x, _f in zip(_payloads, _frozen):",
        "            _code, _v = _lib_fn(_x, *_f)",
        "            if _code:",
        "                _rc(_code)",
        "            _append(_v)",
        "        return _out",
        "    return _run",
    ]
    return "\n".join(lines) + "\n"


def _install_generated_wraps() -> None:
    for entry in abi_spec.ABI_TABLE:
        fn = abi_spec.compile_method(_wrap_src(entry), {}, entry.backend_method)
        fn.__qualname__ = f"MukBackend.{entry.backend_method}"
        fn.__doc__ = f"Generated WRAP_{entry.impl_name} (paper §6.2)."
        setattr(MukBackend, entry.backend_method, fn)
        if entry.persistent:
            pfn = abi_spec.compile_method(
                _plan_src(entry), {}, f"plan_{entry.backend_method}")
            pfn.__qualname__ = f"MukBackend.plan_{entry.backend_method}"
            pfn.__doc__ = (
                f"Generated persistent WRAP_{entry.impl_name}: foreign-handle "
                "conversion cached at plan time (paper §6.2, MPI-4 _init)."
            )
            setattr(MukBackend, f"plan_{entry.backend_method}", pfn)
            if (entry.payload_args == (0,) and not entry.temps
                    and entry.muk_ret == "value"):
                gfn = abi_spec.compile_method(
                    _plan_group_src(entry), {},
                    f"plan_group_{entry.backend_method}")
                gfn.__qualname__ = (
                    f"MukBackend.plan_group_{entry.backend_method}")
                gfn.__doc__ = (
                    f"Generated group WRAP_{entry.impl_name}: every member's "
                    "foreign-handle conversion cached at group-build time; "
                    "the fused run is one loop of foreign calls plus rc "
                    "translation (MPI Startall, PR 5)."
                )
                setattr(MukBackend, f"plan_group_{entry.backend_method}", gfn)


_install_generated_wraps()


# Fault-tier exception to the generated table (installed after it, on
# purpose): a shrunk survivor communicator is an ABI-side construct — the
# foreign implementation has no ULFM and sees only the parent axes, so its
# Comm_size answers the *full* extent.  Group-membership queries for comms
# with exclusions are therefore answered from Mukautuva's mirrored ABI
# table; comms without exclusions keep the generated foreign path.
_generated_comm_size = MukBackend.size  # comm_size's backend_method


def _comm_size_excludes_aware(self, comm):
    info = self.comms.info(comm)
    if info.excludes:
        return info.size
    return _generated_comm_size(self, comm)


_comm_size_excludes_aware.__name__ = "size"
_comm_size_excludes_aware.__qualname__ = "MukBackend.size"
# the override *wraps* the generated foreign path; keep its provenance
_comm_size_excludes_aware.__generated_src__ = \
    _generated_comm_size.__generated_src__
MukBackend.size = _comm_size_excludes_aware
