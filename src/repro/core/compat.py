"""Version-compat shims for the small jax API surface the ABI layer uses.

The repo targets the modern jax API (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types=``) but must also run on older releases where shard_map
lives in ``jax.experimental`` and meshes have no axis types.  Exactly the
spirit of the source paper: one stable calling convention, negotiated
against whatever implementation is present at runtime.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")

if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        """Static axis size on older jax: psum of the literal 1 constant-
        folds to the bound axis size at trace time."""
        return jax.lax.psum(1, axis_name)


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(shape), tuple(names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(names)),
        )
    return jax.make_mesh(tuple(shape), tuple(names))


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs,
                  axis_names: Optional[Sequence[str]] = None,
                  check_vma: bool = False):
        kwargs = {"check_vma": check_vma}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs,
                  axis_names: Optional[Sequence[str]] = None,
                  check_vma: bool = False):
        # older API: axes are manual unless listed in ``auto``
        auto = frozenset()
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _shard_map(
            f, mesh, in_specs, out_specs, check_rep=check_vma, auto=auto
        )
