"""Backend discovery and context initialization — the ``dlopen``/``dlsym``
analogue (paper §6.2: "the first shared library determines which
implementation will be used, and activates it via dlopen and dlsym").

Selection order: explicit ``impl=`` argument, else ``PAX_ABI_IMPL``
environment variable, else the native default ``paxi`` — mirroring how
Mukautuva picks the IMPL shared object at runtime.

``pax_init`` is the ``dlopen`` half; the ``dlsym`` half is performed by
``PaxABI.__init__``, which *negotiates* the declarative function table
(:mod:`repro.core.abi_spec`) against the resolved backend: every entry
point is looked up once, and a backend missing one raises
``PAX_ERR_UNSUPPORTED_OPERATION`` here at init, never mid-step.

Names:

* ``paxi``       — native ABI implementation (zero-overhead path, §6.3);
* ``ring``       — second native implementation, explicit ring schedules;
* ``ring-int8`` / ``ring-bf16`` — ring with wire compression;
* ``ompix``      — foreign implementation, automatically wrapped in the
  Mukautuva translation layer (§6.2);
* ``muk:paxi``   — the trampoline wrapped around a *native* library:
  isolates pure translation-layer overhead (the "+ Mukautuva" rows of
  Table 1);
* ``minimal``    — deliberately-partial native implementation (handle
  queries + sendrecv/reduce_scatter/allgather); every other entry point is
  synthesized by tiered negotiation from the spec's emulation recipes.
"""
from __future__ import annotations

import os
from typing import Callable, Optional, Sequence

import jax

from .abi import PaxABI
from .backends.base import Backend
from .backends.minimal import MinimalBackend
from .backends.ompix import OmpixLib
from .backends.paxi import PaxiBackend
from .backends.ring import RingBackend
from .mukautuva import MukBackend

ENV_VAR = "PAX_ABI_IMPL"
DEFAULT_IMPL = "paxi"

_FACTORIES: dict[str, Callable[[Optional[jax.sharding.Mesh]], Backend]] = {}


def register_backend(name: str, factory: Callable) -> None:
    _FACTORIES[name] = factory


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_FACTORIES))


def _muk_paxi(mesh):
    """Mukautuva over a native library: adapt paxi to the foreign protocol
    so the full conversion path runs with identity conversions."""
    from .backends import ompix as ox

    class _PaxiAsForeign(OmpixLib):
        name = "paxi"

    return MukBackend(_PaxiAsForeign(mesh), mesh)


register_backend("paxi", lambda mesh: PaxiBackend(mesh))
register_backend("ring", lambda mesh: RingBackend(mesh))
register_backend("ring-int8", lambda mesh: RingBackend(mesh, compress="int8"))
register_backend("ring-bf16", lambda mesh: RingBackend(mesh, compress="bf16"))
register_backend("ompix", lambda mesh: MukBackend(OmpixLib(mesh), mesh))
register_backend("muk:paxi", _muk_paxi)
register_backend("minimal", lambda mesh: MinimalBackend(mesh))


def get_backend(name: str, mesh: Optional[jax.sharding.Mesh] = None) -> Backend:
    # Fault injection composes by prefix, NOT by factory registration: the
    # battery's available_backends() sweep must never meet a booby-trapped
    # backend by accident.  "faulty:<inner>" wraps the inner backend with
    # the kill schedule from PAX_FAULT_SCHEDULE (see backends/faulty.py);
    # the foreign ompix path wraps the *library* instead, so the injected
    # failure crosses Mukautuva as a translated rc.
    if name.startswith("faulty:"):
        from .backends.faulty import FaultSchedule, FaultyBackend, FaultyLib

        inner_name = name[len("faulty:"):]
        schedule = FaultSchedule.from_env()
        if inner_name == "ompix":
            return MukBackend(FaultyLib(OmpixLib(mesh), schedule), mesh)
        return FaultyBackend(get_backend(inner_name, mesh), schedule)
    try:
        factory = _FACTORIES[name]
    except KeyError:
        raise ValueError(
            f"unknown PAX ABI implementation {name!r}; available: {available_backends()}"
        ) from None
    return factory(mesh)


def pax_init(
    mesh: Optional[jax.sharding.Mesh] = None,
    impl: Optional[str] = None,
    tools: Sequence = (),
    req_slot_bits: Optional[int] = None,
    integrity: Optional[bool] = None,
) -> PaxABI:
    """``MPI_Init`` analogue: resolve the implementation, build the context.

    The returned :class:`PaxABI` is the only object user code needs; user
    code never sees backend-domain handles, so the implementation can be
    swapped per-init without re-tracing anything built on the ABI.
    ``req_slot_bits`` sets this context's request-pool slot/generation split
    (slots = outstanding-request cap; generations are unbounded above).
    ``integrity`` opts the context into the end-to-end checksummed-wire mode
    (default: the ``PAX_WIRE_INTEGRITY`` environment variable).

    ``impl`` may also be a prebuilt :class:`Backend` instance (a composed
    fault-injection wrapper, a backend with a pre-armed kill schedule...);
    it is used as-is, skipping name resolution.
    """
    if isinstance(impl, Backend):
        return PaxABI(impl, mesh=mesh, tools=tools,
                      req_slot_bits=req_slot_bits, integrity=integrity)
    name = impl or os.environ.get(ENV_VAR, DEFAULT_IMPL)
    backend = get_backend(name, mesh)
    return PaxABI(backend, mesh=mesh, tools=tools,
                  req_slot_bits=req_slot_bits, integrity=integrity)
