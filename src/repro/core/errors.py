"""Error codes and error classes of the PAX ABI.

``PAX_SUCCESS`` is 0 (the MPI requirement the paper leans on for the
translation fast path: *"success is the common case, so static inline it"*
— §6.2 Mukautuva listing, ``RETURN_CODE_IMPL_TO_MUK``).

Error *classes* are small positive ints below ``PAX_INT_CONSTANT_MAX``.
Foreign backends (``backends/ompix.py``) use their own numbering; the
Mukautuva layer translates through :func:`ErrorTranslator.to_abi` with the
same shape as the paper's listing: a ``static inline`` zero check followed by
an out-of-line table lookup.
"""
from __future__ import annotations

from typing import Mapping

PAX_SUCCESS = 0
PAX_ERR_BUFFER = 1
PAX_ERR_COUNT = 2
PAX_ERR_TYPE = 3
PAX_ERR_TAG = 4
PAX_ERR_COMM = 5
PAX_ERR_RANK = 6
PAX_ERR_REQUEST = 7
PAX_ERR_ROOT = 8
PAX_ERR_GROUP = 9
PAX_ERR_OP = 10
PAX_ERR_TOPOLOGY = 11
PAX_ERR_DIMS = 12
PAX_ERR_ARG = 13
PAX_ERR_UNKNOWN = 14
PAX_ERR_TRUNCATE = 15
PAX_ERR_OTHER = 16
PAX_ERR_INTERN = 17
PAX_ERR_PENDING = 18
PAX_ERR_IN_STATUS = 19
PAX_ERR_KEYVAL = 20
PAX_ERR_NO_MEM = 21
PAX_ERR_INFO = 22
PAX_ERR_UNSUPPORTED_OPERATION = 23
# Fault tier (ULFM-style, "The Case for ABI Interoperability in a Fault
# Tolerant MPI"): a peer process is known dead / the communicator has been
# revoked.  Below PAX_ERR_LASTCODE like every other class; backends that
# lack the fault symbols never return these (the ABI's recipes raise them).
PAX_ERR_PROC_FAILED = 24
PAX_ERR_REVOKED = 25
# Transport-integrity tier (PR 10): the wire itself misbehaving, short of a
# rank death.  DATA_CORRUPTION is raised when the opt-in end-to-end integrity
# mode (checksummed plan/group closures, ``PaxABI(integrity=True)``) detects
# a payload that does not agree across the communicator; TIMEOUT is raised by
# the ``wait`` family when a ``timeout_s`` deadline passes before a dropped
# operation completes — the only way a *drop* (a hang, not an error) ever
# surfaces.  Both are below PAX_ERR_LASTCODE like every other class.
PAX_ERR_DATA_CORRUPTION = 26
PAX_ERR_TIMEOUT = 27
PAX_ERR_LASTCODE = 64

_ERROR_NAMES = {
    value: name
    for name, value in list(globals().items())
    if name.startswith("PAX_ERR_") or name == "PAX_SUCCESS"
}


def error_string(code: int) -> str:
    """``MPI_Error_string`` analogue."""
    return _ERROR_NAMES.get(code, f"PAX_ERR_UNKNOWN({code})")


class PaxError(RuntimeError):
    """Raised where C MPI would return a nonzero error code.

    The ABI surface (``core/abi.py``) converts backend error codes into this
    exception when the installed error handler is ``PAX_ERRORS_ARE_FATAL``
    (the default, as in MPI on PAX_COMM_WORLD-equivalents), and returns codes
    when it is ``PAX_ERRORS_RETURN``.
    """

    def __init__(self, code: int, detail: str = "") -> None:
        self.code = code
        msg = error_string(code)
        if detail:
            msg = f"{msg}: {detail}"
        super().__init__(msg)


class IncompleteValue:
    """Sentinel standing in for the result of an operation that will never
    complete: a *dropped* message (``FaultSchedule`` mode ``drop``).

    A drop is a hang, not an error — no backend return code carries it, so
    the injection layer plants this sentinel as the operation's value and the
    ``wait`` family is the only place it is ever observed: ``wait`` with a
    ``timeout_s`` sleeps out the deadline and raises
    :data:`PAX_ERR_TIMEOUT`; ``wait`` without one blocks forever (the
    faithful semantics).  The request stays *active* across the timeout so
    ``Plan.reset``/``PlanGroup.reset`` can abort and re-arm the slot.
    """

    __slots__ = ("detail",)

    def __init__(self, detail: str = "") -> None:
        self.detail = detail

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"IncompleteValue({self.detail!r})"

    def __getitem__(self, _key):
        # Recipe post-processing slices dependency outputs (drop the invented
        # padding, unwrap a scalar); an incomplete result stays incomplete
        # through any such slice so composed emulation chains propagate the
        # sentinel to the wait that will time it out.
        return self


class ErrorTranslator:
    """IMPL→ABI error-code translation (paper §6.2 listing).

    The zero fast path is inlined at every call site by construction (a
    Python ``if`` — the analogue of the paper's ``static inline`` wrapper);
    the table lookup happens only on errors.
    """

    def __init__(self, impl_to_abi: Mapping[int, int]) -> None:
        if any(k == 0 for k in impl_to_abi):
            raise ValueError("0 is PAX_SUCCESS in every convention")
        self._table = dict(impl_to_abi)

    def to_abi(self, impl_code: int) -> int:
        if impl_code == 0:  # success fast path
            return PAX_SUCCESS
        return self._table.get(impl_code, PAX_ERR_UNKNOWN)
