"""PMPI/QMPI-style tool interposition (paper §4.8).

Tools intercept every ABI call *once, against the ABI* — and therefore work
with every backend, which is precisely the ecosystem benefit §4.8 claims a
standard ABI delivers for performance/debugging tools.  Multiple tools stack
(the P^nMPI / QMPI multi-instrumentation model): ``before`` hooks run
outer→inner, ``after`` hooks inner→outer and may transform the result.

Tools may stash state in the status object's reserved fields — the slack the
standard status layout (§5.2) deliberately provides ("the proposed status
object ... has additional space that allows tools to hide state in the
reserved fields").
"""
from __future__ import annotations

import collections
import time
from typing import Any, Optional

from .status import Status


class Tool:
    """Base interposition tool.  Subclass and override hooks."""

    tool_id = 0

    def attach(self, abi) -> None:
        self.abi = abi

    def before(self, fname: str, args: tuple, info: dict) -> None:  # noqa: D401
        pass

    def after(self, fname: str, args: tuple, info: dict, result: Any) -> Any:
        return result

    def annotate_status(self, status: Optional[Status], seq: int) -> None:
        """Hide tool state in the reserved slack (§4.8/§5.2)."""
        if status is not None:
            status.set_reserved(0, self.tool_id)
            status.set_reserved(1, seq & 0x7FFFFFFF)


class CallCounter(Tool):
    """Counts ABI calls by function name."""

    tool_id = 1

    def __init__(self) -> None:
        self.counts: collections.Counter[str] = collections.Counter()

    def before(self, fname, args, info):
        self.counts[fname] += 1

    def reset(self) -> None:
        self.counts.clear()


class ByteCounter(Tool):
    """Tallies collective payload bytes per function — the tool-side ledger
    that EXPERIMENTS.md §Roofline cross-checks against HLO-parsed collective
    bytes."""

    tool_id = 2

    def __init__(self) -> None:
        self.bytes: collections.Counter[str] = collections.Counter()
        self.calls: collections.Counter[str] = collections.Counter()

    def before(self, fname, args, info):
        b = info.get("bytes")
        if b:
            self.bytes[fname] += int(b)
            self.calls[fname] += 1

    def total(self) -> int:
        return sum(self.bytes.values())

    def reset(self) -> None:
        self.bytes.clear()
        self.calls.clear()


class WallClockTracer(Tool):
    """Records (fname, t_ns) pairs of host-side dispatch; the message-rate
    benchmark uses it to attribute per-call overhead.

    Timer state is a per-tool LIFO stack of start times: ``before``/``after``
    pairs nest like the dispatch chain itself, so the stack is exact for
    nested ABI calls, never keys on reusable ``id()`` values, and cannot
    accumulate stale entries (an aborted call's start is popped by the next
    completed ``after`` instead of leaking forever)."""

    tool_id = 3

    def __init__(self, max_events: int = 100000) -> None:
        self.events: list[tuple[str, int]] = []
        self._starts: list[int] = []
        self._max = max_events

    def before(self, fname, args, info):
        self._starts.append(time.perf_counter_ns())

    def after(self, fname, args, info, result):
        if self._starts:
            t0 = self._starts.pop()
            if len(self.events) < self._max:
                self.events.append((fname, time.perf_counter_ns() - t0))
        return result


class SequenceStamper(Tool):
    """Demonstrates tool state hidden in reserved status fields: stamps a
    monotonically increasing sequence number into every status it is handed
    via ``stamp``."""

    tool_id = 4

    def __init__(self) -> None:
        self.seq = 0

    def before(self, fname, args, info):
        self.seq += 1

    def stamp(self, status: Status) -> None:
        self.annotate_status(status, self.seq)
