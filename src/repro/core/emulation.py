"""Emulation recipe builders — synthesizing missing entry points from present ones.

The paper's translation layer works because one standard function table can
front many *unequal* implementations: Mukautuva forwards to whatever the
loaded MPI actually provides and papers over the rest.  This module is the
"papers over" half for the ABI layer itself: every builder here compiles one
missing function-table entry out of entries the backend *does* resolve — the
resolve-and-extend pattern MPICH uses to prototype new entry points over its
existing device layer.

Each ``build_*`` function receives an :class:`EmulationContext` and returns a
closure with the entry's backend-method signature.  The closure captures the
**resolved** dependency callables (native methods or previously-built
emulations — :func:`repro.core.abi_spec.validate_table` guarantees the
dependency order is acyclic and topologically sorted), so emulated entries
chain: on a backend exporting only ``sendrecv/reduce_scatter/allgather``,
``scatter`` resolves as ``scatter -> bcast -> allreduce -> (reduce_scatter,
allgather)`` — three recipes deep, grounding out in native entries.

The closures are installed in ``PaxABI._table`` exactly like native
callables, so ``PaxABI._specialize`` compiles the same per-context inline
fast path around them and interposition tools observe emulated calls exactly
as they observe native ones (one ``before``/``after`` pair for the top-level
entry; the internal dependency calls are direct, not re-interposed).

Wire-semantics notes:

* ``allreduce`` pads the leading axis to a multiple of the communicator size
  and composes reduce-scatter with all-gather (forward/reverse axis order, so
  chunk index == linearized rank); padding rows are reduced and then sliced
  off, which is correct for *any* reduction op because padding adds rows,
  never extra rank contributions.
* ``barrier`` is an all-reduce of a one-element buffer (the zero-byte
  ``ibarrier``-from-``iallreduce`` idiom, rounded up to one element so the
  wire op is well-formed).  Unlike a native barrier it carries no
  optimization-barrier fence, so a scheduler may elide it when nothing
  consumes it — emulation preserves the collective's semantics, not its
  scheduling side effects.
* ``scan``/``exscan`` gather every rank's contribution in linearized rank
  order and fold locally; the exscan convention (rank 0 keeps its input
  unchanged) matches the native backends.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

import functools

from . import handles as H
from .errors import PAX_ERR_PROC_FAILED, IncompleteValue, PaxError


def _incomplete_passthrough(fn: Callable) -> Callable:
    """Propagate the drop sentinel through recipe composition.

    A dropped dependency (``FaultSchedule`` mode ``drop``) yields an
    :class:`IncompleteValue` instead of an array; every downstream stage of
    an emulation chain must hand it through untouched so the sentinel
    reaches the plan/pooled wait — the only layer allowed to observe it
    (and time it out).  Mirrors the injection layer's own argument scan.
    """

    @functools.wraps(fn)
    def run(*args, **kwargs):
        for a in args:
            if a.__class__ is IncompleteValue:
                return a
        return fn(*args, **kwargs)

    return run


class EmulationContext:
    """What a recipe may close over: resolved entries + backend handle queries.

    Deliberately narrow — recipes express entries in terms of *other entries*
    (plus the two non-table handle queries every backend must answer), never
    in terms of backend internals, so one recipe works across paxi-convention
    and Mukautuva-translated backends alike.
    """

    def __init__(self, abi) -> None:
        self._abi = abi

    def dep(self, name: str) -> Callable:
        """The resolved callable for entry ``name`` (native or emulated).

        Forces a lazily-deferred dependency recipe to build now (building a
        recipe implies building everything it stands on), so built closures
        always chain through concrete callables, never through lazy shims.
        """
        return _incomplete_passthrough(self._abi._ensure_built(name))

    def op_fn(self, op: int) -> Callable:
        return self._abi.backend.op_fn(op)

    def lowering_width(self, comm: int) -> int:
        """The width the single-controller lowering runs ``comm`` at: the
        full rank space of its axes.  Excluded ranks still participate in
        the lax lowering (a shrunk comm *names* the survivor group; the
        mesh underneath is unchanged), so recipes that SPLIT payloads
        across the wire — reduce-scatter chunks, allgather rejoins — must
        split by this, never by the membership count ``comm_size``.  The
        two agree on every un-shrunk comm; they differ exactly when a
        recovery rebuilt plans on a shrink survivor (PR 9's serving
        recovery does this for the decode-tp group)."""
        return self._abi.comms.info(comm).full_size

    @property
    def datatypes(self):
        return self._abi.datatypes

    # -- fault-tier accessors (ULFM recipes) --------------------------------
    # The fault entries are the one recipe family that may reach past the
    # entry table into the shared CommTable: they must operate on *revoked*
    # communicators (the ULFM contract), and every plain entry — including
    # `comm_size` — raises PAX_ERR_REVOKED there by design.
    @property
    def comms(self):
        return self._abi.comms

    def local_failed(self, comm: int) -> tuple:
        """Ranks the backend knows dead on ``comm`` (fault injection hook)."""
        return tuple(self._abi.backend.local_failed(comm))

    def register_shrunk(self, parent: int, excludes, name: str = "") -> int:
        """Register the shrink survivor comm; mirror it into foreign libs."""
        new = self._abi.comms.register_shrunk(parent, excludes, name)
        reg = getattr(self._abi.backend, "register_comm", None)
        if reg is not None:  # foreign convention: keep the impl table in sync
            reg(new, self._abi.comms.info(new).axes)
        return new


class PlanContext(EmulationContext):
    """What a recipe *plan* builder may close over.

    ``plan_dep`` compiles a dependency into its own frozen run closure (the
    backend's native plan hook, the dependency's recipe plan, or generic
    argument freezing — see ``PaxABI._plan_run``), so an emulated plan is a
    composition of bare closures: every chain decision — padding geometry,
    slice bounds, axes, op branch — is taken once at plan time.  Payload
    arguments are passed as abstract shapes (``jax.ShapeDtypeStruct``); plan
    builders may inspect ``.shape``/``.dtype``/``.ndim`` only, never values.
    """

    def plan_dep(self, name: str, *bound) -> Callable:
        return _incomplete_passthrough(self._abi._plan_run(name, bound))

    def plan_group_dep(self, name: str, bounds) -> Callable:
        """Compile one *fused* run closure for a whole stage of a plan
        group: ``bounds`` is a list of bound-argument tuples (one per
        member) and the returned closure maps a payload list to an output
        list.  Resolution mirrors the ABI layer's group compiler — backend
        group hook, recipe group stage, or a per-member loop — so a recipe
        group builder composes stages that are themselves stacked
        collectives whenever the backend can fuse them."""
        return self._abi._plan_group_run(name, bounds)

    def wire_block(self) -> int:
        """The backend's preferred padding granule
        (:meth:`Backend.wire_pad_multiple`): recipe plans that invent
        padding round up to a multiple of this so the padded legs stay on
        the backend's fast wire (e.g. the ring backend's fused Pallas hop
        kernels need WIRE_BLOCK-divisible chunks).  The extra zeros are
        reduced and sliced off like any padding — numerics unchanged."""
        return max(1, int(self._abi.backend.wire_pad_multiple()))


def _tag(fn: Callable, name: str, deps: tuple) -> Callable:
    fn.__name__ = name
    fn.__qualname__ = f"emulated.{name}"
    fn.__emulated__ = True
    fn.__emulated_deps__ = tuple(deps)
    return fn


def prefix_fold(g, r, fn: Callable, x, inclusive: bool):
    """The shared scan/exscan kernel: fold gathered contributions ``g``
    (leading axis = linearized communicator rank) into this rank's prefix.

    One definition serves both the native lowering (``_lax.scan_fold``) and
    the emulation recipe, so the ABI-wide exscan convention — rank 0 keeps
    its input ``x`` unchanged (MPI: undefined) — cannot silently diverge
    between native and emulated backends.
    """
    if g.__class__ is IncompleteValue:  # dropped gather: stay incomplete
        return g
    S = g.shape[0]
    acc = g[0]
    out = acc if inclusive else x
    for j in range(1, S):
        prev = acc
        acc = fn(prev, g[j])
        out = jnp.where(r == j, acc if inclusive else prev, out)
    return out


def masked_agree_fold(contribs, alive):
    """The shared ULFM-agree kernel: bitwise-AND fold over the per-rank
    contributions ``contribs``, masked by the survivor vector ``alive`` —
    dead ranks contribute the AND identity (all ones), i.e. are skipped.

    This is the single-controller collapse of agree's masked allreduce-AND
    (the same replication argument as ``build_reduce``: in SPMD every rank
    holds the controller's view, so the wire reduction folds locally).  One
    definition serves the native paxi hook and the emulation recipe, so the
    agreement value cannot diverge between native and emulated backends.
    """
    acc = None
    for c, a in zip(contribs, alive):
        if not a:
            continue
        acc = c if acc is None else acc & c
    if acc is None:
        raise PaxError(PAX_ERR_PROC_FAILED, "agree with no surviving ranks")
    return acc


def comm_failure_view(comms, local_failed, comm: int):
    """Shared fault-entry bookkeeping: the comm's info (revocation allowed),
    the known-failed *member* set, and the acknowledged subset.  Ranks
    already excluded from the group (a shrunk comm) are non-members, not
    failures — ULFM's shrink result reports no failed procs — so the
    backend-reported failure set is intersected with the membership.  Used
    by both the native paxi hooks and the emulation recipes so their
    failure model is one definition."""
    info = comms.info(comm, allow_revoked=True)
    failed = frozenset(local_failed(comm)) - frozenset(info.excludes)
    return info, failed, comms.acked.get(comm, frozenset())


def agree_value(comms, local_failed, flag, comm: int):
    """ULFM agree semantics over the single-controller view: raise
    PAX_ERR_PROC_FAILED while unacknowledged failures exist, else fold the
    masked AND over surviving contributions (all equal to ``flag`` — SPMD)."""
    info, failed, acked = comm_failure_view(comms, local_failed, comm)
    pending = failed - acked
    if pending:
        raise PaxError(
            PAX_ERR_PROC_FAILED,
            f"comm_agree with unacknowledged failed ranks {sorted(pending)} "
            f"on {info.name or hex(comm)}",
        )
    full = info.full_size
    return masked_agree_fold([flag] * full,
                             [r not in failed for r in range(full)])


def build_comm_revoke(ctx: EmulationContext) -> Callable:
    comms = ctx.comms

    def comm_revoke(comm):
        comms.revoke(comm)
        return None

    return _tag(comm_revoke, "comm_revoke", ())


def build_comm_failure_ack(ctx: EmulationContext) -> Callable:
    comms, local_failed = ctx.comms, ctx.local_failed

    def comm_failure_ack(comm):
        _, failed, acked = comm_failure_view(comms, local_failed, comm)
        comms.acked[comm] = acked | failed
        return None

    return _tag(comm_failure_ack, "comm_failure_ack", ())


def build_comm_get_failed(ctx: EmulationContext) -> Callable:
    comms, local_failed = ctx.comms, ctx.local_failed

    def comm_get_failed(comm):
        _, failed, _ = comm_failure_view(comms, local_failed, comm)
        return tuple(sorted(failed))

    return _tag(comm_get_failed, "comm_get_failed", ())


def build_comm_agree(ctx: EmulationContext) -> Callable:
    comms, local_failed = ctx.comms, ctx.local_failed

    def comm_agree(flag, comm):
        return agree_value(comms, local_failed, flag, comm)

    return _tag(comm_agree, "comm_agree", ())


def build_comm_shrink(ctx: EmulationContext) -> Callable:
    agree, get_failed = ctx.dep("comm_agree"), ctx.dep("comm_get_failed")
    comms, local_failed = ctx.comms, ctx.local_failed

    def comm_shrink(comm):
        # ULFM shrink = implicit ack of the known failures, agreement on the
        # failure set (as a rank bitmask through agree's AND fold — identical
        # contributions join trivially in the single-controller view), then
        # dense survivor-comm registration.
        _, failed, acked = comm_failure_view(comms, local_failed, comm)
        comms.acked[comm] = acked | failed
        mask = 0
        for r in failed:
            mask |= 1 << r
        agreed = agree(mask, comm)
        info = comms.info(comm, allow_revoked=True)
        excludes = [r for r in range(info.full_size) if (agreed >> r) & 1]
        assert sorted(excludes) == sorted(get_failed(comm))
        return ctx.register_shrunk(comm, excludes)

    return _tag(comm_shrink, "comm_shrink", ("comm_agree", "comm_get_failed"))


def build_allreduce(ctx: EmulationContext) -> Callable:
    rs, ag = ctx.dep("reduce_scatter"), ctx.dep("allgather")
    width = ctx.lowering_width

    def allreduce(x, op, comm):
        S = width(comm)  # split by the lowering width (see lowering_width)
        if S <= 1:
            return x
        scalar = getattr(x, "ndim", 0) == 0
        if scalar:
            x = jnp.reshape(x, (1,))
        n = x.shape[0]
        pad = (-n) % S
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
            )
        out = ag(rs(x, op, comm), comm)[:n]
        return out[0] if scalar else out

    return _tag(allreduce, "allreduce", ("reduce_scatter", "allgather", "comm_size"))


def build_reduce(ctx: EmulationContext) -> Callable:
    ar = ctx.dep("allreduce")

    def reduce(x, op, root, comm):
        # SPMD: computed everywhere, defined at root (the MPI contract).
        return ar(x, op, comm)

    return _tag(reduce, "reduce", ("allreduce",))


def build_bcast(ctx: EmulationContext) -> Callable:
    ar, rank = ctx.dep("allreduce"), ctx.dep("comm_rank")

    def bcast(x, root, comm):
        r = rank(comm)
        return ar(jnp.where(r == root, x, jnp.zeros_like(x)), H.PAX_SUM, comm)

    return _tag(bcast, "bcast", ("allreduce", "comm_rank"))


def build_barrier(ctx: EmulationContext) -> Callable:
    ar = ctx.dep("allreduce")

    def barrier(comm):
        ar(jnp.zeros((1,), jnp.float32), H.PAX_SUM, comm)
        return None

    return _tag(barrier, "barrier", ("allreduce",))


def _build_scan(ctx: EmulationContext, inclusive: bool, name: str) -> Callable:
    ag, rank, size = ctx.dep("allgather"), ctx.dep("comm_rank"), ctx.dep("comm_size")
    op_fn = ctx.op_fn

    def scan(x, op, comm):
        S = size(comm)
        if S <= 1:
            return x
        g = ag(x[None], comm)  # (S, *x.shape), linearized rank order
        return prefix_fold(g, rank(comm), op_fn(op), x, inclusive)

    return _tag(scan, name, ("allgather", "comm_rank", "comm_size"))


def build_scan(ctx: EmulationContext) -> Callable:
    return _build_scan(ctx, inclusive=True, name="scan")


def build_exscan(ctx: EmulationContext) -> Callable:
    return _build_scan(ctx, inclusive=False, name="exscan")


def build_alltoall(ctx: EmulationContext) -> Callable:
    ag, rank, size = ctx.dep("allgather"), ctx.dep("comm_rank"), ctx.dep("comm_size")

    def alltoall(x, comm, split_axis=0, concat_axis=0):
        S = size(comm)
        if S <= 1:
            return x
        if x.shape[split_axis] % S:
            raise ValueError(
                f"alltoall split axis {split_axis} (length "
                f"{x.shape[split_axis]}) not divisible by comm size {S}"
            )
        blk = x.shape[split_axis] // S
        g = ag(x[None], comm)  # (S, *x.shape)
        mine = lax.dynamic_slice_in_dim(g, rank(comm) * blk, blk,
                                        axis=split_axis + 1)
        return jnp.concatenate([mine[j] for j in range(S)], axis=concat_axis)

    return _tag(alltoall, "alltoall", ("allgather", "comm_rank", "comm_size"))


def build_alltoallv(ctx: EmulationContext) -> Callable:
    a2a, size = ctx.dep("alltoall"), ctx.dep("comm_size")

    def alltoallv(x, sendcounts, recvcounts, comm):
        sendcounts = tuple(int(c) for c in sendcounts)
        recvcounts = tuple(int(c) for c in recvcounts)
        if len(sendcounts) != len(recvcounts):
            raise ValueError("sendcounts and recvcounts must have equal length")
        if len(set(sendcounts) | set(recvcounts)) != 1:
            raise ValueError(
                "SPMD alltoallv requires uniform counts (one static trace "
                "cannot express per-rank-varying counts); got "
                f"sendcounts={sendcounts}, recvcounts={recvcounts}"
            )
        c = sendcounts[0]
        P = len(sendcounts)
        if x.shape[0] != P * c:
            raise ValueError(f"payload has {x.shape[0]} rows, counts promise {P}x{c}")
        S = size(comm)
        if S <= 1:
            if P != 1:
                raise ValueError("group-of-one alltoallv takes exactly one count")
            return x
        if P != S:
            raise ValueError(f"{P} counts for a size-{S} communicator")
        if c == 0:
            return x[:0]
        out = a2a(x.reshape((P, c) + x.shape[1:]), comm, 0, 0)
        return out.reshape((P * c,) + x.shape[1:])

    return _tag(alltoallv, "alltoallv", ("alltoall", "comm_size"))


def build_alltoallw(ctx: EmulationContext) -> Callable:
    a2a = ctx.dep("alltoall")
    datatypes = ctx.datatypes

    def alltoallw(blocks, sendtypes, recvtypes, comm):
        out = a2a(blocks, comm, 0, 0)
        return [
            out[i].astype(datatypes.to_numpy_dtype(recvtypes[i]))
            for i in range(out.shape[0])
        ]

    return _tag(alltoallw, "alltoallw", ("alltoall",))


def build_gather(ctx: EmulationContext) -> Callable:
    ag = ctx.dep("allgather")

    def gather(x, root, comm, axis=0):
        # SPMD gather == allgather (defined at root, replicated elsewhere).
        return ag(x, comm, axis=axis)

    return _tag(gather, "gather", ("allgather",))


def build_scatter(ctx: EmulationContext) -> Callable:
    bc, rank, size = ctx.dep("bcast"), ctx.dep("comm_rank"), ctx.dep("comm_size")

    def scatter(x, root, comm, axis=0):
        y = bc(x, root, comm)
        S = size(comm)
        if S <= 1 or y.__class__ is IncompleteValue:
            return y
        chunk = y.shape[axis] // S
        return lax.dynamic_slice_in_dim(y, rank(comm) * chunk, chunk, axis=axis)

    return _tag(scatter, "scatter", ("bcast", "comm_rank", "comm_size"))


# ---------------------------------------------------------------------------
# Persistent-plan builders (MPI-4 ``<name>_init``).  Each receives the plan's
# bound arguments with payloads as abstract shapes and returns a bare run
# closure: the recipe chain — size queries, padding geometry, slice bounds,
# dependency plan compilation — is composed exactly once here, so a plan
# ``start()`` on an emulated entry does no more per-call work than a native
# one.  Rank queries stay in the closure (``lax.axis_index`` is call-time by
# nature); everything shape- or handle-derived is frozen.
# ---------------------------------------------------------------------------
def plan_allreduce(ctx: PlanContext, x, op, comm) -> Callable:
    S = ctx.lowering_width(comm)  # the rs/ag split must match the lowering
    if S <= 1:
        return lambda x: x
    scalar = len(getattr(x, "shape", ())) == 0
    shape = (1,) if scalar else tuple(x.shape)
    n = shape[0]
    # round invented padding up to the backend's wire granule so the rs leg
    # lands on its fast path (kernel-eligible chunks); S*blk keeps both the
    # rank split and the per-rank chunk aligned
    pad = (-n) % (S * ctx.wire_block())
    rest = shape[1:]
    dtype = x.dtype
    rs = ctx.plan_dep(
        "reduce_scatter", jax.ShapeDtypeStruct((n + pad,) + rest, dtype),
        op, comm, 0)
    ag = ctx.plan_dep(
        "allgather", jax.ShapeDtypeStruct(((n + pad) // S,) + rest, dtype),
        comm, 0)
    if not pad and not scalar:
        return lambda x: ag(rs(x))
    pad_block = (pad,) + rest

    def run(x):
        if scalar:
            x = jnp.reshape(x, (1,))
        if pad:
            x = jnp.concatenate([x, jnp.zeros(pad_block, dtype)], axis=0)
        out = ag(rs(x))[:n]
        return out[0] if scalar else out

    return run


def plan_reduce(ctx: PlanContext, x, op, root, comm) -> Callable:
    # SPMD: computed everywhere, defined at root (the MPI contract).
    return ctx.plan_dep("allreduce", x, op, comm)


def plan_bcast(ctx: PlanContext, x, root, comm) -> Callable:
    ar = ctx.plan_dep("allreduce", x, H.PAX_SUM, comm)
    rank = ctx.dep("comm_rank")

    def run(x):
        return ar(jnp.where(rank(comm) == root, x, jnp.zeros_like(x)))

    return run


def plan_barrier(ctx: PlanContext, comm) -> Callable:
    ar = ctx.plan_dep(
        "allreduce", jax.ShapeDtypeStruct((1,), jnp.float32), H.PAX_SUM, comm)

    def run():
        ar(jnp.zeros((1,), jnp.float32))
        return None

    return run


def _plan_scan(ctx: PlanContext, x, op, comm, inclusive: bool) -> Callable:
    S = ctx.dep("comm_size")(comm)
    if S <= 1:
        return lambda x: x
    ag = ctx.plan_dep(
        "allgather", jax.ShapeDtypeStruct((1,) + tuple(x.shape), x.dtype),
        comm, 0)
    rank = ctx.dep("comm_rank")
    fn = ctx.op_fn(op)

    def run(x):
        return prefix_fold(ag(x[None]), rank(comm), fn, x, inclusive)

    return run


def plan_scan(ctx: PlanContext, x, op, comm) -> Callable:
    return _plan_scan(ctx, x, op, comm, inclusive=True)


def plan_exscan(ctx: PlanContext, x, op, comm) -> Callable:
    return _plan_scan(ctx, x, op, comm, inclusive=False)


def plan_gather(ctx: PlanContext, x, root, comm, axis=0) -> Callable:
    # SPMD gather == allgather (defined at root, replicated elsewhere).
    return ctx.plan_dep("allgather", x, comm, axis)


# ---------------------------------------------------------------------------
# Plan-group builders (the MPI ``Startall`` analogue, PR 5).  Each receives
# the bound argument tuples of every group member — same non-payload
# arguments across members, payloads abstract — and returns one fused run
# closure over the member payload list.  The fusion is **per stage**: every
# member's reduce-scatter leg runs before any all-gather leg, and each stage
# goes through ``PlanContext.plan_group_dep`` so the backend's own group
# hook can collapse a stage into a single stacked collective.
# ---------------------------------------------------------------------------
def plan_group_allreduce(ctx: PlanContext, bounds) -> Callable:
    op, comm = bounds[0][1], bounds[0][2]
    S = ctx.lowering_width(comm)  # the rs/ag split must match the lowering
    if S <= 1:
        return lambda xs: list(xs)
    members = []
    rs_bounds, ag_bounds = [], []
    blk = ctx.wire_block()
    for x, _, _ in bounds:
        if not hasattr(x, "shape") or not hasattr(x, "dtype"):
            return None  # pytree payloads: fall back to per-member plans
        scalar = len(tuple(x.shape)) == 0
        shape = (1,) if scalar else tuple(x.shape)
        n = shape[0]
        pad = (-n) % (S * blk)  # wire-granule-aligned (see plan_allreduce)
        rest = shape[1:]
        members.append((scalar, n, pad, rest, x.dtype))
        rs_bounds.append((jax.ShapeDtypeStruct((n + pad,) + rest, x.dtype),
                          op, comm, 0))
        ag_bounds.append((jax.ShapeDtypeStruct(((n + pad) // S,) + rest,
                                               x.dtype), comm, 0))
    rs_run = ctx.plan_group_dep("reduce_scatter", rs_bounds)
    ag_run = ctx.plan_group_dep("allgather", ag_bounds)

    def run(xs):
        mids = []
        for (scalar, n, pad, rest, dtype), x in zip(members, xs):
            if scalar:
                x = jnp.reshape(x, (1,))
            if pad:
                x = jnp.concatenate([x, jnp.zeros((pad,) + rest, dtype)],
                                    axis=0)
            mids.append(x)
        outs = ag_run(rs_run(mids))  # all rs legs, then all ag legs
        final = []
        for (scalar, n, pad, rest, dtype), o in zip(members, outs):
            if pad or scalar:
                o = o[:n]
            final.append(o[0] if scalar else o)
        return final

    return run


def plan_group_reduce(ctx: PlanContext, bounds) -> Callable:
    # SPMD: computed everywhere, defined at root (the MPI contract).
    return ctx.plan_group_dep(
        "allreduce", [(x, op, comm) for x, op, root, comm in bounds])
