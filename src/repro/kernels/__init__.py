"""Kernel registry: one place that answers "pallas or lax?".

Config switches (``ModelConfig.attention_impl``) and backend plan hooks
(``RingBackend``'s wire-kernel selection) both route through this registry
instead of importing kernel modules ad hoc.  Registration is lazy —
targets are ``"module:attr"`` strings resolved on first use — so importing
:mod:`repro.kernels` never drags in Pallas, and kernel packages can import
the registry without a cycle.

Selection contract (mirrors the backend plan hooks): the *caller* names a
kernel, :func:`kernel_mode` says whether the Pallas variant can run on this
platform (interpret mode on CPU, real lowering on TPU/GPU), and
:func:`resolve` hands back the callable with ``interpret=`` pre-bound — or
the registered lax fallback when Pallas is unavailable.
"""
from __future__ import annotations

import functools
import importlib
from typing import Any, Callable, Optional

import jax

#: name -> variant ("pallas" | "lax") -> lazy "module[:attr]" target
_REGISTRY: dict[str, dict[str, Any]] = {}

#: platforms where the pallas variant is usable (cpu via interpret mode)
_PALLAS_PLATFORMS = ("cpu", "tpu", "gpu")


def register(name: str, variant: str, target: Any) -> None:
    """Register a kernel implementation.  ``target`` is a callable or a
    lazy ``"module[:attr]"`` string resolved on first :func:`get`."""
    if variant not in ("pallas", "lax"):
        raise ValueError(f"unknown kernel variant {variant!r}")
    _REGISTRY.setdefault(name, {})[variant] = target


def _resolve_target(target: Any):
    if callable(target):
        return target
    mod_name, _, attr = str(target).partition(":")
    mod = importlib.import_module(mod_name)
    return getattr(mod, attr) if attr else mod


def get(name: str, variant: str):
    """The registered implementation (callable or module), resolved lazily;
    None if absent."""
    target = _REGISTRY.get(name, {}).get(variant)
    if target is None:
        return None
    fn = _resolve_target(target)
    _REGISTRY[name][variant] = fn  # cache the resolved object
    return fn


def _platform(platform: Optional[str]) -> str:
    return platform or jax.default_backend()


def interpret_on(platform: Optional[str] = None) -> bool:
    """Pallas interpret mode: on for CPU (tests/CI), off on TPU/GPU."""
    return _platform(platform) == "cpu"


def kernel_mode(name: str, platform: Optional[str] = None) -> str:
    """``"pallas"`` iff ``name`` has a Pallas variant runnable on this
    platform, else ``"lax"`` — the value surfaced per ABI entry as
    ``capabilities()[entry]["wire_kernel"]`` by kernel-backed backends."""
    if name in _REGISTRY and "pallas" in _REGISTRY[name] \
            and _platform(platform) in _PALLAS_PLATFORMS:
        return "pallas"
    return "lax"


def resolve(name: str, platform: Optional[str] = None):
    """-> ``(mode, fn)``: the best implementation for this platform.

    ``mode`` is ``"pallas"`` or ``"lax"``; Pallas *callables* come with
    ``interpret=`` pre-bound for the platform (module targets — op
    families like ``ring_wire`` — are returned as-is).  ``(None, None)``
    when nothing is registered under ``name``.
    """
    mode = kernel_mode(name, platform)
    fn = get(name, mode)
    if fn is None and mode == "pallas":  # pallas leg absent at runtime
        mode, fn = "lax", get(name, "lax")
    if fn is None:
        return None, None
    if mode == "pallas" and callable(fn):
        fn = functools.partial(fn, interpret=interpret_on(platform))
    return mode, fn


# -- built-in kernels (lazy: nothing imports until first resolve) -----------
register("flash_attention", "pallas",
         "repro.kernels.flash_attention.ops:flash_mha")
register("ring_wire", "pallas", "repro.kernels.ring_wire.ops")
register("mamba2_ssd", "pallas", "repro.kernels.mamba2_ssd.ops:ssd_apply")
register("rwkv6_scan", "pallas", "repro.kernels.rwkv6_scan.ops:wkv6_apply")
