"""RWKV6 chunked WKV scan — Pallas TPU kernel.

The WKV6 recurrence (data-dependent per-channel decay) in chunked matmul
form: within a chunk the contribution matrix is built from log-space
cumulative decays (fp32, clamped — see models/rwkv.py), the running
(N x N) state lives in VMEM scratch and is carried across the chunk grid
dimension (minor-most, so chunks of one (batch, head) iterate
consecutively), the inter-chunk term is a single (chunk x N) @ (N x N)
MXU matmul.

Layout: r/k/v/wlog (BH, T, N) fp32; u (BH, N); out (BH, T, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0]   # (c, N) fp32
    k = k_ref[0]
    v = v_ref[0]
    wl = w_ref[0]  # per-step log decay, < 0
    u = u_ref[0]   # (1, N) -> broadcast

    la = jnp.cumsum(wl, axis=0)          # inclusive log-decay
    la_prev = la - wl
    q_t = r * jnp.exp(la_prev)           # r_t * A_t
    k_t = k * jnp.exp(-la)               # k_s / A_{s+1}
    att = jax.lax.dot_general(q_t, k_t, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    c = r.shape[0]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    att = jnp.where(ti > si, att, 0.0)   # strictly lower triangle
    diag = jnp.sum(r * (u * k), axis=1)  # bonus term
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag[:, None] * v
    y = y + jax.lax.dot_general(q_t, state_scr[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)

    a_end = jnp.exp(la[-1, :])           # (N,)
    k_scaled = k * jnp.exp(la[-1:, :] - la)
    state_scr[...] = a_end[:, None] * state_scr[...] + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def wkv6(r, k, v, wlog, u, *, chunk: int = 32, interpret: bool = False):
    """r/k/v/wlog: (BH, T, N) fp32; u: (BH, N). Returns (BH, T, N) fp32."""
    BH, T, N = r.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, N), lambda bh, ci: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, wlog, u)
