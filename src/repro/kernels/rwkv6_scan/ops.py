"""jit'd wrapper: model layout (B, T, H, N) -> kernel layout (BH, T, N)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv6


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_apply(r, k, v, wlog, u, *, chunk: int = 32, interpret: bool = False):
    """r/k/v/wlog: (B, T, H, N); u: (H, N). Returns (B, T, H, N) fp32."""
    B, T, H, N = r.shape
    to_flat = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, T, N).astype(jnp.float32)
    uf = jnp.tile(u[None], (B, 1, 1)).reshape(B * H, N).astype(jnp.float32)
    out = wkv6(to_flat(r), to_flat(k), to_flat(v), to_flat(wlog), uf,
               chunk=chunk, interpret=interpret)
    return out.reshape(B, H, T, N).transpose(0, 2, 1, 3)
