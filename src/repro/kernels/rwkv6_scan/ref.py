"""Sequential ground-truth oracle for the WKV6 recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, wlog, u):
    """r/k/v/wlog: (BH, T, N); u: (BH, N). Sequential scan (ground truth).

        y_t[j]    = sum_i r_t[i] (S[i,j] + u[i] k_t[i] v_t[j])
        S[i,j]   <- exp(wlog_t[i]) S[i,j] + k_t[i] v_t[j]
    """
    BH, T, N = r.shape

    def step(S, xs):
        rt, kt, vt, wt = xs
        kv = kt[:, :, None] * vt[:, None, :]          # (BH, N, N)
        y = jnp.einsum("bi,bij->bj", rt, S + u[:, :, None] * kv)
        S = jnp.exp(wt)[:, :, None] * S + kv
        return S, y

    S0 = jnp.zeros((BH, N, N), jnp.float32)
    xs = tuple(x.swapaxes(0, 1) for x in (r, k, v, wlog))
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.swapaxes(0, 1)
