"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Intra-chunk: segment-sum log decays (scalar per step per head) build the
causal decay matrix; (C B^T) masks it into the token-mixing matrix M; two
MXU matmuls produce the intra-chunk output.  The (P x N) state is carried
in VMEM scratch across the chunk grid dimension; inter-chunk output and the
state update are MXU matmuls as well.

Layout (one head per grid row): x (BH, T, P); dt (BH, T); b/c (BH, T, N)
(B/C are shared across heads in Mamba2 — the wrapper broadcasts); A (BH,),
D (BH,).  Output (BH, T, P), fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, o_ref, state_scr):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0]        # (c, P)
    dt = dt_ref[0]      # (c,)
    b = b_ref[0]        # (c, N)
    c = c_ref[0]        # (c, N)
    a = a_ref[0]        # scalar (negative)
    dd = d_ref[0]       # scalar

    wl = dt * a                                 # per-step log decay (c,)
    la = jnp.cumsum(wl)                         # inclusive
    seg = la[:, None] - la[None, :]             # S[t,s] = sum (s..t]
    cc = x.shape[0]
    ti = jax.lax.broadcasted_iota(jnp.int32, (cc, cc), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (cc, cc), 1)
    decay = jnp.where(ti >= si, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (t, s)
    M = cb * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: y_t += (C_t * exp(la_t)) . S_in^T   (S: (P, N))
    q = c * jnp.exp(la)[:, None]                # (c, N)
    y = y + jax.lax.dot_general(q, state_scr[...], (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + x * dd
    o_ref[0] = y.astype(o_ref.dtype)

    # state update: S_out = exp(la_end) S_in + sum_s exp(la_end - la_s) dt_s x_s b_s^T
    k = b * (jnp.exp(la[-1] - la) * dt)[:, None]    # (c, N)
    state_scr[...] = jnp.exp(la[-1]) * state_scr[...] + jax.lax.dot_general(
        x, k, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def ssd(x, dt, b, c, a, d, *, chunk: int = 64, interpret: bool = False):
    """x: (BH,T,P); dt: (BH,T); b/c: (BH,T,N); a/d: (BH,). -> (BH,T,P) fp32."""
    BH, T, P = x.shape
    N = b.shape[-1]
    assert T % chunk == 0
    nc = T // chunk
    return pl.pallas_call(
        _ssd_kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1,), lambda bh, ci: (bh,)),
            pl.BlockSpec((1,), lambda bh, ci: (bh,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, d)
