"""Sequential ground-truth oracle for the Mamba2 SSD recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, b, c, a, d):
    """x: (BH,T,P); dt: (BH,T); b/c: (BH,T,N); a,d: (BH,).

        S[p,n] <- exp(dt_t a) S[p,n] + dt_t x_t[p] b_t[n]
        y_t[p]  = S[p,n] . c_t[n] + d x_t[p]
    """
    BH, T, P = x.shape
    N = b.shape[-1]

    def step(S, xs):
        xt, dtt, bt, ct = xs
        decay = jnp.exp(dtt * a)                       # (BH,)
        upd = (xt * dtt[:, None])[:, :, None] * bt[:, None, :]
        S = decay[:, None, None] * S + upd
        y = jnp.einsum("bpn,bn->bp", S, ct) + x_d(xt)
        return S, y

    def x_d(xt):
        return xt * d[:, None]

    S0 = jnp.zeros((BH, P, N), jnp.float32)
    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1), b.swapaxes(0, 1), c.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.swapaxes(0, 1)
