"""jit'd wrapper: model layout -> per-(batch, head) kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_apply(x, dt, A, B, C, D, *, chunk: int = 64, interpret: bool = False):
    """x: (Bb,T,H,P); dt: (Bb,T,H); A,D: (H,); B,C: (Bb,T,N) (shared across
    heads, as in Mamba2 ngroups=1). Returns (Bb,T,H,P) fp32."""
    Bb, T, H, P = x.shape
    N = B.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(Bb * H, T, P).astype(jnp.float32)
    dtf = dt.transpose(0, 2, 1).reshape(Bb * H, T).astype(jnp.float32)
    bf = jnp.broadcast_to(B[:, None], (Bb, H, T, N)).reshape(Bb * H, T, N).astype(jnp.float32)
    cf = jnp.broadcast_to(C[:, None], (Bb, H, T, N)).reshape(Bb * H, T, N).astype(jnp.float32)
    af = jnp.tile(A[None], (Bb, 1)).reshape(Bb * H).astype(jnp.float32)
    df = jnp.tile(D[None], (Bb, 1)).reshape(Bb * H).astype(jnp.float32)
    out = ssd(xf, dtf, bf, cf, af, df, chunk=chunk, interpret=interpret)
    return out.reshape(Bb, H, T, P).transpose(0, 2, 1, 3)
