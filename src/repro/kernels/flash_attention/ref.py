"""Pure-jnp oracle for the flash-attention kernel (same layout/semantics)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (BH, S, D); k/v: (BKV, S, D). fp32 math, returns q.dtype."""
    BH, S, D = q.shape
    BKV = k.shape[0]
    group = BH // BKV
    kx = jnp.repeat(k, group, axis=0).astype(jnp.float32)
    vx = jnp.repeat(v, group, axis=0).astype(jnp.float32)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), kx) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vx).astype(q.dtype)
