"""Causal GQA flash attention — Pallas TPU kernel.

TPU adaptation of the FlashAttention insight (IO-aware tiling + online
softmax), re-thought for the TPU memory hierarchy per DESIGN.md:

* tiles live in VMEM via BlockSpecs; the MXU consumes (block_q x d) @
  (d x block_k) matmuls with d and block sizes multiples of 128 where the
  dtype allows;
* the kv loop is a grid dimension (minor-most, so it iterates innermost);
  the softmax running state (m, l) and the output accumulator persist in
  VMEM scratch across kv steps — the TPU analogue of keeping them in
  registers/shared memory on GPU;
* causal block-skipping: kv blocks strictly above the diagonal are skipped
  with ``pl.when`` (halves the work — the XLA reference computes the full
  S^2 score matrix, which is exactly the §Perf baseline gap).

Layout: q (BH, S, D) where BH = batch*q_heads; k/v (BKV, S, D) where
BKV = batch*kv_heads; GQA group = H // Hkv resolved in the index maps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, block_q: int, block_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip kv blocks strictly above the diagonal
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (BH, S, D); k/v: (BKV, S, D); BH % BKV == 0. Returns (BH, S, D)."""
    BH, S, D = q.shape
    BKV = k.shape[0]
    assert BH % BKV == 0, (BH, BKV)
    group = BH // BKV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max m
            pltpu.VMEM((block_q,), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, D), jnp.float32), # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
