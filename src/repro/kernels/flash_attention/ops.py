"""jit'd public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, D) + (B, S, Hkv, D) and handles the
(BH, S, D) kernel layout, padding S up to the block size if needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_mha(q, k, v, *, causal: bool = True, block_q: int = 128,
              block_k: int = 128, interpret: bool = False):
    """q: (B, S, H, D); k/v: (B, S, Hkv, D) -> (B, S, H, D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    bq = min(block_q, S)
    bk = min(block_k, S)
    pad = (-S) % max(bq, bk)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    # GQA interleave: head h of q maps to kv head h // (H // Hkv); the kernel
    # index map assumes q heads of one kv group are contiguous, which the
    # transpose-reshape above guarantees (B-major, then H).
    out = flash_attention(qf, kf, vf, causal=causal, block_q=bq, block_k=bk,
                          interpret=interpret)
    if pad:
        out = out[:, :S]
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
