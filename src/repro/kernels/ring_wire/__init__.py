"""Fused ring-wire Pallas kernels (see README.md)."""
from .ops import (  # noqa: F401
    MAX_WIRE_ELEMS,
    WIRE_BLOCK,
    hop_accum,
    hop_add_quant,
    interpret_on,
    pack_eligible,
    pack_parts,
    pack_parts_ef,
    quant,
    unpack_gathers,
    wire_eligible,
)
