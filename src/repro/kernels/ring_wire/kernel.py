"""Fused ring-wire Pallas kernels: one HBM round trip per hop.

The ring backend's compressed wire (``core/backends/ring.py``) composes each
hop from separate lax ops — dequantize the received block, add the local
chunk, re-quantize for the next hop — which materializes three full-size
intermediates per hop.  Each kernel here does the whole per-hop update in a
single pass: one read of the traveling block, one read of the local chunk,
one write of the outgoing block (plus the tiny per-block scale vector).

Layout convention: every payload is viewed as ``(nblocks, WIRE_BLOCK)`` —
the wire block is the quantization granule (int8 absmax scale per block,
an upgrade over the lax path's single global scale) and the lane dimension
of the TPU tile.  The ops wrappers (:mod:`.ops`) own the reshape; kernels
are no-grid ``pallas_call``s over the whole (VMEM-resident) payload, which
is exactly the traveling-chunk regime: a ring hop moves ``n/S`` elements,
far below VMEM at training shard sizes.  ``interpret=True`` runs the same
kernels as jnp ops on CPU (the test/CI story); eligibility for real
TPU/GPU payloads is gated at plan time by :func:`ops.wire_eligible`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: quantization granule and TPU lane width: one absmax scale per 128 wire
#: elements, and the minor dimension of every kernel block view
WIRE_BLOCK = 128

#: absmax floor matching ``ring._quantize`` (avoids 0/0 on all-zero blocks)
_QEPS = 1e-30

#: scale = absmax * (1/127) as a single f32 multiply — a divide here is
#: lowered differently inside vs outside the fused kernel body (1-ULP
#: drift), which would break the bitwise kernel==ref parity contract
_INV127 = float(jnp.float32(1.0) / jnp.float32(127.0))


def _i8_scales(x):
    """Per-block int8 absmax scale of a (nb, WIRE_BLOCK) f32 view."""
    return jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True),
                       _QEPS) * _INV127


def _i8_pack(x, s):
    return jnp.clip(jnp.round(x / s), -127.0, 127.0).astype(jnp.int8)


# ---------------------------------------------------------------------------
# int8 wire: quantize / hop-update / final-accumulate
# ---------------------------------------------------------------------------
def _quant_i8_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]
    s = _i8_scales(x)
    q_ref[...] = _i8_pack(x, s)
    s_ref[...] = s


def quant_i8(x2d, *, interpret: bool):
    """(nb, B) f32 -> ((nb, B) int8, (nb, 1) f32 scales)."""
    nb, b = x2d.shape
    return pl.pallas_call(
        _quant_i8_kernel,
        out_shape=(jax.ShapeDtypeStruct((nb, b), jnp.int8),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)),
        interpret=interpret,
    )(x2d)


def _hop_add_quant_i8_kernel(q_ref, s_ref, a_ref, q2_ref, s2_ref):
    # dequantize + accumulate + re-quantize: ONE read of the traveling
    # block, one write of the outgoing block — the lax composition
    # materializes `received`, `travel` and the quantized result separately
    y = q_ref[...].astype(jnp.float32) * s_ref[...] + a_ref[...]
    s2 = _i8_scales(y)
    q2_ref[...] = _i8_pack(y, s2)
    s2_ref[...] = s2


def hop_add_quant_i8(q2d, s, a2d, *, interpret: bool):
    """Middle ring hop: (q, scales, local chunk) -> (q', scales')."""
    nb, b = q2d.shape
    return pl.pallas_call(
        _hop_add_quant_i8_kernel,
        out_shape=(jax.ShapeDtypeStruct((nb, b), jnp.int8),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)),
        interpret=interpret,
    )(q2d, s, a2d)


def _hop_accum_i8_kernel(q_ref, s_ref, a_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...] + a_ref[...]


def hop_accum_i8(q2d, s, a2d, *, interpret: bool):
    """Final ring hop: dequantize-and-accumulate into f32, one pass."""
    nb, b = q2d.shape
    return pl.pallas_call(
        _hop_accum_i8_kernel,
        out_shape=jax.ShapeDtypeStruct((nb, b), jnp.float32),
        interpret=interpret,
    )(q2d, s, a2d)


# ---------------------------------------------------------------------------
# bf16 wire: pack is a bare cast (bitwise == lax astype); the fused work is
# the add+cast hop update and the final accumulate
# ---------------------------------------------------------------------------
def _hop_add_quant_bf16_kernel(w_ref, a_ref, w2_ref):
    w2_ref[...] = (w_ref[...].astype(jnp.float32) + a_ref[...]).astype(jnp.bfloat16)


def hop_add_quant_bf16(w2d, a2d, *, interpret: bool):
    nb, b = w2d.shape
    return pl.pallas_call(
        _hop_add_quant_bf16_kernel,
        out_shape=jax.ShapeDtypeStruct((nb, b), jnp.bfloat16),
        interpret=interpret,
    )(w2d, a2d)


def _hop_accum_bf16_kernel(w_ref, a_ref, o_ref):
    o_ref[...] = w_ref[...].astype(jnp.float32) + a_ref[...]


def hop_accum_bf16(w2d, a2d, *, interpret: bool):
    nb, b = w2d.shape
    return pl.pallas_call(
        _hop_accum_bf16_kernel,
        out_shape=jax.ShapeDtypeStruct((nb, b), jnp.float32),
        interpret=interpret,
    )(w2d, a2d)


# ---------------------------------------------------------------------------
# fused grad flatten/bucket: the zero1 transposed-bucket gather
# (grad_sync._transposed_bucket_parts) as one kernel pass, optionally fused
# with the bf16 wire cast + error-feedback residual refresh
# ---------------------------------------------------------------------------
def _pack_kernel(x_ref, o_ref, *, dp: int, buckets: int, wire_dtype):
    # x: (dp*buckets, seg) rank-major; o: (buckets, dp, seg) bucket-major —
    # the transposed split whose per-bucket reduce-scatter results
    # concatenate into each rank's contiguous slice of the full vector
    x = x_ref[...]
    seg = x.shape[1]
    o_ref[...] = jnp.swapaxes(
        x.reshape(dp, buckets, seg), 0, 1).astype(wire_dtype)


def pack_transposed(x2d, dp: int, buckets: int, wire_dtype, *, interpret: bool):
    """(dp*buckets, seg) -> (buckets, dp, seg) in the wire dtype."""
    seg = x2d.shape[1]
    return pl.pallas_call(
        functools.partial(_pack_kernel, dp=dp, buckets=buckets,
                          wire_dtype=wire_dtype),
        out_shape=jax.ShapeDtypeStruct((buckets, dp, seg), wire_dtype),
        interpret=interpret,
    )(x2d)


def _pack_ef_kernel(x_ref, e_ref, o_ref, ef_ref, *, dp: int, buckets: int):
    # error-feedback fold + bf16 wire cast + residual refresh + transposed
    # split, one pass: y = g + ef; wire = bf16(y); ef' = y - f32(wire).
    # The lax path materializes y, wire and ef' as three full vectors.
    y = x_ref[...] + e_ref[...]
    w = y.astype(jnp.bfloat16)
    ef_ref[...] = y - w.astype(jnp.float32)
    seg = y.shape[1]
    o_ref[...] = jnp.swapaxes(w.reshape(dp, buckets, seg), 0, 1)


def pack_transposed_ef(x2d, e2d, dp: int, buckets: int, *, interpret: bool):
    """((dp*buckets, seg) f32 grads, same-shape ef) ->
    ((buckets, dp, seg) bf16 wire, (dp*buckets, seg) f32 new ef)."""
    seg = x2d.shape[1]
    return pl.pallas_call(
        functools.partial(_pack_ef_kernel, dp=dp, buckets=buckets),
        out_shape=(jax.ShapeDtypeStruct((buckets, dp, seg), jnp.bfloat16),
                   jax.ShapeDtypeStruct(x2d.shape, jnp.float32)),
        interpret=interpret,
    )(x2d, e2d)


def _unpack_kernel(x_ref, o_ref, *, dp: int, buckets: int):
    # inverse gather (grad_sync._interleave_bucket_gathers): bucket-major
    # (buckets, dp, seg) back to the rank-major flat layout
    x = x_ref[...]
    seg = x.shape[2]
    o_ref[...] = jnp.swapaxes(x, 0, 1).reshape(dp * buckets, seg).astype(
        jnp.float32)


def unpack_transposed(x3d, *, interpret: bool):
    """(buckets, dp, seg) -> (dp*buckets, seg) f32."""
    buckets, dp, seg = x3d.shape
    return pl.pallas_call(
        functools.partial(_unpack_kernel, dp=dp, buckets=buckets),
        out_shape=jax.ShapeDtypeStruct((dp * buckets, seg), jnp.float32),
        interpret=interpret,
    )(x3d)
