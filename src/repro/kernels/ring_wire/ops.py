"""Shape-polymorphic wrappers around the fused ring-wire kernels.

These are the functions the backend plan hooks call.  Payloads arrive as
flat (or leading-axis) arrays; the wrappers view them as ``(nblocks,
WIRE_BLOCK)``, invoke the no-grid kernel, and restore the caller's shape.
Eligibility predicates (:func:`wire_eligible`, :func:`pack_eligible`) are
evaluated at **plan time** against the bound shape/dtype/platform — callers
never see the kernel-vs-lax decision, only ``capabilities()`` does.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel as _k

WIRE_BLOCK = _k.WIRE_BLOCK

#: per-hop payloads above this stay on the lax path on real accelerators —
#: the no-grid kernels hold the whole block view in VMEM (~16 MiB/core);
#: 1M f32 elements is 4 MiB traveling + 4 MiB accumulator, a safe ceiling.
MAX_WIRE_ELEMS = 1 << 20


def _platform(platform: Optional[str]) -> str:
    return platform or jax.default_backend()


def interpret_on(platform: Optional[str] = None) -> bool:
    """Pallas interpret mode: on for CPU (tests/CI), off on TPU/GPU."""
    return _platform(platform) == "cpu"


def wire_eligible(shape, dtype, compress: Optional[str],
                  platform: Optional[str] = None) -> bool:
    """Can the fused hop kernels carry this per-hop chunk?

    Requires a compressed wire (the fusion exists to kill the quantize /
    dequantize intermediates), an f32 payload, and a WIRE_BLOCK-divisible
    element count (the per-block scale layout).  On TPU/GPU additionally
    cap at :data:`MAX_WIRE_ELEMS` so the no-grid kernel stays VMEM-resident.
    """
    if compress not in ("int8", "bf16"):
        return False
    if jnp.dtype(dtype) != jnp.float32:
        return False
    total = 1
    for d in shape:
        total *= int(d)
    if total <= 0 or total % WIRE_BLOCK != 0:
        return False
    plat = _platform(platform)
    if plat not in ("cpu", "tpu", "gpu"):
        return False
    if plat != "cpu" and total > MAX_WIRE_ELEMS:
        return False
    return True


def _as_blocks(x):
    return x.reshape(-1, WIRE_BLOCK)


def quant(x, compress: str, *, interpret: bool):
    """Quantize a chunk for the wire.

    Returns ``(q, scales)`` where ``q`` has ``x``'s shape (int8 or bf16)
    and ``scales`` is the per-block scale vector (``None`` for bf16).
    """
    if compress == "bf16":
        # bare cast: bitwise-identical to the lax astype, no kernel needed
        return x.astype(jnp.bfloat16), None
    q, s = _k.quant_i8(_as_blocks(x), interpret=interpret)
    return q.reshape(x.shape), s


def hop_add_quant(q, scales, addend, compress: str, *, interpret: bool):
    """Middle-hop update: dequantize + add local chunk + re-quantize."""
    if compress == "bf16":
        w2 = _k.hop_add_quant_bf16(_as_blocks(q), _as_blocks(addend),
                                   interpret=interpret)
        return w2.reshape(q.shape), None
    q2, s2 = _k.hop_add_quant_i8(_as_blocks(q), scales, _as_blocks(addend),
                                 interpret=interpret)
    return q2.reshape(q.shape), s2


def hop_accum(q, scales, addend, compress: str, *, interpret: bool):
    """Final-hop update: dequantize + add local chunk, f32 out."""
    if compress == "bf16":
        o = _k.hop_accum_bf16(_as_blocks(q), _as_blocks(addend),
                              interpret=interpret)
    else:
        o = _k.hop_accum_i8(_as_blocks(q), scales, _as_blocks(addend),
                            interpret=interpret)
    return o.reshape(addend.shape)


# ---------------------------------------------------------------------------
# fused grad flatten/bucket (zero1 plan-group payload gather)
# ---------------------------------------------------------------------------
def pack_eligible(padded: int, dp: int, buckets: int,
                  platform: Optional[str] = None) -> bool:
    """Can the fused pack/unpack kernels build the zero1 bucket parts?"""
    if padded <= 0 or dp <= 0 or buckets <= 0 or padded % (dp * buckets) != 0:
        return False
    plat = _platform(platform)
    if plat not in ("cpu", "tpu", "gpu"):
        return False
    if plat != "cpu" and padded > 4 * MAX_WIRE_ELEMS:
        return False
    return True


def pack_parts(flat, dp: int, buckets: int, wire_dtype, *, interpret: bool):
    """Fused ``_transposed_bucket_parts`` + wire cast.

    ``flat``: (padded,) f32 -> list of ``buckets`` parts, each
    ``(padded // buckets,)`` in ``wire_dtype``.
    """
    seg = flat.shape[0] // (dp * buckets)
    out = _k.pack_transposed(flat.reshape(dp * buckets, seg), dp, buckets,
                             jnp.dtype(wire_dtype), interpret=interpret)
    return [out[b].reshape(-1) for b in range(buckets)]


def pack_parts_ef(flat, ef, dp: int, buckets: int, *, interpret: bool):
    """Fused error-feedback fold + bf16 cast + residual + bucket gather.

    Returns ``(parts, new_ef)``: ``parts`` as in :func:`pack_parts` (bf16),
    ``new_ef`` the refreshed (padded,) f32 residual ``(g + ef) - f32(wire)``.
    """
    seg = flat.shape[0] // (dp * buckets)
    out, new_ef = _k.pack_transposed_ef(
        flat.reshape(dp * buckets, seg), ef.reshape(dp * buckets, seg),
        dp, buckets, interpret=interpret)
    return [out[b].reshape(-1) for b in range(buckets)], new_ef.reshape(-1)


def unpack_gathers(outs, dp: int, *, interpret: bool):
    """Fused ``_interleave_bucket_gathers``: per-bucket allgather outputs
    (each ``(padded // buckets,)``) back to one (padded,) f32 vector."""
    buckets = len(outs)
    seg = outs[0].shape[0] // dp
    x3d = jnp.stack([o.reshape(dp, seg) for o in outs], axis=0)
    flat = _k.unpack_transposed(x3d, interpret=interpret)
    return flat.reshape(-1)
