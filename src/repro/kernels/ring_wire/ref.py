"""Pure-lax references for the fused ring-wire kernels.

Two flavours:

* ``*_block``: the *same math* as the Pallas kernels (per-block int8 absmax
  scales) written as unfused jnp ops — the parity oracle for
  ``tests/test_wire_kernels.py``.  Quantize, the bf16 paths and pack/unpack
  match the kernels **bitwise** in interpret mode; the int8 hop paths match
  to one quantum (the kernel's dequant+add contracts to an FMA — single
  rounding — which the unfused composition cannot express).
* ``lax_hop_global``: the original ring-backend hop composition (global
  absmax scale, ``ring._quantize``/``_dequantize``), used by the benchmark
  to measure what the fusion removed.  It is *numerically different* from
  the per-block kernels (coarser scale), so comparisons against it are
  bounded-error, not bitwise.
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import WIRE_BLOCK, _INV127, _QEPS


def _blocks(x):
    return x.reshape(-1, WIRE_BLOCK)


def quant_i8_block(x):
    """Per-block int8 quantization, unfused: (n,) f32 -> (q, (nb,1) scales)."""
    xb = _blocks(x)
    s = jnp.maximum(jnp.max(jnp.abs(xb), axis=1, keepdims=True),
                    _QEPS) * _INV127
    q = jnp.clip(jnp.round(xb / s), -127.0, 127.0).astype(jnp.int8)
    return q.reshape(x.shape), s


def dequant_i8_block(q, s):
    return (_blocks(q).astype(jnp.float32) * s).reshape(q.shape)


def hop_add_quant_i8_block(q, s, addend):
    """Unfused middle hop with per-block scales (kernel parity oracle)."""
    y = dequant_i8_block(q, s) + addend
    return quant_i8_block(y)


def hop_accum_i8_block(q, s, addend):
    return dequant_i8_block(q, s) + addend


def lax_hop_global(q, scale, addend):
    """The pre-fusion ring hop body (``ring.py`` lax composition): global
    absmax dequantize, add, global absmax re-quantize — three materialized
    full-size intermediates.  Benchmark/breakdown baseline only."""
    received = q.astype(jnp.float32) * scale
    y = received + addend
    s2 = jnp.maximum(jnp.max(jnp.abs(y)), 1e-30) / 127.0
    q2 = jnp.clip(jnp.round(y / s2), -127, 127).astype(jnp.int8)
    return q2, s2
