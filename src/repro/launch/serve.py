"""Serving launcher: batched generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --prompt-len 16 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

import repro.configs as cfgs
from repro.configs.base import apply_xla_flags
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.runtime.dist import make_dist
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=cfgs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--impl", default=None)
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV page size in token positions")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt positions fed per engine step")
    args = ap.parse_args(argv)

    # before the first jax operation: XLA_FLAGS is read at client creation
    apply_xla_flags()
    cfg = cfgs.smoke_config(args.arch) if args.smoke else cfgs.get_config(args.arch)
    api = build_model(cfg)
    mesh = make_host_mesh()
    dist = make_dist(mesh, impl=args.impl)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, max_batch=args.batch,
                      max_seq=args.prompt_len + args.new_tokens + 8, dist=dist,
                      block_size=args.block_size,
                      prefill_chunk=args.prefill_chunk)

    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(1, cfg.vocab_size, args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens, temperature=args.temperature)
        for i in range(args.batch)
    ]
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"arch={cfg.name} impl={dist.abi.backend.name}: {args.batch} requests, "
          f"{total_new} tokens in {dt:.2f}s ({total_new/dt:.1f} tok/s)")
    print(f"  stats: {eng.stats}")
    if eng.paged:
        print(f"  kv pool: {eng.alloc.live_blocks} live / "
              f"{eng.alloc.num_blocks - 1} blocks of {eng.block_size}")
    for r in reqs[:2]:
        print(f"  req{r.rid}: {r.out_tokens[:12]}")
    return reqs


if __name__ == "__main__":
    main()
