"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --global-batch 8 --seq-len 128 --smoke \
        --ckpt-dir /tmp/ckpt --impl paxi

``--smoke`` selects the reduced config (CPU-runnable); otherwise the full
assigned config is used (TPU-scale).  The loop runs under the fault-
tolerance supervisor: periodic async checkpoints, restart-on-failure,
straggler watchdog.  ``--impl`` picks the ABI backend (the paper's
recompile-free implementation swap).
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as cfgs
from repro.configs.base import apply_xla_flags
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataPipeline, SyntheticSource
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.runtime.dist import make_dist
from repro.runtime.fault import run_supervised
from repro.train import train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=cfgs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--impl", default=None, help="PAX ABI backend")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    # XLA_FLAGS is parsed at backend-client creation, so install the
    # latency-hiding/async-collective set before the first jax operation
    # (idempotent; hand-set flags win — configs/base.py)
    apply_xla_flags()
    cfg = cfgs.smoke_config(args.arch) if args.smoke else cfgs.get_config(args.arch)
    api = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(args.model_axis))
    dist = make_dist(mesh, impl=args.impl,
                     sequence_parallel=cfg.parallelism.sequence_parallel,
                     compression=cfg.parallelism.grad_compression)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M mesh={dict(mesh.shape)} "
          f"impl={dist.abi.backend.name} mode={cfg.parallelism.grad_sync}")

    key = jax.random.PRNGKey(0)
    # dist activates the ZeRO-1 flat optimizer layout in abi mode
    state = train_loop.init_state(api, key, dist=dist)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state.params))
    print(f"actual params: {n_params/1e6:.2f}M")

    schedule = lambda step: warmup_cosine(step, warmup=args.warmup, total=args.steps)
    step_fn = jax.jit(train_loop.make_train_step(
        api, dist, AdamWConfig(lr=args.lr), schedule=schedule))

    pipe = DataPipeline(SyntheticSource(cfg.vocab_size, seed=0),
                        global_batch=args.global_batch, seq_len=args.seq_len)
    cache = {}

    def get_batch(i):
        # cache recent batches so restarts can replay the same step's data
        if i not in cache:
            cache.clear()
            b = next(pipe)
            cache[i] = {k: jnp.asarray(v) for k, v in b.items()}
        return cache[i]

    ckpt = Checkpointer(args.ckpt_dir, keep=3)
    t0 = time.time()
    last = {"t": t0, "step": 0}

    raw_step = step_fn

    def logged_step(state, batch):
        out = raw_step(state, batch)
        s = int(out[0].step)
        if s % args.log_every == 0:
            dt = (time.time() - last["t"]) / max(s - last["step"], 1)
            toks = args.global_batch * args.seq_len / max(dt, 1e-9)
            print(f"step {s:5d} loss {float(out[1].loss):.4f} "
                  f"gnorm {float(out[1].grad_norm):.3f} {dt*1e3:.0f} ms/step "
                  f"({toks:,.0f} tok/s)")
            last["t"], last["step"] = time.time(), s
        return out

    report = run_supervised(
        logged_step, state, get_batch, checkpointer=ckpt,
        total_steps=args.steps, checkpoint_every=args.ckpt_every,
        state_like=state)
    dt = time.time() - t0
    print(f"done: {report.steps_completed} steps in {dt:.1f}s "
          f"({report.restarts} restarts, {report.stragglers} stragglers); "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")
    return report


if __name__ == "__main__":
    main()
