"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run forces 512 host devices *before* any
jax initialization; everything else sees the real device count).
"""
from __future__ import annotations

import jax

from ..core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data x model single pod; (2,16,16) pod x data x model for two
    pods (512 chips of TPU v5e in the target deployment)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """A mesh over whatever devices exist (tests / examples / smoke runs)."""
    n = jax.device_count()
    assert n % model_axis == 0
    return make_mesh((n // model_axis, model_axis), ("data", "model"))
