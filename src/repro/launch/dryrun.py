import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).  This module is the ONLY place the 512
# placeholder devices exist; tests/benches see the real device count.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production meshes, print memory_analysis() and
cost_analysis(), and record the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh pod1
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, subprocess each
    PYTHONPATH=src python -m repro.launch.dryrun --list

Success here proves the distribution config is coherent: sharding
mismatches, compile-time OOM, or unsupported collectives are bugs.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

import repro.configs as cfgs
from repro.launch.hlo_analysis import roofline_from_compiled, collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.model import analytic_param_count, model_flops_per_token
from repro.optim.adamw import AdamWConfig
from repro.runtime.dist import make_dist
from repro.runtime.sharding import use_rules
from repro.train import train_loop

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _apply_env_overrides(cfg):
    """Hillclimb knobs (EXPERIMENTS.md §Perf): each hypothesis->change cycle
    re-runs a cell under PAX_OVERRIDE_* without touching the baseline config.

      PAX_OVERRIDE_ATTENTION=blockwise|xla
      PAX_OVERRIDE_MICROBATCH=<int>
      PAX_OVERRIDE_REMAT=none|dots|full
      PAX_OVERRIDE_CAPACITY=<float>        (MoE capacity factor)
      PAX_OVERRIDE_COMPRESSION=bf16|int8   (dp grad sync wire)
      PAX_OVERRIDE_SEQPAR=0|1
    """
    par = cfg.parallelism
    if os.environ.get("PAX_OVERRIDE_ATTENTION"):
        cfg = dataclasses.replace(cfg, attention_impl=os.environ["PAX_OVERRIDE_ATTENTION"])
    if os.environ.get("PAX_OVERRIDE_MICROBATCH"):
        par = dataclasses.replace(par, microbatch=int(os.environ["PAX_OVERRIDE_MICROBATCH"]))
    if os.environ.get("PAX_OVERRIDE_REMAT"):
        par = dataclasses.replace(par, remat=os.environ["PAX_OVERRIDE_REMAT"])
    if os.environ.get("PAX_OVERRIDE_COMPRESSION"):
        par = dataclasses.replace(par, grad_compression=os.environ["PAX_OVERRIDE_COMPRESSION"])
    if os.environ.get("PAX_OVERRIDE_SEQPAR"):
        par = dataclasses.replace(par, sequence_parallel=bool(int(os.environ["PAX_OVERRIDE_SEQPAR"])))
    if par is not cfg.parallelism:
        cfg = dataclasses.replace(cfg, parallelism=par)
    if os.environ.get("PAX_OVERRIDE_CAPACITY") and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(os.environ["PAX_OVERRIDE_CAPACITY"])))
    return cfg


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _sanitize_spec(spec: P, mesh) -> P:
    """Drop axes not present in this mesh (e.g. 'pod' on the single-pod
    mesh — cache/state specs name the superset of axes)."""
    names = set(mesh.axis_names)
    parts = []
    for p in tuple(spec):
        if p is None:
            parts.append(None)
        elif isinstance(p, tuple):
            kept = tuple(a for a in p if a in names)
            parts.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            parts.append(p if p in names else None)
    return P(*parts)


def _tree_sds(struct_tree, spec_tree, mesh):
    def one(s, spec):
        if not isinstance(spec, P):
            spec = P()
        spec = _sanitize_spec(_trim(spec, len(s.shape)), mesh)
        # drop uneven dims (e.g. kv_heads=2 over model=16): replicate instead
        parts = []
        for dim, p in zip(s.shape, tuple(spec)):
            if p is not None:
                import math as _m

                size = (_m.prod(mesh.shape[a] for a in p) if isinstance(p, tuple)
                        else mesh.shape[p])
                if size <= 1 or dim % size != 0:
                    p = None
            parts.append(p)
        spec = P(*parts)
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree.map(one, struct_tree, spec_tree,
                        is_leaf=lambda v: isinstance(v, P))


def _trim(spec: P, rank: int) -> P:
    parts = tuple(spec)
    if len(parts) > rank:
        parts = parts[:rank]
    return P(*parts)


def _drop_batch_axes(spec_tree, mesh):
    """For global_batch=1 cells the dp axes cannot shard the batch dim:
    replace ('pod','data') (or subsets) with None in cache/batch specs."""
    dp = {"pod", "data"}

    def fix(spec):
        if not isinstance(spec, P):
            return spec
        parts = []
        for p in tuple(spec):
            if p is None:
                parts.append(None)
            elif isinstance(p, tuple) and set(p) & dp:
                parts.append(None)
            elif p in dp:
                parts.append(None)
            else:
                parts.append(p)
        return P(*parts)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda v: isinstance(v, P))


def batch_struct(cfg, shape, mesh, dp_axes):
    b, s = shape.global_batch, shape.seq_len
    bspec = P(dp_axes) if b % _axes_size(mesh, dp_axes) == 0 and b >= _axes_size(mesh, dp_axes) else P()
    out = {
        "tokens": _sds((b, s), jnp.int32, mesh, bspec),
        "targets": _sds((b, s), jnp.int32, mesh, bspec),
    }
    if cfg.family == "encdec":
        out["frames"] = _sds((b, cfg.encdec.encoder_frames, cfg.d_model), jnp.bfloat16,
                             mesh, bspec)
    if cfg.family == "vlm":
        out["patches"] = _sds((b, cfg.vlm.num_patches, cfg.vlm.patch_embed_dim),
                              jnp.bfloat16, mesh, bspec)
    return out


def _axes_size(mesh, axes):
    import math

    return math.prod(mesh.shape[a] for a in axes)


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------
def lower_cell(arch: str, shape_name: str, multi_pod: bool, impl: str = "paxi",
               unroll: bool = False, layer_override: int = 0):
    """One lowering of one cell.

    ``unroll=False`` (the deployable graph): scan-over-layers + grad
    accumulation — gives the true ``memory_analysis`` and proves the
    sharding compiles.  ``unroll=True`` (the accounting graph): layers
    unrolled and a SINGLE accumulation iteration (global_batch/n_micro)
    lowered, because XLA cost analysis does not multiply while-body
    FLOPs/bytes by trip count; roofline terms come from this graph
    (per-accumulation-iteration, with the once-per-step grad-sync tail
    included).  run_cell() combines both into one record.
    """
    cfg = _apply_env_overrides(cfgs.get_config(arch))
    shape = cfgs.SHAPES_BY_NAME[shape_name]
    n_micro = max(cfg.parallelism.microbatch, 1)
    if unroll:
        cfg = dataclasses.replace(
            cfg, parallelism=dataclasses.replace(
                cfg.parallelism, scan_layers=False, microbatch=1))
        if layer_override:
            cfg = dataclasses.replace(cfg, num_layers=layer_override)
        if shape.kind == "train" and n_micro > 1:
            # per-iteration batch, floored at the dp size so the accounting
            # graph keeps the batch sharded (a replicated batch would inflate
            # the TP collectives beyond anything the deployable graph does)
            dp = 32 if multi_pod else 16
            shape = dataclasses.replace(
                shape, global_batch=max(shape.global_batch // n_micro, dp))
    if shape.kind == "decode" and shape_name == "long_500k" and not cfg.supports_long_context:
        return {"status": "skipped",
                "reason": "full-attention arch; long_500k needs sub-quadratic attention"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    api = build_model(cfg)
    dist = make_dist(mesh, impl=impl,
                     sequence_parallel=cfg.parallelism.sequence_parallel,
                     compression=cfg.parallelism.grad_compression)
    mode = cfg.parallelism.grad_sync
    fsdp = ("pod", "data") if multi_pod else "data"
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        state_struct = jax.eval_shape(lambda: train_loop.init_state(api, key))
        sspecs = train_loop.state_specs(api, mode, fsdp=fsdp, tp=dist.tp_axis)
        state_in = _tree_sds(state_struct, sspecs, mesh)
        batch_in = batch_struct(cfg, shape, mesh, dist.dp_axes)
        step_fn = train_loop.make_train_step(api, dist, AdamWConfig())
        jitted = jax.jit(step_fn, donate_argnums=(0,))
        t0 = time.time()
        lowered = jitted.lower(state_in, batch_in)
        t_lower = time.time() - t0
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        params_struct = jax.eval_shape(api.init, key)
        pspecs = api.param_specs(fsdp=fsdp if mode == "gspmd" else None, tp=dist.tp_axis)
        params_in = _tree_sds(params_struct, pspecs, mesh)
        batch_in = batch_struct(cfg, shape, mesh, dist.dp_axes)

        last_only = bool(int(os.environ.get("PAX_OVERRIDE_PREFILL_LAST", "0")))

        def prefill_fn(params, batch):
            with use_rules(dist.rules):
                # §Perf it2: prefill needs one position's logits; last_only
                # slices the residual stream BEFORE the unembed matmul
                from repro.models import (encdec, hybrid, rwkv, transformer, vlm)
                mod = {"dense": transformer, "moe": transformer, "ssm": rwkv,
                       "hybrid": hybrid, "encdec": encdec, "vlm": vlm}[cfg.family]
                arg = batch if cfg.family in ("encdec", "vlm") else batch["tokens"]
                logits, _ = mod.forward(params, arg, cfg, dist, last_only=last_only)
                return logits[:, -1]

        t0 = time.time()
        lowered = jax.jit(prefill_fn).lower(params_in, batch_in)
        t_lower = time.time() - t0
        tokens = shape.global_batch * shape.seq_len
    else:  # decode
        params_struct = jax.eval_shape(api.init, key)
        pspecs = api.param_specs(fsdp=fsdp if mode == "gspmd" else None, tp=dist.tp_axis)
        params_in = _tree_sds(params_struct, pspecs, mesh)
        B = shape.global_batch
        if cfg.family == "encdec":
            # cache needs encoder frames: eval_shape through init_cache
            from repro.models import encdec as _encdec

            frames_s = jax.ShapeDtypeStruct(
                (B, cfg.encdec.encoder_frames, cfg.d_model), jnp.bfloat16)
            cache_struct = jax.eval_shape(
                lambda p, fr: _encdec.init_cache(p, fr, cfg, B, shape.seq_len),
                params_struct, frames_s)
        else:
            cache_struct = jax.eval_shape(lambda: api.decode_init(B, shape.seq_len))
        cspecs = api.cache_specs()
        if B < _axes_size(mesh, dist.dp_axes):
            cspecs = _drop_batch_axes(cspecs, mesh)
        cache_in = _tree_sds(cache_struct, cspecs, mesh)
        tok_spec = P(dist.dp_axes) if B % _axes_size(mesh, dist.dp_axes) == 0 else P()
        token_in = _sds((B, 1), jnp.int32, mesh, tok_spec)
        index_in = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, token, cache, index):
            with use_rules(dist.rules):
                return api.decode_step(params, token, cache, index, dist)

        t0 = time.time()
        lowered = jax.jit(serve_step, donate_argnums=(2,)).lower(
            params_in, token_in, cache_in, index_in)
        t_lower = time.time() - t0
        tokens = shape.global_batch  # one token per sequence

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    n_active = analytic_param_count(cfg, active_only=True)
    flops_per_tok = model_flops_per_token(cfg)
    if shape.kind != "train":
        flops_per_tok //= 3  # forward only (no backward): 2*N*D
    model_flops = float(flops_per_tok) * tokens
    roof = roofline_from_compiled(compiled, chips, model_flops)
    stats = collective_bytes(compiled.as_text())

    result = {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "mode": mode,
        "impl": impl,
        "unrolled": unroll,
        "accum_steps": n_micro,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "tokens_per_step": tokens,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        },
        "collectives": {"bytes": stats.bytes_by_op, "count": stats.count_by_op},
        "roofline": roof.as_dict(),
    }
    return result


def _layer_period(cfg) -> int:
    return cfg.hybrid.shared_attn_every if cfg.hybrid is not None else 1


def run_cell(arch: str, shape_name: str, multi_pod: bool, impl: str = "paxi"):
    """Deployable (scan) compile for memory + exact roofline accounting.

    Accounting trick: per-layer cost is exactly linear in layer count (the
    stacks are homogeneous — hybrid archs are periodic with period
    ``shared_attn_every``), so instead of unrolling all L layers (hours for
    the 96-layer archs) we compile unrolled graphs at L1 and L2 reduced
    depths and extrapolate: total(L) = fixed + per_layer*(L) with
    per_layer = (m(L2)-m(L1))/(L2-L1).  FLOPs/bytes/collective bytes are
    all linear in L; memory_analysis comes from the deployable graph.
    """
    deploy = lower_cell(arch, shape_name, multi_pod, impl, unroll=False)
    if deploy.get("status") != "ok":
        return deploy
    cfg = cfgs.get_config(arch)
    L = cfg.num_layers
    period = _layer_period(cfg)
    if L <= 8 * period:
        acct = lower_cell(arch, shape_name, multi_pod, impl, unroll=True)
        if acct.get("status") == "ok":
            deploy["roofline"] = acct["roofline"]
            deploy["collectives"] = acct["collectives"]
            deploy["accounting"] = {"method": "full-unroll",
                                    "compile_s": acct["compile_s"],
                                    "tokens": acct["tokens_per_step"]}
        else:
            deploy["accounting_error"] = acct
        return deploy

    L1, L2 = 2 * period, 4 * period  # L=1 graphs fuse atypically; use 2/4
    acct1 = lower_cell(arch, shape_name, multi_pod, impl, unroll=True,
                       layer_override=L1)
    acct2 = lower_cell(arch, shape_name, multi_pod, impl, unroll=True,
                       layer_override=L2)
    if acct1.get("status") != "ok" or acct2.get("status") != "ok":
        deploy["accounting_error"] = (acct1 if acct1.get("status") != "ok" else acct2)
        return deploy

    def extrapolate(key):
        m1, m2 = acct1["roofline"][key], acct2["roofline"][key]
        per = (m2 - m1) / (L2 - L1)
        return max(m1 - per * L1 + per * L, 0.0)

    from repro.launch.hlo_analysis import Roofline

    # MODEL_FLOPS must use the FULL-depth config (acct graphs are shallow)
    fpt = model_flops_per_token(cfg)
    if cfgs.SHAPES_BY_NAME[shape_name].kind != "train":
        fpt //= 3
    model_flops = float(fpt) * acct1["tokens_per_step"]
    roof = Roofline(
        flops_per_device=extrapolate("flops_per_device"),
        hbm_bytes_per_device=extrapolate("hbm_bytes_per_device"),
        collective_bytes_per_device=extrapolate("collective_bytes_per_device"),
        chips=acct1["roofline"]["chips"],
        model_flops_global=model_flops,
    )
    coll = {}
    for op in set(acct1["collectives"]["bytes"]) | set(acct2["collectives"]["bytes"]):
        b1 = acct1["collectives"]["bytes"].get(op, 0)
        b2 = acct2["collectives"]["bytes"].get(op, 0)
        per = (b2 - b1) / (L2 - L1)
        coll[op] = int(max(b1 - per * L1 + per * L, 0))
    deploy["roofline"] = roof.as_dict()
    deploy["collectives"] = {"bytes": coll,
                             "count": acct2["collectives"]["count"]}
    deploy["accounting"] = {
        "method": f"layer-extrapolation L1={L1} L2={L2} -> L={L}",
        "compile_s": acct1["compile_s"] + acct2["compile_s"],
        "tokens": acct1["tokens_per_step"],
    }
    return deploy


ALL_MESHES = ("pod1", "pod2")


def iter_cells():
    for arch in cfgs.ARCH_NAMES:
        cfg = cfgs.get_config(arch)
        for shape in cfgs.shapes_for(cfg):
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod1", "pod2"], default="pod1")
    ap.add_argument("--impl", default=os.environ.get("PAX_ABI_IMPL", "paxi"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    if args.list:
        for arch, shape in iter_cells():
            for m in ALL_MESHES:
                print(f"{arch} {shape} {m}")
        return

    if args.all:
        failures = 0
        for arch, shape in iter_cells():
            for m in ALL_MESHES:
                out = RESULTS_DIR / f"{arch}__{shape}__{m}.json"
                if out.exists() and json.loads(out.read_text()).get("status") in ("ok", "skipped"):
                    print(f"[cached] {arch} {shape} {m}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", m,
                       "--impl", args.impl]
                print(f"[run] {arch} {shape} {m}", flush=True)
                try:
                    proc = subprocess.run(cmd, capture_output=True, text=True,
                                          timeout=args.timeout)
                    if proc.returncode != 0:
                        failures += 1
                        out.write_text(json.dumps({
                            "status": "failed", "arch": arch, "shape": shape,
                            "mesh": m, "stderr": proc.stderr[-2000:]}))
                        print(f"  FAILED: {proc.stderr.strip().splitlines()[-1] if proc.stderr else '?'}")
                except subprocess.TimeoutExpired:
                    failures += 1
                    out.write_text(json.dumps({
                        "status": "timeout", "arch": arch, "shape": shape, "mesh": m}))
                    print("  TIMEOUT")
        print(f"done; {failures} failures")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    t0 = time.time()
    try:
        result = run_cell(args.arch, args.shape, args.mesh == "pod2", args.impl)
    except Exception:
        result = {"status": "error", "arch": args.arch, "shape": args.shape,
                  "mesh": args.mesh, "traceback": traceback.format_exc()[-4000:]}
    result["wall_s"] = round(time.time() - t0, 2)
    variant = os.environ.get("PAX_VARIANT", "")
    suffix = f"__{variant}" if variant else ""
    out = RESULTS_DIR / f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json"
    out.write_text(json.dumps(result, indent=2, default=str))
    if result["status"] == "ok":
        mm = result["memory"]
        rf = result["roofline"]
        print(f"== {args.arch} {args.shape} {args.mesh} [{result['mode']}] "
              f"lower {result['lower_s']}s compile {result['compile_s']}s")
        print(f"   memory/device: args {mm['argument_bytes']/2**30:.2f} GiB, "
              f"temp {mm['temp_bytes']/2**30:.2f} GiB, "
              f"peak~{mm['peak_estimate_bytes']/2**30:.2f} GiB")
        print(f"   roofline: compute {rf['compute_s']*1e3:.2f} ms, "
              f"memory {rf['memory_s']*1e3:.2f} ms, "
              f"collective {rf['collective_s']*1e3:.2f} ms -> {rf['bottleneck']}"
              f"  (useful-flops {rf['useful_flops_fraction']:.2f}, "
              f"MFU-bound {rf['mfu_bound']:.2f})")
    elif result["status"] == "skipped":
        print(f"== {args.arch} {args.shape} {args.mesh}: SKIPPED ({result['reason']})")
    else:
        print(result.get("traceback", result))
        sys.exit(1)


if __name__ == "__main__":
    main()
