"""Compiled-HLO analysis: collective-traffic extraction + roofline terms.

``collective_bytes(hlo_text)`` parses the per-partition optimized HLO and
sums the payload bytes of every collective op (all-reduce, all-gather,
reduce-scatter, all-to-all, collective-permute, + their async -start
forms).  Convention: bytes(op) = max(sum of operand bytes, sum of result
bytes) — i.e. the un-sharded side of the transfer — counted once per op,
per device.  Used by EXPERIMENTS.md §Roofline and cross-checked against
the ABI ByteCounter tool.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s
ICI_LINKS = 4                 # torus links usable per chip (2D torus)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    """Bytes of 'f32[16,128]' or a tuple '(f32[2], bf16[4,4])'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    count_by_op: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    # first pass: result shapes of every definition (for operand lookup)
    result_shape: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            result_shape[m.group(1)] = m.group(2)

    bytes_by_op: dict[str, int] = defaultdict(int)
    count_by_op: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        base = None
        for c in COLLECTIVE_OPS:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        out_bytes = shape_bytes(shape_str)
        # operand bytes: resolve named operands after the op token
        tail = line[line.index(op) + len(op):]
        mo = _OPERANDS_RE.search(tail)
        in_bytes = 0
        if mo:
            for ref in re.findall(r"%([\w.\-]+)", mo.group(1)):
                if ref in result_shape:
                    in_bytes += shape_bytes(result_shape[ref])
        bytes_by_op[base] += max(out_bytes, in_bytes)
        count_by_op[base] += 1
    return CollectiveStats(dict(bytes_by_op), dict(count_by_op))


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops_global: float = 0.0  # 6*N*D

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / (ICI_BW_PER_LINK * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (global): remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-level MFU: useful FLOPs / (chips * peak * step_time)."""
        denom = self.chips * PEAK_FLOPS_BF16 * self.step_time_s
        return self.model_flops_global / denom if denom else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "model_flops_global": self.model_flops_global,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
        }


def roofline_from_compiled(compiled, chips: int, model_flops_global: float) -> Roofline:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    stats = collective_bytes(compiled.as_text())
    return Roofline(flops, hbm, float(stats.total_bytes), chips, model_flops_global)
