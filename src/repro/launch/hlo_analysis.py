"""Compiled-HLO analysis: collective-traffic extraction + roofline terms.

``collective_bytes(hlo_text)`` parses the per-partition optimized HLO and
sums the payload bytes of every collective op (all-reduce, all-gather,
reduce-scatter, all-to-all, collective-permute, + their async -start
forms).  Convention: bytes(op) = max(sum of operand bytes, sum of result
bytes) — i.e. the un-sharded side of the transfer — counted once per op,
per device.  Used by EXPERIMENTS.md §Roofline and cross-checked against
the ABI ByteCounter tool.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s
ICI_LINKS = 4                 # torus links usable per chip (2D torus)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    """Bytes of 'f32[16,128]' or a tuple '(f32[2], bf16[4,4])'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    count_by_op: dict
    # per-op HBM traffic: operand bytes + result bytes (both sides touch
    # HBM), vs ``bytes_by_op``'s max(in, out) wire convention — the term a
    # fused-kernel wire removes is memory traffic, not link traffic
    hbm_by_op: dict = dataclasses.field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    @property
    def total_hbm_bytes(self) -> int:
        return sum(self.hbm_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    # first pass: result shapes of every definition (for operand lookup)
    result_shape: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            result_shape[m.group(1)] = m.group(2)

    bytes_by_op: dict[str, int] = defaultdict(int)
    count_by_op: dict[str, int] = defaultdict(int)
    hbm_by_op: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        base = None
        for c in COLLECTIVE_OPS:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        out_bytes = shape_bytes(shape_str)
        # operand bytes: resolve named operands after the op token
        tail = line[line.index(op) + len(op):]
        mo = _OPERANDS_RE.search(tail)
        in_bytes = 0
        if mo:
            for ref in re.findall(r"%([\w.\-]+)", mo.group(1)):
                if ref in result_shape:
                    in_bytes += shape_bytes(result_shape[ref])
        bytes_by_op[base] += max(out_bytes, in_bytes)
        count_by_op[base] += 1
        hbm_by_op[base] += out_bytes + in_bytes
    return CollectiveStats(dict(bytes_by_op), dict(count_by_op),
                           dict(hbm_by_op))


# ---------------------------------------------------------------------------
# per-op wire breakdown (jaxpr level): what a fused wire kernel removed
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class WireBreakdown:
    """Materialized-output bytes of a wire schedule, split by op class.

    Counted at jaxpr level (sum of output aval bytes per equation), which is
    the robust fusion metric on CPU: XLA's elementwise fuser makes compiled
    ``cost_analysis()`` bytes identical for the fused and unfused paths,
    while the jaxpr shows exactly which intermediates each path *names* —
    the lax hop names the dequantized block, the accumulated block and the
    re-quantized block; the fused hop names only the kernel outputs.

    Classes: ``wire`` (ppermute & friends — inter-chip payload, identical on
    both paths), ``kernel`` (pallas_call outputs), ``quantize`` (narrowing
    dtype converts), ``dequantize`` (widening converts), ``compute``
    (everything else).  Pure-metadata ops (reshape/squeeze/expand_dims)
    count zero bytes.
    """

    bytes_by_class: dict
    count_by_class: dict

    @property
    def materialized_bytes(self) -> int:
        """HBM-side bytes: every class except the inter-chip ``wire``."""
        return sum(v for k, v in self.bytes_by_class.items() if k != "wire")

    def as_dict(self) -> dict:
        return {
            "bytes_by_class": dict(self.bytes_by_class),
            "count_by_class": dict(self.count_by_class),
            "materialized_bytes": self.materialized_bytes,
        }


_METADATA_PRIMS = frozenset({"reshape", "squeeze", "expand_dims"})


def _aval_bytes(aval) -> int:
    try:
        n = 1
        for d in aval.shape:
            n *= int(d)
        return n * aval.dtype.itemsize
    except (AttributeError, TypeError):
        return 0


def wire_breakdown(fn, *args) -> WireBreakdown:
    """Trace ``fn(*args)`` and classify every materialized intermediate.

    Works on any wire schedule (per-hop closures, whole plan runs).  Call
    bodies (pjit/remat/custom_*) are walked transparently; ``pallas_call``
    is a leaf — its outputs are the kernel's one write.
    """
    import jax
    from jax._src import core as jax_core

    from ..core.backends._lax import WIRE_PRIMITIVES

    bytes_by_class: dict[str, int] = defaultdict(int)
    count_by_class: dict[str, int] = defaultdict(int)

    def classify(eqn) -> Optional[str]:
        name = eqn.primitive.name
        if name in _METADATA_PRIMS:
            return None
        if name in WIRE_PRIMITIVES:
            return "wire"
        if name == "pallas_call":
            return "kernel"
        if name == "convert_element_type":
            src = eqn.invars[0].aval.dtype.itemsize
            dst = eqn.outvars[0].aval.dtype.itemsize
            return ("quantize" if dst < src
                    else "dequantize" if dst > src else "compute")
        return "compute"

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            subs = []
            if eqn.primitive.name != "pallas_call":
                for v in eqn.params.values():
                    if isinstance(v, jax_core.ClosedJaxpr):
                        subs.append(v.jaxpr)
                    elif isinstance(v, jax_core.Jaxpr):
                        subs.append(v)
            if subs:  # call-like: count the body, not the call
                for s in subs:
                    walk(s)
                continue
            cls = classify(eqn)
            if cls is None:
                continue
            count_by_class[cls] += 1
            bytes_by_class[cls] += sum(_aval_bytes(v.aval)
                                       for v in eqn.outvars)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return WireBreakdown(dict(bytes_by_class), dict(count_by_class))


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops_global: float = 0.0  # 6*N*D

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / (ICI_BW_PER_LINK * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (global): remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-level MFU: useful FLOPs / (chips * peak * step_time)."""
        denom = self.chips * PEAK_FLOPS_BF16 * self.step_time_s
        return self.model_flops_global / denom if denom else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "model_flops_global": self.model_flops_global,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
        }


def roofline_from_compiled(compiled, chips: int, model_flops_global: float) -> Roofline:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    stats = collective_bytes(compiled.as_text())
    return Roofline(flops, hbm, float(stats.total_bytes), chips, model_flops_global)
