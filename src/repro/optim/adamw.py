"""AdamW, pure JAX, in two layouts:

* **tree**: classic per-leaf moments (used in "gspmd" mode, where XLA shards
  optimizer state like the params via in_shardings);
* **flat/ZeRO-1**: moments live only for this data-parallel rank's shard of
  the flattened gradient vector (used in "abi" mode: the gradient is
  reduce-scattered through the ABI, the update is computed on the shard,
  and the update vector is all-gathered back — DeepSpeed-style ZeRO-1).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamState(NamedTuple):
    step: jax.Array
    m: jax.typing.ArrayLike
    v: jax.typing.ArrayLike


def init_tree(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree.map(jnp.copy, zeros))


def update_tree(cfg: AdamWConfig, grads, state: AdamState, params, lr_scale=1.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / (1 - cfg.b1 ** t)
        vhat = v2 / (1 - cfg.b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * lr_scale * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(step, new_m, new_v), gnorm


# ---------------------------------------------------------------------------
# flat / ZeRO-1
# ---------------------------------------------------------------------------
class FlatAdamState(NamedTuple):
    step: jax.Array
    m: jax.Array   # (shard,) f32 — only this dp-rank's shard
    v: jax.Array
    #: error-feedback buffer.  Per-rank state (each rank's own quantization
    #: residual over the FULL flat vector), so the global-view layout is
    #: (dp * padded,) sharded over the dp axes — every rank sees its
    #: (padded,) residual inside the train step's shard_map region.  A
    #: (dp,)-shaped dummy (one element per rank) when compression is off.
    ef: jax.Array


def flat_size(params) -> int:
    return sum(int(jnp.size(jax.eval_shape(lambda: p) if callable(p) else p))
               for p in jax.tree.leaves(params))


def flatten(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def unflatten_like(vec, tree):
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(jnp.size(l)) if not hasattr(l, "size") else int(l.size)
        out.append(vec[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def zero1_padded_size(n: int, dp_size: int, buckets: int = 1) -> int:
    """Flat-vector length padded so ``dp_size * buckets`` divides it — the
    shared contract between ``init_flat_global``, ``grad_sync.zero1_step``
    bucketing and the train-loop wiring."""
    m = dp_size * max(buckets, 1)
    return -(-n // m) * m


def init_flat_global(params, dp_size: int, *, buckets: int = 1,
                     with_ef: bool = False) -> FlatAdamState:
    """Global-view flat optimizer state: (padded,) moment vectors meant to be
    sharded over the dp axes (each rank sees its (padded/dp,) shard inside
    the train step's shard_map region).  With ``with_ef`` the error-feedback
    buffer is (dp * padded,) — per-rank full-length residuals, sharded the
    same way (see :class:`FlatAdamState`)."""
    n = sum(int(p.size) for p in jax.tree.leaves(params))
    padded = zero1_padded_size(n, dp_size, buckets)
    return FlatAdamState(
        jnp.zeros((), jnp.int32),
        jnp.zeros((padded,), jnp.float32),
        jnp.zeros((padded,), jnp.float32),
        jnp.zeros((dp_size * padded if with_ef else dp_size,), jnp.float32),
    )


def init_flat(params, dp_size: int, with_ef: bool) -> FlatAdamState:
    n = sum(int(p.size) for p in jax.tree.leaves(params))
    padded = zero1_padded_size(n, dp_size)
    shard = padded // dp_size
    return FlatAdamState(
        jnp.zeros((), jnp.int32),
        jnp.zeros((shard,), jnp.float32),
        jnp.zeros((shard,), jnp.float32),
        jnp.zeros((padded if with_ef else 1,), jnp.float32),
    )


def update_flat_shard(cfg: AdamWConfig, g_shard, state: FlatAdamState,
                      p_shard, gnorm, lr_scale=1.0):
    """AdamW on this rank's flat shard. g_shard/p_shard: (shard,) f32."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    g = g_shard * scale
    m2 = cfg.b1 * state.m + (1 - cfg.b1) * g
    v2 = cfg.b2 * state.v + (1 - cfg.b2) * jnp.square(g)
    mhat = m2 / (1 - cfg.b1 ** t)
    vhat = v2 / (1 - cfg.b2 ** t)
    delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p_shard
    new_p_shard = p_shard - cfg.lr * lr_scale * delta
    return new_p_shard, FlatAdamState(step, m2, v2, state.ef)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def warmup_cosine(step, *, warmup: int, total: int, floor: float = 0.1):
    t = step.astype(jnp.float32)
    wu = jnp.minimum(t / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return wu * cos
