"""Checkpointing: async sharded save, atomic publish, elastic restore.

* Each save writes one ``.npz`` per host shard (here: per process) plus a
  JSON manifest with the pytree structure and step; the directory is
  written under a temp name and atomically renamed — a torn save can never
  be mistaken for a checkpoint (crash safety).
* ``save_async`` snapshots device arrays to host then writes in a
  background thread, overlapping I/O with the next training steps.
* ``restore`` rebuilds the pytree; **elastic resharding** comes for free:
  arrays are restored as host numpy and re-placed with whatever sharding
  the (possibly different-sized) new mesh prescribes — the ABI allgather
  path is exercised when re-placing dp-replicated trees.
* ``latest_step`` / ``gc_old`` implement retention for the restart
  supervisor (runtime/fault.py).
* **Content integrity** (PR 10): the manifest records a CRC32 per shard
  file at ``_write`` time; ``restore`` verifies before unpacking, and a
  corrupt or torn shard falls back — loudly, via ``integrity_events`` —
  to the previous retained checkpoint instead of restoring garbage.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _file_crc32(path: Path) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                break
            crc = zlib.crc32(chunk, crc)
    return crc & 0xFFFFFFFF


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed content verification (CRC mismatch, torn or
    unreadable shard).  ``restore`` raises it only when NO retained
    checkpoint at or below the requested step verifies."""


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None
        # loud record of every integrity fallback this checkpointer took:
        # dicts with the rejected step, the reason, and the step restored
        # instead (the restart supervisor copies this into its report)
        self.integrity_events: list[dict] = []

    # -- save --------------------------------------------------------------
    def save(self, step: int, state) -> Path:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        return self._write(step, host_state)

    def save_async(self, step: int, state) -> None:
        """Snapshot to host memory synchronously, write in the background."""
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self._pending = threading.Thread(
            target=self._write, args=(step, host_state), daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_state) -> Path:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves = _flatten_with_names(host_state)
        arrays = {f"leaf_{i}": np.asarray(v) for i, (_, v) in enumerate(leaves)}
        np.savez(tmp / "shard_0.npz", **arrays)
        treedef = jax.tree.structure(host_state)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "names": [n for n, _ in leaves],
            "treedef": str(treedef),
            "time": time.time(),
            # content integrity: CRC32 of each shard file as written — the
            # restore side rejects any bit-flip or truncation before np.load
            "shard_crc32": {"shard_0.npz": _file_crc32(tmp / "shard_0.npz")},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()
        return final

    # -- restore -------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if p.is_dir()
        )
        return steps[-1] if steps else None

    def _retained_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if p.is_dir()
        )

    def _verify(self, path: Path) -> Optional[str]:
        """Content check for one checkpoint directory: ``None`` when every
        shard matches its manifest CRC, else the human-readable reason."""
        try:
            manifest = json.loads((path / "manifest.json").read_text())
        except (OSError, ValueError) as e:
            return f"unreadable manifest ({e})"
        # pre-PR-10 checkpoints carry no CRCs: nothing to verify against
        for shard, want in manifest.get("shard_crc32", {}).items():
            f = path / shard
            if not f.exists():
                return f"missing shard {shard}"
            got = _file_crc32(f)
            if got != want:
                return (f"shard {shard} CRC mismatch "
                        f"(manifest {want:#010x}, file {got:#010x})")
        return None

    def restore(self, like, step: Optional[int] = None, mesh=None, specs=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  If mesh+specs given, device_put each leaf with
        its NamedSharding — this is the elastic-reshard path (the new mesh
        may have a different dp size than the one that saved).

        Every candidate checkpoint is CRC-verified before unpacking; a
        corrupt or torn one is recorded in ``integrity_events`` and the
        previous retained checkpoint is tried instead.  Only when no
        retained checkpoint verifies does :class:`CheckpointCorrupt`
        propagate — restoring garbage is never an outcome.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        candidates = [s for s in self._retained_steps() if s <= step]
        if not candidates:
            raise FileNotFoundError(
                f"no checkpoint at or below step {step} under {self.dir}")
        rejected: list[dict] = []
        for s in reversed(candidates):
            path = self.dir / f"step_{s:010d}"
            reason = self._verify(path)
            if reason is None:
                try:
                    data = np.load(path / "shard_0.npz")
                    manifest = json.loads((path / "manifest.json").read_text())
                    leaves = [data[f"leaf_{i}"]
                              for i in range(manifest["n_leaves"])]
                except Exception as e:  # torn write that still matched CRC
                    reason = f"unreadable shard ({e})"
            if reason is not None:
                event = {"step": s, "reason": reason, "fell_back_to": None}
                rejected.append(event)
                self.integrity_events.append(event)
                continue
            for event in rejected:
                event["fell_back_to"] = s
            treedef = jax.tree.structure(like)
            restored = jax.tree.unflatten(treedef, leaves)
            if mesh is not None and specs is not None:
                from jax.sharding import NamedSharding

                restored = jax.tree.map(
                    lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
                    restored, specs,
                    is_leaf=lambda v: isinstance(v, np.ndarray),
                )
            return restored, s
        raise CheckpointCorrupt(
            f"every retained checkpoint at or below step {step} failed "
            f"verification: {rejected}")

    def _gc(self) -> None:
        steps = sorted(
            (int(p.name.split("_")[1]), p) for p in self.dir.glob("step_*") if p.is_dir()
        )
        for _, p in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(p, ignore_errors=True)
