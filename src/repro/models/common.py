"""Shared model building blocks: norms, activations, RoPE, embeddings,
initializers — pure JAX (params are plain pytrees of jnp arrays).

Every ``init_*`` function has a sibling ``spec_*`` producing a
PartitionSpec tree of identical structure (checked by tests); logical axes:

* ``tp``   — the tensor-parallel ("model") mesh axis
* ``fsdp`` — the fully-sharded-data-parallel axes ("pod","data")

The spec functions receive the axis names so configs can remap.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def spec_norm(kind: str):
    if kind == "rmsnorm":
        return {"scale": P(None)}
    return {"scale": P(None), "bias": P(None)}


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def activation_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": partial(jax.nn.gelu, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron squared-ReLU
    }[name]


GLU_ACTIVATIONS = {"swiglu": "silu", "geglu": "gelu"}


def is_glu(name: str) -> bool:
    return name in GLU_ACTIVATIONS


# ---------------------------------------------------------------------------
# RoPE (full or partial fraction — chatglm applies rotary to half the dims)
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, fraction: float, theta: float):
    rot_dim = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv, rot_dim = rope_frequencies(head_dim, fraction, theta)
    if rot_dim == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., :, None, :].astype(x.dtype)  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :].astype(x.dtype)
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    xr = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr, xp], axis=-1) if rot_dim < head_dim else xr


def sinusoidal_positions(length: int, dim: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d: int, dtype, tie: bool):
    p = {"tok": embed_init(key, vocab, d, dtype)}
    if not tie:
        p["unembed"] = dense_init(jax.random.fold_in(key, 1), d, vocab, dtype)
    return p


def spec_embedding(tie: bool, tp: str, fsdp, vocab: int = 0, tp_size: int = 0):
    # vocab over tp only when even (e.g. whisper's 51865 is not)
    v_tp = tp if not tp_size or (vocab and vocab % tp_size == 0) else None
    p = {"tok": P(v_tp, fsdp)}  # vocab over tp, embed over fsdp
    if not tie:
        p["unembed"] = P(fsdp, v_tp)
    return p


def embed_tokens(params, tokens, d_model: int, compute_dtype):
    return params["tok"].astype(compute_dtype)[tokens] * 1.0


def unembed(params, x, tie: bool):
    w = params["tok"].T if tie else params["unembed"]
    return x @ w.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_cross_entropy(logits, targets, ignore_id: int = -1, z_loss: float = 1e-4):
    """Token-mean CE with optional z-loss; fp32 reduction."""
    logits = logits.astype(jnp.float32)
    mask = (targets != ignore_id).astype(jnp.float32)
    tclip = jnp.maximum(targets, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, tclip[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll.sum() + zl.sum()) / denom


# ---------------------------------------------------------------------------
# remat policies
# ---------------------------------------------------------------------------
def remat_policy(name: str):
    if name == "none":
        return None
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    raise ValueError(name)


def maybe_remat(fn, name: str):
    policy = remat_policy(name)
    if name == "none":
        return fn
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def scan_layers(scan_fn, init, xs, length: int, use_scan: bool):
    """lax.scan over stacked layers, or a Python unroll with identical
    semantics.  The dry-run unrolls because XLA cost analysis does not
    multiply while-body FLOPs by trip count (see launch/dryrun.py)."""
    if use_scan:
        return jax.lax.scan(scan_fn, init, xs)
    carry = init
    ys = []
    for i in range(length):
        xs_i = jax.tree.map(lambda v: v[i], xs)
        carry, y = scan_fn(carry, xs_i)
        ys.append(y)
    if ys and all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *vals: jnp.stack(vals), *ys)
    return carry, stacked
