"""RWKV6 "Finch" — attention-free LM with data-dependent per-channel decay.

Time-mix: data-dependent token-shift (ddlerp with a low-rank adapter), the
WKV6 recurrence

    y_t[j] = sum_i r_t[i] * (S[i,j] + u[i] k_t[i] v_t[j])
    S[i,j] <- w_t[i] * S[i,j] + k_t[i] * v_t[j]

computed in **chunked** matmul form (MXU-friendly; log-space cumulative
decays, clamped for fp32 stability), with the chunk state carried by
``lax.scan``.  ``repro.kernels.rwkv6_scan`` is the Pallas TPU kernel of the
same math; its ref.py sequential scan is the ground truth both are tested
against.  Channel-mix: relu^2 FFN with token-shift gates (v6).

Decode uses the O(1) recurrent state — this is why rwkv6-7b runs the
long_500k cell (no KV cache; state is (H, N, N) per sequence).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime.sharding import shard
from .common import (
    apply_norm,
    scan_layers,
    dense_init,
    dtype_of,
    embed_tokens,
    init_embedding,
    init_norm,
    maybe_remat,
    softmax_cross_entropy,
    spec_embedding,
    spec_norm,
    unembed,
)

LORA_DIM = 32
WLOG_MIN, WLOG_MAX = -5.0, -1e-4  # per-step log-decay clamp (fp32-stable chunks)


class RwkvState(NamedTuple):
    """Recurrent decode state per layer-stack: token-shift + WKV state."""

    shift_tm: jax.Array  # (L, B, d)   last input to time-mix
    shift_cm: jax.Array  # (L, B, d)   last input to channel-mix
    wkv: jax.Array       # (L, B, H, N, N)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_rwkv_layer(key, cfg):
    d = cfg.d_model
    dtype = dtype_of(cfg.param_dtype)
    N = cfg.ssm.head_dim
    H = d // N
    ks = jax.random.split(key, 12)
    branches = ("r", "k", "v", "w", "g")
    p = {
        "ln1": init_norm(d, cfg.norm),
        "ln2": init_norm(d, cfg.norm),
        "mu_base": jnp.zeros((d,), jnp.float32),
        "mu": jnp.zeros((len(branches), d), jnp.float32),
        "lora_a": dense_init(ks[0], d, LORA_DIM * len(branches), jnp.float32),
        "lora_b": (jax.random.normal(ks[1], (len(branches), LORA_DIM, d)) * 0.01).astype(jnp.float32),
        "wr": dense_init(ks[2], d, d, dtype),
        "wk": dense_init(ks[3], d, d, dtype),
        "wv": dense_init(ks[4], d, d, dtype),
        "wg": dense_init(ks[5], d, d, dtype),
        "wo": dense_init(ks[6], d, d, dtype, scale=1.0 / math.sqrt(2 * cfg.num_layers)),
        "w0": jnp.full((d,), -2.0, jnp.float32),  # base log-log decay
        "u": (jax.random.normal(ks[7], (d,)) * 0.1).astype(jnp.float32),
        "ln_x": init_norm(N, "layernorm"),  # per-head group norm
        # channel mix
        "cm_mu_k": jnp.zeros((d,), jnp.float32),
        "cm_mu_r": jnp.zeros((d,), jnp.float32),
        "cm_wk": dense_init(ks[8], d, cfg.d_ff, dtype),
        "cm_wv": dense_init(ks[9], cfg.d_ff, d, dtype),
        "cm_wr": dense_init(ks[10], d, d, dtype),
    }
    return p


def spec_rwkv_layer(cfg, fsdp, tp):
    return {
        "ln1": spec_norm(cfg.norm),
        "ln2": spec_norm(cfg.norm),
        "mu_base": P(None),
        "mu": P(None, None),
        "lora_a": P(fsdp, None),
        "lora_b": P(None, None, fsdp),
        "wr": P(fsdp, tp),
        "wk": P(fsdp, tp),
        "wv": P(fsdp, tp),
        "wg": P(fsdp, tp),
        "wo": P(tp, fsdp),
        "w0": P(None),
        "u": P(None),
        "ln_x": spec_norm("layernorm"),
        "cm_mu_k": P(None),
        "cm_mu_r": P(None),
        "cm_wk": P(fsdp, tp),
        "cm_wv": P(tp, fsdp),
        "cm_wr": P(fsdp, tp),
    }


# ---------------------------------------------------------------------------
# chunked WKV6 (matmul form, log-space decays)
# ---------------------------------------------------------------------------
def wkv6_chunked(r, k, v, wlog, u, state, chunk: int):
    """r,k,v: (B,T,H,N); wlog: (B,T,H,N) per-step log decay (clamped <0);
    u: (H,N); state: (B,H,N,N).  Returns (y, final_state)."""
    B, T, Hh, N = r.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    rc = r.reshape(B, nc, chunk, Hh, N).transpose(1, 0, 3, 2, 4)  # (nc,B,H,c,N)
    kc = k.reshape(B, nc, chunk, Hh, N).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nc, chunk, Hh, N).transpose(1, 0, 3, 2, 4)
    wc = wlog.reshape(B, nc, chunk, Hh, N).transpose(1, 0, 3, 2, 4)

    def one_chunk(S, xs):
        rr, kk, vv, ww = xs  # (B,H,c,N)
        la = jnp.cumsum(ww, axis=2)            # log A_{t+1} = sum_{s<=t} log w_s
        la_incl = la                            # after step t
        la_prev = la - ww                       # before step t (log A_t)
        q_t = rr * jnp.exp(la_prev)             # r_t * A_t
        k_t = kk * jnp.exp(-la_incl)            # k_s / A_{s+1}
        att = jnp.einsum("bhtn,bhsn->bhts", q_t, k_t)
        tri = jnp.tril(jnp.ones((rr.shape[2], rr.shape[2]), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        diag = jnp.einsum("bhtn,bhtn->bht", rr, u[None, :, None, :] * kk)
        y = jnp.einsum("bhts,bhsn->bhtn", att, vv) + diag[..., None] * vv
        y = y + jnp.einsum("bhtn,bhnm->bhtm", q_t, S)  # inter-chunk
        a_end = jnp.exp(la_incl[:, :, -1:, :])          # (B,H,1,N) total decay
        k_scaled = kk * jnp.exp(la_incl[:, :, -1:, :] - la_incl)
        S_new = a_end.squeeze(2)[..., None] * S + jnp.einsum(
            "bhtn,bhtm->bhnm", k_scaled, vv
        )
        return S_new, y

    state, ys = jax.lax.scan(one_chunk, state, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, Hh, N)
    return y, state


def wkv6_step(r, k, v, wlog, u, state):
    """Single-token recurrence. r..: (B,H,N); state: (B,H,N,N)."""
    kv = jnp.einsum("bhi,bhj->bhij", k, v)
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    state = jnp.exp(wlog)[..., None] * state + kv
    return y, state


# ---------------------------------------------------------------------------
# time-mix / channel-mix
# ---------------------------------------------------------------------------
def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift (v6). Returns the 5 mixed branches."""
    xx = x_prev - x
    base = x + xx * p["mu_base"].astype(x.dtype)
    lora = jnp.tanh(base.astype(jnp.float32) @ p["lora_a"])
    lora = lora.reshape(*lora.shape[:-1], 5, LORA_DIM)
    dyn = jnp.einsum("...kl,kld->...kd", lora, p["lora_b"])
    mixes = p["mu"][None, None] + dyn  # (..., 5, d)
    return [x + xx * mixes[..., i, :].astype(x.dtype) for i in range(5)]


def time_mix(p, x, x_prev, cfg, state=None, chunk=32):
    """x: (B,T,d) (chunked path, x_prev = shifted x) or (B,1,d) with state."""
    d = cfg.d_model
    N = cfg.ssm.head_dim
    Hh = d // N
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = (xr @ p["wr"].astype(x.dtype)).reshape(*x.shape[:2], Hh, N).astype(jnp.float32)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(*x.shape[:2], Hh, N).astype(jnp.float32)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(*x.shape[:2], Hh, N).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    wlog_raw = p["w0"][None, None] + (xw.astype(jnp.float32) @ p["lora_a"][:, :LORA_DIM]) @ p["lora_b"][3]
    wlog = jnp.clip(-jnp.exp(wlog_raw), WLOG_MIN, WLOG_MAX)
    wlog = wlog.reshape(*x.shape[:2], Hh, N)
    u = p["u"].reshape(Hh, N)

    if state is None:
        B = x.shape[0]
        S0 = jnp.zeros((B, Hh, N, N), jnp.float32)
        y, S = wkv6_chunked(r, k, v, wlog, u, S0, chunk)
    else:
        y, S = wkv6_step(r[:, 0], k[:, 0], v[:, 0], wlog[:, 0], u, state)
        y = y[:, None]
    # per-head group norm, then gate and project
    y = apply_norm(p["ln_x"], y, "layernorm")
    y = y.reshape(*x.shape[:2], d).astype(x.dtype) * g
    return y @ p["wo"].astype(x.dtype), S


def channel_mix(p, x, x_prev, cfg):
    xx = x_prev - x
    xk = x + xx * p["cm_mu_k"].astype(x.dtype)
    xr = x + xx * p["cm_mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(x.dtype)))
    kk = shard(kk, "batch", "seq", "ffn")
    return jax.nn.sigmoid(xr @ p["cm_wr"].astype(x.dtype)) * (kk @ p["cm_wv"].astype(x.dtype))


def _shift(x):
    """x_prev[t] = x[t-1] (zeros at t=0)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _layer_fwd(p, x, cfg):
    h = apply_norm(p["ln1"], x, cfg.norm)
    y, _ = time_mix(p, h, _shift(h), cfg, chunk=cfg.ssm.chunk_size)
    x = x + y
    h2 = apply_norm(p["ln2"], x, cfg.norm)
    x = x + channel_mix(p, h2, _shift(h2), cfg)
    return shard(x, "batch", "seq", "embed")


def _layer_step(p, x, st_tm, st_cm, wkv, cfg):
    """Single-token step. x: (B,1,d). Shift states are stored f32; cast to
    the stream dtype so the scan carry dtype stays stable."""
    h = apply_norm(p["ln1"], x, cfg.norm)
    y, wkv = time_mix(p, h, st_tm[:, None].astype(h.dtype), cfg, state=wkv)
    x = x + y
    h2 = apply_norm(p["ln2"], x, cfg.norm)
    x = x + channel_mix(p, h2, st_cm[:, None].astype(h2.dtype), cfg)
    return x, h[:, 0].astype(jnp.float32), h2[:, 0].astype(jnp.float32), wkv


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def init_lm(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    k_embed, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: init_rwkv_layer(k, cfg))(layer_keys)
    return {
        "embed": init_embedding(k_embed, cfg.vocab_size, cfg.d_model, dtype,
                                cfg.tie_embeddings),
        "layers": layers,
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }


def spec_lm(cfg, fsdp="data", tp="model"):
    layer = spec_rwkv_layer(cfg, fsdp, tp)
    stacked = jax.tree.map(lambda s: P(None, *s), layer,
                           is_leaf=lambda v: isinstance(v, P))
    return {
        "embed": spec_embedding(cfg.tie_embeddings, tp, fsdp,
                                 vocab=cfg.vocab_size, tp_size=cfg.parallelism.tp_size),
        "layers": stacked,
        "final_norm": spec_norm(cfg.norm),
    }


def forward(params, tokens, cfg, dist=None, last_only=False):
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cfg.d_model, cdt)
    x = shard(x, "batch", "seq", "embed")
    body = maybe_remat(lambda pl, xx: (_layer_fwd(pl, xx, cfg), 0.0),
                       cfg.parallelism.remat)

    def scan_fn(carry, pl):
        y, _ = body(pl, carry)
        return y, jnp.zeros((), jnp.float32)

    x, _ = scan_layers(scan_fn, x, params["layers"], cfg.num_layers,
                       cfg.parallelism.scan_layers)
    if last_only:
        x = x[:, -1:]
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return shard(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, dist=None):
    logits, aux = forward(params, batch["tokens"], cfg, dist)
    return softmax_cross_entropy(logits, batch["targets"]) + aux


def init_state(cfg, batch: int) -> RwkvState:
    d, L = cfg.d_model, cfg.num_layers
    N = cfg.ssm.head_dim
    Hh = d // N
    return RwkvState(
        jnp.zeros((L, batch, d), jnp.float32),
        jnp.zeros((L, batch, d), jnp.float32),
        jnp.zeros((L, batch, Hh, N, N), jnp.float32),
    )


def state_specs(cfg) -> RwkvState:
    b = P(None, ("pod", "data"), None)
    return RwkvState(b, b, P(None, ("pod", "data"), "model", None, None))


def decode_step(params, token, state: RwkvState, index, cfg, dist=None):
    """One-token decode. The 'KV cache' is the O(1) recurrent state."""
    cdt = dtype_of(cfg.compute_dtype)
    x = embed_tokens(params["embed"], token, cfg.d_model, cdt)

    def scan_fn(carry, xs):
        pl, st_tm, st_cm, wkv = xs
        y, tm, cm, wkv = _layer_step(pl, carry, st_tm, st_cm, wkv, cfg)
        return y, (tm, cm, wkv)

    x, (tm, cm, wkv) = scan_layers(
        scan_fn, x, (params["layers"], state.shift_tm, state.shift_cm, state.wkv),
        cfg.num_layers, cfg.parallelism.scan_layers,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits[:, 0, :], RwkvState(tm, cm, wkv)
