"""Dense FFN variants: GLU (swiglu/geglu) and plain (gelu/relu²/silu)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime.sharding import shard
from .common import GLU_ACTIVATIONS, activation_fn, dense_init, dtype_of, is_glu


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype):
    ks = jax.random.split(key, 3)
    if is_glu(activation):
        return {
            "wi": dense_init(ks[0], d_model, d_ff, dtype),
            "wg": dense_init(ks[1], d_model, d_ff, dtype),
            "wo": dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {
        "wi": dense_init(ks[0], d_model, d_ff, dtype),
        "wo": dense_init(ks[2], d_ff, d_model, dtype),
    }


def spec_mlp(activation: str, fsdp, tp):
    if is_glu(activation):
        return {"wi": P(fsdp, tp), "wg": P(fsdp, tp), "wo": P(tp, fsdp)}
    return {"wi": P(fsdp, tp), "wo": P(tp, fsdp)}


def mlp(params, x, activation: str):
    if is_glu(activation):
        act = activation_fn(GLU_ACTIVATIONS[activation])
        h = act(x @ params["wg"].astype(x.dtype)) * (x @ params["wi"].astype(x.dtype))
    else:
        act = activation_fn(activation)
        h = act(x @ params["wi"].astype(x.dtype))
    h = shard(h, "batch", "seq", "ffn")
    return h @ params["wo"].astype(x.dtype)
