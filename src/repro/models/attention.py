"""Grouped-query attention: training (full seq), prefill, and cached decode.

Supports MHA / GQA / MQA via ``num_kv_heads``, partial RoPE (chatglm),
QKV bias (qwen2), large head_dim (gemma), and cross-attention (whisper).
The XLA path below is the dry-run/default implementation;
``repro.kernels.flash_attention`` is the TPU Pallas kernel with identical
semantics (tests assert allclose against this module's math via ref.py).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime.sharding import shard
from .common import apply_rope, dense_init


class KVCache(NamedTuple):
    k: jax.Array  # (batch, max_seq, kv_heads, head_dim)
    v: jax.Array
    # position handled by the caller (one index for the whole model)


def init_attention(key, cfg, d_in: Optional[int] = None):
    d = d_in if d_in is not None else cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    dtype = _pdtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nq * hd, dtype),
        "wk": dense_init(ks[1], d, nkv * hd, dtype),
        "wv": dense_init(ks[2], d, nkv * hd, dtype),
        "wo": dense_init(ks[3], nq * hd, d, dtype, scale=1.0 / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


def spec_attention(cfg, fsdp, tp):
    """TP-shard projections only when whole heads divide the model axis —
    intra-head splits are both slow and (inside partial-manual shard_map
    regions) a known XLA partitioner hazard.  Otherwise replicate over tp
    (Megatron's GQA/MQA practice)."""
    ts = cfg.parallelism.tp_size
    q_tp = tp if ts and cfg.num_heads % ts == 0 else None
    kv_tp = tp if ts and cfg.num_kv_heads % ts == 0 else None
    p = {
        "wq": P(fsdp, q_tp),
        "wk": P(fsdp, kv_tp),
        "wv": P(fsdp, kv_tp),
        "wo": P(q_tp, fsdp),
    }
    if cfg.qkv_bias:
        p.update({"bq": P(q_tp), "bk": P(kv_tp), "bv": P(kv_tp)})
    return p


def _pdtype(cfg):
    from .common import dtype_of

    return dtype_of(cfg.param_dtype)


def _project_qkv(params, x, cfg):
    hd = cfg.resolved_head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    B, S = x.shape[0], x.shape[1]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    return q, k, v


def _sdpa(q, k, v, *, causal: bool, q_offset=0):
    """q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) with Hq = G*Hkv."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Skv)[None, :]
        mask = qpos >= kpos
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, Hq, D)


BLOCKWISE_Q = 512


def _sdpa_blockwise(q, k, v, *, block_q: int = BLOCKWISE_Q):
    """Causal attention computed per query block against only its causal
    KV prefix — the XLA-level counterpart of the Pallas flash kernel
    (kernels/flash_attention) and the §Perf optimization over the naive
    full-S^2 path:

    * FLOPs: sum_i (i+1)/n of the full rectangle ~= (n+1)/2n — a ~2x cut;
    * memory: only one (block_q x prefix) score tile is live at a time
      instead of the full (S x S) matrix.

    Static Python loop over blocks (each with a static prefix length), so
    shapes stay static; layer-level scan keeps HLO growth bounded.
    """
    B, S, Hq, D = q.shape
    # cap the block count so very long sequences don't explode HLO size
    # (compile time); >=2048-wide blocks at 32k keep the flops saving ~47%
    while S // block_q > 16:
        block_q *= 2
    if S % block_q or S <= block_q:
        return _sdpa(q, k, v, causal=True)
    nq = S // block_q
    outs = []
    for i in range(nq):
        qb = jax.lax.slice_in_dim(q, i * block_q, (i + 1) * block_q, axis=1)
        kb = jax.lax.slice_in_dim(k, 0, (i + 1) * block_q, axis=1)
        vb = jax.lax.slice_in_dim(v, 0, (i + 1) * block_q, axis=1)
        outs.append(_sdpa(qb, kb, vb, causal=True, q_offset=i * block_q))
    return jnp.concatenate(outs, axis=1)


def _flash_or_sdpa(q, k, v):
    """``attention_impl == "flash"``: route through the kernel registry
    (:mod:`repro.kernels`) — the Pallas flash kernel with ``interpret=``
    bound for the platform, or the lax ``_sdpa`` fallback when the registry
    has no runnable variant.  Same contract as the backend wire kernels:
    the config names the kernel, the registry picks the implementation."""
    from ..kernels import resolve
    mode, fn = resolve("flash_attention")
    if mode != "pallas" or fn is None:
        return _sdpa(q, k, v, causal=True)
    return fn(q, k, v, causal=True)


def attention(params, x, cfg, *, positions, causal=True, kv_cache: Optional[KVCache] = None,
              cache_index=None, cross_kv=None):
    """Returns (out, new_cache).

    * train/prefill: kv_cache is None (or provided empty to be filled)
    * decode: x is (B, 1, D); kv_cache holds past K/V; cache_index is the
      write position (scalar int32)
    * cross-attention: cross_kv = (k, v) precomputed from the encoder
    """
    if cross_kv is not None:
        hd = cfg.resolved_head_dim
        q = (x @ params["wq"].astype(x.dtype)).reshape(
            x.shape[0], x.shape[1], cfg.num_heads, hd
        )
        k, v = cross_kv
        out = _sdpa(q, k, v, causal=False)
        out = out.reshape(*x.shape[:2], -1)
        return out @ params["wo"].astype(x.dtype), None

    q, k, v = _project_qkv(params, x, cfg)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)

    if kv_cache is None:
        if causal and cfg.attention_impl == "blockwise":
            out = _sdpa_blockwise(q, k, v)
        elif causal and cfg.attention_impl == "flash":
            out = _flash_or_sdpa(q, k, v)
        else:
            out = _sdpa(q, k, v, causal=causal)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(kv_cache.k, k.astype(kv_cache.k.dtype),
                                                 cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(kv_cache.v, v.astype(kv_cache.v.dtype),
                                                 cache_index, axis=1)
        kv_cache = KVCache(ck, cv)
        # causal-valid mask: key position <= absolute query position
        Sq, Skv = x.shape[1], ck.shape[1]
        qpos = cache_index + jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Skv)[None, :]
        valid = kpos <= qpos  # (Sq, Skv)
        out = _sdpa_decode(q, ck, cv, valid)
    out = out.reshape(*x.shape[:2], -1)
    out = out @ params["wo"].astype(x.dtype)
    return out, kv_cache


def _sdpa_decode(q, k, v, valid):
    """``valid``: (Sq, Skv) shared across the batch, or (B, Sq, Skv) for
    per-request masks (the paged path, where each row's length differs)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(q.dtype)).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    mask = (valid[None, None, None, :, :] if valid.ndim == 2
            else valid[:, None, None, :, :])
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(q.dtype))
    return out.reshape(B, Sq, Hq, D)


# ---------------------------------------------------------------------------
# Paged KV: attention that reads through per-request block tables (the
# serving tier's decode path — see repro/serve/kv_cache.py for the
# allocator; blocks are (block_size, Hkv, D) slabs and a block table maps a
# request's logical page j to its physical block table[b, j]).
# ---------------------------------------------------------------------------
def paged_update(k_pages, v_pages, k_new, v_new, block_table, positions):
    """Scatter new K/V rows into their pages.

    ``k_pages``/``v_pages``: (num_blocks, block_size, Hkv, D);
    ``k_new``/``v_new``: (B, S, Hkv, D) already rotated; ``block_table``:
    (B, W) int32 physical ids; ``positions``: (B, S) absolute write
    positions.  Inactive rows point their table at the reserved null block,
    so their writes land in memory no live request reads.
    """
    bs = k_pages.shape[1]
    blk = jnp.take_along_axis(block_table, positions // bs, axis=1)  # (B, S)
    off = positions % bs
    k_pages = k_pages.at[blk, off].set(k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[blk, off].set(v_new.astype(v_pages.dtype))
    return k_pages, v_pages


def paged_attention(q, k_pages, v_pages, block_table, qpos):
    """Attention over a paged cache through per-request block tables.

    ``q``: (B, Sq, Hq, D) rotated queries at absolute positions ``qpos``
    (B, Sq); ``k_pages``/``v_pages``: (num_blocks, block_size, Hkv, D);
    ``block_table``: (B, W).  The gather materializes each request's W
    pages in logical order, so key position ``j`` of the gathered view IS
    absolute position ``j`` of the sequence; the causal-valid mask
    ``kpos <= qpos`` then masks both the unwritten tail and the null-block
    padding in one stroke.
    """
    B, W = block_table.shape
    bs = k_pages.shape[1]
    k = k_pages[block_table].reshape(B, W * bs, *k_pages.shape[2:])
    v = v_pages[block_table].reshape(B, W * bs, *v_pages.shape[2:])
    kpos = jnp.arange(W * bs, dtype=jnp.int32)
    valid = kpos[None, None, :] <= qpos[:, :, None]  # (B, Sq, W*bs)
    return _sdpa_decode(q, k, v, valid)


def attention_paged(params, x, cfg, k_pages, v_pages, block_table, positions):
    """One attention block over a paged cache (the serving decode path).

    ``x``: (B, Sq, d) at absolute ``positions`` (B, Sq); ``k_pages``/
    ``v_pages``: (num_blocks, block_size, Hkv, D); ``block_table``: (B, W).
    Projects QKV, rotates, scatters the new K/V rows into their pages
    (write-then-attend: a token attends to itself and every predecessor in
    the same chunk), and attends through the block table.  Returns
    ``(out, (k_pages, v_pages))`` with the updated pages.
    """
    q, k, v = _project_qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    k_pages, v_pages = paged_update(k_pages, v_pages, k, v, block_table,
                                    positions)
    out = paged_attention(q, k_pages, v_pages, block_table, positions)
    out = out.reshape(*x.shape[:2], -1)
    out = out @ params["wo"].astype(x.dtype)
    return out, (k_pages, v_pages)


def init_kv_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16, d_in=None) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, max_seq, cfg.num_kv_heads, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def kv_cache_specs(rules=None) -> KVCache:
    return KVCache(P(("pod", "data"), None, "model", None),
                   P(("pod", "data"), None, "model", None))
