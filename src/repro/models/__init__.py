"""Model substrate: the ten assigned architectures over six families."""
from .model import ModelApi, analytic_param_count, batch_shapes, build_model, make_batch  # noqa: F401
