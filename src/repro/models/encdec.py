"""Whisper-style encoder-decoder backbone.

Per the harness rules the conv frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (batch, frames, d_model).  Encoder:
bidirectional self-attention + sinusoidal positions.  Decoder: causal
self-attention + cross-attention to the encoder output, learned positions.

``decode_32k`` lowers a decoder step with a 32k self-attn KV cache — an
architectural stretch for whisper-tiny (448 learned positions in the real
model); we extend the learned table to the assigned shape and note the
stretch in DESIGN.md.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime.sharding import shard
from .attention import KVCache, attention, init_attention, spec_attention
from .common import (
    apply_norm,
    scan_layers,
    dense_init,
    dtype_of,
    embed_tokens,
    init_embedding,
    init_norm,
    maybe_remat,
    sinusoidal_positions,
    softmax_cross_entropy,
    spec_embedding,
    spec_norm,
    unembed,
)
from .mlp import init_mlp, mlp, spec_mlp


class EncDecCache(NamedTuple):
    self_kv: KVCache   # (L, B, S, H, D) decoder self-attention
    cross_k: jax.Array  # (L, B, F, H, D) precomputed from encoder output
    cross_v: jax.Array


def _enc_layer_init(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm),
        "attn": init_attention(ks[0], cfg),
        "ln2": init_norm(cfg.d_model, cfg.norm),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def _dec_layer_init(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = _enc_layer_init(ks[0], cfg)
    p["ln_cross"] = init_norm(cfg.d_model, cfg.norm)
    p["cross"] = init_attention(ks[1], cfg)
    return p


def _enc_layer_spec(cfg, fsdp, tp):
    return {
        "ln1": spec_norm(cfg.norm),
        "attn": spec_attention(cfg, fsdp, tp),
        "ln2": spec_norm(cfg.norm),
        "mlp": spec_mlp(cfg.activation, fsdp, tp),
    }


def init_lm(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.encdec.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": init_embedding(ks[2], cfg.vocab_size, cfg.d_model, dtype,
                                cfg.tie_embeddings),
        "pos_dec": (jax.random.normal(ks[3], (cfg.max_seq_len, cfg.d_model)) * 0.01
                    ).astype(dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": init_norm(cfg.d_model, cfg.norm),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }


def spec_lm(cfg, fsdp="data", tp="model"):
    enc = _enc_layer_spec(cfg, fsdp, tp)
    dec = dict(enc)
    dec["ln_cross"] = spec_norm(cfg.norm)
    dec["cross"] = spec_attention(cfg, fsdp, tp)
    stack = lambda t: jax.tree.map(lambda s: P(None, *s), t,
                                   is_leaf=lambda v: isinstance(v, P))
    return {
        "embed": spec_embedding(cfg.tie_embeddings, tp, fsdp,
                                 vocab=cfg.vocab_size, tp_size=cfg.parallelism.tp_size),
        "pos_dec": P(None, None),
        "enc_layers": stack(enc),
        "enc_norm": spec_norm(cfg.norm),
        "dec_layers": stack(dec),
        "final_norm": spec_norm(cfg.norm),
    }


def encode(params, frames, cfg):
    """frames: (B, F, d) precomputed embeddings (conv stub)."""
    frames = frames.astype(dtype_of(cfg.compute_dtype))
    x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)[None].repeat(frames.shape[0], 0)

    def _body(pl, xx):
        h = apply_norm(pl["ln1"], xx, cfg.norm)
        a, _ = attention(pl["attn"], h, cfg, positions=positions, causal=False)
        xx = xx + a
        xx = xx + mlp(pl["mlp"], apply_norm(pl["ln2"], xx, cfg.norm), cfg.activation)
        return shard(xx, "batch", "seq", "embed")

    wrapped = maybe_remat(lambda pl, xx: (_body(pl, xx), 0.0), cfg.parallelism.remat)

    def scan_fn(c, pl):
        y, _ = wrapped(pl, c)
        return y, None

    x, _ = scan_layers(scan_fn, x, params["enc_layers"],
                       cfg.encdec.encoder_layers, cfg.parallelism.scan_layers)
    return apply_norm(params["enc_norm"], x, cfg.norm)


def _cross_kv(pl, enc_out, cfg):
    hd = cfg.resolved_head_dim
    B, F = enc_out.shape[:2]
    k = (enc_out @ pl["cross"]["wk"].astype(enc_out.dtype)).reshape(B, F, cfg.num_kv_heads, hd)
    v = (enc_out @ pl["cross"]["wv"].astype(enc_out.dtype)).reshape(B, F, cfg.num_kv_heads, hd)
    return k, v


def decode_train(params, tokens, enc_out, cfg, last_only=False):
    """Teacher-forced decoder -> logits (B, S, V)."""
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    x = embed_tokens(params["embed"], tokens, cfg.d_model, cdt)
    x = x + params["pos_dec"][:S].astype(cdt)[None]

    def _body(pl, xx):
        h = apply_norm(pl["ln1"], xx, cfg.norm)
        a, _ = attention(pl["attn"], h, cfg, positions=positions, causal=True)
        xx = xx + a
        ck, cv = _cross_kv(pl, enc_out, cfg)
        h2 = apply_norm(pl["ln_cross"], xx, cfg.norm)
        c, _ = attention(pl["cross"], h2, cfg, positions=positions, cross_kv=(ck, cv))
        xx = xx + c
        xx = xx + mlp(pl["mlp"], apply_norm(pl["ln2"], xx, cfg.norm), cfg.activation)
        return shard(xx, "batch", "seq", "embed")

    wrapped = maybe_remat(lambda pl, xx: (_body(pl, xx), 0.0), cfg.parallelism.remat)

    def scan_fn(c, pl):
        y, _ = wrapped(pl, c)
        return y, None

    x, _ = scan_layers(scan_fn, x, params["dec_layers"], cfg.num_layers,
                       cfg.parallelism.scan_layers)
    if last_only:
        x = x[:, -1:]
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return unembed(params["embed"], x, cfg.tie_embeddings)


def forward(params, batch_or_tokens, cfg, dist=None, frames=None, last_only=False):
    if isinstance(batch_or_tokens, dict):
        frames = batch_or_tokens["frames"]
        tokens = batch_or_tokens["tokens"]
    else:
        tokens = batch_or_tokens
    enc_out = encode(params, frames, cfg)
    logits = decode_train(params, tokens, enc_out, cfg, last_only=last_only)
    return shard(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, dist=None):
    logits, aux = forward(params, batch, cfg, dist)
    return softmax_cross_entropy(logits, batch["targets"]) + aux


# ---------------------------------------------------------------------------
# cached decode
# ---------------------------------------------------------------------------
def init_cache(params, frames, cfg, batch: int, max_seq: int) -> EncDecCache:
    """Runs the encoder and precomputes per-layer cross K/V."""
    enc_out = encode(params, frames, cfg)
    hd = cfg.resolved_head_dim

    def per_layer(pl):
        return _cross_kv(pl, enc_out, cfg)

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])  # vmap over L? params stacked
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, hd)
    return EncDecCache(
        KVCache(jnp.zeros(shape, jnp.bfloat16), jnp.zeros(shape, jnp.bfloat16)),
        ck.astype(jnp.bfloat16),
        cv.astype(jnp.bfloat16),
    )


def cache_specs(cfg) -> EncDecCache:
    kv = P(None, ("pod", "data"), None, "model", None)
    return EncDecCache(KVCache(kv, kv), kv, kv)


def decode_step(params, token, cache: EncDecCache, index, cfg, dist=None):
    cdt = dtype_of(cfg.compute_dtype)
    B = token.shape[0]
    positions = jnp.full((B, 1), index, dtype=jnp.int32)
    x = embed_tokens(params["embed"], token, cfg.d_model, cdt)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"], index, 1, 0).astype(cdt)[None, 0:1]

    def scan_fn(carry, xs):
        pl, kv_l, ck_l, cv_l = xs
        h = apply_norm(pl["ln1"], carry, cfg.norm)
        a, new_kv = attention(pl["attn"], h, cfg, positions=positions, causal=True,
                              kv_cache=KVCache(*kv_l), cache_index=index)
        y = carry + a
        h2 = apply_norm(pl["ln_cross"], y, cfg.norm)
        c, _ = attention(pl["cross"], h2, cfg, positions=positions,
                         cross_kv=(ck_l.astype(cdt), cv_l.astype(cdt)))
        y = y + c
        y = y + mlp(pl["mlp"], apply_norm(pl["ln2"], y, cfg.norm), cfg.activation)
        return y, tuple(new_kv)

    x, new_kv = scan_layers(
        scan_fn, x,
        (params["dec_layers"], tuple(cache.self_kv), cache.cross_k, cache.cross_v),
        cfg.num_layers, cfg.parallelism.scan_layers,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits[:, 0, :], EncDecCache(KVCache(*new_kv), cache.cross_k, cache.cross_v)
