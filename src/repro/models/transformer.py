"""Dense / MoE decoder-only transformer LM with scan-over-layers.

Covers qwen2-0.5b, nemotron-4, gemma-7b, chatglm3 (dense) and qwen2-moe,
grok-1 (MoE) through ModelConfig switches: GQA, QKV bias, squared-ReLU,
GeGLU/SwiGLU, partial RoPE, tied embeddings, MoE blocks.

Layers are stacked (leading dim L on every per-layer leaf) and driven by
``jax.lax.scan`` so the HLO stays compact for the 512-device dry-run;
``remat`` wraps the scanned body per config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime.sharding import shard
from .attention import (
    KVCache,
    attention,
    attention_paged,
    init_attention,
    init_kv_cache,
    spec_attention,
)
from .common import (
    apply_norm,
    scan_layers,
    dtype_of,
    embed_tokens,
    init_embedding,
    init_norm,
    maybe_remat,
    softmax_cross_entropy,
    spec_embedding,
    spec_norm,
    unembed,
)
from .mlp import init_mlp, mlp, spec_mlp
from .moe import init_moe, moe_block, spec_moe


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_layer(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_norm(cfg.d_model, cfg.norm),
        "attn": init_attention(ks[0], cfg),
        "ln2": init_norm(cfg.d_model, cfg.norm),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def spec_layer(cfg, fsdp, tp):
    p = {
        "ln1": spec_norm(cfg.norm),
        "attn": spec_attention(cfg, fsdp, tp),
        "ln2": spec_norm(cfg.norm),
    }
    if cfg.moe is not None:
        p["moe"] = spec_moe(cfg, fsdp, tp)
    else:
        p["mlp"] = spec_mlp(cfg.activation, fsdp, tp)
    return p


def init_lm(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    k_embed, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    return {
        "embed": init_embedding(k_embed, cfg.vocab_size, cfg.d_model, dtype,
                                cfg.tie_embeddings),
        "layers": layers,
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }


def spec_lm(cfg, fsdp="data", tp="model"):
    """PartitionSpec tree matching init_lm; stacked layer leaves get a
    leading None (layer) dim."""
    layer = spec_layer(cfg, fsdp, tp)
    stacked = jax.tree.map(lambda s: P(None, *s), layer,
                           is_leaf=lambda v: isinstance(v, P))
    return {
        "embed": spec_embedding(cfg.tie_embeddings, tp, fsdp,
                                 vocab=cfg.vocab_size, tp_size=cfg.parallelism.tp_size),
        "layers": stacked,
        "final_norm": spec_norm(cfg.norm),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _layer_fwd(p, x, positions, cfg, dist):
    h = apply_norm(p["ln1"], x, cfg.norm)
    a, _ = attention(p["attn"], h, cfg, positions=positions, causal=True)
    x = x + a
    x = shard(x, "batch", "seq", "embed")
    h2 = apply_norm(p["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        f, aux = moe_block(p["moe"], h2, cfg, dist)
    else:
        f, aux = mlp(p["mlp"], h2, cfg.activation), jnp.zeros((), jnp.float32)
    x = x + f
    x = shard(x, "batch", "seq", "embed")
    return x, aux


def forward(params, tokens, cfg, dist=None, positions=None, last_only=False):
    """Full-sequence forward -> logits (train / prefill-without-cache).
    ``last_only`` slices the residual stream to the final position BEFORE the
    unembed matmul (prefill needs one position; §Perf it2)."""
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = embed_tokens(params["embed"], tokens, cfg.d_model, cdt)
    x = shard(x, "batch", "seq", "embed")

    body = lambda pl, xx: _layer_fwd(pl, xx, positions, cfg, dist)
    body = maybe_remat(body, cfg.parallelism.remat)

    def scan_fn(carry, pl):
        y, aux = body(pl, carry)
        return y, aux

    x, auxes = scan_layers(scan_fn, x, params["layers"], cfg.num_layers,
                           cfg.parallelism.scan_layers)
    aux = auxes.sum()

    if last_only:
        x = x[:, -1:]
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux


def loss_fn(params, batch, cfg, dist=None):
    logits, aux = forward(params, batch["tokens"], cfg, dist)
    return softmax_cross_entropy(logits, batch["targets"]) + aux


# ---------------------------------------------------------------------------
# serving: prefill fills the cache; decode appends one token
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cache_specs(cfg):
    one = P(("pod", "data"), None, "model", None)
    return KVCache(P(None, *one), P(None, *one))


def _layer_decode(p, x, cache_l, index, positions, cfg, dist):
    h = apply_norm(p["ln1"], x, cfg.norm)
    a, new_cache = attention(
        p["attn"], h, cfg, positions=positions, causal=True,
        kv_cache=cache_l, cache_index=index,
    )
    x = x + a
    h2 = apply_norm(p["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        f, _ = moe_block(p["moe"], h2, cfg, dist)
    else:
        f = mlp(p["mlp"], h2, cfg.activation)
    return x + f, new_cache


def decode_step(params, token, cache, index, cfg, dist=None):
    """token: (B, 1) int32; cache: stacked KVCache; index: scalar int32.
    Returns (logits (B, vocab), new_cache)."""
    cdt = dtype_of(cfg.compute_dtype)
    B = token.shape[0]
    positions = jnp.full((B, 1), index, dtype=jnp.int32)
    x = embed_tokens(params["embed"], token, cfg.d_model, cdt)

    def scan_fn(carry, xs):
        pl, cache_l = xs
        y, new_cache_l = _layer_decode(pl, carry, cache_l, index, positions, cfg, dist)
        return y, new_cache_l

    x, new_cache = scan_layers(scan_fn, x, (params["layers"], cache),
                               cfg.num_layers, cfg.parallelism.scan_layers)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits[:, 0, :], new_cache


# ---------------------------------------------------------------------------
# paged serving: decode / chunked prefill through per-request block tables
# (the serving tier — repro/serve — owns the allocator; this is the model
# side: fixed-size KV blocks, block-table indirection, per-request lengths)
# ---------------------------------------------------------------------------
def init_paged_cache(cfg, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16) -> KVCache:
    """The paged KV slab: ``(L, num_blocks, block_size, kv_heads, head_dim)``
    per side.  Block 0 is the serving tier's reserved null block."""
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def paged_cache_specs(cfg):
    """Pages carry no batch dim — shard the kv-head axis over tp, replicate
    the block pool (every dp replica serves its own requests)."""
    one = P(None, None, None, "model", None)
    return KVCache(one, one)


def _layer_paged(p, x, pages_l, block_tables, positions, cfg, dist):
    h = apply_norm(p["ln1"], x, cfg.norm)
    a, new_pages_l = attention_paged(
        p["attn"], h, cfg, pages_l[0], pages_l[1], block_tables, positions)
    x = x + a
    h2 = apply_norm(p["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        f, _ = moe_block(p["moe"], h2, cfg, dist)
    else:
        f = mlp(p["mlp"], h2, cfg.activation)
    return x + f, KVCache(*new_pages_l)


def decode_step_paged(params, token, pages, block_tables, lengths, cfg,
                      dist=None):
    """One decode step through per-request block tables.

    ``token``: (B, 1) int32; ``pages``: stacked :func:`init_paged_cache`
    KVCache; ``block_tables``: (B, W) int32 physical block ids;
    ``lengths``: (B,) int32 tokens already cached per request — the new
    token is written at position ``lengths[b]`` and attends to
    ``0..lengths[b]``.  Inactive rows point at the null block with length 0.
    Returns ``(logits (B, vocab), new_pages)``.
    """
    cdt = dtype_of(cfg.compute_dtype)
    positions = lengths[:, None].astype(jnp.int32)  # (B, 1)
    x = embed_tokens(params["embed"], token, cfg.d_model, cdt)

    def scan_fn(carry, xs):
        pl, pages_l = xs
        return _layer_paged(pl, carry, pages_l, block_tables, positions,
                            cfg, dist)

    x, new_pages = scan_layers(scan_fn, x, (params["layers"], pages),
                               cfg.num_layers, cfg.parallelism.scan_layers)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits[:, 0, :], new_pages


def prefill_chunk_paged(params, tokens, pages, block_tables, start, cfg,
                        dist=None):
    """One prefill chunk into the paged cache.

    ``tokens``: (B, C) int32 — positions ``start .. start+C`` of the
    prompt; chunks of one request run with B=1, so admitting a long prompt
    never changes the decode batch shape.  The final chunk may carry pad
    tokens past the true prompt length: their K/V land at positions the
    decode loop overwrites before its mask ever exposes them (write-then-
    read per position), so padding needs no separate masking.  Returns
    ``(logits (B, C, vocab), new_pages)`` — the caller samples from the
    last *real* position's row.
    """
    cdt = dtype_of(cfg.compute_dtype)
    B, C = tokens.shape
    positions = (jnp.int32(start)
                 + jnp.arange(C, dtype=jnp.int32)[None, :]).repeat(B, 0)
    x = embed_tokens(params["embed"], tokens, cfg.d_model, cdt)

    def scan_fn(carry, xs):
        pl, pages_l = xs
        return _layer_paged(pl, carry, pages_l, block_tables, positions,
                            cfg, dist)

    x, new_pages = scan_layers(scan_fn, x, (params["layers"], pages),
                               cfg.num_layers, cfg.parallelism.scan_layers)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits, new_pages


def prefill(params, tokens, cfg, dist=None, max_seq: Optional[int] = None):
    """Run the prompt, returning (last_logits, filled_cache, next_index)."""
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    max_seq = max_seq or cfg.max_seq_len
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = embed_tokens(params["embed"], tokens, cfg.d_model, cdt)
    cache = init_cache(cfg, B, max_seq)

    def scan_fn(carry, xs):
        pl, cache_l = xs
        h = apply_norm(pl["ln1"], carry, cfg.norm)
        a, new_cache_l = attention(
            pl["attn"], h, cfg, positions=positions, causal=True,
            kv_cache=cache_l, cache_index=0,
        )
        y = carry + a
        h2 = apply_norm(pl["ln2"], y, cfg.norm)
        if cfg.moe is not None:
            f, _ = moe_block(pl["moe"], h2, cfg, dist)
        else:
            f = mlp(pl["mlp"], h2, cfg.activation)
        return y + f, new_cache_l

    x, cache = scan_layers(scan_fn, x, (params["layers"], cache),
                           cfg.num_layers, cfg.parallelism.scan_layers)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x[:, -1:, :], cfg.tie_embeddings)
    return logits[:, 0, :], cache, jnp.int32(S)
