"""Mixture-of-Experts block with two parallelism modes.

* ``ep`` (expert parallelism — qwen2-moe): tokens are sequence-split over the
  ``model`` axis inside a nested manual ``shard_map``; dispatch uses a
  sort-based (MegaBlocks-style) layout into a capacity-padded ``(E, C, d)``
  buffer; the exchange is an **explicit ABI alltoall** (the paper's technique
  carrying real traffic), experts compute locally, a second alltoall returns
  tokens.  Router aux loss is reduced through ``abi.allreduce``.

* ``tp`` (grok-1, whose 8 experts don't divide the 16-way model axis):
  experts stay unsharded on the expert dim; each expert's ``d_ff`` is
  tensor-parallel over ``model`` via GSPMD; dispatch/combine stay local.

Token dropping beyond capacity follows GShard/Switch semantics.
EP divisibility padding (qwen: 60 -> 64) gives padded experts -inf router
logits, so they receive only capacity slack, never real probability mass.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime.sharding import shard
from .common import GLU_ACTIVATIONS, activation_fn, dense_init, is_glu
from .mlp import init_mlp, mlp, spec_mlp


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_moe(key, cfg, dtype):
    m = cfg.moe
    E = m.padded_experts or m.num_experts
    f = m.expert_d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    ew = {
        "wi": _stack_init(ks[0], E, d, f, dtype),
        "wo": _stack_init(ks[2], E, f, d, dtype),
    }
    if is_glu(cfg.activation):
        ew["wg"] = _stack_init(ks[1], E, d, f, dtype)
    p = {
        "router": dense_init(ks[3], d, m.num_experts, jnp.float32),
        "experts": ew,
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, m.num_shared_experts * f, cfg.activation, dtype)
        p["shared_gate"] = dense_init(ks[5], d, 1, dtype)
    return p


def _stack_init(key, E, din, dout, dtype):
    std = 1.0 / math.sqrt(din)
    return (jax.random.normal(key, (E, din, dout)) * std).astype(dtype)


def spec_moe(cfg, fsdp, tp):
    m = cfg.moe
    if m.parallelism == "ep":
        # expert dim over tp axis; within-expert dims over fsdp
        ew = {"wi": P(tp, fsdp, None), "wo": P(tp, None, fsdp)}
        if is_glu(cfg.activation):
            ew["wg"] = P(tp, fsdp, None)
    else:  # tp: d_ff over tp axis, experts unsharded, fsdp on d_model dims
        ew = {"wi": P(None, fsdp, tp), "wo": P(None, tp, fsdp)}
        if is_glu(cfg.activation):
            ew["wg"] = P(None, fsdp, tp)
    p = {"router": P(None, None), "experts": ew}
    if m.num_shared_experts:
        p["shared"] = spec_mlp(cfg.activation, fsdp, tp)
        p["shared_gate"] = P(None, None)
    return p


# ---------------------------------------------------------------------------
# routing (shared by both modes)
# ---------------------------------------------------------------------------
def _route(params, xf, m):
    """xf: (T, d) fp32-ish. Returns (gates (T,k), experts (T,k), aux_loss)."""
    logits = xf.astype(jnp.float32) @ params["router"]  # (T, E_real)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, m.top_k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch/GShard load-balance loss over REAL experts
    E = m.num_experts
    onehot = jax.nn.one_hot(experts[..., 0], E)  # primary assignment
    load = onehot.mean(0)
    importance = probs.mean(0)
    aux = E * jnp.sum(load * importance) * m.aux_loss_weight
    return gates, experts, aux


def _expert_ffn(w, x, activation):
    """w: dict of (din,dout) mats for ONE expert; x: (C, d)."""
    if is_glu(activation):
        act = activation_fn(GLU_ACTIVATIONS[activation])
        h = act(x @ w["wg"].astype(x.dtype)) * (x @ w["wi"].astype(x.dtype))
    else:
        h = activation_fn(activation)(x @ w["wi"].astype(x.dtype))
    return h @ w["wo"].astype(x.dtype)


def _dispatch_sort(x, experts, gates, E_pad, C):
    """Sort-based dispatch of (T,d) tokens into an (E_pad, C, d) buffer.

    Returns (buffer, combine_info) where combine_info lets us scatter expert
    outputs back and apply gate weights.  Tokens beyond capacity are dropped.
    """
    T, d = x.shape
    k = experts.shape[1]
    flat_e = experts.reshape(-1)  # (T*k,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, st = flat_e[order], flat_g[order], flat_t[order]
    # position within expert group
    ones = jnp.ones_like(se)
    pos_total = jnp.cumsum(ones) - 1
    seg_start = jnp.searchsorted(se, jnp.arange(E_pad), side="left")
    pos_in_e = pos_total - seg_start[se]
    keep = pos_in_e < C
    slot = se * C + jnp.where(keep, pos_in_e, 0)
    buffer = jnp.zeros((E_pad * C, d), x.dtype)
    buffer = buffer.at[slot].add(jnp.where(keep[:, None], x[st], 0))
    return buffer.reshape(E_pad, C, d), (st, sg, slot, keep)


def _combine_sort(expert_out, combine, T, d):
    st, sg, slot, keep = combine
    flat = expert_out.reshape(-1, d)
    vals = flat[slot] * jnp.where(keep, sg, 0.0)[:, None].astype(flat.dtype)
    out = jnp.zeros((T, d), flat.dtype)
    return out.at[st].add(vals)


# ---------------------------------------------------------------------------
# the block
# ---------------------------------------------------------------------------
def moe_block(params, x, cfg, dist=None):
    """x: (B, S, d).  Returns (y, aux_loss).

    ``dist`` is the DistContext (abi + comms + mesh); EP requires it.  The
    EP path auto-falls-back to TP dispatch when S doesn't divide the model
    axis (decode) or no dist is given (pure-CPU smoke tests).
    """
    m = cfg.moe
    B, S, d = x.shape
    use_ep = (
        m.parallelism == "ep"
        and dist is not None
        and S % dist.tp_size == 0
        and dist.tp_size > 1
    )
    y_shared = _shared_path(params, x, cfg)
    if use_ep:
        y, aux = _moe_ep(params, x, cfg, dist)
    else:
        y, aux = _moe_local(params, x, cfg)
    if y_shared is not None:
        y = y + y_shared
    return y, aux


def _shared_path(params, x, cfg):
    if not cfg.moe.num_shared_experts:
        return None
    g = jax.nn.sigmoid(x @ params["shared_gate"].astype(x.dtype))
    return mlp(params["shared"], x, cfg.activation) * g


def _capacity(T, k, E, factor):
    return max(int(math.ceil(T * k / E * factor)), 4)


def _moe_local(params, x, cfg):
    """TP mode (and smoke fallback): dispatch local, expert FFNs vmapped;
    GSPMD shards d_ff over the model axis per spec_moe."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    gates, experts, aux = _route(params, xf, m)
    E_pad = m.padded_experts or m.num_experts
    C = _capacity(T, m.top_k, m.num_experts, m.capacity_factor)
    buf, combine = _dispatch_sort(xf, experts, gates, E_pad, C)
    buf = shard(buf, None, None, None)
    out = jax.vmap(lambda w, t: _expert_ffn(w, t, cfg.activation))(params["experts"], buf)
    y = _combine_sort(out, combine, T, d)
    return y.reshape(B, S, d), aux


def _moe_ep(params, x, cfg, dist):
    """EP mode: nested manual shard_map over the model axis; explicit ABI
    alltoall dispatch (DESIGN.md §Arch-applicability)."""
    m = cfg.moe
    abi = dist.abi
    R = dist.tp_size
    B, S, d = x.shape
    E_pad = m.padded_experts or m.num_experts
    assert E_pad % R == 0, f"EP needs {R} | {E_pad}"
    E_local = E_pad // R
    T_local = B * (S // R)
    C = _capacity(T_local, m.top_k, m.num_experts, m.capacity_factor)

    def body(x_slice, router, ew):
        # x_slice: (B, S/R, d) — this rank's sequence slice
        xf = x_slice.reshape(T_local, d)
        gates, experts, aux = _route({"router": router}, xf, m)
        buf, combine = _dispatch_sort(xf, experts, gates, E_pad, C)
        # EXPLICIT ABI ALLTOALL: (E_pad, C, d) -> (E_local, R*C, d)
        recv = abi.alltoall(buf, dist.tp_comm, split_axis=0, concat_axis=1)
        out = jax.vmap(lambda w, t: _expert_ffn(w, t, cfg.activation))(ew, recv)
        back = abi.alltoall(out, dist.tp_comm, split_axis=1, concat_axis=0)
        y = _combine_sort(back, combine, T_local, d)
        # mean aux over EP ranks with exact gradient weight 1/R per rank
        # (without vma tracking psum transposes to psum, which would scale
        # router gradients by R — split value/grad via stop_gradient)
        sg = jax.lax.stop_gradient(aux)
        mean = abi.allreduce(sg, _sum_handle(), dist.tp_comm) / R
        aux = aux / R + (mean - sg / R)
        return y.reshape(B, S // R, d), aux

    # when nested inside a partial-manual region (the ABI train step), the
    # context mesh already has Manual dp axes — shard_map must receive it
    mesh = dist.mesh
    try:
        ctx = jax.sharding.get_abstract_mesh()
        if ctx is not None and dist.tp_axis in (ctx.axis_names or ()):
            mesh = ctx
    except Exception:
        pass
    from ..core.compat import shard_map as _compat_shard_map

    f = _compat_shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, dist.tp_axis, None), P(None, None),
                  _ep_expert_specs(cfg, dist.tp_axis)),
        out_specs=(P(None, dist.tp_axis, None), P()),
        axis_names={dist.tp_axis},
        check_vma=False,
    )
    return f(x, params["router"], params["experts"])


def _ep_expert_specs(cfg, tp_axis):
    specs = {"wi": P(tp_axis, None, None), "wo": P(tp_axis, None, None)}
    if is_glu(cfg.activation):
        specs["wg"] = P(tp_axis, None, None)
    return specs


def _sum_handle():
    from ..core import handles as H

    return H.PAX_SUM
