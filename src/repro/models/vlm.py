"""Phi-3-vision: phi3-mini transformer backbone + CLIP frontend STUB.

Per the harness rules the modality frontend is a stub: ``input_specs()``
provides precomputed patch embeddings (B, num_patches, clip_dim).  This
module adds the projector (clip_dim -> d_model MLP, as in the real model)
and prepends the projected image tokens to the text sequence; everything
downstream is the dense transformer from transformer.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime.sharding import shard
from .common import (
    apply_norm,
    dense_init,
    dtype_of,
    embed_tokens,
    softmax_cross_entropy,
    unembed,
)
from . import transformer as tfm


def init_lm(key, cfg):
    k_proj, k_base = jax.random.split(key)
    dtype = dtype_of(cfg.param_dtype)
    v = cfg.vlm
    base = tfm.init_lm(k_base, cfg)
    ks = jax.random.split(k_proj, 2)
    base["projector"] = {
        "w1": dense_init(ks[0], v.patch_embed_dim, cfg.d_model, dtype),
        "w2": dense_init(ks[1], cfg.d_model, cfg.d_model, dtype),
    }
    return base


def spec_lm(cfg, fsdp="data", tp="model"):
    spec = tfm.spec_lm(cfg, fsdp, tp)
    spec["projector"] = {"w1": P(None, fsdp), "w2": P(fsdp, tp)}
    return spec


def project_patches(params, patches, cfg):
    cdt = dtype_of(cfg.compute_dtype)
    patches = patches.astype(cdt)
    h = jax.nn.gelu(patches @ params["projector"]["w1"].astype(cdt))
    return h @ params["projector"]["w2"].astype(cdt)


def forward(params, batch, cfg, dist=None, last_only=False):
    """batch: {"tokens": (B,S), "patches": (B,Np,clip_dim), "targets": (B,S)}.
    Image tokens are prepended; loss only on text positions."""
    cdt = dtype_of(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    img = project_patches(params, batch["patches"], cfg)  # (B, Np, d)
    Np = img.shape[1]
    txt = embed_tokens(params["embed"], tokens, cfg.d_model, cdt)
    x = jnp.concatenate([img, txt], axis=1)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.arange(Np + S, dtype=jnp.int32)[None].repeat(B, 0)

    from .common import maybe_remat

    body = maybe_remat(
        lambda pl, xx: tfm._layer_fwd(pl, xx, positions, cfg, dist),
        cfg.parallelism.remat,
    )

    def scan_fn(carry, pl):
        y, aux = body(pl, carry)
        return y, aux

    from .common import scan_layers as _scan

    x, auxes = _scan(scan_fn, x, params["layers"], cfg.num_layers,
                     cfg.parallelism.scan_layers)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    tail = x[:, -1:] if last_only else x[:, Np:]
    logits = unembed(params["embed"], tail, cfg.tie_embeddings)
    return shard(logits, "batch", "seq", "vocab"), auxes.sum()


def loss_fn(params, batch, cfg, dist=None):
    logits, aux = forward(params, batch, cfg, dist)
    return softmax_cross_entropy(logits, batch["targets"]) + aux


# decode reuses the dense-transformer cache machinery: the image prefix is
# prefilled into the cache, then decoding proceeds token by token.
init_cache = tfm.init_cache
cache_specs = tfm.cache_specs
decode_step = tfm.decode_step


def prefill_multimodal(params, tokens, patches, cfg, dist=None, max_seq=None):
    """Prefill with image prefix + prompt tokens; returns (logits, cache, idx)."""
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    img = project_patches(params, patches, cfg)
    Np = img.shape[1]
    max_seq = max_seq or cfg.max_seq_len
    txt = embed_tokens(params["embed"], tokens, cfg.d_model, cdt)
    x = jnp.concatenate([img, txt], axis=1)
    positions = jnp.arange(Np + S, dtype=jnp.int32)[None].repeat(B, 0)
    cache = tfm.init_cache(cfg, B, max_seq)

    from .attention import attention
    from .mlp import mlp as mlp_fn
    from .moe import moe_block

    def scan_fn(carry, xs):
        pl, cache_l = xs
        h = apply_norm(pl["ln1"], carry, cfg.norm)
        a, new_cache_l = attention(pl["attn"], h, cfg, positions=positions,
                                   causal=True, kv_cache=cache_l, cache_index=0)
        y = carry + a
        h2 = apply_norm(pl["ln2"], y, cfg.norm)
        if cfg.moe is not None:
            f, _ = moe_block(pl["moe"], h2, cfg, dist)
        else:
            f = mlp_fn(pl["mlp"], h2, cfg.activation)
        return y + f, new_cache_l

    from .common import scan_layers as _scan

    x, cache = _scan(scan_fn, x, (params["layers"], cache), cfg.num_layers,
                     cfg.parallelism.scan_layers)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x[:, -1:], cfg.tie_embeddings)
    return logits[:, 0], cache, jnp.int32(Np + S)
