"""Zamba2-style hybrid: a Mamba2 backbone with a single SHARED attention
block applied every k layers (cfg.hybrid.shared_attn_every).

Faithful structural points (deviations noted in DESIGN.md):
* the shared block's weights are one parameter set reused at every
  application (Zamba's parameter-efficiency trick);
* its input is concat(hidden, initial_embedding) (2*d wide), projected into
  the attention block, output added back to the residual stream.

The backbone scans over stacked Mamba2 layers; the shared block fires via
``lax.cond`` on the layer index so the scan stays compact.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime.sharding import shard
from .attention import KVCache, attention, init_attention, spec_attention
from .common import (
    apply_norm,
    scan_layers,
    dense_init,
    dtype_of,
    embed_tokens,
    init_embedding,
    init_norm,
    maybe_remat,
    softmax_cross_entropy,
    spec_embedding,
    spec_norm,
    unembed,
)
from .mamba import (
    MambaState,
    init_mamba_layer,
    init_mamba_state,
    mamba_block,
    mamba_state_specs,
    spec_mamba_layer,
)
from .mlp import init_mlp, mlp, spec_mlp


class HybridState(NamedTuple):
    mamba: MambaState        # stacked (L, ...)
    attn_kv: KVCache         # single shared-block cache (B, S, H, D)


def _attn_cfg(cfg):
    """The shared block attends at d_model with cfg's head counts."""
    return cfg


def init_lm(key, cfg):
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    layers = jax.vmap(lambda k: init_mamba_layer(k, cfg))(layer_keys)
    d = cfg.d_model
    shared_in = 2 * d if cfg.hybrid.concat_embedding else d
    shared = {
        "in_proj": dense_init(ks[1], shared_in, d, dtype),
        "ln1": init_norm(d, cfg.norm),
        "attn": init_attention(ks[2], _attn_cfg(cfg)),
        "ln2": init_norm(d, cfg.norm),
        "mlp": init_mlp(ks[3], d, cfg.d_ff, cfg.activation, dtype),
        "out_proj": dense_init(ks[4], d, d, dtype, scale=0.5),
    }
    return {
        "embed": init_embedding(ks[5], cfg.vocab_size, d, dtype, cfg.tie_embeddings),
        "layers": layers,
        "shared": shared,
        "final_norm": init_norm(d, cfg.norm),
    }


def spec_lm(cfg, fsdp="data", tp="model"):
    layer = spec_mamba_layer(cfg, fsdp, tp)
    stacked = jax.tree.map(lambda s: P(None, *s), layer,
                           is_leaf=lambda v: isinstance(v, P))
    shared = {
        "in_proj": P(fsdp, tp),
        "ln1": spec_norm(cfg.norm),
        "attn": spec_attention(cfg, fsdp, tp),
        "ln2": spec_norm(cfg.norm),
        "mlp": spec_mlp(cfg.activation, fsdp, tp),
        "out_proj": P(fsdp, tp),
    }
    return {
        "embed": spec_embedding(cfg.tie_embeddings, tp, fsdp,
                                 vocab=cfg.vocab_size, tp_size=cfg.parallelism.tp_size),
        "layers": stacked,
        "shared": shared,
        "final_norm": spec_norm(cfg.norm),
    }


def _shared_block(p, x, emb0, positions, cfg, kv_cache=None, cache_index=None):
    inp = jnp.concatenate([x, emb0], axis=-1) if cfg.hybrid.concat_embedding else x
    h = inp @ p["in_proj"].astype(x.dtype)
    a, new_cache = attention(
        p["attn"], apply_norm(p["ln1"], h, cfg.norm), cfg,
        positions=positions, causal=True, kv_cache=kv_cache, cache_index=cache_index,
    )
    h = h + a
    h = h + mlp(p["mlp"], apply_norm(p["ln2"], h, cfg.norm), cfg.activation)
    return x + h @ p["out_proj"].astype(x.dtype), new_cache


def forward(params, tokens, cfg, dist=None, last_only=False):
    cdt = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    emb0 = embed_tokens(params["embed"], tokens, cfg.d_model, cdt)
    x = shard(emb0, "batch", "seq", "embed")
    every = cfg.hybrid.shared_attn_every

    def body(pl_and_idx, xx):
        pl, idx = pl_and_idx
        y, _ = mamba_block(pl, xx, cfg)
        xx = xx + y

        def with_attn(v):
            out, _ = _shared_block(params["shared"], v, emb0, positions, cfg)
            return out

        xx = jax.lax.cond((idx + 1) % every == 0, with_attn, lambda v: v, xx)
        return shard(xx, "batch", "seq", "embed")

    wrapped = maybe_remat(lambda pli, xx: (body(pli, xx), 0.0), cfg.parallelism.remat)

    def scan_fn(carry, pli):
        y, _ = wrapped(pli, carry)
        return y, jnp.zeros((), jnp.float32)

    idxs = jnp.arange(cfg.num_layers)
    x, _ = scan_layers(scan_fn, x, (params["layers"], idxs), cfg.num_layers,
                       cfg.parallelism.scan_layers)
    if last_only:
        x = x[:, -1:]
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return shard(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, dist=None):
    logits, aux = forward(params, batch["tokens"], cfg, dist)
    return softmax_cross_entropy(logits, batch["targets"]) + aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def num_firings(cfg) -> int:
    return cfg.num_layers // cfg.hybrid.shared_attn_every


def init_state(cfg, batch: int, max_seq: int) -> HybridState:
    one = init_mamba_state(cfg, batch)
    stacked = jax.tree.map(
        lambda v: jnp.zeros((cfg.num_layers,) + v.shape, v.dtype), one
    )
    hd = cfg.resolved_head_dim
    F = num_firings(cfg)  # each shared-block firing depth has its own cache
    kv = KVCache(
        jnp.zeros((F, batch, max_seq, cfg.num_kv_heads, hd), jnp.bfloat16),
        jnp.zeros((F, batch, max_seq, cfg.num_kv_heads, hd), jnp.bfloat16),
    )
    return HybridState(stacked, kv)


def state_specs(cfg) -> HybridState:
    ms = mamba_state_specs(cfg)
    stacked = jax.tree.map(lambda s: P(None, *s), ms,
                           is_leaf=lambda v: isinstance(v, P))
    kv = KVCache(P(None, ("pod", "data"), None, "model", None),
                 P(None, ("pod", "data"), None, "model", None))
    return HybridState(stacked, kv)


def decode_step(params, token, state: HybridState, index, cfg, dist=None):
    cdt = dtype_of(cfg.compute_dtype)
    B = token.shape[0]
    positions = jnp.full((B, 1), index, dtype=jnp.int32)
    emb0 = embed_tokens(params["embed"], token, cfg.d_model, cdt)
    x = emb0
    every = cfg.hybrid.shared_attn_every

    # each firing depth f has its own KV cache slice kv[f]; the stack is
    # threaded through the scan carry
    def scan_fn(carry, xs):
        xx, kv = carry
        pl, ms_l, idx = xs
        y, new_ms = mamba_block(pl, xx, cfg, state=MambaState(*ms_l))
        xx = xx + y
        f = (idx + 1) // every - 1  # firing index when (idx+1) % every == 0

        def with_attn(operands):
            v, kv_stack = operands
            kv_in = KVCache(
                jax.lax.dynamic_index_in_dim(kv_stack.k, f, 0, keepdims=False),
                jax.lax.dynamic_index_in_dim(kv_stack.v, f, 0, keepdims=False),
            )
            out, kv_out = _shared_block(
                params["shared"], v, emb0, positions, cfg,
                kv_cache=kv_in, cache_index=index,
            )
            kv_stack = KVCache(
                jax.lax.dynamic_update_index_in_dim(kv_stack.k, kv_out.k, f, 0),
                jax.lax.dynamic_update_index_in_dim(kv_stack.v, kv_out.v, f, 0),
            )
            return out, kv_stack

        xx, kv = jax.lax.cond(
            (idx + 1) % every == 0, with_attn, lambda o: o, (xx, kv)
        )
        return (xx, kv), tuple(new_ms)

    idxs = jnp.arange(cfg.num_layers)
    (x, kv), new_ms = scan_layers(
        scan_fn, (x, state.attn_kv), (params["layers"], tuple(state.mamba), idxs),
        cfg.num_layers, cfg.parallelism.scan_layers,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits[:, 0, :], HybridState(MambaState(*new_ms), kv)
