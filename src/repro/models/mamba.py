"""Mamba2 (SSD — state-space duality) block, chunked-scan training form and
O(1) recurrent decode, as used by zamba2-2.7b.

Per head h (scalar decay a_t = exp(dt_t * A_h), A_h < 0):

    state[p, n] <- a_t * state[p, n] + dt_t * x_t[p] * B_t[n]
    y_t[p]      =  state[p, n] . C_t[n]  + D_h * x_t[p]

Training uses the chunked SSD algorithm (segment-sum log decays inside a
chunk; inter-chunk state carried by scan) — matmul form for the MXU.
``repro.kernels.mamba2_ssd`` is the Pallas kernel of the intra-chunk math.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime.sharding import shard
from .common import apply_norm, dense_init, dtype_of, init_norm, spec_norm


class MambaState(NamedTuple):
    conv: jax.Array  # (B, K-1, conv_channels) rolling conv input window
    ssm: jax.Array   # (B, H, P, N)


def _dims(cfg):
    d = cfg.d_model
    s = cfg.ssm
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    return d, d_inner, H, s.head_dim, s.state_size


def init_mamba_layer(key, cfg):
    d, d_inner, H, Pdim, N = _dims(cfg)
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    conv_ch = d_inner + 2 * N  # x, B, C all go through the causal conv
    return {
        "norm": init_norm(d, cfg.norm),
        # in_proj -> [z (gate), x, B, C, dt]
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.conv_kernel, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),  # A = -exp
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),
        "out_norm": init_norm(d_inner, "rmsnorm"),
        "out_proj": dense_init(ks[2], d_inner, d, dtype,
                               scale=1.0 / math.sqrt(2 * cfg.num_layers)),
    }


def spec_mamba_layer(cfg, fsdp, tp):
    return {
        "norm": spec_norm(cfg.norm),
        "in_proj": P(fsdp, tp),
        "conv_w": P(None, tp),
        "conv_b": P(tp),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "out_norm": spec_norm("rmsnorm"),
        "out_proj": P(tp, fsdp),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifts. x: (B,T,C); w: (K,C)."""
    K = w.shape[0]
    y = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        y = y + shifted * w[K - 1 - i]
    return jax.nn.silu(y + b)


def _segsum(wlog):
    """wlog: (..., c). Returns (..., c, c) with S[t,s] = sum_{r=s+1..t} wlog_r
    for s<t, 0 on diag, -inf above."""
    c = wlog.shape[-1]
    cs = jnp.cumsum(wlog, axis=-1)
    S = cs[..., :, None] - cs[..., None, :]
    tri = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(tri, S, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, state, chunk: int):
    """x: (B,T,H,P); dt: (B,T,H) (softplus'd); A: (H,) negative; B,C: (B,T,N);
    state: (B,H,P,N).  Returns (y, final_state)."""
    Bb, T, Hh, Pd = x.shape
    N = B.shape[-1]
    assert T % chunk == 0
    nc = T // chunk

    xr = x.reshape(Bb, nc, chunk, Hh, Pd)
    dtr = dt.reshape(Bb, nc, chunk, Hh)
    Br = B.reshape(Bb, nc, chunk, N)
    Cr = C.reshape(Bb, nc, chunk, N)
    # per-step log decay: dt_t * A_h  (negative)
    wlog = dtr * A[None, None, None, :]  # (B,nc,c,H)

    def one_chunk(S, xs):
        xc, dtc, Bc, Cc, wl = xs  # (B,c,H,P),(B,c,H),(B,c,N),(B,c,N),(B,c,H)
        wl_h = wl.transpose(0, 2, 1)  # (B,H,c)
        seg = _segsum(wl_h)           # (B,H,t,s) = sum of log decays (s..t]
        decay = jnp.exp(seg)
        cb = jnp.einsum("btn,bsn->bts", Cc, Bc)  # (B,t,s)
        M = cb[:, None] * decay * dtc.transpose(0, 2, 1)[:, :, None, :]  # (B,H,t,s)
        y_intra = jnp.einsum("bhts,bshp->bthp", M, xc)
        # inter-chunk: y_t += C_t . exp(la_incl[t]) S_in
        la = jnp.cumsum(wl_h, axis=-1)  # (B,H,c), inclusive of step t
        y_inter = jnp.einsum("bhtn,bhpn->bthp",
                             Cc[:, None, :, :] * jnp.exp(la)[..., None], S)
        y = y_intra + y_inter + xc * D[None, None, :, None]
        # state update: S_out = exp(la_end) S_in + sum_s exp(la_end-la_s) dt_s x_s B_s^T
        a_end = jnp.exp(la[..., -1])  # (B,H)
        k = (Bc[:, None, :, :] * jnp.exp(la[..., -1:, None] - la[..., None])
             * dtc.transpose(0, 2, 1)[..., None])
        S_new = a_end[..., None, None] * S + jnp.einsum("bhtn,bthp->bhpn", k, xc)
        return S_new, y

    xs = (
        xr.transpose(1, 0, 2, 3, 4),
        dtr.transpose(1, 0, 2, 3),
        Br.transpose(1, 0, 2, 3),
        Cr.transpose(1, 0, 2, 3),
        wlog.transpose(1, 0, 2, 3),
    )
    state, ys = jax.lax.scan(one_chunk, state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, T, Hh, Pd)
    return y, state


def ssd_step(x, dt, A, B, C, D, state):
    """x: (B,H,P); dt: (B,H); B,C: (B,N); state: (B,H,P,N)."""
    a = jnp.exp(dt * A[None, :])  # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", x * dt[..., None], B)
    state = a[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C) + x * D[None, :, None]
    return y, state


def mamba_block(p, x, cfg, state: MambaState = None, chunk=None):
    """x: (B,T,d). Returns (y, new_state or None)."""
    d, d_inner, Hh, Pd, N = _dims(cfg)
    chunk = chunk or cfg.ssm.chunk_size
    B_, T, _ = x.shape
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xin, Bc, Cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    if state is None:
        conv_out = _causal_conv(conv_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
        new_conv = None
    else:
        window = jnp.concatenate([state.conv, conv_in[:, :, :]], axis=1)  # (B,K,C)
        K = p["conv_w"].shape[0]
        y = (window * p["conv_w"].astype(x.dtype)[None]).sum(1, keepdims=True)
        conv_out = jax.nn.silu(y + p["conv_b"].astype(x.dtype))
        new_conv = window[:, 1:]
    xc, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    xh = xc.reshape(B_, T, Hh, Pd).astype(jnp.float32)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    Bf, Cf = Bc.astype(jnp.float32), Cc.astype(jnp.float32)
    if state is None:
        S0 = jnp.zeros((B_, Hh, Pd, N), jnp.float32)
        y, S = ssd_chunked(xh, dtp, A, Bf, Cf, p["D"], S0, chunk)
        new_state = None
    else:
        y, S = ssd_step(xh[:, 0], dtp[:, 0], A, Bf[:, 0], Cf[:, 0], p["D"], state.ssm)
        y = y[:, None]
        new_state = MambaState(new_conv, S)
    y = y.reshape(B_, T, d_inner).astype(x.dtype)
    y = apply_norm(p["out_norm"], y, "rmsnorm") * jax.nn.silu(z)
    y = shard(y, "batch", "seq", "ffn")
    out = y @ p["out_proj"].astype(x.dtype)
    if state is None:
        return out, None
    return out, new_state


def init_mamba_state(cfg, batch: int) -> MambaState:
    d, d_inner, Hh, Pd, N = _dims(cfg)
    K = cfg.ssm.conv_kernel
    conv_ch = d_inner + 2 * N
    return MambaState(
        jnp.zeros((batch, K - 1, conv_ch), dtype_of(cfg.compute_dtype)),
        jnp.zeros((batch, Hh, Pd, N), jnp.float32),
    )


def mamba_state_specs(cfg) -> MambaState:
    return MambaState(
        P(("pod", "data"), None, "model"),
        P(("pod", "data"), "model", None, None),
    )
