"""Model factory: one uniform API over the six architecture families.

``build_model(cfg)`` returns a :class:`ModelApi` with:

* ``init(key)``             -> params pytree
* ``param_specs(fsdp, tp)`` -> PartitionSpec pytree (same structure)
* ``loss_fn(params, batch, dist)``            (train)
* ``forward(params, batch, dist)``            (logits)
* ``decode_init(...)`` / ``decode_step(...)`` (serving)
* ``input_specs(shape_cfg, ...)``             -> ShapeDtypeStructs for dry-run

plus ``analytic_param_count`` for the roofline MODEL_FLOPS term.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, hybrid, rwkv, transformer, vlm
from .common import is_glu


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable
    param_specs: Callable
    loss_fn: Callable
    forward: Callable
    decode_init: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    cache_specs: Optional[Callable] = None


def build_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return ModelApi(
            cfg,
            init=lambda key: transformer.init_lm(key, cfg),
            param_specs=lambda fsdp="data", tp="model": transformer.spec_lm(cfg, fsdp, tp),
            loss_fn=lambda p, b, dist=None: transformer.loss_fn(p, b, cfg, dist),
            forward=lambda p, b, dist=None: transformer.forward(p, b["tokens"], cfg, dist),
            decode_init=lambda batch, max_seq: transformer.init_cache(cfg, batch, max_seq),
            decode_step=lambda p, tok, cache, idx, dist=None: transformer.decode_step(
                p, tok, cache, idx, cfg, dist),
            cache_specs=lambda: transformer.cache_specs(cfg),
        )
    if fam == "ssm":
        return ModelApi(
            cfg,
            init=lambda key: rwkv.init_lm(key, cfg),
            param_specs=lambda fsdp="data", tp="model": rwkv.spec_lm(cfg, fsdp, tp),
            loss_fn=lambda p, b, dist=None: rwkv.loss_fn(p, b, cfg, dist),
            forward=lambda p, b, dist=None: rwkv.forward(p, b["tokens"], cfg, dist),
            decode_init=lambda batch, max_seq: rwkv.init_state(cfg, batch),
            decode_step=lambda p, tok, st, idx, dist=None: rwkv.decode_step(
                p, tok, st, idx, cfg, dist),
            cache_specs=lambda: rwkv.state_specs(cfg),
        )
    if fam == "hybrid":
        return ModelApi(
            cfg,
            init=lambda key: hybrid.init_lm(key, cfg),
            param_specs=lambda fsdp="data", tp="model": hybrid.spec_lm(cfg, fsdp, tp),
            loss_fn=lambda p, b, dist=None: hybrid.loss_fn(p, b, cfg, dist),
            forward=lambda p, b, dist=None: hybrid.forward(p, b["tokens"], cfg, dist),
            decode_init=lambda batch, max_seq: hybrid.init_state(cfg, batch, max_seq),
            decode_step=lambda p, tok, st, idx, dist=None: hybrid.decode_step(
                p, tok, st, idx, cfg, dist),
            cache_specs=lambda: hybrid.state_specs(cfg),
        )
    if fam == "encdec":
        return ModelApi(
            cfg,
            init=lambda key: encdec.init_lm(key, cfg),
            param_specs=lambda fsdp="data", tp="model": encdec.spec_lm(cfg, fsdp, tp),
            loss_fn=lambda p, b, dist=None: encdec.loss_fn(p, b, cfg, dist),
            forward=lambda p, b, dist=None: encdec.forward(p, b, cfg, dist),
            decode_init=None,  # cache needs frames: use encdec.init_cache directly
            decode_step=lambda p, tok, cache, idx, dist=None: encdec.decode_step(
                p, tok, cache, idx, cfg, dist),
            cache_specs=lambda: encdec.cache_specs(cfg),
        )
    if fam == "vlm":
        return ModelApi(
            cfg,
            init=lambda key: vlm.init_lm(key, cfg),
            param_specs=lambda fsdp="data", tp="model": vlm.spec_lm(cfg, fsdp, tp),
            loss_fn=lambda p, b, dist=None: vlm.loss_fn(p, b, cfg, dist),
            forward=lambda p, b, dist=None: vlm.forward(p, b, cfg, dist),
            decode_init=lambda batch, max_seq: vlm.init_cache(cfg, batch, max_seq),
            decode_step=lambda p, tok, cache, idx, dist=None: vlm.decode_step(
                p, tok, cache, idx, cfg, dist),
            cache_specs=lambda: vlm.cache_specs(cfg),
        )
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# batch construction (concrete for smoke/examples; ShapeDtypeStruct for dryrun)
# ---------------------------------------------------------------------------
def batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> dict[str, Any]:
    shapes = {
        "tokens": ((batch, seq), jnp.int32),
        "targets": ((batch, seq), jnp.int32),
    }
    if cfg.family == "encdec":
        shapes["frames"] = ((batch, cfg.encdec.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        shapes["patches"] = ((batch, cfg.vlm.num_patches, cfg.vlm.patch_embed_dim), jnp.bfloat16)
    return shapes


def make_batch(key, cfg: ModelConfig, batch: int, seq: int) -> dict[str, Any]:
    ks = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size, jnp.int32),
    }
    out["targets"] = jnp.roll(out["tokens"], -1, axis=1)
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            ks[1], (batch, cfg.encdec.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            ks[2], (batch, cfg.vlm.num_patches, cfg.vlm.patch_embed_dim), jnp.bfloat16)
    return out


# ---------------------------------------------------------------------------
# analytic parameter counts (roofline MODEL_FLOPS = 6*N*D)
# ---------------------------------------------------------------------------
def _mlp_params(d: int, f: int, activation: str) -> int:
    return d * f * (3 if is_glu(activation) else 2)


def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d, f, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.num_layers
    hd = cfg.resolved_head_dim
    n = V * d * (1 if cfg.tie_embeddings else 2)  # embed + unembed

    def attn_params():
        return d * hd * cfg.num_heads * 2 + d * hd * cfg.num_kv_heads * 2

    if cfg.family in ("dense", "moe", "vlm"):
        per_layer = attn_params()
        if cfg.moe is not None:
            m = cfg.moe
            e_all = m.num_experts
            e_act = m.top_k
            expert = _mlp_params(d, m.expert_d_ff, cfg.activation)
            per_layer += (e_act if active_only else e_all) * expert
            per_layer += d * m.num_experts  # router
            if m.num_shared_experts:
                per_layer += _mlp_params(d, m.num_shared_experts * m.expert_d_ff,
                                         cfg.activation) + d
        else:
            per_layer += _mlp_params(d, f, cfg.activation)
        n += L * per_layer
        if cfg.family == "vlm":
            n += cfg.vlm.patch_embed_dim * d + d * d
        return n

    if cfg.family == "ssm":  # rwkv6
        per_layer = 5 * d * d + d * 32 * 5 * 2  # time-mix mats + lora
        per_layer += d * f * 2 + d * d  # channel mix
        return n + L * per_layer

    if cfg.family == "hybrid":
        s = cfg.ssm
        d_inner = s.expand * d
        H = d_inner // s.head_dim
        N = s.state_size
        per_layer = d * (2 * d_inner + 2 * N + H) + d_inner * d  # in/out proj
        shared = (2 * d) * d + attn_params() + _mlp_params(d, f, cfg.activation) + d * d
        return n + L * per_layer + shared

    if cfg.family == "encdec":
        enc = cfg.encdec.encoder_layers * (attn_params() + _mlp_params(d, f, cfg.activation))
        dec = L * (attn_params() * 2 + _mlp_params(d, f, cfg.activation))
        return n + enc + dec + cfg.max_seq_len * d

    raise ValueError(cfg.family)


def model_flops_per_token(cfg: ModelConfig) -> int:
    """6*N_active per token (standard training-FLOPs approximation)."""
    return 6 * analytic_param_count(cfg, active_only=True)
