#!/usr/bin/env python
"""Generate ``docs/abi_reference.md`` from the declarative function table.

    PYTHONPATH=src python docs/generate_abi_reference.py            # write
    PYTHONPATH=src python docs/generate_abi_reference.py --check    # CI gate

The reference is *generated*, never hand-edited: every row is rendered from
``repro.core.abi_spec.ABI_TABLE`` — the same data that generates the ABI
methods, the backend placeholders, and the Mukautuva wrappers — so the
document cannot lie about the spec.  ``--check`` regenerates in memory and
exits 1 on any drift from the checked-in file (wired into the tier-1 CI
leg); a test twin lives in ``tests/test_docs_reference.py``.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.core import abi_spec  # noqa: E402
from repro.core import errors as _errors  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "abi_reference.md")

_TIER_NOTE = {
    abi_spec.REQUIRED: ("must resolve natively at `pax_init` or init fails "
                        "(pure handle queries; the ground recipes stand on)"),
    abi_spec.OPTIONAL: ("native when the backend exports the symbol, "
                        "recipe-emulated otherwise; calling an unresolved "
                        "entry raises `PAX_ERR_UNSUPPORTED_OPERATION`"),
    abi_spec.FAULT: ("ULFM-style fault-tolerance extension; negotiates like "
                     "optional but is reported as its own tier by "
                     "`capabilities()`"),
}


def _args_cell(entry) -> str:
    parts = []
    for a in entry.args:
        cell = f"`{a.name}`:{a.kind}"
        if a.has_default:
            cell += f"={a.default!r}"
        parts.append(cell)
    return ", ".join(parts)


def _bytes_cell(entry) -> str:
    if entry.bytes_arg is None:
        return "—"
    cell = f"`{entry.bytes_arg}`"
    if entry.dtype_size_kwarg:
        cell += " (×`datatype` size)"
    return cell


def _plan_cell(entry) -> str:
    if not entry.persistent:
        return "—"
    if entry.recipe is not None and entry.recipe.plan is not None:
        return "recipe-plan"
    return "native/generic"


def _group_cell(entry) -> str:
    if not entry.persistent:
        return "—"
    if entry.recipe is not None and entry.recipe.plan_group is not None:
        return "recipe-stage"
    return "backend-hook/per-member"


def _recipe_cell(entry) -> str:
    if entry.recipe is None:
        return "—" if entry.tier == abi_spec.REQUIRED else "— (native only)"
    order = abi_spec.EMULATION_ORDER
    deps = ", ".join(f"`{d}`" for d in entry.recipe.deps) or "(none)"
    return f"{deps} — #{order.index(entry.name) + 1} in build order"


def _integrity_cell(entry) -> str:
    return entry.integrity or "—"


_ERR_NOTE = {
    "PAX_ERR_PROC_FAILED": "fault tier: a peer is dead (ULFM)",
    "PAX_ERR_REVOKED": "fault tier: the communicator was revoked (ULFM)",
    "PAX_ERR_DATA_CORRUPTION": (
        "transport tier: a checksummed collective disagreed across the "
        "communicator (integrity mode; the payload carries the poison fill)"),
    "PAX_ERR_TIMEOUT": (
        "transport tier: a `wait` with `timeout_s` expired before the "
        "operation completed (a dropped message); the request stays active "
        "so `Plan.reset`/`PlanGroup.reset` can abort and re-arm the slot"),
}


def _muk_cell(entry) -> str:
    cell = f"`{entry.impl_name}` → {entry.muk_ret}"
    if entry.temps:
        cell += ", keeps temps in the request map"
    if entry.fills_status:
        cell += ", fills `status`"
    return cell


def generate() -> str:
    lines = [
        "# PAX ABI function-table reference",
        "",
        "**Generated from `src/repro/core/abi_spec.py` — do not edit.**",
        "Regenerate with `PYTHONPATH=src python docs/generate_abi_reference.py`;",
        "CI fails when this file drifts from the spec "
        "(`--check`, run in the tier-1 leg).",
        "",
        "Every row below is one `AbiEntry` of `ABI_TABLE` — the single "
        "declarative spec",
        "that generates the `PaxABI` methods (blocking, nonblocking `i*`, "
        "persistent",
        "`<name>_init`), the backend capability placeholders, and the "
        "Mukautuva",
        "translation wrappers.  See `ROADMAP.md` for the architecture notes "
        "and",
        "`serve/README.md` for how the serving tier drives the plan-group "
        "surface.",
        "",
        "## Negotiation tiers",
        "",
    ]
    for tier in (abi_spec.REQUIRED, abi_spec.OPTIONAL, abi_spec.FAULT):
        n = sum(1 for e in abi_spec.ABI_TABLE if e.tier == tier)
        lines.append(f"* **{tier}** ({n} entries): {_TIER_NOTE[tier]}")
    lines += [
        "",
        "## Function table",
        "",
        "Columns: *arguments* list each argument's domain (`payload` passes "
        "through,",
        "handle domains are checked in the ABI layer and converted by "
        "Mukautuva);",
        "*bytes* is the payload argument tools account; *`i*`* / *`_init`* "
        "mark the",
        "generated nonblocking and persistent-plan variants; *plan* / "
        "*group* name",
        "the persistent compilation source (`recipe-plan`/`recipe-stage` = "
        "the",
        "emulation recipe compiles the plan or the fused Startall group "
        "itself);",
        "*recipe deps* lists the emulation dependencies and the entry's "
        "position in",
        "`EMULATION_ORDER` (the topological build order negotiation "
        "resolves in);",
        "*integrity* names the end-to-end checksum rule the opt-in "
        "integrity mode",
        "(`pax_init(..., integrity=True)`) compiles into the entry's "
        "plan/group run",
        "closures (`replicated` = all members must agree bitwise-ish across "
        "the",
        "communicator; `conserved` = the scattered output must conserve the "
        "input",
        "checksum under `PAX_SUM`); a violation raises "
        "`PAX_ERR_DATA_CORRUPTION` at",
        "materialization and the payload carries the canonical poison fill;",
        "*Mukautuva* gives the foreign symbol and return protocol of the "
        "generated",
        "conversion wrapper.",
        "",
        "| entry | tier | arguments | bytes | `i*` | `_init` | plan | group "
        "| integrity | recipe deps | Mukautuva |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for e in abi_spec.ABI_TABLE:
        lines.append("| " + " | ".join([
            f"`{e.name}`",
            e.tier,
            _args_cell(e),
            _bytes_cell(e),
            "✓" if e.nonblocking else "—",
            "✓" if e.persistent else "—",
            _plan_cell(e),
            _group_cell(e),
            _integrity_cell(e),
            _recipe_cell(e),
            _muk_cell(e),
        ]) + " |")
    lines += [
        "",
        "## Emulation build order",
        "",
        "`EMULATION_ORDER` — every recipe dependency precedes its "
        "dependents, so",
        "negotiation builds emulation closures in one forward pass:",
        "",
    ]
    lines.append(" → ".join(f"`{n}`" for n in abi_spec.EMULATION_ORDER))
    lines += [
        "",
        "## Error classes",
        "",
        "The ABI error domain (`repro.core.errors`), surfaced as `PaxError` "
        "under",
        "`PAX_ERRORS_ARE_FATAL` (the default) or returned as codes under",
        "`PAX_ERRORS_RETURN`.  The wait family (`wait`, `waitall`, "
        "`Plan.wait`,",
        "`PlanGroup.wait`) accepts `timeout_s`; without it a dropped "
        "operation is a",
        "faithful hang.  `TRANSPORT_ERRORS` groups the two transport codes "
        "for",
        "retry policies (`runtime.fault.RetryPolicy`, "
        "`serve.ServeSupervisor`).",
        "",
        "| code | name | note |",
        "|---|---|---|",
    ]
    for code, name in sorted(_errors._ERROR_NAMES.items()):
        if code >= _errors.PAX_ERR_LASTCODE:
            continue
        lines.append(f"| {code} | `{name}` | {_ERR_NOTE.get(name, '—')} |")
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if docs/abi_reference.md drifts from the "
                         "spec instead of rewriting it")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args(argv)
    text = generate()
    if args.check:
        on_disk = open(args.out).read() if os.path.exists(args.out) else ""
        if on_disk != text:
            print(f"DRIFT: {args.out} does not match ABI_TABLE — regenerate "
                  "with: PYTHONPATH=src python docs/generate_abi_reference.py",
                  file=sys.stderr)
            return 1
        print(f"OK: {args.out} matches ABI_TABLE "
              f"({len(abi_spec.ABI_TABLE)} entries)")
        return 0
    with open(args.out, "w") as f:
        f.write(text)
    print(f"wrote {args.out} ({len(abi_spec.ABI_TABLE)} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
