"""Unit tests for the HLO collective-traffic parser + roofline math."""
import pytest

from repro.launch.hlo_analysis import (
    CollectiveStats,
    Roofline,
    collective_bytes,
    shape_bytes,
)

HLO = """
HloModule jit_step, num_partitions=256

%region_0.0 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add.0 = f32[] add(%a, %b)
}

ENTRY %main_spmd (p0: bf16[128,256]) -> bf16[128,256] {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ar = bf16[128,256]{1,0} all-reduce(%p0), channel_id=1, to_apply=%region_0.0
  %ag = bf16[256,256]{1,0} all-gather(%ar), channel_id=2, dimensions={0}
  %rs = bf16[16,256]{1,0} reduce-scatter(%ag), channel_id=3, to_apply=%region_0.0
  %cp = bf16[16,256]{1,0} collective-permute(%rs), channel_id=4
  %a2a = bf16[16,256]{1,0} all-to-all(%cp), channel_id=5
  ROOT %out = bf16[128,256]{1,0} all-gather(%a2a), channel_id=6, dimensions={0}
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert shape_bytes("(f32[2], bf16[4,4])") == 8 + 32
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("token[]") == 0


def test_collective_bytes_parses_all_ops():
    stats = collective_bytes(HLO)
    assert set(stats.count_by_op) == {
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute",
    }
    assert stats.count_by_op["all-gather"] == 2
    # all-reduce: max(in, out) = 128*256*2
    assert stats.bytes_by_op["all-reduce"] == 128 * 256 * 2
    # all-gather #1: out 256x256 > in 128x256 -> counts the gathered side
    # all-gather #2: out 128x256 > in 16x256
    assert stats.bytes_by_op["all-gather"] == (256 * 256 + 128 * 256) * 2
    # reduce-scatter: input (256x256) is the unsharded side
    assert stats.bytes_by_op["reduce-scatter"] == 256 * 256 * 2
    assert stats.total_count == 6


def test_async_start_not_double_counted():
    hlo = """
ENTRY %m (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %s = f32[64]{0} all-reduce-start(%p0), channel_id=1
  ROOT %d = f32[64]{0} all-reduce-done(%s)
}
"""
    stats = collective_bytes(hlo)
    assert stats.count_by_op == {"all-reduce": 1}
    assert stats.bytes_by_op["all-reduce"] == 64 * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        flops_per_device=197e12 * 0.010,          # 10 ms compute
        hbm_bytes_per_device=819e9 * 0.020,       # 20 ms memory
        collective_bytes_per_device=200e9 * 0.005,  # 5 ms collective
        chips=256,
        model_flops_global=197e12 * 0.010 * 256 * 0.5,
    )
    assert r.compute_s == pytest.approx(0.010)
    assert r.memory_s == pytest.approx(0.020)
    assert r.collective_s == pytest.approx(0.005)
    assert r.bottleneck == "memory"
    assert r.step_time_s == pytest.approx(0.020)
    assert r.useful_flops_fraction == pytest.approx(0.5)
    # MFU bound: useful flops / (chips*peak*steptime) = .5*10ms/20ms = 0.25
    assert r.mfu_bound == pytest.approx(0.25)
