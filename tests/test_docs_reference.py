"""The spec-generated ABI reference may never drift from ``ABI_TABLE``
(the docs analogue of the negotiation contract: one spec, every consumer
generated from it — including the human-readable one)."""
import importlib.util
import os

from repro.core import abi_spec

_DOCS = os.path.join(os.path.dirname(__file__), "..", "docs")


def _load_generator():
    spec = importlib.util.spec_from_file_location(
        "generate_abi_reference",
        os.path.join(_DOCS, "generate_abi_reference.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_reference_matches_spec():
    gen = _load_generator()
    on_disk = open(os.path.join(_DOCS, "abi_reference.md")).read()
    assert on_disk == gen.generate(), (
        "docs/abi_reference.md drifted from ABI_TABLE; regenerate with: "
        "PYTHONPATH=src python docs/generate_abi_reference.py")


def test_reference_covers_every_entry_and_tier():
    gen = _load_generator()
    text = gen.generate()
    for e in abi_spec.ABI_TABLE:
        assert f"`{e.name}`" in text, e.name
        assert f"`{e.impl_name}`" in text, e.impl_name
    for tier in (abi_spec.REQUIRED, abi_spec.OPTIONAL, abi_spec.FAULT):
        assert f"**{tier}**" in text
    # the build order is part of the contract the doc renders
    assert " → ".join(f"`{n}`" for n in abi_spec.EMULATION_ORDER) in text


def test_check_mode_detects_drift(tmp_path):
    gen = _load_generator()
    good = tmp_path / "abi_reference.md"
    good.write_text(gen.generate())
    assert gen.main(["--check", "--out", str(good)]) == 0
    good.write_text(gen.generate().replace("allreduce", "allredoos", 1))
    assert gen.main(["--check", "--out", str(good)]) == 1
