"""Persistent plan-based operations (MPI-4 ``<name>_init`` + Start/Wait).

Covers the plan subsystem's contracts:

* plan constructors are generated for every persistent function-table row;
* plan-time hoisting preserves semantics (plan result == blocking result,
  across native, emulated and Mukautuva-translated backends);
* persistent requests are restartable pool slots: start-before-wait misuse
  raises ``PAX_ERR_REQUEST``, a freed plan's handles are dead *forever*
  (generation bump), and a 2000-step start/wait churn allocates no new
  ``Request`` objects or slots;
* tools respecialize live plans on attach/detach (the documented contract);
* Mukautuva converts foreign handles at plan time, once;
* the zero1 wiring builds plans at ``init_state`` and threads bf16 error
  feedback through the train loop.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core import abi_spec
from repro.core import handles as H
from repro.core.abi import _REQ_GEN_SHIFT, PaxABI, Request
from repro.core.errors import (
    PAX_ERR_REQUEST,
    PAX_ERR_UNSUPPORTED_OPERATION,
    PaxError,
)

X = jnp.arange(6.0)


@pytest.fixture()
def abi(mesh1):
    return C.pax_init(mesh1, impl="paxi")


# ---------------------------------------------------------------------------
# surface generation + semantics
# ---------------------------------------------------------------------------
def test_plan_constructors_generated_from_spec(abi):
    for entry in abi_spec.ABI_TABLE:
        has = hasattr(abi, f"{entry.name}_init")
        assert has == bool(entry.persistent), entry.name
    # persistent derives from nonblocking (MPI-4 gave every nonblocking
    # collective an _init twin)
    for entry in abi_spec.ABI_TABLE:
        assert entry.persistent == entry.nonblocking


def test_plan_matches_blocking_across_backends(mesh1):
    for impl in ("paxi", "ring", "minimal", "ompix", "muk:paxi"):
        abi = C.pax_init(mesh1, impl=impl)
        plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
        req = plan.start(X)
        np.testing.assert_allclose(
            np.asarray(abi.wait(req)),
            np.asarray(abi.allreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)), err_msg=impl)
        # restart with a new payload of the same shape
        plan.start(X * 3)
        np.testing.assert_allclose(np.asarray(plan.wait()), np.asarray(X * 3))
        plan.free()


def test_plan_payload_is_bound_abstractly(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    # the plan stores shape/dtype, not the example array (no pinned buffers)
    assert isinstance(plan.bound[0], jax.ShapeDtypeStruct)
    # ...per leaf: pytree payloads must not pin their buffers either
    plan_tree = abi.allreduce_init({"w": X, "b": X * 2}, C.PAX_SUM,
                                   C.PAX_COMM_SELF)
    assert all(isinstance(l, jax.ShapeDtypeStruct)
               for l in jax.tree.leaves(plan_tree.bound[0]))
    out = abi.wait(plan_tree.start({"w": X, "b": X * 2}))
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(X) * 2)
    # and accepts an abstract example directly
    plan2 = abi.reduce_scatter_init(
        jax.ShapeDtypeStruct((4,), jnp.float32), C.PAX_SUM, C.PAX_COMM_SELF)
    np.testing.assert_allclose(
        np.asarray(abi.wait(plan2.start(jnp.ones(4)))), np.ones(4))


def test_plan_handle_checks_happen_at_plan_time(abi):
    with pytest.raises(PaxError):
        abi.allreduce_init(X, C.PAX_COMM_WORLD, C.PAX_COMM_SELF)  # op domain
    with pytest.raises(PaxError):
        abi.allreduce_init(X, C.PAX_SUM, C.PAX_SUM)  # comm domain


def test_unavailable_entry_fails_at_plan_time(mesh1):
    from repro.core.backends.paxi import PaxiBackend

    class _Groundless(PaxiBackend):
        # no reduce_scatter/allgather: the allreduce chain cannot ground out
        name = "groundless"
        ABI_SUBSET = frozenset({"comm_size", "comm_rank", "type_size",
                                "sendrecv"})

    abi = PaxABI(_Groundless(mesh1))
    with pytest.raises(PaxError) as e:
        abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert e.value.code == PAX_ERR_UNSUPPORTED_OPERATION


def test_barrier_plan_has_no_payload(abi):
    plan = abi.barrier_init(C.PAX_COMM_SELF)
    req = plan.start()
    assert abi.wait(req) is None


# ---------------------------------------------------------------------------
# restartable request slots x the free-list pool
# ---------------------------------------------------------------------------
def test_start_before_wait_raises_err_request(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    plan.start(X)
    with pytest.raises(PaxError) as e:
        plan.start(X)
    assert e.value.code == PAX_ERR_REQUEST
    plan.wait()
    plan.start(X)  # legal again after completion
    plan.wait()


def test_plan_freed_handle_dead_forever(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    req = plan.start(X)
    handle = req.handle
    slot = H.user_handle_index(handle)
    gen = handle >> _REQ_GEN_SHIFT
    abi.wait(req)
    plan.free()
    # every handle the plan ever returned is stale forever
    with pytest.raises(PaxError) as e:
        abi.wait(Request(handle, persistent=True))
    assert e.value.code == PAX_ERR_REQUEST
    with pytest.raises(PaxError):
        abi.wait(Request(handle))
    # the plan itself is dead
    with pytest.raises(PaxError):
        plan.start(X)
    with pytest.raises(PaxError):
        plan.wait()
    plan.free()  # idempotent
    # the slot itself recycles with an advanced generation
    r = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert H.user_handle_index(r.handle) == slot
    assert r.handle >> _REQ_GEN_SHIFT > gen
    abi.wait(r)


def test_dropped_plan_reclaims_slot_on_gc(mesh1):
    """A plan garbage-collected without free() must not leak its slot: with
    a tiny pool, repeatedly building and dropping plans would otherwise
    exhaust it."""
    import gc

    abi = C.pax_init(mesh1, impl="paxi", req_slot_bits=3)  # 8 slots
    for _ in range(50):
        plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
        abi.wait(plan.start(X))
        del plan
        gc.collect()
    assert len(abi._req_free) == len(abi._req_pool)  # every slot came back
    # an explicitly freed plan's finalizer is detached (no double retire):
    # the generation advances exactly once per free
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    slot = H.user_handle_index(plan.request.handle)
    gen = abi._req_gen[slot]
    plan.free()
    del plan
    gc.collect()
    assert abi._req_gen[slot] == gen + 1
    assert abi._req_free.count(slot) == 1


def test_free_active_plan_refused(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    plan.start(X)
    with pytest.raises(PaxError) as e:
        plan.free()
    assert e.value.code == PAX_ERR_REQUEST
    plan.wait()
    plan.free()


def test_churn_2000_steps_allocates_nothing(abi):
    """The satellite contract: steady-state start/wait churn allocates no
    new Request objects or slots and never advances the generation."""
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    req0 = plan.start(X)
    handle0 = req0.handle
    plan.wait()
    pool_len = len(abi._req_pool)
    issued = abi.requests_issued
    gens = list(abi._req_gen)
    for _ in range(2000):
        req = plan.start(X)
        assert req is req0            # same Request object, recycled in place
        assert req.handle == handle0  # same slot, same generation
        plan.wait()
    assert len(abi._req_pool) == pool_len
    assert abi.requests_issued == issued  # starts are not allocations
    assert abi._req_gen == gens           # no generation churn
    assert abi.outstanding_requests == 0


def test_persistent_and_pooled_requests_share_waitall_testall(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    pr = plan.start(X)
    nr = abi.iallreduce(X * 2, C.PAX_SUM, C.PAX_COMM_SELF)
    assert abi.outstanding_requests == 2
    flag, vals = abi.testall([pr, nr])
    assert flag
    np.testing.assert_allclose(np.asarray(vals[0]), np.asarray(X))
    np.testing.assert_allclose(np.asarray(vals[1]), np.asarray(X) * 2)
    assert abi.outstanding_requests == 0


def test_active_plan_blocks_finalize(mesh1):
    abi = C.pax_init(mesh1, impl="paxi")
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    plan.start(X)
    with pytest.raises(PaxError):
        abi.finalize()
    plan.wait()
    abi.finalize()  # inactive plans hold slots but are not outstanding work
    assert abi.finalized


def test_plan_reset_recovers_aborted_trace(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    plan.start(X)
    plan.reset()  # e.g. a trace aborted between start and wait
    plan.start(X)
    plan.wait()


# ---------------------------------------------------------------------------
# plan-time hoisting specifics
# ---------------------------------------------------------------------------
def test_tools_respecialize_live_plans(abi):
    cc = C.CallCounter()
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    abi.wait(plan.start(X))
    assert cc.counts["allreduce"] == 0
    abi.attach_tool(cc)
    abi.wait(plan.start(X))  # the live plan was recompiled with the tool
    assert cc.counts["allreduce"] == 1
    bc = C.ByteCounter()
    abi.attach_tool(bc)
    abi.wait(plan.start(X))
    assert bc.bytes["allreduce"] == X.size * 4  # bytes from the bound shape
    abi.detach_tool(cc)
    abi.detach_tool(bc)
    abi.wait(plan.start(X))
    assert cc.counts["allreduce"] == 2


def test_mukautuva_converts_at_plan_time_once(mesh1):
    abi = C.pax_init(mesh1, impl="ompix")
    muk = abi.backend
    calls = {"op": 0, "comm": 0}
    orig_op, orig_comm = muk._convert_op, muk._convert_comm

    def count_op(h):
        calls["op"] += 1
        return orig_op(h)

    def count_comm(h):
        calls["comm"] += 1
        return orig_comm(h)

    muk._convert_op, muk._convert_comm = count_op, count_comm
    try:
        plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
        after_plan = dict(calls)
        assert after_plan["op"] >= 1 and after_plan["comm"] >= 1
        for _ in range(10):
            abi.wait(plan.start(X))
        assert calls == after_plan  # zero conversions per start
    finally:
        muk._convert_op, muk._convert_comm = orig_op, orig_comm


def test_capabilities_report_plan_sources(mesh1):
    caps = C.pax_init(mesh1, impl="paxi").capabilities()
    assert caps["allreduce"]["plan"] == "backend-hook"
    assert caps["alltoall"]["plan"] == "generic"
    assert "plan" not in caps["comm_size"]  # no persistent variant
    caps_min = C.pax_init(mesh1, impl="minimal").capabilities()
    assert caps_min["allreduce"]["plan"] == "recipe-plan"
    assert caps_min["reduce_scatter"]["plan"] == "backend-hook"  # paxi hook
    caps_muk = C.pax_init(mesh1, impl="ompix").capabilities()
    assert caps_muk["allreduce"]["plan"] == "backend-hook"  # generated wrap
    assert caps_muk["reduce"]["plan"] == "recipe-plan"      # emulated hole


def test_generic_plan_freezes_emulated_entry(mesh1):
    """Entries without a recipe plan builder still plan (generic argument
    freezing around the built emulation closure) — and building the plan is
    the 'first plan' trigger of lazy recipe resolution."""
    abi = C.pax_init(mesh1, impl="minimal")
    assert abi._table["alltoall"].__lazy_recipe__["impl"] is None
    x = jnp.arange(4.0).reshape(4, 1)
    plan = abi.alltoall_init(x, C.PAX_COMM_SELF)
    assert getattr(abi._table["alltoall"], "__emulated__", False)  # built now
    np.testing.assert_allclose(np.asarray(abi.wait(plan.start(x))),
                               np.asarray(x))


# ---------------------------------------------------------------------------
# layout-keyed plan cache: <name>_init is idempotent per layout
# ---------------------------------------------------------------------------
def test_plan_cache_hit_is_identity(abi):
    p1 = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    pool = len(abi._req_pool)
    issued = abi.requests_issued
    p2 = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert p2 is p1                        # same live plan, not a twin
    assert len(abi._req_pool) == pool      # zero new slots
    assert abi.requests_issued == issued   # zero allocations
    # an abstract example with the same signature hits the same entry
    p3 = abi.allreduce_init(jax.ShapeDtypeStruct(X.shape, X.dtype),
                            C.PAX_SUM, C.PAX_COMM_SELF)
    assert p3 is p1
    # a different layout is a different plan
    p4 = abi.allreduce_init(X[:3], C.PAX_SUM, C.PAX_COMM_SELF)
    assert p4 is not p1
    p1.free()
    p4.free()


def test_plan_cache_skips_active_plans(abi):
    """The MPI _init contract: every init yields an independently startable
    request.  A cache hit on an IN-FLIGHT plan would break double-buffered
    overlap, so it hands out a fresh twin instead (which takes over the
    cache slot)."""
    p1 = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    p1.start(X)
    p2 = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert p2 is not p1            # active plans are never handed out twice
    p2.start(X * 2)                # both in flight at once
    np.testing.assert_allclose(np.asarray(p1.wait()), np.asarray(X))
    np.testing.assert_allclose(np.asarray(p2.wait()), np.asarray(X) * 2)
    # both inactive now: the newest owns the cache slot
    assert abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF) is p2
    p1.free()
    p2.free()


def test_plan_group_start_checks_payload_count(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    group = abi.plan_group([plan] * 3, name="counted")
    with pytest.raises(PaxError) as e:
        group.start([X, X])        # short list must not truncate silently
    assert e.value.code == PAX_ERR_REQUEST and "counted" in str(e.value)
    with pytest.raises(PaxError):
        group.start([X] * 4)
    abi.wait(group.start([X, X, X]))
    group.free()
    plan.free()


def test_entry_envs_bounded_across_respecialization(abi1):
    """attach/detach cycles must not grow the compiled-globals ledger (one
    env per entry, replaced on respecialization — no leak)."""
    count0 = len(abi1._entry_envs)
    cc = C.CallCounter()
    for _ in range(5):
        abi1.attach_tool(cc)
        abi1.detach_tool(cc)
    assert len(abi1._entry_envs) == count0
    assert all(not isinstance(v, list) for v in abi1._entry_envs.values())


def test_plan_cache_evicts_on_free(abi):
    p1 = abi.reduce_scatter_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    p1.free()
    p2 = abi.reduce_scatter_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert p2 is not p1                    # freed plans never resurrect
    abi.wait(p2.start(X))
    p2.free()


def test_plan_cache_keys_every_non_payload_arg(abi):
    a = abi.reduce_scatter_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    b = abi.reduce_scatter_init(X, C.PAX_MAX, C.PAX_COMM_SELF)
    c = abi.reduce_scatter_init(X, C.PAX_SUM, C.PAX_COMM_WORLD)
    assert len({id(a), id(b), id(c)}) == 3
    for p in (a, b, c):
        p.free()


# ---------------------------------------------------------------------------
# plan groups (MPI Startall)
# ---------------------------------------------------------------------------
def test_plan_group_matches_per_plan_semantics(mesh1):
    for impl in ("paxi", "ring", "minimal", "ompix", "muk:paxi"):
        abi = C.pax_init(mesh1, impl=impl)
        plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
        group = abi.plan_group([plan, plan, plan], name="g3")
        req = group.start([X, X * 2, X * 3])
        outs = abi.wait(req)
        assert len(outs) == 3
        for k, o in enumerate(outs):
            np.testing.assert_allclose(
                np.asarray(o), np.asarray(X) * (k + 1), err_msg=impl)
        # restart: same group slot, new payloads
        group.start([X * 4, X * 5, X * 6])
        outs2 = group.wait()
        np.testing.assert_allclose(np.asarray(outs2[0]), np.asarray(X) * 4)
        group.free()
        plan.free()
        assert abi.outstanding_requests == 0


def test_plan_group_mixed_entries_and_payloadless_members(abi):
    par = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    pbar = abi.barrier_init(C.PAX_COMM_SELF)
    pag = abi.allgather_init(X, C.PAX_COMM_SELF)
    group = abi.plan_group([par, pbar, pag], name="mixed")
    outs = abi.wait(group.start([X, None, X * 2]))
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(X))
    assert outs[1] is None
    np.testing.assert_allclose(np.asarray(outs[2]), np.asarray(X) * 2)
    group.free()


def test_plan_group_misuse_names_the_group(abi):
    """Satellite: an aborted trace leaves the group active; the double
    start surfaces PAX_ERR_REQUEST *with the group name*, and reset()
    recovers exactly like Plan.reset."""
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    group = abi.plan_group([plan, plan], name="zero1-rs-test")
    group.start([X, X])
    with pytest.raises(PaxError) as e:
        group.start([X, X])
    assert e.value.code == PAX_ERR_REQUEST
    assert "zero1-rs-test" in str(e.value)
    group.reset()  # the escape hatch, e.g. a trace aborted mid-flight
    group.start([X, X])
    group.wait()
    # the member plan is independent: its own misuse error names the entry
    plan.start(X)
    with pytest.raises(PaxError) as e2:
        plan.start(X)
    assert e2.value.code == PAX_ERR_REQUEST and "allreduce" in str(e2.value)
    plan.reset()
    plan.start(X)
    plan.wait()
    group.free()


def test_plan_group_free_contract(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    group = abi.plan_group([plan], name="solo")
    group.start([X])
    with pytest.raises(PaxError):
        group.free()  # active groups refuse to free
    group.wait()
    handle = group.request.handle
    group.free()
    with pytest.raises(PaxError):
        group.start([X])
    with pytest.raises(PaxError):
        abi.wait(Request(handle, persistent=True))  # handles dead forever
    group.free()  # idempotent
    abi.wait(plan.start(X))  # members untouched by group free
    plan.free()


def test_plan_group_active_blocks_finalize(mesh1):
    abi = C.pax_init(mesh1, impl="paxi")
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    group = abi.plan_group([plan])
    group.start([X])
    assert abi.outstanding_requests == 1
    with pytest.raises(PaxError):
        abi.finalize()
    group.wait()
    abi.finalize()


def test_plan_group_churn_allocates_nothing(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    group = abi.plan_group([plan] * 4, name="churn")
    payloads = [X, X, X, X]
    req0 = group.start(payloads)
    group.wait()
    pool_len = len(abi._req_pool)
    issued = abi.requests_issued
    gens = list(abi._req_gen)
    for _ in range(500):
        assert group.start(payloads) is req0
        group.wait()
    assert len(abi._req_pool) == pool_len
    assert abi.requests_issued == issued
    assert abi._req_gen == gens
    group.free()
    plan.free()


def test_tools_respecialize_live_groups(abi):
    """attach_tool/detach_tool recompile live groups: one interposition per
    group start, bytes summed over every member's bound shape."""
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    group = abi.plan_group([plan, plan], name="tooled-group")
    abi.wait(group.start([X, X]))
    cc = C.CallCounter()
    bc = C.ByteCounter()
    abi.attach_tool(cc)
    abi.attach_tool(bc)
    abi.wait(group.start([X, X]))
    assert cc.counts["tooled-group"] == 1          # ONE interposition
    assert bc.bytes["tooled-group"] == 2 * X.size * 4  # group-summed bytes
    abi.detach_tool(cc)
    abi.detach_tool(bc)
    abi.wait(group.start([X, X]))
    assert cc.counts["tooled-group"] == 1
    group.free()
    plan.free()


def test_plan_group_rejects_foreign_and_freed_members(mesh1, abi):
    other = C.pax_init(mesh1, impl="paxi")
    p_other = other.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    with pytest.raises(PaxError):
        abi.plan_group([p_other], name="alien")
    p = abi.allreduce_init(X * 7, C.PAX_SUM, C.PAX_COMM_SELF)
    p.free()
    with pytest.raises(PaxError):
        abi.plan_group([p], name="dead")


def test_capabilities_report_group_sources(mesh1):
    caps = C.pax_init(mesh1, impl="paxi").capabilities()
    assert caps["allreduce"]["plan_group"] == "backend-hook"
    assert caps["allreduce"]["group_hook"] is True
    assert caps["alltoall"]["plan_group"] == "generic"
    assert "plan_group" not in caps["comm_size"]
    caps_min = C.pax_init(mesh1, impl="minimal").capabilities()
    assert caps_min["allreduce"]["plan_group"] == "recipe-stage"
    assert caps_min["reduce_scatter"]["plan_group"] == "backend-hook"
    caps_muk = C.pax_init(mesh1, impl="ompix").capabilities()
    assert caps_muk["allreduce"]["plan_group"] == "backend-hook"
    assert caps_muk["allreduce"]["group_hook"] is True


# ---------------------------------------------------------------------------
# lazy-shim self-patch (the PR-4 footgun, fixed)
# ---------------------------------------------------------------------------
def test_lazy_shim_self_patches_hoisted_callables(mesh1):
    """A callable hoisted BEFORE the first call must run the built closure
    afterwards — the shim's cell and the compiled entry's globals are both
    patched in place, so no warmup re-fetch is ever needed."""
    abi = C.pax_init(mesh1, impl="minimal")
    shim = abi._table["allreduce"]
    hoisted = abi.allreduce                  # specialized entry, pre-build
    assert shim.__lazy_recipe__["impl"] is None
    assert hoisted.__globals__["_impl"] is shim
    out = hoisted(X, C.PAX_SUM, C.PAX_COMM_SELF)  # first call builds
    np.testing.assert_allclose(np.asarray(out), np.asarray(X))
    built = abi._table["allreduce"]
    assert getattr(built, "__emulated__", False)
    # the shim now dispatches through one cell index, not a dict+branch...
    assert shim.__lazy_cell__[0] is built
    # ...and the hoisted specialized entry was respecialized in place
    assert hoisted.__globals__["_impl"] is built
    np.testing.assert_allclose(
        np.asarray(hoisted(X * 2, C.PAX_SUM, C.PAX_COMM_SELF)),
        np.asarray(X) * 2)


# ---------------------------------------------------------------------------
# zero1 wiring: plans built at init_state + bf16 error feedback threaded
# ---------------------------------------------------------------------------
def _zero1_setup(mesh1, compression):
    import repro.configs as cfgs
    from repro.models import build_model
    from repro.runtime.dist import make_dist

    cfg = cfgs.smoke_config("qwen2-0.5b")
    cfg = dataclasses.replace(
        cfg, parallelism=dataclasses.replace(
            cfg.parallelism, zero1=True, zero1_buckets=2,
            grad_compression=compression))
    api = build_model(cfg)
    dist = make_dist(mesh1, impl="paxi")
    return api, dist


def test_init_state_builds_zero1_plans(mesh1):
    from repro.optim.adamw import FlatAdamState
    from repro.train import train_loop

    api, dist = _zero1_setup(mesh1, None)
    state = train_loop.init_state(api, jax.random.PRNGKey(0), dist=dist)
    assert isinstance(state.opt, FlatAdamState)
    plans = dist.zero1_plans
    assert plans is not None and plans.buckets == 2
    assert plans.padded == state.opt.m.shape[0]
    assert len(plans.rs) == 2 and len(plans.ag) == 2
    # no compression: the ef buffer is the (dp,) dummy
    assert state.opt.ef.shape[0] == dist.dp_size


def test_reinit_same_layout_keeps_zero1_plans(mesh1):
    """Re-init with an unchanged layout is identity (the layout-keyed plan
    cache): the live plans/groups are kept, zero new request slots.  A
    genuine layout change retires the old slots and re-plans — repeated
    re-init must never leak pool slots either way."""
    import dataclasses as _dc

    from repro.train import train_loop

    api, dist = _zero1_setup(mesh1, None)
    train_loop.init_state(api, jax.random.PRNGKey(0), dist=dist)
    pool = len(dist.abi._req_pool)
    free0 = len(dist.abi._req_free)
    old = dist.zero1_plans
    for i in range(3):
        train_loop.init_state(api, jax.random.PRNGKey(i), dist=dist)
    assert dist.zero1_plans is old              # layout unchanged: identity
    assert len(dist.abi._req_pool) == pool      # zero new slots
    assert len(dist.abi._req_free) == free0
    # a genuine layout change (bucket retune) retires the old plans/groups
    api.cfg = _dc.replace(api.cfg, parallelism=_dc.replace(
        api.cfg.parallelism, zero1_buckets=4))
    train_loop.init_state(api, jax.random.PRNGKey(7), dist=dist)
    assert dist.zero1_plans is not old
    assert dist.zero1_plans.buckets == 4
    with pytest.raises(PaxError):               # the old group is dead
        old.rs_group.start([jnp.zeros(old.padded // old.buckets)] * old.buckets)
    assert len(dist.abi._req_pool) == pool      # slots recycled, not grown


def test_plans_mismatched_compression_fall_back(mesh1):
    """None and int8 both ship an f32 wire but use different contexts — the
    layout key must tell them apart so a mismatched plans object falls back
    to the pooled path instead of starting plans on the wrong pool."""
    from repro.runtime.dist import make_dist
    from repro.train.grad_sync import build_zero1_plans, reduce_scatter_grads

    from jax.sharding import PartitionSpec as P

    dist = make_dist(mesh1, impl="paxi", compression="int8")
    assert dist.abi_compressed is not None
    plans = build_zero1_plans(dist, 8, 2, None)  # built for the plain wire
    assert not plans.matches(8, dist.dp_size, 2, jnp.float32, "int8")
    assert not plans.matches(8, dist.dp_size + 1, 2, jnp.float32, None)  # dp keyed
    f = dist.abi.shard_region(
        lambda v: reduce_scatter_grads(dist, v, compression="int8",
                                       buckets=2, plans=plans)[0],
        in_specs=P(), out_specs=P())
    g = jax.jit(f)(jnp.arange(8.0))
    np.testing.assert_allclose(np.asarray(g), np.arange(8.0))  # dp=1 mean
    # the plans' requests were never touched by the mismatched sync
    assert all(p.request.done for p in plans.rs)
    assert dist.abi.outstanding_requests == 0
    assert dist.abi_compressed.outstanding_requests == 0


def test_train_loop_threads_error_feedback_bf16(mesh1):
    from repro.optim.adamw import AdamWConfig
    from repro.train import train_loop

    api, dist = _zero1_setup(mesh1, "bf16")
    state = train_loop.init_state(api, jax.random.PRNGKey(0), dist=dist)
    padded = state.opt.m.shape[0]
    # bf16 compression: per-rank full-length residuals, dp-sharded globally
    assert state.opt.ef.shape[0] == dist.dp_size * padded
    step_fn = jax.jit(train_loop.make_train_step(api, dist, AdamWConfig(lr=1e-3)))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "targets": jnp.ones((2, 16), jnp.int32)}
    s1, m1 = step_fn(state, batch)
    ef1 = np.asarray(s1.opt.ef)
    assert np.isfinite(ef1).all()
    assert np.abs(ef1).sum() > 0  # the bf16 wire residual was captured
    s2, m2 = step_fn(s1, batch)   # and feeds the next step without blowing up
    assert np.isfinite(np.asarray(m2.loss))
    assert np.isfinite(np.asarray(s2.opt.ef)).all()
    assert dist.abi.outstanding_requests == 0
