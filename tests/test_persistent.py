"""Persistent plan-based operations (MPI-4 ``<name>_init`` + Start/Wait).

Covers the plan subsystem's contracts:

* plan constructors are generated for every persistent function-table row;
* plan-time hoisting preserves semantics (plan result == blocking result,
  across native, emulated and Mukautuva-translated backends);
* persistent requests are restartable pool slots: start-before-wait misuse
  raises ``PAX_ERR_REQUEST``, a freed plan's handles are dead *forever*
  (generation bump), and a 2000-step start/wait churn allocates no new
  ``Request`` objects or slots;
* tools respecialize live plans on attach/detach (the documented contract);
* Mukautuva converts foreign handles at plan time, once;
* the zero1 wiring builds plans at ``init_state`` and threads bf16 error
  feedback through the train loop.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core import abi_spec
from repro.core import handles as H
from repro.core.abi import _REQ_GEN_SHIFT, PaxABI, Request
from repro.core.errors import (
    PAX_ERR_REQUEST,
    PAX_ERR_UNSUPPORTED_OPERATION,
    PaxError,
)

X = jnp.arange(6.0)


@pytest.fixture()
def abi(mesh1):
    return C.pax_init(mesh1, impl="paxi")


# ---------------------------------------------------------------------------
# surface generation + semantics
# ---------------------------------------------------------------------------
def test_plan_constructors_generated_from_spec(abi):
    for entry in abi_spec.ABI_TABLE:
        has = hasattr(abi, f"{entry.name}_init")
        assert has == bool(entry.persistent), entry.name
    # persistent derives from nonblocking (MPI-4 gave every nonblocking
    # collective an _init twin)
    for entry in abi_spec.ABI_TABLE:
        assert entry.persistent == entry.nonblocking


def test_plan_matches_blocking_across_backends(mesh1):
    for impl in ("paxi", "ring", "minimal", "ompix", "muk:paxi"):
        abi = C.pax_init(mesh1, impl=impl)
        plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
        req = plan.start(X)
        np.testing.assert_allclose(
            np.asarray(abi.wait(req)),
            np.asarray(abi.allreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)), err_msg=impl)
        # restart with a new payload of the same shape
        plan.start(X * 3)
        np.testing.assert_allclose(np.asarray(plan.wait()), np.asarray(X * 3))
        plan.free()


def test_plan_payload_is_bound_abstractly(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    # the plan stores shape/dtype, not the example array (no pinned buffers)
    assert isinstance(plan.bound[0], jax.ShapeDtypeStruct)
    # ...per leaf: pytree payloads must not pin their buffers either
    plan_tree = abi.allreduce_init({"w": X, "b": X * 2}, C.PAX_SUM,
                                   C.PAX_COMM_SELF)
    assert all(isinstance(l, jax.ShapeDtypeStruct)
               for l in jax.tree.leaves(plan_tree.bound[0]))
    out = abi.wait(plan_tree.start({"w": X, "b": X * 2}))
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(X) * 2)
    # and accepts an abstract example directly
    plan2 = abi.reduce_scatter_init(
        jax.ShapeDtypeStruct((4,), jnp.float32), C.PAX_SUM, C.PAX_COMM_SELF)
    np.testing.assert_allclose(
        np.asarray(abi.wait(plan2.start(jnp.ones(4)))), np.ones(4))


def test_plan_handle_checks_happen_at_plan_time(abi):
    with pytest.raises(PaxError):
        abi.allreduce_init(X, C.PAX_COMM_WORLD, C.PAX_COMM_SELF)  # op domain
    with pytest.raises(PaxError):
        abi.allreduce_init(X, C.PAX_SUM, C.PAX_SUM)  # comm domain


def test_unavailable_entry_fails_at_plan_time(mesh1):
    from repro.core.backends.paxi import PaxiBackend

    class _Groundless(PaxiBackend):
        # no reduce_scatter/allgather: the allreduce chain cannot ground out
        name = "groundless"
        ABI_SUBSET = frozenset({"comm_size", "comm_rank", "type_size",
                                "sendrecv"})

    abi = PaxABI(_Groundless(mesh1))
    with pytest.raises(PaxError) as e:
        abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert e.value.code == PAX_ERR_UNSUPPORTED_OPERATION


def test_barrier_plan_has_no_payload(abi):
    plan = abi.barrier_init(C.PAX_COMM_SELF)
    req = plan.start()
    assert abi.wait(req) is None


# ---------------------------------------------------------------------------
# restartable request slots x the free-list pool
# ---------------------------------------------------------------------------
def test_start_before_wait_raises_err_request(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    plan.start(X)
    with pytest.raises(PaxError) as e:
        plan.start(X)
    assert e.value.code == PAX_ERR_REQUEST
    plan.wait()
    plan.start(X)  # legal again after completion
    plan.wait()


def test_plan_freed_handle_dead_forever(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    req = plan.start(X)
    handle = req.handle
    slot = H.user_handle_index(handle)
    gen = handle >> _REQ_GEN_SHIFT
    abi.wait(req)
    plan.free()
    # every handle the plan ever returned is stale forever
    with pytest.raises(PaxError) as e:
        abi.wait(Request(handle, persistent=True))
    assert e.value.code == PAX_ERR_REQUEST
    with pytest.raises(PaxError):
        abi.wait(Request(handle))
    # the plan itself is dead
    with pytest.raises(PaxError):
        plan.start(X)
    with pytest.raises(PaxError):
        plan.wait()
    plan.free()  # idempotent
    # the slot itself recycles with an advanced generation
    r = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert H.user_handle_index(r.handle) == slot
    assert r.handle >> _REQ_GEN_SHIFT > gen
    abi.wait(r)


def test_dropped_plan_reclaims_slot_on_gc(mesh1):
    """A plan garbage-collected without free() must not leak its slot: with
    a tiny pool, repeatedly building and dropping plans would otherwise
    exhaust it."""
    import gc

    abi = C.pax_init(mesh1, impl="paxi", req_slot_bits=3)  # 8 slots
    for _ in range(50):
        plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
        abi.wait(plan.start(X))
        del plan
        gc.collect()
    assert len(abi._req_free) == len(abi._req_pool)  # every slot came back
    # an explicitly freed plan's finalizer is detached (no double retire):
    # the generation advances exactly once per free
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    slot = H.user_handle_index(plan.request.handle)
    gen = abi._req_gen[slot]
    plan.free()
    del plan
    gc.collect()
    assert abi._req_gen[slot] == gen + 1
    assert abi._req_free.count(slot) == 1


def test_free_active_plan_refused(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    plan.start(X)
    with pytest.raises(PaxError) as e:
        plan.free()
    assert e.value.code == PAX_ERR_REQUEST
    plan.wait()
    plan.free()


def test_churn_2000_steps_allocates_nothing(abi):
    """The satellite contract: steady-state start/wait churn allocates no
    new Request objects or slots and never advances the generation."""
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    req0 = plan.start(X)
    handle0 = req0.handle
    plan.wait()
    pool_len = len(abi._req_pool)
    issued = abi.requests_issued
    gens = list(abi._req_gen)
    for _ in range(2000):
        req = plan.start(X)
        assert req is req0            # same Request object, recycled in place
        assert req.handle == handle0  # same slot, same generation
        plan.wait()
    assert len(abi._req_pool) == pool_len
    assert abi.requests_issued == issued  # starts are not allocations
    assert abi._req_gen == gens           # no generation churn
    assert abi.outstanding_requests == 0


def test_persistent_and_pooled_requests_share_waitall_testall(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    pr = plan.start(X)
    nr = abi.iallreduce(X * 2, C.PAX_SUM, C.PAX_COMM_SELF)
    assert abi.outstanding_requests == 2
    flag, vals = abi.testall([pr, nr])
    assert flag
    np.testing.assert_allclose(np.asarray(vals[0]), np.asarray(X))
    np.testing.assert_allclose(np.asarray(vals[1]), np.asarray(X) * 2)
    assert abi.outstanding_requests == 0


def test_active_plan_blocks_finalize(mesh1):
    abi = C.pax_init(mesh1, impl="paxi")
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    plan.start(X)
    with pytest.raises(PaxError):
        abi.finalize()
    plan.wait()
    abi.finalize()  # inactive plans hold slots but are not outstanding work
    assert abi.finalized


def test_plan_reset_recovers_aborted_trace(abi):
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    plan.start(X)
    plan.reset()  # e.g. a trace aborted between start and wait
    plan.start(X)
    plan.wait()


# ---------------------------------------------------------------------------
# plan-time hoisting specifics
# ---------------------------------------------------------------------------
def test_tools_respecialize_live_plans(abi):
    cc = C.CallCounter()
    plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
    abi.wait(plan.start(X))
    assert cc.counts["allreduce"] == 0
    abi.attach_tool(cc)
    abi.wait(plan.start(X))  # the live plan was recompiled with the tool
    assert cc.counts["allreduce"] == 1
    bc = C.ByteCounter()
    abi.attach_tool(bc)
    abi.wait(plan.start(X))
    assert bc.bytes["allreduce"] == X.size * 4  # bytes from the bound shape
    abi.detach_tool(cc)
    abi.detach_tool(bc)
    abi.wait(plan.start(X))
    assert cc.counts["allreduce"] == 2


def test_mukautuva_converts_at_plan_time_once(mesh1):
    abi = C.pax_init(mesh1, impl="ompix")
    muk = abi.backend
    calls = {"op": 0, "comm": 0}
    orig_op, orig_comm = muk._convert_op, muk._convert_comm

    def count_op(h):
        calls["op"] += 1
        return orig_op(h)

    def count_comm(h):
        calls["comm"] += 1
        return orig_comm(h)

    muk._convert_op, muk._convert_comm = count_op, count_comm
    try:
        plan = abi.allreduce_init(X, C.PAX_SUM, C.PAX_COMM_SELF)
        after_plan = dict(calls)
        assert after_plan["op"] >= 1 and after_plan["comm"] >= 1
        for _ in range(10):
            abi.wait(plan.start(X))
        assert calls == after_plan  # zero conversions per start
    finally:
        muk._convert_op, muk._convert_comm = orig_op, orig_comm


def test_capabilities_report_plan_sources(mesh1):
    caps = C.pax_init(mesh1, impl="paxi").capabilities()
    assert caps["allreduce"]["plan"] == "backend-hook"
    assert caps["alltoall"]["plan"] == "generic"
    assert "plan" not in caps["comm_size"]  # no persistent variant
    caps_min = C.pax_init(mesh1, impl="minimal").capabilities()
    assert caps_min["allreduce"]["plan"] == "recipe-plan"
    assert caps_min["reduce_scatter"]["plan"] == "backend-hook"  # paxi hook
    caps_muk = C.pax_init(mesh1, impl="ompix").capabilities()
    assert caps_muk["allreduce"]["plan"] == "backend-hook"  # generated wrap
    assert caps_muk["reduce"]["plan"] == "recipe-plan"      # emulated hole


def test_generic_plan_freezes_emulated_entry(mesh1):
    """Entries without a recipe plan builder still plan (generic argument
    freezing around the built emulation closure) — and building the plan is
    the 'first plan' trigger of lazy recipe resolution."""
    abi = C.pax_init(mesh1, impl="minimal")
    assert abi._table["alltoall"].__lazy_recipe__["impl"] is None
    x = jnp.arange(4.0).reshape(4, 1)
    plan = abi.alltoall_init(x, C.PAX_COMM_SELF)
    assert getattr(abi._table["alltoall"], "__emulated__", False)  # built now
    np.testing.assert_allclose(np.asarray(abi.wait(plan.start(x))),
                               np.asarray(x))


# ---------------------------------------------------------------------------
# zero1 wiring: plans built at init_state + bf16 error feedback threaded
# ---------------------------------------------------------------------------
def _zero1_setup(mesh1, compression):
    import repro.configs as cfgs
    from repro.models import build_model
    from repro.runtime.dist import make_dist

    cfg = cfgs.smoke_config("qwen2-0.5b")
    cfg = dataclasses.replace(
        cfg, parallelism=dataclasses.replace(
            cfg.parallelism, zero1=True, zero1_buckets=2,
            grad_compression=compression))
    api = build_model(cfg)
    dist = make_dist(mesh1, impl="paxi")
    return api, dist


def test_init_state_builds_zero1_plans(mesh1):
    from repro.optim.adamw import FlatAdamState
    from repro.train import train_loop

    api, dist = _zero1_setup(mesh1, None)
    state = train_loop.init_state(api, jax.random.PRNGKey(0), dist=dist)
    assert isinstance(state.opt, FlatAdamState)
    plans = dist.zero1_plans
    assert plans is not None and plans.buckets == 2
    assert plans.padded == state.opt.m.shape[0]
    assert len(plans.rs) == 2 and len(plans.ag) == 2
    # no compression: the ef buffer is the (dp,) dummy
    assert state.opt.ef.shape[0] == dist.dp_size


def test_reinit_frees_old_zero1_plans(mesh1):
    """Rebuilding state on the same dist retires the old plans' slots —
    repeated init_state must not leak request-pool slots."""
    from repro.train import train_loop

    api, dist = _zero1_setup(mesh1, None)
    train_loop.init_state(api, jax.random.PRNGKey(0), dist=dist)
    pool = len(dist.abi._req_pool)
    free0 = len(dist.abi._req_free)
    old = dist.zero1_plans
    for i in range(3):
        train_loop.init_state(api, jax.random.PRNGKey(i), dist=dist)
    assert len(dist.abi._req_pool) == pool      # slots recycled, not grown
    assert len(dist.abi._req_free) == free0
    with pytest.raises(PaxError):               # the old plans are dead
        old.rs[0].start(jnp.zeros(old.padded // old.buckets))


def test_plans_mismatched_compression_fall_back(mesh1):
    """None and int8 both ship an f32 wire but use different contexts — the
    layout key must tell them apart so a mismatched plans object falls back
    to the pooled path instead of starting plans on the wrong pool."""
    from repro.runtime.dist import make_dist
    from repro.train.grad_sync import build_zero1_plans, reduce_scatter_grads

    from jax.sharding import PartitionSpec as P

    dist = make_dist(mesh1, impl="paxi", compression="int8")
    assert dist.abi_compressed is not None
    plans = build_zero1_plans(dist, 8, 2, None)  # built for the plain wire
    assert not plans.matches(8, dist.dp_size, 2, jnp.float32, "int8")
    assert not plans.matches(8, dist.dp_size + 1, 2, jnp.float32, None)  # dp keyed
    f = dist.abi.shard_region(
        lambda v: reduce_scatter_grads(dist, v, compression="int8",
                                       buckets=2, plans=plans)[0],
        in_specs=P(), out_specs=P())
    g = jax.jit(f)(jnp.arange(8.0))
    np.testing.assert_allclose(np.asarray(g), np.arange(8.0))  # dp=1 mean
    # the plans' requests were never touched by the mismatched sync
    assert all(p.request.done for p in plans.rs)
    assert dist.abi.outstanding_requests == 0
    assert dist.abi_compressed.outstanding_requests == 0


def test_train_loop_threads_error_feedback_bf16(mesh1):
    from repro.optim.adamw import AdamWConfig
    from repro.train import train_loop

    api, dist = _zero1_setup(mesh1, "bf16")
    state = train_loop.init_state(api, jax.random.PRNGKey(0), dist=dist)
    padded = state.opt.m.shape[0]
    # bf16 compression: per-rank full-length residuals, dp-sharded globally
    assert state.opt.ef.shape[0] == dist.dp_size * padded
    step_fn = jax.jit(train_loop.make_train_step(api, dist, AdamWConfig(lr=1e-3)))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "targets": jnp.ones((2, 16), jnp.int32)}
    s1, m1 = step_fn(state, batch)
    ef1 = np.asarray(s1.opt.ef)
    assert np.isfinite(ef1).all()
    assert np.abs(ef1).sum() > 0  # the bf16 wire residual was captured
    s2, m2 = step_fn(s1, batch)   # and feeds the next step without blowing up
    assert np.isfinite(np.asarray(m2.loss))
    assert np.isfinite(np.asarray(s2.opt.ef)).all()
    assert dist.abi.outstanding_requests == 0
