"""Tiered negotiation with collective emulation: partial backends are
admitted at ``pax_init``, missing optional entries are synthesized from the
spec's emulation recipes in topological order, missing *required* entries
still fail at init, dependency cycles are rejected at spec-load time, and
``PAX_ERR_UNSUPPORTED_OPERATION`` fires at call time exactly when no recipe
chain grounds out in native entries."""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as C
from repro.core import abi_spec
from repro.core import emulation as em
from repro.core.abi import PaxABI
from repro.core.backends.minimal import MinimalBackend
from repro.core.backends.paxi import PaxiBackend
from repro.core.errors import PAX_ERR_UNSUPPORTED_OPERATION, PaxError


# ---------------------------------------------------------------------------
# spec-load validation
# ---------------------------------------------------------------------------
def test_table_validates_and_orders_topologically():
    order = abi_spec.validate_table(abi_spec.ABI_TABLE)
    assert set(order) == {e.name for e in abi_spec.ABI_TABLE}
    pos = {n: i for i, n in enumerate(order)}
    for entry in abi_spec.ABI_TABLE:
        if entry.recipe is not None:
            for dep in entry.recipe.deps:
                assert pos[dep] < pos[entry.name], (dep, entry.name)


def _mini_entry(name, recipe=None, tier=abi_spec.OPTIONAL):
    return abi_spec.AbiEntry(
        name=name, impl_name=name.capitalize(),
        args=(abi_spec.Arg("comm", abi_spec.COMM),),
        tier=tier, recipe=recipe,
    )


def test_recipe_cycle_rejected_at_spec_load():
    table = (
        _mini_entry("a", abi_spec.Recipe(("b",), em.build_barrier)),
        _mini_entry("b", abi_spec.Recipe(("c",), em.build_barrier)),
        _mini_entry("c", abi_spec.Recipe(("a",), em.build_barrier)),
    )
    with pytest.raises(ValueError) as e:
        abi_spec.validate_table(table)
    assert "cycle" in str(e.value)


def test_recipe_self_cycle_rejected():
    table = (_mini_entry("a", abi_spec.Recipe(("a",), em.build_barrier)),)
    with pytest.raises(ValueError, match="cycle"):
        abi_spec.validate_table(table)


def test_recipe_unknown_dep_rejected():
    table = (_mini_entry("a", abi_spec.Recipe(("ghost",), em.build_barrier)),)
    with pytest.raises(ValueError, match="unknown entry"):
        abi_spec.validate_table(table)


def test_required_entry_with_recipe_rejected():
    table = (
        _mini_entry("a"),
        _mini_entry("b", abi_spec.Recipe(("a",), em.build_barrier),
                    tier=abi_spec.REQUIRED),
    )
    with pytest.raises(ValueError, match="required"):
        abi_spec.validate_table(table)


def test_required_tier_is_the_query_floor():
    required = {e.name for e in abi_spec.ABI_TABLE if e.tier == abi_spec.REQUIRED}
    assert required == {"comm_size", "comm_rank", "type_size"}


# ---------------------------------------------------------------------------
# init-time negotiation outcomes
# ---------------------------------------------------------------------------
class _NoRankBackend(PaxiBackend):
    name = "norank"
    rank = None  # comm_rank is REQUIRED -> init must fail


def test_missing_required_entry_fails_at_init(mesh1):
    with pytest.raises(PaxError) as e:
        PaxABI(_NoRankBackend(mesh1))
    assert e.value.code == PAX_ERR_UNSUPPORTED_OPERATION
    assert "comm_rank" in str(e.value)


def test_partial_surface_typo_rejected(mesh1):
    class _Typo(PaxiBackend):
        name = "typo"
        ABI_SUBSET = frozenset({"comm_size", "comm_rank", "type_size",
                                "reduce-scatter"})  # typo: dash, not underscore

    with pytest.raises(ValueError, match="unknown"):
        _Typo(mesh1)


class _GroundlessBackend(PaxiBackend):
    """No reduce_scatter and no allgather: the allreduce recipe (and every
    chain through it or through allgather) cannot ground out."""

    name = "groundless"
    ABI_SUBSET = frozenset({"comm_size", "comm_rank", "type_size", "sendrecv",
                            "alltoall"})


def test_unsupported_fires_only_when_no_chain_grounds_out(mesh1):
    abi = PaxABI(_GroundlessBackend(mesh1))  # init admits the partial backend
    caps = abi.capabilities()
    # chains grounding out in native entries resolve...
    assert caps["sendrecv"]["source"] == "native"
    assert caps["alltoallv"]["source"] == "emulated"   # <- native alltoall
    assert caps["alltoallw"]["source"] == "emulated"
    # ...chains that don't, do not — and say why
    for name in ("allreduce", "gather", "scan", "bcast", "scatter", "barrier"):
        assert caps[name]["source"] == "unavailable", name
    assert "reduce_scatter" in caps["allreduce"]["reason"]
    assert "allreduce" in caps["barrier"]["reason"]  # transitively unmet
    x = jnp.arange(4.0)
    with pytest.raises(PaxError) as e:
        abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF)
    assert e.value.code == PAX_ERR_UNSUPPORTED_OPERATION
    with pytest.raises(PaxError):
        abi.ibarrier(C.PAX_COMM_SELF)  # i* twin of an unavailable entry
    # the resolvable surface still works
    assert np.allclose(abi.alltoallv(x, [4], [4], C.PAX_COMM_SELF), x)


# ---------------------------------------------------------------------------
# the minimal backend: emulation end-to-end on one device
# ---------------------------------------------------------------------------
def test_minimal_backend_emulates_whole_surface(mesh1):
    abi = C.pax_init(mesh1, impl="minimal")
    caps = abi.capabilities()
    assert {n for n, i in caps.items() if i["source"] == "native"} == set(
        MinimalBackend.ABI_SUBSET
    )
    assert not [n for n, i in caps.items() if i["source"] == "unavailable"]
    emulated = {n for n, i in caps.items() if i["source"] == "emulated"}
    assert {"allreduce", "bcast", "barrier", "scatter", "alltoallw"} <= emulated
    # deepest chain in the table: scatter -> bcast -> allreduce -> rs+ag
    assert caps["scatter"]["deps"] == ("bcast", "comm_rank", "comm_size")
    assert caps["bcast"]["deps"] == ("allreduce", "comm_rank")
    assert caps["allreduce"]["deps"] == ("reduce_scatter", "allgather", "comm_size")
    # group-of-one semantics through the emulated surface
    x = jnp.arange(6.0)
    assert np.allclose(abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
    assert np.allclose(abi.scan(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
    assert np.allclose(abi.exscan(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
    assert np.allclose(abi.bcast(x, 0, C.PAX_COMM_SELF), x)
    assert np.allclose(abi.gather(x, 0, C.PAX_COMM_SELF), x)
    assert abi.barrier(C.PAX_COMM_SELF) is None
    with pytest.raises(ValueError):  # recipe keeps the SPMD-uniform contract
        abi.alltoallv(x, [6], [4], C.PAX_COMM_SELF)


def test_emulated_entries_are_specialized_and_tooled(mesh1):
    """Emulated entries go through the same init-time specialization and
    tool interposition as native ones: one before/after pair per top-level
    call, byte accounting from the spec's rule, and respecialization on
    attach/detach."""
    cc, bc = C.CallCounter(), C.ByteCounter()
    abi = C.pax_init(mesh1, impl="minimal", tools=[cc, bc])
    x = jnp.ones((8,), jnp.float32)
    abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF)
    abi.bcast(x, 0, C.PAX_COMM_SELF)
    # the emulated bcast calls allreduce internally, but tools see only the
    # top-level entry (the dependency calls are direct, not re-interposed)
    assert cc.counts["allreduce"] == 1
    assert cc.counts["bcast"] == 1
    assert bc.bytes["allreduce"] == 8 * 4
    # specialized instance entry points shadow the generic class methods
    assert "allreduce" in abi.__dict__ and "iallreduce" in abi.__dict__
    assert getattr(abi.__dict__["allreduce"], "__generated_src__", None)
    # the table feeding specialization holds the tagged emulation closure
    assert getattr(abi._table["allreduce"], "__emulated__", False)
    assert abi._table["allreduce"].__emulated_deps__ == (
        "reduce_scatter", "allgather", "comm_size")


def test_emulated_nonblocking_twins_complete(mesh1):
    abi = C.pax_init(mesh1, impl="minimal")
    x = jnp.ones(4)
    reqs = [
        abi.iallreduce(x, C.PAX_SUM, C.PAX_COMM_SELF),
        abi.ibarrier(C.PAX_COMM_SELF),   # ibarrier == iallreduce recipe
        abi.iscan(x, C.PAX_SUM, C.PAX_COMM_SELF),
        abi.ibcast(x, 0, C.PAX_COMM_SELF),
        abi.igather(x, 0, C.PAX_COMM_SELF),
    ]
    assert abi.outstanding_requests == len(reqs)
    flag, vals = abi.testall(reqs)
    assert flag and len(vals) == len(reqs)
    assert abi.outstanding_requests == 0


def test_capabilities_report_translates_across_mukautuva(mesh1):
    """ompix deliberately exports no Reduce/Gather symbols; the report names
    the missing foreign symbol and the ABI-layer recipe that filled it."""
    abi = C.pax_init(mesh1, impl="ompix")
    caps = abi.capabilities()
    assert caps["allreduce"]["source"] == "native"
    assert caps["allreduce"]["impl_symbol"] == "Allreduce"
    for name in ("reduce", "gather"):
        assert caps[name]["source"] == "emulated", name
        assert caps[name]["native"] is False
        assert caps[name]["impl"] == "ompix"
    # emulated reduce through the translation layer still computes
    x = jnp.arange(4.0)
    assert np.allclose(abi.reduce(x, C.PAX_SUM, 0, C.PAX_COMM_SELF), x)
    assert np.allclose(abi.gather(x, 0, C.PAX_COMM_SELF), x)


def test_full_backends_stay_fully_native(mesh1):
    caps = C.pax_init(mesh1, impl="paxi").capabilities()
    assert all(i["source"] == "native" for i in caps.values())
    # muk:paxi fronts the same partial foreign symbol table as ompix, so it
    # shares ompix's two emulated holes — and, like every foreign lib
    # without ULFM symbols, gets the fault tier from the spec recipes
    # above Mukautuva — and is native everywhere else
    caps = C.pax_init(mesh1, impl="muk:paxi").capabilities()
    fault_rows = {e.name for e in abi_spec.ABI_TABLE
                  if e.tier == abi_spec.FAULT}
    assert {n for n, i in caps.items() if i["source"] != "native"} == {
        "reduce", "gather"} | fault_rows


def test_recipes_resolve_lazily(mesh1):
    """Lazy recipe resolution (ROADMAP open item): negotiation *decides*
    emulated at init, but the closure is compiled on first call (or first
    plan) — and capabilities() reports 'emulated' without forcing a build."""
    abi = C.pax_init(mesh1, impl="minimal")
    shim = abi._table["scan"]
    assert shim.__lazy_recipe__["impl"] is None  # deferred at init
    caps = abi.capabilities()
    assert caps["scan"]["source"] == "emulated"
    assert caps["scan"]["deps"] == ("allgather", "comm_rank", "comm_size")
    assert shim.__lazy_recipe__["impl"] is None  # the report forced nothing
    import jax.numpy as jnp

    x = jnp.arange(4.0)
    assert np.allclose(abi.scan(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
    # first call built the closure, swapped the table and respecialized
    built = abi._table["scan"]
    assert built is not shim and getattr(built, "__emulated__", False)
    assert shim.__lazy_recipe__["impl"] is built  # hoisted shims stay valid
    # deps force transitively: building scatter builds bcast and allreduce
    abi2 = C.pax_init(mesh1, impl="minimal")
    assert abi2._table["bcast"].__lazy_recipe__["impl"] is None
    abi2.scatter(x, 0, C.PAX_COMM_SELF)
    for name in ("scatter", "bcast", "allreduce"):
        assert getattr(abi2._table[name], "__emulated__", False), name
    # independent contexts build independently
    abi3 = C.pax_init(mesh1, impl="minimal")
    assert abi3._table["scatter"].__lazy_recipe__["impl"] is None


def test_lazy_build_failure_is_isolated(mesh1):
    """An unused broken recipe costs nothing; its entry fails on first use,
    not at init (the lazy contract's error-locality flip side)."""
    calls = {"n": 0}

    def exploding_build(ctx):
        calls["n"] += 1
        raise RuntimeError("recipe build exploded")

    entry = abi_spec.ENTRY_BY_NAME["scan"]
    orig = entry.recipe
    object.__setattr__(entry, "recipe",
                       abi_spec.Recipe(orig.deps, exploding_build))
    try:
        abi = C.pax_init(mesh1, impl="minimal")  # init does not build
        assert calls["n"] == 0
        import jax.numpy as jnp

        with pytest.raises(RuntimeError, match="exploded"):
            abi.scan(jnp.arange(4.0), C.PAX_SUM, C.PAX_COMM_SELF)
        assert calls["n"] == 1
        # the rest of the surface is unaffected
        assert np.allclose(
            abi.allreduce(jnp.arange(4.0), C.PAX_SUM, C.PAX_COMM_SELF),
            np.arange(4.0))
    finally:
        object.__setattr__(entry, "recipe", orig)


def test_ring_allreduce_is_recipe_composed(mesh1):
    """ring dropped its hand-written RS+AG allreduce; the spec recipe now
    composes its native ring reduce-scatter and all-gather."""
    abi = C.pax_init(mesh1, impl="ring")
    caps = abi.capabilities()
    assert caps["allreduce"]["source"] == "emulated"
    assert caps["reduce_scatter"]["source"] == "native"
    assert caps["allgather"]["source"] == "native"
    x = jnp.arange(8.0)
    assert np.allclose(abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
