"""Free-list request pool: (slot, generation) handle encoding, exact
use-after-wait detection, slot reuse, the index-space regression (the old
monotonically increasing index exhausted ``make_user_handle`` after 2^24
nonblocking calls), and the widened per-context split (generations live
above the classification bits and never wrap, so a stale handle can never
alias a slot reuse — the old 10-bit generation aliased after 1024 reuses)."""
import jax.numpy as jnp
import pytest

import repro.core as C
from repro.core import handles as H
from repro.core.abi import (
    _REQ_GEN_SHIFT,
    _REQ_MAX_SLOTS,
    Request,
)
from repro.core.errors import PAX_ERR_REQUEST, PaxError


@pytest.fixture()
def abi(mesh1):
    return C.pax_init(mesh1, impl="paxi")


X = jnp.ones(4)


def _slot(req):
    return H.user_handle_index(req.handle)


def _gen(req):
    return req.handle >> _REQ_GEN_SHIFT


def test_handles_encode_slot_and_generation(abi):
    r0 = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    r1 = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert H.handle_kind(r0.handle) == H.HandleKind.REQUEST
    assert (_slot(r0), _gen(r0)) == (0, 0)
    assert (_slot(r1), _gen(r1)) == (1, 0)
    abi.waitall([r0, r1])
    # post-retirement reissue: generation above the classification bits, so
    # the handle still decodes as a REQUEST user handle
    r2 = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert _gen(r2) >= 1
    assert H.handle_kind(r2.handle) == H.HandleKind.REQUEST
    assert H.is_user_handle(r2.handle)
    abi.wait(r2)


def test_use_after_wait_raises_err_request(abi):
    req = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    handle = req.handle
    abi.wait(req)
    # a fresh Request object with the stale handle: exactly detected
    with pytest.raises(PaxError) as e:
        abi.wait(Request(handle))
    assert e.value.code == PAX_ERR_REQUEST
    with pytest.raises(PaxError):
        abi.test(Request(handle))
    # the same (completed) object is idempotent, not an error
    assert abi.wait(req) is req.value


def test_slot_reuse_preserves_generation_safety(abi):
    r1 = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    stale = r1.handle
    slot1, gen1 = _slot(r1), _gen(r1)
    abi.wait(r1)
    r2 = abi.iallreduce(X * 2, C.PAX_SUM, C.PAX_COMM_SELF)
    # the slot is recycled (LIFO free list), the generation advanced
    assert _slot(r2) == slot1 == 0
    assert _gen(r2) == gen1 + 1
    assert r2.handle != stale
    # the stale handle does not alias the live request
    with pytest.raises(PaxError):
        abi.wait(Request(stale))
    # and the live one still completes fine
    flag, _ = abi.testall([r2])
    assert flag
    assert abi.outstanding_requests == 0


def test_pool_recycles_request_objects_in_place(abi):
    r1 = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    abi.wait(r1)
    r2 = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert r2 is r1  # steady state allocates no new Request objects
    abi.wait(r2)


def test_generation_never_wraps_or_aliases(abi):
    """The ROADMAP open item, fixed: pre-widening, the 10-bit generation
    wrapped after 1024 reuses of a slot, at which point a very stale handle
    aliased the live request.  Generations now live above the handle's
    classification bits as an unbounded counter: 1500 reuses of slot 0 later,
    the cycle-0 handle is still exactly detected as stale and the pool is
    still one slot."""
    first = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    stale = first.handle
    abi.wait(first)
    cycles = 1500  # > the old 1024-generation wrap
    for i in range(cycles):
        req = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
        assert _slot(req) == 0
        assert _gen(req) == i + 1
        assert req.handle != stale
        with pytest.raises(PaxError):  # would alias at i==1023 pre-widening
            abi.wait(Request(stale))
        abi.wait(req)
    assert len(abi._req_pool) == 1
    assert abi.requests_issued == cycles + 1
    assert H.handle_kind(req.handle) == H.HandleKind.REQUEST


def test_lifetime_count_past_16m_does_not_exhaust_handles(abi):
    """Pre-PR-2, the 16,777,216th nonblocking call raised ValueError from
    make_user_handle mid-run.  The pool's handles are (slot, generation)
    only; a lifetime count beyond 2^24 is irrelevant by construction."""
    abi.requests_issued = (1 << 24) + 7  # simulate a long-lived context
    req = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert H.user_handle_index(req.handle) <= H._USER_INDEX_MASK
    abi.wait(req)
    assert abi.requests_issued == (1 << 24) + 8


def test_per_context_slot_split(mesh1):
    """The split is per-context: a small-slot context caps its outstanding
    requests (clean PAX_ERR_REQUEST beyond) without touching the default."""
    small = C.pax_init(mesh1, impl="paxi", req_slot_bits=3)
    assert small._req_max_slots == 8
    reqs = [small.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF) for _ in range(8)]
    with pytest.raises(PaxError) as e:
        small.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert e.value.code == PAX_ERR_REQUEST
    assert "pool exhausted" in str(e.value)
    small.waitall(reqs)
    assert small.outstanding_requests == 0
    # the default split is unchanged, and bad splits are rejected up front
    assert C.pax_init(mesh1, impl="paxi")._req_max_slots == _REQ_MAX_SLOTS == 1 << 14
    with pytest.raises(ValueError):
        C.pax_init(mesh1, impl="paxi", req_slot_bits=25)


def test_pool_exhaustion_is_a_clean_error(abi):
    reqs = [abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
            for _ in range(_REQ_MAX_SLOTS)]
    with pytest.raises(PaxError) as e:
        abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    assert e.value.code == PAX_ERR_REQUEST
    assert "pool exhausted" in str(e.value)
    abi.waitall(reqs)
    assert abi.outstanding_requests == 0


def test_testall_mixed_done_and_live(abi):
    reqs = [abi.iallreduce(X * i, C.PAX_SUM, C.PAX_COMM_SELF) for i in range(4)]
    abi.wait(reqs[1])  # complete one out of band
    flag, vals = abi.testall(reqs)
    assert flag and len(vals) == 4
    # a foreign handle makes the scan report not-ready (old semantics)
    live = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    flag, vals = abi.testall([live, Request(H.make_user_handle(H.HandleKind.REQUEST, 12345))])
    assert not flag and vals is None
    abi.wait(live)


def test_request_identity_semantics():
    """Satellite: eq=False — hash/eq are object identity, not field-wise."""
    a = Request(42, value=1)
    b = Request(42, value=1)
    assert a != b and a == a
    assert hash(a) != hash(b) or a is b  # identity hash, not handle hash
    assert len({a, b}) == 2


def test_finalize_counts_pool_live(abi):
    req = abi.iallreduce(X, C.PAX_SUM, C.PAX_COMM_SELF)
    with pytest.raises(PaxError):
        abi.finalize()
    abi.wait(req)
    abi.finalize()
    assert abi.finalized


def test_temp_state_freed_on_completion(mesh1):
    """alltoallw temporaries ride in the pooled request and are freed at
    completion (the §6.2 request-map contract, pool edition)."""
    import jax
    from jax.sharding import PartitionSpec as P

    abi = C.pax_init(mesh1, impl="ompix")
    mp = abi.comm_from_axes(("model",))
    seen = {}

    def body(blocks):
        req = abi.ialltoallw(blocks, [C.PAX_FLOAT32], [C.PAX_FLOAT16], mp)
        seen["held"] = req.temp_state is not None
        (out,) = abi.wait(req)
        seen["freed"] = req.temp_state is None
        return out

    f = abi.shard_region(body, in_specs=P(), out_specs=P())
    jax.jit(f)(jnp.ones((1, 4), jnp.float32))
    assert seen == {"held": True, "freed": True}
