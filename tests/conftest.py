"""Shared fixtures.

NOTE: no XLA_FLAGS device-count forcing here — in-process tests must see the
real single CPU device (the harness rule).  Multi-device behaviour is tested
through subprocess batteries (tests/multidev_battery.py) which set
``--xla_force_host_platform_device_count`` privately.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def mesh1():
    """A 1x1 mesh: degenerate but exercises every code path."""
    from repro.core.compat import make_mesh

    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def abi1(mesh1):
    import repro.core as C

    return C.pax_init(mesh1, impl="paxi")
