"""Serve engine: per-request sampling params (satellite fix — the batch
previously ran entirely under requests[0]'s temperature/top_k)."""
import jax
import numpy as np

import repro.configs as cfgs
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def _engine(max_batch=2):
    cfg = cfgs.smoke_config("qwen2-0.5b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return ServeEngine(api, params, max_batch=max_batch, max_seq=64)


def test_mixed_batch_honors_each_requests_params():
    prompt = np.arange(1, 9, dtype=np.int32)
    ref = _engine().generate(prompt, max_new_tokens=8)  # solo greedy

    eng = _engine()
    hot = Request(0, prompt, max_new_tokens=8, temperature=5.0)
    greedy = Request(1, prompt, max_new_tokens=8, temperature=0.0)
    eng.run([hot, greedy])
    # the greedy row must be untouched by its neighbor's temperature —
    # with the old batch-wide requests[0] params it would have sampled hot
    assert greedy.out_tokens == list(ref)
    assert len(hot.out_tokens) == 8


def test_per_request_max_new_tokens():
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = _engine()
    # max_new_tokens=1 is the edge: the cap must apply to the very first
    # (prefill-sampled) token too, not only to decode-loop tokens
    one = Request(0, prompt, max_new_tokens=1)
    short = Request(1, prompt, max_new_tokens=3)
    eng.run([one, short])
    assert len(one.out_tokens) == 1
    assert len(short.out_tokens) == 3

    eng2 = _engine()
    long = Request(0, prompt, max_new_tokens=8)
    eng2.run([long])
    assert len(long.out_tokens) == 8


def test_homogeneous_batch_single_group():
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = _engine()
    reqs = [Request(i, prompt, max_new_tokens=4, temperature=0.0) for i in range(2)]
    eng.run(reqs)
    assert reqs[0].out_tokens == reqs[1].out_tokens  # same prompt, greedy
