"""Multi-device correctness battery, run in a subprocess with 8 fake CPU
devices (so the in-process test session keeps seeing 1 real device).

Run directly:  python tests/multidev_battery.py
Or via pytest: tests/test_collectives.py spawns it.

Sections:
  1. backend semantics equivalence (all backends vs numpy oracles)
  2. HLO identity: ABI(paxi) vs raw jax.lax  — the Table-1 zero-overhead claim
  3. bcast/sendrecv/scatter/alltoall/barrier correctness
  4. user ops + MINLOC across ranks (callback path)
  5. Mukautuva across ranks: alltoallw with per-peer dtypes + request map
  6. ring compression error bounds
  7. ZeRO-1 flat round trip across dp ranks (pooled nonblocking path)
  8. tiered negotiation: minimal backend emulation chains end-to-end
  9. persistent plans: plan-time hoisting == per-call semantics
 10. plan groups (Startall): group == per-plan zero1, dp=2 and dp=8
 11. hierarchical multi-axis alltoallv (world comm, 2x4 mesh)
 12. fused wire kernels inside real ring schedules (plan-time selection)
 13. fault tier: injected rank death on three dispatch paths
 14. elastic-dp: kill rank 5 at dp=8, shrink, bitwise resume at dp=4
 15. serving decode-tp plan group == pooled i* bcast (tp=4)
 16. serving fault supervisor: mid-decode kill at tp=4, heartbeat-observed
     death, shrink + token-identical replay (three dispatch paths)
 17. uneven-shard elastic recovery: dp=8 -> dp=7 (all survivors kept)
 18. transport integrity: corrupted zero1 collective detected -> retried ->
     bitwise resume; dropped decode-tp bcast -> timeout -> heartbeat
     confirm -> shrink -> token-identical replay (three dispatch paths)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as C
from repro.core.compat import make_mesh, shard_map
from repro.core import handles as H

mesh = make_mesh((2, 4), ("data", "model"))

XG = np.arange(64.0).reshape(8, 8) + 1.0  # rank-major chunks


def section(name):
    print(f"--- {name}")


# ---------------------------------------------------------------------------
section("1. backend semantics vs numpy oracles (every registered backend)")
exp_sum, exp_max, exp_min, exp_prod = XG.sum(0), XG.max(0), XG.min(0), XG.prod(0)
exp_scan = np.cumsum(XG, axis=0)                       # inclusive prefix, rank-major
exp_exscan = np.concatenate([XG[:1], exp_scan[:-1]])   # rank 0: input unchanged

# the equivalence battery runs over EVERY registered implementation — the
# spec-driven surface (including scan/exscan/alltoallv) must agree everywhere
for impl in sorted(C.available_backends()):
    abi = C.pax_init(mesh, impl=impl)
    world = C.PAX_COMM_WORLD
    dp = abi.comm_from_axes(("data",))
    mp = abi.comm_from_axes(("model",))

    def body(x):
        return (
            abi.allreduce(x, C.PAX_SUM, world),
            abi.allreduce(x, C.PAX_MAX, world),
            abi.allreduce(x, C.PAX_MIN, world),
            abi.allreduce(x, C.PAX_PROD, world),
            abi.allgather(x, dp),
            abi.reduce_scatter(x, C.PAX_SUM, world),
            abi.scan(x, C.PAX_SUM, world),
            abi.exscan(x, C.PAX_SUM, world),
            abi.alltoallv(x, (2, 2, 2, 2), (2, 2, 2, 2), mp),
            abi.alltoall(x.reshape(4, 2), mp, 0, 0).reshape(-1),
        )

    f = abi.shard_region(
        body, in_specs=P(("data", "model")),
        out_specs=(P(), P(), P(), P(), P("model"), P(("data", "model")),
                   P(("data", "model")), P(("data", "model")),
                   P(("data", "model")), P(("data", "model"))),
    )
    s, mx, mn, pr, ag, rs, sc, ex, a2av, a2a = jax.jit(f)(jnp.asarray(XG.reshape(-1)))
    tol = 0.03 if "int8" in impl else (0.01 if "bf16" in impl else 1e-5)
    np.testing.assert_allclose(np.asarray(s[:8]), exp_sum, rtol=tol)
    np.testing.assert_allclose(np.asarray(mx[:8]), exp_max)
    np.testing.assert_allclose(np.asarray(mn[:8]), exp_min)
    np.testing.assert_allclose(np.asarray(pr[:8]), exp_prod, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(rs), exp_sum, rtol=tol)
    np.testing.assert_allclose(
        np.asarray(ag[:16]), np.concatenate([XG[0], XG[4]])
    )  # model-col 0 gathers data-ranks {0,4}
    np.testing.assert_allclose(
        np.asarray(sc).reshape(8, 8), exp_scan, rtol=tol
    )  # inclusive prefix over linearized world rank
    np.testing.assert_allclose(
        np.asarray(ex).reshape(8, 8), exp_exscan, rtol=tol
    )  # exclusive prefix; rank 0 keeps its input (ABI convention)
    np.testing.assert_allclose(
        np.asarray(a2av), np.asarray(a2a), rtol=1e-6
    )  # uniform-count alltoallv == alltoall
    print(f"  {impl}: OK")

# ---------------------------------------------------------------------------
section("2. HLO identity: ABI(paxi) == raw jax.lax (Table 1, zero overhead)")
abi = C.pax_init(mesh, impl="paxi")


def step_abi(g):
    return abi.allreduce(g * 2.0, C.PAX_SUM, C.PAX_COMM_WORLD)


def step_raw(g):
    return jax.lax.psum(g * 2.0, ("data", "model"))


x = jnp.ones((8, 16))
spec = P(("data", "model"))
f_abi = jax.jit(shard_map(step_abi, mesh=mesh, in_specs=spec, out_specs=P()))
f_raw = jax.jit(shard_map(step_raw, mesh=mesh, in_specs=spec, out_specs=P()))


def norm_hlo(txt: str) -> str:
    """Keep only computation lines: strip op metadata and the source-location
    index tables (FileNames/FunctionNames/FileLocations/StackFrames)."""
    lines = []
    skipping = False
    for line in txt.splitlines():
        if line.strip() in ("FileNames", "FunctionNames", "FileLocations", "StackFrames"):
            skipping = True
            continue
        if skipping:
            if line.strip() == "":
                skipping = False
            continue
        line = re.sub(r", metadata=\{[^}]*\}", "", line)
        line = re.sub(r"HloModule \S+", "HloModule M", line)
        lines.append(line)
    return "\n".join(lines)


h_abi = norm_hlo(f_abi.lower(x).compile().as_text())
h_raw = norm_hlo(f_raw.lower(x).compile().as_text())
assert h_abi == h_raw, "ABI lowering differs from raw lax lowering!"
assert "all-reduce" in h_abi
print("  optimized HLO identical:", len(h_abi), "chars")

# ---------------------------------------------------------------------------
section("3. bcast / sendrecv / scatter / alltoall / barrier")
abi = C.pax_init(mesh, impl="paxi")
mp = abi.comm_from_axes(("model",))
world = C.PAX_COMM_WORLD


def body3(x):
    b = abi.bcast(x, root=3, comm=world)  # broadcast rank 3's chunk
    ring_perm = [(i, (i + 1) % 4) for i in range(4)]
    sr = abi.sendrecv(x, ring_perm, mp)
    a2a = abi.alltoall(x.reshape(4, 2), mp, 0, 0)
    abi.barrier(world)
    sc = abi.scatter(b, root=0, comm=world)  # split bcast chunk 8 ways
    return b, sr, a2a.reshape(-1), sc


f3 = abi.shard_region(
    body3, in_specs=P(("data", "model")),
    out_specs=(P(), P(("data", "model")), P(("data", "model")), P(("data", "model"))),
)
b, sr, a2a, sc = jax.jit(f3)(jnp.asarray(XG.reshape(-1)))
np.testing.assert_allclose(np.asarray(b[:8]), XG[3])  # everyone sees rank 3
# sendrecv ring over model: device (0,1) receives from (0,0)
np.testing.assert_allclose(np.asarray(sr[8:16]), XG[0])
# alltoall over model among ranks (0,0..3): device (0,0) collects block 0 of each
exp_a2a0 = np.concatenate([XG[m][0:2] for m in range(4)])
np.testing.assert_allclose(np.asarray(a2a[:8]), exp_a2a0)
# scatter of the bcast result: rank k gets elem k of XG[3]
np.testing.assert_allclose(np.asarray(sc), XG[3])
print("  OK")

# ---------------------------------------------------------------------------
section("4. user op + MINLOC across ranks")
abi = C.pax_init(mesh, impl="paxi")
opq = abi.op_create(lambda a, b: jnp.sqrt(a * a + b * b), name="l2")


def body4(x):
    q = abi.allreduce(x, opq, world)
    pairs = jnp.stack([x, jnp.full_like(x, C_rank())], axis=-1)
    ml = abi.allreduce(pairs, C.PAX_MINLOC, world)
    return q, ml


def C_rank():
    from repro.core.backends import _lax

    return _lax.rank(("data", "model")).astype(jnp.float32)


f4 = abi.shard_region(body4, in_specs=P(("data", "model")), out_specs=(P(), P()))
q, ml = jax.jit(f4)(jnp.asarray(XG.reshape(-1)))
np.testing.assert_allclose(np.asarray(q[:8]), np.sqrt((XG**2).sum(0)), rtol=1e-5)
np.testing.assert_allclose(np.asarray(ml[:8, 0]), XG.min(0))
np.testing.assert_allclose(np.asarray(ml[:8, 1]), XG.argmin(0))  # winning rank
print("  OK")

# ---------------------------------------------------------------------------
section("5. Mukautuva across ranks: alltoallw + trampoline")
abi = C.pax_init(mesh, impl="ompix")
mp = abi.comm_from_axes(("model",))
send_t = [C.PAX_FLOAT32] * 4
recv_t = [C.PAX_FLOAT64, C.PAX_FLOAT32, C.PAX_FLOAT64, C.PAX_FLOAT32]


def body5(x):
    blocks = x.reshape(4, 2)
    parts = abi.alltoallw(blocks, send_t, recv_t, mp)
    return tuple(p.astype(jnp.float32) for p in parts)


f5 = abi.shard_region(body5, in_specs=P(("data", "model")),
                      out_specs=tuple(P(("data", "model")) for _ in range(4)))
parts = jax.jit(f5)(jnp.asarray(XG.reshape(-1)))
np.testing.assert_allclose(np.asarray(parts[0])[:2], XG[0][0:2])
print("  alltoallw OK (per-peer dtype conversion via impl)")

opspy = abi.op_create(lambda a, b: a + b, name="sumspy")


def body5b(x):
    return abi.allreduce(x, opspy, world)


f5b = abi.shard_region(body5b, in_specs=P(("data", "model")), out_specs=P())
v = jax.jit(f5b)(jnp.asarray(XG.reshape(-1)))
np.testing.assert_allclose(np.asarray(v[:8]), exp_sum, rtol=1e-5)
print("  user-op through foreign backend OK")

# ---------------------------------------------------------------------------
section("6. ring compression error bounds")
gold = exp_sum
for impl, bound in (("ring-bf16", 0.01), ("ring-int8", 0.05)):
    abi = C.pax_init(mesh, impl=impl)
    f6 = abi.shard_region(
        lambda x: abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_WORLD),
        in_specs=P(("data", "model")), out_specs=P(),
    )
    v = np.asarray(jax.jit(f6)(jnp.asarray(XG.reshape(-1)))[:8])
    rel = np.abs(v - gold) / np.abs(gold)
    assert rel.max() < bound, (impl, rel.max())
    print(f"  {impl}: max rel err {rel.max():.4f} < {bound}")

# scan/exscan on the compressed wire (the hierarchical multi-axis ring
# schedule; previously these fell back to the uncompressed generic fold).
# The error budget is bigger than rs/ag's: a contribution is re-quantized on
# every hop it travels and the row-total all-reduce adds its own hops.
for impl, bound in (("ring-bf16", 0.02), ("ring-int8", 0.05)):
    abi = C.pax_init(mesh, impl=impl)
    f6s = abi.shard_region(
        lambda x: (abi.scan(x, C.PAX_SUM, C.PAX_COMM_WORLD),
                   abi.exscan(x, C.PAX_SUM, C.PAX_COMM_WORLD)),
        in_specs=P(("data", "model")),
        out_specs=(P(("data", "model")), P(("data", "model"))),
    )
    sc6, ex6 = jax.jit(f6s)(jnp.asarray(XG.reshape(-1)))
    rel_sc = np.abs(np.asarray(sc6).reshape(8, 8) - exp_scan) / np.abs(exp_scan)
    rel_ex = np.abs(np.asarray(ex6).reshape(8, 8) - exp_exscan) / np.abs(exp_exscan)
    assert rel_sc.max() < bound, (impl, "scan", rel_sc.max())
    assert rel_ex.max() < bound, (impl, "exscan", rel_ex.max())
    print(f"  {impl}: scan/exscan max rel err "
          f"{max(rel_sc.max(), rel_ex.max()):.4f} < {bound}")

# ---------------------------------------------------------------------------
section("7. ZeRO-1 flat round trip across dp ranks (pooled nonblocking path)")
# dp=2 over the "data" axis: reduce-scatter of the dp-mean gradient, shard
# update f(g)=g*2, all-gather back must equal mean(g_dp) * 2 on every rank
from repro.runtime.dist import make_dist
from repro.train.grad_sync import zero1_step

dist = make_dist(mesh, impl="paxi")
assert dist.dp_size == 2, dist.dp_axes
NV = 16


def body7(v):
    params, ef = zero1_step(dist, v, lambda s: s * 2.0, buckets=2)
    assert ef is None
    return params


f7 = dist.abi.shard_region(body7, in_specs=P("data"), out_specs=P())
vin = np.arange(2 * NV, dtype=np.float32).reshape(-1)  # rank-major halves
out = np.asarray(jax.jit(f7)(jnp.asarray(vin))[:NV])
expect = (vin[:NV] + vin[NV:]) / 2.0 * 2.0
np.testing.assert_allclose(out, expect, rtol=1e-6)
assert dist.abi.outstanding_requests == 0
print("  zero1_step dp=2 buckets=2 OK (pool drained)")

# the train-loop flat layout: moments shard P(dp_axes), params replicated
from repro.optim import adamw as _adamw
from repro.train import train_loop as _tl

flat = _adamw.init_flat_global({"w": np.zeros(NV, np.float32)}, dist.dp_size,
                               buckets=2)
assert flat.m.shape[0] % (dist.dp_size * 2) == 0
print("  init_flat_global padding contract OK")

# body_zero1's alignment invariant at dp=2: the comm_rank_traced slice of a
# replicated flat vector, the P(dp_axes)-sharded view of the same vector,
# and the (transposed-split, bucketed) reduce-scatter shard must all be the
# SAME contiguous rank slice — moments would otherwise pair with the wrong
# gradient elements and training would silently diverge at dp>1
from repro.core.communicator import comm_rank_traced
from repro.train.grad_sync import reduce_scatter_grads

full = np.arange(NV, dtype=np.float32)       # NV=16, dp=2 -> shard 8
shard_len = NV // dist.dp_size


def body7b(m_shard, v_full):
    r = comm_rank_traced(dist.abi.comms.info(dist.dp_comm))
    p_slice = jax.lax.dynamic_slice_in_dim(v_full, r * shard_len, shard_len)
    # g_shard: dp-mean reduce-scatter of the replicated vector == rank slice
    g_shard, _ = reduce_scatter_grads(dist, v_full, buckets=2)
    return m_shard - p_slice, g_shard - p_slice


f7b = dist.abi.shard_region(
    body7b, in_specs=(P("data"), P()), out_specs=(P("data"), P("data")))
d_m, d_g = jax.jit(f7b)(jnp.asarray(full), jnp.asarray(full))
np.testing.assert_allclose(np.asarray(d_m), 0.0)  # sharded view == rank slice
np.testing.assert_allclose(np.asarray(d_g), 0.0)  # rs shard == rank slice
assert dist.abi.outstanding_requests == 0
print("  zero1 moment/param/grad shard alignment dp=2 OK")

# ---------------------------------------------------------------------------
section("8. tiered negotiation: minimal backend emulation chains end-to-end")
# The deliberately-partial backend (handle queries + sendrecv/reduce_scatter/
# allgather) must run the training round trip and the deepest recipe chains
# (scatter -> bcast -> allreduce -> rs+ag) purely through emulation.
dist_min = make_dist(mesh, impl="minimal")
caps = dist_min.abi.capabilities()
assert caps["allreduce"]["source"] == "emulated", caps["allreduce"]
assert caps["scatter"]["source"] == "emulated"
assert caps["scatter"]["deps"] == ("bcast", "comm_rank", "comm_size")
assert caps["reduce_scatter"]["source"] == "native"
assert not [n for n, i in caps.items() if i["source"] == "unavailable"]

out8 = np.asarray(jax.jit(dist_min.abi.shard_region(
    lambda v: zero1_step(dist_min, v, lambda s: s * 2.0, buckets=2)[0],
    in_specs=P("data"), out_specs=P()))(jnp.asarray(vin))[:NV])
np.testing.assert_allclose(out8, expect, rtol=1e-6)
assert dist_min.abi.outstanding_requests == 0
print("  zero1_step dp=2 on minimal backend OK (native rs/ag, pooled i*)")

abi_min = dist_min.abi
mp8 = abi_min.comm_from_axes(("model",))


def body8(x):
    # allreduce (emulated, depth 1), bcast (depth 2) and scatter (depth 3 —
    # the deepest chain), plus emulated alltoall/scan/barrier, all checked
    # against the native-oracle expectations from sections 1 and 3
    ar = abi_min.allreduce(x, C.PAX_SUM, world)
    b = abi_min.bcast(x, root=3, comm=world)
    sc8 = abi_min.scatter(b, root=0, comm=world)
    a2a = abi_min.alltoall(x.reshape(4, 2), mp8, 0, 0)
    s = abi_min.scan(x, C.PAX_SUM, world)
    abi_min.barrier(world)
    return ar, b, sc8, a2a.reshape(-1), s


f8 = abi_min.shard_region(
    body8, in_specs=P(("data", "model")),
    out_specs=(P(), P(), P(("data", "model")), P(("data", "model")),
               P(("data", "model"))),
)
ar8, b, sc8, a2a8, s8 = jax.jit(f8)(jnp.asarray(XG.reshape(-1)))
np.testing.assert_allclose(np.asarray(ar8[:8]), exp_sum, rtol=1e-5)
np.testing.assert_allclose(np.asarray(b[:8]), XG[3])
np.testing.assert_allclose(np.asarray(sc8), XG[3])
np.testing.assert_allclose(np.asarray(a2a8[:8]), exp_a2a0)
np.testing.assert_allclose(np.asarray(s8).reshape(8, 8), exp_scan, rtol=1e-5)
assert dist_min.abi.outstanding_requests == 0
print("  emulation chains (depth 1-3) match native oracles OK")

# ---------------------------------------------------------------------------
section("9. persistent plans: plan-time hoisting == per-call semantics (dp=2)")
# the zero1 round trip on persistent plans (the init_state wiring) must give
# byte-identical math to the pooled i* path of section 7, and the plans'
# restartable requests must flip inactive<->active across steps without
# touching the pool
from repro.train.grad_sync import build_zero1_plans

plans = build_zero1_plans(dist, NV, 2)
pool_before = len(dist.abi._req_pool)


def body9(v):
    params, ef = zero1_step(dist, v, lambda s: s * 2.0, buckets=2, plans=plans)
    assert ef is None
    return params


f9 = dist.abi.shard_region(body9, in_specs=P("data"), out_specs=P())
out9 = np.asarray(jax.jit(f9)(jnp.asarray(vin))[:NV])
np.testing.assert_allclose(out9, expect, rtol=1e-6)
# restart: a second trace re-drives the same plans (inactive -> active -> ...)
out9b = np.asarray(jax.jit(dist.abi.shard_region(
    body9, in_specs=P("data"), out_specs=P()))(jnp.asarray(vin))[:NV])
np.testing.assert_allclose(out9b, expect, rtol=1e-6)
assert dist.abi.outstanding_requests == 0
assert len(dist.abi._req_pool) == pool_before  # no slot churn across steps
print("  zero1 persistent-plan round trip dp=2 buckets=2 OK (slots reused)")

# emulated persistent plan with plan-time padding: 11 rows over an 8-rank
# world comm — the recipe plan precomputes pad=5 and the [:11] slice; result
# must match the blocking emulated allreduce exactly
abi_min9 = dist_min.abi
plan9 = abi_min9.allreduce_init(jnp.zeros(11, jnp.float32), C.PAX_SUM, world)
f9c = abi_min9.shard_region(
    lambda x: (abi_min9.wait(plan9.start(x)), abi_min9.allreduce(x, C.PAX_SUM, world)),
    in_specs=P(), out_specs=(P(), P()))
v_pers, v_block = jax.jit(f9c)(jnp.arange(11.0) + 1.0)
np.testing.assert_allclose(np.asarray(v_pers), np.asarray(v_block), rtol=1e-6)
np.testing.assert_allclose(np.asarray(v_pers), (np.arange(11.0) + 1.0) * 8)
caps9 = abi_min9.capabilities()
assert caps9["allreduce"]["plan"] == "recipe-plan"
print("  emulated persistent allreduce (plan-time pad/slice) dp=8 OK")

# error feedback through the zero1 wiring at dp=2: with bf16 compression the
# per-rank residual v - bf16(v) comes back from reduce_scatter_grads and,
# folded into the next step, makes the delivered sum unbiased:
#   g1 + g2 = bf16(v) + bf16(v + e1) = 2v - e2   (residuals never lost)
ef0 = jnp.zeros((2 * NV,), jnp.float32)  # per-rank full-length residuals
vfine = jnp.asarray(np.linspace(0.1, 1.7, NV, dtype=np.float32))  # inexact in bf16


def body9d(ef):
    g1, ef1 = reduce_scatter_grads(dist, vfine, compression="bf16", buckets=2,
                                   ef=ef)
    g2, ef2 = reduce_scatter_grads(dist, vfine, compression="bf16", buckets=2,
                                   ef=ef1)
    return g1, g2, ef1, ef2


f9d = dist.abi.shard_region(body9d, in_specs=P("data"),
                            out_specs=(P("data"),) * 4)
g1, g2, ef1, ef2 = (np.asarray(a) for a in jax.jit(f9d)(ef0))
v_np = np.asarray(vfine)
w1 = np.asarray(jnp.asarray(vfine).astype(jnp.bfloat16).astype(jnp.float32))
e1 = v_np - w1
assert np.abs(e1).max() > 0  # the bf16 residual is real for these values
np.testing.assert_allclose(ef1[:NV], e1, atol=0)   # rank 0's residual, exact
np.testing.assert_allclose(ef1[NV:], e1, atol=0)   # rank 1's (same grads)
np.testing.assert_allclose(g1, w1, rtol=0, atol=1e-7)  # dp-mean of wires
# the EF identity: two delivered steps sum to 2v minus only the *last*
# residual — the step-1 quantization error was recovered, not dropped
np.testing.assert_allclose(g1 + g2, 2 * v_np - ef2[:NV], rtol=0, atol=1e-6)
print(f"  zero1 bf16 error feedback dp=2 OK (residual max {np.abs(e1).max():.2e})")

# ---------------------------------------------------------------------------
section("10. plan groups (Startall): group == per-plan zero1, dp=2 and dp=8")
# The whole-group start/wait pair must deliver byte-identical math to the
# pooled per-bucket path, across a native backend (paxi: stacked-collective
# group hooks), the emulated-minimal backend (recipe stage fusion: all rs
# legs before any ag leg) and a Mukautuva-wrapped backend (generated group
# wrappers, conversion cached at group-build time) — at dp=2 (2x4 mesh) and
# dp=8 (8x1 mesh).
mesh8 = make_mesh((8, 1), ("data", "model"))
for impl10 in ("paxi", "minimal", "ompix"):
    for m10, dp10 in ((mesh, 2), (mesh8, 8)):
        d10 = make_dist(m10, impl=impl10)
        assert d10.dp_size == dp10
        plans10 = build_zero1_plans(d10, NV, 2)
        caps10 = d10.abi.capabilities()
        if impl10 == "minimal":
            assert caps10["allreduce"]["plan_group"] == "recipe-stage"
        else:
            assert caps10["allreduce"]["plan_group"] == "backend-hook"
        vin10 = np.arange(dp10 * NV, dtype=np.float32)
        exp10 = vin10.reshape(dp10, NV).mean(0) * 2.0

        def body10(v, _d=d10, _p=plans10):
            grouped = zero1_step(_d, v, lambda s: s * 2.0, buckets=2,
                                 plans=_p)[0]
            pooled = zero1_step(_d, v, lambda s: s * 2.0, buckets=2)[0]
            return grouped, pooled

        f10 = d10.abi.shard_region(body10, in_specs=P("data"),
                                   out_specs=(P(), P()))
        grouped, pooled = jax.jit(f10)(jnp.asarray(vin10))
        np.testing.assert_allclose(np.asarray(grouped[:NV]), exp10, rtol=1e-6,
                                   err_msg=f"{impl10} dp={dp10}")
        np.testing.assert_allclose(np.asarray(grouped), np.asarray(pooled),
                                   rtol=0, atol=0,
                                   err_msg=f"{impl10} dp={dp10}")
        assert d10.abi.outstanding_requests == 0
        print(f"  {impl10} dp={dp10}: group == per-plan (bitwise) OK")

# the ring backend's fused compressed wire: the grouped rs/ag ride ONE ring
# schedule whose per-hop quantization covers all buckets; error stays within
# the section-6 budget and the uncompressed group is exact vs the oracle
for impl10, bound10 in (("ring", 0.0), ("ring-bf16", 0.01)):
    d10 = make_dist(mesh, impl=impl10)
    plans10 = build_zero1_plans(d10, NV, 2)
    vin10 = np.arange(2 * NV, dtype=np.float32) + 1.0
    exp10 = vin10.reshape(2, NV).mean(0) * 2.0
    f10 = d10.abi.shard_region(
        lambda v, _d=d10, _p=plans10: zero1_step(
            _d, v, lambda s: s * 2.0, buckets=2, plans=_p)[0],
        in_specs=P("data"), out_specs=P())
    out10 = np.asarray(jax.jit(f10)(jnp.asarray(vin10))[:NV])
    if bound10 == 0.0:
        np.testing.assert_allclose(out10, exp10, rtol=1e-6, err_msg=impl10)
    else:
        rel10 = np.abs(out10 - exp10) / np.maximum(np.abs(exp10), 1e-6)
        assert rel10.max() < bound10, (impl10, rel10.max())
    assert d10.abi.outstanding_requests == 0
    print(f"  {impl10}: fused-wire grouped zero1 OK")

# ---------------------------------------------------------------------------
section("11. hierarchical multi-axis alltoallv (world comm, 2x4 mesh)")
# alltoallv over the 8-rank world communicator decomposes axis by axis (the
# ring_scan_sum_multi pattern): with c=1 and rank r holding XG[r], peer j
# receives element r — the result is the global transpose.  c=2 checks the
# block layout too.  Oracles are pure numpy; every backend must agree
# (paxi/ring lower natively, minimal emulates over allgather, ompix crosses
# Mukautuva).
for impl11 in ("paxi", "ring", "minimal", "ompix"):
    abi11 = C.pax_init(mesh, impl=impl11)
    f11 = abi11.shard_region(
        lambda x: abi11.alltoallv(x, (1,) * 8, (1,) * 8, world),
        in_specs=P(("data", "model")), out_specs=P(("data", "model")))
    out11 = np.asarray(jax.jit(f11)(jnp.asarray(XG.reshape(-1)))).reshape(8, 8)
    np.testing.assert_allclose(out11, XG.T, err_msg=impl11)
    X2 = np.arange(128.0).reshape(8, 16)
    f11b = abi11.shard_region(
        lambda x: abi11.alltoallv(x, (2,) * 8, (2,) * 8, world),
        in_specs=P(("data", "model")), out_specs=P(("data", "model")))
    out11b = np.asarray(jax.jit(f11b)(jnp.asarray(X2.reshape(-1)))).reshape(8, 16)
    exp11b = np.stack([X2[:, 2 * r:2 * r + 2].reshape(-1) for r in range(8)])
    np.testing.assert_allclose(out11b, exp11b, err_msg=impl11)
    print(f"  {impl11}: multi-axis alltoallv == transpose oracle OK")

# ---------------------------------------------------------------------------
section("12. fused wire kernels inside real ring schedules (plan-time selection)")
# Sections 6/10 exercised the compressed ring at shapes the Pallas hop
# kernels decline (per-hop chunks not WIRE_BLOCK-divisible) — proving the
# lax fallback.  Here the shapes are kernel-eligible: at dp=2 a 1024-element
# zero1 with 2 buckets gives 256-element ring chunks (fused hop kernels
# live), at dp=8 the 64-element chunks fall back to lax while the fused
# flatten/bucket pack kernels stay engaged — both legs of the plan-time
# selection contract in one section.
from repro.kernels.ring_wire.kernel import WIRE_BLOCK as _WB

NV12 = 8 * _WB  # 1024

# capability tags: the compressed ring advertises its wire pipeline
for impl12, want12 in (("ring-int8", "pallas"), ("ring-bf16", "pallas"),
                       ("ring", "lax"), ("paxi", None)):
    caps12 = C.pax_init(mesh, impl=impl12).capabilities()
    got12 = caps12["reduce_scatter"].get("wire_kernel")
    assert got12 == want12, (impl12, got12)
print("  capabilities()[reduce_scatter][wire_kernel] tags OK")

# grouped zero1 over the compressed ring at a kernel-eligible layout
for impl12, bound12 in (("ring-bf16", 0.01), ("ring-int8", 0.05)):
    d12 = make_dist(mesh, impl=impl12)
    plans12 = build_zero1_plans(d12, NV12, 2)
    assert plans12.wire_kernel == "pallas"  # fused pack/unpack attached
    vin12 = np.linspace(0.1, 33.0, 2 * NV12, dtype=np.float32)
    exp12 = vin12.reshape(2, NV12).mean(0) * 2.0
    f12 = d12.abi.shard_region(
        lambda v, _d=d12, _p=plans12: zero1_step(
            _d, v, lambda s: s * 2.0, buckets=2, plans=_p)[0],
        in_specs=P("data"), out_specs=P())
    out12 = np.asarray(jax.jit(f12)(jnp.asarray(vin12))[:NV12])
    rel12 = np.abs(out12 - exp12) / np.maximum(np.abs(exp12), 1e-6)
    assert rel12.max() < bound12, (impl12, rel12.max())
    assert d12.abi.outstanding_requests == 0
    print(f"  {impl12}: fused-hop grouped zero1 (256-elem chunks) "
          f"max rel err {rel12.max():.4f} < {bound12}")

# the EF identity of section 9d re-proven on the FUSED pack path (the
# pack_parts_ef kernel folds ef + casts + gathers in one pass) at dp=2 and
# dp=8 — residual semantics must be bit-identical to the lax pipeline
vfine12 = jnp.asarray(np.linspace(0.1, 1.7, NV12, dtype=np.float32))
for m12, dp12 in ((mesh, 2), (mesh8, 8)):
    d12 = make_dist(m12, impl="paxi")
    plans12 = build_zero1_plans(d12, NV12, 2, compression="bf16")
    assert plans12.wire_kernel == "pallas" and plans12.pack is not None

    def body12(ef, _d=d12, _p=plans12):
        g1, ef1 = reduce_scatter_grads(_d, vfine12, compression="bf16",
                                       buckets=2, ef=ef, plans=_p)
        g2, ef2 = reduce_scatter_grads(_d, vfine12, compression="bf16",
                                       buckets=2, ef=ef1, plans=_p)
        return g1, g2, ef1, ef2

    f12b = d12.abi.shard_region(body12, in_specs=P("data"),
                                out_specs=(P("data"),) * 4)
    g1, g2, ef1, ef2 = (np.asarray(a)
                        for a in jax.jit(f12b)(jnp.zeros((dp12 * NV12,),
                                                         jnp.float32)))
    v_np = np.asarray(vfine12)
    w1 = np.asarray(vfine12.astype(jnp.bfloat16).astype(jnp.float32))
    e1 = v_np - w1
    assert np.abs(e1).max() > 0
    np.testing.assert_allclose(ef1[:NV12], e1, atol=0)  # fused residual exact
    np.testing.assert_allclose(g1, w1, rtol=0, atol=1e-7)
    np.testing.assert_allclose(g1 + g2, 2 * v_np - ef2[:NV12],
                               rtol=0, atol=1e-6)
    assert d12.abi.outstanding_requests == 0
    print(f"  fused-pack bf16 error feedback dp={dp12} OK "
          f"(residual max {np.abs(e1).max():.2e})")

# emulated allreduce over the compressed ring at a non-aligned length: the
# recipe plan pads 1000 -> 1024 (S * wire_block) at plan time, so the rs
# leg's 128-element chunks stay kernel-eligible (per-block scales), while
# the blocking call pads only to S (125-element chunks -> lax global-scale
# fallback).  The two are *different* valid int8 approximations — each must
# meet the section-6 budget against the exact oracle, and the kernel path
# (finer scale granularity) must not be the worse of the two.
abi12 = C.pax_init(mesh, impl="ring-int8")
assert abi12.backend.wire_pad_multiple() == _WB
plan12 = abi12.allreduce_init(jnp.zeros(1000, jnp.float32), C.PAX_SUM, world)
f12c = abi12.shard_region(
    lambda x: (abi12.wait(plan12.start(x)),
               abi12.allreduce(x, C.PAX_SUM, world)),
    in_specs=P(), out_specs=(P(), P()))
x12 = jnp.asarray(np.linspace(0.5, 40.0, 1000, dtype=np.float32))
v_pers12, v_block12 = jax.jit(f12c)(x12)
gold12 = 8.0 * np.asarray(x12)
rel_pers = np.abs(np.asarray(v_pers12) - gold12) / gold12
rel_block = np.abs(np.asarray(v_block12) - gold12) / gold12
assert rel_pers.max() < 0.05, rel_pers.max()
# the global-scale fallback is coarser on this 80x-dynamic-range input;
# it gets a proportionally looser budget (the kernel path is the one the
# section-6 0.05 budget must hold for)
assert rel_block.max() < 0.06, rel_block.max()
assert rel_pers.max() <= rel_block.max() + 1e-6, (rel_pers.max(),
                                                  rel_block.max())
print(f"  ring-int8 persistent allreduce n=1000 (block-padded recipe) "
      f"max rel err {rel_pers.max():.4f} (blocking lax {rel_block.max():.4f})"
      " OK")

# ---------------------------------------------------------------------------
section("13. fault tier: injected rank death on three dispatch paths (dp=8)")
# The same ULFM walk — kill -> PROC_FAILED, revoke -> REVOKED exactly,
# ack/agree, shrink 8 -> 7 — through three different dispatch stories:
# paxi (native fault hooks, tripwired optional entries), minimal (recipe
# emulation over the shared kernels) and ompix (failure injected as a
# foreign rc, translated across Mukautuva).
from repro.core.backends.faulty import (FaultSchedule, FaultyBackend,
                                        FaultyLib, fault_schedule_of)
from repro.core.backends.ompix import OmpixLib
from repro.core.mukautuva import MukBackend
from repro.core.errors import (PAX_ERR_PROC_FAILED, PAX_ERR_REVOKED, PaxError)


def make_faulty(impl, m, sched):
    if impl == "ompix":
        return MukBackend(FaultyLib(OmpixLib(m), sched), m)
    return FaultyBackend(C.get_backend(impl, m), sched)


for impl13 in ("paxi", "minimal", "ompix"):
    sched13 = FaultSchedule()
    abi13 = C.pax_init(mesh8, impl=make_faulty(impl13, mesh8, sched13))
    dp13 = abi13.comm_from_axes(("data",), "dp")
    want13 = "native" if impl13 == "paxi" else "emulated"
    caps13 = abi13.capabilities()
    for e13 in ("comm_revoke", "comm_failure_ack", "comm_get_failed",
                "comm_agree", "comm_shrink"):
        assert caps13[e13]["tier"] == "fault", (impl13, e13)
        assert caps13[e13]["source"] == want13, (impl13, e13, caps13[e13])

    def run13(_abi=None, _dp=None):
        _abi, _dp = _abi or abi13, _dp or dp13
        f = _abi.shard_region(lambda x: _abi.allreduce(x, C.PAX_SUM, _dp),
                              in_specs=P("data"), out_specs=P())
        return np.asarray(jax.jit(f)(jnp.ones(8, np.float32)))

    assert run13()[0] == 8.0  # pre-fault: clean dispatch
    sched13.arm(5, after=0)
    try:
        run13()
        raise AssertionError(f"{impl13}: injected death did not surface")
    except PaxError as e13x:
        assert e13x.code == PAX_ERR_PROC_FAILED, (impl13, e13x.code)
    # the detector reports the corpse; agree refuses before acknowledgement
    assert abi13.comm_get_failed(dp13) == (5,), impl13
    try:
        abi13.comm_agree(1, dp13)
        raise AssertionError(f"{impl13}: agree accepted unacked failure")
    except PaxError as e13x:
        assert e13x.code == PAX_ERR_PROC_FAILED
    abi13.comm_revoke(dp13)
    try:
        run13()
        raise AssertionError(f"{impl13}: revoked comm still dispatches")
    except PaxError as e13x:  # REVOKED outranks PROC_FAILED (ULFM)
        assert e13x.code == PAX_ERR_REVOKED, (impl13, e13x.code)
    # fault entries keep working on the revoked comm; shrink recovers
    abi13.comm_failure_ack(dp13)
    assert abi13.comm_agree(1, dp13) == 1
    surv13 = abi13.comm_shrink(dp13)
    assert abi13.comms.info(surv13).excludes == (5,)
    assert abi13.comm_size(surv13) == 7
    # on the survivor comm the corpse is a non-member, not a failure
    assert abi13.comm_get_failed(surv13) == ()
    assert abi13.comm_agree(1, surv13) == 1
    print(f"  {impl13}: kill->PROC_FAILED, revoke->REVOKED, shrink 8->7 OK")

# CI chaos leg: when PAX_FAULT_SCHEDULE is set, the registry's faulty:
# prefix must arm from the environment and the schedule must fire at the
# configured call count — the deterministic chaos contract.
env13 = os.environ.get("PAX_FAULT_SCHEDULE")
se13 = None
if env13:
    abi13e = C.pax_init(mesh8, impl="faulty:paxi")
    se13 = fault_schedule_of(abi13e.backend)
    assert se13 is not None and se13.armed, env13
if se13 is not None and se13.mode != "die":
    # transport schedules (corrupt/drop/delay) exercise section 18's env
    # leg instead — they never set ``dead``, so the death walk below would
    # be vacuous
    print(f"  env chaos schedule {env13!r}: transport mode, see section 18")
elif se13 is not None:
    dpe13 = abi13e.comm_from_axes(("data",), "dp")
    for _ in range(se13.at_call + 1):  # drive the counter to the kill point
        se13.on_call()
    assert se13.dead
    try:
        run13(abi13e, dpe13)
        raise AssertionError("env-armed schedule did not fire")
    except PaxError as e13x:
        assert e13x.code == PAX_ERR_PROC_FAILED
    abi13e.comm_revoke(dpe13)
    abi13e.comm_failure_ack(dpe13)
    surv13e = abi13e.comm_shrink(dpe13)
    lost13 = 1 if 0 <= se13.kill_rank < 8 else 0
    assert abi13e.comm_size(surv13e) == 8 - lost13
    print(f"  env chaos schedule {env13!r}: fired and recovered OK")

# ---------------------------------------------------------------------------
section("14. elastic-dp: kill rank 5 at dp=8, shrink, bitwise resume at dp=4")
# The end-to-end recovery contract: supervised training at dp=8 loses rank 5
# mid-run; the fault-tier walk shrinks the world, the policy rebuilds a
# dp=4 mesh over the survivors (power-of-two trim of the 7), the checkpoint
# reshards onto it, and the resumed trajectory is BITWISE identical to an
# uninterrupted dp=4 oracle restored from the same checkpoint.  Replay is
# bounded by the checkpoint cadence (the recovery_steps_overhead gate).
import shutil
import tempfile

import repro.configs as cfgs
from repro.checkpoint.checkpointer import Checkpointer
from repro.models import build_model, make_batch
from repro.optim.adamw import AdamWConfig
from repro.runtime.dist import survivor_mesh
from repro.runtime.fault import run_supervised
from repro.train import train_loop

cfg14 = cfgs.smoke_config("qwen2-0.5b")
api14 = build_model(cfg14)
key14 = jax.random.PRNGKey(0)
opt14 = AdamWConfig(lr=5e-3)
TOTAL14, EVERY14, KILL_AT14, KILL_RANK14 = 8, 4, 6, 5

n14 = sum(int(x.size) for x in jax.tree.leaves(api14.init(key14)))
assert n14 % 8 == 0, n14  # flat zero1 layout identical at dp=8 and dp=4


def batch_at14(step):
    return make_batch(jax.random.PRNGKey(1000 + step), cfg14, 8, 16)


mesh4 = jax.sharding.Mesh(
    np.array(jax.devices()[:4], dtype=object).reshape(4, 1),
    ("data", "model"))
# the policy's survivor trim must land on exactly this mesh
smesh14 = survivor_mesh(mesh8, (KILL_RANK14,))
assert tuple(smesh14.devices.flat[:4]) == tuple(mesh4.devices.flat)

for impl14 in ("paxi", "minimal", "ompix"):
    sched14 = FaultSchedule()
    dist8 = make_dist(mesh8, impl=make_faulty(impl14, mesh8, sched14))
    assert dist8.dp_size == 8
    state0 = train_loop.init_state(api14, key14, dist8)
    step8 = train_loop.with_failure_probe(
        dist8, jax.jit(train_loop.make_train_step(api14, dist8, opt14)))
    policy14 = train_loop.elastic_recovery_policy(
        api14, opt14, dist8, key14, impl=impl14)
    killed14 = []

    def get_batch14(i, _s=sched14, _k=killed14):
        if i == KILL_AT14 and not _k:
            _k.append(i)
            _s.kill_rank = KILL_RANK14
            _s.dead = True  # the detector now reports rank 5 dead
        return batch_at14(i)

    ckdir14 = tempfile.mkdtemp(prefix=f"elastic_{impl14}_")
    ck14 = Checkpointer(ckdir14, keep=5)
    report14 = run_supervised(
        step8, state0, get_batch14, checkpointer=ck14,
        total_steps=TOTAL14, checkpoint_every=EVERY14, max_restarts=2,
        recover=policy14)
    assert report14.restarts == 1, impl14
    assert report14.steps_completed == TOTAL14
    assert len(report14.losses) == TOTAL14  # one loss per step, replay-clean
    assert policy14.dist.dp_size == 4      # 7 survivors -> power-of-two trim
    assert policy14.dist is not dist8

    # the oracle: an uninterrupted dp=4 run restored from the SAME step-4
    # checkpoint, on the same survivor devices, with the plain backend
    dist4 = make_dist(mesh4, impl=impl14)
    like4 = train_loop.init_state(api14, key14, dist4)
    specs4 = train_loop.state_specs(api14, "abi", dp_axes=dist4.dp_axes)
    state4, step4 = ck14.restore(like4, step=EVERY14, mesh=mesh4, specs=specs4)
    assert step4 == EVERY14  # replayed steps <= checkpoint_every
    jstep4 = jax.jit(train_loop.make_train_step(api14, dist4, opt14))
    for s14 in range(EVERY14, TOTAL14):
        state4, _m14 = jstep4(state4, batch_at14(s14))
    v_leaves = jax.tree.leaves(report14.final_state)
    o_leaves = jax.tree.leaves(state4)
    assert len(v_leaves) == len(o_leaves)
    for a14, b14 in zip(v_leaves, o_leaves):
        np.testing.assert_array_equal(np.asarray(a14), np.asarray(b14))
    shutil.rmtree(ckdir14, ignore_errors=True)
    print(f"  {impl14}: death at step {KILL_AT14} -> dp=4 resume "
          "bitwise == oracle OK")

# ---------------------------------------------------------------------------
section("15. serving decode-tp plan group == pooled i* bcast (tp=4)")
# The serve engine's per-token control-plane sync (sampled tokens + active
# mask broadcast from tp root 0) rides ONE persistent plan group built at
# engine init.  Across backends, the group start/wait must be bitwise equal
# to the pooled nonblocking ibcast/waitall reference on genuinely different
# per-rank data (tp_comm spans "model", size 4), and a counting tool must
# see exactly one "decode-tp" call per step and none of the pooled entries.
from repro.serve.engine import DecodeSync

MB15 = 8
tok15 = jnp.arange(4 * MB15, dtype=jnp.int32) * 3 + 1   # rank-major blocks
act15 = (jnp.arange(4 * MB15, dtype=jnp.int32) % 2).astype(jnp.int32)
exp_tok15 = np.tile(np.asarray(tok15[:MB15]), 4)        # root 0's block
exp_act15 = np.tile(np.asarray(act15[:MB15]), 4)
for impl15 in ("paxi", "minimal", "ompix"):
    if impl15 not in C.available_backends():
        continue
    dist15 = make_dist(mesh, impl=impl15)
    abi15 = dist15.abi
    cc15 = C.CallCounter()
    abi15.attach_tool(cc15)
    ds15 = DecodeSync(abi15, dist15.tp_comm, MB15, mesh)
    spec15 = (P("model"), P("model"))

    def grp15(t, a, _ds=ds15, _abi=abi15):
        outs = _abi.wait(_ds.group.start([t, a]))
        return outs[0], outs[1]

    def pool15(t, a, _ds=ds15, _abi=abi15):
        outs = _abi.waitall([_abi.ibcast(t, 0, _ds.comm),
                             _abi.ibcast(a, 0, _ds.comm)])
        return outs[0], outs[1]

    for _rep15 in range(3):   # restartable: same group slot every step
        gt15, ga15 = shard_map(grp15, mesh=mesh, in_specs=spec15,
                               out_specs=spec15)(tok15, act15)
        pt15, pa15 = shard_map(pool15, mesh=mesh, in_specs=spec15,
                               out_specs=spec15)(tok15, act15)
        np.testing.assert_array_equal(np.asarray(gt15), np.asarray(pt15))
        np.testing.assert_array_equal(np.asarray(ga15), np.asarray(pa15))
    np.testing.assert_array_equal(np.asarray(gt15), exp_tok15)
    np.testing.assert_array_equal(np.asarray(ga15), exp_act15)
    assert cc15.counts[DecodeSync.NAME] == 3, cc15.counts
    assert cc15.counts["bcast"] == 6, cc15.counts  # pooled reference only
    ds15.free()
    print(f"  {impl15}: decode-tp group == pooled (bitwise), "
          "1 group call/step OK")

# ---------------------------------------------------------------------------
section("16. serving fault supervisor: mid-decode kill at tp=4, heartbeat-"
        "observed death, shrink + token-identical replay")
# The PR-9 acceptance scenario.  A supervised serving engine loses a tp
# rank mid-decode with THREE requests in flight.  The backend does NOT
# declare the death (declare_failures=False — the silent-killer mode):
# only the HeartbeatMonitor's missed-beat state machine can name the
# corpse, via the heartbeat_silent transport hook.  The supervisor walks
# revoke -> ack -> get_failed -> agree -> shrink on the tp comm, rebuilds
# DecodeSync on the shrunk survivor comm, and replays the in-flight
# requests from their prompts.  Because sampling keys are
# fold_in(fold_in(key, rid), len(out_tokens)), the replayed streams must
# be BITWISE identical to an unfailed oracle — on all three dispatch
# paths (paxi native, minimal emulation, ompix across Mukautuva).
from repro.runtime.liveness import HeartbeatMonitor
from repro.serve.engine import Request, ServeEngine
from repro.serve.supervisor import ServeSupervisor

params16 = api14.init(jax.random.PRNGKey(0))


def mk_reqs16():
    # request 1 samples at temperature 0.8: replay identity must hold for
    # seeded sampling, not just greedy argmax
    return [Request(i, np.arange(1, 6 + i, dtype=np.int32),
                    max_new_tokens=16, temperature=0.8 if i == 1 else 0.0)
            for i in range(3)]


def make_faulty16(impl, m, sched):
    if impl == "ompix":
        return MukBackend(FaultyLib(OmpixLib(m), sched,
                                    declare_failures=False), m)
    return FaultyBackend(C.get_backend(impl, m), sched,
                         declare_failures=False)


# ONE engine: the jitted prefill/decode functions compile once and every
# leg (oracle + three impls) reuses them — only the DecodeSync, monitor
# and supervisor are per-impl.
eng16 = ServeEngine(api14, params16, max_batch=3, max_seq=64, block_size=4,
                    prefill_chunk=4, seed=0)
oreqs16 = mk_reqs16()
eng16.run(oreqs16)
want16 = [r.out_tokens for r in oreqs16]

for impl16 in ("paxi", "minimal", "ompix"):
    sched16 = FaultSchedule()
    abi16 = C.pax_init(mesh, impl=make_faulty16(impl16, mesh, sched16))
    tp16 = abi16.comm_from_axes(("model",), "tp")
    eng16.decode_sync = DecodeSync(abi16, tp16, 3, mesh)
    mon16 = HeartbeatMonitor(abi16, tp16, mesh, miss_threshold=2,
                             suspicion_ticks=1).install()
    sup16 = ServeSupervisor(eng16, monitor=mon16, heartbeat_every=1)
    for r16 in mk_reqs16():
        eng16.submit(r16)
    reqs16 = list(eng16.scheduler.waiting)
    # step until every slot is decoding — max_new_tokens=16 keeps the
    # earliest request alive long past the last one's prefill runway, so
    # the all-decoding window is guaranteed to exist
    while not all(s16 is not None and s16.state == "decode"
                  for s16 in eng16.scheduler.slots):
        sup16.step()
    mid16 = [len(r16.out_tokens) for r16 in reqs16]
    assert all(m16 > 0 for m16 in mid16), mid16   # genuinely mid-decode
    sched16.arm(2, after=0)                        # rank 2 dies silently
    sup16.drain()
    got16 = [r16.out_tokens for r16 in reqs16]
    assert got16 == want16, (impl16, got16, want16)
    assert sup16.report.failures == 1, sup16.report
    assert sup16.report.tokens_replayed == sum(mid16), (
        sup16.report.tokens_replayed, mid16)
    assert abi16.comms.info(eng16.decode_sync.comm).excludes == (2,)
    assert 2 in mon16.confirmed                    # observed, not declared
    sup16.report.assert_consistent()
    mon16.uninstall()
    eng16.decode_sync.free()
    eng16.decode_sync = None
    print(f"  {impl16}: mid-decode kill (in-flight {mid16}) -> shrink, "
          f"replay {sup16.report.tokens_replayed} tokens, "
          "streams bitwise == oracle OK")

# CI chaos-serve leg: with PAX_FAULT_SCHEDULE armed, the registry's
# faulty: prefix feeds the serving supervisor too.  The scheduled rank is
# killed up front (counter driven to the kill point, as in section 13);
# if it is a member of the tp comm the supervisor must recover before a
# single token is lost, and if it is NOT a member (the training chaos
# leg's rank=5 vs tp full size 4) the run must complete unfailed — the
# detectors filter by membership.
env16 = os.environ.get("PAX_FAULT_SCHEDULE")
se16 = None
if env16:
    abi16e = C.pax_init(mesh, impl="faulty:paxi")
    se16 = fault_schedule_of(abi16e.backend)
    assert se16 is not None and se16.armed, env16
if se16 is not None and se16.mode != "die":
    print(f"  env chaos schedule {env16!r}: transport mode, see section 18")
elif se16 is not None:
    tp16e = abi16e.comm_from_axes(("model",), "tp")
    eng16.decode_sync = DecodeSync(abi16e, tp16e, 3, mesh)
    mon16e = HeartbeatMonitor(abi16e, tp16e, mesh, miss_threshold=2,
                              suspicion_ticks=1).install()
    sup16e = ServeSupervisor(eng16, monitor=mon16e, heartbeat_every=1)
    for _ in range(se16.at_call + 1):   # drive the counter to the kill
        se16.on_call()
    assert se16.dead
    member16 = 0 <= se16.kill_rank < abi16e.comms.info(tp16e).full_size
    oreqs16e = mk_reqs16()
    for r16 in oreqs16e:
        eng16.submit(r16)
    sup16e.drain()
    assert [r16.out_tokens for r16 in oreqs16e] == want16
    if member16:
        assert sup16e.report.failures == 1, sup16e.report
        assert abi16e.comms.info(eng16.decode_sync.comm).excludes == (
            se16.kill_rank,)
    else:
        assert sup16e.report.failures == 0, sup16e.report
    sup16e.report.assert_consistent()
    mon16e.uninstall()
    eng16.decode_sync.free()
    eng16.decode_sync = None
    print(f"  env chaos schedule {env16!r}: serve leg "
          f"{'recovered' if member16 else 'unfailed (non-member corpse)'}"
          " OK")

# ---------------------------------------------------------------------------
section("17. uneven-shard elastic recovery: dp=8 -> dp=7, all survivors kept")
# The power-of-two trim in section 14 throws away three healthy ranks when
# one dies.  elastic_recovery_policy(uneven_shards=True) keeps all seven:
# the global batch is rebalanced per step (host-side trim to a dp
# multiple, deterministically the tail), and the per-leaf DDP optimizer
# layout replaces the zero1 flat layout (which pads per-dp-extent and
# cannot restore an old checkpoint shape at a new dp).  The resumed
# trajectory must be bitwise identical to an uninterrupted dp=7 oracle
# restored from the same checkpoint and fed the same rebalanced batches.
import dataclasses

cfg17 = dataclasses.replace(
    cfg14, parallelism=dataclasses.replace(cfg14.parallelism, zero1=False))
api17 = build_model(cfg17)
sched17 = FaultSchedule()
dist17 = make_dist(mesh8, impl=make_faulty("paxi", mesh8, sched17))
state17 = train_loop.init_state(api17, key14, dist17)
step17 = train_loop.with_failure_probe(
    dist17, jax.jit(train_loop.make_train_step(api17, dist17, opt14)))
policy17 = train_loop.elastic_recovery_policy(
    api17, opt14, dist17, key14, impl="paxi", uneven_shards=True)
killed17 = []


def batch_at17(step):
    return make_batch(jax.random.PRNGKey(1000 + step), cfg17, 8, 16)


def get_batch17(i):
    if i == KILL_AT14 and not killed17:
        killed17.append(i)
        sched17.kill_rank = KILL_RANK14
        sched17.dead = True
    return batch_at17(i)


ckdir17 = tempfile.mkdtemp(prefix="uneven_")
ck17 = Checkpointer(ckdir17, keep=5)
report17 = run_supervised(
    step17, state17, get_batch17, checkpointer=ck17,
    total_steps=TOTAL14, checkpoint_every=EVERY14, max_restarts=2,
    recover=policy17)
assert report17.restarts == 1
assert report17.steps_completed == TOTAL14
assert policy17.dist.dp_size == 7      # every survivor kept, no trim

# oracle: uninterrupted dp=7 run restored from the SAME step-4 checkpoint
# on the survivor mesh, fed the SAME tail-trimmed batches
mesh7 = survivor_mesh(mesh8, (KILL_RANK14,))
assert mesh7.shape["data"] == 7
dist7 = make_dist(mesh7, impl="paxi")
like7 = train_loop.init_state(api17, key14, dist7)
specs7 = train_loop.state_specs(api17, "abi")   # per-leaf DDP layout
state7, step7 = ck17.restore(like7, step=EVERY14, mesh=mesh7, specs=specs7)
assert step7 == EVERY14
jstep7 = jax.jit(train_loop.make_train_step(api17, dist7, opt14))
for s17 in range(EVERY14, TOTAL14):
    state7, _m17 = jstep7(state7, train_loop.rebalance_batch(
        batch_at17(s17), 7))
v17 = jax.tree.leaves(report17.final_state)
o17 = jax.tree.leaves(state7)
assert len(v17) == len(o17)
for a17, b17 in zip(v17, o17):
    np.testing.assert_array_equal(np.asarray(a17), np.asarray(b17))
shutil.rmtree(ckdir17, ignore_errors=True)
print(f"  paxi: death at step {KILL_AT14} -> dp=7 uneven resume "
      "bitwise == oracle OK")

# ---------------------------------------------------------------------------
section("18. transport integrity: corrupted zero1 collective + dropped "
        "decode-tp bcast (three dispatch paths)")
# The PR-10 acceptance scenario, both halves of the escalation funnel.
#
# Training half: one zero1 collective is corrupted mid-run at dp=8 with
# integrity mode ON.  The checksummed plan-group closure detects the
# disagreement in-trace and folds the canonical poison into the payload;
# ``verify_clean`` (the RetryPolicy's verify hook) raises
# PAX_ERR_DATA_CORRUPTION at materialization, the policy re-runs the step
# (corruption is one-shot, so the retry is clean) and the finished
# trajectory must be BITWISE identical to an unfailed oracle on the same
# backend.  The injection fires at trace time, so arming re-jits the step
# through a fresh callable (jax caches traces per function identity).
#
# Serving half: one decode-tp broadcast is dropped mid-decode at tp=4 —
# a real hang, surfaced only by the DecodeSync wait timeout.  The
# supervisor retries in place (``transport_retries``), the drop is sticky,
# and the exhausted retry escalates into the PR-9 walk: heartbeat confirm
# (a dropping link stops answering heartbeats) -> revoke -> shrink ->
# rebuild -> replay, streams bitwise equal to the unfailed oracle.
import time as _time

from repro.core.errors import (PAX_ERR_DATA_CORRUPTION, PAX_ERR_REQUEST,
                               PAX_ERR_TIMEOUT)
from repro.runtime.fault import RetryPolicy

for impl18 in ("paxi", "minimal", "ompix"):
    sched18 = FaultSchedule()
    dist18 = make_dist(mesh8, impl=make_faulty(impl18, mesh8, sched18),
                       integrity=True)
    assert dist18.abi.integrity
    state18 = train_loop.init_state(api14, key14, dist18)
    raw18 = train_loop.make_train_step(api14, dist18, opt14)

    def fresh18(_raw=raw18):
        # a fresh callable object per (re)arm: jax.jit caches traces per
        # function identity, so re-jitting the raw step directly would
        # never re-run the trace-time tripwire
        return jax.jit(lambda s, b, _r=_raw: _r(s, b))

    holder18 = {"f": fresh18()}

    def step18(s, b, _h=holder18):
        return _h["f"](s, b)

    armed18 = []

    def get_batch18(i, _h=holder18, _s=sched18, _a=armed18, _f=fresh18):
        if i == KILL_AT14 - 4 and not _a:   # step 2: mid-run, pre-checkpoint
            _a.append(i)
            _s.arm(3, after=0, mode="corrupt")
            _h["f"] = _f()                   # fresh trace sees the tripwire
        return batch_at14(i)

    retry18 = RetryPolicy(
        max_retries=2,
        reset=lambda _h=holder18, _f=fresh18: _h.__setitem__("f", _f()),
        verify=lambda out, _d=dist18: _d.abi.verify_clean(out, "train step"))
    ckdir18 = tempfile.mkdtemp(prefix="integrity_")
    report18 = run_supervised(
        step18, state18, get_batch18, checkpointer=Checkpointer(ckdir18),
        total_steps=4, checkpoint_every=2, max_restarts=1, retry=retry18)
    assert report18.steps_completed == 4, report18
    assert report18.restarts == 0, report18            # retried, not restarted
    assert report18.transport_retries == 1, report18
    assert report18.transport_escalations == 0, report18
    assert sched18.corrupted, impl18                   # the one-shot fired

    # oracle: unfailed run, SAME impl (plain backend), integrity still on
    disto18 = make_dist(mesh8, impl=impl18, integrity=True)
    stateo18 = train_loop.init_state(api14, key14, disto18)
    stepo18 = jax.jit(train_loop.make_train_step(api14, disto18, opt14))
    for s18 in range(4):
        stateo18, _m18 = stepo18(stateo18, batch_at14(s18))
    v18 = jax.tree.leaves(report18.final_state)
    o18 = jax.tree.leaves(stateo18)
    assert len(v18) == len(o18)
    for a18, b18 in zip(v18, o18):
        np.testing.assert_array_equal(np.asarray(a18), np.asarray(b18))
    shutil.rmtree(ckdir18, ignore_errors=True)
    print(f"  {impl18}: corrupt mid-zero1 -> detect -> retry, "
          "resume bitwise == oracle OK")

for impl18s in ("paxi", "minimal", "ompix"):
    sched18s = FaultSchedule()
    abi18s = C.pax_init(mesh, impl=make_faulty16(impl18s, mesh, sched18s))
    tp18s = abi18s.comm_from_axes(("model",), "tp")
    eng16.decode_sync = DecodeSync(abi18s, tp18s, 3, mesh)
    mon18s = HeartbeatMonitor(abi18s, tp18s, mesh, miss_threshold=2,
                              suspicion_ticks=1).install()
    sup18s = ServeSupervisor(eng16, monitor=mon18s, heartbeat_every=1,
                             wait_timeout_s=0.15, transport_retries=1)
    for r18s in mk_reqs16():
        eng16.submit(r18s)
    reqs18s = list(eng16.scheduler.waiting)
    while not all(s18s is not None and s18s.state == "decode"
                  for s18s in eng16.scheduler.slots):
        sup18s.step()
    mid18s = [len(r18s.out_tokens) for r18s in reqs18s]
    assert all(m18s > 0 for m18s in mid18s), mid18s   # genuinely mid-decode
    sched18s.arm(2, after=0, mode="drop")             # rank 2's link silent
    sup18s.drain()
    got18s = [r18s.out_tokens for r18s in reqs18s]
    assert got18s == want16, (impl18s, got18s, want16)
    assert sup18s.report.transport_retries == 1, sup18s.report
    assert sup18s.report.transport_escalations == 1, sup18s.report
    assert sup18s.report.failures == 1, sup18s.report
    assert abi18s.comms.info(eng16.decode_sync.comm).excludes == (2,)
    assert 2 in mon18s.confirmed         # observed via missed beats
    sup18s.report.assert_consistent()
    mon18s.uninstall()
    eng16.decode_sync.free()
    eng16.decode_sync = None
    print(f"  {impl18s}: dropped decode bcast -> timeout -> retry -> "
          "confirm -> shrink, replay bitwise == oracle OK")

# CI chaos-transport leg: with a corrupt/drop PAX_FAULT_SCHEDULE armed,
# the registry's faulty: prefix must surface the transport fault through
# the integrity/timeout contract and recover through the documented path
# (one-shot corrupt -> clean re-run; sticky drop -> reset + heal).
env18 = os.environ.get("PAX_FAULT_SCHEDULE")
se18 = None
if env18:
    abi18e = C.pax_init(mesh8, impl="faulty:paxi", integrity=True)
    se18 = fault_schedule_of(abi18e.backend)
    assert se18 is not None and se18.armed, env18
if se18 is not None and se18.mode in ("corrupt", "drop"):
    dpe18 = abi18e.comm_from_axes(("data",), "dp")
    xe18 = jnp.arange(32.0, dtype=jnp.float32) + 1.0
    plan18e = abi18e.allreduce_init(
        jax.ShapeDtypeStruct((32,), jnp.float32), C.PAX_SUM, dpe18)
    fe18 = shard_map(
        lambda v: abi18e.wait(plan18e.start(v), timeout_s=0.5),
        mesh=mesh8, in_specs=P(), out_specs=P())
    want18e = np.asarray(xe18) * 8.0
    for _ in range(se18.at_call):        # drive to just before the fault
        se18.on_call()
    if se18.mode == "corrupt":
        try:
            abi18e.verify_clean(fe18(xe18), "env chaos allreduce")
            raise AssertionError("env-armed corruption went undetected")
        except PaxError as e18x:
            assert e18x.code == PAX_ERR_DATA_CORRUPTION, e18x
        assert se18.corrupted             # one-shot: consumed by the hit
        np.testing.assert_array_equal(    # clean re-run, nothing wedged
            np.asarray(fe18(xe18)), want18e)
    else:                                 # drop: timeout -> reset -> heal
        t18e = _time.perf_counter()
        try:
            fe18(xe18)
            raise AssertionError("env-armed drop did not time out")
        except PaxError as e18x:
            assert e18x.code == PAX_ERR_TIMEOUT, e18x
        assert _time.perf_counter() - t18e >= 0.5
        plan18e.reset()                   # the post-timeout abort contract
        se18.dropping = False             # link heals; schedule disarmed
        se18.kill_rank = -1
        np.testing.assert_array_equal(np.asarray(fe18(xe18)), want18e)
    print(f"  env chaos schedule {env18!r}: transport fault surfaced and "
          "recovered OK")

print("BATTERY PASSED")
