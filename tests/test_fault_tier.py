"""Fault tier (ULFM analogue): revoke/ack/get_failed/agree/shrink semantics,
the fault-injection backend, and the supervised loop's recovery satellites.

Multi-rank end-to-end legs (kill a rank at dp=8, shrink, bitwise-identical
resumption at dp=4) live in tests/multidev_battery.py; here we unit-test
the host-level kernels on a synthetic 8-rank communicator table and the
ABI integration on the 1-device mesh.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.core as C
from repro.core import emulation as em
from repro.core.backends.faulty import (FaultSchedule, FaultyBackend,
                                        FaultyLib, fault_schedule_of)
from repro.core.communicator import CommTable
from repro.core.errors import (PAX_ERR_PROC_FAILED, PAX_ERR_REVOKED, PaxError)


class _FakeMesh:
    """Duck-typed 8x1 mesh: CommTable only reads axis_names and shape."""

    axis_names = ("data", "model")
    shape = {"data": 8, "model": 1}


def _table():
    t = CommTable(_FakeMesh())
    return t, C.PAX_COMM_WORLD


# ---------------------------------------------------------------------------
# shared kernels (one definition drives native paxi hooks AND recipes)
# ---------------------------------------------------------------------------
def test_masked_agree_fold_skips_dead_ranks():
    contribs = [0b111, 0b101, 0b110, 0b011]
    assert em.masked_agree_fold(contribs, [True] * 4) == 0b000
    # rank 3 dead: its 0b011 contribution must not participate
    assert em.masked_agree_fold(contribs, [True, True, True, False]) == 0b100
    # determinism: same inputs, same agreement value, every time
    for _ in range(3):
        assert em.masked_agree_fold(contribs, [True, False, True, True]) == 0b010


def test_masked_agree_fold_no_survivors_raises():
    with pytest.raises(PaxError) as ei:
        em.masked_agree_fold([1, 1], [False, False])
    assert ei.value.code == PAX_ERR_PROC_FAILED


def test_comm_failure_view_excluded_ranks_are_not_failures():
    t, world = _table()
    detector = lambda comm: (3, 5)
    info, failed, acked = em.comm_failure_view(t, detector, world)
    assert failed == frozenset({3, 5}) and acked == frozenset()
    # a shrunk comm excluding rank 5: the corpse is a non-member there
    child = t.register_shrunk(world, (5,))
    info_c, failed_c, _ = em.comm_failure_view(t, detector, child)
    assert info_c.excludes == (5,)
    assert failed_c == frozenset({3})


def test_agree_refuses_unacked_failures_then_succeeds():
    t, world = _table()
    detector = lambda comm: (2,)
    with pytest.raises(PaxError) as ei:
        em.agree_value(t, detector, 1, world)
    assert ei.value.code == PAX_ERR_PROC_FAILED
    # acknowledge, then agreement folds over the 7 survivors
    _, failed, acked = em.comm_failure_view(t, detector, world)
    t.acked[world] = acked | failed
    assert em.agree_value(t, detector, 1, world) == 1
    assert em.agree_value(t, detector, 0b1010, world) == 0b1010


# ---------------------------------------------------------------------------
# CommTable: revocation poisoning + shrink registration
# ---------------------------------------------------------------------------
def test_revoke_poisons_info_exactly():
    t, world = _table()
    dp = t.comm_from_axes(("data",), "dp")
    t.revoke(dp)
    assert t.is_revoked(dp)
    assert dp not in t.axes_by_handle  # hot path poisoned by construction
    with pytest.raises(PaxError) as ei:
        t.info(dp)
    assert ei.value.code == PAX_ERR_REVOKED
    # the fault tier's escape hatch still sees the metadata
    assert t.info(dp, allow_revoked=True).full_size == 8
    # other comms untouched
    assert t.info(world).full_size == 8


def test_register_shrunk_accumulates_excludes():
    t, world = _table()
    child = t.register_shrunk(world, (5,), "survivors")
    ci = t.info(child)
    assert ci.excludes == (5,) and ci.size == 7 and ci.full_size == 8
    grandchild = t.register_shrunk(child, (1,))
    cg = t.info(grandchild)
    assert cg.excludes == (1, 5) and cg.size == 6
    # shrinking twice on the same failures is idempotent in the excludes
    again = t.register_shrunk(child, (5, 1))
    assert t.info(again).excludes == (1, 5)


# ---------------------------------------------------------------------------
# ABI integration (1-device mesh): negotiation, revocation, plan reset
# ---------------------------------------------------------------------------
FAULT_ENTRIES = ("comm_revoke", "comm_failure_ack", "comm_get_failed",
                 "comm_agree", "comm_shrink")


def test_fault_tier_negotiation_sources(mesh1):
    caps_paxi = C.pax_init(mesh1, impl="paxi").capabilities()
    caps_min = C.pax_init(mesh1, impl="minimal").capabilities()
    caps_omp = C.pax_init(mesh1, impl="ompix").capabilities()
    for e in FAULT_ENTRIES:
        assert caps_paxi[e]["tier"] == "fault"
        assert caps_paxi[e]["source"] == "native"
        assert caps_min[e]["source"] == "emulated"   # recipe over the table
        assert caps_omp[e]["source"] == "emulated"   # ompix drops the symbols
    # no fault entry may be unavailable anywhere (negotiation contract)
    for caps in (caps_paxi, caps_min, caps_omp):
        assert not [n for n, i in caps.items() if i["source"] == "unavailable"]


@pytest.mark.parametrize("impl", ["paxi", "minimal", "ompix"])
def test_revoke_then_collective_raises_revoked_exactly(mesh1, impl):
    abi = C.pax_init(mesh1, impl=impl)
    world = C.PAX_COMM_WORLD
    abi.comm_revoke(world)
    f = abi.shard_region(lambda x: abi.allreduce(x, C.PAX_SUM, world),
                         in_specs=P(), out_specs=P())
    with pytest.raises(PaxError) as ei:
        jax.jit(f)(jnp.ones(4, jnp.float32))
    assert ei.value.code == PAX_ERR_REVOKED
    # fault-tier entries still operate on the revoked comm (ULFM contract)
    abi.comm_failure_ack(world)
    assert abi.comm_get_failed(world) == ()
    assert abi.comm_agree(1, world) == 1
    survivor = abi.comm_shrink(world)
    assert survivor != world
    assert abi.comm_size(survivor) == 1  # no failures: same group, new comm


def test_revoke_resets_plans_and_groups_on_that_comm(mesh1):
    abi = C.pax_init(mesh1, impl="paxi")
    world = C.PAX_COMM_WORLD
    dp = abi.comm_from_axes(("data",), "dp")
    x = jnp.zeros(4, jnp.float32)
    p_world = abi.allreduce_init(x, C.PAX_SUM, world)
    p_dp = abi.allreduce_init(x, C.PAX_SUM, dp)
    group = abi.plan_group([p_world], "g")
    assert p_world.request is not None and p_dp.request is not None
    # simulate mid-trace active plans (start without wait)
    for obj in (p_world, p_dp, group):
        obj.request.done = False
    abi.comm_revoke(world)
    assert p_world.request.done      # plan on the revoked comm: reset
    assert group.request.done        # group with a member on it: reset
    assert not p_dp.request.done     # other comms untouched
    p_dp.reset()


# ---------------------------------------------------------------------------
# fault injection: schedule, tripwire, registry composition
# ---------------------------------------------------------------------------
def test_fault_schedule_from_env_parses(monkeypatch):
    monkeypatch.setenv("PAX_FAULT_SCHEDULE", "rank=5,at=12")
    s = FaultSchedule.from_env()
    assert (s.kill_rank, s.at_call) == (5, 12) and s.armed and not s.dead
    assert FaultSchedule.from_env("").armed is False
    with pytest.raises(ValueError):
        FaultSchedule.from_env("bogus=1")


def test_fault_schedule_counting():
    s = FaultSchedule()
    s.arm(0, after=2)
    assert not s.on_call() and not s.on_call()  # calls 1, 2
    assert s.on_call() and s.dead               # call 3 crosses at_call=2


def test_faulty_backend_tripwire_and_revoked_precedence(mesh1):
    sched = FaultSchedule()
    backend = FaultyBackend(C.get_backend("paxi", mesh1), sched)
    abi = C.pax_init(mesh1, impl=backend)
    world = C.PAX_COMM_WORLD
    caps = abi.capabilities()
    assert caps["allreduce"]["fault_injection"] is True
    for e in FAULT_ENTRIES:  # rebound native hooks stay native
        assert caps[e]["source"] == "native"

    def run():
        return jax.jit(abi.shard_region(
            lambda x: abi.allreduce(x, C.PAX_SUM, world),
            in_specs=P(), out_specs=P()))(jnp.ones(4, jnp.float32))

    run()  # pre-fault: clean
    sched.arm(0, after=0)
    with pytest.raises(PaxError) as ei:
        run()
    assert ei.value.code == PAX_ERR_PROC_FAILED
    # detector reports the corpse; ULFM walk completes on the dead world
    assert abi.comm_get_failed(world) == (0,)
    abi.comm_revoke(world)
    with pytest.raises(PaxError) as ei:  # REVOKED outranks PROC_FAILED
        run()
    assert ei.value.code == PAX_ERR_REVOKED


def test_registry_faulty_prefix_and_instance_init(mesh1):
    b = C.get_backend("faulty:minimal", mesh1)
    assert b.name == "faulty:minimal"
    assert fault_schedule_of(b) is b.schedule
    abi = C.pax_init(mesh1, impl=b)
    assert abi.backend is b
    # the sweep of plain backends never meets the injection wrapper
    assert not any(n.startswith("faulty") for n in C.available_backends())


# ---------------------------------------------------------------------------
# heartbeat transport attribution: silence is not declaration
# ---------------------------------------------------------------------------
def test_heartbeat_silent_is_transport_not_declaration(mesh1):
    # plain backends: nobody is ever transport-silent
    plain = C.get_backend("paxi", mesh1)
    assert plain.heartbeat_silent(C.PAX_COMM_WORLD) == ()

    sched = FaultSchedule()
    backend = FaultyBackend(C.get_backend("paxi", mesh1), sched,
                            declare_failures=False)
    abi = C.pax_init(mesh1, impl=backend)
    world = C.PAX_COMM_WORLD
    assert backend.heartbeat_silent(world) == ()  # alive: answering
    sched.arm(0, after=0)
    sched.on_call()
    # the silent killer: the wire goes quiet but nothing is *declared* —
    # only an installed liveness monitor can name this corpse
    assert backend.local_failed(world) == ()
    assert backend.heartbeat_silent(world) == (0,)
    # attribution survives revocation: the monitor reads the corpse
    # mid-recovery-walk, after the comm is already poisoned
    abi.comm_revoke(world)
    assert backend.heartbeat_silent(world) == (0,)


def test_heartbeat_silent_respects_membership(mesh1):
    sched = FaultSchedule()
    backend = FaultyBackend(C.get_backend("paxi", mesh1), sched)
    sched.arm(5, after=0)  # rank 5 does not exist on the 1-rank world
    sched.on_call()
    assert sched.dead
    assert backend.local_failed(C.PAX_COMM_WORLD) == ()
    assert backend.heartbeat_silent(C.PAX_COMM_WORLD) == ()


def test_heartbeat_silent_crosses_mukautuva(mesh1):
    from repro.core.backends.ompix import OmpixLib
    from repro.core.mukautuva import MukBackend

    # a foreign lib without the symbol: delegation degrades to "no idea"
    bare = MukBackend(OmpixLib(mesh1), mesh1)
    assert bare.heartbeat_silent(C.PAX_COMM_WORLD) == ()

    sched = FaultSchedule()
    mb = MukBackend(FaultyLib(OmpixLib(mesh1), sched,
                              declare_failures=False), mesh1)
    C.pax_init(mesh1, impl=mb)
    world = C.PAX_COMM_WORLD
    assert mb.heartbeat_silent(world) == ()
    sched.arm(0, after=0)
    sched.on_call()
    assert mb.local_failed(world) == ()      # undeclared…
    assert mb.heartbeat_silent(world) == (0,)  # …but silent on the wire


def test_monitor_tripwire_race_revoked_outranks_proc_failed(mesh1):
    """PR-9 regression: with a liveness monitor installed on a silent-killer
    backend, REVOKED must still outrank PROC_FAILED on the hot path even
    while both the tripwire schedule and the monitor name the corpse."""
    from repro.runtime.liveness import HeartbeatMonitor

    sched = FaultSchedule()
    backend = FaultyBackend(C.get_backend("paxi", mesh1), sched,
                            declare_failures=False)
    abi = C.pax_init(mesh1, impl=backend)
    world = C.PAX_COMM_WORLD
    mon = HeartbeatMonitor(abi, world, mesh1, miss_threshold=2,
                           suspicion_ticks=1).install()
    try:
        assert abi.comm_get_failed(world) == ()
        sched.arm(0, after=0)
        sched.on_call()        # dead — and heartbeat-silent
        mon.beat()             # one missed beat: below the miss threshold
        assert abi.comm_get_failed(world) == ()
        mon.beat()             # miss_threshold + suspicion_ticks - 1 = 2
        assert 0 in mon.confirmed
        assert abi.comm_get_failed(world) == (0,)

        def run():
            return jax.jit(abi.shard_region(
                lambda x: abi.allreduce(x, C.PAX_SUM, world),
                in_specs=P(), out_specs=P()))(jnp.ones(4, jnp.float32))

        with pytest.raises(PaxError) as ei:  # tripwire fires first
            run()
        assert ei.value.code == PAX_ERR_PROC_FAILED
        abi.comm_revoke(world)
        # the race: schedule dead AND monitor confirmed AND comm revoked —
        # the hot path must report the poisoning, not the death
        with pytest.raises(PaxError) as ei:
            run()
        assert ei.value.code == PAX_ERR_REVOKED
        # the monitor's dup comm is its own handle: beats keep flowing and
        # the detector view of the revoked comm stays attributable
        mon.beat()
        assert mon.failed(world) == (0,)
    finally:
        mon.uninstall()


# ---------------------------------------------------------------------------
# supervised-loop satellites: loss realignment, on_straggler restarts
# ---------------------------------------------------------------------------
class _Loss:
    def __init__(self, v):
        self.loss = v


def _acc_step(fail_at, attempts):
    def step_fn(state, batch):
        step = int(state["step"])
        if step in fail_at and attempts[step] == 0:
            attempts[step] += 1
            raise RuntimeError(f"injected at {step}")
        new = {"step": state["step"] + 1, "acc": state["acc"] + batch["x"]}
        return new, _Loss(float(new["acc"]))
    return step_fn


def test_losses_realigned_after_replay(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.runtime.fault import run_supervised

    attempts = {7: 0, 12: 0}
    report = run_supervised(
        _acc_step({7, 12}, attempts),
        {"step": jnp.int32(0), "acc": jnp.float32(0.0)},
        lambda i: {"x": float(i)},
        checkpointer=Checkpointer(tmp_path, keep=3),
        total_steps=20, checkpoint_every=5, max_restarts=5)
    assert report.steps_completed == 20 and report.restarts == 2
    # exactly one loss per step — replayed steps overwrite, never duplicate
    assert len(report.losses) == 20
    expect = np.cumsum([float(i) for i in range(20)])
    np.testing.assert_allclose(report.losses, expect)


def test_on_straggler_restart_path(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.runtime.fault import StepWatchdog, run_supervised

    class Forced(StepWatchdog):
        """Deterministic straggler flag (wall-clock-free)."""

        def __init__(self, at, decision):
            super().__init__(on_straggler=lambda s, dt: decision)
            self.at = at

        def observe(self, step, dt):
            if step == self.at and not self.stragglers:
                self.stragglers.append((step, dt))
                return True
            return False

    for decision, want_restarts in (("restart", 1), ("continue", 0)):
        wd = Forced(at=6, decision=decision)
        report = run_supervised(
            _acc_step(set(), {}),
            {"step": jnp.int32(0), "acc": jnp.float32(0.0)},
            lambda i: {"x": float(i)},
            checkpointer=Checkpointer(tmp_path / decision, keep=3),
            total_steps=12, checkpoint_every=4, max_restarts=3,
            watchdog=wd)
        assert report.restarts == want_restarts, decision
        assert report.stragglers == 1
        assert report.steps_completed == 12
        assert len(report.losses) == 12  # proactive restart replays nothing
        assert float(report.final_state["acc"]) == sum(range(12))


def test_on_straggler_rejects_bad_decision():
    from repro.runtime.fault import StepWatchdog

    wd = StepWatchdog(on_straggler=lambda s, dt: "panic")
    with pytest.raises(ValueError):
        wd.on_straggler(3, 1.0)
    assert StepWatchdog().on_straggler(3, 1.0) == "continue"


def test_supervisor_report_invariant():
    from repro.runtime.fault import SupervisorReport

    SupervisorReport(20, 0, 0, None, [])          # no-metrics runs stay legal
    SupervisorReport(20, 0, 0, None, [0.0] * 20)
    SupervisorReport(25, 0, 0, None, [0.0] * 5, resumed_from=20)
    with pytest.raises(AssertionError):
        SupervisorReport(20, 0, 0, None, [0.0] * 21)
