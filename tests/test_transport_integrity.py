"""Transport-integrity tier (PR 10) unit tests.

Covers the single-process-provable pieces of the corruption/drop/delay
story: the extended ``FaultSchedule`` grammar and per-mode ``fault_now``
semantics, the in-trace checksum envelope (hoisting: zero added trace ops
when disabled; detection: the conserved rule on the 1-device mesh),
``wait`` timeout exactness and the post-timeout ``reset`` contract on both
the plan and pooled paths, ``RetryPolicy`` retry/escalation ordering, and
checkpoint content integrity (bit-flip and truncation fall back to the
previous retained checkpoint, loudly).

The multi-rank ends — a corrupted dp allreduce detected mid-zero1 and a
dropped decode-tp broadcast timed out, confirmed, shrunk and replayed —
are battery §18 (tests/multidev_battery.py): the replicated agreement rule
needs ≥ 2 members to disagree, so it is only provable there.
"""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.core as C
from repro.checkpoint.checkpointer import CheckpointCorrupt, Checkpointer
from repro.core.backends.faulty import (FaultSchedule, FaultyBackend,
                                        fault_schedule_of)
from repro.core.compat import shard_map
from repro.core.errors import (PAX_ERR_DATA_CORRUPTION, PAX_ERR_PROC_FAILED,
                               PAX_ERR_REQUEST, PAX_ERR_TIMEOUT,
                               IncompleteValue, PaxError, error_string)
from repro.core.registry import get_backend
from repro.runtime.fault import (TRANSPORT_ERRORS, RetryPolicy,
                                 escalate_to_failure)


def _faulty_ctx(mesh1, integrity=None):
    sched = FaultSchedule()
    backend = FaultyBackend(get_backend("paxi", mesh1), sched)
    abi = C.pax_init(mesh1, impl=backend, integrity=integrity)
    return sched, abi


# ---------------------------------------------------------------------------
# schedule grammar and per-mode semantics
# ---------------------------------------------------------------------------
def test_schedule_env_grammar_modes_and_delay():
    old = FaultSchedule.from_env("rank=2,at=5")
    assert (old.kill_rank, old.at_call, old.mode) == (2, 5, "die")
    drop = FaultSchedule.from_env("rank=1,at=0,mode=drop")
    assert drop.mode == "drop" and drop.armed
    slow = FaultSchedule.from_env("rank=0,at=3,mode=delay,delay=0.25")
    assert slow.mode == "delay" and slow.delay_s == 0.25
    assert not FaultSchedule.from_env("").armed


def test_schedule_env_grammar_rejects_bad_fields():
    with pytest.raises(ValueError):
        FaultSchedule.from_env("rank=1,at=0,mode=bogus")
    with pytest.raises(ValueError):
        FaultSchedule.from_env("rank=1,frob=2")
    with pytest.raises(ValueError):
        FaultSchedule().arm(0, mode="bogus")


def test_fault_now_die_is_sticky():
    s = FaultSchedule()
    s.arm(0, after=1, mode="die")
    assert s.fault_now() is None          # call 1 == at_call: not yet
    assert s.fault_now() == "die"
    assert s.fault_now() == "die" and s.dead


def test_fault_now_corrupt_is_one_shot():
    s = FaultSchedule()
    s.arm(0, after=0, mode="corrupt")
    assert s.fault_now() == "corrupt"
    s.corrupted = True                    # the injector marks it spent
    assert s.fault_now() is None and not s.dead


def test_fault_now_drop_is_sticky_delay_repeats():
    s = FaultSchedule()
    s.arm(0, after=0, mode="drop")
    assert s.fault_now() == "drop" and s.dropping
    assert s.fault_now() == "drop"
    d = FaultSchedule()
    d.arm(0, after=0, mode="delay")
    assert d.fault_now() == "delay"
    assert d.fault_now() == "delay" and not d.dead


def test_error_strings_for_transport_codes():
    assert error_string(PAX_ERR_DATA_CORRUPTION) == "PAX_ERR_DATA_CORRUPTION"
    assert error_string(PAX_ERR_TIMEOUT) == "PAX_ERR_TIMEOUT"
    assert TRANSPORT_ERRORS == (PAX_ERR_DATA_CORRUPTION, PAX_ERR_TIMEOUT)


# ---------------------------------------------------------------------------
# checksum envelope: hoisting and detection on the 1-device mesh
# ---------------------------------------------------------------------------
def _plan_trace(mesh1, abi, plan):
    return jax.make_jaxpr(
        shard_map(lambda v: abi.wait(plan.start(v)), mesh=mesh1,
                  in_specs=P(), out_specs=P()))


def test_integrity_off_adds_zero_trace_ops(mesh1):
    """Hoisting contract: the envelope is decided at plan compile, so an
    integrity-off plan traces to the IDENTICAL jaxpr as one from a context
    that never heard of the flag — and the on-side trace carries the fused
    checksum."""
    x = jnp.arange(8, dtype=jnp.float32)
    ex = jax.ShapeDtypeStruct((8,), jnp.float32)
    jaxprs = {}
    for name, integrity in (("naive", None), ("off", False), ("on", True)):
        abi = C.pax_init(mesh1, impl="paxi", integrity=integrity)
        comm = abi.comm_from_axes(("data",), "dp")
        plan = abi.allreduce_init(ex, C.PAX_SUM, comm)
        jaxprs[name] = str(_plan_trace(mesh1, abi, plan)(x))
    assert jaxprs["off"] == jaxprs["naive"]
    assert len(jaxprs["on"]) > len(jaxprs["off"])


def test_drop_guard_compiled_only_for_loss_capable_backends(mesh1):
    """Host-side hoisting twin of the trace-time contract: only a backend
    that can inject drops (``can_lose_messages``) gets the sentinel guard
    in its plan/group wait closures — a plain backend's wait is the bare
    two-field flip, so the transport tier costs it nothing per call.  The
    guarded closure binds ``IncompleteValue`` as a default (a LOAD_FAST,
    not a global lookup), which is also how this test detects it."""
    from repro.core.errors import IncompleteValue as IV
    ex = jax.ShapeDtypeStruct((4,), jnp.float32)

    plain = C.pax_init(mesh1, impl="paxi")
    assert not plain._can_drop
    p = plain.allreduce_init(ex, C.PAX_SUM, C.PAX_COMM_SELF)
    assert not any(d is IV for d in (p.wait.__defaults__ or ()))

    sched, faulty = _faulty_ctx(mesh1)
    assert faulty._can_drop
    f = faulty.allreduce_init(ex, C.PAX_SUM, C.PAX_COMM_SELF)
    assert any(d is IV for d in (f.wait.__defaults__ or ()))

    # the group wait mirrors the same decision (scan bound vs absent)
    gp = plain.plan_group([plain.allreduce_init(ex, C.PAX_SUM,
                                                C.PAX_COMM_SELF)])
    gf = faulty.plan_group([faulty.allreduce_init(ex, C.PAX_SUM,
                                                  C.PAX_COMM_SELF)])
    assert len(gp.wait.__defaults__) < len(gf.wait.__defaults__)


def test_conserved_rule_detects_corruption_and_retry_is_clean(mesh1):
    """The reduce_scatter conservation rule is provable at world size 1:
    sum(out) must equal sum(in); a sign-flipped member breaks it, the
    output comes back poisoned, and ``verify_clean`` raises
    ``PAX_ERR_DATA_CORRUPTION`` at materialization.  The corruption is
    one-shot, so the bare retry is bitwise what the unfailed run was."""
    sched, abi = _faulty_ctx(mesh1, integrity=True)
    comm = abi.comm_from_axes(("data",), "dp")
    ex = jax.ShapeDtypeStruct((8,), jnp.float32)
    plan = abi.reduce_scatter_init(ex, C.PAX_SUM, comm)
    f = shard_map(lambda v: abi.wait(plan.start(v)), mesh=mesh1,
                  in_specs=P(), out_specs=P())
    x = jnp.arange(8, dtype=jnp.float32) + 1.0

    clean = np.asarray(f(x))
    abi.verify_clean(clean, "clean reduce_scatter")

    sched.arm(0, after=0, mode="corrupt")
    bad = np.asarray(f(x))
    with pytest.raises(PaxError) as ei:
        abi.verify_clean(bad, "corrupted reduce_scatter")
    assert ei.value.code == PAX_ERR_DATA_CORRUPTION
    assert sched.corrupted                    # spent: one-shot

    again = np.asarray(f(x))
    abi.verify_clean(again, "retried reduce_scatter")
    np.testing.assert_array_equal(again, clean)


def test_integrity_off_lets_corruption_through(mesh1):
    """The contract of the default mode: no checksums, no detection —
    ``verify_clean`` is a no-op and the corrupted value flows through
    (what every pre-PR-10 context did)."""
    sched, abi = _faulty_ctx(mesh1, integrity=False)
    comm = abi.comm_from_axes(("data",), "dp")
    ex = jax.ShapeDtypeStruct((8,), jnp.float32)
    plan = abi.reduce_scatter_init(ex, C.PAX_SUM, comm)
    f = shard_map(lambda v: abi.wait(plan.start(v)), mesh=mesh1,
                  in_specs=P(), out_specs=P())
    x = jnp.arange(8, dtype=jnp.float32) + 1.0
    sched.arm(0, after=0, mode="corrupt")
    silent = np.asarray(f(x))
    abi.verify_clean(silent, "off")           # no-op by contract
    np.testing.assert_array_equal(silent, -np.asarray(x))  # sign-flipped


# ---------------------------------------------------------------------------
# drop -> wait timeout -> reset (plan, group member, pooled)
# ---------------------------------------------------------------------------
def test_plan_wait_timeout_exactness_and_reset(mesh1):
    sched, abi = _faulty_ctx(mesh1)
    comm = abi.comm_from_axes(("data",), "dp")  # drops target axes comms
    x = jnp.ones((4,), jnp.float32)
    plan = abi.allreduce_init(jax.ShapeDtypeStruct((4,), jnp.float32),
                              C.PAX_SUM, comm)
    f = shard_map(lambda v: abi.wait(plan.start(v), timeout_s=0.15),
                  mesh=mesh1, in_specs=P(), out_specs=P())
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))  # clean

    sched.arm(0, after=0, mode="drop")
    t0 = time.perf_counter()
    with pytest.raises(PaxError) as ei:
        f(x)
    dt = time.perf_counter() - t0
    assert ei.value.code == PAX_ERR_TIMEOUT
    assert 0.15 <= dt < 1.5                   # deadline honored, not a hang

    # the request stays ACTIVE across the raise: a restart is refused
    # (PAX_ERR_REQUEST), a re-wait times out again — reset is the only out
    with pytest.raises(PaxError) as ei2:
        f(x)
    assert ei2.value.code == PAX_ERR_REQUEST
    with pytest.raises(PaxError) as ei3:
        plan.wait(timeout_s=0.01)
    assert ei3.value.code == PAX_ERR_TIMEOUT

    plan.reset()
    sched.kill_rank = -1                      # link healed (test-only)
    sched.dropping = False
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))


def test_pooled_wait_and_waitall_timeout(mesh1):
    sched, abi = _faulty_ctx(mesh1)
    comm = abi.comm_from_axes(("data",), "dp")
    x = jnp.ones((4,), jnp.float32)

    f = shard_map(
        lambda v: abi.wait(abi.iallreduce(v, C.PAX_SUM, comm),
                           timeout_s=0.02),
        mesh=mesh1, in_specs=P(), out_specs=P())
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))  # clean

    sched.arm(0, after=0, mode="drop")
    with pytest.raises(PaxError) as ei:
        f(x)
    assert ei.value.code == PAX_ERR_TIMEOUT

    g = shard_map(
        lambda v: abi.waitall([abi.iallreduce(v, C.PAX_SUM, comm)],
                              timeout_s=0.02),
        mesh=mesh1, in_specs=P(), out_specs=P())
    with pytest.raises(PaxError) as ei2:
        g(x)
    assert ei2.value.code == PAX_ERR_TIMEOUT


def test_incomplete_value_sentinel_identity():
    iv = IncompleteValue("dropped allreduce")
    assert iv.__class__ is IncompleteValue
    assert "dropped allreduce" in repr(iv)


# ---------------------------------------------------------------------------
# RetryPolicy: ordering, exhaustion, escalation
# ---------------------------------------------------------------------------
def test_retry_policy_reset_before_rerun_then_verify():
    events = []
    n = {"calls": 0}

    def attempt():
        n["calls"] += 1
        events.append(f"attempt{n['calls']}")
        if n["calls"] == 1:
            raise PaxError(PAX_ERR_TIMEOUT, "transient drop")
        return "ok"

    pol = RetryPolicy(max_retries=2,
                      reset=lambda: events.append("reset"),
                      verify=lambda out: events.append("verify"))
    assert pol.run(attempt, what="unit") == "ok"
    assert events == ["attempt1", "reset", "attempt2", "verify"]
    assert pol.retries == 1 and pol.escalations == 0


def test_retry_policy_verify_failure_is_retried():
    n = {"calls": 0}

    def attempt():
        n["calls"] += 1
        return n["calls"]

    def verify(out):
        if out == 1:  # first result is poisoned
            raise PaxError(PAX_ERR_DATA_CORRUPTION, "poisoned payload")

    pol = RetryPolicy(max_retries=2, verify=verify)
    assert pol.run(attempt) == 2
    assert pol.retries == 1


def test_retry_policy_exhaustion_escalates_then_raises():
    events, escalated = [], []

    def attempt():
        events.append("attempt")
        raise PaxError(PAX_ERR_DATA_CORRUPTION, "persistently bad wire")

    pol = RetryPolicy(max_retries=2,
                      reset=lambda: events.append("reset"),
                      escalate=escalated.append)
    with pytest.raises(PaxError) as ei:
        pol.run(attempt, what="unit")
    assert ei.value.code == PAX_ERR_DATA_CORRUPTION
    # attempt -> reset, three times (initial + 2 retries), then escalate
    assert events == ["attempt", "reset"] * 3
    assert escalated == [ei.value]
    assert pol.retries == 2 and pol.escalations == 1


def test_retry_policy_rank_death_is_not_a_flaky_link():
    def attempt():
        raise PaxError(PAX_ERR_PROC_FAILED, "a corpse, not a drop")

    pol = RetryPolicy(reset=lambda: pytest.fail("reset on non-retryable"))
    with pytest.raises(PaxError) as ei:
        pol.run(attempt)
    assert ei.value.code == PAX_ERR_PROC_FAILED
    assert pol.retries == 0 and pol.escalations == 0


class _Monitor:
    """Confirms rank 3 silent after ``confirm_after`` beats."""

    def __init__(self, confirm_after):
        self.ticks, self.confirm_after = 0, confirm_after

    def beat(self):
        self.ticks += 1
        return (3,) if self.ticks >= self.confirm_after else ()


def test_escalate_to_failure_confirms_then_raises_proc_failed():
    cause = PaxError(PAX_ERR_TIMEOUT, "dropped bcast")
    esc = escalate_to_failure(_Monitor(confirm_after=3))
    with pytest.raises(PaxError) as ei:
        esc(cause)
    assert ei.value.code == PAX_ERR_PROC_FAILED
    assert ei.value.__cause__ is cause
    assert "3" in str(ei.value)


def test_escalate_to_failure_unconfirmed_returns():
    esc = escalate_to_failure(_Monitor(confirm_after=10 ** 9), max_ticks=4)
    assert esc(PaxError(PAX_ERR_TIMEOUT, "x")) is None


# ---------------------------------------------------------------------------
# checkpoint content integrity
# ---------------------------------------------------------------------------
def _state(v):
    return {"w": jnp.full((4,), v, jnp.float32),
            "step": jnp.asarray(v, jnp.int32)}


def _shard(ckdir, step):
    return ckdir / f"step_{step:010d}" / "shard_0.npz"


def test_checkpoint_bitflip_falls_back_loudly(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    for s in (2, 4, 6):
        ck.save(s, _state(float(s)))

    blob = bytearray(_shard(tmp_path, 6).read_bytes())
    blob[len(blob) // 2] ^= 0x40              # one flipped bit mid-shard
    _shard(tmp_path, 6).write_bytes(bytes(blob))

    restored, step = ck.restore(_state(0.0))
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full((4,), 4.0, np.float32))
    [event] = ck.integrity_events
    assert event["step"] == 6 and event["fell_back_to"] == 4
    assert "CRC mismatch" in event["reason"]


def test_checkpoint_truncation_falls_back(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    for s in (1, 3):
        ck.save(s, _state(float(s)))
    blob = _shard(tmp_path, 3).read_bytes()
    _shard(tmp_path, 3).write_bytes(blob[: len(blob) // 2])  # torn write

    restored, step = ck.restore(_state(0.0))
    assert step == 1
    assert ck.integrity_events[0]["step"] == 3
    assert ck.integrity_events[0]["fell_back_to"] == 1


def test_checkpoint_all_corrupt_raises_never_restores_garbage(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    for s in (1, 2):
        ck.save(s, _state(float(s)))
        blob = bytearray(_shard(tmp_path, s).read_bytes())
        blob[4] ^= 0xFF
        _shard(tmp_path, s).write_bytes(bytes(blob))
    with pytest.raises(CheckpointCorrupt):
        ck.restore(_state(0.0))
    assert [e["step"] for e in ck.integrity_events] == [2, 1]
    assert all(e["fell_back_to"] is None for e in ck.integrity_events)


def test_checkpoint_missing_shard_is_a_reason(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    for s in (1, 2):
        ck.save(s, _state(float(s)))
    _shard(tmp_path, 2).unlink()
    restored, step = ck.restore(_state(0.0))
    assert step == 1
    assert "missing shard" in ck.integrity_events[0]["reason"]


# ---------------------------------------------------------------------------
# injection composes under Mukautuva (schedule shared through wrappers)
# ---------------------------------------------------------------------------
def test_fault_schedule_of_surfaces_shared_schedule(mesh1):
    sched, abi = _faulty_ctx(mesh1)
    assert fault_schedule_of(abi.backend) is sched
