"""Multi-device collective semantics + the HLO-identity (zero-overhead)
claim, via the subprocess battery (8 fake CPU devices, isolated from this
process's single-device view)."""
import os
import subprocess
import sys

import pytest

BATTERY = os.path.join(os.path.dirname(__file__), "multidev_battery.py")


def test_multidev_battery():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # battery sets its own
    proc = subprocess.run(
        [sys.executable, BATTERY],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"battery failed (rc={proc.returncode})\n--- stdout\n{proc.stdout}"
            f"\n--- stderr\n{proc.stderr[-4000:]}"
        )
    assert "BATTERY PASSED" in proc.stdout
