"""Per-kernel validation: shape/dtype sweeps, interpret=True vs ref.py
oracles, plus cross-checks against the model-layer implementations."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6_scan.ops import wkv6_apply
from repro.kernels.rwkv6_scan.ref import wkv6_ref
from repro.kernels.mamba2_ssd.ops import ssd_apply
from repro.kernels.mamba2_ssd.ref import ssd_ref

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FA_SWEEP = [
    # B, S, H, Hkv, D, bq, bk, causal, dtype, tol
    (2, 256, 4, 2, 64, 128, 128, True, jnp.float32, 2e-5),
    (1, 128, 2, 2, 32, 64, 64, False, jnp.float32, 2e-5),
    (2, 256, 8, 2, 64, 128, 64, True, jnp.float32, 2e-5),
    (1, 256, 4, 1, 128, 64, 128, True, jnp.float32, 2e-5),  # MQA
    (2, 192, 4, 4, 64, 64, 64, True, jnp.float32, 2e-5),    # S%128 != 0
    (2, 256, 4, 2, 64, 128, 128, True, jnp.bfloat16, 2e-2),
]


@pytest.mark.parametrize("B,S,H,Hkv,D,bq,bk,causal,dtype,tol", FA_SWEEP)
def test_flash_attention_sweep(B, S, H, Hkv, D, bq, bk, causal, dtype, tol):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = flash_mha(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    ref = attention_ref(qf, kf, vf, causal=causal).reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_matches_model_attention():
    """Kernel semantics == the model's XLA attention path."""
    from repro.models.attention import _sdpa

    B, S, H, Hkv, D = 2, 128, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    model_out = _sdpa(q, k, v, causal=True)
    kern_out = flash_mha(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(kern_out), np.asarray(model_out),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------
WKV_SWEEP = [
    # B, T, H, N, chunk
    (2, 64, 3, 8, 16),
    (1, 128, 2, 16, 32),
    (2, 96, 1, 32, 32),
    (1, 64, 4, 64, 16),
]


@pytest.mark.parametrize("B,T,H,N,chunk", WKV_SWEEP)
def test_wkv6_sweep(B, T, H, N, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, N))
    k = jax.random.normal(ks[1], (B, T, H, N))
    v = jax.random.normal(ks[2], (B, T, H, N))
    wlog = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, T, H, N)) * 0.5), -5, -1e-4)
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    out = wkv6_apply(r, k, v, wlog, u, chunk=chunk, interpret=True)
    rf = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, T, N)
    uf = jnp.tile(u[None], (B, 1, 1)).reshape(B * H, N)
    ref = wkv6_ref(rf(r), rf(k), rf(v), rf(wlog), uf)
    ref = ref.reshape(B, H, T, N).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4, rtol=5e-4)


def test_wkv6_matches_model_chunked():
    """Kernel == the model's chunked jnp implementation."""
    from repro.models.rwkv import wkv6_chunked

    B, T, H, N, chunk = 2, 64, 2, 16, 16
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, N))
    k = jax.random.normal(ks[1], (B, T, H, N))
    v = jax.random.normal(ks[2], (B, T, H, N))
    wlog = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, T, H, N)) * 0.5), -5, -1e-4)
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    kern = wkv6_apply(r, k, v, wlog, u, chunk=chunk, interpret=True)
    model, _ = wkv6_chunked(r, k, v, wlog, u, jnp.zeros((B, H, N, N)), chunk)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(model), atol=3e-4, rtol=3e-4)


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------
SSD_SWEEP = [
    # B, T, H, P, N, chunk
    (2, 64, 3, 4, 8, 16),
    (1, 128, 2, 16, 16, 32),
    (2, 128, 1, 32, 64, 64),
    (1, 64, 4, 64, 16, 16),
]


@pytest.mark.parametrize("B,T,H,P,N,chunk", SSD_SWEEP)
def test_ssd_sweep(B, T, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jnp.linspace(0.0, 1.0, H))
    Bc = jax.random.normal(ks[2], (B, T, N))
    Cc = jax.random.normal(ks[3], (B, T, N))
    D = jnp.ones((H,)) * 0.5
    out = ssd_apply(x, dt, A, Bc, Cc, D, chunk=chunk, interpret=True)
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, T, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, T)
    bf = jnp.broadcast_to(Bc[:, None], (B, H, T, N)).reshape(B * H, T, N)
    cf = jnp.broadcast_to(Cc[:, None], (B, H, T, N)).reshape(B * H, T, N)
    af = jnp.tile(A[None], (B, 1)).reshape(-1)
    df = jnp.tile(D[None], (B, 1)).reshape(-1)
    ref = ssd_ref(xf, dtf, bf, cf, af, df).reshape(B, H, T, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4, rtol=5e-4)


def test_ssd_matches_model_chunked():
    from repro.models.mamba import ssd_chunked

    B, T, H, P, N, chunk = 2, 64, 2, 8, 16, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jnp.linspace(0.0, 1.0, H))
    Bc = jax.random.normal(ks[2], (B, T, N))
    Cc = jax.random.normal(ks[3], (B, T, N))
    D = jnp.ones((H,)) * 0.5
    kern = ssd_apply(x, dt, A, Bc, Cc, D, chunk=chunk, interpret=True)
    model, _ = ssd_chunked(x, dt, A, Bc, Cc, D, jnp.zeros((B, H, P, N)), chunk)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(model), atol=3e-4, rtol=3e-4)


# ---------------------------------------------------------------------------
# blockwise-causal XLA attention (the §Perf optimization) vs naive path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,block", [(256, 64), (512, 128), (384, 128)])
def test_blockwise_sdpa_matches_naive(S, block):
    from repro.models.attention import _sdpa, _sdpa_blockwise

    B, H, Hkv, D = 2, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    ref = _sdpa(q, k, v, causal=True)
    out = _sdpa_blockwise(q, k, v, block_q=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)
