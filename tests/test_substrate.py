"""Substrate tests: data pipeline, optimizer, checkpointing, fault
tolerance (simulated failures), serving engine, end-to-end mini-training."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as cfgs
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataPipeline, SyntheticSource, pack_documents
from repro.models import build_model, make_batch
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.runtime.dist import make_dist
from repro.runtime.fault import StepWatchdog, run_supervised
from repro.serve.engine import ServeEngine
from repro.train import train_loop


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_shapes_and_targets():
    src = SyntheticSource(vocab_size=100, seed=1)
    pipe = DataPipeline(src, global_batch=4, seq_len=32)
    b = next(pipe)
    assert b["tokens"].shape == (4, 32)
    assert b["targets"].shape == (4, 32)
    # next-token alignment where not padded
    live = b["targets"] != -1
    assert live.any()
    pipe.close()


def test_pipeline_host_sharding_disjoint_and_deterministic():
    src = lambda: SyntheticSource(vocab_size=1000, seed=7)
    a0 = next(DataPipeline(src(), global_batch=8, seq_len=16, host_id=0, num_hosts=2))
    a1 = next(DataPipeline(src(), global_batch=8, seq_len=16, host_id=1, num_hosts=2))
    b0 = next(DataPipeline(src(), global_batch=8, seq_len=16, host_id=0, num_hosts=2))
    assert a0["tokens"].shape == (4, 16)  # local shard
    np.testing.assert_array_equal(a0["tokens"], b0["tokens"])  # deterministic
    assert not np.array_equal(a0["tokens"], a1["tokens"])      # disjoint streams


def test_packing_no_token_loss():
    docs = [np.arange(1, 50, dtype=np.int32), np.arange(100, 140, dtype=np.int32)]
    out = list(pack_documents(iter(docs), batch=1, seq_len=16))
    toks = np.concatenate([b["tokens"].ravel() for b in out])
    assert (toks[:16] == np.arange(1, 17)).all()


# ---------------------------------------------------------------------------
# optimizer / schedule
# ---------------------------------------------------------------------------
def test_warmup_cosine_shape():
    s = warmup_cosine(jnp.arange(0, 100), warmup=10, total=100)
    assert float(s[0]) == 0.0
    assert float(s[10]) == pytest.approx(1.0, abs=1e-3)
    assert float(s[99]) < 0.2


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path):
    ckpt = Checkpointer(tmp_path, keep=2)
    state = {"w": jnp.arange(6.0).reshape(2, 3), "n": jnp.int32(7)}
    for step in (1, 2, 3):
        ckpt.save(step, jax.tree.map(lambda x: x * step, state))
    assert ckpt.latest_step() == 3
    restored, step = ckpt.restore(state)
    assert step == 3
    np.testing.assert_allclose(restored["w"], np.arange(6.0).reshape(2, 3) * 3)
    # retention
    assert len(list(tmp_path.glob("step_*"))) == 2


def test_checkpoint_async_and_atomicity(tmp_path):
    ckpt = Checkpointer(tmp_path)
    state = {"w": jnp.ones((128, 128))}
    ckpt.save_async(5, state)
    ckpt.wait()
    assert ckpt.latest_step() == 5
    assert not list(tmp_path.glob(".tmp_*"))  # no torn temp dirs


def test_checkpoint_elastic_reshard(tmp_path, mesh1):
    """Restore with explicit mesh+specs (the elastic path)."""
    from jax.sharding import PartitionSpec as P

    ckpt = Checkpointer(tmp_path)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(1, state)
    restored, _ = ckpt.restore(state, mesh=mesh1, specs={"w": P("data", None)})
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding.spec == P("data", None)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_supervised_restart_recovers(tmp_path):
    """Inject failures at steps 7 and 12; training must complete all 20
    steps with consistent final state."""
    ckpt = Checkpointer(tmp_path, keep=3)
    failures = {7, 12}
    seen = []

    def step_fn(state, batch):
        step = int(state["step"])
        if step in failures and batch["attempt"][step] == 0:
            batch["attempt"][step] += 1
            raise RuntimeError(f"injected failure at {step}")
        seen.append(step)
        return {"step": state["step"] + 1, "acc": state["acc"] + batch["x"]}, None

    attempts = {s: 0 for s in failures}
    get_batch = lambda i: {"x": float(i), "attempt": attempts}
    init = {"step": jnp.int32(0), "acc": jnp.float32(0.0)}
    report = run_supervised(step_fn, init, get_batch, checkpointer=ckpt,
                            total_steps=20, checkpoint_every=5, max_restarts=5)
    assert report.steps_completed == 20
    assert report.restarts == 2
    assert int(report.final_state["step"]) == 20
    # acc == sum over steps 0..19 exactly once (replays roll back to ckpt,
    # so the acc computed from checkpointed state stays consistent)
    assert float(report.final_state["acc"]) == sum(range(20))


def test_supervisor_gives_up(tmp_path):
    ckpt = Checkpointer(tmp_path)

    def bad_step(state, batch):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        run_supervised(bad_step, {"step": jnp.int32(0)}, lambda i: {},
                       checkpointer=ckpt, total_steps=3, max_restarts=2)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(window=16, straggler_factor=2.0)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 0.5)
    assert not wd.observe(11, 0.11)
    assert wd.stragglers and wd.stragglers[0][0] == 10


# ---------------------------------------------------------------------------
# training end-to-end (tiny) + serving
# ---------------------------------------------------------------------------
def test_train_step_abi_runs_and_descends(mesh1):
    cfg = cfgs.smoke_config("qwen2-0.5b")
    api = build_model(cfg)
    dist = make_dist(mesh1, impl="paxi")
    key = jax.random.PRNGKey(0)
    state = train_loop.init_state(api, key)
    step = train_loop.make_train_step(api, dist, AdamWConfig(lr=5e-3))
    jstep = jax.jit(step)
    batch = make_batch(key, cfg, 4, 32)
    losses = []
    for _ in range(5):
        state, metrics = jstep(state, batch)
        losses.append(float(metrics.loss))
    assert losses[-1] < losses[0], losses  # same batch -> must descend
    assert int(state.step) == 5


def test_train_modes_agree(mesh1):
    """abi-mode and gspmd-mode steps produce the same loss trajectory on a
    1-device mesh (where grad sync is identity)."""
    import dataclasses as dc

    key = jax.random.PRNGKey(1)
    losses = {}
    for mode in ("abi", "gspmd"):
        cfg = cfgs.smoke_config("chatglm3-6b")
        cfg = dc.replace(cfg, parallelism=dc.replace(cfg.parallelism, grad_sync=mode))
        api = build_model(cfg)
        dist = make_dist(mesh1, impl="paxi")
        state = train_loop.init_state(api, key)
        step = jax.jit(train_loop.make_train_step(api, dist, AdamWConfig()))
        batch = make_batch(key, cfg, 2, 16)
        ls = []
        for _ in range(3):
            state, m = step(state, batch)
            ls.append(float(m.loss))
        losses[mode] = ls
    np.testing.assert_allclose(losses["abi"], losses["gspmd"], rtol=1e-4)


def test_train_step_zero1_flat_matches_per_leaf(mesh1):
    """The ZeRO-1 flat layout (init_state given the dist) must produce the
    same loss trajectory as the legacy per-leaf layout on dp=1, driving the
    pooled nonblocking reduce-scatter/all-gather path."""
    from repro.optim.adamw import FlatAdamState

    cfg = cfgs.smoke_config("qwen2-0.5b")
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    batch = make_batch(key, cfg, 4, 32)
    losses = {}
    for layout in ("leaf", "zero1"):
        dist = make_dist(mesh1, impl="paxi")
        state = train_loop.init_state(api, key,
                                      dist=dist if layout == "zero1" else None)
        if layout == "zero1":
            assert isinstance(state.opt, FlatAdamState)
        step = jax.jit(train_loop.make_train_step(api, dist, AdamWConfig(lr=5e-3)))
        ls = []
        for _ in range(4):
            state, m = step(state, batch)
            ls.append(float(m.loss))
        losses[layout] = ls
        assert dist.abi.outstanding_requests == 0
    np.testing.assert_allclose(losses["zero1"], losses["leaf"], rtol=1e-4)
    assert losses["zero1"][-1] < losses["zero1"][0]


def test_serve_engine_greedy_deterministic(mesh1):
    cfg = cfgs.smoke_config("qwen2-0.5b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, max_batch=2, max_seq=64)
    prompt = np.arange(1, 9, dtype=np.int32)
    out1 = eng.generate(prompt, max_new_tokens=8)
    out2 = eng.generate(prompt, max_new_tokens=8)
    assert out1.shape == (8,)
    np.testing.assert_array_equal(out1, out2)  # greedy == deterministic
    assert (out1 >= 0).all() and (out1 < cfg.vocab_size).all()


def test_serve_engine_rwkv_state_path(mesh1):
    cfg = cfgs.smoke_config("rwkv6-7b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, max_seq=64)
    out = eng.generate(np.arange(1, 6, dtype=np.int32), max_new_tokens=4)
    assert out.shape == (4,)
