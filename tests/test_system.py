"""End-to-end behaviour test for the paper's system: data pipeline ->
fault-tolerant ABI training -> checkpoint -> restore -> serve, one flow."""
import numpy as np

import jax
import jax.numpy as jnp

import repro.configs as cfgs
import repro.core as C
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataPipeline, SyntheticSource
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.dist import make_dist
from repro.runtime.fault import run_supervised
from repro.serve.engine import ServeEngine
from repro.train import train_loop


def test_end_to_end_system(tmp_path, mesh1):
    cfg = cfgs.smoke_config("qwen2-0.5b")
    api = build_model(cfg)

    # the ABI with a byte-counting tool stacked (PMPI-style)
    counter = C.ByteCounter()
    dist = make_dist(mesh1, impl="paxi", tools=[counter])

    # data pipeline -> jnp batches, deterministic
    pipe = DataPipeline(SyntheticSource(cfg.vocab_size, seed=3),
                        global_batch=2, seq_len=16)
    batches = [next(pipe) for _ in range(4)]
    pipe.close()
    get_batch = lambda i: {k: jnp.asarray(v) for k, v in batches[i % 4].items()}

    # fault-tolerant training through the ABI train step
    state = train_loop.init_state(api, jax.random.PRNGKey(0))
    step_fn = jax.jit(train_loop.make_train_step(api, dist, AdamWConfig(lr=1e-3)))
    ckpt = Checkpointer(tmp_path, keep=2)
    report = run_supervised(step_fn, state, get_batch, checkpointer=ckpt,
                            total_steps=4, checkpoint_every=2, state_like=state)
    assert report.steps_completed == 4
    assert np.isfinite(report.losses).all()
    assert counter.total() > 0  # the tool observed the grad-sync traffic

    # checkpoint -> restore: states must match bit-for-bit
    restored, step = ckpt.restore(report.final_state)
    assert step == 4
    for a, b in zip(jax.tree.leaves(report.final_state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # serve with the trained weights
    eng = ServeEngine(api, restored.params, max_seq=48, dist=dist)
    out = eng.generate(np.arange(1, 9, dtype=np.int32), max_new_tokens=6)
    assert out.shape == (6,)
    assert ((out >= 0) & (out < cfg.vocab_size)).all()
