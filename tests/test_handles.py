"""Handle-code tests: bit-for-bit fidelity to the paper's Appendix A, plus
hypothesis property tests on the code's invariants."""
import pytest

from _hyp import given, settings, st

from repro.core import handles as H
from repro.core import constants as K


# ---------------------------------------------------------------------------
# Appendix A spot values (exact)
# ---------------------------------------------------------------------------
APPENDIX_A1 = {
    "PAX_OP_NULL": 0b0000100000,
    "PAX_SUM": 0b0000100001,
    "PAX_MIN": 0b0000100010,
    "PAX_MAX": 0b0000100011,
    "PAX_PROD": 0b0000100100,
    "PAX_BAND": 0b0000101000,
    "PAX_BOR": 0b0000101001,
    "PAX_BXOR": 0b0000101010,
    "PAX_LAND": 0b0000110000,
    "PAX_LOR": 0b0000110001,
    "PAX_LXOR": 0b0000110010,
    "PAX_MINLOC": 0b0000111000,
    "PAX_MAXLOC": 0b0000111001,
    "PAX_REPLACE": 0b0000111100,
    "PAX_NO_OP": 0b0000111101,
}
APPENDIX_A2 = {
    "PAX_COMM_NULL": 0b0100000000,
    "PAX_COMM_WORLD": 0b0100000001,
    "PAX_COMM_SELF": 0b0100000010,
    "PAX_GROUP_NULL": 0b0100000100,
    "PAX_GROUP_EMPTY": 0b0100000101,
    "PAX_WIN_NULL": 0b0100001000,
    "PAX_FILE_NULL": 0b0100001100,
    "PAX_SESSION_NULL": 0b0100010000,
    "PAX_MESSAGE_NULL": 0b0100010100,
    "PAX_MESSAGE_NO_PROC": 0b0100010101,
    "PAX_ERRHANDLER_NULL": 0b0100011000,
    "PAX_ERRORS_ARE_FATAL": 0b0100011001,
    "PAX_ERRORS_RETURN": 0b0100011010,
    "PAX_ERRORS_ABORT": 0b0100011011,
    "PAX_REQUEST_NULL": 0b0100100000,
}
APPENDIX_A3 = {
    "PAX_DATATYPE_NULL": 0b1000000000,
    "PAX_AINT": 0b1000000001,
    "PAX_COUNT": 0b1000000010,
    "PAX_OFFSET": 0b1000000011,
    "PAX_PACKED": 0b1000000111,
    "PAX_SHORT": 0b1000001000,
    "PAX_INT": 0b1000001001,
    "PAX_LONG": 0b1000001010,
    "PAX_LONG_LONG": 0b1000001011,
    "PAX_UNSIGNED_SHORT": 0b1000001100,
    "PAX_UNSIGNED_INT": 0b1000001101,
    "PAX_UNSIGNED_LONG": 0b1000001110,
    "PAX_UNSIGNED_LONG_LONG": 0b1000001111,
    "PAX_FLOAT": 0b1000010000,
    "PAX_INT8_T": 0b1001000000,
    "PAX_UINT8_T": 0b1001000001,
    "PAX_CHAR": 0b1001000011,
    "PAX_SIGNED_CHAR": 0b1001000100,
    "PAX_UNSIGNED_CHAR": 0b1001000101,
    "PAX_BYTE": 0b1001000111,
    "PAX_INT16_T": 0b1001001000,
    "PAX_UINT16_T": 0b1001001001,
    "PAX_FLOAT16": 0b1001001010,
    "PAX_INT32_T": 0b1001010000,
    "PAX_UINT32_T": 0b1001010001,
    "PAX_FLOAT32": 0b1001010010,
    "PAX_INT64_T": 0b1001011000,
    "PAX_UINT64_T": 0b1001011001,
    "PAX_FLOAT64": 0b1001011010,
    "PAX_COMPLEX64": 0b1001011011,
}


@pytest.mark.parametrize("table", [APPENDIX_A1, APPENDIX_A2, APPENDIX_A3])
def test_appendix_values_exact(table):
    for name, value in table.items():
        assert getattr(H, name) == value, name


def test_zero_always_invalid():
    assert H.handle_kind(0) == H.HandleKind.INVALID
    assert not H.is_predefined(-1)
    assert H.handle_kind(-5) == H.HandleKind.INVALID


def test_null_handles_are_prefix_then_zeros():
    # each null handle's low bits below its kind-range start are zero
    for kind, null in H.NULL_HANDLES.items():
        assert H.is_null(null)
        assert H.handle_kind(null) == kind
    # e.g. REQUEST_NULL = 0b0100100000: bits after the kind prefix are zero
    assert H.PAX_REQUEST_NULL & 0b11111 == 0
    assert H.PAX_OP_NULL & 0b11111 == 0
    assert H.PAX_DATATYPE_NULL & 0b11111111 == 0


def test_all_predefined_fit_zero_page():
    for value in H.PREDEFINED_NAMES:
        assert 0 < value < H.ZERO_PAGE_SIZE


def test_predefined_unique():
    values = list(H.PREDEFINED_NAMES)
    assert len(values) == len(set(values))


def test_kind_classification_bitmask():
    for name, v in APPENDIX_A1.items():
        assert H.handle_kind(v) == H.HandleKind.OP, name
    for v in APPENDIX_A3.values():
        assert H.handle_kind(v) == H.HandleKind.DATATYPE
    assert H.handle_kind(H.PAX_COMM_WORLD) == H.HandleKind.COMM
    assert H.handle_kind(H.PAX_GROUP_EMPTY) == H.HandleKind.GROUP
    assert H.handle_kind(H.PAX_WIN_NULL) == H.HandleKind.WIN
    assert H.handle_kind(H.PAX_FILE_NULL) == H.HandleKind.FILE
    assert H.handle_kind(H.PAX_SESSION_NULL) == H.HandleKind.SESSION
    assert H.handle_kind(H.PAX_MESSAGE_NO_PROC) == H.HandleKind.MESSAGE
    assert H.handle_kind(H.PAX_ERRORS_RETURN) == H.HandleKind.ERRHANDLER
    assert H.handle_kind(H.PAX_REQUEST_NULL) == H.HandleKind.REQUEST


def test_op_groups():
    """Arithmetic/bit/logical/other ops live in their Appendix A.1 ranges."""
    arith = [H.PAX_SUM, H.PAX_MIN, H.PAX_MAX, H.PAX_PROD]
    assert all(0b0000100001 <= v <= 0b0000100111 for v in arith)
    bits = [H.PAX_BAND, H.PAX_BOR, H.PAX_BXOR]
    assert all(0b0000101000 <= v <= 0b0000101111 for v in bits)
    logic = [H.PAX_LAND, H.PAX_LOR, H.PAX_LXOR]
    assert all(0b0000110000 <= v <= 0b0000110111 for v in logic)


def test_datatype_size_encoding():
    """Fixed-size types encode log2(size) in bits 3..5 (paper §5.4/A.3)."""
    assert H.datatype_encoded_size(H.PAX_BYTE) == 1  # 2^0b000
    assert H.datatype_encoded_size(H.PAX_INT32_T) == 4  # 2^0b010
    assert H.datatype_encoded_size(H.PAX_INT64_T) == 8
    assert H.datatype_encoded_size(H.PAX_FLOAT16) == 2
    assert H.datatype_encoded_size(H.PAX_BFLOAT16) == 2  # TPU extension slot
    assert H.datatype_encoded_size(H.PAX_FLOAT8_E4M3) == 1
    assert H.datatype_encoded_size(H.PAX_COMPLEX128) == 16
    # variable-size types do NOT encode size
    assert H.datatype_is_variable_size(H.PAX_INT)
    with pytest.raises(ValueError):
        H.datatype_log2_size(H.PAX_INT)


def test_describe_names_constants():
    """'tell the user by name what constant they passed' (§5.4)."""
    assert H.describe(H.PAX_SUM) == "PAX_SUM"
    assert H.describe(H.PAX_COMM_WORLD) == "PAX_COMM_WORLD"
    assert "INVALID" in H.describe(0)


def test_room_for_extensions():
    """The code has free space for new handle types and constants (§5.4)."""
    used = set(H.PREDEFINED_NAMES)
    dtype_page = [v for v in range(512, 1024)]
    free_dtypes = [v for v in dtype_page if v not in used]
    assert len(free_dtypes) > 400  # "less than 100 values are used"
    op_range = [v for v in range(32, 64)]
    assert len([v for v in op_range if v not in used]) >= 10


# ---------------------------------------------------------------------------
# Integer constants (§5.4)
# ---------------------------------------------------------------------------
def test_negative_constants_unique():
    values = list(K.unique_negative_constants().values())
    assert len(values) == len(set(values))
    assert all(v < 0 for v in values)


def test_xor_constants_powers_of_two():
    for v in K.xor_constants().values():
        assert v > 0 and (v & (v - 1)) == 0


def test_constants_within_portable_int():
    for name, v in vars(K).items():
        if name.startswith("PAX_") and isinstance(v, int):
            assert abs(v) <= K.PAX_INT_CONSTANT_MAX, name


def test_string_length_constants():
    assert K.PAX_MAX_LIBRARY_VERSION_STRING == 8192


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------
@given(st.integers(min_value=-(2**31), max_value=2**31 - 1))
@settings(max_examples=300)
def test_handle_kind_total(h):
    """Classification is total: never raises, always returns a HandleKind."""
    kind = H.handle_kind(h)
    assert isinstance(kind, H.HandleKind)


@given(
    st.sampled_from([k for k in H.HandleKind if k != H.HandleKind.INVALID]),
    st.integers(min_value=0, max_value=(1 << 24) - 1),
)
@settings(max_examples=200)
def test_user_handle_roundtrip(kind, index):
    h = H.make_user_handle(kind, index)
    assert H.is_user_handle(h)
    assert not H.is_predefined(h)
    assert H.handle_kind(h) == kind
    assert H.user_handle_index(h) == index
    assert h >= H.ZERO_PAGE_SIZE  # never collides with the zero page


@given(st.integers(min_value=0, max_value=H.ZERO_PAGE_SIZE - 1))
@settings(max_examples=300)
def test_zero_page_classification_consistent(h):
    """Within the zero page, any value classified as a fixed-size datatype
    must decode a size; nulls must classify to their kind."""
    kind = H.handle_kind(h)
    if kind == H.HandleKind.DATATYPE and H.datatype_is_fixed_size(h):
        assert H.datatype_encoded_size(h) in (1, 2, 4, 8, 16, 32, 64, 128)
    if H.is_null(h):
        assert kind != H.HandleKind.INVALID


@given(st.integers(min_value=1, max_value=H.ZERO_PAGE_SIZE - 1))
@settings(max_examples=300)
def test_predefined_kinds_match_table(h):
    """Every named predefined handle classifies to the kind its name says."""
    name = H.PREDEFINED_NAMES.get(h)
    if name is None:
        return
    kind = H.handle_kind(h)
    if "COMM" in name:
        assert kind == H.HandleKind.COMM
    elif "REQUEST" in name:
        assert kind == H.HandleKind.REQUEST
    elif "DATATYPE" in name or name in (
        "PAX_AINT", "PAX_COUNT", "PAX_OFFSET", "PAX_PACKED",
    ):
        assert kind == H.HandleKind.DATATYPE
