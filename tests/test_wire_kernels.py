"""Fused ring-wire kernels: interpret-mode parity against the lax oracles.

Contract (see kernels/ring_wire/ref.py):

* int8 quantize, both bf16 hop paths and the pack/unpack gather kernels are
  **bitwise** equal to the unfused lax composition of the same math;
* the int8 hop paths match to one quantum — inside the fused body the
  dequant+add contracts to an FMA (single rounding), which the unfused
  composition cannot express.  That is a property of real fused kernels,
  not an interpret-mode artifact, so the tests encode it rather than
  papering over it with loose tolerances.

Plus the plan-time selection surface (kernel registry, capability tags,
eligibility predicates), the hlo_analysis traffic breakdown that proves
the fusion claim, the flash-attention registry routing, and the XLA-flags
launcher wiring.  Multi-device behaviour (the fused hops inside a real
ring schedule, grad_sync plans at dp=2/8) lives in multidev_battery.py
sections 9/10/12.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.ring_wire import ops, ref
from repro.kernels.ring_wire.kernel import WIRE_BLOCK

KEY = jax.random.PRNGKey(7)
N = 8 * WIRE_BLOCK  # 8 scale blocks


def _vec(key, n=N, scale=3.0):
    return scale * jax.random.normal(key, (n,), jnp.float32)


# ---------------------------------------------------------------------------
# int8 hop kernels vs per-block oracles
# ---------------------------------------------------------------------------
def test_quant_i8_bitwise():
    x = _vec(KEY)
    q, s = ops.quant(x, "int8", interpret=True)
    qr, sr = ref.quant_i8_block(x)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_hop_add_quant_i8_one_quantum():
    k1, k2 = jax.random.split(KEY)
    x, a = _vec(k1), _vec(k2)
    q, s = ops.quant(x, "int8", interpret=True)
    q2, s2 = ops.hop_add_quant(q, s, a, "int8", interpret=True)
    q2r, s2r = ref.hop_add_quant_i8_block(q, s, a)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s2r), rtol=1e-6)
    diff = np.abs(np.asarray(q2, np.int32) - np.asarray(q2r, np.int32))
    assert diff.max() <= 1, f"int8 hop drifted {diff.max()} quanta"


def test_hop_accum_i8_close():
    k1, k2 = jax.random.split(KEY, 2)
    x, a = _vec(k1), _vec(k2)
    q, s = ops.quant(x, "int8", interpret=True)
    out = ops.hop_accum(q, s, a, "int8", interpret=True)
    outr = ref.hop_accum_i8_block(q, s, a)
    assert out.dtype == jnp.float32
    # FMA vs mul-then-add: within one rounding of the largest block scale
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                               atol=float(jnp.max(s)))


def test_int8_end_to_end_error_bounded():
    """Dequantized hop result stays within quantization error of exact f32
    (per-block scales: error <= scale/2 per step, two quantization steps)."""
    k1, k2 = jax.random.split(KEY)
    x, a = _vec(k1), _vec(k2)
    q, s = ops.quant(x, "int8", interpret=True)
    q2, s2 = ops.hop_add_quant(q, s, a, "int8", interpret=True)
    approx = ref.dequant_i8_block(q2, s2)
    exact = x + a
    bound = float(jnp.max(s)) / 2 + float(jnp.max(s2)) / 2 + 1e-6
    assert np.abs(np.asarray(approx - exact)).max() <= bound


# ---------------------------------------------------------------------------
# bf16 hop kernels: bitwise vs the astype composition
# ---------------------------------------------------------------------------
def test_hop_bf16_bitwise():
    k1, k2 = jax.random.split(KEY)
    x, a = _vec(k1), _vec(k2)
    w, none = ops.quant(x, "bf16", interpret=True)
    assert none is None and w.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(w), np.asarray(x.astype(jnp.bfloat16)))

    w2, _ = ops.hop_add_quant(w, None, a, "bf16", interpret=True)
    w2r = (w.astype(jnp.float32) + a).astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(w2), np.asarray(w2r))

    o = ops.hop_accum(w, None, a, "bf16", interpret=True)
    np.testing.assert_array_equal(np.asarray(o),
                                  np.asarray(w.astype(jnp.float32) + a))


# ---------------------------------------------------------------------------
# fused pack/unpack vs the grad_sync bucket helpers (bitwise)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dp,buckets,wire_dtype",
                         [(2, 1, jnp.float32), (2, 2, jnp.float32),
                          (4, 2, jnp.bfloat16), (8, 4, jnp.bfloat16)])
def test_pack_parts_matches_transposed_bucket_parts(dp, buckets, wire_dtype):
    from repro.train.grad_sync import _transposed_bucket_parts

    padded = dp * buckets * 12
    flat = _vec(KEY, padded)
    parts = ops.pack_parts(flat, dp, buckets, wire_dtype, interpret=True)
    refs = _transposed_bucket_parts(flat.astype(wire_dtype), dp, buckets)
    assert len(parts) == buckets
    for p, r in zip(parts, refs):
        assert p.dtype == jnp.dtype(wire_dtype)
        np.testing.assert_array_equal(np.asarray(p), np.asarray(r))


def test_pack_parts_ef_matches_unfused_fold():
    from repro.train.grad_sync import _transposed_bucket_parts

    dp, buckets, padded = 4, 2, 4 * 2 * 24
    k1, k2 = jax.random.split(KEY)
    g, ef = _vec(k1, padded), 0.01 * _vec(k2, padded)
    parts, new_ef = ops.pack_parts_ef(g, ef, dp, buckets, interpret=True)
    y = g + ef
    wire = y.astype(jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(new_ef), np.asarray(y - wire.astype(jnp.float32)))
    for p, r in zip(parts, _transposed_bucket_parts(wire, dp, buckets)):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(r))


def test_unpack_gathers_inverts_pack():
    from repro.train.grad_sync import _interleave_bucket_gathers

    dp, buckets, padded = 4, 4, 4 * 4 * 16
    flat = _vec(KEY, padded)
    parts = ops.pack_parts(flat, dp, buckets, jnp.float32, interpret=True)
    # kernel inverse == helper inverse == identity
    back = ops.unpack_gathers(parts, dp, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))
    np.testing.assert_array_equal(
        np.asarray(_interleave_bucket_gathers(parts, dp)), np.asarray(flat))


# ---------------------------------------------------------------------------
# eligibility predicates + kernel registry + capability tags
# ---------------------------------------------------------------------------
def test_wire_eligible():
    ok = dict(compress="int8", platform="cpu")
    assert ops.wire_eligible((N,), jnp.float32, **ok)
    assert ops.wire_eligible((8, WIRE_BLOCK), jnp.float32, **ok)
    assert not ops.wire_eligible((N,), jnp.float32, compress=None,
                                 platform="cpu")            # uncompressed
    assert not ops.wire_eligible((N - 1,), jnp.float32, **ok)  # % block
    assert not ops.wire_eligible((N,), jnp.bfloat16, **ok)     # payload dtype
    assert not ops.wire_eligible((N,), jnp.float32, compress="int8",
                                 platform="weird")
    # TPU/GPU cap at MAX_WIRE_ELEMS; CPU interpret has no cap
    big = (2 * ops.MAX_WIRE_ELEMS,)
    assert ops.wire_eligible(big, jnp.float32, compress="int8", platform="cpu")
    assert not ops.wire_eligible(big, jnp.float32, compress="int8",
                                 platform="tpu")


def test_pack_eligible():
    assert ops.pack_eligible(64, 4, 2, platform="cpu")
    assert not ops.pack_eligible(63, 4, 2, platform="cpu")   # divisibility
    assert not ops.pack_eligible(64, 4, 2, platform="weird")
    assert not ops.pack_eligible(0, 4, 2, platform="cpu")


def test_registry_modes():
    from repro import kernels as reg

    assert reg.kernel_mode("ring_wire", "cpu") == "pallas"
    assert reg.kernel_mode("ring_wire", "weird") == "lax"
    assert reg.kernel_mode("no_such_kernel", "cpu") == "lax"
    mode, mod = reg.resolve("ring_wire", "cpu")
    assert mode == "pallas" and mod is ops
    mode, fn = reg.resolve("flash_attention", "cpu")
    assert mode == "pallas" and callable(fn)


def test_capabilities_wire_kernel_tag(mesh1):
    import repro.core as C

    caps = C.pax_init(mesh1, impl="ring-int8").capabilities()
    assert caps["reduce_scatter"]["wire_kernel"] == "pallas"
    assert caps["allgather"]["wire_kernel"] == "lax"  # nothing to dequantize
    plain = C.pax_init(mesh1, impl="ring").capabilities()
    assert plain["reduce_scatter"]["wire_kernel"] == "lax"
    # non-ring backends don't grow the tag at all
    paxi = C.pax_init(mesh1, impl="paxi").capabilities()
    assert "wire_kernel" not in paxi["reduce_scatter"]


# ---------------------------------------------------------------------------
# hlo_analysis: the traffic breakdown that proves the fusion claim
# ---------------------------------------------------------------------------
def test_wire_breakdown_fused_vs_lax():
    from repro.core.backends.ring import _quantize
    from repro.launch.hlo_analysis import wire_breakdown

    k1, k2 = jax.random.split(KEY)
    x, a = _vec(k1), _vec(k2)
    q_l, s_l = _quantize(x, "int8")
    q_f, s_f = ops.quant(x, "int8", interpret=True)

    lax_bd = wire_breakdown(lambda q, s, ad: ref.lax_hop_global(q, s, ad),
                            q_l, s_l, a)
    fus_bd = wire_breakdown(
        lambda q, s, ad: ops.hop_add_quant(q, s, ad, "int8", interpret=True),
        q_f, s_f, a)

    # the lax hop materializes dequantize + quantize intermediates
    assert lax_bd.bytes_by_class.get("dequantize", 0) > 0
    assert lax_bd.bytes_by_class.get("quantize", 0) > 0
    # the fused hop materializes NONE — only the kernel outputs
    assert fus_bd.bytes_by_class.get("quantize", 0) == 0
    assert fus_bd.bytes_by_class.get("dequantize", 0) == 0
    assert fus_bd.count_by_class.get("kernel", 0) == 1
    ratio = fus_bd.materialized_bytes / lax_bd.materialized_bytes
    assert ratio <= 0.5, f"fused/lax materialized bytes {ratio:.3f}"


def test_collective_stats_hbm_by_op():
    from repro.launch.hlo_analysis import collective_bytes

    hlo = """
  %p0 = f32[128]{0} parameter(0)
  %ar = f32[128]{0} all-reduce(f32[128]{0} %p0), replica_groups={}
  %ag = f32[256]{0} all-gather(f32[128]{0} %ar), dimensions={0}
"""
    stats = collective_bytes(hlo)
    assert stats.hbm_by_op["all-reduce"] == 2 * 128 * 4  # in + out
    assert stats.hbm_by_op["all-gather"] == (128 + 256) * 4
    assert stats.total_hbm_bytes == sum(stats.hbm_by_op.values())


# ---------------------------------------------------------------------------
# attention_impl routing through the registry
# ---------------------------------------------------------------------------
def test_attention_flash_matches_xla():
    import dataclasses

    from repro.configs.base import ModelConfig
    from repro.models.attention import attention, init_attention

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                      d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2,
                      param_dtype="float32", compute_dtype="float32",
                      attention_impl="flash")
    params = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 128), jnp.float32)
    positions = jnp.arange(128)[None, :].repeat(2, axis=0)
    out_flash, _ = attention(params, x, cfg, positions=positions)
    cfg_xla = dataclasses.replace(cfg, attention_impl="xla")
    out_xla, _ = attention(params, x, cfg_xla, positions=positions)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_xla),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# XLA-flags launcher wiring (satellite: latency-hiding declarative config)
# ---------------------------------------------------------------------------
def test_apply_xla_flags_gpu_set_and_idempotency():
    from repro.configs.base import XLAFlagsConfig, apply_xla_flags

    env = {}
    first = apply_xla_flags(platform="gpu", env=env)
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" in first.split()
    assert "--xla_gpu_enable_pipelined_collectives=true" in first.split()
    # the removed historical spelling must never be emitted (fatal at
    # client creation on the pinned jaxlib)
    assert "--xla_gpu_enable_async_collectives" not in first
    assert apply_xla_flags(platform="gpu", env=env) == first  # idempotent

    # an existing token with the same key wins
    env2 = {"XLA_FLAGS": "--xla_gpu_enable_latency_hiding_scheduler=false"}
    merged = apply_xla_flags(platform="gpu", env=env2).split()
    assert "--xla_gpu_enable_latency_hiding_scheduler=false" in merged
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" not in merged

    # unrelated user flags are preserved
    env3 = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    merged3 = apply_xla_flags(platform="gpu", env=env3).split()
    assert merged3[0] == "--xla_force_host_platform_device_count=8"

    # cpu platform: only `extra` tokens, no GPU flags
    env4 = {}
    cpu = apply_xla_flags(XLAFlagsConfig(extra=("--x=1",)),
                          platform="cpu", env=env4)
    assert cpu == "--x=1"
    assert apply_xla_flags(platform="cpu", env={}) == ""


def test_xla_flags_config_off_values():
    from repro.configs.base import XLAFlagsConfig

    off = XLAFlagsConfig(enable_latency_hiding_scheduler=False)
    toks = off.flags("gpu")
    assert "--xla_gpu_enable_latency_hiding_scheduler=false" in toks
