"""Hypothesis compatibility shim for minimal environments.

When hypothesis is installed, re-exports ``given``/``settings``/``st``
unchanged.  When it is absent, ``given`` turns the property test into a
skip-marked stub so the rest of the suite still runs.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def shim():
                pass

            shim.__name__ = fn.__name__
            shim.__doc__ = fn.__doc__
            return shim

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Accepts any strategy construction; values are never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
