"""ABI context tests on a 1x1 mesh: handle flow, requests, tools, errors,
Mukautuva conversion logic — everything that doesn't need >1 device."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.core as C
from repro.core import handles as H
from repro.core.errors import PAX_ERR_ARG, PAX_ERR_COMM, PAX_ERR_OP, PaxError


def test_init_backends_available(mesh1):
    assert {"paxi", "ompix", "ring", "muk:paxi"} <= set(C.available_backends())


def test_env_var_selection(mesh1, monkeypatch):
    monkeypatch.setenv("PAX_ABI_IMPL", "ring")
    abi = C.pax_init(mesh1)
    assert abi.backend.name == "ring"


def test_unknown_impl_rejected(mesh1):
    with pytest.raises(ValueError):
        C.pax_init(mesh1, impl="openmpi")  # not a thing here


def test_comm_identity(abi1):
    assert abi1.comm_size(C.PAX_COMM_WORLD) == 1
    assert abi1.comm_size(C.PAX_COMM_SELF) == 1
    dp = abi1.comm_from_axes(("data",), "dp")
    assert abi1.comm_size(dp) == 1
    assert H.is_user_handle(dp)
    dup = abi1.comm_dup(dp)
    assert dup != dp and abi1.comm_size(dup) == 1


def test_wrong_handle_kind_named_in_error(abi1):
    with pytest.raises(PaxError) as e:
        abi1.allreduce(jnp.ones(2), C.PAX_COMM_WORLD, C.PAX_COMM_WORLD)  # op<->comm swap
    assert "PAX_COMM_WORLD" in str(e.value)  # names the constant (§5.4)
    with pytest.raises(PaxError):
        abi1.allreduce(jnp.ones(2), C.PAX_SUM, C.PAX_SUM)


def test_comm_null_rejected(abi1):
    with pytest.raises(PaxError) as e:
        abi1.comm_size(C.PAX_COMM_NULL)
    assert e.value.code == PAX_ERR_COMM


def test_self_collectives_identity(abi1):
    x = jnp.arange(6.0)
    assert np.allclose(abi1.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
    assert np.allclose(abi1.allgather(x, C.PAX_COMM_SELF), x)
    assert np.allclose(abi1.bcast(x, 0, C.PAX_COMM_SELF), x)


def test_type_size_through_abi(abi1):
    assert abi1.type_size(C.PAX_FLOAT32) == 4
    assert abi1.type_size(C.PAX_BFLOAT16) == 2
    derived = abi1.type_contiguous(5, C.PAX_FLOAT64)
    assert abi1.type_size(derived) == 40


def test_user_op_roundtrip(abi1):
    op = abi1.op_create(lambda a, b: jnp.maximum(a, b) + 1, name="maxplus")
    assert H.handle_kind(op) == H.HandleKind.OP
    x = jnp.array([1.0, 5.0])
    # over SELF the reduction is identity (single contribution)
    y = abi1.allreduce(x, op, C.PAX_COMM_SELF)
    assert np.allclose(y, x)
    abi1.op_free(op)


def test_requests_lifecycle(mesh1):
    abi = C.pax_init(mesh1, impl="paxi")
    x = jnp.ones(4)
    reqs = [abi.iallreduce(x * i, C.PAX_SUM, C.PAX_COMM_SELF) for i in range(5)]
    assert abi.outstanding_requests == 5
    flag, vals = abi.testall(reqs)
    assert flag and len(vals) == 5
    assert abi.outstanding_requests == 0
    # double-wait raises
    with pytest.raises(PaxError):
        abi.wait(C.Request(reqs[0].handle))
    # REQUEST_NULL wait is a no-op
    from repro.core.abi import REQUEST_NULL

    assert abi.wait(REQUEST_NULL) is None


def test_finalize_with_outstanding_requests(mesh1):
    abi = C.pax_init(mesh1, impl="paxi")
    abi.iallreduce(jnp.ones(2), C.PAX_SUM, C.PAX_COMM_SELF)
    with pytest.raises(PaxError):
        abi.finalize()


def test_status_filled_by_sendrecv(abi1):
    s = C.Status()
    y = abi1.sendrecv(jnp.ones(3), [(0, 0)], C.PAX_COMM_SELF, status=s)
    assert s.ERROR == C.PAX_SUCCESS
    assert np.allclose(y, 1.0)


# ---------------------------------------------------------------------------
# Interposition (§4.8)
# ---------------------------------------------------------------------------
def test_tool_stack_counts_and_bytes(mesh1):
    cc, bc = C.CallCounter(), C.ByteCounter()
    abi = C.pax_init(mesh1, impl="paxi", tools=[cc, bc])
    x = jnp.ones((8, 4), dtype=jnp.float32)
    abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF)
    abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF)
    abi.allgather(x, C.PAX_COMM_SELF)
    assert cc.counts["allreduce"] == 2
    assert cc.counts["allgather"] == 1
    assert bc.bytes["allreduce"] == 2 * 8 * 4 * 4
    assert bc.total() == 3 * 8 * 4 * 4


def test_tools_work_with_every_backend(mesh1):
    """Compiled once against the ABI, reused with different implementations —
    the §4.8 property."""
    for impl in ("paxi", "ring", "ompix", "muk:paxi"):
        cc = C.CallCounter()
        abi = C.pax_init(mesh1, impl=impl, tools=[cc])
        abi.allreduce(jnp.ones(2), C.PAX_SUM, C.PAX_COMM_SELF)
        assert cc.counts["allreduce"] == 1, impl


def test_tool_state_in_reserved_status_fields(mesh1):
    stamper = C.SequenceStamper()
    abi = C.pax_init(mesh1, impl="paxi", tools=[stamper])
    s = C.Status()
    abi.sendrecv(jnp.ones(2), [(0, 0)], C.PAX_COMM_SELF, status=s)
    stamper.stamp(s)
    assert s.get_reserved(0) == stamper.tool_id
    assert s.get_reserved(1) == stamper.seq >= 1
    # public fields untouched by the tool
    assert s.ERROR == C.PAX_SUCCESS


# ---------------------------------------------------------------------------
# Mukautuva translation layer (§6.2) — 1-device-visible behaviour
# ---------------------------------------------------------------------------
def test_mukautuva_handle_conversion_fast_paths(mesh1):
    abi = C.pax_init(mesh1, impl="ompix")
    muk = abi.backend
    assert muk.convention == "foreign"
    # predefined conversions hit the if-chain, not the table
    world = muk._convert_comm(C.PAX_COMM_WORLD)
    assert world is muk.lib.comm_world
    assert muk._convert_op(C.PAX_SUM) is muk.lib.op_globals["OMPIX_SUM"]
    # user comm goes through the table
    dp = abi.comm_from_axes(("data",))
    assert muk._convert_comm(dp) is muk._comm_table[dp]
    with pytest.raises(PaxError):
        muk._convert_comm(H.make_user_handle(H.HandleKind.COMM, 999))


def test_mukautuva_error_translation(mesh1):
    abi = C.pax_init(mesh1, impl="ompix")
    with pytest.raises(PaxError) as e:
        abi.comm_size(C.PAX_COMM_NULL)
    assert e.value.code == PAX_ERR_COMM  # ompix code 72 -> ABI code


def test_mukautuva_type_size_via_impl_lookup(mesh1):
    """Through Mukautuva the size comes from the foreign descriptor chase,
    and must agree with the native bit-encoded answer."""
    muk = C.pax_init(mesh1, impl="ompix")
    nat = C.pax_init(mesh1, impl="paxi")
    for h in (C.PAX_FLOAT32, C.PAX_BFLOAT16, C.PAX_INT64_T, C.PAX_INT, C.PAX_DOUBLE):
        assert muk.type_size(h) == nat.type_size(h), H.describe(h)


def test_mukautuva_callback_trampoline_receives_abi_dtype(mesh1):
    """§6.2: the foreign impl invokes the callback with ITS dtype handle; the
    trampoline must convert back so user code sees the ABI handle."""
    abi = C.pax_init(mesh1, impl="ompix")
    seen = []

    def user_op(a, b, dtype_handle):
        seen.append(dtype_handle)
        return a + b

    op = abi.op_create(user_op, name="spy")
    impl_op = abi.backend._convert_op(op)
    # simulate the implementation invoking the registered callback with its
    # own handle, the way ompix's generic reduction would
    out = impl_op.fn(jnp.ones(2), jnp.ones(2), abi.backend.lib.dtype_globals["OMPIX_FLOAT"])
    assert np.allclose(out, 2.0)
    assert seen == [C.PAX_FLOAT32]  # converted back to the ABI domain


def test_mukautuva_alltoallw_request_map(mesh1):
    """Converted datatype vectors live in the request map until completion
    (the std::map of §6.2), then are freed."""
    abi = C.pax_init(mesh1, impl="ompix")
    mp = abi.comm_from_axes(("model",))
    st_, rt = [C.PAX_FLOAT32], [C.PAX_FLOAT16]
    captured = {}

    def body(blocks):
        req = abi.ialltoallw(blocks, st_, rt, mp)
        captured["held"] = req.temp_state is not None
        (out,) = abi.wait(req)
        captured["freed"] = req.temp_state is None
        return out

    f = abi.shard_region(body, in_specs=P(), out_specs=P())
    out = jax.jit(f)(jnp.ones((1, 4), jnp.float32))
    assert captured["held"], "converted dtype vectors must be held in the request"
    assert captured["freed"], "temporaries must be freed upon completion"
    assert out.dtype == jnp.float16  # per-peer recv-type cast applied
    assert np.allclose(np.asarray(out, dtype=np.float32), 1.0)


def test_retrace_free_backend_swap(mesh1):
    """User code traced against the ABI produces a working computation for
    every backend without modification — the 'recompile-free' property."""
    x = jnp.arange(4.0)

    def user_step(abi):
        f = abi.shard_region(
            lambda v: abi.allreduce(v * 2, C.PAX_SUM, C.PAX_COMM_WORLD),
            in_specs=P(), out_specs=P(),
        )
        return jax.jit(f)(x)

    results = [user_step(C.pax_init(mesh1, impl=i)) for i in ("paxi", "ring", "ompix")]
    for r in results[1:]:
        assert np.allclose(r, results[0])
