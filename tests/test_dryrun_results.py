"""Validates the multi-pod dry-run artifacts (produced by
``python -m repro.launch.dryrun --all``).

These tests assert over whatever cells have been recorded; the cell
*enumeration* test pins the full 40-cell matrix (32 runnable + 8
documented long_500k skips).  Run the sweep first for full coverage.
"""
import json
from pathlib import Path

import pytest

import repro.configs as cfgs

RESULTS = Path(__file__).resolve().parents[1] / "benchmarks" / "results" / "dryrun"


def _cells():
    if not RESULTS.exists():
        return []
    out = []
    for f in sorted(RESULTS.glob("*.json")):
        try:
            out.append((f.name, json.loads(f.read_text())))
        except Exception:
            pass
    return out


def test_cell_matrix_enumeration():
    """10 archs x 4 LM shapes = 40 assigned cells; long_500k is only
    meaningful for the 2 sub-quadratic archs (8x3 + 2x4) => 32 runnable
    cells, 8 skipped-by-design (x2 meshes)."""
    total, runnable = 0, 0
    for arch in cfgs.ARCH_NAMES:
        cfg = cfgs.get_config(arch)
        total += 4
        runnable += len(cfgs.shapes_for(cfg))
    assert total == 40
    assert runnable == 32


@pytest.mark.skipif(not RESULTS.exists() or not list(RESULTS.glob("*.json")),
                    reason="dry-run sweep not yet executed")
def test_recorded_cells_are_healthy():
    cells = _cells()
    bad = [(n, c.get("status")) for n, c in cells
           if c.get("status") not in ("ok", "skipped")]
    assert not bad, f"unhealthy dry-run cells: {bad}"


@pytest.mark.skipif(not RESULTS.exists() or not list(RESULTS.glob("*.json")),
                    reason="dry-run sweep not yet executed")
def test_roofline_terms_present_and_positive():
    for name, c in _cells():
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        assert r["compute_s"] > 0, name
        assert r["memory_s"] > 0, name
        assert r["bottleneck"] in ("compute", "memory", "collective"), name
        assert 0 < r["useful_flops_fraction"] < 2.0, (name, r["useful_flops_fraction"])
        m = c["memory"]
        assert m["argument_bytes"] > 0, name


@pytest.mark.skipif(not RESULTS.exists() or not list(RESULTS.glob("*.json")),
                    reason="dry-run sweep not yet executed")
def test_multipod_cells_shard_the_pod_axis():
    """The 2x16x16 lowering must spread state across 512 chips: per-device
    argument bytes on pod2 must not exceed the pod1 value (state is sharded
    or replicated, never inflated)."""
    by_key = {}
    for name, c in _cells():
        if c.get("status") == "ok":
            by_key[(c["arch"], c["shape"], c["mesh"])] = c
    pairs = 0
    for (arch, shape, mesh), c in by_key.items():
        if mesh != "16x16":
            continue
        c2 = by_key.get((arch, shape, "2x16x16"))
        if c2 is None:
            continue
        pairs += 1
        assert (c2["memory"]["argument_bytes"]
                <= c["memory"]["argument_bytes"] * 1.05), (arch, shape)
    assert pairs >= 1
