"""The declarative function table (abi_spec) and everything generated from
it: PaxABI methods + i* twins, Mukautuva WRAP_* wrappers, init-time
negotiation, the zero-tool fast path, the reverse dtype map, and the new
scan/exscan/alltoallv entry points."""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as C
from repro.core import abi_spec
from repro.core import handles as H
from repro.core.abi import PaxABI
from repro.core.backends.base import Backend
from repro.core.backends.paxi import PaxiBackend
from repro.core.errors import PAX_ERR_UNSUPPORTED_OPERATION, PaxError
from repro.core.mukautuva import MukBackend

ALL_IMPLS = ("paxi", "ring", "ompix", "muk:paxi")


# ---------------------------------------------------------------------------
# the spec drives every layer — no hand-written per-collective dispatch
# ---------------------------------------------------------------------------
def test_every_entry_generated_on_abi():
    for entry in abi_spec.ABI_TABLE:
        fn = getattr(PaxABI, entry.name)
        assert hasattr(fn, "__generated_src__"), entry.name
        if entry.nonblocking:
            ifn = getattr(PaxABI, f"i{entry.name}")
            assert hasattr(ifn, "__generated_src__"), f"i{entry.name}"


def test_every_wrap_generated_on_mukautuva():
    for entry in abi_spec.ABI_TABLE:
        fn = getattr(MukBackend, entry.backend_method)
        assert hasattr(fn, "__generated_src__"), entry.backend_method
        assert entry.impl_name in fn.__generated_src__


def test_no_handwritten_dispatch_methods():
    """The acceptance criterion: every entry-point method on PaxABI and
    MukBackend comes from the spec, not from the class body."""
    for entry in abi_spec.ABI_TABLE:
        assert getattr(PaxABI.__dict__[entry.name], "__generated_src__", None)
        assert getattr(
            MukBackend.__dict__[entry.backend_method], "__generated_src__", None
        )


def test_spec_covers_new_entries():
    names = {e.name for e in abi_spec.ABI_TABLE}
    assert {"scan", "exscan", "alltoallv"} <= names


# ---------------------------------------------------------------------------
# init-time negotiation (the dlsym analogue)
# ---------------------------------------------------------------------------
class _NoTypeSizeBackend(PaxiBackend):
    name = "notypesize"
    type_size = None  # simulate a library that does not export the symbol


def test_negotiation_rejects_missing_required_entry_at_init(mesh1):
    with pytest.raises(PaxError) as e:
        PaxABI(_NoTypeSizeBackend(mesh1))
    assert e.value.code == PAX_ERR_UNSUPPORTED_OPERATION
    assert "type_size" in str(e.value)


class _NoScanBackend(PaxiBackend):
    name = "noscan"
    scan = None  # missing OPTIONAL symbol -> emulated, not rejected


def test_negotiation_emulates_missing_optional_entry(mesh1):
    abi = PaxABI(_NoScanBackend(mesh1))
    assert abi.capabilities()["scan"]["source"] == "emulated"
    x = jnp.arange(4.0)
    assert np.allclose(abi.scan(x, C.PAX_SUM, C.PAX_COMM_SELF), x)


def test_negotiation_resolves_full_table(mesh1):
    for impl in ALL_IMPLS:
        abi = C.pax_init(mesh1, impl=impl)
        assert set(abi._table) == {e.name for e in abi_spec.ABI_TABLE}, impl


def test_base_placeholders_marked_unsupported():
    for entry in abi_spec.ABI_TABLE:
        placeholder = Backend.__dict__[entry.backend_method]
        assert getattr(placeholder, "_pax_unsupported", False), entry.name


# ---------------------------------------------------------------------------
# new entry points, every backend (1-device semantics)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ALL_IMPLS)
def test_scan_exscan_alltoallv_self(mesh1, impl):
    abi = C.pax_init(mesh1, impl=impl)
    x = jnp.arange(6.0)
    # over SELF the prefix is the lone contribution
    assert np.allclose(abi.scan(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
    # exscan convention: rank 0 keeps its input
    assert np.allclose(abi.exscan(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
    y = abi.alltoallv(x, [6], [6], C.PAX_COMM_SELF)
    assert np.allclose(y, x)
    # SPMD restriction: non-uniform counts are rejected loudly, never
    # silently padded or truncated
    with pytest.raises(ValueError):
        abi.alltoallv(x, [6], [4], C.PAX_COMM_SELF)


@pytest.mark.parametrize("impl", ALL_IMPLS)
def test_nonblocking_variants_exist_and_complete(mesh1, impl):
    abi = C.pax_init(mesh1, impl=impl)
    x = jnp.ones(4)
    reqs = [
        abi.iallreduce(x, C.PAX_SUM, C.PAX_COMM_SELF),
        abi.iscan(x, C.PAX_SUM, C.PAX_COMM_SELF),
        abi.iexscan(x, C.PAX_SUM, C.PAX_COMM_SELF),
        abi.ibcast(x, 0, C.PAX_COMM_SELF),
        abi.igather(x, 0, C.PAX_COMM_SELF),
    ]
    assert abi.outstanding_requests == len(reqs)
    flag, vals = abi.testall(reqs)
    assert flag and len(vals) == len(reqs)
    assert abi.outstanding_requests == 0


# ---------------------------------------------------------------------------
# zero-tool fast path vs tool path
# ---------------------------------------------------------------------------
def test_fast_path_equals_tool_path(mesh1):
    x = jnp.arange(8.0)
    fast = C.pax_init(mesh1, impl="paxi")
    cc, bc = C.CallCounter(), C.ByteCounter()
    slow = C.pax_init(mesh1, impl="paxi", tools=[cc, bc])
    for abi in (fast, slow):
        assert np.allclose(abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
        assert np.allclose(abi.scan(x, C.PAX_SUM, C.PAX_COMM_SELF), x)
    # the fast path skipped the tool chain entirely; the tool path counted
    assert cc.counts["allreduce"] == 1 and cc.counts["scan"] == 1
    assert bc.bytes["scan"] == 8 * 4  # byte-accounting rule from the spec


def test_handle_checks_from_declared_domains(mesh1):
    abi = C.pax_init(mesh1, impl="paxi")
    x = jnp.ones(2)
    with pytest.raises(PaxError):
        abi.scan(x, C.PAX_COMM_WORLD, C.PAX_COMM_WORLD)  # op domain violated
    with pytest.raises(PaxError):
        abi.alltoallv(x, [2], [2], C.PAX_SUM)  # comm domain violated


# ---------------------------------------------------------------------------
# Mukautuva: O(1) reverse dtype map
# ---------------------------------------------------------------------------
def test_reverse_dtype_map_predefined(mesh1):
    muk = C.pax_init(mesh1, impl="ompix").backend
    impl_float = muk.lib.dtype_globals["OMPIX_FLOAT"]
    assert muk._dtype_to_abi(impl_float) == C.PAX_FLOAT32  # canonical wins
    impl_i8 = muk.lib.dtype_globals["OMPIX_INT8"]
    assert muk._dtype_to_abi(impl_i8) == C.PAX_INT8_T  # not the CHAR alias


def test_reverse_dtype_map_updated_at_registration(mesh1):
    abi = C.pax_init(mesh1, impl="ompix")
    muk = abi.backend
    derived = abi.type_contiguous(3, C.PAX_FLOAT32)
    impl_obj = muk._dtype_table[derived]
    assert muk._dtype_to_abi(impl_obj) == derived
    # unknown impl handle degrades to DATATYPE_NULL, as before
    from repro.core.backends.ompix import OmpixDatatype

    stray = OmpixDatatype("stray", 4, np.dtype("float32"))
    assert muk._dtype_to_abi(stray) == C.PAX_DATATYPE_NULL


# ---------------------------------------------------------------------------
# WallClockTracer: LIFO timer stack
# ---------------------------------------------------------------------------
def test_wallclock_tracer_stack(mesh1):
    tracer = C.WallClockTracer()
    abi = C.pax_init(mesh1, impl="paxi", tools=[tracer])
    x = jnp.ones(4)
    abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF)
    abi.allgather(x, C.PAX_COMM_SELF)
    assert [f for f, _ in tracer.events] == ["allreduce", "allgather"]
    assert tracer._starts == []  # no leaked timer state
    # a failed call must not leave a stale start behind forever
    with pytest.raises(PaxError):
        abi.allreduce(x, C.PAX_COMM_WORLD, C.PAX_COMM_WORLD)
    abi.allreduce(x, C.PAX_SUM, C.PAX_COMM_SELF)
    assert len(tracer.events) == 3 and tracer._starts == []


# ---------------------------------------------------------------------------
# grad_sync ZeRO-1 through the generated nonblocking path
# ---------------------------------------------------------------------------
def test_zero1_step_bucketed(mesh1):
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.runtime.dist import make_dist
    from repro.train.grad_sync import zero1_step

    dist = make_dist(mesh1, impl="paxi")
    g = jnp.arange(8.0)

    def body(v):
        params, ef = zero1_step(dist, v, lambda s: s * 2.0, buckets=2)
        assert ef is None
        return params

    f = dist.abi.shard_region(body, in_specs=P(), out_specs=P())
    params = jax.jit(f)(g)
    assert np.allclose(params, g * 2.0)  # dp=1: shard == full vector
    assert dist.abi.outstanding_requests == 0
